"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, bucket counts, filter parameters, and data
distributions; counts must match exactly (they're small integers in f32),
sums to float tolerance.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from hypothesis import given, settings, strategies as st

from compile.kernels.filter_hist import filter_hist_pallas
from compile.kernels.ref import filter_hist_ref
from compile.specs import CITIGROUP, GOLDMAN, NEG_INF, QUERY_SPECS


def random_batch(rng, rows, buckets, *, nan_frac=0.0, near_box=None):
    lon = rng.uniform(-74.05, -73.90, rows).astype(np.float32)
    lat = rng.uniform(40.60, 40.90, rows).astype(np.float32)
    if near_box is not None:
        # Half the rows land inside the target box so the filter is exercised.
        k = rows // 2
        lon[:k] = rng.uniform(near_box[0], near_box[1], k).astype(np.float32)
        lat[:k] = rng.uniform(near_box[2], near_box[3], k).astype(np.float32)
    if nan_frac > 0:
        m = rng.random(rows) < nan_frac
        lon[m] = np.nan
        lat[m] = np.nan
    tip = rng.exponential(4.0, rows).astype(np.float32)
    key = rng.integers(-2, buckets + 2, rows).astype(np.int32)
    val = rng.uniform(0.0, 2.0, rows).astype(np.float32)
    return lon, lat, tip, key, val


def run_both(args, **kw):
    got = np.asarray(filter_hist_pallas(*args, **kw))
    want = np.asarray(filter_hist_ref(*args, **{k: v for k, v in kw.items() if k != "block_rows"}))
    return got, want


@settings(max_examples=30, deadline=None)
@given(
    rows=st.sampled_from([64, 128, 512, 1024]),
    buckets=st.sampled_from([1, 6, 24, 90, 180]),
    seed=st.integers(0, 2**31 - 1),
    tip_min=st.sampled_from([NEG_INF, 0.0, 5.0, 10.0]),
)
def test_pallas_matches_ref_random(rows, buckets, seed, tip_min):
    rng = np.random.default_rng(seed)
    args = random_batch(rng, rows, buckets)
    got, want = run_both(
        args, bbox=GOLDMAN, tip_min=tip_min, buckets=buckets, block_rows=64
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    box=st.sampled_from([GOLDMAN, CITIGROUP]),
)
def test_pallas_matches_ref_dense_hits(seed, box):
    # Rows concentrated inside the filter box: exercises real accumulation.
    rng = np.random.default_rng(seed)
    args = random_batch(rng, 512, 24, near_box=box)
    got, want = run_both(args, bbox=box, tip_min=NEG_INF, buckets=24, block_rows=128)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert got[:, 1].sum() > 0, "some rows must pass the filter"


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_nan_padding_rows_never_count(seed):
    rng = np.random.default_rng(seed)
    lon, lat, tip, key, val = random_batch(rng, 256, 8, nan_frac=0.3)
    got, want = run_both(
        (lon, lat, tip, key, val),
        bbox=(float("-inf"), float("inf"), float("-inf"), float("inf")),
        tip_min=NEG_INF,
        buckets=8,
        block_rows=64,
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    valid = (~np.isnan(lon)) & (key >= 0) & (key < 8)
    assert got[:, 1].sum() == pytest.approx(valid.sum())


def test_out_of_range_keys_dropped():
    lon = np.zeros(64, np.float32)
    lat = np.zeros(64, np.float32)
    tip = np.zeros(64, np.float32)
    val = np.ones(64, np.float32)
    key = np.full(64, -1, np.int32)
    key[:4] = 99  # above bucket range too
    got = np.asarray(
        filter_hist_pallas(
            lon, lat, tip, key, val,
            bbox=(-1.0, 1.0, -1.0, 1.0), tip_min=NEG_INF, buckets=4, block_rows=32,
        )
    )
    assert got.sum() == 0.0


def test_multi_block_accumulation_equals_single_block():
    rng = np.random.default_rng(7)
    args = random_batch(rng, 1024, 24, near_box=GOLDMAN)
    multi = np.asarray(
        filter_hist_pallas(*args, bbox=GOLDMAN, tip_min=NEG_INF, buckets=24, block_rows=128)
    )
    single = np.asarray(
        filter_hist_pallas(*args, bbox=GOLDMAN, tip_min=NEG_INF, buckets=24, block_rows=1024)
    )
    np.testing.assert_allclose(multi, single, rtol=1e-5, atol=1e-5)


def test_all_query_spec_constants_work():
    rng = np.random.default_rng(11)
    for spec in QUERY_SPECS:
        args = random_batch(rng, 256, spec.buckets, near_box=spec.bbox if spec.bbox[0] > -75 else None)
        got, want = run_both(
            args, bbox=spec.bbox, tip_min=spec.tip_min, buckets=spec.buckets, block_rows=64
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5, err_msg=spec.name)


def test_exact_counts_small_case():
    # Hand-computed: 3 rows in box, keys 1,1,3; vals 2,3,4; one row outside.
    lon = np.array([0.5, 0.5, 0.5, 9.0], np.float32)
    lat = np.array([0.5, 0.5, 0.5, 0.5], np.float32)
    tip = np.zeros(4, np.float32)
    key = np.array([1, 1, 3, 1], np.int32)
    val = np.array([2.0, 3.0, 4.0, 7.0], np.float32)
    got = np.asarray(
        filter_hist_pallas(
            lon, lat, tip, key, val, bbox=(0.0, 1.0, 0.0, 1.0), tip_min=NEG_INF, buckets=4,
            block_rows=4,
        )
    )
    assert got[1, 0] == 5.0 and got[1, 1] == 2.0
    assert got[3, 0] == 4.0 and got[3, 1] == 1.0
    assert got[0].sum() == 0 and got[2].sum() == 0
