"""L2 correctness: per-query jitted graphs + AOT round-trip shape checks."""

import json
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from compile.aot import lower_all, to_hlo_text
from compile.kernels.ref import filter_hist_ref
from compile.model import all_query_fns, example_args, make_combine_fn, make_query_fn
from compile.specs import DEFAULT_BATCH_ROWS, QUERY_SPECS


def batch(rng, rows):
    return (
        rng.uniform(-74.05, -73.90, rows).astype(np.float32),
        rng.uniform(40.60, 40.90, rows).astype(np.float32),
        rng.exponential(4.0, rows).astype(np.float32),
        rng.integers(0, 24, rows).astype(np.int32),
        np.ones(rows, np.float32),
    )


def test_query_fns_match_ref():
    rng = np.random.default_rng(3)
    rows = 512
    for spec in QUERY_SPECS:
        fn = jax.jit(make_query_fn(spec, block_rows=128))
        lon, lat, tip, _, val = batch(rng, rows)
        key = rng.integers(0, spec.buckets, rows).astype(np.int32)
        (got,) = fn(lon, lat, tip, key, val)
        want = filter_hist_ref(
            lon, lat, tip, key, val, bbox=spec.bbox, tip_min=spec.tip_min, buckets=spec.buckets
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_output_is_one_tuple():
    spec = QUERY_SPECS[1]
    fn = make_query_fn(spec, block_rows=64)
    rng = np.random.default_rng(5)
    lon, lat, tip, key, val = batch(rng, 64)
    out = fn(lon, lat, tip, key, val)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (spec.buckets, 2)


def test_combine_fn_adds():
    fn = jax.jit(make_combine_fn())
    a = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)
    b = jnp.ones((6, 2), jnp.float32)
    (c,) = fn(a, b)
    np.testing.assert_allclose(np.asarray(c), np.asarray(a) + 1.0)


def test_lowering_produces_hlo_text():
    spec = QUERY_SPECS[1]
    fn = jax.jit(make_query_fn(spec))
    lowered = fn.lower(*example_args(1024))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[24,2]" in text, "output histogram shape present"


def test_aot_bundle_manifest_and_files():
    with tempfile.TemporaryDirectory() as d:
        manifest = lower_all(d, batch_rows=1024)
        assert manifest["batch_rows"] == 1024
        # 7 query artifacts + one combine per distinct bucket count.
        distinct_buckets = {s.buckets for s in QUERY_SPECS}
        assert len(manifest["queries"]) == 7 + len(distinct_buckets)
        with open(os.path.join(d, "manifest.json")) as f:
            on_disk = json.load(f)
        assert on_disk == manifest
        for stem in manifest["queries"]:
            path = os.path.join(d, f"{stem}.hlo.txt")
            assert os.path.getsize(path) > 100, stem
        # Every artifact parses as HLO text (spot: contains module header).
        with open(os.path.join(d, "q6_hist.hlo.txt")) as f:
            assert "HloModule" in f.read(200)


def test_all_query_fns_cover_specs():
    fns = all_query_fns(256)
    assert [s.name for s, _, _ in fns] == [s.name for s in QUERY_SPECS]
    assert fns[0][2][0].shape == (256,)
    assert DEFAULT_BATCH_ROWS % 512 == 0, "default batch divides the pallas block"
