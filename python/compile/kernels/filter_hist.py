"""L1 — the fused filter+histogram Pallas kernel.

The hot spot of every Flint query is the same dense loop: test each trip
row against the query's geo box and tip threshold, then scatter-add its
value into a small histogram keyed by a precomputed bucket column. On
GPU one would write this as a warp-per-chunk atomically-accumulating
scatter; the TPU-idiomatic formulation (DESIGN.md §Hardware-Adaptation)
is instead:

* rows are tiled into ``(BLOCK_ROWS,)`` VMEM blocks via ``BlockSpec`` —
  the HBM→VMEM schedule a CUDA kernel would express with threadblocks;
* the scatter becomes a dense one-hot contraction (``eq @ val``), which
  the VPU/MXU execute without atomics — histogram width K ≤ 180 keeps
  the one-hot tile (BLOCK_ROWS × K) small;
* the ``(K, 2)`` accumulator lives in the output block, revisited by
  every grid step (grid-accumulate pattern: zeroed on step 0).

``interpret=True`` is mandatory here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute; interpret mode lowers to
plain HLO ops that run anywhere (and is what ships in the artifacts).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(lon_ref, lat_ref, tip_ref, key_ref, val_ref, out_ref, *, bbox, tip_min, buckets):
    """One grid step: accumulate a row block into the shared output."""
    # Zero the accumulator on the first block.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lon = lon_ref[...]
    lat = lat_ref[...]
    tip = tip_ref[...]
    key = key_ref[...]
    val = val_ref[...]

    lon_min, lon_max, lat_min, lat_max = bbox
    mask = (
        (lon >= lon_min)
        & (lon <= lon_max)
        & (lat >= lat_min)
        & (lat <= lat_max)
        & (tip >= tip_min)
        & (key >= 0)
        & (key < buckets)
    )
    # Dense one-hot contraction instead of scatter: rows × buckets tile in
    # VMEM, reduced along rows. No atomics, fully vectorized.
    onehot = (key[:, None] == jnp.arange(buckets, dtype=jnp.int32)[None, :]) & mask[:, None]
    onehot_f = onehot.astype(jnp.float32)
    sums = jnp.sum(onehot_f * val[:, None], axis=0)  # f32[K]
    counts = jnp.sum(onehot_f, axis=0)  # f32[K]
    out_ref[...] += jnp.stack([sums, counts], axis=1)


def filter_hist_pallas(
    lon, lat, tip, key, val, *, bbox, tip_min, buckets, block_rows=512, interpret=True
):
    """Pallas version of :func:`ref.filter_hist_ref` (same signature plus
    tiling knobs). Rows must be a multiple of ``block_rows``; callers pad
    (the Rust executor always supplies full batches)."""
    rows = lon.shape[0]
    if rows % block_rows != 0:
        # Tests drive odd sizes; fall back to one block covering all rows.
        block_rows = rows
    grid = (rows // block_rows,)

    row_spec = pl.BlockSpec((block_rows,), lambda i: (i,))
    out_spec = pl.BlockSpec((buckets, 2), lambda i: (0, 0))  # revisited per step

    kernel = functools.partial(_kernel, bbox=bbox, tip_min=tip_min, buckets=buckets)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, row_spec, row_spec, row_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((buckets, 2), jnp.float32),
        interpret=interpret,
    )(lon, lat, tip, key, val)
