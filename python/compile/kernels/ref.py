"""Pure-jnp oracle for the fused filter+histogram kernel.

This is the semantic ground truth both implementations are held to:
* the Pallas kernel (``filter_hist.py``) is asserted against it in
  ``python/tests/test_kernel.py`` (hypothesis sweeps), and
* the Rust native kernel implements the same math
  (``rust/src/compute/kernels.rs``), cross-checked end-to-end against the
  Rust oracle.
"""

import jax.numpy as jnp


def filter_hist_ref(lon, lat, tip, key, val, *, bbox, tip_min, buckets):
    """Masked histogram: rows passing the geo/tip filter scatter ``val``
    (and a count of 1) into ``hist[key]``.

    Args:
      lon, lat, tip, val: f32[N]; key: i32[N].
      bbox: (lon_min, lon_max, lat_min, lat_max) — inclusive bounds.
      tip_min: minimum tip (inclusive); -inf disables the filter.
      buckets: K, the histogram width.

    Returns:
      f32[K, 2]: per-bucket (sum of val, count). Rows with key outside
      [0, K) never contribute. NaN coordinates never pass the box test
      (this is how padding rows are masked).
    """
    lon_min, lon_max, lat_min, lat_max = bbox
    mask = (
        (lon >= lon_min)
        & (lon <= lon_max)
        & (lat >= lat_min)
        & (lat <= lat_max)
        & (tip >= tip_min)
        & (key >= 0)
        & (key < buckets)
    )
    # Out-of-range keys clamp to 0 but are masked, so they add nothing.
    safe_key = jnp.clip(key, 0, buckets - 1)
    sums = jnp.zeros((buckets,), jnp.float32).at[safe_key].add(
        jnp.where(mask, val, 0.0)
    )
    counts = jnp.zeros((buckets,), jnp.float32).at[safe_key].add(
        jnp.where(mask, 1.0, 0.0)
    )
    return jnp.stack([sums, counts], axis=1)
