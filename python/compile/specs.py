"""Query specifications — the Python mirror of `rust/src/compute/queries.rs`.

The geo boxes, tip thresholds, bucket counts, and key sources here are
baked as constants into the AOT HLO artifacts, so they MUST match the
Rust definitions bit-for-bit. The end-to-end integration test (Flint
with PJRT vs the Rust oracle) catches any drift.
"""

from dataclasses import dataclass

# Landmark bounding boxes (rust/src/data/schema.rs).
GOLDMAN = (-74.0156, -74.0138, 40.7139, 40.7155)  # lon_min, lon_max, lat_min, lat_max
CITIGROUP = (-74.0124, -74.0106, 40.7189, 40.7205)
EVERYWHERE = (float("-inf"), float("inf"), float("-inf"), float("inf"))

NEG_INF = float("-inf")


@dataclass(frozen=True)
class QuerySpec:
    """One fused filter+histogram kernel configuration."""

    name: str  # artifact stem, e.g. "q1_hist"
    bbox: tuple  # (lon_min, lon_max, lat_min, lat_max)
    tip_min: float
    buckets: int


# Mirrors QueryId::spec() in rust/src/compute/queries.rs. The key/value
# *columns* are prepared by the Rust executor (weather lookup, month×taxi
# composition); the artifact only sees dense (lon, lat, tip, key, val).
QUERY_SPECS = [
    QuerySpec("q0_hist", EVERYWHERE, NEG_INF, 1),
    QuerySpec("q1_hist", GOLDMAN, NEG_INF, 24),
    QuerySpec("q2_hist", CITIGROUP, NEG_INF, 24),
    QuerySpec("q3_hist", GOLDMAN, 10.0, 24),
    QuerySpec("q4_hist", EVERYWHERE, NEG_INF, 90),
    QuerySpec("q5_hist", EVERYWHERE, NEG_INF, 180),
    QuerySpec("q6_hist", EVERYWHERE, NEG_INF, 6),
]

# Static row count per batch (must match flint.batch_rows in Rust config).
DEFAULT_BATCH_ROWS = 8192

# Pallas row-block size: 512 rows × 180 buckets × 4 B one-hot ≈ 360 KiB of
# VMEM for the widest query — comfortably under a TPU core's ~16 MiB (see
# DESIGN.md §Hardware-Adaptation).
DEFAULT_BLOCK_ROWS = 512
