"""L2 — per-query JAX compute graphs.

Each Table I query lowers to one jitted function over a fixed-size
columnar batch, calling the L1 Pallas kernel with the query's constants
(geo box, tip threshold, bucket count) baked in. ``aot.py`` lowers these
once to HLO text; the Rust executors run them via PJRT on every batch.

The function signature is the artifact ABI shared with
``rust/src/runtime/mod.rs``:

    (lon f32[B], lat f32[B], tip f32[B], key i32[B], val f32[B])
        -> (hist f32[K, 2],)
"""

import jax
import jax.numpy as jnp

from compile.kernels.filter_hist import filter_hist_pallas
from compile.kernels.ref import filter_hist_ref
from compile.specs import DEFAULT_BLOCK_ROWS, QUERY_SPECS, QuerySpec


def make_query_fn(spec: QuerySpec, *, block_rows: int = DEFAULT_BLOCK_ROWS, use_pallas=True):
    """Build the batch-processing function for one query."""

    def fn(lon, lat, tip, key, val):
        if use_pallas:
            hist = filter_hist_pallas(
                lon,
                lat,
                tip,
                key,
                val,
                bbox=spec.bbox,
                tip_min=spec.tip_min,
                buckets=spec.buckets,
                block_rows=block_rows,
            )
        else:
            hist = filter_hist_ref(
                lon, lat, tip, key, val, bbox=spec.bbox, tip_min=spec.tip_min, buckets=spec.buckets
            )
        # 1-tuple: the Rust side unwraps with to_tuple1 (return_tuple=True).
        return (hist,)

    fn.__name__ = f"flint_{spec.name}"
    return fn


def make_combine_fn():
    """Reduce-stage partial-histogram combine: (a, b) -> (a + b,).

    Kept as a separate tiny graph so the reduce stage is also PJRT-served
    (DESIGN.md §3); shapes are per-query, so aot.py lowers one per spec.
    """

    def fn(a, b):
        return (a + b,)

    return fn


def example_args(batch_rows: int):
    """ShapeDtypeStructs matching the artifact ABI."""
    f = jax.ShapeDtypeStruct((batch_rows,), jnp.float32)
    i = jax.ShapeDtypeStruct((batch_rows,), jnp.int32)
    return (f, f, f, i, f)


def all_query_fns(batch_rows: int, *, use_pallas=True):
    """(spec, jitted fn, example args) per query."""
    out = []
    for spec in QUERY_SPECS:
        fn = make_query_fn(spec, use_pallas=use_pallas)
        out.append((spec, jax.jit(fn), example_args(batch_rows)))
    return out
