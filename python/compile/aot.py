"""AOT lowering: JAX → HLO text artifacts + manifest.

Run once by ``make artifacts``. Python never executes at query time; the
Rust runtime loads these files through PJRT.

Interchange is HLO **text**, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids, which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile.model import all_query_fns, example_args, make_combine_fn  # noqa: E402
from compile.specs import DEFAULT_BATCH_ROWS, QUERY_SPECS  # noqa: E402

import jax.numpy as jnp  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (return_tuple=True, so
    the Rust side unwraps with ``to_tuple1``)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str, batch_rows: int) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "batch_rows": batch_rows,
        "jax_version": jax.__version__,
        "queries": {},
    }
    for spec, fn, args in all_query_fns(batch_rows):
        lowered = fn.lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{spec.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["queries"][spec.name] = {"buckets": spec.buckets}
        print(f"  {spec.name}: {len(text)} chars -> {path}")

    # Combine graphs (reduce stage), one per distinct bucket count.
    combine = make_combine_fn()
    for buckets in sorted({s.buckets for s in QUERY_SPECS}):
        h = jax.ShapeDtypeStruct((buckets, 2), jnp.float32)
        lowered = jax.jit(combine).lower(h, h)
        name = f"combine_{buckets}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["queries"][name] = {"buckets": buckets}
        print(f"  {name} -> {path}")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument("--batch-rows", type=int, default=DEFAULT_BATCH_ROWS)
    args = ap.parse_args()
    manifest = lower_all(args.out, args.batch_rows)
    print(f"wrote {len(manifest['queries'])} artifacts (batch_rows={args.batch_rows})")


if __name__ == "__main__":
    main()
