//! SQL frontend example: the paper's taxi analytics written as SQL
//! instead of driver programs — lexed, parsed, rewritten (predicate +
//! projection pushdown), cost-planned (broadcast vs shuffle join from
//! table-size estimates), and lowered onto the same `Rdd` lineage API
//! the hand-built queries use. Prints EXPLAIN for each statement, runs
//! it serverlessly, and cross-checks the rows against the
//! single-threaded lineage interpreter.
//!
//! Run: `cargo run --release --example sql_taxi`

use flint::config::FlintConfig;
use flint::data::generate_taxi_dataset;
use flint::exec::FlintContext;
use flint::plan::interp;
use flint::services::SimEnv;

fn main() {
    let mut cfg = FlintConfig::default();
    cfg.artifacts_dir = "artifacts".into();
    cfg.data.object_bytes = 8 * 1024 * 1024;
    cfg.flint.input_split_bytes = 8 * 1024 * 1024;
    cfg.flint.use_pjrt = false;
    let env = SimEnv::new(cfg);
    println!("generating 200k synthetic taxi trips...");
    let ds = generate_taxi_dataset(&env, "trips", 200_000);
    let sc = FlintContext::new(env.clone());
    sc.prewarm();
    // The manifest carries per-object day statistics — the planner's
    // table-size estimates and the engine's split pruning both read it.
    sc.register_manifest(&ds);

    let queries = [
        (
            "drop-offs near Goldman Sachs by hour (Q1)",
            "SELECT hour, COUNT(*) FROM trips \
             WHERE dropoff_lon BETWEEN -74.0156 AND -74.0138 \
             AND dropoff_lat BETWEEN 40.7139 AND 40.7155 \
             GROUP BY hour ORDER BY hour",
        ),
        (
            "trips by precipitation bucket (Q6 — the CBO picks the broadcast join)",
            "SELECT w.bucket, COUNT(*) FROM trips t \
             JOIN weather w ON t.day = w.day \
             GROUP BY w.bucket ORDER BY w.bucket",
        ),
    ];
    for (what, text) in queries {
        println!("=== {what}\n");
        println!("{}", sc.sql_explain(text).expect("explain"));
        let job = sc.sql_job(text).expect("compile");
        let result = job.collect().expect("run");
        println!("{}", result.render());

        // Oracle: the lineage interpreter over the same objects must
        // agree with the serverless engine row-for-row.
        let lines = |bucket: &str, prefix: &str| -> Vec<String> {
            let mut listed = env.s3().list(bucket, prefix).unwrap_or_default();
            listed.sort();
            let mut out = Vec::new();
            for (key, _) in listed {
                if let Ok((obj, _)) = env.s3().get_object(bucket, &key, env.flint_read_profile()) {
                    out.extend(String::from_utf8_lossy(obj.bytes()).lines().map(String::from));
                }
            }
            out
        };
        let expect = job.shape(interp::interpret(&job.rdd, &lines));
        assert_eq!(result.rows, expect, "engine diverged from the interpreter oracle");
        println!("(oracle check passed: {} rows)\n", result.rows.len());
    }
    println!("cumulative simulated cost: ${:.4}", env.cost().total());
}
