//! Domain example: the full NYC-taxi exploratory-analysis session from
//! the paper's §IV — all seven queries on the Flint engine, with their
//! actual analytical answers (the part the paper's blog-post inspiration
//! cared about).
//!
//! Run: `cargo run --release --example taxi_analytics`

use flint::compute::queries::{QueryId, QueryResult};
use flint::compute::value::Value;
use flint::config::FlintConfig;
use flint::data::schema::TripRecord;
use flint::data::{generate_taxi_dataset, INPUT_BUCKET};
use flint::exec::{Engine, FlintContext, FlintEngine};
use flint::services::SimEnv;

fn main() {
    let mut cfg = FlintConfig::default();
    cfg.artifacts_dir = "artifacts".into();
    cfg.data.object_bytes = 8 * 1024 * 1024;
    cfg.flint.input_split_bytes = 8 * 1024 * 1024;
    let env = SimEnv::new(cfg);
    println!("generating 500k synthetic taxi trips...");
    let dataset = generate_taxi_dataset(&env, "trips", 500_000);
    let engine = FlintEngine::new(env.clone());
    engine.prewarm();
    println!(
        "PJRT artifacts: {}\n",
        if engine.uses_pjrt() { "loaded (AOT kernels on the hot path)" } else { "absent (native fallback; run `make artifacts`)" }
    );

    for q in QueryId::ALL {
        let report = engine.run_query(q, &dataset).expect("query");
        println!("=== {} — {}", q, q.description());
        println!("    {}", report.summary());
        match (&report.result, q) {
            (QueryResult::Count(n), _) => println!("    {n} trips total"),
            (QueryResult::Buckets(rows), QueryId::Q4) => {
                // Credit share trend: first vs last year observed.
                let early: Vec<_> = rows.iter().filter(|(k, _, _)| *k < 12).collect();
                let late: Vec<_> = rows.iter().filter(|(k, _, _)| *k >= 78).collect();
                let share = |rs: &[&(i64, f64, f64)]| {
                    let c: f64 = rs.iter().map(|(_, s, _)| s).sum();
                    let n: f64 = rs.iter().map(|(_, _, n)| n).sum();
                    100.0 * c / n.max(1.0)
                };
                println!(
                    "    credit-card share: {:.1}% (2009) -> {:.1}% (2015-16) — the cash->credit flip",
                    share(&early),
                    share(&late)
                );
            }
            (QueryResult::Buckets(rows), QueryId::Q5) => {
                let green: f64 = rows.iter().filter(|(k, _, _)| k % 2 == 1).map(|(_, _, n)| n).sum();
                let yellow: f64 = rows.iter().filter(|(k, _, _)| k % 2 == 0).map(|(_, _, n)| n).sum();
                println!(
                    "    {yellow:.0} yellow vs {green:.0} green trips ({:.1}% green; green cabs launched Aug 2013)",
                    100.0 * green / (green + yellow)
                );
            }
            (QueryResult::Buckets(rows), QueryId::Q6) => {
                println!("    trips by precipitation:");
                let names = ["dry", "trace", "light", "moderate", "heavy", "extreme"];
                for (k, _, n) in rows {
                    println!("      {:9} {n:8.0}", names[*k as usize]);
                }
            }
            (QueryResult::Buckets(rows), _) => {
                let busiest = rows.iter().max_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
                let total: f64 = rows.iter().map(|(_, _, n)| n).sum();
                if let Some((hour, _, n)) = busiest {
                    println!(
                        "    {total:.0} matching drop-offs; busiest hour {hour:02}:00 ({n:.0} trips)"
                    );
                }
            }
        }
        println!();
    }

    // Ad-hoc exploration beyond the published queries goes through the
    // session API: any lineage, same serverless substrate. Here, the
    // passenger-count distribution (no kernel exists for it).
    let sc = FlintContext::new(env.clone());
    let by_passengers = sc
        .text_file(INPUT_BUCKET, "trips/")
        .flat_map(|line| {
            let Some(text) = line.as_str() else { return Vec::new() };
            match TripRecord::parse_csv(text.as_bytes()) {
                Some(r) => vec![Value::pair(
                    Value::I64(r.passenger_count as i64),
                    Value::I64(1),
                )],
                None => Vec::new(),
            }
        })
        .reduce_by_key(8, |a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()));
    println!("=== ad-hoc (session API) — trips by passenger count");
    for pair in by_passengers.collect().expect("ad-hoc query") {
        println!(
            "    {} passenger(s): {}",
            pair.key().as_i64().unwrap(),
            pair.val().as_i64().unwrap()
        );
    }

    println!("\ncumulative simulated cost: ${:.4}", env.cost().total());
}
