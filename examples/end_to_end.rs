//! The end-to-end validation driver (DESIGN.md §7, EXPERIMENTS.md §E2E):
//! proves all layers compose on a real small workload.
//!
//! * generates a ~130 MB / 1 M-trip synthetic TLC dataset into the
//!   simulated S3,
//! * runs every benchmark query on all three engines — Flint's executors
//!   run the **AOT PJRT artifacts** (L1 Pallas kernel → L2 JAX graph →
//!   HLO → Rust) when `make artifacts` has been run,
//! * verifies every engine's answer against the single-threaded oracle,
//! * prints the Table-I-style measured comparison and the paper-scale
//!   extrapolation.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use flint::bench::paper::{estimate, PaperEngine};
use flint::compute::oracle;
use flint::compute::queries::QueryId;
use flint::config::FlintConfig;
use flint::data::{generate_taxi_dataset, INPUT_BUCKET};
use flint::exec::{ClusterEngine, ClusterMode, Engine, FlintContext, FlintEngine};
use flint::services::SimEnv;
use flint::util::human_bytes;

fn main() {
    let trips: u64 = std::env::var("FLINT_E2E_TRIPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000);
    let mut cfg = FlintConfig::default();
    cfg.artifacts_dir = "artifacts".into();
    cfg.data.object_bytes = 16 * 1024 * 1024;
    cfg.flint.input_split_bytes = 8 * 1024 * 1024;

    let env = SimEnv::new(cfg.clone());
    let t0 = std::time::Instant::now();
    println!("[1/4] generating {trips} synthetic TLC trips...");
    let dataset = generate_taxi_dataset(&env, "trips", trips);
    println!(
        "      {} in {} objects ({:.1}s)",
        human_bytes(dataset.total_bytes),
        dataset.num_objects(),
        t0.elapsed().as_secs_f64()
    );

    println!("[2/4] starting engines...");
    let flint = FlintEngine::new(env.clone());
    flint.prewarm();
    let spark = ClusterEngine::new(env.clone(), ClusterMode::Spark);
    let pyspark = ClusterEngine::new(env.clone(), ClusterMode::PySpark);
    println!(
        "      flint kernels: {}",
        if flint.uses_pjrt() {
            "PJRT (AOT Pallas/JAX artifacts)"
        } else {
            "native Rust (run `make artifacts` for the PJRT path)"
        }
    );

    println!("[3/4] running Q0–Q6 on flint / pyspark / spark, verifying vs oracle...");
    let mut failures = 0;
    let mut measured = Vec::new();
    let mut flint_reports = Vec::new();
    for q in QueryId::ALL {
        let expect = oracle::evaluate(&env, &dataset, q);
        let rf = flint.run_query(q, &dataset).expect("flint");
        let rp = pyspark.run_query(q, &dataset).expect("pyspark");
        let rs = spark.run_query(q, &dataset).expect("spark");
        for r in [&rf, &rp, &rs] {
            if !r.result.approx_eq(&expect) {
                eprintln!("  MISMATCH {} on {q}", r.engine);
                failures += 1;
            }
        }
        println!(
            "  {q}: flint {:7.1}s ${:.4} | pyspark {:7.1}s ${:.4} | spark {:7.1}s ${:.4}  [verified]",
            rf.latency_s, rf.cost_usd, rp.latency_s, rp.cost_usd, rs.latency_s, rs.cost_usd
        );
        measured.push((q, rf.latency_s, rp.latency_s, rs.latency_s));
        flint_reports.push(rf);
    }
    assert_eq!(failures, 0, "all engines must agree with the oracle");

    println!("\n[4/4] paper-scale extrapolation (215 GiB / 1.3 B trips):\n");
    println!("|   | Flint | PySpark | Spark |  (paper: Flint/PySpark/Spark) |");
    println!("|---|---|---|---|---|");
    const PAPER: [(f64, f64, f64); 7] = [
        (101.0, 211.0, 188.0),
        (190.0, 316.0, 189.0),
        (203.0, 314.0, 187.0),
        (165.0, 312.0, 188.0),
        (132.0, 225.0, 189.0),
        (159.0, 312.0, 189.0),
        (277.0, 337.0, 191.0),
    ];
    for (i, report) in flint_reports.iter().enumerate() {
        let q = QueryId::ALL[i];
        let f = estimate(q, report, &cfg, &dataset, PaperEngine::Flint);
        let p = estimate(q, report, &cfg, &dataset, PaperEngine::PySpark);
        let s = estimate(q, report, &cfg, &dataset, PaperEngine::Spark);
        println!(
            "| {q} | {:.0}s ${:.2} | {:.0}s ${:.2} | {:.0}s ${:.2} | ({:.0}/{:.0}/{:.0}) |",
            f.0, f.1, p.0, p.1, s.0, s.1, PAPER[i].0, PAPER[i].1, PAPER[i].2
        );
    }

    // The session-style generic API runs the same substrate: a trivial
    // lineage's count must agree with Q0's typed kernel count.
    let sc = FlintContext::new(env.clone());
    let generic_count = sc
        .text_file(INPUT_BUCKET, "trips/")
        .count()
        .expect("session count");
    assert_eq!(generic_count, trips, "FlintContext count == generated trips");
    println!("\nsession-API cross-check: sc.text_file(...).count() == {generic_count}  [verified]");

    println!("\nheadline checks:");
    let q0 = &measured[0];
    println!(
        "  Flint beats PySpark on every query: {}",
        measured.iter().all(|(_, f, p, _)| f < p)
    );
    println!("  Q0 (read-bound) Flint vs Spark: {:.1}s vs {:.1}s", q0.1, q0.3);
    println!("  total simulated spend: ${:.4}", env.cost().total());
    println!("\nEND-TO-END OK — all layers composed, all results verified.");
}
