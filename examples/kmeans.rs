//! Extension (§IV/§VI future work: "a broader range of queries ...
//! iterative algorithms"): k-means over drop-off coordinates, run as a
//! sequence of serverless jobs.
//!
//! Each iteration is one Flint job — assign points to the nearest
//! centroid (map, closure capturing the current centroids), then average
//! per cluster (reduceByKey + driver-side divide). This is exactly how
//! iterative workloads behave on a pay-as-you-go engine with no resident
//! cluster state: the input is re-read from S3 every pass (the cost the
//! paper's future-work section is implicitly worried about), and the
//! example reports how per-iteration cost compares to the one-shot
//! queries.
//!
//! Run: `cargo run --release --example kmeans`

use flint::compute::value::Value;
use flint::config::FlintConfig;
use flint::data::schema::TripRecord;
use flint::data::{generate_taxi_dataset, INPUT_BUCKET};
use flint::exec::FlintContext;
use flint::services::SimEnv;

const K: usize = 4;
const ITERATIONS: usize = 5;

fn main() {
    let mut cfg = FlintConfig::default();
    cfg.artifacts_dir = "artifacts".into();
    cfg.data.object_bytes = 4 * 1024 * 1024;
    cfg.flint.input_split_bytes = 4 * 1024 * 1024;
    let env = SimEnv::new(cfg);
    println!("generating 200k trips...");
    generate_taxi_dataset(&env, "trips", 200_000);
    let sc = FlintContext::new(env.clone());
    sc.prewarm();

    // Initial centroids: spread across Manhattan-ish coordinates.
    let mut centroids: Vec<(f64, f64)> = vec![
        (-74.01, 40.71),
        (-73.99, 40.74),
        (-73.97, 40.77),
        (-73.95, 40.80),
    ];
    println!("k-means, k={K}, {ITERATIONS} serverless jobs:\n");

    for iter in 0..ITERATIONS {
        let cents = centroids.clone();
        let assign = sc
            .text_file(INPUT_BUCKET, "trips/")
            .map(move |line| {
                let Some(text) = line.as_str() else { return Value::Null };
                let Some(r) = TripRecord::parse_csv(text.as_bytes()) else {
                    return Value::Null;
                };
                let (x, y) = (r.dropoff_lon as f64, r.dropoff_lat as f64);
                // Nearest centroid (the closure captures this iteration's
                // centroids — the "serialized code" of the paper).
                let mut best = 0usize;
                let mut best_d = f64::INFINITY;
                for (i, (cx, cy)) in cents.iter().enumerate() {
                    let d = (x - cx) * (x - cx) + (y - cy) * (y - cy);
                    if d < best_d {
                        best_d = d;
                        best = i;
                    }
                }
                Value::pair(
                    Value::I64(best as i64),
                    Value::List(vec![Value::F64(x), Value::F64(y), Value::F64(1.0)]),
                )
            })
            .filter(|v| !matches!(v, Value::Null))
            .reduce_by_key(K, |a, b| {
                let (Value::List(a), Value::List(b)) = (a, b) else { unreachable!() };
                Value::List(vec![
                    Value::F64(a[0].as_f64().unwrap() + b[0].as_f64().unwrap()),
                    Value::F64(a[1].as_f64().unwrap() + b[1].as_f64().unwrap()),
                    Value::F64(a[2].as_f64().unwrap() + b[2].as_f64().unwrap()),
                ])
            });

        let before = env.cost().snapshot();
        let sums = assign.collect().expect("iteration");
        let cost = env.cost().snapshot().since(&before).total();

        let mut shift = 0.0f64;
        let mut sizes = vec![0u64; K];
        for pair in &sums {
            let k = pair.key().as_i64().unwrap() as usize;
            let Value::List(s) = pair.val() else { unreachable!() };
            let n = s[2].as_f64().unwrap().max(1.0);
            let nx = s[0].as_f64().unwrap() / n;
            let ny = s[1].as_f64().unwrap() / n;
            shift += ((nx - centroids[k].0).powi(2) + (ny - centroids[k].1).powi(2)).sqrt();
            centroids[k] = (nx, ny);
            sizes[k] = n as u64;
        }
        println!(
            "  iter {iter}: centroid shift {shift:.5}°, cluster sizes {sizes:?}, job cost ${cost:.4}"
        );
    }

    println!("\nfinal drop-off clusters:");
    for (i, (x, y)) in centroids.iter().enumerate() {
        println!("  cluster {i}: ({x:.4}, {y:.4})");
    }
    println!(
        "\ntotal spend across {ITERATIONS} jobs: ${:.4} — and $0 between them (pay as you go)",
        env.cost().total()
    );
}
