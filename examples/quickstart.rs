//! Quickstart: the paper's Q1 driver program, written against Flint's
//! session-style generic API — a `FlintContext` plays the part of
//! PySpark's `SparkContext`, running on the serverless engine.
//!
//! This is the Rust analogue of the paper's §IV snippet:
//!
//! ```python
//! arr = src.map(lambda x: x.split(',')) \
//!          .filter(lambda x: inside(x, goldman)) \
//!          .map(lambda x: (get_hour(x[2]), 1)) \
//!          .reduceByKey(add, 30) \
//!          .collect()
//! ```
//!
//! Run: `cargo run --release --example quickstart`
//!
//! The engine schedules pipelined by default (§III-A: reducers long-poll
//! while mappers flush; `--set flint.scheduler=barrier` reproduces the
//! paper's serial Σ-makespan clock exactly). Under real serverless
//! variance you would also turn on backup tasks for stragglers:
//! `flint.speculation=on` (+ `flint.speculation.multiplier`,
//! `flint.speculation.quantile`) — see README.md for the knobs and
//! `cargo bench --bench straggler_ablation` for the effect.

use flint::compute::value::Value;
use flint::config::FlintConfig;
use flint::data::schema::{TripRecord, GOLDMAN};
use flint::data::{generate_taxi_dataset, INPUT_BUCKET};
use flint::exec::FlintContext;
use flint::services::SimEnv;

fn main() {
    // A small simulated environment with a fresh synthetic TLC dataset.
    let mut cfg = FlintConfig::default();
    cfg.artifacts_dir = "artifacts".into();
    cfg.data.object_bytes = 4 * 1024 * 1024;
    cfg.flint.input_split_bytes = 4 * 1024 * 1024;
    let env = SimEnv::new(cfg);
    println!("generating 200k synthetic taxi trips into simulated S3...");
    generate_taxi_dataset(&env, "trips", 200_000);

    // The session: `sc` is the SparkContext analogue. Sources come from
    // the context, so the Rdds it hands out are bound to it — actions
    // need no engine parameter.
    let sc = FlintContext::new(env.clone());
    sc.prewarm();

    // The driver program — arbitrary user closures, exactly like
    // PySpark. Everything below is *lazy*: it only grows a lineage.
    let src = sc.text_file(INPUT_BUCKET, "trips/");
    let hourly = src
        .map(|line| {
            // x.split(',') — parse the CSV record.
            let text = line.as_str().expect("text input");
            match TripRecord::parse_csv(text.as_bytes()) {
                Some(r) => Value::List(vec![
                    Value::F64(r.dropoff_lon as f64),
                    Value::F64(r.dropoff_lat as f64),
                    Value::I64(flint::data::chrono::hour_of_day(r.dropoff_ts) as i64),
                ]),
                None => Value::Null,
            }
        })
        .filter(|v| {
            // inside(x, goldman)
            let Value::List(f) = v else { return false };
            GOLDMAN.contains(f[0].as_f64().unwrap() as f32, f[1].as_f64().unwrap() as f32)
        })
        .map(|v| {
            // (get_hour(x[2]), 1)
            let Value::List(f) = v else { unreachable!() };
            Value::pair(f[2].clone(), Value::I64(1))
        })
        .reduce_by_key(30, |a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()));

    // `explain()` shows what the general lineage→DAG compiler
    // (`plan::lower`) made of the lineage: stages cut at the shuffle.
    println!("\ncompiled stage DAG:\n{}", hourly.explain());

    // The action triggers lower + the DAG driver: tasks in simulated
    // Lambdas, shuffle via SQS — pure pay-as-you-go.
    let result = hourly.collect().expect("query");

    println!("Goldman Sachs drop-offs by hour:");
    let mut rows: Vec<(i64, i64)> = result
        .iter()
        .map(|v| (v.key().as_i64().unwrap(), v.val().as_i64().unwrap()))
        .collect();
    rows.sort();
    let max = rows.iter().map(|(_, n)| *n).max().unwrap_or(1);
    for (hour, n) in &rows {
        println!("  {hour:02}:00  {n:5}  {}", "#".repeat((n * 40 / max) as usize));
    }
    println!(
        "\n(ran {} Lambda invocations, {} SQS operations, $0 idle cost — pay as you go)",
        env.metrics().get("lambda.invocations"),
        env.metrics().get("sqs.send_batch") + env.metrics().get("sqs.receive_batch"),
    );
}
