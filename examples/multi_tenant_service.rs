//! Multi-tenant service demo: three tenants share one Flint deployment
//! — one object store, one Lambda pool, one event clock — under
//! weighted fair-share arbitration, and every dollar lands in exactly
//! one tenant's ledger.
//!
//! Run: `cargo run --release --example multi_tenant_service`

use flint::compute::value::Value;
use flint::config::{parse::apply_toml, FlintConfig};
use flint::data::{generate_taxi_dataset, INPUT_BUCKET};
use flint::exec::{FlintContext, FlintService};
use flint::plan::{Action, Rdd};
use flint::services::SimEnv;

/// Dropoff-hour histogram: scan → shuffle → 8-way reduce.
fn hour_histogram(sc: &FlintContext) -> Rdd {
    sc.text_file(INPUT_BUCKET, "trips/")
        .map(|line| {
            let text = line.as_str().expect("text input");
            let hour = flint::data::schema::TripRecord::parse_csv(text.as_bytes())
                .map(|r| flint::data::chrono::hour_of_day(r.dropoff_ts) as i64)
                .unwrap_or(0);
            Value::pair(Value::I64(hour), Value::I64(1))
        })
        .reduce_by_key(8, |a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()))
}

fn main() {
    // The tuning a service operator would ship in flint.toml: weighted
    // fair sharing with a premium tenant, and a bounded admission queue.
    let mut cfg = FlintConfig::default();
    cfg.artifacts_dir = "artifacts".into();
    cfg.data.object_bytes = 2 * 1024 * 1024;
    cfg.flint.input_split_bytes = 1024 * 1024;
    apply_toml(
        &mut cfg,
        "flint.service.policy = \"weighted\"\n\
         flint.service.max_queued = 16\n\
         flint.service.weight.acme = 3.0\n",
    )
    .expect("service config");

    let env = SimEnv::new(cfg);
    println!("[1/3] generating synthetic TLC trips...");
    generate_taxi_dataset(&env, "trips", 200_000);

    let service = FlintService::new(env.clone());
    service.prewarm();

    // Three tenants author lineages through their own sessions, then
    // burst four queries at the shared pool at t = 0.
    println!("[2/3] submitting a 4-query burst from 3 tenants...");
    let acme = service.session("acme");
    let globex = service.session("globex");
    let initech = service.session("initech");
    let hist = hour_histogram(&acme);
    service.submit("acme", &hist, Action::Collect).expect("admit");
    service.submit("acme", &hour_histogram(&acme), Action::Count).expect("admit");
    service.submit("globex", &hour_histogram(&globex), Action::Collect).expect("admit");
    service.submit("initech", &hour_histogram(&initech), Action::Count).expect("admit");

    println!("[3/3] running on the shared clock...\n");
    let report = service.run().expect("service run");

    println!(
        "policy = {}, slots = {}, makespan = {:.2}s, pool idle = {:.2}s\n",
        report.policy.name(),
        report.slots,
        report.makespan_s,
        report.idle_s
    );
    println!("| query | tenant | start (s) | end (s) | latency (s) | cost (USD) |");
    println!("|---|---|---|---|---|---|");
    for q in &report.queries {
        println!(
            "| q{} | {} | {:.2} | {:.2} | {:.2} | {:.4} |",
            q.qid,
            q.tenant,
            q.window.start_s,
            q.window.end_s,
            q.window.latency_s,
            q.cost.total()
        );
    }
    println!("\n{}", report.render_ledgers());
    let ledger_sum: f64 = report.ledgers.values().map(|l| l.total_usd()).sum();
    println!(
        "ledger sum = ${ledger_sum:.4}, pool spend = ${:.4} (conserved)",
        report.run_cost.total()
    );
}
