//! Robustness demo (§VI / experiment A2): SQS at-least-once duplicates,
//! executor crashes, and the 300 s duration cap, all hitting one query —
//! and the answer staying exact.
//!
//! Run: `cargo run --release --example failure_injection`

use flint::compute::oracle;
use flint::compute::queries::QueryId;
use flint::config::FlintConfig;
use flint::data::generate_taxi_dataset;
use flint::exec::{Engine, FlintEngine};
use flint::services::SimEnv;

fn main() {
    let mut cfg = FlintConfig::default();
    cfg.artifacts_dir = "artifacts".into();
    cfg.data.object_bytes = 4 * 1024 * 1024;
    cfg.flint.input_split_bytes = 4 * 1024 * 1024;
    // Hostile conditions: 20% duplicate deliveries, 5% invocation
    // crashes, and a duration cap tight enough to force chaining.
    cfg.sim.sqs_duplicate_prob = 0.20;
    cfg.sim.lambda_failure_prob = 0.05;
    cfg.sim.compute_scale = 50.0; // CPython-era executor speed
    // Margin must cover one batch of scaled compute (the chain check
    // runs between batches).
    cfg.sim.lambda_time_limit_s = 0.6;
    cfg.sim.lambda_chain_margin_s = 0.2;
    cfg.flint.max_task_retries = 8;

    let env = SimEnv::new(cfg);
    println!("generating 300k trips; injecting duplicates (20%), crashes (5%), 0.6s duration cap...");
    let dataset = generate_taxi_dataset(&env, "trips", 300_000);
    let engine = FlintEngine::new(env.clone());
    engine.prewarm();

    let query = QueryId::Q5;
    let expect = oracle::evaluate(&env, &dataset, query);
    let report = engine.run_query(query, &dataset).expect("query survives");

    println!("\n{}", report.summary());
    println!("  chains (duration cap):      {}", report.chains);
    println!("  retries (injected crashes): {}", report.retries);
    println!("  duplicate msgs dropped:     {}", report.duplicates_dropped);
    println!("  sqs messages nacked:        {}", env.metrics().get("sqs.nacked"));
    println!(
        "  result exact despite all of the above: {}",
        report.result.approx_eq(&expect)
    );
    assert!(report.result.approx_eq(&expect));

    // Negative control: §VI dedup off → the same conditions corrupt Q5.
    let mut cfg2 = FlintConfig::default();
    cfg2.artifacts_dir = "artifacts".into();
    cfg2.data.object_bytes = 4 * 1024 * 1024;
    cfg2.flint.input_split_bytes = 4 * 1024 * 1024;
    cfg2.sim.sqs_duplicate_prob = 0.20;
    cfg2.flint.dedup_enabled = false;
    let env2 = SimEnv::new(cfg2);
    let ds2 = generate_taxi_dataset(&env2, "trips", 300_000);
    let engine2 = FlintEngine::new(env2.clone());
    let expect2 = oracle::evaluate(&env2, &ds2, query);
    let r2 = engine2.run_query(query, &ds2).expect("runs");
    println!(
        "\nnegative control (dedup disabled): result exact = {} (expected: false)",
        r2.result.approx_eq(&expect2)
    );
    assert!(!r2.result.approx_eq(&expect2), "duplicates must corrupt without dedup");
    println!("\nFAILURE-INJECTION DEMO OK");
}
