#!/usr/bin/env bash
# Tier-1 gate in one command: release build, the full test suite, and
# the CI smoke benches. The shuffle_ablation smoke run includes the A11
# lineage-cache ablation and drops `BENCH_cache.json` in the repo root,
# so the first toolchain-equipped machine records real cache numbers as
# a side effect of gating. CI calls this script; run it locally before
# pushing to reproduce exactly what CI checks.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
cargo test -q

# Smoke benches are gates, not just measurements: each exits non-zero
# on a modeled-performance regression (speculation, codec, pruning, SQL
# optimizer, exchange, backend auto-selection, fair scheduling, and the
# lineage cache's warm-beats-cold + off-switch identity).
cargo bench --bench straggler_ablation -- --smoke
cargo bench --bench shuffle_ablation -- --smoke
cargo bench --bench concurrency_ablation -- --smoke

echo "tier1: OK (cache ablation numbers in $(pwd)/BENCH_cache.json)"
