//! The plan layer: RDD lineage → physical plan → stage DAG → tasks.
//!
//! Mirrors the Spark machinery Flint plugs into (§III of the paper): a
//! driver program builds an RDD lineage; the DAG scheduler cuts it into
//! stages at wide (shuffle) dependencies; each stage becomes a set of
//! tasks — one per input split or shuffle partition. Unlike the original
//! serial driver, stages form a true **DAG**: each stage carries
//! explicit parent edges, multi-parent stages (unions/cogroups) are
//! expressible, and the engine's scheduler decides per run whether to
//! execute with hard barriers (the Qubole-style S3 backend) or
//! *pipelined* — launching consumers while their producers still flush,
//! the paper's SQS long-polling semantics. Flint "only needs to know
//! about stages and tasks", and so does everything downstream of this
//! module.

pub mod dag;
pub mod rdd;
pub mod task;

pub use dag::{
    build_union_plan, Action, PhysicalPlan, Stage, StageCompute, StageInput, StageOutput,
    UnionBranch,
};
pub use rdd::{DynOp, Rdd};
pub use task::{InputSplit, ResumeState, TaskDescriptor, TaskInput, TaskOutput};

use crate::compute::queries::QueryId;
use crate::config::FlintConfig;
use crate::data::Dataset;

/// Build the physical plan for one of the paper's benchmark queries
/// (the typed kernel fast path).
pub fn kernel_plan(query: QueryId, dataset: &Dataset, config: &FlintConfig) -> PhysicalPlan {
    dag::build_kernel_plan(query, dataset, config)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::services::SimEnv;

    #[test]
    fn q0_is_single_stage_and_q1_is_two() {
        let env = SimEnv::new(FlintConfig::for_tests());
        let ds = crate::data::generate_taxi_dataset(&env, "trips", 2_000);
        let p0 = kernel_plan(QueryId::Q0, &ds, env.config());
        assert_eq!(p0.stages.len(), 1);
        assert!(p0.stages[0].parents.is_empty());
        let p1 = kernel_plan(QueryId::Q1, &ds, env.config());
        assert_eq!(p1.stages.len(), 2);
        assert!(matches!(p1.stages[0].output, StageOutput::Shuffle { partitions: 30, .. }));
        assert!(matches!(p1.stages[1].input, StageInput::Shuffle { partitions: 30 }));
        assert_eq!(p1.stages[1].parents, vec![0]);
        p1.validate().unwrap();
    }
}
