//! The plan layer: RDD lineage → physical plan → stages → tasks.
//!
//! Mirrors the Spark machinery Flint plugs into (§III of the paper): a
//! driver program builds an RDD lineage; the DAG scheduler cuts it into
//! stages at wide (shuffle) dependencies; each stage becomes a set of
//! tasks — one per input split or shuffle partition; the engine's
//! scheduler backend executes stages in order with a barrier between
//! them. Flint "only needs to know about stages and tasks", and so does
//! everything downstream of this module.

pub mod dag;
pub mod rdd;
pub mod task;

pub use dag::{Action, PhysicalPlan, Stage, StageCompute, StageInput, StageOutput};
pub use rdd::{DynOp, Rdd};
pub use task::{InputSplit, ResumeState, TaskDescriptor, TaskInput, TaskOutput};

use crate::compute::queries::QueryId;
use crate::config::FlintConfig;
use crate::data::Dataset;

/// Build the physical plan for one of the paper's benchmark queries
/// (the typed kernel fast path).
pub fn kernel_plan(query: QueryId, dataset: &Dataset, config: &FlintConfig) -> PhysicalPlan {
    dag::build_kernel_plan(query, dataset, config)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::services::SimEnv;

    #[test]
    fn q0_is_single_stage_and_q1_is_two() {
        let env = SimEnv::new(FlintConfig::for_tests());
        let ds = crate::data::generate_taxi_dataset(&env, "trips", 2_000);
        let p0 = kernel_plan(QueryId::Q0, &ds, env.config());
        assert_eq!(p0.stages.len(), 1);
        let p1 = kernel_plan(QueryId::Q1, &ds, env.config());
        assert_eq!(p1.stages.len(), 2);
        assert!(matches!(p1.stages[0].output, StageOutput::Shuffle { partitions: 30, .. }));
        assert!(matches!(p1.stages[1].input, StageInput::Shuffle { partitions: 30 }));
    }
}
