//! The plan layer: RDD lineage → physical plan → stage DAG → tasks.
//!
//! Mirrors the Spark machinery Flint plugs into (§III of the paper): a
//! driver program builds an RDD lineage against a session
//! (`exec::FlintContext`); the general compiler [`lower`] cuts it into
//! stages at wide (shuffle) dependencies; each stage becomes a set of
//! tasks — one per input split or shuffle partition. There is no
//! per-shape lowering: `lower` recurses over *any* lineage graph —
//! arbitrary interleavings of narrow ops, `reduce_by_key`, and
//! `cogroup`/`join` (including reduceByKey downstream of a cogroup),
//! multi-way diamonds, and shared sub-lineages, which plan one stage and
//! fan their shuffle out on multiple DAG edges. Stages form a true
//! **DAG**: each stage carries explicit parent edges, and the engine's
//! scheduler decides per run whether to execute with hard barriers (the
//! Qubole-style S3 backend) or *pipelined* — launching consumers while
//! their producers still flush, the paper's SQS long-polling semantics.
//! Flint "only needs to know about stages and tasks", and so does
//! everything downstream of this module.
//!
//! [`interp`] is the reference semantics: a single-threaded interpreter
//! over the same lineage graph, used as the oracle the distributed
//! execution is tested against.

pub mod dag;
pub mod interp;
pub mod rdd;
pub mod task;

pub use dag::{
    build_kernel_join_plan, build_union_plan, lower, lower_resolved, Action, ActionOut,
    CacheResolution, PhysicalPlan, Stage, StageCompute, StageInput, StageOutput, UnionBranch,
};
pub use rdd::{DynOp, Rdd, SessionBinding, StorageLevel};
pub use task::{CachePart, InputSplit, ResumeState, TaskDescriptor, TaskInput, TaskOutput};

use crate::compute::queries::QueryId;
use crate::config::FlintConfig;
use crate::data::Dataset;

/// Build the physical plan for one of the paper's benchmark queries
/// (the typed kernel fast path).
pub fn kernel_plan(query: QueryId, dataset: &Dataset, config: &FlintConfig) -> PhysicalPlan {
    dag::build_kernel_plan(query, dataset, config)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::services::SimEnv;

    #[test]
    fn q0_is_single_stage_and_q1_is_two() {
        let env = SimEnv::new(FlintConfig::for_tests());
        let ds = crate::data::generate_taxi_dataset(&env, "trips", 2_000);
        let p0 = kernel_plan(QueryId::Q0, &ds, env.config());
        assert_eq!(p0.stages.len(), 1);
        assert!(p0.stages[0].parents.is_empty());
        let p1 = kernel_plan(QueryId::Q1, &ds, env.config());
        assert_eq!(p1.stages.len(), 2);
        assert!(matches!(p1.stages[0].output, StageOutput::Shuffle { partitions: 30, .. }));
        assert!(matches!(p1.stages[1].input, StageInput::Shuffle { partitions: 30 }));
        assert_eq!(p1.stages[1].parents, vec![0]);
        p1.validate().unwrap();
    }

    #[test]
    fn q6j_is_a_four_stage_join_diamond() {
        let env = SimEnv::new(FlintConfig::for_tests());
        let ds = crate::data::generate_taxi_dataset(&env, "trips", 2_000);
        let plan = kernel_plan(QueryId::Q6J, &ds, env.config());
        assert_eq!(plan.stages.len(), 4);
        assert!(matches!(plan.stages[0].compute, StageCompute::KernelScan { .. }));
        assert!(matches!(plan.stages[1].compute, StageCompute::DynScan { .. }));
        assert!(matches!(plan.stages[2].compute, StageCompute::KernelJoin { .. }));
        assert!(matches!(plan.stages[3].compute, StageCompute::KernelReduce { .. }));
        assert_eq!(plan.stages[2].parents, vec![0, 1], "join consumes both scans");
        assert_eq!(plan.stages[3].parents, vec![2]);
        assert!(plan.weather.is_none(), "no broadcast side table: the join ships it");
        assert!(plan.stages[1].num_tasks() >= 1, "weather branch has real splits");
        plan.validate().unwrap();
        let text = plan.explain();
        assert!(text.contains("KernelJoin(Q6J)"), "{text}");
        assert!(text.contains("<- s0, s1"), "{text}");
    }
}
