//! A single-threaded, in-process interpreter over [`Rdd`] lineages —
//! the *reference semantics* of the generic API.
//!
//! Where [`crate::plan::lower`] compiles a lineage to a distributed
//! stage DAG (shuffles, queues, retries, dedup), this module just walks
//! the same node graph and computes the answer directly. The
//! randomized-lineage property tests execute every generated lineage
//! both ways and require the results to match exactly, on every shuffle
//! backend and under both schedulers — so the interpreter is the oracle
//! that pins what "correct" means for arbitrary operator trees.
//!
//! Determinism notes (matching the executor's contracts):
//! * `reduce_by_key` folds values in arrival order; engine and
//!   interpreter only agree when the combine is associative and
//!   commutative — the same requirement Spark places on `reduceByKey`.
//! * each `cogroup` side is sorted into the `Value::total_cmp` total
//!   order, exactly as the executor sorts per-edge value lists (queue
//!   arrival order across producers is racy).
//! * `collect` output is compared order-insensitively; the driver sorts
//!   merged values the same way ([`interpret`] returns them sorted).

use crate::compute::value::Value;
use crate::plan::rdd::{DynOp, Rdd, RddNode};
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

/// Evaluate a lineage against in-memory sources: `lines(bucket, prefix)`
/// returns the text lines a `text_file` of that source would read.
/// Returns the record stream the lineage produces, sorted into the
/// deterministic `total_cmp` order (the same order `collect` reports).
pub fn interpret(rdd: &Rdd, lines: &dyn Fn(&str, &str) -> Vec<String>) -> Vec<Value> {
    let mut memo: HashMap<usize, Vec<Value>> = HashMap::new();
    let mut out = eval(rdd, lines, &mut memo);
    out.sort_by(|a, b| a.total_cmp(b));
    out
}

/// Number of records the lineage produces (the `count` action's oracle).
pub fn interpret_count(rdd: &Rdd, lines: &dyn Fn(&str, &str) -> Vec<String>) -> u64 {
    let mut memo: HashMap<usize, Vec<Value>> = HashMap::new();
    eval(rdd, lines, &mut memo).len() as u64
}

/// Recursive evaluation, memoized on node identity so shared
/// sub-lineages (diamonds) evaluate once — mirroring the compiler's
/// stage sharing, and keeping deep DAGs linear-time.
fn eval(
    rdd: &Rdd,
    lines: &dyn Fn(&str, &str) -> Vec<String>,
    memo: &mut HashMap<usize, Vec<Value>>,
) -> Vec<Value> {
    let key = Arc::as_ptr(&rdd.node) as *const () as usize;
    if let Some(cached) = memo.get(&key) {
        return cached.clone();
    }
    let result = match &*rdd.node {
        RddNode::TextFile { bucket, prefix } => {
            lines(bucket, prefix).into_iter().map(Value::Str).collect()
        }
        RddNode::Narrow { parent, op } => {
            let input = eval(parent, lines, memo);
            let mut out = Vec::with_capacity(input.len());
            let ops = std::slice::from_ref(op);
            for v in input {
                DynOp::apply_chain(ops, v, &mut out);
            }
            out
        }
        RddNode::ReduceByKey { parent, combine, .. } => {
            let input = eval(parent, lines, memo);
            let mut agg: BTreeMap<Vec<u8>, Value> = BTreeMap::new();
            for pair in input {
                let kb = pair.key().encode();
                let val = pair.val().clone();
                match agg.remove(&kb) {
                    Some(prev) => {
                        agg.insert(kb, combine(prev, val));
                    }
                    None => {
                        agg.insert(kb, val);
                    }
                }
            }
            agg.into_iter()
                .map(|(kb, v)| {
                    let (k, _) = Value::decode(&kb).expect("round-trips its own encoding");
                    Value::pair(k, v)
                })
                .collect()
        }
        RddNode::CoGroup { left, right, .. } => {
            let l = eval(left, lines, memo);
            let r = eval(right, lines, memo);
            let mut groups: BTreeMap<Vec<u8>, [Vec<Value>; 2]> = BTreeMap::new();
            for (side, input) in [(0usize, l), (1usize, r)] {
                for pair in input {
                    let kb = pair.key().encode();
                    groups.entry(kb).or_default()[side].push(pair.val().clone());
                }
            }
            groups
                .into_iter()
                .map(|(kb, mut sides)| {
                    let (k, _) = Value::decode(&kb).expect("round-trips its own encoding");
                    for side in &mut sides {
                        side.sort_by(|a, b| a.total_cmp(b));
                    }
                    Value::pair(
                        k,
                        Value::List(sides.into_iter().map(Value::List).collect()),
                    )
                })
                .collect()
        }
        // Caching is a materialization hint, not an operator: the
        // reference semantics see straight through it. Cached engine
        // runs are pinned against this same oracle, which is exactly
        // what makes the cache "semantically invisible".
        RddNode::Cached { parent, .. } => eval(parent, lines, memo),
    };
    memo.insert(key, result.clone());
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src() -> impl Fn(&str, &str) -> Vec<String> {
        |_: &str, prefix: &str| match prefix {
            "l/" => vec!["aa".into(), "bbb".into(), "cc".into()],
            "r/" => vec!["x".into(), "yyy".into()],
            _ => Vec::new(),
        }
    }

    fn pairify(rdd: &Rdd) -> Rdd {
        // (len, 1) pairs.
        rdd.map(|v| {
            let len = v.as_str().map(|s| s.len() as i64).unwrap_or(0);
            Value::pair(Value::I64(len), Value::I64(1))
        })
    }

    #[test]
    fn narrow_and_reduce() {
        let rdd = pairify(&Rdd::text_file("b", "l/")).reduce_by_key(4, |a, b| {
            Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap())
        });
        let out = interpret(&rdd, &src());
        // lengths: 2, 3, 2 -> {2: 2, 3: 1}
        assert_eq!(
            out,
            vec![
                Value::pair(Value::I64(2), Value::I64(2)),
                Value::pair(Value::I64(3), Value::I64(1)),
            ]
        );
        assert_eq!(interpret_count(&rdd, &src()), 2);
    }

    #[test]
    fn cogroup_groups_per_side_sorted() {
        let l = pairify(&Rdd::text_file("b", "l/"));
        let r = pairify(&Rdd::text_file("b", "r/"));
        let out = interpret(&l.cogroup(&r, 2), &src());
        // keys: 2 (left only x2), 3 (left 1, right 1), 1 (right only).
        assert_eq!(out.len(), 3);
        let key3 = out
            .iter()
            .find(|v| v.key().as_i64() == Some(3))
            .expect("key 3 present");
        let Value::List(sides) = key3.val() else { panic!("{key3:?}") };
        assert_eq!(sides.len(), 2);
    }

    #[test]
    fn cache_markers_are_invisible_to_the_oracle() {
        let build = |cached: bool| {
            let base = pairify(&Rdd::text_file("b", "l/"));
            let base = if cached { base.cache() } else { base };
            let summed = base.reduce_by_key(4, |a, b| {
                Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap())
            });
            let summed = if cached { summed.cache() } else { summed };
            summed.map(|v| v)
        };
        assert_eq!(interpret(&build(true), &src()), interpret(&build(false), &src()));
        assert_eq!(interpret_count(&build(true), &src()), 2);
    }

    #[test]
    fn shared_nodes_evaluate_once_but_correctly() {
        let base = pairify(&Rdd::text_file("b", "l/"));
        let a = base.reduce_by_key(2, |a, _| a);
        let b = base.reduce_by_key(2, |_, b| b);
        let joined = a.join(&b, 2);
        let out = interpret(&joined, &src());
        assert_eq!(out.len(), 2, "one joined record per distinct length key: {out:?}");
    }
}
