//! The generic RDD API — a PySpark-flavoured lineage builder over
//! dynamic [`Value`]s, so Flint remains a *general* execution engine
//! (the paper: "since Flint is a Spark execution engine, it supports
//! arbitrary RDD transformations").
//!
//! The benchmarked queries use the typed kernel path (`dag.rs`); this
//! path is exercised by `examples/quickstart.rs` and the generic-plan
//! integration tests.
//!
//! **Serialization substitution** (DESIGN.md §2): real Flint pickles the
//! Python task closure into the Lambda payload. Rust closures cannot be
//! serialized, so a plan's closures live in a process-local registry and
//! the payload carries a plan reference plus an estimated code size — the
//! payload-size *accounting* (and the 6 MB limit machinery) is preserved.

use crate::compute::value::Value;
use std::sync::Arc;

pub type MapFn = Arc<dyn Fn(Value) -> Value + Send + Sync>;
pub type FilterFn = Arc<dyn Fn(&Value) -> bool + Send + Sync>;
pub type FlatMapFn = Arc<dyn Fn(Value) -> Vec<Value> + Send + Sync>;
pub type CombineFn = Arc<dyn Fn(Value, Value) -> Value + Send + Sync>;

/// One narrow transformation in a stage's op chain.
#[derive(Clone)]
pub enum DynOp {
    Map(MapFn),
    Filter(FilterFn),
    FlatMap(FlatMapFn),
}

impl DynOp {
    /// Apply the chain to one record, producing zero or more records.
    pub fn apply_chain(ops: &[DynOp], input: Value, out: &mut Vec<Value>) {
        fn rec(ops: &[DynOp], v: Value, out: &mut Vec<Value>) {
            match ops.first() {
                None => out.push(v),
                Some(DynOp::Map(f)) => rec(&ops[1..], f(v), out),
                Some(DynOp::Filter(p)) => {
                    if p(&v) {
                        rec(&ops[1..], v, out);
                    }
                }
                Some(DynOp::FlatMap(f)) => {
                    for item in f(v) {
                        rec(&ops[1..], item, out);
                    }
                }
            }
        }
        rec(ops, input, out);
    }

    /// Estimated serialized size of this op's "code" — stands in for the
    /// pickled closure bytes in payload accounting.
    pub fn code_bytes(&self) -> u64 {
        2048
    }
}

impl std::fmt::Debug for DynOp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DynOp::Map(_) => f.write_str("Map(<closure>)"),
            DynOp::Filter(_) => f.write_str("Filter(<closure>)"),
            DynOp::FlatMap(_) => f.write_str("FlatMap(<closure>)"),
        }
    }
}

/// RDD lineage node.
pub enum RddNode {
    /// Read text lines from every object under `bucket/prefix`; records
    /// are `Value::Str` lines.
    TextFile { bucket: String, prefix: String },
    Narrow { parent: Rdd, op: DynOp },
    /// Wide dependency: hash-partition pairs by key, combine values.
    ReduceByKey { parent: Rdd, partitions: usize, combine: CombineFn },
    /// Two-sided wide dependency: hash-partition both sides' pairs on
    /// the key; the reduce side groups each key's values *per origin
    /// edge* (the per-parent-tagged shuffle), yielding
    /// `(key, [left_values, right_values])`.
    CoGroup { left: Rdd, right: Rdd, partitions: usize },
}

/// A handle to a lineage node (cheap to clone; lineage is immutable).
#[derive(Clone)]
pub struct Rdd {
    pub node: Arc<RddNode>,
}

impl std::fmt::Debug for Rdd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &*self.node {
            RddNode::TextFile { bucket, prefix } => write!(f, "TextFile({bucket}/{prefix})"),
            RddNode::Narrow { parent, op } => write!(f, "{parent:?} -> {op:?}"),
            RddNode::ReduceByKey { parent, partitions, .. } => {
                write!(f, "{parent:?} -> ReduceByKey({partitions})")
            }
            RddNode::CoGroup { left, right, partitions } => {
                write!(f, "CoGroup({left:?}, {right:?}, {partitions})")
            }
        }
    }
}

impl Rdd {
    /// `sc.textFile("s3://bucket/prefix")`.
    pub fn text_file(bucket: &str, prefix: &str) -> Rdd {
        Rdd {
            node: Arc::new(RddNode::TextFile {
                bucket: bucket.to_string(),
                prefix: prefix.to_string(),
            }),
        }
    }

    pub fn map(&self, f: impl Fn(Value) -> Value + Send + Sync + 'static) -> Rdd {
        Rdd {
            node: Arc::new(RddNode::Narrow { parent: self.clone(), op: DynOp::Map(Arc::new(f)) }),
        }
    }

    pub fn filter(&self, f: impl Fn(&Value) -> bool + Send + Sync + 'static) -> Rdd {
        Rdd {
            node: Arc::new(RddNode::Narrow {
                parent: self.clone(),
                op: DynOp::Filter(Arc::new(f)),
            }),
        }
    }

    pub fn flat_map(&self, f: impl Fn(Value) -> Vec<Value> + Send + Sync + 'static) -> Rdd {
        Rdd {
            node: Arc::new(RddNode::Narrow {
                parent: self.clone(),
                op: DynOp::FlatMap(Arc::new(f)),
            }),
        }
    }

    /// `rdd.reduceByKey(combine, numPartitions)` — records must be pairs.
    pub fn reduce_by_key(
        &self,
        partitions: usize,
        combine: impl Fn(Value, Value) -> Value + Send + Sync + 'static,
    ) -> Rdd {
        assert!(partitions > 0, "reduceByKey needs at least one partition");
        Rdd {
            node: Arc::new(RddNode::ReduceByKey {
                parent: self.clone(),
                partitions,
                combine: Arc::new(combine),
            }),
        }
    }

    /// `a.cogroup(b, numPartitions)` — both sides must emit pairs. Each
    /// result record is `(key, [left_values, right_values])` where each
    /// side's values arrive as a deterministically-sorted `Value::List`
    /// (queue arrival order across producers is racy, so the executor
    /// sorts within each side).
    pub fn cogroup(&self, other: &Rdd, partitions: usize) -> Rdd {
        assert!(partitions > 0, "cogroup needs at least one partition");
        Rdd {
            node: Arc::new(RddNode::CoGroup {
                left: self.clone(),
                right: other.clone(),
                partitions,
            }),
        }
    }

    /// `a.join(b, numPartitions)` — inner equi-join on the pair key:
    /// cogroup plus the per-key cross product, yielding
    /// `(key, (left_value, right_value))` records.
    pub fn join(&self, other: &Rdd, partitions: usize) -> Rdd {
        self.cogroup(other, partitions).flat_map(|v| {
            let key = v.key().clone();
            let Value::List(sides) = v.val() else { return Vec::new() };
            let (Some(Value::List(l)), Some(Value::List(r))) = (sides.first(), sides.get(1))
            else {
                return Vec::new();
            };
            let mut out = Vec::with_capacity(l.len() * r.len());
            for lv in l {
                for rv in r {
                    out.push(Value::pair(key.clone(), Value::pair(lv.clone(), rv.clone())));
                }
            }
            out
        })
    }

    /// When the lineage is `left.cogroup(right, p)` followed only by
    /// narrow ops, return `(left, right, partitions, post_ops)` — the
    /// shape `plan::build_join_plan` lowers. Returns `None` for plain
    /// linear lineages (no cogroup anywhere); panics on shapes the
    /// planner does not support yet (a shuffle downstream of a cogroup).
    pub fn cogroup_shape(&self) -> Option<(Rdd, Rdd, usize, Vec<DynOp>)> {
        let mut post: Vec<DynOp> = Vec::new();
        let mut node = self.clone();
        loop {
            let next = match &*node.node {
                RddNode::TextFile { .. } => return None,
                RddNode::Narrow { parent, op } => {
                    post.push(op.clone());
                    parent.clone()
                }
                RddNode::ReduceByKey { parent, .. } => {
                    assert!(
                        parent.cogroup_shape().is_none(),
                        "a reduceByKey downstream of cogroup is not supported yet: \
                         aggregate inside the cogroup's post ops or collect and fold"
                    );
                    return None;
                }
                RddNode::CoGroup { left, right, partitions } => {
                    post.reverse();
                    return Some((left.clone(), right.clone(), *partitions, post));
                }
            };
            node = next;
        }
    }

    /// Walk the lineage root-ward, returning (source, segments) where
    /// each segment is the narrow op chain between wide deps, and a
    /// segment's `shuffle` is the wide dep *terminating* it (feeding the
    /// next segment).
    pub fn linearize(&self) -> LinearizedLineage {
        enum Event {
            Op(DynOp),
            Shuffle(usize, CombineFn),
        }
        // Collect action-side-first, then replay source-first.
        let mut events: Vec<Event> = Vec::new();
        let mut node = self.clone();
        let source;
        loop {
            match &*node.node {
                RddNode::TextFile { bucket, prefix } => {
                    source = (bucket.clone(), prefix.clone());
                    break;
                }
                RddNode::Narrow { parent, op } => {
                    events.push(Event::Op(op.clone()));
                    node = parent.clone();
                }
                RddNode::ReduceByKey { parent, partitions, combine } => {
                    events.push(Event::Shuffle(*partitions, combine.clone()));
                    node = parent.clone();
                }
                RddNode::CoGroup { .. } => {
                    panic!(
                        "cogroup lineages are planned via Rdd::cogroup_shape / \
                         plan::build_join_plan, not linearize"
                    )
                }
            }
        }
        events.reverse();

        let mut segments: Vec<LineageSegment> = Vec::new();
        let mut current_ops: Vec<DynOp> = Vec::new();
        for ev in events {
            match ev {
                Event::Op(op) => current_ops.push(op),
                Event::Shuffle(partitions, combine) => {
                    segments.push(LineageSegment {
                        ops: std::mem::take(&mut current_ops),
                        shuffle: Some((partitions, combine)),
                    });
                }
            }
        }
        segments.push(LineageSegment { ops: current_ops, shuffle: None });
        LinearizedLineage { source, segments }
    }
}

/// One narrow chain, optionally ending in a shuffle.
pub struct LineageSegment {
    pub ops: Vec<DynOp>,
    /// `Some((partitions, combine))` when the segment ends at a
    /// reduceByKey; the *following* segment starts from its output.
    pub shuffle: Option<(usize, CombineFn)>,
}

/// Lineage flattened into source + segments (source-first order).
pub struct LinearizedLineage {
    pub source: (String, String),
    pub segments: Vec<LineageSegment>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v_i64(v: i64) -> Value {
        Value::I64(v)
    }

    #[test]
    fn chain_application_order() {
        let ops = vec![
            DynOp::Map(Arc::new(|v: Value| Value::I64(v.as_i64().unwrap() + 1))),
            DynOp::Filter(Arc::new(|v: &Value| v.as_i64().unwrap() % 2 == 0)),
            DynOp::FlatMap(Arc::new(|v: Value| {
                let x = v.as_i64().unwrap();
                vec![Value::I64(x), Value::I64(x * 10)]
            })),
        ];
        let mut out = Vec::new();
        DynOp::apply_chain(&ops, v_i64(1), &mut out); // 1+1=2, even, -> [2, 20]
        DynOp::apply_chain(&ops, v_i64(2), &mut out); // 3 is odd -> dropped
        assert_eq!(out, vec![v_i64(2), v_i64(20)]);
    }

    #[test]
    fn linearize_splits_at_shuffles() {
        let rdd = Rdd::text_file("b", "p")
            .map(|v| v)
            .filter(|_| true)
            .reduce_by_key(8, |a, _| a)
            .map(|v| v);
        let lin = rdd.linearize();
        assert_eq!(lin.source, ("b".to_string(), "p".to_string()));
        assert_eq!(lin.segments.len(), 2);
        assert_eq!(lin.segments[0].ops.len(), 2, "map+filter before shuffle");
        assert_eq!(lin.segments[0].shuffle.as_ref().unwrap().0, 8);
        assert_eq!(lin.segments[1].ops.len(), 1, "map after shuffle");
        assert!(lin.segments[1].shuffle.is_none());
    }

    #[test]
    fn two_shuffles_three_segments() {
        let rdd = Rdd::text_file("b", "p")
            .map(|v| v)
            .reduce_by_key(4, |a, _| a)
            .reduce_by_key(2, |a, _| a);
        let lin = rdd.linearize();
        assert_eq!(lin.segments.len(), 3);
        assert_eq!(lin.segments[0].shuffle.as_ref().unwrap().0, 4);
        assert_eq!(lin.segments[1].shuffle.as_ref().unwrap().0, 2);
        assert!(lin.segments[1].ops.is_empty());
    }

    #[test]
    fn cogroup_shape_extracts_branches_and_post_ops() {
        let left = Rdd::text_file("b", "l/").map(|v| v);
        let right = Rdd::text_file("b", "r/");
        let rdd = left.cogroup(&right, 4).map(|v| v).filter(|_| true);
        let (l, r, parts, post) = rdd.cogroup_shape().expect("cogroup shape");
        assert_eq!(parts, 4);
        assert_eq!(post.len(), 2, "narrow ops after the cogroup, source-first");
        assert!(matches!(post[0], DynOp::Map(_)));
        assert!(matches!(post[1], DynOp::Filter(_)));
        assert!(matches!(&*l.node, RddNode::Narrow { .. }));
        assert!(matches!(&*r.node, RddNode::TextFile { .. }));
        // Plain lineages have no cogroup shape.
        assert!(Rdd::text_file("b", "p").map(|v| v).cogroup_shape().is_none());
    }

    #[test]
    fn join_post_op_expands_cross_product() {
        // join = cogroup + flatMap; feed the flatMap a synthetic cogroup
        // record and check the inner-join expansion.
        let joined = Rdd::text_file("b", "l/").join(&Rdd::text_file("b", "r/"), 2);
        let (_, _, _, post) = joined.cogroup_shape().expect("join is a cogroup shape");
        assert_eq!(post.len(), 1);
        let record = Value::pair(
            Value::I64(7),
            Value::List(vec![
                Value::List(vec![Value::I64(1), Value::I64(2)]),
                Value::List(vec![Value::str("a")]),
            ]),
        );
        let mut out = Vec::new();
        DynOp::apply_chain(&post, record, &mut out);
        assert_eq!(out.len(), 2, "2 left x 1 right");
        assert_eq!(out[0], Value::pair(Value::I64(7), Value::pair(Value::I64(1), Value::str("a"))));
        // An empty side joins to nothing (inner join).
        let empty = Value::pair(
            Value::I64(8),
            Value::List(vec![Value::List(vec![Value::I64(1)]), Value::List(Vec::new())]),
        );
        let mut none = Vec::new();
        DynOp::apply_chain(&post, empty, &mut none);
        assert!(none.is_empty());
    }

    #[test]
    #[should_panic(expected = "not supported yet")]
    fn reduce_by_key_after_cogroup_panics() {
        let rdd = Rdd::text_file("b", "l/")
            .cogroup(&Rdd::text_file("b", "r/"), 2)
            .reduce_by_key(2, |a, _| a);
        let _ = rdd.cogroup_shape();
    }

    #[test]
    fn map_only_lineage_is_one_segment() {
        let rdd = Rdd::text_file("b", "p").map(|v| v);
        let lin = rdd.linearize();
        assert_eq!(lin.segments.len(), 1);
        assert!(lin.segments[0].shuffle.is_none());
    }
}
