//! The generic RDD API — a PySpark-flavoured lineage builder over
//! dynamic [`Value`]s, so Flint remains a *general* execution engine
//! (the paper: "since Flint is a Spark execution engine, it supports
//! arbitrary RDD transformations").
//!
//! Lineages are built lazily: transformations (`map`, `filter`,
//! `flat_map`, `reduce_by_key`, `cogroup`, the `join` family) only grow
//! an immutable node graph. Actions (`collect`, `count`, `reduce`,
//! `take`, `save_as_text_file`) hand the lineage to the general
//! compiler [`crate::plan::lower`], which cuts it into a stage DAG at
//! wide dependencies — *any* interleaving of narrow and wide ops is
//! planned, including reduceByKey downstream of a cogroup and diamonds
//! that share a sub-lineage — and the bound session executes the plan.
//! [`Rdd::explain`] renders the compiled DAG without running it.
//!
//! An `Rdd` is *bound to a session*: [`crate::exec::FlintContext`]
//! installs a [`SessionBinding`] when it creates sources, and every
//! transformation threads the binding through, so `rdd.collect()` needs
//! no engine parameter — exactly the PySpark driver experience. Lineages
//! built with the free [`Rdd::text_file`] are unbound (useful for
//! engine-agnostic cross-checks via `FlintContext::collect`); calling an
//! action on them is an error, not a panic.
//!
//! The benchmarked queries use the typed kernel path (`dag.rs`); this
//! path is exercised by `examples/quickstart.rs` and the generic-plan
//! integration tests.
//!
//! **Serialization substitution** (DESIGN.md §2): real Flint pickles the
//! Python task closure into the Lambda payload. Rust closures cannot be
//! serialized, so a plan's closures live in a process-local registry and
//! the payload carries a plan reference plus an estimated code size — the
//! payload-size *accounting* (and the 6 MB limit machinery) is preserved.

use crate::compute::value::Value;
use crate::plan::dag::{self, Action, ActionOut, PhysicalPlan};
use crate::plan::task::InputSplit;
use anyhow::{anyhow, Result};
use std::sync::Arc;

pub type MapFn = Arc<dyn Fn(Value) -> Value + Send + Sync>;
pub type FilterFn = Arc<dyn Fn(&Value) -> bool + Send + Sync>;
pub type FlatMapFn = Arc<dyn Fn(Value) -> Vec<Value> + Send + Sync>;
pub type CombineFn = Arc<dyn Fn(Value, Value) -> Value + Send + Sync>;

/// One narrow transformation in a stage's op chain.
#[derive(Clone)]
pub enum DynOp {
    Map(MapFn),
    Filter(FilterFn),
    FlatMap(FlatMapFn),
    /// Typed dropoff-day predicate over raw CSV trip lines (inclusive
    /// day indexes since 2009-01-01). Unlike an opaque `Filter` closure,
    /// the planner and executor can *see* this predicate, so a scan
    /// whose chain leads with it prunes whole splits via manifest stats
    /// before fetching them. Non-line or unparsable records are dropped.
    DayRange { min_day: i32, max_day: i32 },
}

/// Dropoff-day index of a raw CSV trip line (field 2), if parsable.
fn line_day_index(line: &str) -> Option<i32> {
    let field = line.split(',').nth(2)?;
    crate::data::chrono::parse_datetime(field.as_bytes())
        .map(crate::data::chrono::day_index)
}

impl DynOp {
    /// Apply the chain to one record, producing zero or more records.
    pub fn apply_chain(ops: &[DynOp], input: Value, out: &mut Vec<Value>) {
        fn rec(ops: &[DynOp], v: Value, out: &mut Vec<Value>) {
            match ops.first() {
                None => out.push(v),
                Some(DynOp::Map(f)) => rec(&ops[1..], f(v), out),
                Some(DynOp::Filter(p)) => {
                    if p(&v) {
                        rec(&ops[1..], v, out);
                    }
                }
                Some(DynOp::FlatMap(f)) => {
                    for item in f(v) {
                        rec(&ops[1..], item, out);
                    }
                }
                Some(DynOp::DayRange { min_day, max_day }) => {
                    let keep = v
                        .as_str()
                        .and_then(line_day_index)
                        .is_some_and(|d| (*min_day..=*max_day).contains(&d));
                    if keep {
                        rec(&ops[1..], v, out);
                    }
                }
            }
        }
        rec(ops, input, out);
    }

    /// The day predicate a scan may prune with: the intersection of every
    /// `DayRange` op reachable from the head of the chain through other
    /// line-preserving ops. A `DayRange` commutes past a preceding opaque
    /// `Filter`: a filter only *drops* records, so the survivors are
    /// still the raw CSV lines the manifest statistics describe, and a
    /// split disjoint from the range produces nothing either way. The
    /// walk stops at `Map`/`FlatMap` — behind those the records are no
    /// longer raw lines, so a later range says nothing about the split.
    pub fn leading_day_range(ops: &[DynOp]) -> Option<(i32, i32)> {
        let mut range: Option<(i32, i32)> = None;
        for op in ops {
            let (min_day, max_day) = match op {
                DynOp::DayRange { min_day, max_day } => (min_day, max_day),
                DynOp::Filter(_) => continue,
                DynOp::Map(_) | DynOp::FlatMap(_) => break,
            };
            range = Some(match range {
                None => (*min_day, *max_day),
                Some((lo, hi)) => (lo.max(*min_day), hi.min(*max_day)),
            });
        }
        range
    }

    /// Estimated serialized size of this op's "code" — stands in for the
    /// pickled closure bytes in payload accounting. Sized per op kind
    /// (a pickled flatMap generator closes over more than a predicate
    /// does); a stage's chain sums these, so a long chain grows the
    /// payload linearly and eventually trips the 6 MB limit machinery's
    /// S3 spill path exactly like a fat real closure would.
    pub fn code_bytes(&self) -> u64 {
        match self {
            DynOp::Map(_) => 1_792,
            DynOp::Filter(_) => 1_024,
            DynOp::FlatMap(_) => 2_560,
            // A structured predicate: two ints plus op kind, no closure
            // environment to pickle.
            DynOp::DayRange { .. } => 192,
        }
    }
}

impl std::fmt::Debug for DynOp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DynOp::Map(_) => f.write_str("Map(<closure>)"),
            DynOp::Filter(_) => f.write_str("Filter(<closure>)"),
            DynOp::FlatMap(_) => f.write_str("FlatMap(<closure>)"),
            DynOp::DayRange { min_day, max_day } => {
                write!(f, "DayRange({min_day}..={max_day})")
            }
        }
    }
}

/// Where a cached cut's partitions may live — Spark's `StorageLevel`,
/// reduced to the tiers this engine models. The effective tier is the
/// intersection of this per-node request and the global
/// `flint.cache.tier` policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorageLevel {
    /// Warm-container memory tier only: partitions survive while the
    /// builder's containers stay warm, vanish on cold starts.
    Memory,
    /// Committed S3 objects only (always durable, always a GET away).
    S3,
    /// Both: S3 for durability, warm-container memory for speed.
    MemoryAndS3,
}

/// RDD lineage node.
pub enum RddNode {
    /// Read text lines from every object under `bucket/prefix`; records
    /// are `Value::Str` lines.
    TextFile { bucket: String, prefix: String },
    Narrow { parent: Rdd, op: DynOp },
    /// Wide dependency: hash-partition pairs by key, combine values.
    ReduceByKey { parent: Rdd, partitions: usize, combine: CombineFn },
    /// Two-sided wide dependency: hash-partition both sides' pairs on
    /// the key; the reduce side groups each key's values *per origin
    /// edge* (the per-parent-tagged shuffle), yielding
    /// `(key, [left_values, right_values])`.
    CoGroup { left: Rdd, right: Rdd, partitions: usize },
    /// Persistence marker (`rdd.cache()` / `rdd.persist(level)`).
    /// Semantically the identity — the interpreter evaluates straight
    /// through it — but the action path may *cut* here: a resolved
    /// cache entry replaces the whole sub-lineage below with a
    /// `CachedScan` over materialized partitions. Unresolved markers
    /// (cache disabled, eviction, no session) are transparent.
    Cached { parent: Rdd, level: StorageLevel },
}

/// What a session installs on the `Rdd`s it creates: how to resolve a
/// source's input splits and how to execute a compiled plan. Implemented
/// by `exec::FlintContext` for both the serverless engine and the
/// cluster baselines.
pub trait SessionBinding: Send + Sync {
    /// Input splits for a `text_file` source (typically an object-store
    /// listing of `bucket/prefix`).
    fn input_splits(&self, bucket: &str, prefix: &str) -> Vec<InputSplit>;
    /// Execute a compiled physical plan, returning the action's merged
    /// output.
    fn execute(&self, plan: &PhysicalPlan) -> Result<ActionOut>;
    /// Resolve the cached cut points of `rdd` before an action lowers
    /// it: build or look up materialized partitions for every `Cached`
    /// node this session's cache policy admits. The default (unbound
    /// lineages, engines without a cache) resolves nothing, leaving
    /// every `Cached` marker transparent.
    fn resolve_cache(&self, _rdd: &Rdd) -> dag::CacheResolution {
        dag::CacheResolution::default()
    }
}

/// A handle to a lineage node (cheap to clone; lineage is immutable).
/// Carries the session binding installed by the `FlintContext` that
/// created its source, so actions execute without an engine parameter.
#[derive(Clone)]
pub struct Rdd {
    pub node: Arc<RddNode>,
    session: Option<Arc<dyn SessionBinding>>,
}

impl std::fmt::Debug for Rdd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &*self.node {
            RddNode::TextFile { bucket, prefix } => write!(f, "TextFile({bucket}/{prefix})"),
            RddNode::Narrow { parent, op } => write!(f, "{parent:?} -> {op:?}"),
            RddNode::ReduceByKey { parent, partitions, .. } => {
                write!(f, "{parent:?} -> ReduceByKey({partitions})")
            }
            RddNode::CoGroup { left, right, partitions } => {
                write!(f, "CoGroup({left:?}, {right:?}, {partitions})")
            }
            RddNode::Cached { parent, level } => {
                write!(f, "{parent:?} -> Cached({level:?})")
            }
        }
    }
}

impl Rdd {
    /// `sc.textFile("s3://bucket/prefix")`, unbound. Prefer
    /// `FlintContext::text_file`, which binds the result to a session so
    /// actions work; unbound lineages are for engine-agnostic
    /// cross-checks (`FlintContext::collect` runs them on any context).
    pub fn text_file(bucket: &str, prefix: &str) -> Rdd {
        Rdd {
            node: Arc::new(RddNode::TextFile {
                bucket: bucket.to_string(),
                prefix: prefix.to_string(),
            }),
            session: None,
        }
    }

    /// Install a session binding (used by `FlintContext::text_file`).
    pub fn with_session(mut self, session: Arc<dyn SessionBinding>) -> Rdd {
        self.session = Some(session);
        self
    }

    fn derive(&self, node: RddNode) -> Rdd {
        Rdd { node: Arc::new(node), session: self.session.clone() }
    }

    pub fn map(&self, f: impl Fn(Value) -> Value + Send + Sync + 'static) -> Rdd {
        self.derive(RddNode::Narrow { parent: self.clone(), op: DynOp::Map(Arc::new(f)) })
    }

    pub fn filter(&self, f: impl Fn(&Value) -> bool + Send + Sync + 'static) -> Rdd {
        self.derive(RddNode::Narrow { parent: self.clone(), op: DynOp::Filter(Arc::new(f)) })
    }

    pub fn flat_map(&self, f: impl Fn(Value) -> Vec<Value> + Send + Sync + 'static) -> Rdd {
        self.derive(RddNode::Narrow { parent: self.clone(), op: DynOp::FlatMap(Arc::new(f)) })
    }

    /// Typed dropoff-day filter over raw CSV trip lines (inclusive day
    /// indexes since 2009-01-01). Plant it directly on a `text_file`
    /// source: because the predicate is visible to the engine, scans can
    /// skip fetching splits whose manifest stats are disjoint from the
    /// range — an opaque `filter` closure can never be pruned on.
    pub fn filter_day_range(&self, min_day: i32, max_day: i32) -> Rdd {
        self.derive(RddNode::Narrow {
            parent: self.clone(),
            op: DynOp::DayRange { min_day, max_day },
        })
    }

    /// `rdd.reduceByKey(combine, numPartitions)` — records must be pairs.
    pub fn reduce_by_key(
        &self,
        partitions: usize,
        combine: impl Fn(Value, Value) -> Value + Send + Sync + 'static,
    ) -> Rdd {
        assert!(partitions > 0, "reduceByKey needs at least one partition");
        self.derive(RddNode::ReduceByKey {
            parent: self.clone(),
            partitions,
            combine: Arc::new(combine),
        })
    }

    /// `a.cogroup(b, numPartitions)` — both sides must emit pairs. Each
    /// result record is `(key, [left_values, right_values])` where each
    /// side's values arrive as a deterministically-sorted `Value::List`
    /// (queue arrival order across producers is racy, so the executor
    /// sorts within each side).
    pub fn cogroup(&self, other: &Rdd, partitions: usize) -> Rdd {
        assert!(partitions > 0, "cogroup needs at least one partition");
        if let (Some(a), Some(b)) = (&self.session, &other.session) {
            // Two different sessions would silently resolve the right
            // side's source in the wrong environment (an empty listing
            // scans nothing) — refuse loudly instead.
            assert!(
                std::ptr::eq(Arc::as_ptr(a) as *const (), Arc::as_ptr(b) as *const ()),
                "cogroup/join across two different FlintContext sessions: \
                 build both sides from the same context"
            );
        }
        let session = self.session.clone().or_else(|| other.session.clone());
        Rdd {
            node: Arc::new(RddNode::CoGroup {
                left: self.clone(),
                right: other.clone(),
                partitions,
            }),
            session,
        }
    }

    /// Shared lowering for the join family: cogroup plus a per-key
    /// expansion flatMap. `keep_left`/`keep_right` select which
    /// unmatched sides survive, padded with `Value::Null` (PySpark's
    /// `None`).
    fn join_with(&self, other: &Rdd, partitions: usize, keep_left: bool, keep_right: bool) -> Rdd {
        self.cogroup(other, partitions).flat_map(move |v| {
            let key = v.key().clone();
            let Value::List(sides) = v.val() else { return Vec::new() };
            let (Some(Value::List(l)), Some(Value::List(r))) = (sides.first(), sides.get(1))
            else {
                return Vec::new();
            };
            let mut out = Vec::new();
            match (l.is_empty(), r.is_empty()) {
                (false, false) => {
                    out.reserve(l.len() * r.len());
                    for lv in l {
                        for rv in r {
                            out.push(Value::pair(
                                key.clone(),
                                Value::pair(lv.clone(), rv.clone()),
                            ));
                        }
                    }
                }
                (false, true) if keep_left => {
                    for lv in l {
                        out.push(Value::pair(key.clone(), Value::pair(lv.clone(), Value::Null)));
                    }
                }
                (true, false) if keep_right => {
                    for rv in r {
                        out.push(Value::pair(key.clone(), Value::pair(Value::Null, rv.clone())));
                    }
                }
                _ => {}
            }
            out
        })
    }

    /// `a.join(b, numPartitions)` — inner equi-join on the pair key:
    /// cogroup plus the per-key cross product, yielding
    /// `(key, (left_value, right_value))` records.
    pub fn join(&self, other: &Rdd, partitions: usize) -> Rdd {
        self.join_with(other, partitions, false, false)
    }

    /// `a.leftOuterJoin(b)`: every left record survives; keys with no
    /// right match yield `(key, (left_value, Null))`.
    pub fn left_outer_join(&self, other: &Rdd, partitions: usize) -> Rdd {
        self.join_with(other, partitions, true, false)
    }

    /// `a.rightOuterJoin(b)`: every right record survives; keys with no
    /// left match yield `(key, (Null, right_value))`.
    pub fn right_outer_join(&self, other: &Rdd, partitions: usize) -> Rdd {
        self.join_with(other, partitions, false, true)
    }

    /// `a.fullOuterJoin(b)`: both unmatched sides survive, Null-padded.
    pub fn full_outer_join(&self, other: &Rdd, partitions: usize) -> Rdd {
        self.join_with(other, partitions, true, true)
    }

    /// `rdd.cache()`: mark this point of the lineage for reuse at the
    /// default storage level (memory + S3). Lazy, like Spark: nothing
    /// materializes until an action runs; actions after the first start
    /// from the materialized cut instead of recomputing the sub-lineage
    /// — including actions on *other* lineages that share this exact
    /// sub-lineage, via the service-level fingerprint registry.
    pub fn cache(&self) -> Rdd {
        self.persist(StorageLevel::MemoryAndS3)
    }

    /// `rdd.persist(level)`: `cache()` with an explicit storage level.
    pub fn persist(&self, level: StorageLevel) -> Rdd {
        self.derive(RddNode::Cached { parent: self.clone(), level })
    }

    // -- actions --------------------------------------------------------

    fn session(&self) -> Result<&Arc<dyn SessionBinding>> {
        self.session.as_ref().ok_or_else(|| {
            anyhow!(
                "this Rdd is not bound to a session; build it from \
                 FlintContext::text_file (or run it with FlintContext::collect)"
            )
        })
    }

    /// Compile this lineage for `action` with the bound session's split
    /// resolution (the lazy→physical step every action takes). Cache
    /// markers are left transparent — this is the build-free path
    /// `explain` uses; actions go through [`Rdd::lower_for_action`],
    /// which asks the session to resolve (and possibly build) caches
    /// first.
    pub fn lower(&self, action: Action) -> Result<PhysicalPlan> {
        let session = self.session()?;
        Ok(dag::lower(self, action, &|bucket, prefix| {
            session.input_splits(bucket, prefix)
        }))
    }

    /// Compile for an action that is about to *run*: the session
    /// resolves every admitted `Cached` marker (building missing
    /// entries), and the compiled plan cuts at the resolved ones.
    fn lower_for_action(&self, action: Action) -> Result<PhysicalPlan> {
        let session = self.session()?;
        let resolution = session.resolve_cache(self);
        Ok(dag::lower_resolved(
            self,
            action,
            &|bucket, prefix| session.input_splits(bucket, prefix),
            &resolution,
        ))
    }

    /// `rdd.collect()`: execute and return all records (in the
    /// deterministic `Value::total_cmp` order).
    pub fn collect(&self) -> Result<Vec<Value>> {
        self.session()?.execute(&self.lower_for_action(Action::Collect)?)?.into_values()
    }

    /// `rdd.count()`: number of records the lineage produces.
    pub fn count(&self) -> Result<u64> {
        self.session()?.execute(&self.lower_for_action(Action::Count)?)?.into_count()
    }

    /// `rdd.reduce(f)`: fold all records with `f` at the driver (`None`
    /// for an empty result). `f` should be associative and commutative —
    /// records arrive in the deterministic collect order, not input
    /// order.
    pub fn reduce(&self, f: impl Fn(Value, Value) -> Value) -> Result<Option<Value>> {
        Ok(self.collect()?.into_iter().reduce(f))
    }

    /// `rdd.take(n)`: the first `n` records of the deterministic collect
    /// order. (A serverless engine has no partition-at-a-time incremental
    /// fetch: the plan runs fully, then truncates at the driver.)
    pub fn take(&self, n: usize) -> Result<Vec<Value>> {
        let mut values = self.collect()?;
        values.truncate(n);
        Ok(values)
    }

    /// `rdd.saveAsTextFile(...)`: write one object per final-stage task
    /// under `bucket/prefix`; returns the object count.
    pub fn save_as_text_file(&self, bucket: &str, prefix: &str) -> Result<u64> {
        let action = Action::SaveAsText { bucket: bucket.to_string(), prefix: prefix.to_string() };
        self.session()?.execute(&self.lower_for_action(action)?)?.into_saved()
    }

    /// Render the stage DAG this lineage compiles to (without running
    /// it). Unbound lineages still explain, with unresolved (zero-split)
    /// sources.
    pub fn explain(&self) -> String {
        match self.lower(Action::Collect) {
            Ok(plan) => plan.explain(),
            Err(_) => dag::lower(self, Action::Collect, &|_, _| Vec::new()).explain(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v_i64(v: i64) -> Value {
        Value::I64(v)
    }

    #[test]
    fn chain_application_order() {
        let ops = vec![
            DynOp::Map(Arc::new(|v: Value| Value::I64(v.as_i64().unwrap() + 1))),
            DynOp::Filter(Arc::new(|v: &Value| v.as_i64().unwrap() % 2 == 0)),
            DynOp::FlatMap(Arc::new(|v: Value| {
                let x = v.as_i64().unwrap();
                vec![Value::I64(x), Value::I64(x * 10)]
            })),
        ];
        let mut out = Vec::new();
        DynOp::apply_chain(&ops, v_i64(1), &mut out); // 1+1=2, even, -> [2, 20]
        DynOp::apply_chain(&ops, v_i64(2), &mut out); // 3 is odd -> dropped
        assert_eq!(out, vec![v_i64(2), v_i64(20)]);
    }

    #[test]
    fn day_range_op_filters_lines_and_is_visible_to_the_planner() {
        use crate::data::chrono::{day_index, epoch_from_datetime, format_datetime};
        let ts = epoch_from_datetime(2014, 3, 10, 9, 30, 0);
        let day = day_index(ts);
        let line = format!("0,{},{},1,2.0", format_datetime(ts - 600), format_datetime(ts));
        let ops = vec![DynOp::DayRange { min_day: day - 1, max_day: day + 1 }];
        let mut out = Vec::new();
        DynOp::apply_chain(&ops, Value::str(line.clone()), &mut out);
        assert_eq!(out.len(), 1, "in-range line survives");
        let miss = vec![DynOp::DayRange { min_day: day + 5, max_day: day + 9 }];
        DynOp::apply_chain(&miss, Value::str(line), &mut out);
        DynOp::apply_chain(&miss, Value::str("garbage"), &mut out);
        DynOp::apply_chain(&miss, Value::I64(3), &mut out);
        assert_eq!(out.len(), 1, "out-of-range, unparsable, non-line all dropped");

        // Ranges intersect; an opaque Filter is transparent to the walk
        // (it only drops records, survivors are still raw lines), so the
        // range behind it still participates — here the conjunction is
        // unsatisfiable (50..=10), which prunes *every* split, exactly
        // what an always-empty scan deserves.
        let chain = vec![
            DynOp::DayRange { min_day: 0, max_day: 100 },
            DynOp::DayRange { min_day: 50, max_day: 200 },
            DynOp::Filter(Arc::new(|_| true)),
            DynOp::DayRange { min_day: 0, max_day: 10 },
        ];
        assert_eq!(DynOp::leading_day_range(&chain), Some((50, 10)));
        assert_eq!(DynOp::leading_day_range(&chain[..3]), Some((50, 100)));
        assert_eq!(DynOp::leading_day_range(&chain[2..]), Some((0, 10)), "commutes past Filter");
        assert_eq!(DynOp::leading_day_range(&[]), None);

        // Map/FlatMap still stop the walk: records behind them are no
        // longer raw CSV lines, so a later DayRange must not prune.
        let mapped = vec![
            DynOp::Map(Arc::new(|v| v)),
            DynOp::DayRange { min_day: 0, max_day: 10 },
        ];
        assert_eq!(DynOp::leading_day_range(&mapped), None);
        let flat = vec![
            DynOp::Filter(Arc::new(|_| true)),
            DynOp::FlatMap(Arc::new(|v| vec![v])),
            DynOp::DayRange { min_day: 0, max_day: 10 },
        ];
        assert_eq!(DynOp::leading_day_range(&flat), None);
    }

    #[test]
    fn code_bytes_sized_per_op_kind() {
        let map = DynOp::Map(Arc::new(|v| v));
        let filter = DynOp::Filter(Arc::new(|_| true));
        let flat = DynOp::FlatMap(Arc::new(|v| vec![v]));
        // A flatMap closure pickles bigger than a map, which pickles
        // bigger than a bare predicate — and none of them are the old
        // flat 2048.
        assert!(flat.code_bytes() > map.code_bytes());
        assert!(map.code_bytes() > filter.code_bytes());
        // Chains account linearly: the payload machinery sums these.
        let chain = [map, filter, flat];
        let total: u64 = chain.iter().map(DynOp::code_bytes).sum();
        assert_eq!(total, 1_792 + 1_024 + 2_560);
    }

    /// Extract the expansion flatMap a join variant plants after its
    /// cogroup, and run it over a synthetic cogroup record.
    fn expand(joined: &Rdd, record: Value) -> Vec<Value> {
        let RddNode::Narrow { parent, op } = &*joined.node else {
            panic!("join is cogroup + flatMap: {joined:?}")
        };
        assert!(matches!(&*parent.node, RddNode::CoGroup { .. }), "{parent:?}");
        let mut out = Vec::new();
        DynOp::apply_chain(std::slice::from_ref(op), record, &mut out);
        out
    }

    fn cogroup_record(key: i64, left: Vec<Value>, right: Vec<Value>) -> Value {
        Value::pair(
            Value::I64(key),
            Value::List(vec![Value::List(left), Value::List(right)]),
        )
    }

    #[test]
    fn join_post_op_expands_cross_product() {
        let joined = Rdd::text_file("b", "l/").join(&Rdd::text_file("b", "r/"), 2);
        let record = cogroup_record(7, vec![v_i64(1), v_i64(2)], vec![Value::str("a")]);
        let out = expand(&joined, record);
        assert_eq!(out.len(), 2, "2 left x 1 right");
        assert_eq!(out[0], Value::pair(v_i64(7), Value::pair(v_i64(1), Value::str("a"))));
        // An empty side joins to nothing (inner join).
        let empty = cogroup_record(8, vec![v_i64(1)], Vec::new());
        assert!(expand(&joined, empty).is_empty());
    }

    #[test]
    fn outer_join_variants_pad_with_null() {
        let l = Rdd::text_file("b", "l/");
        let r = Rdd::text_file("b", "r/");
        let left_only = || cogroup_record(1, vec![v_i64(10)], Vec::new());
        let right_only = || cogroup_record(2, Vec::new(), vec![v_i64(20)]);
        let both = || cogroup_record(3, vec![v_i64(10)], vec![v_i64(20)]);

        let left = l.left_outer_join(&r, 2);
        assert_eq!(
            expand(&left, left_only()),
            vec![Value::pair(v_i64(1), Value::pair(v_i64(10), Value::Null))]
        );
        assert!(expand(&left, right_only()).is_empty(), "left outer drops unmatched right");
        assert_eq!(expand(&left, both()).len(), 1);

        let right = l.right_outer_join(&r, 2);
        assert!(expand(&right, left_only()).is_empty(), "right outer drops unmatched left");
        assert_eq!(
            expand(&right, right_only()),
            vec![Value::pair(v_i64(2), Value::pair(Value::Null, v_i64(20)))]
        );

        let full = l.full_outer_join(&r, 2);
        assert_eq!(expand(&full, left_only()).len(), 1);
        assert_eq!(expand(&full, right_only()).len(), 1);
        assert_eq!(
            expand(&full, both()),
            vec![Value::pair(v_i64(3), Value::pair(v_i64(10), v_i64(20)))]
        );
    }

    #[test]
    fn unbound_actions_error_instead_of_running() {
        let rdd = Rdd::text_file("b", "p").map(|v| v);
        let err = rdd.collect().unwrap_err().to_string();
        assert!(err.contains("not bound to a session"), "{err}");
        assert!(rdd.count().is_err());
        // explain still works (unresolved sources, zero tasks).
        let text = rdd.explain();
        assert!(text.contains("DynScan"), "{text}");
    }

    #[test]
    fn transformations_thread_the_session_binding() {
        struct Nop;
        impl SessionBinding for Nop {
            fn input_splits(&self, _: &str, _: &str) -> Vec<InputSplit> {
                Vec::new()
            }
            fn execute(&self, _: &PhysicalPlan) -> Result<ActionOut> {
                Ok(ActionOut::Count(42))
            }
        }
        let bound = Rdd::text_file("b", "p").with_session(Arc::new(Nop));
        let derived = bound.map(|v| v).filter(|_| true).reduce_by_key(2, |a, _| a);
        assert_eq!(derived.count().unwrap(), 42, "binding survives transformations");
        // cogroup picks up the binding from either side.
        let unbound = Rdd::text_file("b", "q");
        assert!(unbound.cogroup(&bound, 2).count().is_ok());
        assert!(bound.cogroup(&unbound, 2).count().is_ok());
    }
}
