//! The physical plan: a **stage DAG** — lineage (or a benchmark query) →
//! [`PhysicalPlan`] — the structure both engines execute.
//!
//! Generic lineages go through one recursive compiler, [`lower`]: it
//! walks an arbitrary [`Rdd`] graph action-side-down, cuts a stage at
//! every wide dependency (`reduce_by_key`, `cogroup`/`join`), and fuses
//! the narrow ops between cuts into the consuming stage's chain. There
//! are no special-cased shapes: reduceByKey downstream of a cogroup, a
//! cogroup of two reduces, multi-way diamonds — every interleaving
//! lowers through the same recursion. A sub-lineage consumed by two
//! wide children (the same `Rdd` handle, by `Arc` pointer identity, at
//! the same partition count) plans its stage **once** and fans its
//! shuffle out on two edges — the driver materializes one queue set per
//! DAG edge, so both consumers drain their own copy. (Map-side combine
//! is per-consumer, so a shared stage ships raw records.)
//!
//! Stages carry explicit ids and *parent edges*: a stage consumes the
//! shuffle output of every parent listed in [`Stage::parents`], so plans
//! are not restricted to linear chains — multi-parent stages are
//! first-class, and the reduce side consumes each parent's stream
//! *tagged with its origin edge*: [`build_union_plan`] merges them
//! (union semantics), while the cogroup stages [`lower`] emits and Q6J's
//! [`build_kernel_join_plan`] keep the sides apart for true
//! cogroup/join semantics. `flint explain` renders the join shape as a
//! diamond, e.g. for Q6J:
//!
//! ```text
//!   stage 0: [s3 xN] -> KernelScan(Q6J) -> Shuffle(30) (N tasks)
//!   stage 1: [s3 x1] -> DynScan(1 ops) -> Shuffle(30) (1 tasks)
//!   stage 2: [sqs x30] -> KernelJoin(Q6J) -> Shuffle(6) (30 tasks)  <- s0, s1
//!   stage 3: [sqs x6] -> KernelReduce(Q6J) -> Act(Collect) (6 tasks)  <- s2
//! ```
//!
//! Stages are
//! stored in topological order (`parents[i] < id` for every edge), which
//! [`PhysicalPlan::validate`] enforces; the driver executes them in that
//! order while the virtual clock (`simtime::schedule`) decides how much
//! of their execution *overlaps* under the pipelined SQS semantics of
//! §III-A (reducers long-poll their queues while mappers still flush).

use crate::compute::csv::split_ranges;
use crate::compute::queries::{KernelSpec, QueryId, QueryResult};
use crate::compute::value::Value;
use crate::config::FlintConfig;
use crate::data::weather::{precip_bucket, PRECIP_BUCKETS};
use crate::data::Dataset;
use crate::plan::rdd::{CombineFn, DynOp, Rdd, RddNode};
use crate::plan::task::{CachePart, InputSplit};
use std::collections::HashMap;
use std::sync::Arc;

/// What the final stage does with its output.
#[derive(Clone)]
pub enum Action {
    /// Return a total row count to the driver (Q0, `rdd.count()`).
    Count,
    /// Materialize grouped/collected records at the driver (`collect`).
    Collect,
    /// Write text output to `bucket/prefix` (`saveAsTextFile`).
    SaveAsText { bucket: String, prefix: String },
    /// Materialize a cached lineage cut: one committed binary
    /// `Value`-stream object per final-stage task under `bucket/prefix`
    /// (the cache-build sub-plan the session runs on a `cache()` miss).
    /// Never user-visible — actions on the original lineage read the
    /// parts back through a `CachedScan` stage.
    CacheWrite { bucket: String, prefix: String },
}

impl std::fmt::Debug for Action {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Action::Count => f.write_str("Count"),
            Action::Collect => f.write_str("Collect"),
            Action::SaveAsText { bucket, prefix } => write!(f, "SaveAsText({bucket}/{prefix})"),
            Action::CacheWrite { bucket, prefix } => write!(f, "CacheWrite({bucket}/{prefix})"),
        }
    }
}

/// Resolved cache cut points for one lowering: `Cached` lineage nodes
/// (by `Arc` identity) whose materialized partitions exist and may be
/// scanned instead of recomputing the sub-lineage below them. Built by
/// the session's [`crate::plan::rdd::SessionBinding::resolve_cache`]
/// before an action lowers; the default (empty) resolution leaves every
/// marker transparent, which is also what `explain` and the
/// interpreter see.
#[derive(Default, Clone)]
pub struct CacheResolution {
    entries: HashMap<usize, Arc<Vec<CachePart>>>,
}

impl CacheResolution {
    /// Identity key of a lineage node (the same `Arc` pointer identity
    /// the stage-sharing memo uses).
    pub fn node_key(rdd: &Rdd) -> usize {
        Arc::as_ptr(&rdd.node) as *const () as usize
    }

    pub fn insert(&mut self, key: usize, parts: Arc<Vec<CachePart>>) {
        self.entries.insert(key, parts);
    }

    pub fn get(&self, key: usize) -> Option<&Arc<Vec<CachePart>>> {
        self.entries.get(&key)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Merged result of a plan's final stage — what an [`Action`] yields
/// back at the driver (lives next to `Action` so the session layer can
/// speak it without reaching into the executor).
#[derive(Debug, Clone)]
pub enum ActionOut {
    Count(u64),
    KernelRows(Vec<(i64, f64, f64)>),
    Values(Vec<Value>),
    Saved(u64),
}

impl ActionOut {
    /// Convert to the benchmark-comparable form (kernel queries only).
    pub fn to_query_result(&self) -> Option<QueryResult> {
        match self {
            ActionOut::Count(n) => Some(QueryResult::Count(*n)),
            ActionOut::KernelRows(rows) => {
                let mut rows = rows.clone();
                rows.sort_by_key(|(k, _, _)| *k);
                Some(QueryResult::Buckets(rows))
            }
            _ => None,
        }
    }

    /// A `collect`'s values, or an error naming what came back instead —
    /// the single unwrap every collect-shaped caller shares.
    pub fn into_values(self) -> anyhow::Result<Vec<Value>> {
        match self {
            ActionOut::Values(values) => Ok(values),
            other => anyhow::bail!("collect produced {other:?}"),
        }
    }

    /// A `count`'s total, or an error naming what came back instead.
    pub fn into_count(self) -> anyhow::Result<u64> {
        match self {
            ActionOut::Count(n) => Ok(n),
            other => anyhow::bail!("count produced {other:?}"),
        }
    }

    /// A `saveAsTextFile`'s object count, or an error naming what came
    /// back instead.
    pub fn into_saved(self) -> anyhow::Result<u64> {
        match self {
            ActionOut::Saved(n) => Ok(n),
            other => anyhow::bail!("saveAsTextFile produced {other:?}"),
        }
    }
}

/// Where a stage reads from.
#[derive(Debug, Clone)]
pub enum StageInput {
    /// Source stage: byte-range splits of S3 objects.
    S3Splits(Vec<InputSplit>),
    /// Downstream stage: one task per shuffle partition, draining that
    /// partition's queue of **every** parent stage.
    Shuffle { partitions: usize },
    /// Source stage: one task per materialized partition of a cached
    /// lineage cut (`CachedScan` stages only).
    CacheParts(Vec<CachePart>),
}

/// Where a stage writes to.
#[derive(Clone)]
pub enum StageOutput {
    /// Hash-partitioned shuffle into `partitions` queues (or S3 objects,
    /// per the configured shuffle backend).
    Shuffle { partitions: usize, combine: Option<CombineFn> },
    /// Final stage: feed the action.
    Act(Action),
}

impl std::fmt::Debug for StageOutput {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StageOutput::Shuffle { partitions, .. } => write!(f, "Shuffle({partitions})"),
            StageOutput::Act(a) => write!(f, "Act({a:?})"),
        }
    }
}

/// The per-record work a stage performs.
#[derive(Clone)]
pub enum StageCompute {
    /// Typed fast path: parse trips into columnar batches, run the fused
    /// filter+histogram kernel (native or PJRT artifact).
    KernelScan { spec: KernelSpec },
    /// Typed reduce: merge `(bucket, (sum, count))` partials.
    KernelReduce { spec: KernelSpec },
    /// Generic path: apply a dynamic op chain to each input line.
    DynScan { ops: Vec<DynOp> },
    /// Generic reduce: combine pair values by key, then apply a post
    /// chain.
    DynReduce { combine: CombineFn, post_ops: Vec<DynOp> },
    /// Typed two-sided equi-join (Q6J). Streams are consumed *per parent
    /// edge* (the tagged shuffle): edge `parents[0]` ships per-join-key
    /// fact partials as Kernel records, edge `parents[1]` ships
    /// `(join_key, value)` dimension pairs as Dyn records; the output
    /// re-keys the fact partials by their dimension value.
    KernelJoin { spec: KernelSpec },
    /// Generic cogroup: group each parent edge's pair-values by key,
    /// then feed `(key, [values_per_edge, ...])` through a post chain.
    DynCoGroup { post_ops: Vec<DynOp> },
    /// Read a cached lineage cut's materialized `Value` stream (memory
    /// tier when the container holds it, committed S3 object otherwise)
    /// and apply the narrow ops layered *above* the cache marker.
    CachedScan { ops: Vec<DynOp> },
}

impl std::fmt::Debug for StageCompute {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StageCompute::KernelScan { spec } => write!(f, "KernelScan({})", spec.query),
            StageCompute::KernelReduce { spec } => write!(f, "KernelReduce({})", spec.query),
            StageCompute::DynScan { ops } => write!(f, "DynScan({} ops)", ops.len()),
            StageCompute::DynReduce { post_ops, .. } => {
                write!(f, "DynReduce(+{} post ops)", post_ops.len())
            }
            StageCompute::KernelJoin { spec } => write!(f, "KernelJoin({})", spec.query),
            StageCompute::DynCoGroup { post_ops } => {
                write!(f, "DynCoGroup(+{} post ops)", post_ops.len())
            }
            StageCompute::CachedScan { ops } => write!(f, "CachedScan({} ops)", ops.len()),
        }
    }
}

/// One stage of the DAG.
#[derive(Debug, Clone)]
pub struct Stage {
    pub id: u32,
    /// Stage ids whose shuffle output this stage consumes. Empty for S3
    /// scan stages. Every parent must shuffle into the same partition
    /// count (this stage's task count).
    pub parents: Vec<u32>,
    pub compute: StageCompute,
    pub input: StageInput,
    pub output: StageOutput,
}

impl Stage {
    /// Number of tasks this stage launches.
    pub fn num_tasks(&self) -> usize {
        match &self.input {
            StageInput::S3Splits(splits) => splits.len(),
            StageInput::Shuffle { partitions } => *partitions,
            StageInput::CacheParts(parts) => parts.len(),
        }
    }
}

/// A complete physical plan.
#[derive(Debug, Clone)]
pub struct PhysicalPlan {
    /// Unique id (scopes queue names, shuffle keys, the plan registry).
    pub plan_id: String,
    /// Stages in topological order (`parents[i] < id`).
    pub stages: Vec<Stage>,
    pub action: Action,
    /// Set when this is a benchmark-query plan (enables the PJRT path and
    /// the weather side input for Q6).
    pub query: Option<QueryId>,
    /// Weather side-table S3 location, when any stage needs it.
    pub weather: Option<(String, String)>,
}

impl PhysicalPlan {
    /// Total tasks across stages.
    pub fn total_tasks(&self) -> usize {
        self.stages.iter().map(Stage::num_tasks).sum()
    }

    /// The stage with id `id` (ids are dense and equal their index).
    pub fn stage(&self, id: u32) -> &Stage {
        &self.stages[id as usize]
    }

    /// Stage ids that consume `id`'s shuffle output.
    pub fn children(&self, id: u32) -> Vec<u32> {
        self.stages
            .iter()
            .filter(|s| s.parents.contains(&id))
            .map(|s| s.id)
            .collect()
    }

    /// Check the DAG invariants the driver and virtual clock rely on:
    /// dense ids in topological order, edge consistency (a parent exists,
    /// shuffles, and shuffles into the consumer's partition count), and
    /// shuffle inputs backed by at least one parent.
    pub fn validate(&self) -> Result<(), String> {
        for (i, s) in self.stages.iter().enumerate() {
            if s.id as usize != i {
                return Err(format!("stage {} stored at index {i}", s.id));
            }
            // A duplicate parent entry would mean two readers draining
            // (and the driver twice deleting) one edge's queues.
            let mut dedup = s.parents.clone();
            dedup.sort_unstable();
            dedup.dedup();
            if dedup.len() != s.parents.len() {
                return Err(format!("stage {} lists a duplicate parent", s.id));
            }
            for &p in &s.parents {
                if p >= s.id {
                    return Err(format!(
                        "stage {} lists parent {p}: not topologically ordered",
                        s.id
                    ));
                }
                let parent = &self.stages[p as usize];
                let StageOutput::Shuffle { partitions, .. } = &parent.output else {
                    return Err(format!("stage {} parent {p} does not shuffle", s.id));
                };
                if let StageInput::Shuffle { partitions: want } = &s.input {
                    if partitions != want {
                        return Err(format!(
                            "stage {} wants {want} partitions but parent {p} shuffles {partitions}",
                            s.id
                        ));
                    }
                }
            }
            match &s.input {
                StageInput::Shuffle { .. } if s.parents.is_empty() => {
                    return Err(format!("stage {} reads a shuffle but has no parents", s.id));
                }
                StageInput::S3Splits(_) if !s.parents.is_empty() => {
                    return Err(format!("stage {} reads S3 but lists parents", s.id));
                }
                StageInput::CacheParts(_) if !s.parents.is_empty() => {
                    return Err(format!("stage {} reads a cache cut but lists parents", s.id));
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Render the stage/queue topology (the `flint explain` output and
    /// the Figure 1 analogue). Parent edges are shown as `<- sN`.
    pub fn explain(&self) -> String {
        let mut out = format!("plan {} ({:?})\n", self.plan_id, self.action);
        for s in &self.stages {
            let input = match &s.input {
                StageInput::S3Splits(sp) => format!("s3 x{}", sp.len()),
                StageInput::Shuffle { partitions } => format!("sqs x{partitions}"),
                StageInput::CacheParts(parts) => format!("cache x{}", parts.len()),
            };
            let deps = if s.parents.is_empty() {
                String::new()
            } else {
                format!(
                    "  <- {}",
                    s.parents
                        .iter()
                        .map(|p| format!("s{p}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            };
            out.push_str(&format!(
                "  stage {}: [{input}] -> {:?} -> {:?} ({} tasks){deps}\n",
                s.id,
                s.compute,
                s.output,
                s.num_tasks()
            ));
        }
        out
    }
}

/// Compute the input splits for a dataset.
pub fn input_splits(dataset: &Dataset, split_bytes: u64) -> Vec<InputSplit> {
    let mut splits = Vec::new();
    for (key, size) in &dataset.objects {
        // Every split of an object inherits the object's manifest stats
        // (conservative for any byte subrange of the object).
        let stats = dataset.object_stats.get(key).copied();
        for (start, end) in split_ranges(*size, split_bytes) {
            splits.push(InputSplit {
                bucket: dataset.bucket.clone(),
                key: key.clone(),
                start,
                end,
                object_size: *size,
                stats,
            });
        }
    }
    splits
}

fn next_plan_id() -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(0);
    format!("plan-{}", NEXT.fetch_add(1, Ordering::Relaxed))
}

/// Physical plan for a benchmark query (typed kernel path). Q0 is
/// map-only + Count; everything else is scan → shuffle → reduce →
/// Collect, exactly the two-stage shape the paper's Figure 1 shows.
pub fn build_kernel_plan(query: QueryId, dataset: &Dataset, config: &FlintConfig) -> PhysicalPlan {
    if query.is_join() {
        return build_kernel_join_plan(query, dataset, config);
    }
    let spec = query.spec();
    let splits = input_splits(dataset, config.flint.input_split_bytes);
    let weather = spec
        .needs_weather()
        .then(|| (dataset.bucket.clone(), dataset.weather_key.clone()));

    let mut stages = Vec::new();
    if spec.reduce_partitions == 0 {
        stages.push(Stage {
            id: 0,
            parents: Vec::new(),
            compute: StageCompute::KernelScan { spec },
            input: StageInput::S3Splits(splits),
            output: StageOutput::Act(Action::Count),
        });
        return PhysicalPlan {
            plan_id: next_plan_id(),
            stages,
            action: Action::Count,
            query: Some(query),
            weather,
        };
    }

    stages.push(Stage {
        id: 0,
        parents: Vec::new(),
        compute: StageCompute::KernelScan { spec },
        input: StageInput::S3Splits(splits),
        output: StageOutput::Shuffle { partitions: spec.reduce_partitions, combine: None },
    });
    stages.push(Stage {
        id: 1,
        parents: vec![0],
        compute: StageCompute::KernelReduce { spec },
        input: StageInput::Shuffle { partitions: spec.reduce_partitions },
        output: StageOutput::Act(Action::Collect),
    });
    PhysicalPlan {
        plan_id: next_plan_id(),
        stages,
        action: Action::Collect,
        query: Some(query),
        weather,
    }
}

/// What a narrow op chain bottoms out on: an S3 source, a wide
/// (shuffle) dependency, or a *resolved* cached cut whose materialized
/// partitions replace the sub-lineage below it.
enum ChainBase {
    Source { bucket: String, prefix: String },
    Wide(Rdd),
    Cached(Arc<Vec<CachePart>>),
}

/// Walk root-ward from `rdd` through narrow nodes only, returning the
/// base the chain hangs off plus the ops in application (source-first)
/// order. A `Cached` marker with an entry in `resolution` terminates
/// the walk (the cut's partitions stand in for everything below);
/// an unresolved marker is transparent — the walk continues into its
/// parent and the plan is exactly the uncached plan.
fn narrow_chain(rdd: &Rdd, resolution: &CacheResolution) -> (ChainBase, Vec<DynOp>) {
    let mut ops = Vec::new();
    let mut node = rdd.clone();
    loop {
        let next = match &*node.node {
            RddNode::TextFile { bucket, prefix } => {
                ops.reverse();
                return (ChainBase::Source { bucket: bucket.clone(), prefix: prefix.clone() }, ops);
            }
            RddNode::Narrow { parent, op } => {
                ops.push(op.clone());
                parent.clone()
            }
            RddNode::ReduceByKey { .. } | RddNode::CoGroup { .. } => {
                ops.reverse();
                return (ChainBase::Wide(node.clone()), ops);
            }
            RddNode::Cached { parent, .. } => {
                match resolution.get(CacheResolution::node_key(&node)) {
                    Some(parts) => {
                        ops.reverse();
                        return (ChainBase::Cached(parts.clone()), ops);
                    }
                    None => parent.clone(),
                }
            }
        };
        node = next;
    }
}

/// The general lineage→DAG compiler: recursively cut *any* [`Rdd`]
/// graph at its wide dependencies and emit a topologically-ordered
/// [`PhysicalPlan`]. Narrow ops fuse into the stage that consumes them;
/// a `reduce_by_key` becomes a [`StageCompute::DynReduce`] stage and a
/// `cogroup` (or any `join` variant) a two-parent
/// [`StageCompute::DynCoGroup`] stage — each of which may itself feed a
/// further shuffle, so reduceByKey downstream of a cogroup lowers to
/// the 4-stage dyn diamond without any special case.
///
/// Sharing: a sub-lineage consumed by more than one wide child (the
/// same `Arc` node at the same partition count) is planned **once**;
/// the driver fans its shuffle output out on one queue set per
/// consuming edge. The one exception is a self-cogroup
/// (`a.cogroup(&a, p)`): a stage cannot appear twice in one parent
/// list, so the right side plans a duplicate stage.
pub fn lower(
    rdd: &Rdd,
    action: Action,
    splits: &dyn Fn(&str, &str) -> Vec<InputSplit>,
) -> PhysicalPlan {
    lower_resolved(rdd, action, splits, &CacheResolution::default())
}

/// [`lower`] with resolved cache cut points: every `Cached` node listed
/// in `resolution` compiles to a [`StageCompute::CachedScan`] source
/// stage over its materialized partitions instead of recompiling the
/// sub-lineage below it. With an empty resolution this *is* `lower`.
pub fn lower_resolved(
    rdd: &Rdd,
    action: Action,
    splits: &dyn Fn(&str, &str) -> Vec<InputSplit>,
    resolution: &CacheResolution,
) -> PhysicalPlan {
    let mut lw = Lowering { stages: Vec::new(), memo: HashMap::new(), splits, resolution };
    let (base, ops) = narrow_chain(rdd, resolution);
    match base {
        ChainBase::Source { bucket, prefix } => {
            lw.push(
                Vec::new(),
                StageCompute::DynScan { ops },
                StageInput::S3Splits((lw.splits)(&bucket, &prefix)),
                StageOutput::Act(action.clone()),
            );
        }
        ChainBase::Wide(wide) => {
            let (compute, parents, partitions) = lw.wide_inputs(&wide, ops);
            lw.push(
                parents,
                compute,
                StageInput::Shuffle { partitions },
                StageOutput::Act(action.clone()),
            );
        }
        ChainBase::Cached(parts) => {
            lw.push(
                Vec::new(),
                StageCompute::CachedScan { ops },
                StageInput::CacheParts(parts.to_vec()),
                StageOutput::Act(action.clone()),
            );
        }
    }
    let plan = PhysicalPlan {
        plan_id: next_plan_id(),
        stages: lw.stages,
        action,
        query: None,
        weather: None,
    };
    debug_assert!(plan.validate().is_ok(), "{:?}", plan.validate());
    plan
}

/// In-progress lowering state: stages are appended parents-first, so
/// ids come out topologically ordered by construction.
struct Lowering<'a> {
    stages: Vec<Stage>,
    /// Planned shuffle-producer stages by (lineage node identity,
    /// partition count) — the sub-lineage sharing map.
    memo: HashMap<(usize, usize), u32>,
    splits: &'a dyn Fn(&str, &str) -> Vec<InputSplit>,
    resolution: &'a CacheResolution,
}

impl Lowering<'_> {
    fn push(
        &mut self,
        parents: Vec<u32>,
        compute: StageCompute,
        input: StageInput,
        output: StageOutput,
    ) -> u32 {
        let id = self.stages.len() as u32;
        self.stages.push(Stage { id, parents, compute, input, output });
        id
    }

    /// Plan (or reuse) the stage that computes `rdd`'s record stream and
    /// hash-partitions it into a `partitions`-way shuffle. `combine` is
    /// the consuming reduce's map-side combine, when there is one.
    fn shuffle_producer(
        &mut self,
        rdd: &Rdd,
        partitions: usize,
        combine: Option<CombineFn>,
        share: bool,
    ) -> u32 {
        let key = (Arc::as_ptr(&rdd.node) as *const () as usize, partitions);
        if share {
            if let Some(&id) = self.memo.get(&key) {
                // Second consumer of this sub-lineage: the stage now fans
                // out on multiple edges. Map-side combine is a
                // per-consumer optimization, so a shared stream must ship
                // raw records.
                let out = &mut self.stages[id as usize].output;
                if let StageOutput::Shuffle { combine, .. } = out {
                    *combine = None;
                }
                return id;
            }
        }
        let (base, ops) = narrow_chain(rdd, self.resolution);
        let output = StageOutput::Shuffle { partitions, combine };
        let id = match base {
            ChainBase::Source { bucket, prefix } => self.push(
                Vec::new(),
                StageCompute::DynScan { ops },
                StageInput::S3Splits((self.splits)(&bucket, &prefix)),
                output,
            ),
            ChainBase::Wide(wide) => {
                let (compute, parents, in_parts) = self.wide_inputs(&wide, ops);
                self.push(parents, compute, StageInput::Shuffle { partitions: in_parts }, output)
            }
            ChainBase::Cached(parts) => self.push(
                Vec::new(),
                StageCompute::CachedScan { ops },
                StageInput::CacheParts(parts.to_vec()),
                output,
            ),
        };
        if share {
            self.memo.insert(key, id);
        }
        id
    }

    /// Compute + parent edges + input partition count for a stage whose
    /// input is wide node `wide`'s shuffle, with `post_ops` fused after
    /// the wide op.
    fn wide_inputs(&mut self, wide: &Rdd, post_ops: Vec<DynOp>) -> (StageCompute, Vec<u32>, usize) {
        match &*wide.node {
            RddNode::ReduceByKey { parent, partitions, combine } => {
                let p = self.shuffle_producer(parent, *partitions, Some(combine.clone()), true);
                (
                    StageCompute::DynReduce { combine: combine.clone(), post_ops },
                    vec![p],
                    *partitions,
                )
            }
            RddNode::CoGroup { left, right, partitions } => {
                let lp = self.shuffle_producer(left, *partitions, None, true);
                // Self-cogroup: both sides are the same lineage node, but
                // a stage cannot be listed twice in one parent list
                // (duplicate edges break queue lifecycle), so the right
                // side plans an unshared duplicate. Anything *below* it
                // still shares through the memo.
                let rp = if Arc::ptr_eq(&left.node, &right.node) {
                    self.shuffle_producer(right, *partitions, None, false)
                } else {
                    self.shuffle_producer(right, *partitions, None, true)
                };
                (StageCompute::DynCoGroup { post_ops }, vec![lp, rp], *partitions)
            }
            RddNode::TextFile { .. } | RddNode::Narrow { .. } | RddNode::Cached { .. } => {
                unreachable!("narrow_chain stops only at wide nodes")
            }
        }
    }
}

/// The dimension branch's op chain for the kernel join plans: parse the
/// weather CSV (`day_index,precip`) into `(I64 day, I64 precip_bucket)`
/// pairs, dropping malformed lines.
fn weather_dim_ops() -> Vec<DynOp> {
    vec![DynOp::FlatMap(Arc::new(|v: Value| {
        let Some(line) = v.as_str() else { return Vec::new() };
        let Some((day, precip)) = line.split_once(',') else { return Vec::new() };
        let (Ok(day), Ok(p)) = (day.trim().parse::<i64>(), precip.trim().parse::<f32>()) else {
            return Vec::new();
        };
        vec![Value::pair(Value::I64(day), Value::I64(precip_bucket(p) as i64))]
    }))]
}

/// Physical plan for a shuffle-join benchmark query (Q6J) — the exchange
/// operator the broadcast-lookup Q6 avoids:
///
/// ```text
///   stage 0  KernelScan  trips   -> shuffle(join partitions, day key)
///   stage 1  DynScan     weather -> shuffle(join partitions, day key)
///   stage 2  KernelJoin  <- s0, s1  -> shuffle(precip buckets)
///   stage 3  KernelReduce <- s2     -> Collect
/// ```
///
/// Both scan stages hash-partition on the *day* key (the partitioners
/// are aligned across the typed/dyn record types — see
/// `exec::shuffle::kernel_partition`), so each join task sees every
/// record for its slice of days from both sides, tagged per parent edge.
/// The join re-keys by precipitation bucket and a final reduce merges
/// per-bucket partials, exactly matching Q6's broadcast answer.
pub fn build_kernel_join_plan(
    query: QueryId,
    dataset: &Dataset,
    config: &FlintConfig,
) -> PhysicalPlan {
    let spec = query.spec();
    assert!(spec.reduce_partitions > 0, "a join query must shuffle");
    let join_parts = spec.reduce_partitions;
    let splits = input_splits(dataset, config.flint.input_split_bytes);
    let dim_splits: Vec<InputSplit> =
        split_ranges(dataset.weather_bytes, config.flint.input_split_bytes)
            .into_iter()
            .map(|(start, end)| InputSplit {
                bucket: dataset.bucket.clone(),
                key: dataset.weather_key.clone(),
                start,
                end,
                object_size: dataset.weather_bytes,
                stats: None, // the weather table has no trip-day manifest stats
            })
            .collect();

    let stages = vec![
        Stage {
            id: 0,
            parents: Vec::new(),
            compute: StageCompute::KernelScan { spec },
            input: StageInput::S3Splits(splits),
            output: StageOutput::Shuffle { partitions: join_parts, combine: None },
        },
        Stage {
            id: 1,
            parents: Vec::new(),
            compute: StageCompute::DynScan { ops: weather_dim_ops() },
            input: StageInput::S3Splits(dim_splits),
            output: StageOutput::Shuffle { partitions: join_parts, combine: None },
        },
        Stage {
            id: 2,
            parents: vec![0, 1],
            compute: StageCompute::KernelJoin { spec },
            input: StageInput::Shuffle { partitions: join_parts },
            output: StageOutput::Shuffle { partitions: PRECIP_BUCKETS, combine: None },
        },
        Stage {
            id: 3,
            parents: vec![2],
            compute: StageCompute::KernelReduce { spec },
            input: StageInput::Shuffle { partitions: PRECIP_BUCKETS },
            output: StageOutput::Act(Action::Collect),
        },
    ];
    let plan = PhysicalPlan {
        plan_id: next_plan_id(),
        stages,
        action: Action::Collect,
        query: Some(query),
        // No broadcast side table: the weather data rides the shuffle.
        weather: None,
    };
    debug_assert!(plan.validate().is_ok(), "{:?}", plan.validate());
    plan
}

/// One input branch of a multi-parent (union/cogroup) plan.
pub struct UnionBranch {
    /// Narrow op chain applied to this branch's lines; must emit pairs.
    pub ops: Vec<DynOp>,
    /// S3 splits this branch scans.
    pub splits: Vec<InputSplit>,
}

/// Multi-parent physical plan: N independent scan stages (one per
/// branch, possibly over different datasets) all hash-partition their
/// pairs into the same `partitions` space; a single reduce stage lists
/// **all** scan stages as parents and drains every branch's queue for
/// its partition — the `union(...).reduceByKey(...)` / cogroup shape
/// that joins and multi-dataset queries build on. This is the plan shape
/// the serial pre-DAG driver could not express.
pub fn build_union_plan(
    branches: Vec<UnionBranch>,
    partitions: usize,
    combine: CombineFn,
    post_ops: Vec<DynOp>,
    action: Action,
) -> PhysicalPlan {
    assert!(!branches.is_empty(), "union plan needs at least one branch");
    assert!(partitions > 0, "union plan needs at least one partition");
    let n = branches.len();
    let mut stages: Vec<Stage> = branches
        .into_iter()
        .enumerate()
        .map(|(i, b)| Stage {
            id: i as u32,
            parents: Vec::new(),
            compute: StageCompute::DynScan { ops: b.ops },
            input: StageInput::S3Splits(b.splits),
            output: StageOutput::Shuffle { partitions, combine: Some(combine.clone()) },
        })
        .collect();
    stages.push(Stage {
        id: n as u32,
        parents: (0..n as u32).collect(),
        compute: StageCompute::DynReduce { combine, post_ops },
        input: StageInput::Shuffle { partitions },
        output: StageOutput::Act(action.clone()),
    });
    let plan = PhysicalPlan {
        plan_id: next_plan_id(),
        stages,
        action,
        query: None,
        weather: None,
    };
    debug_assert!(plan.validate().is_ok(), "{:?}", plan.validate());
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::value::Value;
    use std::sync::Arc;

    fn fake_splits(n: usize) -> Vec<InputSplit> {
        (0..n)
            .map(|i| InputSplit {
                bucket: "b".into(),
                key: format!("k{i}"),
                start: 0,
                end: 100,
                object_size: 100,
                stats: None,
            })
            .collect()
    }

    #[test]
    fn dyn_plan_two_stages() {
        let rdd = Rdd::text_file("b", "p")
            .map(|v| Value::pair(v, Value::I64(1)))
            .reduce_by_key(4, |a, b| {
                Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap())
            });
        let plan = lower(&rdd, Action::Collect, &|_, _| fake_splits(3));
        assert_eq!(plan.stages.len(), 2);
        assert_eq!(plan.stages[0].num_tasks(), 3);
        assert_eq!(plan.stages[1].num_tasks(), 4);
        assert!(matches!(plan.stages[1].compute, StageCompute::DynReduce { .. }));
        assert!(plan.query.is_none());
        assert_eq!(plan.total_tasks(), 7);
        assert_eq!(plan.stages[0].parents, Vec::<u32>::new());
        assert_eq!(plan.stages[1].parents, vec![0]);
        assert!(
            matches!(plan.stages[0].output, StageOutput::Shuffle { combine: Some(_), .. }),
            "single-consumer reduce keeps the map-side combine"
        );
        plan.validate().unwrap();
    }

    #[test]
    fn dyn_map_only_plan() {
        let rdd = Rdd::text_file("b", "p").filter(|_| true);
        let plan = lower(&rdd, Action::Count, &|_, _| fake_splits(2));
        assert_eq!(plan.stages.len(), 1);
        assert!(matches!(plan.stages[0].output, StageOutput::Act(Action::Count)));
        plan.validate().unwrap();
    }

    #[test]
    fn explain_renders_topology() {
        let rdd = Rdd::text_file("b", "p")
            .map(|v| Value::pair(v, Value::I64(1)))
            .reduce_by_key(4, |a, _| a);
        let plan = lower(&rdd, Action::Collect, &|_, _| fake_splits(3));
        let text = plan.explain();
        assert!(text.contains("stage 0"), "{text}");
        assert!(text.contains("sqs x4"), "{text}");
        assert!(text.contains("<- s0"), "parent edges rendered: {text}");
    }

    #[test]
    fn plan_ids_unique() {
        let rdd = Rdd::text_file("b", "p");
        let a = lower(&rdd, Action::Count, &|_, _| fake_splits(1));
        let b = lower(&rdd, Action::Count, &|_, _| fake_splits(1));
        assert_ne!(a.plan_id, b.plan_id);
    }

    #[test]
    fn chained_reduces_lower_to_a_stage_per_shuffle() {
        let rdd = Rdd::text_file("b", "p")
            .map(|v| Value::pair(v, Value::I64(1)))
            .reduce_by_key(4, |a, _| a)
            .map(|v| v)
            .reduce_by_key(2, |a, _| a)
            .filter(|_| true);
        let plan = lower(&rdd, Action::Collect, &|_, _| fake_splits(3));
        assert_eq!(plan.stages.len(), 3);
        for id in [1usize, 2] {
            assert!(matches!(
                &plan.stages[id].compute,
                StageCompute::DynReduce { post_ops, .. } if post_ops.len() == 1
            ));
        }
        assert_eq!(plan.stages[1].parents, vec![0]);
        assert_eq!(plan.stages[2].parents, vec![1]);
        assert!(matches!(plan.stages[1].output, StageOutput::Shuffle { partitions: 2, .. }));
        plan.validate().unwrap();
    }

    #[test]
    fn reduce_by_key_downstream_of_cogroup_lowers_to_four_stages() {
        // The shape the old per-shape planner panicked on
        // ("not supported yet"): cogroup, then a further shuffle.
        let left = Rdd::text_file("b", "l/").map(|v| v);
        let right = Rdd::text_file("b", "r/");
        let rdd = left
            .cogroup(&right, 4)
            .map(|v| v)
            .reduce_by_key(2, |a, _| a);
        let plan = lower(&rdd, Action::Collect, &|_, prefix| {
            fake_splits(if prefix == "l/" { 3 } else { 2 })
        });
        assert_eq!(plan.stages.len(), 4, "{}", plan.explain());
        assert!(matches!(plan.stages[0].compute, StageCompute::DynScan { .. }));
        assert!(matches!(plan.stages[1].compute, StageCompute::DynScan { .. }));
        let StageCompute::DynCoGroup { post_ops } = &plan.stages[2].compute else {
            panic!("stage 2 is the cogroup: {:?}", plan.stages[2].compute)
        };
        assert_eq!(post_ops.len(), 1, "the map between cogroup and reduce fuses here");
        assert_eq!(plan.stages[2].parents, vec![0, 1]);
        assert!(
            matches!(
                plan.stages[2].output,
                StageOutput::Shuffle { partitions: 2, combine: Some(_) }
            ),
            "cogroup shuffles into the downstream reduce with its map-side combine"
        );
        assert!(matches!(plan.stages[3].compute, StageCompute::DynReduce { .. }));
        assert_eq!(plan.stages[3].parents, vec![2]);
        plan.validate().unwrap();
    }

    #[test]
    fn shared_sublineage_plans_once_and_fans_out() {
        // base feeds two different reduces: one scan stage, two edges.
        let base = Rdd::text_file("b", "p").map(|v| Value::pair(v, Value::I64(1)));
        let a = base.reduce_by_key(4, |a, _| a);
        let b = base.reduce_by_key(4, |_, b| b);
        let rdd = a.join(&b, 3);
        let plan = lower(&rdd, Action::Collect, &|_, _| fake_splits(5));
        let text = plan.explain();
        assert_eq!(plan.stages.len(), 4, "one shared scan, two reduces, one join:\n{text}");
        assert!(matches!(plan.stages[0].compute, StageCompute::DynScan { .. }));
        assert_eq!(plan.children(0), vec![1, 2], "the scan's shuffle fans out on two edges");
        assert!(
            matches!(plan.stages[0].output, StageOutput::Shuffle { combine: None, .. }),
            "a shared stream ships raw records (map-side combine is per-consumer)"
        );
        assert_eq!(plan.stages[3].parents, vec![1, 2]);
        plan.validate().unwrap();
    }

    #[test]
    fn shared_sublineage_with_different_partition_counts_plans_twice() {
        let base = Rdd::text_file("b", "p").map(|v| Value::pair(v, Value::I64(1)));
        let a = base.reduce_by_key(4, |a, _| a);
        let b = base.reduce_by_key(5, |a, _| a);
        let plan = lower(&a.join(&b, 3), Action::Collect, &|_, _| fake_splits(2));
        // Partition counts differ, so the scan cannot share one shuffle.
        assert_eq!(plan.stages.len(), 5, "{}", plan.explain());
        plan.validate().unwrap();
    }

    #[test]
    fn self_cogroup_duplicates_the_top_stage_but_shares_below() {
        let base = Rdd::text_file("b", "p")
            .map(|v| Value::pair(v, Value::I64(1)))
            .reduce_by_key(4, |a, _| a);
        let plan = lower(&base.cogroup(&base, 4), Action::Collect, &|_, _| fake_splits(2));
        // scan (shared), reduce, duplicate reduce, cogroup.
        assert_eq!(plan.stages.len(), 4, "{}", plan.explain());
        assert_eq!(plan.children(0), vec![1, 2], "the scan below the self-cogroup IS shared");
        let cg = &plan.stages[3];
        assert_eq!(cg.parents, vec![1, 2], "no duplicate parent edge");
        plan.validate().unwrap();
    }

    fn add_combine() -> CombineFn {
        Arc::new(|a: Value, b: Value| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()))
    }

    #[test]
    fn union_plan_has_multi_parent_reduce() {
        let branches = vec![
            UnionBranch { ops: Vec::new(), splits: fake_splits(3) },
            UnionBranch { ops: Vec::new(), splits: fake_splits(2) },
        ];
        let plan = build_union_plan(branches, 4, add_combine(), Vec::new(), Action::Collect);
        assert_eq!(plan.stages.len(), 3);
        assert_eq!(plan.stages[2].parents, vec![0, 1], "reduce lists both scans");
        assert_eq!(plan.stages[2].num_tasks(), 4);
        assert_eq!(plan.children(0), vec![2]);
        assert_eq!(plan.children(1), vec![2]);
        plan.validate().unwrap();
        let text = plan.explain();
        assert!(text.contains("<- s0, s1"), "{text}");
    }

    #[test]
    fn join_lineage_lowers_to_a_two_scan_diamond() {
        let left = Rdd::text_file("b", "l/").map(|v| v);
        let right = Rdd::text_file("b", "r/");
        let rdd = left.join(&right, 4);
        let plan = lower(&rdd, Action::Collect, &|_, prefix| {
            fake_splits(if prefix == "l/" { 3 } else { 2 })
        });
        assert_eq!(plan.stages.len(), 3);
        assert_eq!(plan.stages[0].num_tasks(), 3, "left branch splits resolved by prefix");
        assert_eq!(plan.stages[1].num_tasks(), 2, "right branch splits resolved by prefix");
        let StageCompute::DynCoGroup { post_ops } = &plan.stages[2].compute else {
            panic!("join lowers to a cogroup stage: {:?}", plan.stages[2].compute)
        };
        assert_eq!(post_ops.len(), 1, "the join's cross-product flatMap");
        assert_eq!(plan.stages[2].parents, vec![0, 1]);
        plan.validate().unwrap();
        let text = plan.explain();
        assert!(text.contains("DynCoGroup"), "{text}");
        assert!(text.contains("<- s0, s1"), "{text}");
    }

    fn fake_parts(n: usize) -> Arc<Vec<CachePart>> {
        Arc::new(
            (0..n)
                .map(|i| CachePart {
                    bucket: "flint-cache".into(),
                    key: format!("fp-0000000000000000/part-{i:05}"),
                    bytes: 100,
                    mem: None,
                })
                .collect(),
        )
    }

    #[test]
    fn unresolved_cache_marker_is_transparent() {
        let build = |cached: bool| {
            let base = Rdd::text_file("b", "p").map(|v| Value::pair(v, Value::I64(1)));
            let base = if cached { base.cache() } else { base };
            base.reduce_by_key(4, |a, _| a)
        };
        let plain = lower(&build(false), Action::Collect, &|_, _| fake_splits(3));
        let marked = lower(&build(true), Action::Collect, &|_, _| fake_splits(3));
        assert_eq!(plain.explain().lines().count(), marked.explain().lines().count());
        assert_eq!(marked.stages.len(), 2, "{}", marked.explain());
        assert!(matches!(marked.stages[0].compute, StageCompute::DynScan { .. }));
        assert!(
            matches!(marked.stages[0].output, StageOutput::Shuffle { combine: Some(_), .. }),
            "a transparent marker must not disturb the map-side combine"
        );
        marked.validate().unwrap();
    }

    #[test]
    fn resolved_cache_truncates_the_plan() {
        // scan -> shuffle -> reduce, cached, then one narrow op on top:
        // with the cut resolved the whole shuffle below disappears.
        let cached = Rdd::text_file("b", "p")
            .map(|v| Value::pair(v, Value::I64(1)))
            .reduce_by_key(4, |a, _| a)
            .cache();
        let rdd = cached.map(|v| v);
        let mut res = CacheResolution::default();
        res.insert(CacheResolution::node_key(&cached), fake_parts(4));
        let plan = lower_resolved(&rdd, Action::Collect, &|_, _| fake_splits(3), &res);
        assert_eq!(plan.stages.len(), 1, "{}", plan.explain());
        let StageCompute::CachedScan { ops } = &plan.stages[0].compute else {
            panic!("expected CachedScan: {:?}", plan.stages[0].compute)
        };
        assert_eq!(ops.len(), 1, "only the op above the cut survives");
        assert!(matches!(&plan.stages[0].input, StageInput::CacheParts(p) if p.len() == 4));
        assert_eq!(plan.stages[0].num_tasks(), 4, "one task per cached partition");
        assert!(plan.explain().contains("cache x4"), "{}", plan.explain());
        plan.validate().unwrap();
    }

    #[test]
    fn resolved_cache_feeds_a_downstream_shuffle() {
        let cached = Rdd::text_file("b", "p").map(|v| Value::pair(v, Value::I64(1))).cache();
        let rdd = cached.reduce_by_key(2, |a, _| a);
        let mut res = CacheResolution::default();
        res.insert(CacheResolution::node_key(&cached), fake_parts(3));
        let plan = lower_resolved(&rdd, Action::Collect, &|_, _| fake_splits(5), &res);
        assert_eq!(plan.stages.len(), 2, "{}", plan.explain());
        assert!(matches!(plan.stages[0].compute, StageCompute::CachedScan { .. }));
        assert!(
            matches!(
                plan.stages[0].output,
                StageOutput::Shuffle { partitions: 2, combine: Some(_) }
            ),
            "a cached scan feeding a reduce keeps the map-side combine"
        );
        assert_eq!(plan.stages[0].num_tasks(), 3, "cache partitions, not S3 splits");
        assert!(matches!(plan.stages[1].compute, StageCompute::DynReduce { .. }));
        plan.validate().unwrap();
    }

    #[test]
    fn shared_cached_cut_plans_once_in_a_diamond() {
        let cached = Rdd::text_file("b", "p").map(|v| Value::pair(v, Value::I64(1))).cache();
        let a = cached.reduce_by_key(4, |a, _| a);
        let b = cached.reduce_by_key(4, |_, b| b);
        let rdd = a.join(&b, 3);
        let mut res = CacheResolution::default();
        res.insert(CacheResolution::node_key(&cached), fake_parts(2));
        let plan = lower_resolved(&rdd, Action::Collect, &|_, _| fake_splits(5), &res);
        assert_eq!(plan.stages.len(), 4, "one shared cached scan:\n{}", plan.explain());
        assert!(matches!(plan.stages[0].compute, StageCompute::CachedScan { .. }));
        assert_eq!(plan.children(0), vec![1, 2], "the cached scan fans out on two edges");
        assert!(
            matches!(plan.stages[0].output, StageOutput::Shuffle { combine: None, .. }),
            "a shared cached stream ships raw records"
        );
        plan.validate().unwrap();
    }

    #[test]
    fn validate_rejects_cache_parts_with_parents() {
        let cached = Rdd::text_file("b", "p").map(|v| Value::pair(v, Value::I64(1))).cache();
        let mut res = CacheResolution::default();
        res.insert(CacheResolution::node_key(&cached), fake_parts(2));
        let mut plan =
            lower_resolved(&cached.reduce_by_key(2, |a, _| a), Action::Collect, &|_, _| {
                fake_splits(1)
            }, &res);
        plan.stages[1].input = StageInput::CacheParts(fake_parts(2).to_vec());
        assert!(plan.validate().is_err(), "cache-cut stages are sources");
    }

    #[test]
    fn weather_dim_ops_parse_and_drop_garbage() {
        let ops = weather_dim_ops();
        let mut out = Vec::new();
        DynOp::apply_chain(&ops, Value::str("12,0.300"), &mut out);
        DynOp::apply_chain(&ops, Value::str("not,a number"), &mut out);
        DynOp::apply_chain(&ops, Value::str("garbage"), &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].key().as_i64(), Some(12));
        assert_eq!(out[0].val().as_i64(), Some(precip_bucket(0.3) as i64));
    }

    #[test]
    fn validate_rejects_broken_dags() {
        let mut plan = build_union_plan(
            vec![UnionBranch { ops: Vec::new(), splits: fake_splits(1) }],
            2,
            add_combine(),
            Vec::new(),
            Action::Collect,
        );
        // Forward edge: parent id >= own id.
        plan.stages[1].parents = vec![1];
        assert!(plan.validate().is_err());
        // Duplicate parent edge (two readers on one edge's queues).
        plan.stages[1].parents = vec![0, 0];
        assert!(plan.validate().is_err());
        // Partition mismatch.
        plan.stages[1].parents = vec![0];
        plan.stages[1].input = StageInput::Shuffle { partitions: 3 };
        assert!(plan.validate().is_err());
        // Shuffle input without parents.
        plan.stages[1].input = StageInput::Shuffle { partitions: 2 };
        plan.stages[1].parents = Vec::new();
        assert!(plan.validate().is_err());
    }
}
