//! DAG scheduling: lineage (or a benchmark query) → [`PhysicalPlan`] —
//! the stage/task structure both engines execute.

use crate::compute::queries::{KernelSpec, QueryId};
use crate::compute::csv::split_ranges;
use crate::config::FlintConfig;
use crate::data::Dataset;
use crate::plan::rdd::{CombineFn, DynOp, Rdd};
use crate::plan::task::InputSplit;

/// What the final stage does with its output.
#[derive(Clone)]
pub enum Action {
    /// Return a total row count to the driver (Q0, `rdd.count()`).
    Count,
    /// Materialize grouped/collected records at the driver (`collect`).
    Collect,
    /// Write text output to `bucket/prefix` (`saveAsTextFile`).
    SaveAsText { bucket: String, prefix: String },
}

impl std::fmt::Debug for Action {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Action::Count => f.write_str("Count"),
            Action::Collect => f.write_str("Collect"),
            Action::SaveAsText { bucket, prefix } => write!(f, "SaveAsText({bucket}/{prefix})"),
        }
    }
}

/// Where a stage reads from.
#[derive(Debug, Clone)]
pub enum StageInput {
    /// First stage: byte-range splits of S3 objects.
    S3Splits(Vec<InputSplit>),
    /// Later stages: one task per shuffle partition of the previous stage.
    Shuffle { partitions: usize },
}

/// Where a stage writes to.
#[derive(Clone)]
pub enum StageOutput {
    /// Hash-partitioned shuffle into `partitions` queues (or S3 objects,
    /// per the configured shuffle backend).
    Shuffle { partitions: usize, combine: Option<CombineFn> },
    /// Final stage: feed the action.
    Act(Action),
}

impl std::fmt::Debug for StageOutput {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StageOutput::Shuffle { partitions, .. } => write!(f, "Shuffle({partitions})"),
            StageOutput::Act(a) => write!(f, "Act({a:?})"),
        }
    }
}

/// The per-record work a stage performs.
#[derive(Clone)]
pub enum StageCompute {
    /// Typed fast path: parse trips into columnar batches, run the fused
    /// filter+histogram kernel (native or PJRT artifact).
    KernelScan { spec: KernelSpec },
    /// Typed reduce: merge `(bucket, (sum, count))` partials.
    KernelReduce { spec: KernelSpec },
    /// Generic path: apply a dynamic op chain to each input line.
    DynScan { ops: Vec<DynOp> },
    /// Generic reduce: combine pair values by key, then apply a post
    /// chain.
    DynReduce { combine: CombineFn, post_ops: Vec<DynOp> },
}

impl std::fmt::Debug for StageCompute {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StageCompute::KernelScan { spec } => write!(f, "KernelScan({})", spec.query),
            StageCompute::KernelReduce { spec } => write!(f, "KernelReduce({})", spec.query),
            StageCompute::DynScan { ops } => write!(f, "DynScan({} ops)", ops.len()),
            StageCompute::DynReduce { post_ops, .. } => {
                write!(f, "DynReduce(+{} post ops)", post_ops.len())
            }
        }
    }
}

/// One barrier-synchronized stage.
#[derive(Debug, Clone)]
pub struct Stage {
    pub id: u32,
    pub compute: StageCompute,
    pub input: StageInput,
    pub output: StageOutput,
}

impl Stage {
    /// Number of tasks this stage launches.
    pub fn num_tasks(&self) -> usize {
        match &self.input {
            StageInput::S3Splits(splits) => splits.len(),
            StageInput::Shuffle { partitions } => *partitions,
        }
    }
}

/// A complete physical plan.
#[derive(Debug, Clone)]
pub struct PhysicalPlan {
    /// Unique id (scopes queue names, shuffle keys, the plan registry).
    pub plan_id: String,
    pub stages: Vec<Stage>,
    pub action: Action,
    /// Set when this is a benchmark-query plan (enables the PJRT path and
    /// the weather side input for Q6).
    pub query: Option<QueryId>,
    /// Weather side-table S3 location, when any stage needs it.
    pub weather: Option<(String, String)>,
}

impl PhysicalPlan {
    /// Total tasks across stages.
    pub fn total_tasks(&self) -> usize {
        self.stages.iter().map(Stage::num_tasks).sum()
    }

    /// Render the stage/queue topology (the `flint explain` output and
    /// the Figure 1 analogue).
    pub fn explain(&self) -> String {
        let mut out = format!("plan {} ({:?})\n", self.plan_id, self.action);
        for s in &self.stages {
            let input = match &s.input {
                StageInput::S3Splits(sp) => format!("s3 x{}", sp.len()),
                StageInput::Shuffle { partitions } => format!("sqs x{partitions}"),
            };
            out.push_str(&format!(
                "  stage {}: [{input}] -> {:?} -> {:?} ({} tasks)\n",
                s.id,
                s.compute,
                s.output,
                s.num_tasks()
            ));
        }
        out
    }
}

/// Compute the input splits for a dataset.
pub fn input_splits(dataset: &Dataset, split_bytes: u64) -> Vec<InputSplit> {
    let mut splits = Vec::new();
    for (key, size) in &dataset.objects {
        for (start, end) in split_ranges(*size, split_bytes) {
            splits.push(InputSplit {
                bucket: dataset.bucket.clone(),
                key: key.clone(),
                start,
                end,
                object_size: *size,
            });
        }
    }
    splits
}

fn next_plan_id() -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(0);
    format!("plan-{}", NEXT.fetch_add(1, Ordering::Relaxed))
}

/// Physical plan for a benchmark query (typed kernel path). Q0 is
/// map-only + Count; everything else is scan → shuffle → reduce →
/// Collect, exactly the two-stage shape the paper's Figure 1 shows.
pub fn build_kernel_plan(query: QueryId, dataset: &Dataset, config: &FlintConfig) -> PhysicalPlan {
    let spec = query.spec();
    let splits = input_splits(dataset, config.flint.input_split_bytes);
    let weather = spec
        .needs_weather()
        .then(|| (dataset.bucket.clone(), dataset.weather_key.clone()));

    let mut stages = Vec::new();
    if spec.reduce_partitions == 0 {
        stages.push(Stage {
            id: 0,
            compute: StageCompute::KernelScan { spec },
            input: StageInput::S3Splits(splits),
            output: StageOutput::Act(Action::Count),
        });
        return PhysicalPlan {
            plan_id: next_plan_id(),
            stages,
            action: Action::Count,
            query: Some(query),
            weather,
        };
    }

    stages.push(Stage {
        id: 0,
        compute: StageCompute::KernelScan { spec },
        input: StageInput::S3Splits(splits),
        output: StageOutput::Shuffle { partitions: spec.reduce_partitions, combine: None },
    });
    stages.push(Stage {
        id: 1,
        compute: StageCompute::KernelReduce { spec },
        input: StageInput::Shuffle { partitions: spec.reduce_partitions },
        output: StageOutput::Act(Action::Collect),
    });
    PhysicalPlan {
        plan_id: next_plan_id(),
        stages,
        action: Action::Collect,
        query: Some(query),
        weather,
    }
}

/// Physical plan for a generic RDD lineage + action.
pub fn build_dyn_plan(
    rdd: &Rdd,
    action: Action,
    dataset_lookup: impl Fn(&str, &str) -> Vec<InputSplit>,
) -> PhysicalPlan {
    let lin = rdd.linearize();
    let splits = dataset_lookup(&lin.source.0, &lin.source.1);
    let mut stages = Vec::new();
    let n = lin.segments.len();
    let mut pending_combine: Option<CombineFn> = None;
    for (i, seg) in lin.segments.into_iter().enumerate() {
        let input = if i == 0 {
            StageInput::S3Splits(splits.clone())
        } else {
            let partitions = match &stages[i - 1] {
                Stage { output: StageOutput::Shuffle { partitions, .. }, .. } => *partitions,
                _ => unreachable!("non-first segment follows a shuffle"),
            };
            StageInput::Shuffle { partitions }
        };
        let output = match &seg.shuffle {
            Some((partitions, combine)) => StageOutput::Shuffle {
                partitions: *partitions,
                combine: Some(combine.clone()),
            },
            None => StageOutput::Act(action.clone()),
        };
        let compute = if i == 0 {
            StageCompute::DynScan { ops: seg.ops }
        } else {
            StageCompute::DynReduce {
                combine: pending_combine.clone().expect("combine from previous segment"),
                post_ops: seg.ops,
            }
        };
        pending_combine = seg.shuffle.map(|(_, c)| c);
        debug_assert!(i < n);
        stages.push(Stage { id: i as u32, compute, input, output });
    }
    PhysicalPlan {
        plan_id: next_plan_id(),
        stages,
        action,
        query: None,
        weather: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::value::Value;

    fn fake_splits(n: usize) -> Vec<InputSplit> {
        (0..n)
            .map(|i| InputSplit {
                bucket: "b".into(),
                key: format!("k{i}"),
                start: 0,
                end: 100,
                object_size: 100,
            })
            .collect()
    }

    #[test]
    fn dyn_plan_two_stages() {
        let rdd = Rdd::text_file("b", "p")
            .map(|v| Value::pair(v, Value::I64(1)))
            .reduce_by_key(4, |a, b| {
                Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap())
            });
        let plan = build_dyn_plan(&rdd, Action::Collect, |_, _| fake_splits(3));
        assert_eq!(plan.stages.len(), 2);
        assert_eq!(plan.stages[0].num_tasks(), 3);
        assert_eq!(plan.stages[1].num_tasks(), 4);
        assert!(matches!(plan.stages[1].compute, StageCompute::DynReduce { .. }));
        assert!(plan.query.is_none());
        assert_eq!(plan.total_tasks(), 7);
    }

    #[test]
    fn dyn_map_only_plan() {
        let rdd = Rdd::text_file("b", "p").filter(|_| true);
        let plan = build_dyn_plan(&rdd, Action::Count, |_, _| fake_splits(2));
        assert_eq!(plan.stages.len(), 1);
        assert!(matches!(plan.stages[0].output, StageOutput::Act(Action::Count)));
    }

    #[test]
    fn explain_renders_topology() {
        let rdd = Rdd::text_file("b", "p")
            .map(|v| Value::pair(v, Value::I64(1)))
            .reduce_by_key(4, |a, _| a);
        let plan = build_dyn_plan(&rdd, Action::Collect, |_, _| fake_splits(3));
        let text = plan.explain();
        assert!(text.contains("stage 0"), "{text}");
        assert!(text.contains("sqs x4"), "{text}");
    }

    #[test]
    fn plan_ids_unique() {
        let rdd = Rdd::text_file("b", "p");
        let a = build_dyn_plan(&rdd, Action::Count, |_, _| fake_splits(1));
        let b = build_dyn_plan(&rdd, Action::Count, |_, _| fake_splits(1));
        assert_ne!(a.plan_id, b.plan_id);
    }
}
