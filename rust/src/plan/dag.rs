//! The physical plan: a **stage DAG** — lineage (or a benchmark query) →
//! [`PhysicalPlan`] — the structure both engines execute.
//!
//! Stages carry explicit ids and *parent edges*: a stage consumes the
//! shuffle output of every parent listed in [`Stage::parents`], so plans
//! are no longer restricted to linear chains — multi-parent stages
//! (unions, cogroups, and eventually joins) are first-class. Stages are
//! stored in topological order (`parents[i] < id` for every edge), which
//! [`PhysicalPlan::validate`] enforces; the driver executes them in that
//! order while the virtual clock (`simtime::schedule`) decides how much
//! of their execution *overlaps* under the pipelined SQS semantics of
//! §III-A (reducers long-poll their queues while mappers still flush).

use crate::compute::csv::split_ranges;
use crate::compute::queries::{KernelSpec, QueryId};
use crate::config::FlintConfig;
use crate::data::Dataset;
use crate::plan::rdd::{CombineFn, DynOp, Rdd};
use crate::plan::task::InputSplit;

/// What the final stage does with its output.
#[derive(Clone)]
pub enum Action {
    /// Return a total row count to the driver (Q0, `rdd.count()`).
    Count,
    /// Materialize grouped/collected records at the driver (`collect`).
    Collect,
    /// Write text output to `bucket/prefix` (`saveAsTextFile`).
    SaveAsText { bucket: String, prefix: String },
}

impl std::fmt::Debug for Action {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Action::Count => f.write_str("Count"),
            Action::Collect => f.write_str("Collect"),
            Action::SaveAsText { bucket, prefix } => write!(f, "SaveAsText({bucket}/{prefix})"),
        }
    }
}

/// Where a stage reads from.
#[derive(Debug, Clone)]
pub enum StageInput {
    /// Source stage: byte-range splits of S3 objects.
    S3Splits(Vec<InputSplit>),
    /// Downstream stage: one task per shuffle partition, draining that
    /// partition's queue of **every** parent stage.
    Shuffle { partitions: usize },
}

/// Where a stage writes to.
#[derive(Clone)]
pub enum StageOutput {
    /// Hash-partitioned shuffle into `partitions` queues (or S3 objects,
    /// per the configured shuffle backend).
    Shuffle { partitions: usize, combine: Option<CombineFn> },
    /// Final stage: feed the action.
    Act(Action),
}

impl std::fmt::Debug for StageOutput {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StageOutput::Shuffle { partitions, .. } => write!(f, "Shuffle({partitions})"),
            StageOutput::Act(a) => write!(f, "Act({a:?})"),
        }
    }
}

/// The per-record work a stage performs.
#[derive(Clone)]
pub enum StageCompute {
    /// Typed fast path: parse trips into columnar batches, run the fused
    /// filter+histogram kernel (native or PJRT artifact).
    KernelScan { spec: KernelSpec },
    /// Typed reduce: merge `(bucket, (sum, count))` partials.
    KernelReduce { spec: KernelSpec },
    /// Generic path: apply a dynamic op chain to each input line.
    DynScan { ops: Vec<DynOp> },
    /// Generic reduce: combine pair values by key, then apply a post
    /// chain.
    DynReduce { combine: CombineFn, post_ops: Vec<DynOp> },
}

impl std::fmt::Debug for StageCompute {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StageCompute::KernelScan { spec } => write!(f, "KernelScan({})", spec.query),
            StageCompute::KernelReduce { spec } => write!(f, "KernelReduce({})", spec.query),
            StageCompute::DynScan { ops } => write!(f, "DynScan({} ops)", ops.len()),
            StageCompute::DynReduce { post_ops, .. } => {
                write!(f, "DynReduce(+{} post ops)", post_ops.len())
            }
        }
    }
}

/// One stage of the DAG.
#[derive(Debug, Clone)]
pub struct Stage {
    pub id: u32,
    /// Stage ids whose shuffle output this stage consumes. Empty for S3
    /// scan stages. Every parent must shuffle into the same partition
    /// count (this stage's task count).
    pub parents: Vec<u32>,
    pub compute: StageCompute,
    pub input: StageInput,
    pub output: StageOutput,
}

impl Stage {
    /// Number of tasks this stage launches.
    pub fn num_tasks(&self) -> usize {
        match &self.input {
            StageInput::S3Splits(splits) => splits.len(),
            StageInput::Shuffle { partitions } => *partitions,
        }
    }
}

/// A complete physical plan.
#[derive(Debug, Clone)]
pub struct PhysicalPlan {
    /// Unique id (scopes queue names, shuffle keys, the plan registry).
    pub plan_id: String,
    /// Stages in topological order (`parents[i] < id`).
    pub stages: Vec<Stage>,
    pub action: Action,
    /// Set when this is a benchmark-query plan (enables the PJRT path and
    /// the weather side input for Q6).
    pub query: Option<QueryId>,
    /// Weather side-table S3 location, when any stage needs it.
    pub weather: Option<(String, String)>,
}

impl PhysicalPlan {
    /// Total tasks across stages.
    pub fn total_tasks(&self) -> usize {
        self.stages.iter().map(Stage::num_tasks).sum()
    }

    /// The stage with id `id` (ids are dense and equal their index).
    pub fn stage(&self, id: u32) -> &Stage {
        &self.stages[id as usize]
    }

    /// Stage ids that consume `id`'s shuffle output.
    pub fn children(&self, id: u32) -> Vec<u32> {
        self.stages
            .iter()
            .filter(|s| s.parents.contains(&id))
            .map(|s| s.id)
            .collect()
    }

    /// Check the DAG invariants the driver and virtual clock rely on:
    /// dense ids in topological order, edge consistency (a parent exists,
    /// shuffles, and shuffles into the consumer's partition count), and
    /// shuffle inputs backed by at least one parent.
    pub fn validate(&self) -> Result<(), String> {
        for (i, s) in self.stages.iter().enumerate() {
            if s.id as usize != i {
                return Err(format!("stage {} stored at index {i}", s.id));
            }
            // Duplicate parent entries would double-decrement the
            // driver's per-edge queue refcounts.
            let mut dedup = s.parents.clone();
            dedup.sort_unstable();
            dedup.dedup();
            if dedup.len() != s.parents.len() {
                return Err(format!("stage {} lists a duplicate parent", s.id));
            }
            for &p in &s.parents {
                if p >= s.id {
                    return Err(format!(
                        "stage {} lists parent {p}: not topologically ordered",
                        s.id
                    ));
                }
                let parent = &self.stages[p as usize];
                let StageOutput::Shuffle { partitions, .. } = &parent.output else {
                    return Err(format!("stage {} parent {p} does not shuffle", s.id));
                };
                if let StageInput::Shuffle { partitions: want } = &s.input {
                    if partitions != want {
                        return Err(format!(
                            "stage {} wants {want} partitions but parent {p} shuffles {partitions}",
                            s.id
                        ));
                    }
                }
            }
            match &s.input {
                StageInput::Shuffle { .. } if s.parents.is_empty() => {
                    return Err(format!("stage {} reads a shuffle but has no parents", s.id));
                }
                StageInput::S3Splits(_) if !s.parents.is_empty() => {
                    return Err(format!("stage {} reads S3 but lists parents", s.id));
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Render the stage/queue topology (the `flint explain` output and
    /// the Figure 1 analogue). Parent edges are shown as `<- sN`.
    pub fn explain(&self) -> String {
        let mut out = format!("plan {} ({:?})\n", self.plan_id, self.action);
        for s in &self.stages {
            let input = match &s.input {
                StageInput::S3Splits(sp) => format!("s3 x{}", sp.len()),
                StageInput::Shuffle { partitions } => format!("sqs x{partitions}"),
            };
            let deps = if s.parents.is_empty() {
                String::new()
            } else {
                format!(
                    "  <- {}",
                    s.parents
                        .iter()
                        .map(|p| format!("s{p}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            };
            out.push_str(&format!(
                "  stage {}: [{input}] -> {:?} -> {:?} ({} tasks){deps}\n",
                s.id,
                s.compute,
                s.output,
                s.num_tasks()
            ));
        }
        out
    }
}

/// Compute the input splits for a dataset.
pub fn input_splits(dataset: &Dataset, split_bytes: u64) -> Vec<InputSplit> {
    let mut splits = Vec::new();
    for (key, size) in &dataset.objects {
        for (start, end) in split_ranges(*size, split_bytes) {
            splits.push(InputSplit {
                bucket: dataset.bucket.clone(),
                key: key.clone(),
                start,
                end,
                object_size: *size,
            });
        }
    }
    splits
}

fn next_plan_id() -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(0);
    format!("plan-{}", NEXT.fetch_add(1, Ordering::Relaxed))
}

/// Physical plan for a benchmark query (typed kernel path). Q0 is
/// map-only + Count; everything else is scan → shuffle → reduce →
/// Collect, exactly the two-stage shape the paper's Figure 1 shows.
pub fn build_kernel_plan(query: QueryId, dataset: &Dataset, config: &FlintConfig) -> PhysicalPlan {
    let spec = query.spec();
    let splits = input_splits(dataset, config.flint.input_split_bytes);
    let weather = spec
        .needs_weather()
        .then(|| (dataset.bucket.clone(), dataset.weather_key.clone()));

    let mut stages = Vec::new();
    if spec.reduce_partitions == 0 {
        stages.push(Stage {
            id: 0,
            parents: Vec::new(),
            compute: StageCompute::KernelScan { spec },
            input: StageInput::S3Splits(splits),
            output: StageOutput::Act(Action::Count),
        });
        return PhysicalPlan {
            plan_id: next_plan_id(),
            stages,
            action: Action::Count,
            query: Some(query),
            weather,
        };
    }

    stages.push(Stage {
        id: 0,
        parents: Vec::new(),
        compute: StageCompute::KernelScan { spec },
        input: StageInput::S3Splits(splits),
        output: StageOutput::Shuffle { partitions: spec.reduce_partitions, combine: None },
    });
    stages.push(Stage {
        id: 1,
        parents: vec![0],
        compute: StageCompute::KernelReduce { spec },
        input: StageInput::Shuffle { partitions: spec.reduce_partitions },
        output: StageOutput::Act(Action::Collect),
    });
    PhysicalPlan {
        plan_id: next_plan_id(),
        stages,
        action: Action::Collect,
        query: Some(query),
        weather,
    }
}

/// Physical plan for a generic RDD lineage + action.
pub fn build_dyn_plan(
    rdd: &Rdd,
    action: Action,
    dataset_lookup: impl Fn(&str, &str) -> Vec<InputSplit>,
) -> PhysicalPlan {
    let lin = rdd.linearize();
    let splits = dataset_lookup(&lin.source.0, &lin.source.1);
    let mut stages = Vec::new();
    let n = lin.segments.len();
    let mut pending_combine: Option<CombineFn> = None;
    for (i, seg) in lin.segments.into_iter().enumerate() {
        let (input, parents) = if i == 0 {
            (StageInput::S3Splits(splits.clone()), Vec::new())
        } else {
            let partitions = match &stages[i - 1] {
                Stage { output: StageOutput::Shuffle { partitions, .. }, .. } => *partitions,
                _ => unreachable!("non-first segment follows a shuffle"),
            };
            (StageInput::Shuffle { partitions }, vec![(i - 1) as u32])
        };
        let output = match &seg.shuffle {
            Some((partitions, combine)) => StageOutput::Shuffle {
                partitions: *partitions,
                combine: Some(combine.clone()),
            },
            None => StageOutput::Act(action.clone()),
        };
        let compute = if i == 0 {
            StageCompute::DynScan { ops: seg.ops }
        } else {
            StageCompute::DynReduce {
                combine: pending_combine.clone().expect("combine from previous segment"),
                post_ops: seg.ops,
            }
        };
        pending_combine = seg.shuffle.map(|(_, c)| c);
        debug_assert!(i < n);
        stages.push(Stage { id: i as u32, parents, compute, input, output });
    }
    PhysicalPlan {
        plan_id: next_plan_id(),
        stages,
        action,
        query: None,
        weather: None,
    }
}

/// One input branch of a multi-parent (union/cogroup) plan.
pub struct UnionBranch {
    /// Narrow op chain applied to this branch's lines; must emit pairs.
    pub ops: Vec<DynOp>,
    /// S3 splits this branch scans.
    pub splits: Vec<InputSplit>,
}

/// Multi-parent physical plan: N independent scan stages (one per
/// branch, possibly over different datasets) all hash-partition their
/// pairs into the same `partitions` space; a single reduce stage lists
/// **all** scan stages as parents and drains every branch's queue for
/// its partition — the `union(...).reduceByKey(...)` / cogroup shape
/// that joins and multi-dataset queries build on. This is the plan shape
/// the serial pre-DAG driver could not express.
pub fn build_union_plan(
    branches: Vec<UnionBranch>,
    partitions: usize,
    combine: CombineFn,
    post_ops: Vec<DynOp>,
    action: Action,
) -> PhysicalPlan {
    assert!(!branches.is_empty(), "union plan needs at least one branch");
    assert!(partitions > 0, "union plan needs at least one partition");
    let n = branches.len();
    let mut stages: Vec<Stage> = branches
        .into_iter()
        .enumerate()
        .map(|(i, b)| Stage {
            id: i as u32,
            parents: Vec::new(),
            compute: StageCompute::DynScan { ops: b.ops },
            input: StageInput::S3Splits(b.splits),
            output: StageOutput::Shuffle { partitions, combine: Some(combine.clone()) },
        })
        .collect();
    stages.push(Stage {
        id: n as u32,
        parents: (0..n as u32).collect(),
        compute: StageCompute::DynReduce { combine, post_ops },
        input: StageInput::Shuffle { partitions },
        output: StageOutput::Act(action.clone()),
    });
    let plan = PhysicalPlan {
        plan_id: next_plan_id(),
        stages,
        action,
        query: None,
        weather: None,
    };
    debug_assert!(plan.validate().is_ok(), "{:?}", plan.validate());
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::value::Value;
    use std::sync::Arc;

    fn fake_splits(n: usize) -> Vec<InputSplit> {
        (0..n)
            .map(|i| InputSplit {
                bucket: "b".into(),
                key: format!("k{i}"),
                start: 0,
                end: 100,
                object_size: 100,
            })
            .collect()
    }

    #[test]
    fn dyn_plan_two_stages() {
        let rdd = Rdd::text_file("b", "p")
            .map(|v| Value::pair(v, Value::I64(1)))
            .reduce_by_key(4, |a, b| {
                Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap())
            });
        let plan = build_dyn_plan(&rdd, Action::Collect, |_, _| fake_splits(3));
        assert_eq!(plan.stages.len(), 2);
        assert_eq!(plan.stages[0].num_tasks(), 3);
        assert_eq!(plan.stages[1].num_tasks(), 4);
        assert!(matches!(plan.stages[1].compute, StageCompute::DynReduce { .. }));
        assert!(plan.query.is_none());
        assert_eq!(plan.total_tasks(), 7);
        assert_eq!(plan.stages[0].parents, Vec::<u32>::new());
        assert_eq!(plan.stages[1].parents, vec![0]);
        plan.validate().unwrap();
    }

    #[test]
    fn dyn_map_only_plan() {
        let rdd = Rdd::text_file("b", "p").filter(|_| true);
        let plan = build_dyn_plan(&rdd, Action::Count, |_, _| fake_splits(2));
        assert_eq!(plan.stages.len(), 1);
        assert!(matches!(plan.stages[0].output, StageOutput::Act(Action::Count)));
        plan.validate().unwrap();
    }

    #[test]
    fn explain_renders_topology() {
        let rdd = Rdd::text_file("b", "p")
            .map(|v| Value::pair(v, Value::I64(1)))
            .reduce_by_key(4, |a, _| a);
        let plan = build_dyn_plan(&rdd, Action::Collect, |_, _| fake_splits(3));
        let text = plan.explain();
        assert!(text.contains("stage 0"), "{text}");
        assert!(text.contains("sqs x4"), "{text}");
        assert!(text.contains("<- s0"), "parent edges rendered: {text}");
    }

    #[test]
    fn plan_ids_unique() {
        let rdd = Rdd::text_file("b", "p");
        let a = build_dyn_plan(&rdd, Action::Count, |_, _| fake_splits(1));
        let b = build_dyn_plan(&rdd, Action::Count, |_, _| fake_splits(1));
        assert_ne!(a.plan_id, b.plan_id);
    }

    fn add_combine() -> CombineFn {
        Arc::new(|a: Value, b: Value| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()))
    }

    #[test]
    fn union_plan_has_multi_parent_reduce() {
        let branches = vec![
            UnionBranch { ops: Vec::new(), splits: fake_splits(3) },
            UnionBranch { ops: Vec::new(), splits: fake_splits(2) },
        ];
        let plan = build_union_plan(branches, 4, add_combine(), Vec::new(), Action::Collect);
        assert_eq!(plan.stages.len(), 3);
        assert_eq!(plan.stages[2].parents, vec![0, 1], "reduce lists both scans");
        assert_eq!(plan.stages[2].num_tasks(), 4);
        assert_eq!(plan.children(0), vec![2]);
        assert_eq!(plan.children(1), vec![2]);
        plan.validate().unwrap();
        let text = plan.explain();
        assert!(text.contains("<- s0, s1"), "{text}");
    }

    #[test]
    fn validate_rejects_broken_dags() {
        let mut plan = build_union_plan(
            vec![UnionBranch { ops: Vec::new(), splits: fake_splits(1) }],
            2,
            add_combine(),
            Vec::new(),
            Action::Collect,
        );
        // Forward edge: parent id >= own id.
        plan.stages[1].parents = vec![1];
        assert!(plan.validate().is_err());
        // Duplicate parent edge (would double-decrement queue refcounts).
        plan.stages[1].parents = vec![0, 0];
        assert!(plan.validate().is_err());
        // Partition mismatch.
        plan.stages[1].parents = vec![0];
        plan.stages[1].input = StageInput::Shuffle { partitions: 3 };
        assert!(plan.validate().is_err());
        // Shuffle input without parents.
        plan.stages[1].input = StageInput::Shuffle { partitions: 2 };
        plan.stages[1].parents = Vec::new();
        assert!(plan.validate().is_err());
    }
}
