//! Task descriptors — what the Flint scheduler serializes into each
//! Lambda invocation's request payload (§III: "the serialized code to
//! execute, metadata about the relationship of this task to the entire
//! physical plan, and metadata about where the executor reads its input
//! and writes its output").

use crate::data::ObjectStats;
use crate::util::json::Json;
use std::sync::Arc;

/// A byte-range split of one S3 object.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InputSplit {
    pub bucket: String,
    pub key: String,
    pub start: u64,
    pub end: u64,
    pub object_size: u64,
    /// Day/month statistics of the *object* this split belongs to (every
    /// split inherits its object's ranges, which stay conservative for
    /// any byte subrange). `None` when the manifest carried no stats —
    /// the scan then simply cannot prune.
    pub stats: Option<ObjectStats>,
}

impl InputSplit {
    pub fn len(&self) -> u64 {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("bucket", self.bucket.as_str())
            .set("key", self.key.as_str())
            .set("start", self.start)
            .set("end", self.end)
            .set("object_size", self.object_size);
        if let Some(st) = &self.stats {
            j = j.set(
                "stats",
                Json::obj()
                    .set("min_day", st.min_day as i64)
                    .set("max_day", st.max_day as i64)
                    .set("min_month", st.min_month as i64)
                    .set("max_month", st.max_month as i64)
                    .set("rows", st.rows),
            );
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<InputSplit, String> {
        let stats = match j.get("stats") {
            None => None,
            Some(s) => Some(ObjectStats {
                min_day: s.req_i64("min_day").map_err(|e| e.to_string())? as i32,
                max_day: s.req_i64("max_day").map_err(|e| e.to_string())? as i32,
                min_month: s.req_i64("min_month").map_err(|e| e.to_string())? as i32,
                max_month: s.req_i64("max_month").map_err(|e| e.to_string())? as i32,
                rows: s.req_u64("rows").map_err(|e| e.to_string())?,
            }),
        };
        Ok(InputSplit {
            bucket: j.req_str("bucket").map_err(|e| e.to_string())?.to_string(),
            key: j.req_str("key").map_err(|e| e.to_string())?.to_string(),
            start: j.req_u64("start").map_err(|e| e.to_string())?,
            end: j.req_u64("end").map_err(|e| e.to_string())?,
            object_size: j.req_u64("object_size").map_err(|e| e.to_string())?,
            stats,
        })
    }
}

/// One materialized partition of a cached lineage cut: a committed S3
/// object of `Value::encode` records, optionally shadowed by a
/// warm-container memory-tier copy.
#[derive(Clone, PartialEq, Eq)]
pub struct CachePart {
    pub bucket: String,
    pub key: String,
    /// Size of the committed S3 object (admission/eviction accounting).
    pub bytes: u64,
    /// Memory-tier copy. Present only while the cache registry's memory
    /// tier holds this partition; never serialized into payloads — the
    /// bytes model data already resident in a kept-alive container, not
    /// bytes shipped with the invocation.
    pub mem: Option<Arc<Vec<u8>>>,
}

impl std::fmt::Debug for CachePart {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CachePart({}/{}, {}B{})",
            self.bucket,
            self.key,
            self.bytes,
            if self.mem.is_some() { ", mem" } else { "" }
        )
    }
}

impl CachePart {
    pub fn to_json(&self) -> Json {
        // `mem` intentionally omitted: the memory tier is container
        // state, not payload.
        Json::obj()
            .set("bucket", self.bucket.as_str())
            .set("key", self.key.as_str())
            .set("bytes", self.bytes)
    }
}

/// Where a task reads.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TaskInput {
    Split(InputSplit),
    /// Drain shuffle partition `partition` of **every** producing stage
    /// listed in `parents` (queue or S3 prefix chosen by the engine's
    /// shuffle backend). A single-parent chain has one entry; unions and
    /// cogroups list all of their map stages.
    ShufflePartition { partition: u32, parents: Vec<u32> },
    /// Read one materialized partition of a cached lineage cut
    /// (`CachedScan` stages).
    CachedPart(CachePart),
}

/// Where a task writes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TaskOutput {
    Shuffle { partitions: u32 },
    /// Results return to the driver in the Lambda response.
    Driver,
    /// Results written to S3 (`saveAsTextFile`).
    S3 { bucket: String, prefix: String },
}

/// Chaining state (§III-B): how far into the input the previous
/// invocation got, plus the serialized partial aggregate when the task is
/// a reducer.
#[derive(Debug, Clone, PartialEq)]
pub struct ResumeState {
    /// Bytes of the split already consumed (map tasks); the continuation
    /// range-GETs only the remainder.
    pub input_offset: u64,
    /// Input fully consumed; only the output flush remains (a chain
    /// point taken when the final shuffle flush wouldn't fit under the
    /// duration cap).
    pub input_done: bool,
    /// Rows already emitted (diagnostics / determinism checks).
    pub rows_done: u64,
    /// Serialized partial aggregate (reduce tasks); spilled to S3 by the
    /// scheduler when it exceeds the payload budget.
    pub partial: Vec<u8>,
    /// Next shuffle sequence number per output partition, so a chained
    /// continuation keeps the `(producer, seq)` stream contiguous.
    pub next_seqs: Vec<u64>,
    /// How many times this task has chained so far.
    pub links: u32,
}

/// The full task descriptor.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskDescriptor {
    pub plan_id: String,
    pub stage_id: u32,
    pub task_index: u32,
    pub attempt: u32,
    pub input: TaskInput,
    pub output: TaskOutput,
    pub resume: Option<ResumeState>,
    /// Estimated bytes of serialized task code (stands in for the pickled
    /// closure; kernel tasks reference a named artifact instead).
    pub code_bytes: u64,
}

impl TaskDescriptor {
    /// Stable producer id for shuffle dedup (§VI): *attempt-independent*,
    /// so a retried task re-sends byte-identical `(producer, seq)` pairs
    /// and the reduce side can drop both SQS duplicates and retry
    /// duplicates.
    pub fn producer_id(&self) -> u64 {
        ((self.stage_id as u64) << 32) | self.task_index as u64
    }

    /// Serialize to the Lambda request payload (JSON). The paper's 6 MB
    /// payload limit applies to these bytes plus the resume state.
    pub fn to_payload(&self) -> Vec<u8> {
        let input = match &self.input {
            TaskInput::Split(s) => Json::obj().set("split", s.to_json()),
            TaskInput::ShufflePartition { partition, parents } => Json::obj()
                .set("partition", *partition as u64)
                .set(
                    "parents",
                    Json::Arr(parents.iter().map(|p| Json::from(*p as u64)).collect()),
                ),
            TaskInput::CachedPart(p) => Json::obj().set("cache_part", p.to_json()),
        };
        let output = match &self.output {
            TaskOutput::Shuffle { partitions } => {
                Json::obj().set("kind", "shuffle").set("partitions", *partitions as u64)
            }
            TaskOutput::Driver => Json::obj().set("kind", "driver"),
            TaskOutput::S3 { bucket, prefix } => Json::obj()
                .set("kind", "s3")
                .set("bucket", bucket.as_str())
                .set("prefix", prefix.as_str()),
        };
        let mut j = Json::obj()
            .set("plan_id", self.plan_id.as_str())
            .set("stage_id", self.stage_id as u64)
            .set("task_index", self.task_index as u64)
            .set("attempt", self.attempt as u64)
            .set("input", input)
            .set("output", output)
            .set("code_bytes", self.code_bytes);
        if let Some(r) = &self.resume {
            // Partial state rides along base64-free: JSON-escaped latin1
            // would bloat; model it as a length + checksum (the bytes
            // themselves live in the driver/S3 per the payload-split
            // machinery, which is what real Flint does for large states).
            j = j.set(
                "resume",
                Json::obj()
                    .set("input_offset", r.input_offset)
                    .set("rows_done", r.rows_done)
                    .set("partial_bytes", r.partial.len() as u64)
                    .set("links", r.links as u64),
            );
        }
        let mut payload = j.encode().into_bytes();
        // The partial aggregate itself counts against the payload limit.
        if let Some(r) = &self.resume {
            payload.extend_from_slice(&r.partial);
        }
        // The "serialized code" counts too.
        payload.extend(std::iter::repeat_n(b'#', self.code_bytes as usize));
        payload
    }

    /// Payload size without materializing (scheduler-side limit checks).
    pub fn payload_len(&self) -> u64 {
        self.to_payload().len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_task() -> TaskDescriptor {
        TaskDescriptor {
            plan_id: "plan-1".into(),
            stage_id: 0,
            task_index: 3,
            attempt: 0,
            input: TaskInput::Split(InputSplit {
                bucket: "b".into(),
                key: "k".into(),
                start: 0,
                end: 100,
                object_size: 200,
                stats: None,
            }),
            output: TaskOutput::Shuffle { partitions: 30 },
            resume: None,
            code_bytes: 512,
        }
    }

    #[test]
    fn producer_id_ignores_attempt() {
        let mut t = sample_task();
        let id0 = t.producer_id();
        t.attempt = 2;
        assert_eq!(t.producer_id(), id0, "dedup requires attempt-stable producer ids");
        t.stage_id = 1;
        assert_ne!(t.producer_id(), id0);
        t.stage_id = 0;
        t.task_index = 4;
        assert_ne!(t.producer_id(), id0);
    }

    #[test]
    fn producer_ids_collision_free_across_stages() {
        // The multi-parent reduce paths thread ONE dedup set through all
        // parent edges on the claim that producer ids embed the producing
        // stage. Pin it: stage id occupies the high 32 bits and task
        // index the low 32, so no (stage, task) pair aliases another —
        // cross-parent (producer, seq) spaces are disjoint.
        let mut ids = std::collections::HashSet::new();
        for stage in 0..8u32 {
            for task in 0..64u32 {
                let mut t = sample_task();
                t.stage_id = stage;
                t.task_index = task;
                assert!(
                    ids.insert(t.producer_id()),
                    "producer id collision at stage {stage} task {task}"
                );
                assert_eq!(t.producer_id() >> 32, stage as u64);
                assert_eq!(t.producer_id() & 0xffff_ffff, task as u64);
            }
        }
    }

    #[test]
    fn payload_includes_code_and_partial() {
        let mut t = sample_task();
        t.code_bytes = 1000;
        let base = t.payload_len();
        t.code_bytes = 2000; // same digit width in the JSON header
        assert_eq!(t.payload_len(), base + 1000);
        t.resume = Some(ResumeState {
            input_offset: 10,
            input_done: false,
            rows_done: 5,
            partial: vec![0u8; 2000],
            next_seqs: vec![0; 4],
            links: 1,
        });
        assert!(t.payload_len() > base + 512 + 2000);
    }

    #[test]
    fn payload_parses_as_json_prefix() {
        let t = sample_task();
        let payload = t.to_payload();
        // JSON document ends at the matching brace before code padding.
        let json_end = payload.iter().rposition(|&b| b == b'}').unwrap() + 1;
        let j = Json::parse(std::str::from_utf8(&payload[..json_end]).unwrap()).unwrap();
        assert_eq!(j.req_str("plan_id").unwrap(), "plan-1");
        assert_eq!(j.req_u64("task_index").unwrap(), 3);
        let split = InputSplit::from_json(j.get("input").unwrap().get("split").unwrap()).unwrap();
        assert_eq!(split.end, 100);
    }

    #[test]
    fn shuffle_input_payload_carries_parents() {
        let mut t = sample_task();
        t.input = TaskInput::ShufflePartition { partition: 2, parents: vec![0, 1] };
        t.output = TaskOutput::Driver;
        let payload = t.to_payload();
        let json_end = payload.iter().rposition(|&b| b == b'}').unwrap() + 1;
        let j = Json::parse(std::str::from_utf8(&payload[..json_end]).unwrap()).unwrap();
        let input = j.get("input").unwrap();
        let parents = input.req_arr("parents").unwrap();
        assert_eq!(parents.len(), 2);
        assert_eq!(parents[1].as_u64(), Some(1));
        assert_eq!(input.req_u64("partition").unwrap(), 2);
    }

    #[test]
    fn cached_part_payload_omits_mem_tier() {
        let mut t = sample_task();
        t.input = TaskInput::CachedPart(CachePart {
            bucket: "flint-cache".into(),
            key: "fp-0011223344556677/part-00000".into(),
            bytes: 4096,
            mem: None,
        });
        t.output = TaskOutput::Driver;
        let base = t.payload_len();
        if let TaskInput::CachedPart(p) = &mut t.input {
            p.mem = Some(Arc::new(vec![0u8; 100_000]));
        }
        assert_eq!(
            t.payload_len(),
            base,
            "memory-tier bytes are container state, not payload bytes"
        );
        let payload = t.to_payload();
        let json_end = payload.iter().rposition(|&b| b == b'}').unwrap() + 1;
        let j = Json::parse(std::str::from_utf8(&payload[..json_end]).unwrap()).unwrap();
        let part = j.get("input").unwrap().get("cache_part").unwrap();
        assert_eq!(part.req_str("bucket").unwrap(), "flint-cache");
        assert_eq!(part.req_u64("bytes").unwrap(), 4096);
        assert!(part.get("mem").is_none());
    }

    #[test]
    fn split_roundtrip() {
        let s = InputSplit {
            bucket: "in".into(),
            key: "trips/part-00001.csv".into(),
            start: 64,
            end: 128,
            object_size: 999,
            stats: None,
        };
        assert_eq!(InputSplit::from_json(&s.to_json()).unwrap(), s);
        assert_eq!(s.len(), 64);
        // Stats survive the payload roundtrip too (pruning happens on
        // the executor side, from the deserialized descriptor).
        let with_stats = InputSplit {
            stats: Some(crate::data::ObjectStats {
                min_day: 120,
                max_day: 240,
                min_month: 3,
                max_month: 8,
                rows: 4321,
            }),
            ..s
        };
        assert_eq!(InputSplit::from_json(&with_stats.to_json()).unwrap(), with_stats);
    }
}
