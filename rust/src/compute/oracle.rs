//! Ground-truth oracle: evaluates any query single-threaded, directly
//! over the dataset's objects, through the *simplest possible* code path
//! (full `TripRecord` parse, BTreeMap aggregation — none of the engines'
//! batching/shuffle machinery). Engine outputs are asserted against this
//! in the integration tests and `examples/end_to_end.rs`.

use crate::compute::queries::{KernelSpec, KeySource, QueryId, QueryResult, ValueSource};
use crate::data::schema::TripRecord;
use crate::data::weather::WeatherTable;
use crate::data::{chrono, Dataset};
use crate::services::SimEnv;
use std::collections::BTreeMap;

/// Evaluate `query` directly. Slow and simple by design.
pub fn evaluate(env: &SimEnv, dataset: &Dataset, query: QueryId) -> QueryResult {
    let spec = query.spec();
    let weather = if spec.needs_weather() {
        let (obj, _) = env
            .s3()
            .get_object(&dataset.bucket, &dataset.weather_key, env.flint_read_profile())
            .expect("weather table present");
        Some(WeatherTable::from_csv(&obj).expect("weather parses"))
    } else {
        None
    };

    let mut count = 0u64;
    let mut groups: BTreeMap<i64, (f64, f64)> = BTreeMap::new();

    for (key, _) in &dataset.objects {
        let (obj, _) = env
            .s3()
            .get_object(&dataset.bucket, key, env.flint_read_profile())
            .expect("object present");
        for line in obj.split(|&b| b == b'\n') {
            if line.is_empty() {
                continue;
            }
            count += 1;
            if spec.key == KeySource::None {
                continue;
            }
            let Some(rec) = TripRecord::parse_csv(line) else { continue };
            if !passes(&spec, &rec) {
                continue;
            }
            let Some(k) = bucket_key(&spec, &rec, weather.as_ref()) else { continue };
            let v = match spec.value {
                ValueSource::One => 1.0,
                ValueSource::CreditFlag => {
                    if rec.payment_type == crate::data::schema::PAYMENT_CREDIT {
                        1.0
                    } else {
                        0.0
                    }
                }
            };
            let e = groups.entry(k).or_insert((0.0, 0.0));
            e.0 += v;
            e.1 += 1.0;
        }
    }

    if spec.key == KeySource::None {
        QueryResult::Count(count)
    } else {
        QueryResult::Buckets(groups.into_iter().map(|(k, (s, c))| (k, s, c)).collect())
    }
}

fn passes(spec: &KernelSpec, rec: &TripRecord) -> bool {
    spec.bbox.contains(rec.dropoff_lon, rec.dropoff_lat) && rec.tip_amount >= spec.tip_min
}

fn bucket_key(spec: &KernelSpec, rec: &TripRecord, weather: Option<&WeatherTable>) -> Option<i64> {
    let k = match spec.key {
        KeySource::None => return None,
        KeySource::Hour => chrono::hour_of_day(rec.dropoff_ts) as i64,
        KeySource::Month => chrono::month_index(rec.dropoff_ts) as i64,
        KeySource::MonthTaxiType => {
            chrono::month_index(rec.dropoff_ts) as i64 * 2 + rec.taxi_type as i64
        }
        KeySource::PrecipBucket => {
            weather.expect("weather").bucket(chrono::day_index(rec.dropoff_ts)) as i64
        }
    };
    if (0..spec.buckets as i64).contains(&k) {
        Some(k)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FlintConfig;
    use crate::data::generate_taxi_dataset;

    fn tiny() -> (SimEnv, Dataset) {
        let env = SimEnv::new(FlintConfig::for_tests());
        let ds = generate_taxi_dataset(&env, "trips", 4_000);
        (env, ds)
    }

    #[test]
    fn q0_counts_all_lines() {
        let (env, ds) = tiny();
        assert_eq!(evaluate(&env, &ds, QueryId::Q0), QueryResult::Count(4_000));
    }

    #[test]
    fn q1_q2_disjoint_and_nonempty() {
        let (env, ds) = tiny();
        let q1 = evaluate(&env, &ds, QueryId::Q1);
        let q2 = evaluate(&env, &ds, QueryId::Q2);
        let (QueryResult::Buckets(g), QueryResult::Buckets(c)) = (&q1, &q2) else {
            panic!("bucketed results expected")
        };
        let total_g: f64 = g.iter().map(|(_, _, c)| c).sum();
        let total_c: f64 = c.iter().map(|(_, _, c)| c).sum();
        assert!(total_g > 0.0, "goldman trips exist in 4k rows... (probabilistic but ~8 expected)");
        assert!(total_g < 100.0);
        assert!(total_c < 100.0);
    }

    #[test]
    fn q4_shares_between_zero_and_one() {
        let (env, ds) = tiny();
        let QueryResult::Buckets(rows) = evaluate(&env, &ds, QueryId::Q4) else {
            panic!()
        };
        assert!(!rows.is_empty());
        let total: f64 = rows.iter().map(|(_, _, c)| c).sum();
        assert_eq!(total as u64, 4_000, "Q4 counts every trip");
        for (_, credit, count) in rows {
            assert!(credit >= 0.0 && credit <= count);
        }
    }

    #[test]
    fn q6_buckets_cover_all_trips() {
        let (env, ds) = tiny();
        let QueryResult::Buckets(rows) = evaluate(&env, &ds, QueryId::Q6) else {
            panic!()
        };
        let total: f64 = rows.iter().map(|(_, _, c)| c).sum();
        assert_eq!(total as u64, 4_000);
        assert!(rows.len() >= 3, "multiple precip buckets populated: {rows:?}");
        // Dry bucket dominates.
        assert_eq!(rows[0].0, 0);
        assert!(rows[0].2 > total * 0.5);
    }
}
