//! Ground-truth oracle: evaluates any query single-threaded, directly
//! over the dataset's objects, through the *simplest possible* code path
//! (full `TripRecord` parse, BTreeMap aggregation — none of the engines'
//! batching/shuffle machinery). Engine outputs are asserted against this
//! in the integration tests and `examples/end_to_end.rs`.

use crate::compute::queries::{KernelSpec, KeySource, QueryId, QueryResult, ValueSource};
use crate::data::schema::TripRecord;
use crate::data::weather::WeatherTable;
use crate::data::{chrono, Dataset};
use crate::services::SimEnv;
use std::collections::BTreeMap;

/// Evaluate `query` directly. Slow and simple by design.
pub fn evaluate(env: &SimEnv, dataset: &Dataset, query: QueryId) -> QueryResult {
    if query.is_join() {
        return evaluate_join(env, dataset, query);
    }
    let spec = query.spec();
    let weather = if spec.needs_weather() {
        let (obj, _) = env
            .s3()
            .get_object(&dataset.bucket, &dataset.weather_key, env.flint_read_profile())
            .expect("weather table present");
        Some(WeatherTable::from_csv(&obj).expect("weather parses"))
    } else {
        None
    };

    let mut count = 0u64;
    let mut groups: BTreeMap<i64, (f64, f64)> = BTreeMap::new();

    for (key, _) in &dataset.objects {
        let (obj, _) = env
            .s3()
            .get_object(&dataset.bucket, key, env.flint_read_profile())
            .expect("object present");
        for line in obj.split(|&b| b == b'\n') {
            if line.is_empty() {
                continue;
            }
            count += 1;
            if spec.key == KeySource::None {
                continue;
            }
            let Some(rec) = TripRecord::parse_csv(line) else { continue };
            if !passes(&spec, &rec) {
                continue;
            }
            let Some(k) = bucket_key(&spec, &rec, weather.as_ref()) else { continue };
            let v = match spec.value {
                ValueSource::One => 1.0,
                ValueSource::CreditFlag => {
                    if rec.payment_type == crate::data::schema::PAYMENT_CREDIT {
                        1.0
                    } else {
                        0.0
                    }
                }
            };
            let e = groups.entry(k).or_insert((0.0, 0.0));
            e.0 += v;
            e.1 += 1.0;
        }
    }

    if spec.key == KeySource::None {
        QueryResult::Count(count)
    } else {
        QueryResult::Buckets(groups.into_iter().map(|(k, (s, c))| (k, s, c)).collect())
    }
}

/// Q6J ground truth computed as an actual equi-join — day-keyed trip
/// counts ⋈ the weather table's day→bucket rows — rather than Q6's
/// broadcast lookup. The two must agree (the weather table covers every
/// day a generated trip can fall on); `q6j_oracle_matches_q6` pins that.
fn evaluate_join(env: &SimEnv, dataset: &Dataset, query: QueryId) -> QueryResult {
    let spec = query.spec();
    // Dimension side: day index → precipitation bucket, from the same
    // CSV rendering the executors read (parse-rounded, like the engine).
    let (obj, _) = env
        .s3()
        .get_object(&dataset.bucket, &dataset.weather_key, env.flint_read_profile())
        .expect("weather table present");
    let weather = WeatherTable::from_csv(&obj).expect("weather parses");
    let dim: BTreeMap<i64, i64> = weather
        .precip
        .iter()
        .enumerate()
        .map(|(d, &p)| (d as i64, crate::data::weather::precip_bucket(p) as i64))
        .collect();

    // Fact side: per-day (value_sum, row_count) partials.
    let mut facts: BTreeMap<i64, (f64, f64)> = BTreeMap::new();
    for (key, _) in &dataset.objects {
        let (obj, _) = env
            .s3()
            .get_object(&dataset.bucket, key, env.flint_read_profile())
            .expect("object present");
        for line in obj.split(|&b| b == b'\n') {
            if line.is_empty() {
                continue;
            }
            let Some(rec) = TripRecord::parse_csv(line) else { continue };
            if !passes(&spec, &rec) {
                continue;
            }
            let d = chrono::day_index(rec.dropoff_ts) as i64;
            if !(0..spec.buckets as i64).contains(&d) {
                continue;
            }
            let v = match spec.value {
                ValueSource::One => 1.0,
                ValueSource::CreditFlag => {
                    if rec.payment_type == crate::data::schema::PAYMENT_CREDIT {
                        1.0
                    } else {
                        0.0
                    }
                }
            };
            let e = facts.entry(d).or_insert((0.0, 0.0));
            e.0 += v;
            e.1 += 1.0;
        }
    }

    // Inner join + re-key by the dimension value.
    let mut groups: BTreeMap<i64, (f64, f64)> = BTreeMap::new();
    for (d, (s, c)) in facts {
        let Some(&bucket) = dim.get(&d) else { continue };
        let e = groups.entry(bucket).or_insert((0.0, 0.0));
        e.0 += s;
        e.1 += c;
    }
    QueryResult::Buckets(groups.into_iter().map(|(k, (s, c))| (k, s, c)).collect())
}

fn passes(spec: &KernelSpec, rec: &TripRecord) -> bool {
    spec.bbox.contains(rec.dropoff_lon, rec.dropoff_lat) && rec.tip_amount >= spec.tip_min
}

fn bucket_key(spec: &KernelSpec, rec: &TripRecord, weather: Option<&WeatherTable>) -> Option<i64> {
    let k = match spec.key {
        KeySource::None => return None,
        KeySource::Hour => chrono::hour_of_day(rec.dropoff_ts) as i64,
        KeySource::Month => chrono::month_index(rec.dropoff_ts) as i64,
        KeySource::MonthTaxiType => {
            chrono::month_index(rec.dropoff_ts) as i64 * 2 + rec.taxi_type as i64
        }
        KeySource::PrecipBucket => {
            weather.expect("weather").bucket(chrono::day_index(rec.dropoff_ts)) as i64
        }
        // Join queries are evaluated by `evaluate_join`, never here.
        KeySource::Day => chrono::day_index(rec.dropoff_ts) as i64,
    };
    if (0..spec.buckets as i64).contains(&k) {
        Some(k)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FlintConfig;
    use crate::data::generate_taxi_dataset;

    fn tiny() -> (SimEnv, Dataset) {
        let env = SimEnv::new(FlintConfig::for_tests());
        let ds = generate_taxi_dataset(&env, "trips", 4_000);
        (env, ds)
    }

    #[test]
    fn q0_counts_all_lines() {
        let (env, ds) = tiny();
        assert_eq!(evaluate(&env, &ds, QueryId::Q0), QueryResult::Count(4_000));
    }

    #[test]
    fn q1_q2_disjoint_and_nonempty() {
        let (env, ds) = tiny();
        let q1 = evaluate(&env, &ds, QueryId::Q1);
        let q2 = evaluate(&env, &ds, QueryId::Q2);
        let (QueryResult::Buckets(g), QueryResult::Buckets(c)) = (&q1, &q2) else {
            panic!("bucketed results expected")
        };
        let total_g: f64 = g.iter().map(|(_, _, c)| c).sum();
        let total_c: f64 = c.iter().map(|(_, _, c)| c).sum();
        assert!(total_g > 0.0, "goldman trips exist in 4k rows... (probabilistic but ~8 expected)");
        assert!(total_g < 100.0);
        assert!(total_c < 100.0);
    }

    #[test]
    fn q4_shares_between_zero_and_one() {
        let (env, ds) = tiny();
        let QueryResult::Buckets(rows) = evaluate(&env, &ds, QueryId::Q4) else {
            panic!()
        };
        assert!(!rows.is_empty());
        let total: f64 = rows.iter().map(|(_, _, c)| c).sum();
        assert_eq!(total as u64, 4_000, "Q4 counts every trip");
        for (_, credit, count) in rows {
            assert!(credit >= 0.0 && credit <= count);
        }
    }

    #[test]
    fn q6j_oracle_matches_q6() {
        // The shuffle-join formulation and the broadcast lookup are the
        // same query: every generated trip's day is covered by the
        // weather table, so the inner join drops nothing.
        let (env, ds) = tiny();
        let join = evaluate(&env, &ds, QueryId::Q6J);
        let broadcast = evaluate(&env, &ds, QueryId::Q6);
        assert!(join.approx_eq(&broadcast), "{join:?} vs {broadcast:?}");
    }

    #[test]
    fn q6_buckets_cover_all_trips() {
        let (env, ds) = tiny();
        let QueryResult::Buckets(rows) = evaluate(&env, &ds, QueryId::Q6) else {
            panic!()
        };
        let total: f64 = rows.iter().map(|(_, _, c)| c).sum();
        assert_eq!(total as u64, 4_000);
        assert!(rows.len() >= 3, "multiple precip buckets populated: {rows:?}");
        // Dry bucket dominates.
        assert_eq!(rows[0].0, 0);
        assert!(rows[0].2 > total * 0.5);
    }
}
