//! Dynamic values for the generic RDD path — the PySpark-like API where
//! user code is arbitrary closures over records (`examples/quickstart.rs`
//! drives this path). The benchmarked queries use the typed kernel path
//! instead; this exists because Flint is a *general* execution engine,
//! not a seven-query appliance.
//!
//! Values serialize to a compact binary format for SQS shuffle transport
//! (tag byte + payload), mirroring how Flint pickles Python objects into
//! SQS message bodies.

use crate::util::fnv1a64;
use std::cmp::Ordering;

/// A dynamically-typed record value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    I64(i64),
    F64(f64),
    Str(String),
    /// A key-value pair (what shuffles operate on).
    Pair(Box<Value>, Box<Value>),
    List(Vec<Value>),
}

impl Value {
    pub fn pair(k: Value, v: Value) -> Value {
        Value::Pair(Box::new(k), Box::new(v))
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    /// Key of a pair (panics otherwise — shuffle stages require pairs,
    /// same as Spark's `reduceByKey` on non-pair RDDs failing at runtime).
    pub fn key(&self) -> &Value {
        match self {
            Value::Pair(k, _) => k,
            other => panic!("expected a key-value pair, got {other:?}"),
        }
    }

    pub fn val(&self) -> &Value {
        match self {
            Value::Pair(_, v) => v,
            other => panic!("expected a key-value pair, got {other:?}"),
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::I64(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::F64(v) => Some(*v),
            Value::I64(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Stable 64-bit hash (used by the hash partitioner; must not depend
    /// on process-level state, because map tasks run "anywhere").
    pub fn stable_hash(&self) -> u64 {
        let mut buf = Vec::with_capacity(16);
        self.encode_into(&mut buf);
        fnv1a64(&buf)
    }

    /// Binary encoding: tag byte + little-endian payload.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            Value::Null => out.push(0),
            Value::Bool(b) => {
                out.push(1);
                out.push(*b as u8);
            }
            Value::I64(v) => {
                out.push(2);
                out.extend_from_slice(&v.to_le_bytes());
            }
            Value::F64(v) => {
                out.push(3);
                out.extend_from_slice(&v.to_le_bytes());
            }
            Value::Str(s) => {
                out.push(4);
                out.extend_from_slice(&(s.len() as u32).to_le_bytes());
                out.extend_from_slice(s.as_bytes());
            }
            Value::Pair(k, v) => {
                out.push(5);
                k.encode_into(out);
                v.encode_into(out);
            }
            Value::List(items) => {
                out.push(6);
                out.extend_from_slice(&(items.len() as u32).to_le_bytes());
                for item in items {
                    item.encode_into(out);
                }
            }
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Exact length `encode_into` would produce, without allocating — the
    /// shuffle writer's byte-aware chunking asks this per record.
    pub fn encoded_len(&self) -> usize {
        match self {
            Value::Null => 1,
            Value::Bool(_) => 2,
            Value::I64(_) | Value::F64(_) => 9,
            Value::Str(s) => 5 + s.len(),
            Value::Pair(k, v) => 1 + k.encoded_len() + v.encoded_len(),
            Value::List(items) => 5 + items.iter().map(Value::encoded_len).sum::<usize>(),
        }
    }

    /// Decode one value from `bytes`, returning it and the bytes consumed.
    pub fn decode(bytes: &[u8]) -> Option<(Value, usize)> {
        let tag = *bytes.first()?;
        match tag {
            0 => Some((Value::Null, 1)),
            1 => Some((Value::Bool(*bytes.get(1)? != 0), 2)),
            2 => {
                let raw: [u8; 8] = bytes.get(1..9)?.try_into().ok()?;
                Some((Value::I64(i64::from_le_bytes(raw)), 9))
            }
            3 => {
                let raw: [u8; 8] = bytes.get(1..9)?.try_into().ok()?;
                Some((Value::F64(f64::from_le_bytes(raw)), 9))
            }
            4 => {
                let len_raw: [u8; 4] = bytes.get(1..5)?.try_into().ok()?;
                let len = u32::from_le_bytes(len_raw) as usize;
                let s = bytes.get(5..5 + len)?;
                Some((Value::Str(String::from_utf8(s.to_vec()).ok()?), 5 + len))
            }
            5 => {
                let (k, nk) = Value::decode(&bytes[1..])?;
                let (v, nv) = Value::decode(&bytes[1 + nk..])?;
                Some((Value::pair(k, v), 1 + nk + nv))
            }
            6 => {
                let len_raw: [u8; 4] = bytes.get(1..5)?.try_into().ok()?;
                let len = u32::from_le_bytes(len_raw) as usize;
                let mut pos = 5;
                let mut items = Vec::with_capacity(len.min(1024));
                for _ in 0..len {
                    let (v, n) = Value::decode(&bytes[pos..])?;
                    items.push(v);
                    pos += n;
                }
                Some((Value::List(items), pos))
            }
            _ => None,
        }
    }

    /// Decode a concatenated sequence of values.
    pub fn decode_stream(mut bytes: &[u8]) -> Option<Vec<Value>> {
        let mut out = Vec::new();
        while !bytes.is_empty() {
            let (v, n) = Value::decode(bytes)?;
            out.push(v);
            bytes = &bytes[n..];
        }
        Some(out)
    }

    /// Total-order comparison for deterministic result sorting (type tag
    /// first, then value; floats via total_cmp).
    pub fn total_cmp(&self, other: &Value) -> Ordering {
        fn rank(v: &Value) -> u8 {
            match v {
                Value::Null => 0,
                Value::Bool(_) => 1,
                Value::I64(_) => 2,
                Value::F64(_) => 3,
                Value::Str(_) => 4,
                Value::Pair(_, _) => 5,
                Value::List(_) => 6,
            }
        }
        match (self, other) {
            (Value::Bool(a), Value::Bool(b)) => a.cmp(b),
            (Value::I64(a), Value::I64(b)) => a.cmp(b),
            (Value::F64(a), Value::F64(b)) => a.total_cmp(b),
            (Value::Str(a), Value::Str(b)) => a.cmp(b),
            (Value::Pair(ak, av), Value::Pair(bk, bv)) => {
                ak.total_cmp(bk).then_with(|| av.total_cmp(bv))
            }
            (Value::List(a), Value::List(b)) => {
                for (x, y) in a.iter().zip(b.iter()) {
                    let c = x.total_cmp(y);
                    if c != Ordering::Equal {
                        return c;
                    }
                }
                a.len().cmp(&b.len())
            }
            (a, b) => rank(a).cmp(&rank(b)),
        }
    }

    /// Rough in-memory footprint (executor memory accounting).
    pub fn mem_bytes(&self) -> usize {
        match self {
            Value::Null | Value::Bool(_) => 8,
            Value::I64(_) | Value::F64(_) => 16,
            Value::Str(s) => 32 + s.len(),
            Value::Pair(k, v) => 16 + k.mem_bytes() + v.mem_bytes(),
            Value::List(items) => 32 + items.iter().map(Value::mem_bytes).sum::<usize>(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{forall, Gen};

    fn arbitrary_value(g: &mut Gen, depth: usize) -> Value {
        let max_kind = if depth == 0 { 5 } else { 7 };
        match g.usize(max_kind) {
            0 => Value::Null,
            1 => Value::Bool(g.bool()),
            2 => Value::I64(g.i64(i64::MIN / 2, i64::MAX / 2)),
            3 => Value::F64(g.f64(-1e12, 1e12)),
            4 => Value::Str(g.string(24)),
            5 => Value::pair(arbitrary_value(g, 0), arbitrary_value(g, 0)),
            _ => {
                let n = g.usize(4);
                Value::List((0..n).map(|_| arbitrary_value(g, depth - 1)).collect())
            }
        }
    }

    #[test]
    fn prop_encode_decode_roundtrip() {
        forall("value-roundtrip", 400, |g| {
            let v = arbitrary_value(g, 2);
            let enc = v.encode();
            match Value::decode(&enc) {
                Some((back, n)) if back == v && n == enc.len() => Ok(()),
                other => Err(format!("{v:?} -> {other:?}")),
            }
        });
    }

    #[test]
    fn prop_encoded_len_matches_encoding() {
        forall("value-encoded-len", 400, |g| {
            let v = arbitrary_value(g, 2);
            let enc = v.encode();
            if v.encoded_len() == enc.len() {
                Ok(())
            } else {
                Err(format!("{v:?}: encoded_len {} != {}", v.encoded_len(), enc.len()))
            }
        });
    }

    #[test]
    fn prop_stream_roundtrip() {
        forall("value-stream-roundtrip", 100, |g| {
            let vals: Vec<Value> = (0..g.usize(8)).map(|_| arbitrary_value(g, 1)).collect();
            let mut bytes = Vec::new();
            for v in &vals {
                v.encode_into(&mut bytes);
            }
            match Value::decode_stream(&bytes) {
                Some(back) if back == vals => Ok(()),
                other => Err(format!("{vals:?} -> {other:?}")),
            }
        });
    }

    #[test]
    fn hash_stability_and_spread() {
        // Same value -> same hash; different values overwhelmingly differ.
        assert_eq!(Value::I64(7).stable_hash(), Value::I64(7).stable_hash());
        let hashes: std::collections::HashSet<u64> =
            (0..1000).map(|i| Value::I64(i).stable_hash()).collect();
        assert!(hashes.len() > 990);
        // Typed differently -> different hash (tag byte).
        assert_ne!(Value::I64(1).stable_hash(), Value::F64(1.0).stable_hash());
    }

    #[test]
    fn pair_accessors() {
        let p = Value::pair(Value::I64(8), Value::F64(1.0));
        assert_eq!(p.key().as_i64(), Some(8));
        assert_eq!(p.val().as_f64(), Some(1.0));
    }

    #[test]
    #[should_panic(expected = "expected a key-value pair")]
    fn key_on_non_pair_panics() {
        Value::I64(3).key();
    }

    #[test]
    fn total_order_is_deterministic() {
        let mut vals = vec![
            Value::Str("b".into()),
            Value::I64(2),
            Value::Null,
            Value::I64(1),
            Value::Str("a".into()),
        ];
        vals.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(
            vals,
            vec![
                Value::Null,
                Value::I64(1),
                Value::I64(2),
                Value::Str("a".into()),
                Value::Str("b".into()),
            ]
        );
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Value::decode(&[]).is_none());
        assert!(Value::decode(&[99]).is_none());
        assert!(Value::decode(&[2, 1, 2]).is_none(), "truncated i64");
        assert!(Value::decode_stream(&[4, 255, 255, 255, 255]).is_none());
    }
}
