//! Query kernels: the native Rust implementation of the fused
//! *filter → key → masked histogram* loop, plus the key/value
//! preparation shared with the PJRT path.
//!
//! Two execution paths produce identical results:
//! * **native** ([`run_batch_native`]) — scalar Rust, used by the cluster
//!   baselines and as a fallback when artifacts are absent;
//! * **PJRT** ([`crate::runtime`]) — executes the AOT-lowered L2/L1
//!   artifact on the same prepared columns.
//!
//! The key precomputation (weather lookup, month×taxi composition) is
//! done here for both paths so the AOT kernel stays a pure dense
//! filter+histogram — the TPU-idiomatic formulation (DESIGN.md
//! §Hardware-Adaptation).

use crate::compute::batch::ColumnBatch;
use crate::compute::queries::{KernelSpec, KeySource, QueryResult, ValueSource};
use crate::data::weather::WeatherTable;

/// Histogram accumulator: per-bucket value sum and row count, plus the
/// total rows seen (Q0 and diagnostics).
#[derive(Debug, Clone, PartialEq)]
pub struct HistAccum {
    pub sums: Vec<f64>,
    pub counts: Vec<f64>,
    pub rows_seen: u64,
}

impl HistAccum {
    pub fn new(buckets: usize) -> HistAccum {
        HistAccum { sums: vec![0.0; buckets], counts: vec![0.0; buckets], rows_seen: 0 }
    }

    /// Merge another accumulator (reduce stage / combine artifact).
    pub fn merge(&mut self, other: &HistAccum) {
        assert_eq!(self.sums.len(), other.sums.len());
        for i in 0..self.sums.len() {
            self.sums[i] += other.sums[i];
            self.counts[i] += other.counts[i];
        }
        self.rows_seen += other.rows_seen;
    }

    /// Non-empty buckets as sorted `(key, sum, count)` rows.
    pub fn to_rows(&self) -> Vec<(i64, f64, f64)> {
        (0..self.sums.len())
            .filter(|&i| self.counts[i] > 0.0)
            .map(|i| (i as i64, self.sums[i], self.counts[i]))
            .collect()
    }

    pub fn into_result(self, spec: &KernelSpec) -> QueryResult {
        if spec.key == KeySource::None {
            QueryResult::Count(self.rows_seen)
        } else {
            QueryResult::Buckets(self.to_rows())
        }
    }
}

/// Compute the bucket key column for a batch under `spec`. Returns -1 for
/// rows with no valid key (padding, out-of-range months). The weather
/// table must be provided iff `spec.needs_weather()`.
pub fn prepare_keys(spec: &KernelSpec, batch: &ColumnBatch, weather: Option<&WeatherTable>) -> Vec<i32> {
    let n = batch.lon.len();
    match spec.key {
        KeySource::None => vec![0; n],
        KeySource::Hour => batch.hour.clone(),
        KeySource::Month => batch
            .month
            .iter()
            .map(|&m| if (0..spec.buckets as i32).contains(&m) { m } else { -1 })
            .collect(),
        KeySource::MonthTaxiType => batch
            .month
            .iter()
            .zip(&batch.taxi_type)
            .map(|(&m, &t)| {
                let k = m * 2 + t;
                if m >= 0 && (0..spec.buckets as i32).contains(&k) {
                    k
                } else {
                    -1
                }
            })
            .collect(),
        KeySource::PrecipBucket => {
            let w = weather.expect("Q6 requires the weather table");
            batch
                .day
                .iter()
                .map(|&d| if d >= 0 { w.bucket(d) } else { -1 })
                .collect()
        }
        KeySource::Day => batch
            .day
            .iter()
            .map(|&d| if d >= 0 && (d as usize) < spec.buckets { d } else { -1 })
            .collect(),
    }
}

/// Compute the value column (what's summed per bucket).
pub fn prepare_values(spec: &KernelSpec, batch: &ColumnBatch) -> Vec<f32> {
    match spec.value {
        ValueSource::One => vec![1.0; batch.lon.len()],
        ValueSource::CreditFlag => batch.credit.clone(),
    }
}

/// Native fused kernel: filter rows by `spec`'s geo box and tip
/// threshold, scatter-add `values` into `accum` by `keys`. Mirrors the
/// Pallas kernel's semantics exactly (python/compile/kernels/ref.py is
/// the shared oracle).
pub fn run_batch_native(
    spec: &KernelSpec,
    batch: &ColumnBatch,
    keys: &[i32],
    values: &[f32],
    accum: &mut HistAccum,
) {
    let n = batch.len; // only real rows; padding has no effect natively
    let b = spec.bbox;
    let in_ranges = |i: usize| {
        if let Some((lo, hi)) = spec.day_range {
            let d = batch.day[i];
            if d < lo || d > hi {
                return false;
            }
        }
        if let Some((lo, hi)) = spec.month_range {
            let m = batch.month[i];
            if m < lo || m > hi {
                return false;
            }
        }
        true
    };
    // rows_seen counts rows *after* the day/month predicate so Count
    // queries agree with stats-based split pruning: a split skipped via
    // manifest stats must be indistinguishable from one whose rows were
    // all filtered here.
    if spec.day_range.is_none() && spec.month_range.is_none() {
        accum.rows_seen += n as u64;
    } else {
        accum.rows_seen += (0..n).filter(|&i| in_ranges(i)).count() as u64;
    }
    for i in 0..n {
        if !in_ranges(i) {
            continue;
        }
        let lon = batch.lon[i];
        let lat = batch.lat[i];
        if lon < b.lon_min || lon > b.lon_max || lat < b.lat_min || lat > b.lat_max {
            continue;
        }
        if batch.tip[i] < spec.tip_min {
            continue;
        }
        let k = keys[i];
        if k < 0 || k as usize >= accum.sums.len() {
            continue;
        }
        accum.sums[k as usize] += values[i] as f64;
        accum.counts[k as usize] += 1.0;
    }
}

/// Convenience wrapper: prepare keys/values and run the native kernel.
pub fn process_batch_native(
    spec: &KernelSpec,
    batch: &ColumnBatch,
    weather: Option<&WeatherTable>,
    accum: &mut HistAccum,
) {
    let keys = prepare_keys(spec, batch, weather);
    let values = prepare_values(spec, batch);
    run_batch_native(spec, batch, &keys, &values, accum);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::queries::QueryId;
    use crate::data::chrono::epoch_from_datetime;
    use crate::data::schema::{TripRecord, PAYMENT_CASH, PAYMENT_CREDIT};
    use crate::data::weather::WeatherTable;

    fn push(batch: &mut ColumnBatch, lon: f32, lat: f32, hour: u32, credit: bool, tip: f32) {
        let line = TripRecord {
            taxi_type: 0,
            pickup_ts: epoch_from_datetime(2014, 3, 10, hour, 0, 0),
            dropoff_ts: epoch_from_datetime(2014, 3, 10, hour, 12, 0),
            passenger_count: 1,
            trip_distance: 2.0,
            pickup_lon: -73.99,
            pickup_lat: 40.74,
            dropoff_lon: lon,
            dropoff_lat: lat,
            payment_type: if credit { PAYMENT_CREDIT } else { PAYMENT_CASH },
            fare_amount: 10.0,
            tip_amount: tip,
            total_amount: 10.0 + tip,
        }
        .to_csv();
        assert!(batch.push_line(line.as_bytes()));
    }

    #[test]
    fn q1_counts_only_goldman_rows() {
        let spec = QueryId::Q1.spec();
        let mut batch = ColumnBatch::with_capacity(16);
        push(&mut batch, -74.0144, 40.7147, 8, true, 2.0); // Goldman, 8am
        push(&mut batch, -74.0144, 40.7147, 8, false, 0.0); // Goldman, 8am
        push(&mut batch, -73.9800, 40.7500, 8, true, 2.0); // elsewhere
        push(&mut batch, -74.0144, 40.7147, 18, true, 2.0); // Goldman, 6pm
        let mut acc = HistAccum::new(spec.buckets);
        process_batch_native(&spec, &batch, None, &mut acc);
        assert_eq!(acc.rows_seen, 4);
        assert_eq!(acc.counts[8], 2.0);
        assert_eq!(acc.counts[18], 1.0);
        assert_eq!(acc.counts.iter().sum::<f64>(), 3.0);
    }

    #[test]
    fn q3_applies_tip_threshold() {
        let spec = QueryId::Q3.spec();
        let mut batch = ColumnBatch::with_capacity(16);
        push(&mut batch, -74.0144, 40.7147, 9, true, 15.0); // counted
        push(&mut batch, -74.0144, 40.7147, 9, true, 5.0); // tip too small
        push(&mut batch, -73.9800, 40.7500, 9, true, 20.0); // wrong place
        let mut acc = HistAccum::new(spec.buckets);
        process_batch_native(&spec, &batch, None, &mut acc);
        assert_eq!(acc.counts[9], 1.0);
        assert_eq!(acc.counts.iter().sum::<f64>(), 1.0);
    }

    #[test]
    fn q4_sums_credit_flags_and_counts() {
        let spec = QueryId::Q4.spec();
        let mut batch = ColumnBatch::with_capacity(16);
        push(&mut batch, -73.98, 40.75, 9, true, 2.0);
        push(&mut batch, -73.98, 40.75, 9, false, 0.0);
        push(&mut batch, -73.98, 40.75, 9, false, 0.0);
        let mut acc = HistAccum::new(spec.buckets);
        process_batch_native(&spec, &batch, None, &mut acc);
        let month = ((2014 - 2009) * 12 + 2) as usize;
        assert_eq!(acc.sums[month], 1.0, "one credit trip");
        assert_eq!(acc.counts[month], 3.0, "three trips");
    }

    #[test]
    fn q6_uses_weather_lookup() {
        let spec = QueryId::Q6.spec();
        let weather = WeatherTable::generate(1234);
        let mut batch = ColumnBatch::with_capacity(16);
        push(&mut batch, -73.98, 40.75, 9, true, 2.0);
        let mut acc = HistAccum::new(spec.buckets);
        process_batch_native(&spec, &batch, Some(&weather), &mut acc);
        let day = batch.day[0];
        let expect_bucket = weather.bucket(day) as usize;
        assert_eq!(acc.counts[expect_bucket], 1.0);
    }

    #[test]
    fn q6j_keys_by_day_without_weather() {
        let spec = QueryId::Q6J.spec();
        let mut batch = ColumnBatch::with_capacity(16);
        push(&mut batch, -73.98, 40.75, 9, true, 2.0);
        // No weather table needed: the join key is the raw day index.
        let keys = prepare_keys(&spec, &batch, None);
        assert_eq!(keys[0], batch.day[0]);
        let mut acc = HistAccum::new(spec.buckets);
        process_batch_native(&spec, &batch, None, &mut acc);
        assert_eq!(acc.counts[batch.day[0] as usize], 1.0);
    }

    #[test]
    #[should_panic(expected = "Q6 requires the weather table")]
    fn q6_without_weather_panics() {
        let spec = QueryId::Q6.spec();
        let batch = ColumnBatch::with_capacity(4);
        prepare_keys(&spec, &batch, None);
    }

    #[test]
    fn padding_rows_are_masked_out() {
        let spec = QueryId::Q1.spec();
        let mut batch = ColumnBatch::with_capacity(8);
        push(&mut batch, -74.0144, 40.7147, 8, true, 2.0);
        batch.pad_to_capacity();
        let keys = prepare_keys(&spec, &batch, None);
        let values = prepare_values(&spec, &batch);
        let mut acc = HistAccum::new(spec.buckets);
        run_batch_native(&spec, &batch, &keys, &values, &mut acc);
        assert_eq!(acc.counts[8], 1.0);
        assert_eq!(acc.counts.iter().sum::<f64>(), 1.0, "padding contributed nothing");
    }

    #[test]
    fn day_range_masks_rows_and_rows_seen() {
        // All pushed rows land on 2014-03-10; a window around that day
        // keeps them, a disjoint window drops them (including rows_seen,
        // so Count queries respect the predicate).
        let mut batch = ColumnBatch::with_capacity(8);
        push(&mut batch, -74.0144, 40.7147, 8, true, 2.0);
        push(&mut batch, -74.0144, 40.7147, 9, true, 2.0);
        let day = batch.day[0];

        let keep = QueryId::Q1.spec().with_day_range(day - 1, day + 1);
        let mut acc = HistAccum::new(keep.buckets);
        process_batch_native(&keep, &batch, None, &mut acc);
        assert_eq!(acc.rows_seen, 2);
        assert_eq!(acc.counts.iter().sum::<f64>(), 2.0);

        let drop = QueryId::Q1.spec().with_day_range(day + 10, day + 20);
        let mut acc = HistAccum::new(drop.buckets);
        process_batch_native(&drop, &batch, None, &mut acc);
        assert_eq!(acc.rows_seen, 0);
        assert_eq!(acc.counts.iter().sum::<f64>(), 0.0);

        let month = batch.month[0];
        let drop_m = QueryId::Q0.spec().with_month_range(month + 1, month + 2);
        let mut acc = HistAccum::new(drop_m.buckets);
        process_batch_native(&drop_m, &batch, None, &mut acc);
        assert_eq!(acc.into_result(&drop_m), QueryResult::Count(0));
    }

    #[test]
    fn merge_accumulators() {
        let mut a = HistAccum::new(4);
        a.sums[1] = 2.0;
        a.counts[1] = 2.0;
        a.rows_seen = 10;
        let mut b = HistAccum::new(4);
        b.sums[1] = 3.0;
        b.counts[1] = 3.0;
        b.counts[2] = 1.0;
        b.rows_seen = 5;
        a.merge(&b);
        assert_eq!(a.sums[1], 5.0);
        assert_eq!(a.counts[2], 1.0);
        assert_eq!(a.rows_seen, 15);
        assert_eq!(a.to_rows(), vec![(1, 5.0, 5.0), (2, 0.0, 1.0)]);
    }

    #[test]
    fn q0_result_is_count() {
        let spec = QueryId::Q0.spec();
        let mut batch = ColumnBatch::with_capacity(8);
        push(&mut batch, -73.98, 40.75, 9, true, 2.0);
        push(&mut batch, -73.98, 40.75, 10, true, 2.0);
        let mut acc = HistAccum::new(spec.buckets);
        process_batch_native(&spec, &batch, None, &mut acc);
        assert_eq!(acc.into_result(&spec), QueryResult::Count(2));
    }

    #[test]
    fn q5_composes_month_and_taxi_type() {
        let spec = QueryId::Q5.spec();
        let mut batch = ColumnBatch::with_capacity(8);
        // A green cab (taxi_type=1) in March 2014.
        let line = TripRecord {
            taxi_type: 1,
            pickup_ts: epoch_from_datetime(2014, 3, 10, 9, 0, 0),
            dropoff_ts: epoch_from_datetime(2014, 3, 10, 9, 12, 0),
            passenger_count: 1,
            trip_distance: 2.0,
            pickup_lon: -73.99,
            pickup_lat: 40.74,
            dropoff_lon: -73.95,
            dropoff_lat: 40.78,
            payment_type: PAYMENT_CREDIT,
            fare_amount: 10.0,
            tip_amount: 1.0,
            total_amount: 11.0,
        }
        .to_csv();
        assert!(batch.push_line(line.as_bytes()));
        let keys = prepare_keys(&spec, &batch, None);
        let month = (2014 - 2009) * 12 + 2;
        assert_eq!(keys[0], month * 2 + 1);
    }
}
