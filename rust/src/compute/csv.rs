//! Byte-range split handling for CSV objects — the Hadoop/Spark input
//! split rule the Flint executors follow (§III-A: "this iterator will
//! fetch a range of bytes from an S3 object").
//!
//! Ownership rule (Hadoop `LineRecordReader`): a non-first split discards
//! everything up to and including the first newline in its range, then
//! owns every line starting at an offset in `(start, end]`; the first
//! split additionally owns the line at offset 0. A reader whose last
//! owned line crosses the range end keeps reading past it (executors
//! fetch `end + MAX_LINE_BYTES`, capped at the object size, for that
//! reason). Together the splits of an object yield each line exactly
//! once.

use memchr::memchr;

/// Upper bound on one CSV line; generated TLC rows are ~131 bytes, so 4
/// KiB is a comfortable margin for the overfetch window.
pub const MAX_LINE_BYTES: u64 = 4096;

/// Cut `[0, object_size)` into ranges of at most `split_bytes`.
pub fn split_ranges(object_size: u64, split_bytes: u64) -> Vec<(u64, u64)> {
    assert!(split_bytes > 0);
    if object_size == 0 {
        return Vec::new();
    }
    let mut out = Vec::with_capacity((object_size / split_bytes + 1) as usize);
    let mut start = 0;
    while start < object_size {
        let end = (start + split_bytes).min(object_size);
        out.push((start, end));
        start = end;
    }
    out
}

/// The byte range an executor must fetch to process split
/// `[start, end)` of an object of `object_size` bytes (overfetch for the
/// trailing line).
pub fn fetch_range(start: u64, end: u64, object_size: u64) -> (u64, u64) {
    (start, (end + MAX_LINE_BYTES).min(object_size))
}

/// Iterator over the lines owned by a split.
///
/// `window` is the fetched bytes covering `[start, fetch_end)`;
/// `split_len = end - start` is the owned range length. Lines are yielded
/// without their trailing `\n`. Empty lines are skipped.
pub struct SplitLines<'a> {
    window: &'a [u8],
    /// Cursor into `window`.
    pos: usize,
    /// Offset (into `window`) at/after which no new line may *start*.
    own_end: usize,
    done: bool,
}

impl<'a> SplitLines<'a> {
    /// `is_first` is true when the split starts at object offset 0 (no
    /// leading partial line to skip).
    pub fn new(window: &'a [u8], split_len: u64, is_first: bool) -> SplitLines<'a> {
        let mut pos = 0;
        if !is_first {
            // Skip the partial line owned by the previous split.
            pos = match memchr(b'\n', window) {
                Some(nl) => nl + 1,
                None => window.len(), // no newline at all: nothing owned
            };
        }
        SplitLines { window, pos, own_end: split_len as usize, done: false }
    }

    /// Byte offset of the cursor within the *fetched window* — the resume
    /// point executor chaining records.
    pub fn offset(&self) -> usize {
        self.pos
    }

    /// Restart from a recorded offset (chained executor resume).
    pub fn seek(&mut self, offset: usize) {
        self.pos = offset.min(self.window.len());
    }
}

impl<'a> Iterator for SplitLines<'a> {
    type Item = &'a [u8];

    fn next(&mut self) -> Option<&'a [u8]> {
        loop {
            // Hadoop's LineRecordReader rule: a non-first split discards
            // everything through its first newline and then owns every
            // line *starting* at offset <= end (note `>` not `>=`: a line
            // beginning exactly at the range end belongs to this split,
            // because the next split will discard it).
            if self.done || self.pos > self.own_end || self.pos >= self.window.len() {
                return None;
            }
            let start = self.pos;
            match memchr(b'\n', &self.window[start..]) {
                Some(rel) => {
                    self.pos = start + rel + 1;
                    if rel == 0 {
                        continue; // empty line
                    }
                    return Some(&self.window[start..start + rel]);
                }
                None => {
                    // Last line of the object (no trailing newline).
                    self.done = true;
                    if start < self.window.len() {
                        return Some(&self.window[start..]);
                    }
                    return None;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    fn collect_all_lines(data: &[u8], split_bytes: u64) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        for (start, end) in split_ranges(data.len() as u64, split_bytes) {
            let (fs, fe) = fetch_range(start, end, data.len() as u64);
            let window = &data[fs as usize..fe as usize];
            for line in SplitLines::new(window, end - start, start == 0) {
                out.push(line.to_vec());
            }
        }
        out
    }

    #[test]
    fn ranges_cover_exactly() {
        assert_eq!(split_ranges(100, 30), vec![(0, 30), (30, 60), (60, 90), (90, 100)]);
        assert_eq!(split_ranges(0, 10), vec![]);
        assert_eq!(split_ranges(10, 100), vec![(0, 10)]);
    }

    #[test]
    fn every_line_exactly_once_regardless_of_split() {
        let data = b"alpha\nbravo\ncharlie\ndelta\necho\n";
        let expect: Vec<Vec<u8>> =
            data.split(|&b| b == b'\n').filter(|l| !l.is_empty()).map(|l| l.to_vec()).collect();
        for split in 1..(data.len() as u64 + 5) {
            let got = collect_all_lines(data, split);
            assert_eq!(got, expect, "split_bytes={split}");
        }
    }

    #[test]
    fn missing_trailing_newline() {
        let data = b"one\ntwo\nthree";
        for split in 1..(data.len() as u64 + 2) {
            let got = collect_all_lines(data, split);
            assert_eq!(got.len(), 3, "split={split}");
            assert_eq!(got[2], b"three");
        }
    }

    #[test]
    fn prop_splits_partition_lines() {
        forall("split-lines-partition", 150, |g| {
            // Random small "CSV": lines of random lengths.
            let nlines = g.usize(30) + 1;
            let mut data = Vec::new();
            let mut expect = Vec::new();
            for i in 0..nlines {
                let len = g.usize(20) + 1;
                let line: Vec<u8> = (0..len).map(|j| b'a' + ((i + j) % 26) as u8).collect();
                expect.push(line.clone());
                data.extend_from_slice(&line);
                data.push(b'\n');
            }
            if g.bool() {
                data.pop(); // sometimes strip the trailing newline
            }
            let split = g.u64(40) + 1;
            let got = collect_all_lines(&data, split);
            if got != expect {
                return Err(format!(
                    "split={split} got {} lines, want {}",
                    got.len(),
                    expect.len()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn seek_resumes_iteration() {
        let data = b"aa\nbb\ncc\ndd\n";
        let mut it = SplitLines::new(data, data.len() as u64, true);
        assert_eq!(it.next().unwrap(), b"aa");
        let resume = it.offset();
        assert_eq!(it.next().unwrap(), b"bb");
        // A fresh iterator seeked to `resume` sees the same remainder.
        let mut it2 = SplitLines::new(data, data.len() as u64, true);
        it2.seek(resume);
        let rest: Vec<&[u8]> = it2.collect();
        assert_eq!(rest, vec![b"bb" as &[u8], b"cc", b"dd"]);
    }
}
