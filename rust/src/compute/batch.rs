//! Columnar trip batches — the unit of work handed to the compute
//! kernels (native or PJRT).
//!
//! The executor parses CSV lines directly into column vectors (no
//! per-row struct allocation on the hot path) and flushes a full batch
//! through the query kernel. Batch capacity matches the AOT artifacts'
//! static row dimension (`flint.batch_rows`).

use crate::data::chrono::{day_index, hour_of_day, month_index, parse_datetime};
use crate::data::schema::{parse_f32, parse_u8};

/// Which CSV fields a scan must decode — the query's referenced-column
/// set ([`crate::compute::queries::KernelSpec::projection`]). Skipped
/// fields are still structurally validated (the 13-column comma count is
/// always enforced) but their bytes are never parsed; the corresponding
/// columns receive neutral placeholder values no projected query reads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ColProjection {
    /// Field 0 → `taxi_type`.
    pub taxi_type: bool,
    /// Field 2 (dropoff datetime) → `hour`/`month`/`day`.
    pub time: bool,
    /// Fields 7/8 → `lon`/`lat`.
    pub geo: bool,
    /// Field 9 → `credit`.
    pub payment: bool,
    /// Field 11 → `tip`.
    pub tip: bool,
}

impl ColProjection {
    /// Decode every field (the pre-projection behavior).
    pub const ALL: ColProjection =
        ColProjection { taxi_type: true, time: true, geo: true, payment: true, tip: true };

    /// Number of CSV fields this projection decodes (geo is two fields).
    pub fn num_fields(&self) -> usize {
        usize::from(self.taxi_type)
            + usize::from(self.time)
            + 2 * usize::from(self.geo)
            + usize::from(self.payment)
            + usize::from(self.tip)
    }
}

/// Column-oriented batch of the fields the evaluation queries touch.
#[derive(Debug, Clone)]
pub struct ColumnBatch {
    pub capacity: usize,
    pub len: usize,
    /// Dropoff coordinates (Q1–Q3 filter on these).
    pub lon: Vec<f32>,
    pub lat: Vec<f32>,
    /// Dropoff hour-of-day (Q1–Q3 key).
    pub hour: Vec<i32>,
    /// Months since 2009-01 (Q4/Q5 key).
    pub month: Vec<i32>,
    /// Days since 2009-01-01 (Q6 weather-join key).
    pub day: Vec<i32>,
    /// 1.0 if paid by credit card (Q4 numerator), else 0.0.
    pub credit: Vec<f32>,
    /// 0 = yellow, 1 = green (Q5).
    pub taxi_type: Vec<i32>,
    /// Tip in dollars (Q3 filter).
    pub tip: Vec<f32>,
}

impl ColumnBatch {
    pub fn with_capacity(capacity: usize) -> ColumnBatch {
        assert!(capacity > 0);
        ColumnBatch {
            capacity,
            len: 0,
            lon: Vec::with_capacity(capacity),
            lat: Vec::with_capacity(capacity),
            hour: Vec::with_capacity(capacity),
            month: Vec::with_capacity(capacity),
            day: Vec::with_capacity(capacity),
            credit: Vec::with_capacity(capacity),
            taxi_type: Vec::with_capacity(capacity),
            tip: Vec::with_capacity(capacity),
        }
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.capacity
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn clear(&mut self) {
        self.len = 0;
        self.lon.clear();
        self.lat.clear();
        self.hour.clear();
        self.month.clear();
        self.day.clear();
        self.credit.clear();
        self.taxi_type.clear();
        self.tip.clear();
    }

    /// Parse one CSV line straight into the columns. Returns `false` (and
    /// appends nothing) for malformed rows. Column order is defined in
    /// [`crate::data::schema`].
    ///
    /// Hot path (§Perf): comma positions come from SIMD `memchr` rather
    /// than a byte loop, and only the six needed fields (0 taxi_type,
    /// 2 dropoff datetime, 7/8 dropoff lon/lat, 9 payment, 11 tip) are
    /// decoded.
    pub fn push_line(&mut self, line: &[u8]) -> bool {
        self.push_line_projected(line, ColProjection::ALL)
    }

    /// [`push_line`](Self::push_line) decoding only the fields `proj`
    /// selects. The 13-column structure is always validated, but a
    /// skipped field's bytes are never parsed (so a value that would
    /// fail to parse in an unreferenced field no longer rejects the
    /// row — acceptable because every referenced column is still exact).
    /// Skipped columns receive neutral placeholders: coordinates 0.0
    /// (inside `EVERYWHERE`), tip 0.0 (passes a `-inf` threshold),
    /// month/day -1 (masked, like padding), hour 0, taxi 0, credit 0.0.
    pub fn push_line_projected(&mut self, line: &[u8], proj: ColProjection) -> bool {
        debug_assert!(!self.is_full());
        let mut taxi: Option<u8> = None;
        let mut ts: Option<i64> = None;
        let mut lon: Option<f32> = None;
        let mut lat: Option<f32> = None;
        let mut pay: Option<u8> = None;
        let mut tip: Option<f32> = None;
        let mut field_start = 0usize;
        let mut field_idx = 0usize;
        for comma in memchr::memchr_iter(b',', line).chain(std::iter::once(line.len())) {
            let f = &line[field_start..comma];
            match field_idx {
                0 if proj.taxi_type => taxi = parse_u8(f),
                2 if proj.time => ts = parse_datetime(f),
                7 if proj.geo => lon = parse_f32(f),
                8 if proj.geo => lat = parse_f32(f),
                9 if proj.payment => pay = parse_u8(f),
                11 if proj.tip => tip = parse_f32(f),
                _ => {}
            }
            field_idx += 1;
            if field_idx > crate::data::schema::NUM_COLUMNS {
                return false; // too many columns
            }
            field_start = comma + 1;
        }
        if field_idx != crate::data::schema::NUM_COLUMNS {
            return false;
        }
        // Every *referenced* field must have parsed; skipped fields are
        // substituted below.
        if (proj.taxi_type && taxi.is_none())
            || (proj.time && ts.is_none())
            || (proj.geo && (lon.is_none() || lat.is_none()))
            || (proj.payment && pay.is_none())
            || (proj.tip && tip.is_none())
        {
            return false;
        }
        self.lon.push(lon.unwrap_or(0.0));
        self.lat.push(lat.unwrap_or(0.0));
        match ts {
            Some(ts) => {
                self.hour.push(hour_of_day(ts) as i32);
                self.month.push(month_index(ts));
                self.day.push(day_index(ts));
            }
            None => {
                self.hour.push(0);
                self.month.push(-1);
                self.day.push(-1);
            }
        }
        self.credit.push(
            if pay == Some(crate::data::schema::PAYMENT_CREDIT) { 1.0 } else { 0.0 },
        );
        self.taxi_type.push(taxi.unwrap_or(0) as i32);
        self.tip.push(tip.unwrap_or(0.0));
        self.len += 1;
        true
    }

    /// Pad every column to `capacity` (PJRT artifacts have a static row
    /// dimension). Padding rows carry an out-of-range key so kernels mask
    /// them out; returns the pre-pad length.
    pub fn pad_to_capacity(&mut self) -> usize {
        let real = self.len;
        while self.lon.len() < self.capacity {
            self.lon.push(f32::NAN);
            self.lat.push(f32::NAN);
            self.hour.push(-1);
            self.month.push(-1);
            self.day.push(-1);
            self.credit.push(0.0);
            self.taxi_type.push(0);
            self.tip.push(0.0);
        }
        real
    }

    /// Approximate heap bytes held (executor memory accounting).
    pub fn mem_bytes(&self) -> usize {
        self.capacity * (4 * 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::chrono::epoch_from_datetime;
    use crate::data::schema::{TripRecord, PAYMENT_CASH, PAYMENT_CREDIT};

    fn record(hour: u32, credit: bool, tip: f32) -> String {
        TripRecord {
            taxi_type: 0,
            pickup_ts: epoch_from_datetime(2014, 3, 10, hour, 0, 0) - 600,
            dropoff_ts: epoch_from_datetime(2014, 3, 10, hour, 12, 0),
            passenger_count: 1,
            trip_distance: 2.0,
            pickup_lon: -73.99,
            pickup_lat: 40.74,
            dropoff_lon: -74.0144,
            dropoff_lat: 40.7147,
            payment_type: if credit { PAYMENT_CREDIT } else { PAYMENT_CASH },
            fare_amount: 10.0,
            tip_amount: tip,
            total_amount: 10.0 + tip,
        }
        .to_csv()
    }

    #[test]
    fn push_line_extracts_fields() {
        let mut b = ColumnBatch::with_capacity(8);
        assert!(b.push_line(record(9, true, 12.5).as_bytes()));
        assert_eq!(b.len, 1);
        assert_eq!(b.hour[0], 9);
        assert_eq!(b.credit[0], 1.0);
        assert!((b.tip[0] - 12.5).abs() < 1e-4);
        assert!((b.lon[0] + 74.0144).abs() < 1e-3);
        assert_eq!(b.month[0], (2014 - 2009) * 12 + 2);
        assert!(b.push_line(record(17, false, 0.0).as_bytes()));
        assert_eq!(b.credit[1], 0.0);
        assert_eq!(b.hour[1], 17);
    }

    #[test]
    fn malformed_lines_rejected_without_partial_rows() {
        let mut b = ColumnBatch::with_capacity(8);
        assert!(!b.push_line(b"1,2,3"));
        assert!(!b.push_line(b""));
        assert!(!b.push_line(record(9, true, 1.0).replace(',', ";").as_bytes()));
        // Bad float in the tip field.
        let bad = record(9, true, 1.0).replace("1.00,11.00", "x.00,11.00");
        let _ = b.push_line(bad.as_bytes());
        assert_eq!(b.len, b.lon.len());
        assert_eq!(b.len, b.tip.len());
    }

    #[test]
    fn projected_push_skips_unreferenced_fields() {
        // A Q1-shaped projection: geo + time, no taxi/payment/tip.
        let proj =
            ColProjection { taxi_type: false, time: true, geo: true, payment: false, tip: false };
        assert_eq!(proj.num_fields(), 3);
        assert_eq!(ColProjection::ALL.num_fields(), 6);

        let mut b = ColumnBatch::with_capacity(8);
        assert!(b.push_line_projected(record(9, true, 12.5).as_bytes(), proj));
        assert_eq!(b.hour[0], 9);
        assert!((b.lon[0] + 74.0144).abs() < 1e-3);
        // Skipped columns hold neutral placeholders.
        assert_eq!(b.credit[0], 0.0);
        assert_eq!(b.taxi_type[0], 0);
        assert_eq!(b.tip[0], 0.0);

        // Garbage in a *skipped* field no longer rejects the row (the
        // bytes are never parsed), but structure is still enforced.
        let bad_tip = record(9, true, 1.0).replace("1.00,11.00", "x.00,11.00");
        assert!(b.push_line_projected(bad_tip.as_bytes(), proj));
        assert!(!b.push_line_projected(b"1,2,3", proj));
        // Garbage in a *referenced* field still rejects.
        let bad_time = record(9, true, 1.0).replacen("2014-03-10", "xxxx-03-10", 2);
        assert!(!b.push_line_projected(bad_time.as_bytes(), proj));
        assert_eq!(b.len, b.lon.len());
        assert_eq!(b.len, b.tip.len());
    }

    #[test]
    fn padding_marks_invalid_keys() {
        let mut b = ColumnBatch::with_capacity(4);
        b.push_line(record(9, true, 0.0).as_bytes());
        let real = b.pad_to_capacity();
        assert_eq!(real, 1);
        assert_eq!(b.lon.len(), 4);
        assert_eq!(b.hour[3], -1);
        assert!(b.lon[3].is_nan());
    }

    #[test]
    fn clear_resets() {
        let mut b = ColumnBatch::with_capacity(2);
        b.push_line(record(9, true, 0.0).as_bytes());
        b.push_line(record(10, true, 0.0).as_bytes());
        assert!(b.is_full());
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.lon.len(), 0);
    }
}
