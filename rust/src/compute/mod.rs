//! The compute layer: CSV split handling, columnar batches, the paper's
//! seven evaluation queries, and both execution paths for their inner
//! loop — the native Rust kernels and the PJRT-loaded AOT artifacts
//! (L1/L2, built by `make artifacts`).

pub mod batch;
pub mod csv;
pub mod kernels;
pub mod oracle;
pub mod queries;
pub mod value;
