//! The paper's evaluation queries Q0–Q6 (§IV), expressed as kernel
//! specifications over the columnar batch.
//!
//! Every query reduces to the same fused shape — *filter → bucket-key →
//! masked histogram* — which is exactly what the L1 Pallas kernel
//! implements (`python/compile/kernels/filter_hist.py`). A query is a
//! [`KernelSpec`]: which geo box and tip threshold filter rows, how the
//! bucket key is derived, what value is summed, and how many reduce
//! partitions the shuffle uses (Q1's `reduceByKey(add, 30)`).

use crate::data::schema::{GeoBox, CITIGROUP, GOLDMAN};
use crate::data::weather::PRECIP_BUCKETS;

/// The seven Table I queries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum QueryId {
    /// Line count — raw S3 read throughput.
    Q0,
    /// Goldman Sachs drop-offs by hour.
    Q1,
    /// Citigroup drop-offs by hour.
    Q2,
    /// Goldman drop-offs with tips > $10, by hour.
    Q3,
    /// Credit-card payment share by month.
    Q4,
    /// Yellow vs green trips by month.
    Q5,
    /// Trips by precipitation bucket.
    Q6,
    /// Q6 as a true shuffle join: trips and the weather table are both
    /// hash-partitioned on the day key and joined reduce-side (vs Q6's
    /// broadcast map-side lookup). Not in the paper's Table I; it pins
    /// the engine's exchange-operator join path against the same oracle.
    Q6J,
}

impl QueryId {
    /// The paper's seven Table I queries.
    pub const ALL: [QueryId; 7] = [
        QueryId::Q0,
        QueryId::Q1,
        QueryId::Q2,
        QueryId::Q3,
        QueryId::Q4,
        QueryId::Q5,
        QueryId::Q6,
    ];

    /// Table I plus the repo's extension queries (Q6J: the shuffle-join
    /// variant of Q6, which has no published row).
    pub const ALL_WITH_JOINS: [QueryId; 8] = [
        QueryId::Q0,
        QueryId::Q1,
        QueryId::Q2,
        QueryId::Q3,
        QueryId::Q4,
        QueryId::Q5,
        QueryId::Q6,
        QueryId::Q6J,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            QueryId::Q0 => "Q0",
            QueryId::Q1 => "Q1",
            QueryId::Q2 => "Q2",
            QueryId::Q3 => "Q3",
            QueryId::Q4 => "Q4",
            QueryId::Q5 => "Q5",
            QueryId::Q6 => "Q6",
            QueryId::Q6J => "Q6J",
        }
    }

    /// Row index into the paper's published Table I (None for extension
    /// queries with no published numbers).
    pub fn published_index(&self) -> Option<usize> {
        match self {
            QueryId::Q0 => Some(0),
            QueryId::Q1 => Some(1),
            QueryId::Q2 => Some(2),
            QueryId::Q3 => Some(3),
            QueryId::Q4 => Some(4),
            QueryId::Q5 => Some(5),
            QueryId::Q6 => Some(6),
            QueryId::Q6J => None,
        }
    }

    /// Whether the physical plan is the two-sided shuffle join (fact and
    /// dimension scans feeding a `KernelJoin` stage) rather than a
    /// scan → reduce chain.
    pub fn is_join(&self) -> bool {
        matches!(self, QueryId::Q6J)
    }

    pub fn description(&self) -> &'static str {
        match self {
            QueryId::Q0 => "line count (raw S3 throughput)",
            QueryId::Q1 => "Goldman Sachs drop-offs by hour",
            QueryId::Q2 => "Citigroup drop-offs by hour",
            QueryId::Q3 => "Goldman drop-offs with tip > $10, by hour",
            QueryId::Q4 => "credit vs cash share by month",
            QueryId::Q5 => "yellow vs green trips by month",
            QueryId::Q6 => "trips by precipitation bucket",
            QueryId::Q6J => "trips by precipitation bucket (shuffle join on day key)",
        }
    }

    pub fn parse(s: &str) -> Option<QueryId> {
        match s.to_ascii_uppercase().as_str() {
            "Q0" | "0" => Some(QueryId::Q0),
            "Q1" | "1" => Some(QueryId::Q1),
            "Q2" | "2" => Some(QueryId::Q2),
            "Q3" | "3" => Some(QueryId::Q3),
            "Q4" | "4" => Some(QueryId::Q4),
            "Q5" | "5" => Some(QueryId::Q5),
            "Q6" | "6" => Some(QueryId::Q6),
            "Q6J" | "6J" => Some(QueryId::Q6J),
            _ => None,
        }
    }

    /// The kernel spec implementing this query.
    pub fn spec(&self) -> KernelSpec {
        match self {
            QueryId::Q0 => KernelSpec {
                query: *self,
                bbox: GeoBox::EVERYWHERE,
                tip_min: f32::NEG_INFINITY,
                key: KeySource::None,
                value: ValueSource::One,
                buckets: 1,
                reduce_partitions: 0, // map-only: counts merge at the driver
                day_range: None,
                month_range: None,
            },
            QueryId::Q1 => KernelSpec {
                query: *self,
                bbox: GOLDMAN,
                tip_min: f32::NEG_INFINITY,
                key: KeySource::Hour,
                value: ValueSource::One,
                buckets: 24,
                reduce_partitions: 30, // the paper's reduceByKey(add, 30)
                day_range: None,
                month_range: None,
            },
            QueryId::Q2 => KernelSpec {
                query: *self,
                bbox: CITIGROUP,
                tip_min: f32::NEG_INFINITY,
                key: KeySource::Hour,
                value: ValueSource::One,
                buckets: 24,
                reduce_partitions: 30,
                day_range: None,
                month_range: None,
            },
            QueryId::Q3 => KernelSpec {
                query: *self,
                bbox: GOLDMAN,
                tip_min: 10.0,
                key: KeySource::Hour,
                value: ValueSource::One,
                buckets: 24,
                reduce_partitions: 30,
                day_range: None,
                month_range: None,
            },
            QueryId::Q4 => KernelSpec {
                query: *self,
                bbox: GeoBox::EVERYWHERE,
                tip_min: f32::NEG_INFINITY,
                key: KeySource::Month,
                value: ValueSource::CreditFlag,
                buckets: 90, // Jan 2009 .. Jun 2016
                reduce_partitions: 30,
                day_range: None,
                month_range: None,
            },
            QueryId::Q5 => KernelSpec {
                query: *self,
                bbox: GeoBox::EVERYWHERE,
                tip_min: f32::NEG_INFINITY,
                key: KeySource::MonthTaxiType,
                value: ValueSource::One,
                buckets: 180, // month × {yellow, green}
                reduce_partitions: 30,
                day_range: None,
                month_range: None,
            },
            QueryId::Q6 => KernelSpec {
                query: *self,
                bbox: GeoBox::EVERYWHERE,
                tip_min: f32::NEG_INFINITY,
                key: KeySource::PrecipBucket,
                value: ValueSource::One,
                buckets: PRECIP_BUCKETS,
                reduce_partitions: PRECIP_BUCKETS,
                day_range: None,
                month_range: None,
            },
            // Q6 over the shuffle: the fact scan histograms per *day*
            // (one bucket per covered day), both sides hash-partition on
            // the day key into `reduce_partitions` join partitions, and
            // the join stage re-keys by precipitation bucket.
            QueryId::Q6J => KernelSpec {
                query: *self,
                bbox: GeoBox::EVERYWHERE,
                tip_min: f32::NEG_INFINITY,
                key: KeySource::Day,
                value: ValueSource::One,
                buckets: crate::data::weather::NUM_DAYS,
                reduce_partitions: 30,
                day_range: None,
                month_range: None,
            },
        }
    }

    /// Whether the physical plan has a shuffle stage.
    pub fn has_shuffle(&self) -> bool {
        self.spec().reduce_partitions > 0
    }

    /// Relative number of intermediate groups — the paper observes Flint
    /// latency tracks this (Q0 < Q1 ≈ Q3 < Q4 < Q5 < Q6-ish ordering by
    /// shuffle volume per task).
    pub fn intermediate_groups(&self) -> usize {
        self.spec().buckets * usize::from(self.has_shuffle())
    }
}

impl std::fmt::Display for QueryId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// How the bucket key is derived for a row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KeySource {
    /// No key (count-only, Q0).
    None,
    /// Dropoff hour of day, 0..24.
    Hour,
    /// Months since 2009-01, 0..90.
    Month,
    /// `month * 2 + taxi_type`, 0..180.
    MonthTaxiType,
    /// Precipitation bucket of the dropoff day (weather-table lookup).
    PrecipBucket,
    /// Days since 2009-01-01, 0..NUM_DAYS — the Q6J join key (no side
    /// table needed map-side; the weather lookup moves to the join).
    Day,
}

/// What gets summed per bucket (a count is always kept alongside).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ValueSource {
    /// Sum of 1s (plain count).
    One,
    /// Sum of the credit-payment indicator (Q4's numerator).
    CreditFlag,
}

/// The fused filter+histogram kernel parameters for one query.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelSpec {
    pub query: QueryId,
    pub bbox: GeoBox,
    pub tip_min: f32,
    pub key: KeySource,
    pub value: ValueSource,
    /// Number of histogram buckets (static in the AOT artifact).
    pub buckets: usize,
    /// Reduce-side partition count (0 = map-only).
    pub reduce_partitions: usize,
    /// Inclusive dropoff-day predicate (day indexes since 2009-01-01):
    /// rows outside are filtered map-side, and the scan skips fetching
    /// splits whose manifest statistics sit entirely outside the range.
    pub day_range: Option<(i32, i32)>,
    /// Inclusive dropoff-month predicate (months since 2009-01).
    pub month_range: Option<(i32, i32)>,
}

impl KernelSpec {
    /// Artifact file stem for this query (`artifacts/<stem>.hlo.txt`).
    pub fn artifact_stem(&self) -> String {
        format!("{}_hist", self.query.name().to_ascii_lowercase())
    }

    /// Whether the spec needs the weather side table.
    pub fn needs_weather(&self) -> bool {
        self.key == KeySource::PrecipBucket
    }

    /// Derived spec with a dropoff-day predicate `[lo, hi]` inclusive.
    pub fn with_day_range(mut self, lo: i32, hi: i32) -> KernelSpec {
        self.day_range = Some((lo, hi));
        self
    }

    /// Derived spec with a dropoff-month predicate `[lo, hi]` inclusive.
    pub fn with_month_range(mut self, lo: i32, hi: i32) -> KernelSpec {
        self.month_range = Some((lo, hi));
        self
    }

    /// The referenced-column set: which CSV fields the scan must decode
    /// for this spec. Everything else is structurally validated (comma
    /// count) but never parsed.
    pub fn projection(&self) -> crate::compute::batch::ColProjection {
        crate::compute::batch::ColProjection {
            taxi_type: self.key == KeySource::MonthTaxiType,
            time: self.key != KeySource::None
                || self.day_range.is_some()
                || self.month_range.is_some(),
            geo: self.bbox != GeoBox::EVERYWHERE,
            payment: self.value == ValueSource::CreditFlag,
            tip: self.tip_min > f32::NEG_INFINITY,
        }
    }
}

/// A query's final answer, in a directly comparable form.
#[derive(Debug, Clone, PartialEq)]
pub enum QueryResult {
    /// Q0: total line count.
    Count(u64),
    /// Everything else: sorted `(bucket_key, value_sum, row_count)` rows,
    /// one per non-empty bucket.
    Buckets(Vec<(i64, f64, f64)>),
}

impl QueryResult {
    /// Human-readable rendering for examples/CLI.
    pub fn render(&self, query: QueryId) -> String {
        match self {
            QueryResult::Count(n) => format!("{query}: {n} lines"),
            QueryResult::Buckets(rows) => {
                let mut out = format!("{query}: {} groups\n", rows.len());
                for (k, sum, count) in rows {
                    match query {
                        QueryId::Q4 => {
                            let share = if *count > 0.0 { sum / count } else { 0.0 };
                            out.push_str(&format!(
                                "  month {k:3}: {:.1}% credit of {count:.0} trips\n",
                                share * 100.0
                            ));
                        }
                        _ => out.push_str(&format!("  key {k:4}: {count:.0}\n")),
                    }
                }
                out
            }
        }
    }

    /// Approximate equality (floating sums may differ in low bits across
    /// engines; counts must match exactly).
    pub fn approx_eq(&self, other: &QueryResult) -> bool {
        match (self, other) {
            (QueryResult::Count(a), QueryResult::Count(b)) => a == b,
            (QueryResult::Buckets(a), QueryResult::Buckets(b)) => {
                a.len() == b.len()
                    && a.iter().zip(b).all(|((ka, sa, ca), (kb, sb, cb))| {
                        ka == kb
                            && (sa - sb).abs() <= 1e-6 * (1.0 + sa.abs())
                            && (ca - cb).abs() <= 1e-6 * (1.0 + ca.abs())
                    })
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_queries_have_distinct_specs() {
        for q in QueryId::ALL {
            let s = q.spec();
            assert_eq!(s.query, q);
            assert!(s.buckets >= 1);
        }
        assert!(!QueryId::Q0.has_shuffle());
        assert!(QueryId::Q1.has_shuffle());
        assert_eq!(QueryId::Q1.spec().reduce_partitions, 30);
    }

    #[test]
    fn parse_names() {
        assert_eq!(QueryId::parse("q3"), Some(QueryId::Q3));
        assert_eq!(QueryId::parse("5"), Some(QueryId::Q5));
        assert_eq!(QueryId::parse("q6j"), Some(QueryId::Q6J));
        assert_eq!(QueryId::parse("6J"), Some(QueryId::Q6J));
        assert_eq!(QueryId::parse("Q9"), None);
    }

    #[test]
    fn q6j_is_the_day_keyed_join() {
        let s = QueryId::Q6J.spec();
        assert!(QueryId::Q6J.is_join());
        assert!(!QueryId::Q6.is_join());
        assert_eq!(s.key, KeySource::Day);
        assert_eq!(s.buckets, crate::data::weather::NUM_DAYS);
        assert!(s.reduce_partitions > 0);
        assert!(
            !s.needs_weather(),
            "the join ships the weather table through the shuffle, not as a broadcast"
        );
        assert_eq!(QueryId::Q6J.published_index(), None);
        for q in QueryId::ALL {
            assert!(q.published_index().is_some(), "{q} has a Table I row");
        }
    }

    #[test]
    fn intermediate_group_ordering_matches_paper_narrative() {
        // Q0 has none; Q6 (6 buckets) is small-group but join-heavy;
        // Q5 has the most groups.
        assert_eq!(QueryId::Q0.intermediate_groups(), 0);
        assert!(QueryId::Q5.intermediate_groups() > QueryId::Q4.intermediate_groups());
        assert!(QueryId::Q4.intermediate_groups() > QueryId::Q1.intermediate_groups());
    }

    #[test]
    fn q3_filters_tips() {
        let s = QueryId::Q3.spec();
        assert_eq!(s.tip_min, 10.0);
        assert_eq!(s.bbox, crate::data::schema::GOLDMAN);
    }

    #[test]
    fn projection_tracks_referenced_columns() {
        use crate::compute::batch::ColProjection;
        // Q0 is a pure line count: no field is referenced at all.
        assert_eq!(
            QueryId::Q0.spec().projection(),
            ColProjection {
                taxi_type: false,
                time: false,
                geo: false,
                payment: false,
                tip: false
            }
        );
        // Q3 filters on geo + tip and keys on hour; payment/taxi unused.
        let p3 = QueryId::Q3.spec().projection();
        assert!(p3.geo && p3.time && p3.tip && !p3.payment && !p3.taxi_type);
        // Q4 sums the credit flag; Q5 keys on taxi type.
        assert!(QueryId::Q4.spec().projection().payment);
        assert!(QueryId::Q5.spec().projection().taxi_type);
        // A day predicate forces the timestamp even on a count query.
        let ranged = QueryId::Q0.spec().with_day_range(10, 20);
        assert_eq!(ranged.day_range, Some((10, 20)));
        assert!(ranged.projection().time);
        assert!(QueryId::Q0.spec().with_month_range(0, 5).projection().time);
    }

    #[test]
    fn result_approx_eq() {
        let a = QueryResult::Buckets(vec![(1, 10.0, 10.0), (2, 5.0, 5.0)]);
        let b = QueryResult::Buckets(vec![(1, 10.0 + 1e-9, 10.0), (2, 5.0, 5.0)]);
        assert!(a.approx_eq(&b));
        let c = QueryResult::Buckets(vec![(1, 11.0, 10.0), (2, 5.0, 5.0)]);
        assert!(!a.approx_eq(&c));
        assert!(!a.approx_eq(&QueryResult::Count(3)));
        assert!(QueryResult::Count(5).approx_eq(&QueryResult::Count(5)));
    }

    #[test]
    fn artifact_stems_unique() {
        let mut stems: Vec<String> =
            QueryId::ALL_WITH_JOINS.iter().map(|q| q.spec().artifact_stem()).collect();
        stems.sort();
        stems.dedup();
        assert_eq!(stems.len(), QueryId::ALL_WITH_JOINS.len());
    }
}
