//! Synthetic daily precipitation for Central Park, 2009-01-01 …
//! 2016-06-30 — the side table Q6 joins against ("do people take the taxi
//! more when it rains?"). The paper uses NOAA daily observations; this
//! generator reproduces the relevant statistics: ~30% of days have
//! measurable precipitation, amounts are roughly exponential, and wet
//! days *reduce* trip volume slightly (the generator couples trip counts
//! to this table so Q6 has a real signal to find).

use crate::data::chrono::days_from_civil;
use crate::util::rng::Pcg64;

/// Number of days covered (2009-01-01 .. 2016-06-30 inclusive).
pub fn num_days() -> usize {
    (days_from_civil(2016, 6, 30) - days_from_civil(2009, 1, 1) + 1) as usize
}

/// [`num_days`] as a compile-time constant — `KernelSpec` is `Copy` and
/// built from literals (Q6J's day-keyed histogram needs one bucket per
/// day). Pinned against the computed value in tests.
pub const NUM_DAYS: usize = 2738;

/// The daily precipitation table, indexed by day-index (days since
/// 2009-01-01).
#[derive(Debug, Clone)]
pub struct WeatherTable {
    /// Daily precipitation in inches.
    pub precip: Vec<f32>,
}

/// Precipitation histogram buckets used by Q6 (inches):
/// 0: dry (0), 1: trace (<0.1), 2: light (<0.25), 3: moderate (<0.5),
/// 4: heavy (<1.0), 5: extreme (>=1.0).
pub const PRECIP_BUCKETS: usize = 6;

pub fn precip_bucket(inches: f32) -> i32 {
    if inches <= 0.0 {
        0
    } else if inches < 0.1 {
        1
    } else if inches < 0.25 {
        2
    } else if inches < 0.5 {
        3
    } else if inches < 1.0 {
        4
    } else {
        5
    }
}

impl WeatherTable {
    /// Deterministic table from a seed.
    pub fn generate(seed: u64) -> WeatherTable {
        let mut rng = Pcg64::new(seed, 4242);
        let n = num_days();
        let mut precip = Vec::with_capacity(n);
        for day in 0..n {
            // Wet-day probability with a mild seasonal swing (wetter
            // spring/summer storms).
            let season = (day as f64 / 365.25 * std::f64::consts::TAU).sin();
            let p_wet = 0.30 + 0.05 * season;
            let amount = if rng.chance(p_wet) {
                // Exponential-ish amounts, mean ~0.3in, capped at 4in.
                (rng.exp(1.0 / 0.3)).min(4.0) as f32
            } else {
                0.0
            };
            precip.push(amount);
        }
        WeatherTable { precip }
    }

    pub fn get(&self, day_index: i32) -> f32 {
        if day_index < 0 || day_index as usize >= self.precip.len() {
            0.0
        } else {
            self.precip[day_index as usize]
        }
    }

    pub fn bucket(&self, day_index: i32) -> i32 {
        precip_bucket(self.get(day_index))
    }

    /// Trip-volume multiplier for a day: rain suppresses trips a little
    /// (this is what Q6 measures; the sign matters more than magnitude).
    pub fn demand_multiplier(&self, day_index: i32) -> f64 {
        let p = self.get(day_index) as f64;
        (1.0 - 0.15 * (p / (p + 0.5))).max(0.5)
    }

    /// Serialize as CSV `day_index,precip` (the broadcast side table the
    /// Q6 executors read from S3).
    pub fn to_csv(&self) -> Vec<u8> {
        let mut out = String::with_capacity(self.precip.len() * 12);
        for (i, p) in self.precip.iter().enumerate() {
            out.push_str(&format!("{i},{p:.3}\n"));
        }
        out.into_bytes()
    }

    /// Parse the CSV form back.
    pub fn from_csv(data: &[u8]) -> Option<WeatherTable> {
        let text = std::str::from_utf8(data).ok()?;
        let mut precip = Vec::new();
        for line in text.lines() {
            if line.is_empty() {
                continue;
            }
            let (idx, val) = line.split_once(',')?;
            let idx: usize = idx.parse().ok()?;
            if idx != precip.len() {
                return None; // must be dense and ordered
            }
            precip.push(val.parse().ok()?);
        }
        Some(WeatherTable { precip })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_paper_date_range() {
        // 2009-2015 full years (2557 days incl leaps) + Jan-Jun 2016 (182).
        assert_eq!(num_days(), 2738);
        assert_eq!(num_days(), NUM_DAYS, "const must track the computed range");
        let w = WeatherTable::generate(7);
        assert_eq!(w.precip.len(), 2738);
    }

    #[test]
    fn wet_day_fraction_realistic() {
        let w = WeatherTable::generate(7);
        let wet = w.precip.iter().filter(|&&p| p > 0.0).count();
        let frac = wet as f64 / w.precip.len() as f64;
        assert!((0.2..0.4).contains(&frac), "wet fraction {frac}");
    }

    #[test]
    fn buckets_partition_the_range() {
        assert_eq!(precip_bucket(0.0), 0);
        assert_eq!(precip_bucket(0.05), 1);
        assert_eq!(precip_bucket(0.2), 2);
        assert_eq!(precip_bucket(0.4), 3);
        assert_eq!(precip_bucket(0.9), 4);
        assert_eq!(precip_bucket(2.5), 5);
    }

    #[test]
    fn deterministic() {
        let a = WeatherTable::generate(99);
        let b = WeatherTable::generate(99);
        assert_eq!(a.precip, b.precip);
        let c = WeatherTable::generate(100);
        assert_ne!(a.precip, c.precip);
    }

    #[test]
    fn csv_roundtrip() {
        let w = WeatherTable::generate(5);
        let csv = w.to_csv();
        let back = WeatherTable::from_csv(&csv).unwrap();
        assert_eq!(back.precip.len(), w.precip.len());
        for (a, b) in w.precip.iter().zip(back.precip.iter()) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn rain_reduces_demand() {
        let w = WeatherTable::generate(5);
        let dry_day = w.precip.iter().position(|&p| p == 0.0).unwrap() as i32;
        let wet_day = w.precip.iter().position(|&p| p > 0.5).unwrap() as i32;
        assert!(w.demand_multiplier(dry_day) > w.demand_multiplier(wet_day));
        assert_eq!(w.demand_multiplier(dry_day), 1.0);
    }

    #[test]
    fn out_of_range_days_dry() {
        let w = WeatherTable::generate(5);
        assert_eq!(w.get(-1), 0.0);
        assert_eq!(w.get(1_000_000), 0.0);
    }
}
