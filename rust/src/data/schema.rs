//! The trip-record schema: a simplified NYC TLC CSV layout carrying every
//! field the paper's queries touch, plus the landmark geometry (Goldman
//! Sachs and Citigroup headquarters) that Q1–Q3 filter on.

use crate::data::chrono::{format_datetime, parse_datetime};

/// CSV column order (header-less files, like the TLC drops of the era):
///
/// ```text
/// taxi_type,pickup_datetime,dropoff_datetime,passenger_count,
/// trip_distance,pickup_longitude,pickup_latitude,dropoff_longitude,
/// dropoff_latitude,payment_type,fare_amount,tip_amount,total_amount
/// ```
pub const NUM_COLUMNS: usize = 13;

/// Taxi colors (Q5).
pub const TAXI_YELLOW: u8 = 0;
pub const TAXI_GREEN: u8 = 1;

/// TLC payment codes (Q4): 1 = credit card, 2 = cash (others exist in the
/// real data — dispute, no-charge — and appear rarely here too).
pub const PAYMENT_CREDIT: u8 = 1;
pub const PAYMENT_CASH: u8 = 2;
pub const PAYMENT_OTHER: u8 = 3;

/// One parsed trip record.
#[derive(Debug, Clone, PartialEq)]
pub struct TripRecord {
    pub taxi_type: u8,
    pub pickup_ts: i64,
    pub dropoff_ts: i64,
    pub passenger_count: u8,
    pub trip_distance: f32,
    pub pickup_lon: f32,
    pub pickup_lat: f32,
    pub dropoff_lon: f32,
    pub dropoff_lat: f32,
    pub payment_type: u8,
    pub fare_amount: f32,
    pub tip_amount: f32,
    pub total_amount: f32,
}

impl TripRecord {
    /// Serialize as one CSV line (no trailing newline).
    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{},{},{:.2},{:.6},{:.6},{:.6},{:.6},{},{:.2},{:.2},{:.2}",
            self.taxi_type,
            format_datetime(self.pickup_ts),
            format_datetime(self.dropoff_ts),
            self.passenger_count,
            self.trip_distance,
            self.pickup_lon,
            self.pickup_lat,
            self.dropoff_lon,
            self.dropoff_lat,
            self.payment_type,
            self.fare_amount,
            self.tip_amount,
            self.total_amount
        )
    }

    /// Parse one CSV line. Returns `None` for malformed rows (the real
    /// TLC data has them; engines must skip, not crash).
    pub fn parse_csv(line: &[u8]) -> Option<TripRecord> {
        let mut fields = [b"" as &[u8]; NUM_COLUMNS];
        let mut n = 0;
        for part in line.split(|&b| b == b',') {
            if n >= NUM_COLUMNS {
                return None; // too many columns
            }
            fields[n] = part;
            n += 1;
        }
        if n != NUM_COLUMNS {
            return None;
        }
        Some(TripRecord {
            taxi_type: parse_u8(fields[0])?,
            pickup_ts: parse_datetime(fields[1])?,
            dropoff_ts: parse_datetime(fields[2])?,
            passenger_count: parse_u8(fields[3])?,
            trip_distance: parse_f32(fields[4])?,
            pickup_lon: parse_f32(fields[5])?,
            pickup_lat: parse_f32(fields[6])?,
            dropoff_lon: parse_f32(fields[7])?,
            dropoff_lat: parse_f32(fields[8])?,
            payment_type: parse_u8(fields[9])?,
            fare_amount: parse_f32(fields[10])?,
            tip_amount: parse_f32(fields[11])?,
            total_amount: parse_f32(fields[12])?,
        })
    }
}

#[inline]
pub fn parse_u8(b: &[u8]) -> Option<u8> {
    if b.is_empty() || b.len() > 3 {
        return None;
    }
    let mut v: u32 = 0;
    for &c in b {
        if !c.is_ascii_digit() {
            return None;
        }
        v = v * 10 + (c - b'0') as u32;
    }
    u8::try_from(v).ok()
}

/// Fast decimal parse for the fixed-precision floats the generator emits
/// (sign, digits, optional fraction). Falls back to `str::parse` for
/// anything fancier (exponents).
#[inline]
pub fn parse_f32(b: &[u8]) -> Option<f32> {
    let (neg, rest) = match b.first() {
        Some(b'-') => (true, &b[1..]),
        _ => (false, b),
    };
    if rest.is_empty() {
        return None;
    }
    let mut int_part: i64 = 0;
    let mut i = 0;
    while i < rest.len() && rest[i].is_ascii_digit() {
        int_part = int_part * 10 + (rest[i] - b'0') as i64;
        if int_part > 1 << 52 {
            return std::str::from_utf8(b).ok()?.parse().ok();
        }
        i += 1;
    }
    let mut value = int_part as f64;
    if i < rest.len() {
        if rest[i] != b'.' {
            return std::str::from_utf8(b).ok()?.parse().ok();
        }
        i += 1;
        let mut frac: i64 = 0;
        let mut scale: f64 = 1.0;
        while i < rest.len() {
            if !rest[i].is_ascii_digit() {
                return std::str::from_utf8(b).ok()?.parse().ok();
            }
            frac = frac * 10 + (rest[i] - b'0') as i64;
            scale *= 10.0;
            i += 1;
        }
        value += frac as f64 / scale;
    }
    Some(if neg { -value as f32 } else { value as f32 })
}

/// An axis-aligned geo bounding box (the paper filters "by geo
/// coordinates"; we use tight boxes around the buildings).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GeoBox {
    pub lon_min: f32,
    pub lon_max: f32,
    pub lat_min: f32,
    pub lat_max: f32,
}

impl GeoBox {
    #[inline]
    pub fn contains(&self, lon: f32, lat: f32) -> bool {
        lon >= self.lon_min && lon <= self.lon_max && lat >= self.lat_min && lat <= self.lat_max
    }

    /// A box that accepts everything (used when a query has no geo filter).
    pub const EVERYWHERE: GeoBox = GeoBox {
        lon_min: f32::NEG_INFINITY,
        lon_max: f32::INFINITY,
        lat_min: f32::NEG_INFINITY,
        lat_max: f32::INFINITY,
    };
}

/// Goldman Sachs HQ, 200 West St (Q1, Q3).
pub const GOLDMAN: GeoBox = GeoBox {
    lon_min: -74.0156,
    lon_max: -74.0138,
    lat_min: 40.7139,
    lat_max: 40.7155,
};

/// Citigroup HQ, 388 Greenwich St (Q2).
pub const CITIGROUP: GeoBox = GeoBox {
    lon_min: -74.0124,
    lon_max: -74.0106,
    lat_min: 40.7189,
    lat_max: 40.7205,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::chrono::epoch_from_datetime;

    fn sample() -> TripRecord {
        TripRecord {
            taxi_type: TAXI_YELLOW,
            pickup_ts: epoch_from_datetime(2013, 5, 14, 17, 5, 0),
            dropoff_ts: epoch_from_datetime(2013, 5, 14, 17, 30, 0),
            passenger_count: 2,
            trip_distance: 3.25,
            pickup_lon: -73.9857,
            pickup_lat: 40.7484,
            dropoff_lon: -74.0144,
            dropoff_lat: 40.7147,
            payment_type: PAYMENT_CREDIT,
            fare_amount: 14.5,
            tip_amount: 2.9,
            total_amount: 17.4,
        }
    }

    #[test]
    fn csv_roundtrip() {
        let r = sample();
        let line = r.to_csv();
        let back = TripRecord::parse_csv(line.as_bytes()).unwrap();
        assert_eq!(back.taxi_type, r.taxi_type);
        assert_eq!(back.pickup_ts, r.pickup_ts);
        assert_eq!(back.dropoff_ts, r.dropoff_ts);
        assert!((back.dropoff_lon - r.dropoff_lon).abs() < 1e-4);
        assert!((back.tip_amount - r.tip_amount).abs() < 1e-4);
        assert_eq!(back.payment_type, r.payment_type);
    }

    #[test]
    fn malformed_rows_rejected() {
        assert!(TripRecord::parse_csv(b"").is_none());
        assert!(TripRecord::parse_csv(b"1,2,3").is_none());
        let r = sample().to_csv();
        let too_many = format!("{r},extra");
        assert!(TripRecord::parse_csv(too_many.as_bytes()).is_none());
        let bad_date = r.replace("2013-05-14 17:05:00", "not-a-date-at-all!");
        assert!(TripRecord::parse_csv(bad_date.as_bytes()).is_none());
    }

    #[test]
    fn geo_boxes() {
        // The sample drops off at Goldman.
        let r = sample();
        assert!(GOLDMAN.contains(r.dropoff_lon, r.dropoff_lat));
        assert!(!CITIGROUP.contains(r.dropoff_lon, r.dropoff_lat));
        assert!(GeoBox::EVERYWHERE.contains(0.0, 0.0));
        assert!(!GOLDMAN.contains(-74.0144, 40.7200), "outside latitude band");
        // Goldman and Citigroup boxes are disjoint.
        assert!(GOLDMAN.lat_max < CITIGROUP.lat_min);
    }

    #[test]
    fn numeric_parsers() {
        assert_eq!(parse_u8(b"0"), Some(0));
        assert_eq!(parse_u8(b"255"), Some(255));
        assert_eq!(parse_u8(b"256"), None);
        assert_eq!(parse_u8(b"1a"), None);
        assert_eq!(parse_u8(b""), None);
        assert!((parse_f32(b"3.25").unwrap() - 3.25).abs() < 1e-6);
        assert!((parse_f32(b"-74.0144").unwrap() + 74.0144).abs() < 1e-4);
        assert_eq!(parse_f32(b"12").unwrap(), 12.0);
        assert_eq!(parse_f32(b""), None);
        assert_eq!(parse_f32(b"x"), None);
        // exponent falls back to std parse
        assert_eq!(parse_f32(b"1e2"), Some(100.0));
    }
}
