//! Dataset management: the synthetic TLC corpus, its manifest, and the
//! upload path into the simulated S3.

pub mod chrono;
pub mod schema;
pub mod taxi;
pub mod weather;

use crate::services::SimEnv;
use crate::util::ThreadPool;

/// Default bucket layout.
pub const INPUT_BUCKET: &str = "nyc-tlc";
pub const OUTPUT_BUCKET: &str = "flint-results";
pub const SHUFFLE_BUCKET: &str = "flint-shuffle";
pub const WEATHER_KEY: &str = "weather/daily.csv";

/// Manifest of a generated dataset living in the simulated S3.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub bucket: String,
    pub prefix: String,
    /// `(key, size_bytes)` per object, ordered by key.
    pub objects: Vec<(String, u64)>,
    pub total_bytes: u64,
    pub trips: u64,
    /// Key of the weather side table (same bucket).
    pub weather_key: String,
    /// Size of the weather side table object (the join plans scan it as
    /// a first-class input branch, which needs byte-range splits).
    pub weather_bytes: u64,
    /// Seed it was generated from (for reproducibility records).
    pub seed: u64,
}

impl Dataset {
    pub fn num_objects(&self) -> usize {
        self.objects.len()
    }

    /// Mean bytes per trip — used by the paper-scale extrapolation.
    pub fn bytes_per_trip(&self) -> f64 {
        if self.trips == 0 {
            0.0
        } else {
            self.total_bytes as f64 / self.trips as f64
        }
    }
}

/// Generate `trips` synthetic trips into the simulated S3, in objects of
/// roughly `config.data.object_bytes`, plus the weather side table.
/// Deterministic per config seed; parallel across objects.
pub fn generate_taxi_dataset(env: &SimEnv, prefix: &str, trips: u64) -> Dataset {
    let seed = env.config().seed;
    let object_bytes = env.config().data.object_bytes.max(64 * 1024);
    // ~131 bytes per row (measured from the generator's output format).
    let rows_per_object = (object_bytes / 131).max(1);
    let num_objects = trips.div_ceil(rows_per_object).max(1) as usize;

    env.s3().create_bucket(INPUT_BUCKET);
    env.s3().create_bucket(OUTPUT_BUCKET);
    env.s3().create_bucket(SHUFFLE_BUCKET);

    // Weather side table first (small).
    let weather = weather::WeatherTable::generate(seed);
    let weather_csv = weather.to_csv();
    let weather_bytes = weather_csv.len() as u64;
    env.s3()
        .put_object(INPUT_BUCKET, WEATHER_KEY, weather_csv)
        .expect("bucket exists");

    // Objects in parallel; each object is an independent RNG stream.
    let pool = ThreadPool::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4));
    let prefix_owned = prefix.to_string();
    let env2 = env.clone();
    let specs: Vec<(usize, u64)> = (0..num_objects)
        .map(|i| {
            let start = i as u64 * rows_per_object;
            let count = rows_per_object.min(trips - start);
            (i, count)
        })
        .collect();
    let results = pool.map(specs, move |(i, count)| {
        let key = format!("{}/part-{:05}.csv", prefix_owned, i);
        let data = taxi::generate_csv_object(seed, 1000 + i as u64, count);
        let size = data.len() as u64;
        env2.s3().put_object(INPUT_BUCKET, &key, data).expect("bucket exists");
        (key, size)
    });

    let mut objects: Vec<(String, u64)> = results
        .into_iter()
        .map(|r| r.expect("generation must not panic"))
        .collect();
    objects.sort();
    let total_bytes = objects.iter().map(|(_, s)| s).sum();

    Dataset {
        bucket: INPUT_BUCKET.to_string(),
        prefix: prefix.to_string(),
        objects,
        total_bytes,
        trips,
        weather_key: WEATHER_KEY.to_string(),
        weather_bytes,
        seed,
    }
}

/// Rebuild a manifest by listing the bucket (e.g. after a prior
/// generation in the same process).
pub fn load_dataset(env: &SimEnv, prefix: &str, trips: u64) -> Option<Dataset> {
    let listed = env.s3().list(INPUT_BUCKET, &format!("{prefix}/")).ok()?;
    if listed.is_empty() {
        return None;
    }
    let total_bytes = listed.iter().map(|(_, s)| s).sum();
    // A manifest without its weather side table is incomplete — Q6 fails
    // loudly and Q6J's dimension scan would silently join to nothing —
    // so a missing object means there is no dataset to load.
    let weather_bytes = env.s3().head_object(INPUT_BUCKET, WEATHER_KEY).ok()?;
    Some(Dataset {
        bucket: INPUT_BUCKET.to_string(),
        prefix: prefix.to_string(),
        objects: listed,
        total_bytes,
        trips,
        weather_key: WEATHER_KEY.to_string(),
        weather_bytes,
        seed: env.config().seed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FlintConfig;

    fn weather_size(env: &SimEnv) -> u64 {
        env.s3().head_object(INPUT_BUCKET, WEATHER_KEY).unwrap()
    }

    #[test]
    fn generate_creates_manifest_and_objects() {
        let env = SimEnv::new(FlintConfig::for_tests());
        let ds = generate_taxi_dataset(&env, "trips", 3_000);
        assert_eq!(ds.trips, 3_000);
        assert!(ds.num_objects() >= 2, "test config uses small objects");
        assert_eq!(ds.total_bytes, env.s3().bucket_bytes(INPUT_BUCKET) - weather_size(&env));
        // Every manifest object exists with the declared size.
        for (key, size) in &ds.objects {
            assert_eq!(env.s3().head_object(INPUT_BUCKET, key).unwrap(), *size);
        }
        // Row count across objects matches.
        let mut rows = 0u64;
        for (key, _) in &ds.objects {
            let (obj, _) = env
                .s3()
                .get_object(INPUT_BUCKET, key, env.flint_read_profile())
                .unwrap();
            rows += obj.split(|&b| b == b'\n').filter(|l| !l.is_empty()).count() as u64;
        }
        assert_eq!(rows, 3_000);
    }

    #[test]
    fn deterministic_across_runs() {
        let env1 = SimEnv::new(FlintConfig::for_tests());
        let env2 = SimEnv::new(FlintConfig::for_tests());
        let d1 = generate_taxi_dataset(&env1, "trips", 1_000);
        let d2 = generate_taxi_dataset(&env2, "trips", 1_000);
        assert_eq!(d1.objects, d2.objects);
        let (a, _) = env1
            .s3()
            .get_object(INPUT_BUCKET, &d1.objects[0].0, env1.flint_read_profile())
            .unwrap();
        let (b, _) = env2
            .s3()
            .get_object(INPUT_BUCKET, &d2.objects[0].0, env2.flint_read_profile())
            .unwrap();
        assert_eq!(a.bytes(), b.bytes());
    }

    #[test]
    fn load_rebuilds_manifest() {
        let env = SimEnv::new(FlintConfig::for_tests());
        let ds = generate_taxi_dataset(&env, "trips", 1_000);
        let loaded = load_dataset(&env, "trips", 1_000).unwrap();
        assert_eq!(loaded.objects, ds.objects);
        assert!(load_dataset(&env, "nothing-here", 0).is_none());
    }
}
