//! Dataset management: the synthetic TLC corpus, its manifest, and the
//! upload path into the simulated S3.

pub mod chrono;
pub mod schema;
pub mod taxi;
pub mod weather;

use crate::services::SimEnv;
use crate::util::ThreadPool;
use std::collections::BTreeMap;

/// Default bucket layout.
pub const INPUT_BUCKET: &str = "nyc-tlc";
pub const OUTPUT_BUCKET: &str = "flint-results";
pub const SHUFFLE_BUCKET: &str = "flint-shuffle";
pub const CACHE_BUCKET: &str = "flint-cache";
pub const WEATHER_KEY: &str = "weather/daily.csv";

/// Per-object column statistics recorded in the dataset manifest.
/// Integer-only (day/month indexes and a row count) so they stay `Eq`
/// and serialize exactly. Conservative by construction: every row in
/// the object falls inside the recorded ranges, so a scan may safely
/// skip the object when a query's predicate range is disjoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObjectStats {
    /// Min/max dropoff day index (days since 2009-01-01), inclusive.
    pub min_day: i32,
    pub max_day: i32,
    /// Min/max dropoff month index (months since 2009-01), inclusive.
    pub min_month: i32,
    pub max_month: i32,
    /// Exact row count.
    pub rows: u64,
}

impl ObjectStats {
    /// Encode as S3 user metadata. On real S3 metadata rides the PUT
    /// itself, so stamping stats onto each generated object is free —
    /// and any later HEAD can recover the `flint.scan.prune` signal
    /// without a manifest.
    pub fn to_meta(&self) -> Vec<(String, String)> {
        vec![
            ("stats-min-day".to_string(), self.min_day.to_string()),
            ("stats-max-day".to_string(), self.max_day.to_string()),
            ("stats-min-month".to_string(), self.min_month.to_string()),
            ("stats-max-month".to_string(), self.max_month.to_string()),
            ("stats-rows".to_string(), self.rows.to_string()),
        ]
    }

    /// Decode from HEAD user metadata. `None` unless every stat key is
    /// present and well-formed — partial or corrupt stats must read as
    /// *no* stats, never as a narrower (unsafe) range.
    pub fn from_meta(meta: &[(String, String)]) -> Option<ObjectStats> {
        fn get<T: std::str::FromStr>(meta: &[(String, String)], key: &str) -> Option<T> {
            meta.iter().find(|(k, _)| k == key)?.1.parse().ok()
        }
        Some(ObjectStats {
            min_day: get(meta, "stats-min-day")?,
            max_day: get(meta, "stats-max-day")?,
            min_month: get(meta, "stats-min-month")?,
            max_month: get(meta, "stats-max-month")?,
            rows: get(meta, "stats-rows")?,
        })
    }

    /// Whether a day predicate `[lo, hi]` can possibly match rows here.
    pub fn overlaps_days(&self, lo: i32, hi: i32) -> bool {
        self.max_day >= lo && self.min_day <= hi
    }

    /// Whether a month predicate `[lo, hi]` can possibly match rows here.
    pub fn overlaps_months(&self, lo: i32, hi: i32) -> bool {
        self.max_month >= lo && self.min_month <= hi
    }
}

/// Month index (months since 2009-01) of a day index.
fn month_of_day(day: i64) -> i32 {
    let (y, m, _) = chrono::civil_from_days(chrono::days_from_civil(2009, 1, 1) + day);
    ((y - 2009) * 12 + (m as i64 - 1)) as i32
}

/// Manifest of a generated dataset living in the simulated S3.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub bucket: String,
    pub prefix: String,
    /// `(key, size_bytes)` per object, ordered by key.
    pub objects: Vec<(String, u64)>,
    pub total_bytes: u64,
    pub trips: u64,
    /// Key of the weather side table (same bucket).
    pub weather_key: String,
    /// Size of the weather side table object (the join plans scan it as
    /// a first-class input branch, which needs byte-range splits).
    pub weather_bytes: u64,
    /// Seed it was generated from (for reproducibility records).
    pub seed: u64,
    /// Per-object day/month statistics, keyed by object key. Empty when
    /// the manifest was rebuilt from a bucket listing (stats live only
    /// in the generated manifest, like a catalog — a listing can't
    /// recover them without reading every object).
    pub object_stats: BTreeMap<String, ObjectStats>,
}

impl Dataset {
    pub fn num_objects(&self) -> usize {
        self.objects.len()
    }

    /// Mean bytes per trip — used by the paper-scale extrapolation.
    pub fn bytes_per_trip(&self) -> f64 {
        if self.trips == 0 {
            0.0
        } else {
            self.total_bytes as f64 / self.trips as f64
        }
    }
}

/// Generate `trips` synthetic trips into the simulated S3, in objects of
/// roughly `config.data.object_bytes`, plus the weather side table.
/// Deterministic per config seed; parallel across objects.
pub fn generate_taxi_dataset(env: &SimEnv, prefix: &str, trips: u64) -> Dataset {
    let seed = env.config().seed;
    let object_bytes = env.config().data.object_bytes.max(64 * 1024);
    // ~131 bytes per row (measured from the generator's output format).
    let rows_per_object = (object_bytes / 131).max(1);
    let num_objects = trips.div_ceil(rows_per_object).max(1) as usize;

    env.s3().create_bucket(INPUT_BUCKET);
    env.s3().create_bucket(OUTPUT_BUCKET);
    env.s3().create_bucket(SHUFFLE_BUCKET);

    // Weather side table first (small).
    let weather = weather::WeatherTable::generate(seed);
    let weather_csv = weather.to_csv();
    let weather_bytes = weather_csv.len() as u64;
    env.s3()
        .put_object(INPUT_BUCKET, WEATHER_KEY, weather_csv)
        .expect("bucket exists");

    // Objects in parallel; each object is an independent RNG stream and
    // covers its own contiguous day window (object i of N gets the i-th
    // slice of the dataset's 2738-day timeline), so the manifest's
    // min/max-day stats are tight enough for scan pruning to bite.
    let pool = ThreadPool::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4));
    let prefix_owned = prefix.to_string();
    let env2 = env.clone();
    let total_days = weather::NUM_DAYS as i64;
    let specs: Vec<(usize, u64)> = (0..num_objects)
        .map(|i| {
            let start = i as u64 * rows_per_object;
            let count = rows_per_object.min(trips - start);
            (i, count)
        })
        .collect();
    let results = pool.map(specs, move |(i, count)| {
        let key = format!("{}/part-{:05}.csv", prefix_owned, i);
        let day_lo = i as i64 * total_days / num_objects as i64;
        let day_hi =
            ((i as i64 + 1) * total_days / num_objects as i64 - 1).max(day_lo);
        let data =
            taxi::generate_csv_object_windowed(seed, 1000 + i as u64, count, day_lo, day_hi);
        let size = data.len() as u64;
        env2.s3().put_object(INPUT_BUCKET, &key, data).expect("bucket exists");
        let stats = ObjectStats {
            min_day: day_lo as i32,
            max_day: day_hi as i32,
            min_month: month_of_day(day_lo),
            max_month: month_of_day(day_hi),
            rows: count,
        };
        // Stamp the stats onto the object itself, so listing-resolved
        // scans (no manifest) can recover them via HEAD.
        env2.s3()
            .set_object_meta(INPUT_BUCKET, &key, stats.to_meta())
            .expect("object was just written");
        (key, size, stats)
    });

    let mut entries: Vec<(String, u64, ObjectStats)> = results
        .into_iter()
        .map(|r| r.expect("generation must not panic"))
        .collect();
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    let object_stats: BTreeMap<String, ObjectStats> =
        entries.iter().map(|(k, _, st)| (k.clone(), *st)).collect();
    let objects: Vec<(String, u64)> =
        entries.into_iter().map(|(k, s, _)| (k, s)).collect();
    let total_bytes = objects.iter().map(|(_, s)| s).sum();

    Dataset {
        bucket: INPUT_BUCKET.to_string(),
        prefix: prefix.to_string(),
        objects,
        total_bytes,
        trips,
        weather_key: WEATHER_KEY.to_string(),
        weather_bytes,
        seed,
        object_stats,
    }
}

/// Rebuild a manifest by listing the bucket (e.g. after a prior
/// generation in the same process).
pub fn load_dataset(env: &SimEnv, prefix: &str, trips: u64) -> Option<Dataset> {
    let listed = env.s3().list(INPUT_BUCKET, &format!("{prefix}/")).ok()?;
    if listed.is_empty() {
        return None;
    }
    let total_bytes = listed.iter().map(|(_, s)| s).sum();
    // A manifest without its weather side table is incomplete — Q6 fails
    // loudly and Q6J's dimension scan would silently join to nothing —
    // so a missing object means there is no dataset to load.
    let weather_bytes = env.s3().head_object(INPUT_BUCKET, WEATHER_KEY).ok()?;
    Some(Dataset {
        bucket: INPUT_BUCKET.to_string(),
        prefix: prefix.to_string(),
        objects: listed,
        total_bytes,
        trips,
        weather_key: WEATHER_KEY.to_string(),
        weather_bytes,
        seed: env.config().seed,
        object_stats: BTreeMap::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FlintConfig;

    fn weather_size(env: &SimEnv) -> u64 {
        env.s3().head_object(INPUT_BUCKET, WEATHER_KEY).unwrap()
    }

    #[test]
    fn generate_creates_manifest_and_objects() {
        let env = SimEnv::new(FlintConfig::for_tests());
        let ds = generate_taxi_dataset(&env, "trips", 3_000);
        assert_eq!(ds.trips, 3_000);
        assert!(ds.num_objects() >= 2, "test config uses small objects");
        assert_eq!(ds.total_bytes, env.s3().bucket_bytes(INPUT_BUCKET) - weather_size(&env));
        // Every manifest object exists with the declared size.
        for (key, size) in &ds.objects {
            assert_eq!(env.s3().head_object(INPUT_BUCKET, key).unwrap(), *size);
        }
        // Row count across objects matches.
        let mut rows = 0u64;
        for (key, _) in &ds.objects {
            let (obj, _) = env
                .s3()
                .get_object(INPUT_BUCKET, key, env.flint_read_profile())
                .unwrap();
            rows += obj.split(|&b| b == b'\n').filter(|l| !l.is_empty()).count() as u64;
        }
        assert_eq!(rows, 3_000);
    }

    #[test]
    fn manifest_stats_are_conservative_and_tile_the_timeline() {
        use crate::data::chrono::{day_index, month_index};
        use crate::data::schema::TripRecord;
        let env = SimEnv::new(FlintConfig::for_tests());
        let ds = generate_taxi_dataset(&env, "trips", 3_000);
        assert_eq!(ds.object_stats.len(), ds.num_objects());
        let mut rows = 0u64;
        for (key, _) in &ds.objects {
            let st = ds.object_stats[key];
            assert!(st.min_day <= st.max_day);
            assert!(st.min_month <= st.max_month);
            rows += st.rows;
            // Every row really falls inside the recorded ranges.
            let (obj, _) = env
                .s3()
                .get_object(INPUT_BUCKET, key, env.flint_read_profile())
                .unwrap();
            for line in obj.split(|&b| b == b'\n').filter(|l| !l.is_empty()) {
                let r = TripRecord::parse_csv(line).unwrap();
                let d = day_index(r.dropoff_ts);
                let m = month_index(r.dropoff_ts);
                assert!((st.min_day..=st.max_day).contains(&d), "day {d} outside stats");
                assert!((st.min_month..=st.max_month).contains(&m), "month {m} outside stats");
            }
        }
        assert_eq!(rows, 3_000, "stats row counts sum to the manifest trips");
        // Object windows tile the full 2009-01-01..2016-06-30 timeline.
        let first = ds.object_stats[&ds.objects[0].0];
        let last = ds.object_stats[&ds.objects.last().unwrap().0];
        assert_eq!(first.min_day, 0);
        assert_eq!(last.max_day as usize, weather::NUM_DAYS - 1);
        assert!(ds.num_objects() >= 2, "test config uses small objects");
        // Disjoint windows make the predicate-overlap test selective.
        assert!(first.overlaps_days(0, 10));
        assert!(!last.overlaps_days(0, 10));
        assert!(!first.overlaps_months(last.min_month.max(first.max_month + 1), 200));
    }

    #[test]
    fn object_stats_meta_roundtrip() {
        let st = ObjectStats { min_day: 3, max_day: 9, min_month: 0, max_month: 1, rows: 42 };
        assert_eq!(ObjectStats::from_meta(&st.to_meta()), Some(st));
        // Partial or empty metadata decodes to no stats at all.
        let mut partial = st.to_meta();
        partial.pop();
        assert_eq!(ObjectStats::from_meta(&partial), None);
        assert_eq!(ObjectStats::from_meta(&[]), None);
        // Every generated object carries its stats in S3 user metadata.
        let env = SimEnv::new(FlintConfig::for_tests());
        let ds = generate_taxi_dataset(&env, "trips", 1_000);
        for (key, _) in &ds.objects {
            let (_, meta) = env.s3().head_object_meta(INPUT_BUCKET, key).unwrap();
            assert_eq!(ObjectStats::from_meta(&meta), Some(ds.object_stats[key]));
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let env1 = SimEnv::new(FlintConfig::for_tests());
        let env2 = SimEnv::new(FlintConfig::for_tests());
        let d1 = generate_taxi_dataset(&env1, "trips", 1_000);
        let d2 = generate_taxi_dataset(&env2, "trips", 1_000);
        assert_eq!(d1.objects, d2.objects);
        let (a, _) = env1
            .s3()
            .get_object(INPUT_BUCKET, &d1.objects[0].0, env1.flint_read_profile())
            .unwrap();
        let (b, _) = env2
            .s3()
            .get_object(INPUT_BUCKET, &d2.objects[0].0, env2.flint_read_profile())
            .unwrap();
        assert_eq!(a.bytes(), b.bytes());
    }

    #[test]
    fn load_rebuilds_manifest() {
        let env = SimEnv::new(FlintConfig::for_tests());
        let ds = generate_taxi_dataset(&env, "trips", 1_000);
        let loaded = load_dataset(&env, "trips", 1_000).unwrap();
        assert_eq!(loaded.objects, ds.objects);
        assert!(loaded.object_stats.is_empty(), "a listing cannot recover stats");
        assert!(load_dataset(&env, "nothing-here", 0).is_none());
    }
}
