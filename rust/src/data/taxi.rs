//! Synthetic NYC TLC trip generator.
//!
//! The paper's 215 GB / ~1.3 B-trip dataset is not redistributable (and
//! would not fit here); this generator produces TLC-schema CSV with the
//! *structure* the seven evaluation queries measure (DESIGN.md §2):
//!
//! * commute-shaped hourly drop-off profile, with dedicated hot spots at
//!   the Goldman Sachs and Citigroup headquarters (Q1–Q3),
//! * credit-card share rising over the 2009→2016 months (Q4 — Schneider's
//!   famous cash→credit crossover),
//! * green cabs appearing in Aug 2013 and growing (Q5),
//! * daily volume coupled to the synthetic weather table (Q6),
//! * generous tippers (> $10) concentrated at the banks (Q3).
//!
//! Generation is deterministic per `(seed, object_index)` and
//! parallelizes across objects.

use crate::data::chrono::{days_from_civil, epoch_from_datetime, month_index};
use crate::data::schema::{
    TripRecord, CITIGROUP, GOLDMAN, PAYMENT_CASH, PAYMENT_CREDIT, PAYMENT_OTHER, TAXI_GREEN,
    TAXI_YELLOW,
};
use crate::data::weather::WeatherTable;
use crate::util::rng::Pcg64;

/// Fraction of trips that drop off at each bank hot spot.
pub const P_GOLDMAN: f64 = 0.0020;
pub const P_CITIGROUP: f64 = 0.0018;

/// Hour-of-day weights for ordinary trips (sums to anything; sampled via
/// cumulative table). Two commute peaks plus an evening shoulder.
const HOUR_WEIGHTS: [f64; 24] = [
    1.7, 1.1, 0.8, 0.6, 0.5, 0.7, 1.5, 2.8, 3.6, 3.0, 2.6, 2.6, 2.8, 2.7, 2.8, 3.0, 3.2, 3.8,
    4.2, 4.0, 3.6, 3.2, 2.8, 2.2,
];

/// Hour weights for bank drop-offs: strongly morning-peaked (people
/// arriving at work) with a lunch shoulder — gives Q1/Q2 a distinctive,
/// assertable shape.
const BANK_HOUR_WEIGHTS: [f64; 24] = [
    0.2, 0.1, 0.1, 0.1, 0.2, 0.8, 2.5, 5.5, 7.0, 5.0, 2.5, 2.0, 2.2, 1.8, 1.5, 1.2, 1.0, 1.2,
    1.5, 1.6, 1.2, 0.8, 0.5, 0.3,
];

/// Trip generator: draws independent trips, deterministic per stream.
pub struct TripGenerator {
    rng: Pcg64,
    weather: WeatherTable,
    hour_cum: [f64; 24],
    bank_hour_cum: [f64; 24],
    first_day: i64,
    /// First day index (days since 2009-01-01) this generator may emit.
    day_lo: i64,
    /// Number of days in the emittable window starting at `day_lo`.
    num_days: i64,
}

fn cumulative(w: &[f64; 24]) -> [f64; 24] {
    let mut cum = [0.0; 24];
    let mut acc = 0.0;
    for (i, &x) in w.iter().enumerate() {
        acc += x;
        cum[i] = acc;
    }
    cum
}

impl TripGenerator {
    pub fn new(seed: u64, stream: u64) -> TripGenerator {
        TripGenerator {
            rng: Pcg64::new(seed, stream),
            weather: WeatherTable::generate(seed),
            hour_cum: cumulative(&HOUR_WEIGHTS),
            bank_hour_cum: cumulative(&BANK_HOUR_WEIGHTS),
            first_day: days_from_civil(2009, 1, 1),
            day_lo: 0,
            num_days: days_from_civil(2016, 6, 30) - days_from_civil(2009, 1, 1) + 1,
        }
    }

    /// Like [`TripGenerator::new`], but restricted to day indexes
    /// `[day_lo, day_hi]` (inclusive, days since 2009-01-01). The dataset
    /// generator gives each object a distinct window so the manifest's
    /// min/max-day statistics are selective enough to prune scans on.
    /// `new(..)` is exactly `new_windowed(.., 0, num_days - 1)`.
    pub fn new_windowed(seed: u64, stream: u64, day_lo: i64, day_hi: i64) -> TripGenerator {
        let mut g = TripGenerator::new(seed, stream);
        assert!(
            0 <= day_lo && day_lo <= day_hi && day_hi < g.num_days,
            "day window [{day_lo}, {day_hi}] outside dataset range [0, {})",
            g.num_days
        );
        g.day_lo = day_lo;
        g.num_days = day_hi - day_lo + 1;
        g
    }

    /// Generate one trip.
    pub fn next_trip(&mut self) -> TripRecord {
        // Day: uniform over the range, thinned by weather demand so rainy
        // days genuinely have fewer trips (the Q6 signal).
        let day = loop {
            let d = self.day_lo + self.rng.range_i64(0, self.num_days);
            if self.rng.f64() < self.weather.demand_multiplier(d as i32) {
                break d;
            }
        };
        let day_abs = self.first_day + day;
        let (y, mo, dd) = crate::data::chrono::civil_from_days(day_abs);

        // Destination class.
        let roll = self.rng.f64();
        let (dropoff_lon, dropoff_lat, at_bank) = if roll < P_GOLDMAN {
            (
                self.rng.range_f64(GOLDMAN.lon_min as f64, GOLDMAN.lon_max as f64) as f32,
                self.rng.range_f64(GOLDMAN.lat_min as f64, GOLDMAN.lat_max as f64) as f32,
                true,
            )
        } else if roll < P_GOLDMAN + P_CITIGROUP {
            (
                self.rng.range_f64(CITIGROUP.lon_min as f64, CITIGROUP.lon_max as f64) as f32,
                self.rng.range_f64(CITIGROUP.lat_min as f64, CITIGROUP.lat_max as f64) as f32,
                true,
            )
        } else {
            // Manhattan-ish scatter; a slice of these will land in the
            // boxes only with negligible probability (the boxes are tiny).
            (
                (-73.98 + self.rng.normal() * 0.035) as f32,
                (40.75 + self.rng.normal() * 0.045) as f32,
                false,
            )
        };

        let hour_cum = if at_bank { &self.bank_hour_cum } else { &self.hour_cum };
        let hour = self.rng.pick_cumulative(hour_cum) as u32;
        let minute = self.rng.below(60) as u32;
        let second = self.rng.below(60) as u32;
        let dropoff_ts = epoch_from_datetime(y, mo, dd, hour, minute, second);

        let trip_minutes = 4.0 + self.rng.exp(1.0 / 9.0).min(90.0);
        let pickup_ts = dropoff_ts - (trip_minutes * 60.0) as i64;
        let trip_distance = (0.4 + trip_minutes * self.rng.range_f64(0.12, 0.35)) as f32;

        // Pickup scatter.
        let pickup_lon = (-73.97 + self.rng.normal() * 0.03) as f32;
        let pickup_lat = (40.75 + self.rng.normal() * 0.04) as f32;

        // Green cabs exist only from Aug 2013, growing to ~22% share.
        let m_idx = month_index(dropoff_ts);
        let green_start = (2013 - 2009) * 12 + 7; // Aug 2013
        let taxi_type = if m_idx >= green_start {
            let ramp = ((m_idx - green_start) as f64 / 36.0).min(1.0);
            if self.rng.chance(0.22 * ramp) {
                TAXI_GREEN
            } else {
                TAXI_YELLOW
            }
        } else {
            TAXI_YELLOW
        };

        // Credit share rises linearly ~32% (2009) -> ~62% (2016).
        let p_credit = 0.32 + 0.30 * (m_idx as f64 / 89.0).clamp(0.0, 1.0);
        let pay_roll = self.rng.f64();
        let payment_type = if pay_roll < p_credit {
            PAYMENT_CREDIT
        } else if pay_roll < 0.985 {
            PAYMENT_CASH
        } else {
            PAYMENT_OTHER
        };

        let fare = (2.5 + trip_distance as f64 * 2.5 + trip_minutes * 0.35) as f32;
        // Tips: card tips recorded; bank drop-offs tip generously — Q3's
        // "who are the generous tippers?" needs > $10 tips to exist and be
        // concentrated at Goldman.
        let tip_amount = if payment_type == PAYMENT_CREDIT {
            let base = fare as f64 * self.rng.range_f64(0.08, 0.30);
            let generous = if at_bank { self.rng.chance(0.18) } else { self.rng.chance(0.01) };
            let tip = if generous { base + self.rng.range_f64(8.0, 30.0) } else { base };
            tip as f32
        } else {
            0.0
        };

        TripRecord {
            taxi_type,
            pickup_ts,
            dropoff_ts,
            passenger_count: 1 + self.rng.below(5) as u8,
            trip_distance,
            pickup_lon,
            pickup_lat,
            dropoff_lon,
            dropoff_lat,
            payment_type,
            fare_amount: fare,
            tip_amount,
            total_amount: fare + tip_amount,
        }
    }

    /// The weather table this generator couples to.
    pub fn weather(&self) -> &WeatherTable {
        &self.weather
    }
}

/// Render `count` trips from `(seed, stream)` as CSV bytes.
pub fn generate_csv_object(seed: u64, stream: u64, count: u64) -> Vec<u8> {
    render_csv(TripGenerator::new(seed, stream), count)
}

/// [`generate_csv_object`] restricted to dropoff days `[day_lo, day_hi]`
/// inclusive (days since 2009-01-01).
pub fn generate_csv_object_windowed(
    seed: u64,
    stream: u64,
    count: u64,
    day_lo: i64,
    day_hi: i64,
) -> Vec<u8> {
    render_csv(TripGenerator::new_windowed(seed, stream, day_lo, day_hi), count)
}

fn render_csv(mut g: TripGenerator, count: u64) -> Vec<u8> {
    // ~131 bytes/row observed; reserve generously to avoid re-allocs.
    let mut out = Vec::with_capacity((count as usize) * 140);
    for _ in 0..count {
        let trip = g.next_trip();
        out.extend_from_slice(trip.to_csv().as_bytes());
        out.push(b'\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::chrono::hour_of_day;

    #[test]
    fn deterministic_per_stream() {
        let a = generate_csv_object(42, 0, 100);
        let b = generate_csv_object(42, 0, 100);
        assert_eq!(a, b);
        let c = generate_csv_object(42, 1, 100);
        assert_ne!(a, c);
    }

    #[test]
    fn all_rows_parse_and_are_in_range() {
        let csv = generate_csv_object(42, 0, 2_000);
        let mut n = 0;
        for line in csv.split(|&b| b == b'\n').filter(|l| !l.is_empty()) {
            let r = TripRecord::parse_csv(line).expect("generated row must parse");
            let m = month_index(r.dropoff_ts);
            assert!((0..=89).contains(&m), "month index {m}");
            assert!(r.pickup_ts < r.dropoff_ts);
            assert!(r.total_amount >= r.fare_amount);
            n += 1;
        }
        assert_eq!(n, 2_000);
    }

    #[test]
    fn windowed_generation_stays_in_window() {
        use crate::data::chrono::day_index;
        let csv = generate_csv_object_windowed(42, 0, 2_000, 100, 199);
        let mut n = 0;
        for line in csv.split(|&b| b == b'\n').filter(|l| !l.is_empty()) {
            let r = TripRecord::parse_csv(line).expect("generated row must parse");
            let d = day_index(r.dropoff_ts);
            assert!((100..=199).contains(&d), "day {d} outside window");
            n += 1;
        }
        assert_eq!(n, 2_000);
        // The full-range constructor is the degenerate window.
        let full = TripGenerator::new(42, 0);
        let windowed =
            generate_csv_object_windowed(42, 0, 500, 0, full.num_days - 1);
        assert_eq!(windowed, generate_csv_object(42, 0, 500));
    }

    #[test]
    fn hotspots_present_at_expected_rate() {
        let csv = generate_csv_object(7, 3, 50_000);
        let mut goldman = 0u32;
        let mut citi = 0u32;
        for line in csv.split(|&b| b == b'\n').filter(|l| !l.is_empty()) {
            let r = TripRecord::parse_csv(line).unwrap();
            if GOLDMAN.contains(r.dropoff_lon, r.dropoff_lat) {
                goldman += 1;
            }
            if CITIGROUP.contains(r.dropoff_lon, r.dropoff_lat) {
                citi += 1;
            }
        }
        // ~100 and ~90 expected on 50k; allow generous slack.
        assert!((50..200).contains(&goldman), "goldman={goldman}");
        assert!((40..180).contains(&citi), "citi={citi}");
    }

    #[test]
    fn bank_dropoffs_morning_peaked() {
        let csv = generate_csv_object(7, 4, 200_000);
        let mut bank_hours = [0u32; 24];
        for line in csv.split(|&b| b == b'\n').filter(|l| !l.is_empty()) {
            let r = TripRecord::parse_csv(line).unwrap();
            if GOLDMAN.contains(r.dropoff_lon, r.dropoff_lat) {
                bank_hours[hour_of_day(r.dropoff_ts) as usize] += 1;
            }
        }
        let morning: u32 = bank_hours[7..10].iter().sum();
        let night: u32 = bank_hours[0..5].iter().sum();
        assert!(morning > night * 3, "morning={morning} night={night}");
    }

    #[test]
    fn credit_share_rises_over_time() {
        let csv = generate_csv_object(11, 5, 100_000);
        let (mut early_credit, mut early_n, mut late_credit, mut late_n) = (0u32, 0u32, 0u32, 0u32);
        for line in csv.split(|&b| b == b'\n').filter(|l| !l.is_empty()) {
            let r = TripRecord::parse_csv(line).unwrap();
            let m = month_index(r.dropoff_ts);
            if m < 24 {
                early_n += 1;
                if r.payment_type == PAYMENT_CREDIT {
                    early_credit += 1;
                }
            } else if m >= 66 {
                late_n += 1;
                if r.payment_type == PAYMENT_CREDIT {
                    late_credit += 1;
                }
            }
        }
        let early = early_credit as f64 / early_n as f64;
        let late = late_credit as f64 / late_n as f64;
        assert!(late > early + 0.15, "early={early:.2} late={late:.2}");
    }

    #[test]
    fn green_cabs_only_after_aug_2013() {
        let csv = generate_csv_object(11, 6, 100_000);
        let green_start = (2013 - 2009) * 12 + 7;
        let mut green_after = 0u32;
        for line in csv.split(|&b| b == b'\n').filter(|l| !l.is_empty()) {
            let r = TripRecord::parse_csv(line).unwrap();
            if r.taxi_type == TAXI_GREEN {
                assert!(month_index(r.dropoff_ts) >= green_start, "green cab before Aug 2013");
                green_after += 1;
            }
        }
        assert!(green_after > 1000, "green cabs exist: {green_after}");
    }

    #[test]
    fn generous_tips_concentrated_at_banks() {
        let csv = generate_csv_object(13, 7, 200_000);
        let (mut bank_big, mut bank_n, mut other_big, mut other_n) = (0u32, 0u32, 0u32, 0u32);
        for line in csv.split(|&b| b == b'\n').filter(|l| !l.is_empty()) {
            let r = TripRecord::parse_csv(line).unwrap();
            let at_bank = GOLDMAN.contains(r.dropoff_lon, r.dropoff_lat)
                || CITIGROUP.contains(r.dropoff_lon, r.dropoff_lat);
            let big = r.tip_amount > 10.0;
            if at_bank {
                bank_n += 1;
                if big {
                    bank_big += 1;
                }
            } else {
                other_n += 1;
                if big {
                    other_big += 1;
                }
            }
        }
        let bank_rate = bank_big as f64 / bank_n.max(1) as f64;
        let other_rate = other_big as f64 / other_n.max(1) as f64;
        assert!(
            bank_rate > other_rate * 2.0,
            "bank_rate={bank_rate:.3} other_rate={other_rate:.3}"
        );
    }
}
