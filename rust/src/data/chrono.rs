//! Civil-calendar ↔ epoch conversions (Howard Hinnant's algorithms).
//!
//! The TLC dataset carries `YYYY-MM-DD HH:MM:SS` timestamps; queries
//! aggregate by hour (Q1–Q3), by month across 2009–2016 (Q4, Q5), and by
//! day for the weather join (Q6). No date crate is vendored, so the two
//! classic algorithms live here, tested against known fixed points.

/// Days since 1970-01-01 for a civil date (proleptic Gregorian).
pub fn days_from_civil(y: i64, m: u32, d: u32) -> i64 {
    debug_assert!((1..=12).contains(&m), "month {m}");
    debug_assert!((1..=31).contains(&d), "day {d}");
    let y = if m <= 2 { y - 1 } else { y };
    let era = if y >= 0 { y } else { y - 399 } / 400;
    let yoe = (y - era * 400) as u64; // [0, 399]
    let mp = (m as u64 + 9) % 12; // [0, 11]
    let doy = (153 * mp + 2) / 5 + d as u64 - 1; // [0, 365]
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy; // [0, 146096]
    era * 146097 + doe as i64 - 719468
}

/// Civil date `(y, m, d)` for days since 1970-01-01.
pub fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719468;
    let era = if z >= 0 { z } else { z - 146096 } / 146097;
    let doe = (z - era * 146097) as u64; // [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36524 - doe / 146096) / 365; // [0, 399]
    let y = yoe as i64 + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100); // [0, 365]
    let mp = (5 * doy + 2) / 153; // [0, 11]
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32; // [1, 31]
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32; // [1, 12]
    (if m <= 2 { y + 1 } else { y }, m, d)
}

/// Unix timestamp (UTC, seconds) for a civil datetime.
pub fn epoch_from_datetime(y: i64, mo: u32, d: u32, h: u32, mi: u32, s: u32) -> i64 {
    days_from_civil(y, mo, d) * 86400 + h as i64 * 3600 + mi as i64 * 60 + s as i64
}

/// `(y, mo, d, h, mi, s)` from a unix timestamp.
pub fn datetime_from_epoch(ts: i64) -> (i64, u32, u32, u32, u32, u32) {
    let days = ts.div_euclid(86400);
    let secs = ts.rem_euclid(86400);
    let (y, mo, d) = civil_from_days(days);
    (y, mo, d, (secs / 3600) as u32, ((secs % 3600) / 60) as u32, (secs % 60) as u32)
}

/// Format as the TLC CSV `YYYY-MM-DD HH:MM:SS`.
pub fn format_datetime(ts: i64) -> String {
    let (y, mo, d, h, mi, s) = datetime_from_epoch(ts);
    format!("{y:04}-{mo:02}-{d:02} {h:02}:{mi:02}:{s:02}")
}

/// Parse `YYYY-MM-DD HH:MM:SS` (fast, byte-level; the executor hot path).
/// Returns `None` on malformed input.
#[inline]
pub fn parse_datetime(b: &[u8]) -> Option<i64> {
    if b.len() < 19 {
        return None;
    }
    #[inline]
    fn num(b: &[u8]) -> Option<i64> {
        let mut v: i64 = 0;
        for &c in b {
            if !c.is_ascii_digit() {
                return None;
            }
            v = v * 10 + (c - b'0') as i64;
        }
        Some(v)
    }
    if b[4] != b'-' || b[7] != b'-' || b[10] != b' ' || b[13] != b':' || b[16] != b':' {
        return None;
    }
    let y = num(&b[0..4])?;
    let mo = num(&b[5..7])? as u32;
    let d = num(&b[8..10])? as u32;
    let h = num(&b[11..13])? as u32;
    let mi = num(&b[14..16])? as u32;
    let s = num(&b[17..19])? as u32;
    if !(1..=12).contains(&mo) || !(1..=31).contains(&d) || h > 23 || mi > 59 || s > 59 {
        return None;
    }
    Some(epoch_from_datetime(y, mo, d, h, mi, s))
}

/// Hour-of-day from a unix timestamp (what Q1–Q3 key on).
#[inline]
pub fn hour_of_day(ts: i64) -> u32 {
    (ts.rem_euclid(86400) / 3600) as u32
}

/// Months elapsed since January 2009 — the Q4/Q5 aggregation key across
/// the paper's Jan 2009 … Jun 2016 dataset (0..=89).
///
/// Hot path (§Perf): a day→month lookup table covering 2009–2017 avoids
/// the civil-calendar divisions for in-range timestamps (the common case
/// — every generated trip); out-of-range falls back to the full
/// conversion.
#[inline]
pub fn month_index(ts: i64) -> i32 {
    let day = ts.div_euclid(86400) - EPOCH_2009_DAYS;
    if (0..DAY_TO_MONTH_DAYS as i64).contains(&day) {
        day_month_lut()[day as usize] as i32
    } else {
        month_index_slow(ts)
    }
}

/// Uncached month index (the LUT's oracle).
pub fn month_index_slow(ts: i64) -> i32 {
    let (y, m, _) = civil_from_days(ts.div_euclid(86400));
    ((y - 2009) * 12 + (m as i64 - 1)) as i32
}

/// Days since epoch of 2009-01-01 (`days_from_civil(2009, 1, 1)`).
const EPOCH_2009_DAYS: i64 = 14245;
/// LUT coverage: 2009-01-01 .. 2017-12-31.
const DAY_TO_MONTH_DAYS: usize = 3287;

fn day_month_lut() -> &'static [u8; DAY_TO_MONTH_DAYS] {
    static LUT: once_cell::sync::OnceCell<[u8; DAY_TO_MONTH_DAYS]> =
        once_cell::sync::OnceCell::new();
    LUT.get_or_init(|| {
        let mut lut = [0u8; DAY_TO_MONTH_DAYS];
        for (d, slot) in lut.iter_mut().enumerate() {
            let (y, m, _) = civil_from_days(EPOCH_2009_DAYS + d as i64);
            *slot = ((y - 2009) * 12 + (m as i64 - 1)) as u8;
        }
        lut
    })
}

/// Days elapsed since 2009-01-01 — the Q6 weather-join key.
#[inline]
pub fn day_index(ts: i64) -> i32 {
    (ts.div_euclid(86400) - days_from_civil(2009, 1, 1)) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    #[test]
    fn known_fixed_points() {
        assert_eq!(days_from_civil(1970, 1, 1), 0);
        assert_eq!(days_from_civil(2000, 3, 1), 11017);
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        // Paper's dataset bounds.
        assert_eq!(civil_from_days(days_from_civil(2009, 1, 1)), (2009, 1, 1));
        assert_eq!(civil_from_days(days_from_civil(2016, 6, 30)), (2016, 6, 30));
    }

    #[test]
    fn leap_years() {
        assert_eq!(
            days_from_civil(2012, 3, 1) - days_from_civil(2012, 2, 28),
            2,
            "2012 is a leap year"
        );
        assert_eq!(
            days_from_civil(2013, 3, 1) - days_from_civil(2013, 2, 28),
            1,
            "2013 is not"
        );
    }

    #[test]
    fn prop_roundtrip_days() {
        forall("civil-roundtrip", 500, |g| {
            let z = g.i64(-200_000, 200_000);
            let (y, m, d) = civil_from_days(z);
            if days_from_civil(y, m, d) != z {
                return Err(format!("day {z} -> {y}-{m}-{d} -> {}", days_from_civil(y, m, d)));
            }
            Ok(())
        });
    }

    #[test]
    fn format_and_parse_roundtrip() {
        forall("datetime-roundtrip", 300, |g| {
            let ts = g.i64(1230768000, 1467244800); // 2009-01-01 .. 2016-06-30
            let text = format_datetime(ts);
            match parse_datetime(text.as_bytes()) {
                Some(back) if back == ts => Ok(()),
                other => Err(format!("{ts} -> {text} -> {other:?}")),
            }
        });
    }

    #[test]
    fn parse_rejects_malformed() {
        assert_eq!(parse_datetime(b"2013-13-01 00:00:00"), None);
        assert_eq!(parse_datetime(b"2013-01-01T00:00:00"), None);
        assert_eq!(parse_datetime(b"short"), None);
        assert_eq!(parse_datetime(b"2013-01-01 25:00:00"), None);
        assert_eq!(parse_datetime(b"2x13-01-01 00:00:00"), None);
    }

    #[test]
    fn month_lut_matches_slow_path_everywhere() {
        // Every day the LUT covers, plus out-of-range fallbacks.
        for day in 0..3287i64 {
            let ts = (14245 + day) * 86400 + 7261;
            assert_eq!(month_index(ts), month_index_slow(ts), "day {day}");
        }
        let before = epoch_from_datetime(2008, 12, 31, 23, 0, 0);
        assert_eq!(month_index(before), month_index_slow(before));
        let after = epoch_from_datetime(2020, 2, 2, 2, 2, 2);
        assert_eq!(month_index(after), month_index_slow(after));
    }

    #[test]
    fn epoch_constant_is_right() {
        assert_eq!(days_from_civil(2009, 1, 1), 14245);
    }

    #[test]
    fn aggregation_keys() {
        let ts = epoch_from_datetime(2013, 5, 14, 17, 30, 0);
        assert_eq!(hour_of_day(ts), 17);
        assert_eq!(month_index(ts), (2013 - 2009) * 12 + 4);
        assert_eq!(day_index(epoch_from_datetime(2009, 1, 2, 0, 0, 0)), 1);
        assert_eq!(month_index(epoch_from_datetime(2009, 1, 31, 23, 59, 59)), 0);
        assert_eq!(month_index(epoch_from_datetime(2016, 6, 1, 0, 0, 0)), 89);
    }
}
