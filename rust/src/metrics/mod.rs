//! Lightweight named counters for diagnostics — the paper's executors
//! return "a variety of diagnostic information (e.g., number of messages,
//! SQS calls, etc.)"; this is where those numbers land.
//!
//! A `Metrics` value is a cheap handle onto a shared registry. A handle
//! may be *scoped* ([`Metrics::scoped`]): every key it reads or writes is
//! silently prefixed (`q0.` + `scheduler.chains` → `q0.scheduler.chains`),
//! so concurrent queries in the multi-tenant service each get their own
//! namespace in one registry instead of silently merging counters. Code
//! holding a scoped handle is scope-oblivious — `get`/`snapshot`/`reset`
//! see only (and exactly) the handle's own subtree, with the prefix
//! stripped, so existing callers behave identically under any scope.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Thread-safe counter registry handle (possibly scoped to a prefix).
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    counters: Arc<Mutex<BTreeMap<String, u64>>>,
    /// Either empty (root) or `"some.prefix."` — always dot-terminated.
    prefix: String,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// A handle onto the same registry with `prefix.` prepended to every
    /// key it touches. Scopes nest: `m.scoped("q0").scoped("retry")`
    /// writes under `q0.retry.`.
    pub fn scoped(&self, prefix: &str) -> Metrics {
        Metrics {
            counters: Arc::clone(&self.counters),
            prefix: format!("{}{}.", self.prefix, prefix),
        }
    }

    fn key(&self, name: &str) -> String {
        format!("{}{}", self.prefix, name)
    }

    /// Add `delta` to the named counter.
    pub fn add(&self, name: &str, delta: u64) {
        let mut map = self.counters.lock().expect("metrics poisoned");
        *map.entry(self.key(name)).or_insert(0) += delta;
    }

    /// Increment by one.
    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .expect("metrics poisoned")
            .get(&self.key(name))
            .copied()
            .unwrap_or(0)
    }

    /// This handle's counters, sorted by name, prefix stripped. The root
    /// handle sees everything (scoped keys appear fully qualified).
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .expect("metrics poisoned")
            .iter()
            .filter_map(|(k, v)| k.strip_prefix(&self.prefix).map(|s| (s.to_string(), *v)))
            .collect()
    }

    /// Clear this handle's subtree (the whole registry for the root).
    pub fn reset(&self) {
        let mut map = self.counters.lock().expect("metrics poisoned");
        if self.prefix.is_empty() {
            map.clear();
        } else {
            map.retain(|k, _| !k.starts_with(&self.prefix));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_work() {
        let m = Metrics::new();
        m.incr("sqs.send_batch");
        m.add("sqs.messages", 10);
        m.incr("sqs.send_batch");
        assert_eq!(m.get("sqs.send_batch"), 2);
        assert_eq!(m.get("sqs.messages"), 10);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn snapshot_sorted() {
        let m = Metrics::new();
        m.incr("z");
        m.incr("a");
        let snap = m.snapshot();
        assert_eq!(snap[0].0, "a");
        assert_eq!(snap[1].0, "z");
    }

    #[test]
    fn reset_clears() {
        let m = Metrics::new();
        m.incr("x");
        m.reset();
        assert_eq!(m.get("x"), 0);
        assert!(m.snapshot().is_empty());
    }

    #[test]
    fn scoped_handles_share_the_registry_under_a_prefix() {
        let root = Metrics::new();
        let q0 = root.scoped("q0");
        let q1 = root.scoped("q1");
        q0.incr("scheduler.chains");
        q0.incr("scheduler.chains");
        q1.incr("scheduler.chains");
        root.incr("scheduler.chains");
        // Each scope sees only its own subtree, scope-obliviously.
        assert_eq!(q0.get("scheduler.chains"), 2);
        assert_eq!(q1.get("scheduler.chains"), 1);
        assert_eq!(root.get("scheduler.chains"), 1);
        // The root sees the fully-qualified union.
        assert_eq!(root.get("q0.scheduler.chains"), 2);
        assert_eq!(root.get("q1.scheduler.chains"), 1);
        let names: Vec<String> = root.snapshot().into_iter().map(|(k, _)| k).collect();
        assert_eq!(
            names,
            vec!["q0.scheduler.chains", "q1.scheduler.chains", "scheduler.chains"]
        );
    }

    #[test]
    fn scoped_snapshot_strips_prefix_and_scopes_nest() {
        let root = Metrics::new();
        let q = root.scoped("q3");
        q.add("shuffle.msgs", 7);
        q.scoped("retry").incr("attempts");
        assert_eq!(
            q.snapshot(),
            vec![("retry.attempts".to_string(), 1), ("shuffle.msgs".to_string(), 7)]
        );
        assert_eq!(root.get("q3.retry.attempts"), 1);
    }

    #[test]
    fn scoped_reset_leaves_other_scopes_alone() {
        let root = Metrics::new();
        root.incr("global");
        let q0 = root.scoped("q0");
        let q1 = root.scoped("q1");
        q0.incr("x");
        q1.incr("x");
        q0.reset();
        assert_eq!(q0.get("x"), 0);
        assert_eq!(q1.get("x"), 1);
        assert_eq!(root.get("global"), 1);
        root.reset();
        assert!(root.snapshot().is_empty());
    }
}
