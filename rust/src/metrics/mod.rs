//! Lightweight named counters for diagnostics — the paper's executors
//! return "a variety of diagnostic information (e.g., number of messages,
//! SQS calls, etc.)"; this is where those numbers land.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Thread-safe counter registry.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Add `delta` to the named counter.
    pub fn add(&self, name: &str, delta: u64) {
        let mut map = self.counters.lock().expect("metrics poisoned");
        *map.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Increment by one.
    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .expect("metrics poisoned")
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// All counters, sorted by name.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        self.counters
            .lock()
            .expect("metrics poisoned")
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }

    pub fn reset(&self) {
        self.counters.lock().expect("metrics poisoned").clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_work() {
        let m = Metrics::new();
        m.incr("sqs.send_batch");
        m.add("sqs.messages", 10);
        m.incr("sqs.send_batch");
        assert_eq!(m.get("sqs.send_batch"), 2);
        assert_eq!(m.get("sqs.messages"), 10);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn snapshot_sorted() {
        let m = Metrics::new();
        m.incr("z");
        m.incr("a");
        let snap = m.snapshot();
        assert_eq!(snap[0].0, "a");
        assert_eq!(snap[1].0, "z");
    }

    #[test]
    fn reset_clears() {
        let m = Metrics::new();
        m.incr("x");
        m.reset();
        assert_eq!(m.get("x"), 0);
        assert!(m.snapshot().is_empty());
    }
}
