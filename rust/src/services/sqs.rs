//! Simulated SQS — the shuffle substrate (§III-A of the paper).
//!
//! Behavioural fidelity targets:
//! * **Batch limits**: at most 10 messages and 256 KB total per
//!   `SendMessageBatch`/`ReceiveMessage` call, 256 KB per message.
//! * **At-least-once delivery**: with configurable probability a message
//!   is delivered twice (AWS documents duplicates as possible); the
//!   paper's §VI dedup design (sequence ids per producer) is exercised
//!   against this.
//! * **Pricing**: every 64 KB chunk of a request is billed as one SQS
//!   request ($0.40/M in 2018) — this is why Flint costs more than Spark
//!   on shuffle-heavy queries.
//! * **Modeled latency**: a request costs one RTT plus payload streaming
//!   time; executors drain queues with repeated receive calls, so queues
//!   with many small batches are slower — reproducing the paper's
//!   "performance ... dependent on the number of intermediate groups".

use crate::config::FlintConfig;
use crate::cost::{CostCategory, CostTracker};
use crate::metrics::Metrics;
use crate::services::failure::FailureInjector;
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex, RwLock};

/// A shuffle message: an opaque body plus the producer/sequence metadata
/// the dedup layer (§VI) relies on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Message {
    pub body: Vec<u8>,
    /// Producing task's unique id (map-side task attempt).
    pub producer: u64,
    /// Per-producer monotonically increasing sequence number.
    pub seq: u64,
}

impl Message {
    pub fn new(body: Vec<u8>, producer: u64, seq: u64) -> Message {
        Message { body, producer, seq }
    }

    /// Wire size used for limit checks and billing (body + attributes).
    pub fn wire_bytes(&self) -> usize {
        self.body.len() + 32
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SqsError {
    NoSuchQueue(String),
    TooManyMessages(usize, usize),
    MessageTooLarge(usize, usize),
    BatchTooLarge(usize, usize),
}

impl std::fmt::Display for SqsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SqsError::NoSuchQueue(queue) => write!(f, "no such queue: {queue}"),
            SqsError::TooManyMessages(got, limit) => {
                write!(f, "batch has {got} messages; the limit is {limit}")
            }
            SqsError::MessageTooLarge(got, limit) => {
                write!(f, "message of {got} bytes exceeds the per-message limit {limit}")
            }
            SqsError::BatchTooLarge(got, limit) => {
                write!(f, "batch of {got} bytes exceeds the per-batch limit {limit}")
            }
        }
    }
}

impl std::error::Error for SqsError {}

#[derive(Default)]
struct Queue {
    messages: VecDeque<Message>,
    /// Delivered but not yet deleted (SQS visibility-timeout model):
    /// receipt handle → message. On `nack` (or executor failure) these
    /// return to the queue, exactly as an expired visibility timeout
    /// would redeliver them.
    in_flight: std::collections::BTreeMap<u64, Message>,
    next_handle: u64,
    /// Total enqueued ever (diagnostics).
    enqueued: u64,
}

/// The queue service.
pub struct SqsService {
    queues: RwLock<BTreeMap<String, Arc<Mutex<Queue>>>>,
    rtt_s: f64,
    mbps: f64,
    batch_max_msgs: usize,
    batch_max_bytes: usize,
    price_per_million: f64,
    cost: Arc<CostTracker>,
    metrics: Metrics,
    failure: Arc<FailureInjector>,
}

/// Billing granularity: every 64 KB of payload counts as one request.
const CHUNK: usize = 64 * 1024;

impl SqsService {
    pub fn new(
        config: &FlintConfig,
        cost: Arc<CostTracker>,
        metrics: Metrics,
        failure: Arc<FailureInjector>,
    ) -> Self {
        SqsService {
            queues: RwLock::new(BTreeMap::new()),
            rtt_s: config.sim.sqs_rtt_s,
            mbps: config.sim.sqs_mbps,
            batch_max_msgs: config.sim.sqs_batch_max_msgs,
            batch_max_bytes: config.sim.sqs_batch_max_bytes,
            price_per_million: config.pricing.sqs_per_million_requests,
            cost,
            metrics,
            failure,
        }
    }

    /// Create a queue (idempotent). The Flint scheduler creates one queue
    /// per reduce partition before launching a shuffle stage.
    pub fn create_queue(&self, name: &str) {
        self.queues
            .write()
            .expect("sqs lock")
            .entry(name.to_string())
            .or_default();
        self.metrics.incr("sqs.create_queue");
    }

    pub fn delete_queue(&self, name: &str) -> Result<(), SqsError> {
        self.queues
            .write()
            .expect("sqs lock")
            .remove(name)
            .map(|_| self.metrics.incr("sqs.delete_queue"))
            .ok_or_else(|| SqsError::NoSuchQueue(name.to_string()))
    }

    pub fn queue_exists(&self, name: &str) -> bool {
        self.queues.read().expect("sqs lock").contains_key(name)
    }

    /// All queue names (diagnostics / leak checks).
    pub fn queue_names(&self) -> Vec<String> {
        self.queues.read().expect("sqs lock").keys().cloned().collect()
    }

    /// Send a batch. Enforces AWS batch limits; injects duplicates per the
    /// at-least-once model. Returns the modeled request duration.
    pub fn send_batch(&self, queue: &str, batch: Vec<Message>) -> Result<f64, SqsError> {
        if batch.len() > self.batch_max_msgs {
            return Err(SqsError::TooManyMessages(batch.len(), self.batch_max_msgs));
        }
        let total: usize = batch.iter().map(Message::wire_bytes).sum();
        for m in &batch {
            if m.wire_bytes() > self.batch_max_bytes {
                return Err(SqsError::MessageTooLarge(m.wire_bytes(), self.batch_max_bytes));
            }
        }
        if total > self.batch_max_bytes {
            return Err(SqsError::BatchTooLarge(total, self.batch_max_bytes));
        }
        let handle = self.handle(queue)?;
        {
            let mut q = handle.lock().expect("queue lock");
            for m in batch {
                let dup = self.failure.sqs_should_duplicate();
                if dup {
                    q.messages.push_back(m.clone());
                    q.enqueued += 1;
                    self.metrics.incr("sqs.duplicates_injected");
                }
                q.messages.push_back(m);
                q.enqueued += 1;
            }
        }
        self.charge(total);
        self.metrics.incr("sqs.send_batch");
        Ok(self.request_time(total))
    }

    /// Receive up to `max` messages (capped at the batch limit), each
    /// paired with a receipt handle. Received messages become *in
    /// flight*: [`Self::delete_batch`] removes them permanently,
    /// [`Self::nack`] (executor failure / visibility expiry) returns them
    /// to the queue. An empty receive is still a billed request — Flint
    /// reducers poll until the queue is dry, and the paper's cost model
    /// pays for those polls.
    pub fn receive_batch(
        &self,
        queue: &str,
        max: usize,
    ) -> Result<(Vec<(Message, u64)>, f64), SqsError> {
        let handle = self.handle(queue)?;
        let mut out = Vec::new();
        let mut bytes = 0usize;
        {
            let mut q = handle.lock().expect("queue lock");
            while out.len() < max.min(self.batch_max_msgs) {
                match q.messages.front() {
                    Some(m) if out.is_empty() || bytes + m.wire_bytes() <= self.batch_max_bytes =>
                    {
                        let m = q.messages.pop_front().expect("front checked");
                        bytes += m.wire_bytes();
                        let receipt = q.next_handle;
                        q.next_handle += 1;
                        q.in_flight.insert(receipt, m.clone());
                        out.push((m, receipt));
                    }
                    _ => break,
                }
            }
        }
        self.charge(bytes);
        self.metrics.incr("sqs.receive_batch");
        self.metrics.add("sqs.messages_received", out.len() as u64);
        Ok((out, self.request_time(bytes)))
    }

    /// Delete received messages (a billed request per batch call, like
    /// AWS `DeleteMessageBatch`). Unknown handles are ignored — deleting
    /// twice is safe, as on AWS.
    pub fn delete_batch(&self, queue: &str, receipts: &[u64]) -> Result<f64, SqsError> {
        let handle = self.handle(queue)?;
        {
            let mut q = handle.lock().expect("queue lock");
            for r in receipts {
                q.in_flight.remove(r);
            }
        }
        self.charge(0);
        self.metrics.incr("sqs.delete_batch");
        Ok(self.request_time(0))
    }

    /// Return in-flight messages to the queue (visibility timeout expiry
    /// — what happens when an executor dies mid-drain). Free: AWS bills
    /// nothing for a timeout.
    pub fn nack(&self, queue: &str, receipts: &[u64]) -> Result<usize, SqsError> {
        let handle = self.handle(queue)?;
        let mut q = handle.lock().expect("queue lock");
        let mut returned = 0;
        for r in receipts {
            if let Some(m) = q.in_flight.remove(r) {
                q.messages.push_back(m);
                returned += 1;
            }
        }
        self.metrics.add("sqs.nacked", returned as u64);
        Ok(returned)
    }

    /// Messages currently delivered-but-undeleted (diagnostics).
    pub fn in_flight(&self, queue: &str) -> Result<usize, SqsError> {
        Ok(self.handle(queue)?.lock().expect("queue lock").in_flight.len())
    }

    /// Approximate number of messages waiting.
    pub fn depth(&self, queue: &str) -> Result<usize, SqsError> {
        Ok(self.handle(queue)?.lock().expect("queue lock").messages.len())
    }

    /// Total ever enqueued (includes injected duplicates).
    pub fn enqueued_total(&self, queue: &str) -> Result<u64, SqsError> {
        Ok(self.handle(queue)?.lock().expect("queue lock").enqueued)
    }

    fn handle(&self, queue: &str) -> Result<Arc<Mutex<Queue>>, SqsError> {
        self.queues
            .read()
            .expect("sqs lock")
            .get(queue)
            .cloned()
            .ok_or_else(|| SqsError::NoSuchQueue(queue.to_string()))
    }

    fn charge(&self, payload_bytes: usize) {
        // ceil(payload / 64KB) chunks, min 1 request.
        let requests = payload_bytes.div_ceil(CHUNK).max(1);
        self.cost.charge(
            CostCategory::SqsRequests,
            requests as f64 * self.price_per_million / 1e6,
        );
        self.metrics.add("sqs.billed_requests", requests as u64);
    }

    fn request_time(&self, payload_bytes: usize) -> f64 {
        self.rtt_s + payload_bytes as f64 / (self.mbps * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn service(dup_prob: f64) -> (SqsService, Metrics, Arc<CostTracker>) {
        let cfg = FlintConfig::default();
        let cost = Arc::new(CostTracker::new());
        let metrics = Metrics::new();
        let failure = Arc::new(FailureInjector::new(42, 0.0, dup_prob));
        let sqs = SqsService::new(&cfg, Arc::clone(&cost), metrics.clone(), failure);
        (sqs, metrics, cost)
    }

    fn msg(body: &[u8], seq: u64) -> Message {
        Message::new(body.to_vec(), 1, seq)
    }

    #[test]
    fn send_receive_fifo() {
        let (sqs, _, _) = service(0.0);
        sqs.create_queue("q");
        sqs.send_batch("q", vec![msg(b"a", 0), msg(b"b", 1)]).unwrap();
        let (got, _) = sqs.receive_batch("q", 10).unwrap();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0.body, b"a");
        assert_eq!(got[1].0.body, b"b");
        let (empty, _) = sqs.receive_batch("q", 10).unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn ack_nack_visibility_semantics() {
        let (sqs, _, _) = service(0.0);
        sqs.create_queue("q");
        sqs.send_batch("q", vec![msg(b"a", 0), msg(b"b", 1), msg(b"c", 2)]).unwrap();
        let (got, _) = sqs.receive_batch("q", 10).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(sqs.depth("q").unwrap(), 0, "all in flight");
        assert_eq!(sqs.in_flight("q").unwrap(), 3);
        // Ack the first, nack the rest (executor died mid-drain).
        sqs.delete_batch("q", &[got[0].1]).unwrap();
        let returned = sqs.nack("q", &[got[1].1, got[2].1]).unwrap();
        assert_eq!(returned, 2);
        assert_eq!(sqs.in_flight("q").unwrap(), 0);
        // Retry sees exactly the unacked messages.
        let (retry, _) = sqs.receive_batch("q", 10).unwrap();
        let bodies: Vec<&[u8]> = retry.iter().map(|(m, _)| m.body.as_slice()).collect();
        assert_eq!(bodies, vec![b"b" as &[u8], b"c"]);
        // Double delete is harmless.
        sqs.delete_batch("q", &[got[0].1]).unwrap();
    }

    #[test]
    fn batch_limits_enforced() {
        let (sqs, _, _) = service(0.0);
        sqs.create_queue("q");
        // 11 messages
        let batch: Vec<Message> = (0..11).map(|i| msg(b"x", i)).collect();
        assert!(matches!(
            sqs.send_batch("q", batch),
            Err(SqsError::TooManyMessages(11, 10))
        ));
        // oversize single message
        let big = vec![msg(&vec![0u8; 300 * 1024], 0)];
        assert!(matches!(sqs.send_batch("q", big), Err(SqsError::MessageTooLarge(_, _))));
        // oversize batch total
        let batch: Vec<Message> = (0..4).map(|i| msg(&vec![0u8; 70 * 1024], i)).collect();
        assert!(matches!(sqs.send_batch("q", batch), Err(SqsError::BatchTooLarge(_, _))));
    }

    #[test]
    fn receive_respects_batch_byte_limit() {
        let (sqs, _, _) = service(0.0);
        sqs.create_queue("q");
        for i in 0..3 {
            sqs.send_batch("q", vec![msg(&vec![0u8; 120 * 1024], i)]).unwrap();
        }
        let (got, _) = sqs.receive_batch("q", 10).unwrap();
        // 2 × ~120KB fits under 256KB; the third does not.
        assert_eq!(got.len(), 2);
        let (rest, _) = sqs.receive_batch("q", 10).unwrap();
        assert_eq!(rest.len(), 1);
    }

    #[test]
    fn redelivery_after_nack_preserves_dedup_metadata() {
        let (sqs, _, _) = service(0.0);
        sqs.create_queue("q");
        sqs.send_batch("q", vec![Message::new(b"x".to_vec(), 77, 5)]).unwrap();
        let (got, _) = sqs.receive_batch("q", 10).unwrap();
        sqs.nack("q", &[got[0].1]).unwrap();
        let (again, _) = sqs.receive_batch("q", 10).unwrap();
        assert_eq!(again[0].0.producer, 77);
        assert_eq!(again[0].0.seq, 5);
    }

    #[test]
    fn duplicates_injected_at_configured_rate() {
        let (sqs, metrics, _) = service(0.2);
        sqs.create_queue("q");
        for b in 0..100u64 {
            let batch: Vec<Message> = (0..10).map(|i| msg(b"d", b * 10 + i)).collect();
            sqs.send_batch("q", batch).unwrap();
        }
        let dups = metrics.get("sqs.duplicates_injected");
        // 1000 messages at 20%: expect ~200.
        assert!((120..280).contains(&(dups as usize)), "dups={dups}");
        assert_eq!(sqs.depth("q").unwrap() as u64, 1000 + dups);
    }

    #[test]
    fn billing_chunks_counted() {
        let (sqs, metrics, cost) = service(0.0);
        sqs.create_queue("q");
        sqs.send_batch("q", vec![msg(&vec![0u8; 100 * 1024], 0)]).unwrap();
        // 100KB+32B => 2 chunks.
        assert_eq!(metrics.get("sqs.billed_requests"), 2);
        let expected = 2.0 * 0.40 / 1e6;
        assert!((cost.total() - expected).abs() < 1e-12);
        // empty receive still bills one request
        let before = metrics.get("sqs.billed_requests");
        sqs.receive_batch("q", 10).unwrap();
        sqs.receive_batch("q", 10).unwrap();
        assert!(metrics.get("sqs.billed_requests") > before);
    }

    #[test]
    fn missing_queue_errors() {
        let (sqs, _, _) = service(0.0);
        assert!(matches!(
            sqs.send_batch("ghost", vec![]),
            Err(SqsError::NoSuchQueue(_))
        ));
        assert!(matches!(sqs.receive_batch("ghost", 1), Err(SqsError::NoSuchQueue(_))));
        assert!(matches!(sqs.delete_queue("ghost"), Err(SqsError::NoSuchQueue(_))));
    }

    #[test]
    fn request_time_includes_rtt_and_bandwidth() {
        let (sqs, _, _) = service(0.0);
        sqs.create_queue("q");
        let t_small = sqs.send_batch("q", vec![msg(b"x", 0)]).unwrap();
        let t_big = sqs
            .send_batch("q", vec![msg(&vec![0u8; 200 * 1024], 1)])
            .unwrap();
        assert!(t_big > t_small);
        assert!(t_small >= 0.0015);
    }
}
