//! Simulated AWS substrates (DESIGN.md §2).
//!
//! The paper runs on S3 + SQS + Lambda + an EC2 Databricks cluster; none
//! of those exist in this environment, so each is rebuilt as an
//! in-process service with the *behavioural* properties that shaped
//! Flint's design: S3's per-stream throughput, SQS's batch limits and
//! at-least-once delivery, Lambda's cold starts and resource caps, and
//! the cluster's per-hour idle-inclusive billing. Each service charges
//! modeled durations (for the virtual clock) and USD (for Table I).

pub mod ec2;
pub mod failure;
pub mod lambda;
pub mod s3;
pub mod sqs;

pub use ec2::ClusterBilling;
pub use failure::FailureInjector;
pub use lambda::{InvocationTicket, LambdaError, LambdaService};
pub use s3::{ObjectStore, ReadProfile, S3Error};
pub use sqs::{Message, SqsError, SqsService};

use crate::config::FlintConfig;
use crate::cost::CostTracker;
use crate::metrics::Metrics;
use crate::util::IdGen;
use std::sync::Arc;

/// The shared simulation environment: one per experiment. Cheap to clone
/// (all state behind one `Arc`).
#[derive(Clone)]
pub struct SimEnv {
    inner: Arc<SimEnvInner>,
}

struct SimEnvInner {
    config: FlintConfig,
    cost: Arc<CostTracker>,
    metrics: Metrics,
    failure: Arc<FailureInjector>,
    s3: Arc<ObjectStore>,
    sqs: Arc<SqsService>,
    lambda: Arc<LambdaService>,
    ids: Arc<IdGen>,
}

impl SimEnv {
    pub fn new(config: FlintConfig) -> SimEnv {
        let cost = Arc::new(CostTracker::new());
        let metrics = Metrics::new();
        let failure = Arc::new(
            FailureInjector::new(
                config.seed,
                config.sim.lambda_failure_prob,
                config.sim.sqs_duplicate_prob,
            )
            .with_stragglers(
                config.sim.straggler_prob,
                config.sim.straggler_factor,
                config.sim.straggler_alpha,
            )
            .with_straggler_containers(config.sim.straggler_containers),
        );
        let s3 = Arc::new(ObjectStore::new(&config, Arc::clone(&cost), metrics.clone()));
        let sqs = Arc::new(SqsService::new(
            &config,
            Arc::clone(&cost),
            metrics.clone(),
            Arc::clone(&failure),
        ));
        let lambda = Arc::new(LambdaService::new(
            &config,
            Arc::clone(&cost),
            metrics.clone(),
            Arc::clone(&failure),
        ));
        SimEnv {
            inner: Arc::new(SimEnvInner {
                config,
                cost,
                metrics,
                failure,
                s3,
                sqs,
                lambda,
                ids: Arc::new(IdGen::new()),
            }),
        }
    }

    /// A view of the same environment whose driver-level metrics land
    /// under `prefix.`: S3/SQS/Lambda state, warm pools, the cost
    /// tracker, the failure injector, and id generation are all shared
    /// with `self` — only the metrics handle differs, so concurrent
    /// queries each write their own `q{n}.*` namespace. Service-internal
    /// counters (`sqs.*`, `lambda.*`, `s3.*`) stay global: they meter
    /// shared infrastructure, not one query.
    pub fn scoped(&self, prefix: &str) -> SimEnv {
        SimEnv {
            inner: Arc::new(SimEnvInner {
                config: self.inner.config.clone(),
                cost: Arc::clone(&self.inner.cost),
                metrics: self.inner.metrics.scoped(prefix),
                failure: Arc::clone(&self.inner.failure),
                s3: Arc::clone(&self.inner.s3),
                sqs: Arc::clone(&self.inner.sqs),
                lambda: Arc::clone(&self.inner.lambda),
                ids: Arc::clone(&self.inner.ids),
            }),
        }
    }

    pub fn config(&self) -> &FlintConfig {
        &self.inner.config
    }

    pub fn s3(&self) -> &ObjectStore {
        &self.inner.s3
    }

    pub fn sqs(&self) -> &SqsService {
        &self.inner.sqs
    }

    pub fn lambda(&self) -> &LambdaService {
        &self.inner.lambda
    }

    pub fn cost(&self) -> &CostTracker {
        &self.inner.cost
    }

    pub fn metrics(&self) -> &Metrics {
        &self.inner.metrics
    }

    pub fn failure(&self) -> &FailureInjector {
        &self.inner.failure
    }

    pub fn ids(&self) -> &IdGen {
        &self.inner.ids
    }

    /// Read profile for Flint executors (boto-like throughput).
    pub fn flint_read_profile(&self) -> ReadProfile {
        ReadProfile {
            first_byte_s: self.inner.config.sim.s3_first_byte_s,
            mbps: self.inner.config.sim.s3_flint_mbps,
        }
    }

    /// Read profile for the Spark cluster (Hadoop-S3A-like throughput).
    pub fn spark_read_profile(&self) -> ReadProfile {
        ReadProfile {
            first_byte_s: self.inner.config.sim.s3_first_byte_s,
            mbps: self.inner.config.sim.s3_spark_mbps,
        }
    }

    /// Reset per-trial accumulators (cost, metrics, warm pools are kept —
    /// the paper benchmarks "after warm-up").
    pub fn reset_trial(&self) {
        self.inner.cost.reset();
        self.inner.metrics.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_shares_state_across_clones() {
        let env = SimEnv::new(FlintConfig::for_tests());
        let env2 = env.clone();
        env.metrics().incr("x");
        assert_eq!(env2.metrics().get("x"), 1);
    }

    #[test]
    fn scoped_env_shares_services_but_namespaces_metrics() {
        let env = SimEnv::new(FlintConfig::for_tests());
        let q0 = env.scoped("q0");
        let q1 = env.scoped("q1");
        q0.metrics().incr("scheduler.chains");
        q1.metrics().add("scheduler.chains", 2);
        env.metrics().incr("scheduler.chains");
        assert_eq!(env.metrics().get("q0.scheduler.chains"), 1);
        assert_eq!(env.metrics().get("q1.scheduler.chains"), 2);
        assert_eq!(env.metrics().get("scheduler.chains"), 1);
        assert_eq!(q0.metrics().get("scheduler.chains"), 1, "scope-oblivious reads");
        // Cost, warm pools, and object state are the same underlying
        // services: money spent through a scoped view lands in the shared
        // tracker.
        assert!(std::ptr::eq(env.cost(), q0.cost()));
        assert!(std::ptr::eq(env.lambda(), q0.lambda()));
        assert!(std::ptr::eq(env.s3(), q1.s3()));
    }

    #[test]
    fn profiles_reflect_config() {
        let env = SimEnv::new(FlintConfig::default());
        assert!(env.flint_read_profile().mbps > env.spark_read_profile().mbps);
    }
}
