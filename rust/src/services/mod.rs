//! Simulated AWS substrates (DESIGN.md §2).
//!
//! The paper runs on S3 + SQS + Lambda + an EC2 Databricks cluster; none
//! of those exist in this environment, so each is rebuilt as an
//! in-process service with the *behavioural* properties that shaped
//! Flint's design: S3's per-stream throughput, SQS's batch limits and
//! at-least-once delivery, Lambda's cold starts and resource caps, and
//! the cluster's per-hour idle-inclusive billing. Each service charges
//! modeled durations (for the virtual clock) and USD (for Table I).

pub mod ec2;
pub mod failure;
pub mod lambda;
pub mod s3;
pub mod sqs;

pub use ec2::ClusterBilling;
pub use failure::FailureInjector;
pub use lambda::{InvocationTicket, LambdaError, LambdaService};
pub use s3::{ObjectStore, ReadProfile, S3Error};
pub use sqs::{Message, SqsError, SqsService};

use crate::config::FlintConfig;
use crate::cost::CostTracker;
use crate::metrics::Metrics;
use crate::util::IdGen;
use std::sync::Arc;

/// The shared simulation environment: one per experiment. Cheap to clone
/// (all state behind one `Arc`).
#[derive(Clone)]
pub struct SimEnv {
    inner: Arc<SimEnvInner>,
}

struct SimEnvInner {
    config: FlintConfig,
    cost: Arc<CostTracker>,
    metrics: Arc<Metrics>,
    failure: Arc<FailureInjector>,
    s3: ObjectStore,
    sqs: SqsService,
    lambda: LambdaService,
    ids: IdGen,
}

impl SimEnv {
    pub fn new(config: FlintConfig) -> SimEnv {
        let cost = Arc::new(CostTracker::new());
        let metrics = Arc::new(Metrics::new());
        let failure = Arc::new(
            FailureInjector::new(
                config.seed,
                config.sim.lambda_failure_prob,
                config.sim.sqs_duplicate_prob,
            )
            .with_stragglers(
                config.sim.straggler_prob,
                config.sim.straggler_factor,
                config.sim.straggler_alpha,
            ),
        );
        let s3 = ObjectStore::new(&config, Arc::clone(&cost), Arc::clone(&metrics));
        let sqs = SqsService::new(
            &config,
            Arc::clone(&cost),
            Arc::clone(&metrics),
            Arc::clone(&failure),
        );
        let lambda = LambdaService::new(
            &config,
            Arc::clone(&cost),
            Arc::clone(&metrics),
            Arc::clone(&failure),
        );
        SimEnv {
            inner: Arc::new(SimEnvInner {
                config,
                cost,
                metrics,
                failure,
                s3,
                sqs,
                lambda,
                ids: IdGen::new(),
            }),
        }
    }

    pub fn config(&self) -> &FlintConfig {
        &self.inner.config
    }

    pub fn s3(&self) -> &ObjectStore {
        &self.inner.s3
    }

    pub fn sqs(&self) -> &SqsService {
        &self.inner.sqs
    }

    pub fn lambda(&self) -> &LambdaService {
        &self.inner.lambda
    }

    pub fn cost(&self) -> &CostTracker {
        &self.inner.cost
    }

    pub fn metrics(&self) -> &Metrics {
        &self.inner.metrics
    }

    pub fn failure(&self) -> &FailureInjector {
        &self.inner.failure
    }

    pub fn ids(&self) -> &IdGen {
        &self.inner.ids
    }

    /// Read profile for Flint executors (boto-like throughput).
    pub fn flint_read_profile(&self) -> ReadProfile {
        ReadProfile {
            first_byte_s: self.inner.config.sim.s3_first_byte_s,
            mbps: self.inner.config.sim.s3_flint_mbps,
        }
    }

    /// Read profile for the Spark cluster (Hadoop-S3A-like throughput).
    pub fn spark_read_profile(&self) -> ReadProfile {
        ReadProfile {
            first_byte_s: self.inner.config.sim.s3_first_byte_s,
            mbps: self.inner.config.sim.s3_spark_mbps,
        }
    }

    /// Reset per-trial accumulators (cost, metrics, warm pools are kept —
    /// the paper benchmarks "after warm-up").
    pub fn reset_trial(&self) {
        self.inner.cost.reset();
        self.inner.metrics.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_shares_state_across_clones() {
        let env = SimEnv::new(FlintConfig::for_tests());
        let env2 = env.clone();
        env.metrics().incr("x");
        assert_eq!(env2.metrics().get("x"), 1);
    }

    #[test]
    fn profiles_reflect_config() {
        let env = SimEnv::new(FlintConfig::default());
        assert!(env.flint_read_profile().mbps > env.spark_read_profile().mbps);
    }
}
