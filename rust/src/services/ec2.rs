//! Cluster (EC2/Databricks) billing — the baseline's cost model.
//!
//! The paper: "Estimated costs for Spark and PySpark are computed as the
//! query latency multiplied by the per-second cost of the cluster." The
//! per-hour rate covers all 11 m4.2xlarge instances plus the platform fee
//! (calibrated in DESIGN.md §5). Idle time *between* queries is exactly
//! what the paper's pay-as-you-go argument is about; `idle_cost` exposes
//! it for the cost-model discussion in EXPERIMENTS.md.

use crate::config::FlintConfig;
use crate::cost::{CostCategory, CostTracker};
use std::sync::Arc;

/// Billing handle for the always-on cluster.
pub struct ClusterBilling {
    per_hour_usd: f64,
    startup_s: f64,
    cost: Arc<CostTracker>,
}

impl ClusterBilling {
    pub fn new(config: &FlintConfig, cost: Arc<CostTracker>) -> Self {
        ClusterBilling {
            per_hour_usd: config.pricing.cluster_per_hour,
            startup_s: config.cluster.startup_s,
            cost,
        }
    }

    /// Charge for `duration_s` of cluster time (query execution — the
    /// paper excludes startup, and so do we, "putting Spark performance in
    /// the best possible light").
    pub fn charge_query(&self, duration_s: f64) -> f64 {
        let usd = duration_s * self.per_second();
        self.cost.charge(CostCategory::ClusterTime, usd);
        usd
    }

    /// USD per second of cluster uptime.
    pub fn per_second(&self) -> f64 {
        self.per_hour_usd / 3600.0
    }

    /// What `idle_s` seconds of idle cluster costs — zero for Flint by
    /// construction, nonzero here; used in the cost-model report.
    pub fn idle_cost(&self, idle_s: f64) -> f64 {
        idle_s * self.per_second()
    }

    /// The cluster startup time the paper mentions (~5 min) but excludes.
    pub fn startup_s(&self) -> f64 {
        self.startup_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostTracker;

    #[test]
    fn per_second_rate_matches_table1_calibration() {
        let cfg = FlintConfig::default();
        let billing = ClusterBilling::new(&cfg, Arc::new(CostTracker::new()));
        // Table I: Spark 188s ↔ $0.37.
        let usd = 188.0 * billing.per_second();
        assert!((usd - 0.37).abs() < 0.01, "got {usd}");
    }

    #[test]
    fn charge_accumulates() {
        let cfg = FlintConfig::default();
        let cost = Arc::new(CostTracker::new());
        let billing = ClusterBilling::new(&cfg, Arc::clone(&cost));
        let usd = billing.charge_query(100.0);
        assert!(usd > 0.0);
        assert!((cost.get(CostCategory::ClusterTime) - usd).abs() < 1e-12);
    }

    #[test]
    fn idle_costs_nonzero() {
        let cfg = FlintConfig::default();
        let billing = ClusterBilling::new(&cfg, Arc::new(CostTracker::new()));
        // One idle hour = full hourly rate; the crux of the paper's
        // pay-as-you-go argument.
        assert!((billing.idle_cost(3600.0) - cfg.pricing.cluster_per_hour).abs() < 1e-9);
    }
}
