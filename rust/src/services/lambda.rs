//! Simulated AWS Lambda — the execution substrate (§III-B of the paper).
//!
//! Enforced limits (2018 values, all config-overridable):
//! * 3008 MB memory per invocation,
//! * 300 s execution duration (executors *chain* before hitting it),
//! * 6 MB request payload (the scheduler spills larger task descriptors
//!   to S3),
//! * account-level concurrency (80 in the paper's evaluation).
//!
//! Warm/cold behaviour: containers enter a per-function warm pool after an
//! invocation completes; an invocation that finds the pool empty pays the
//! cold-start latency. The paper's "Python Lambdas ... start up faster"
//! point is a config knob (`lambda_cold_start_s`).
//!
//! Billing: GB-seconds rounded up to 100 ms, plus a per-request charge.

use crate::config::FlintConfig;
use crate::cost::{CostCategory, CostTracker};
use crate::metrics::Metrics;
use crate::services::failure::FailureInjector;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LambdaError {
    PayloadTooLarge(u64, u64),
    DurationExceeded(u64, u64),
    InjectedFailure(String),
}

impl std::fmt::Display for LambdaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LambdaError::PayloadTooLarge(got, limit) => {
                write!(f, "request payload of {got} bytes exceeds the {limit}-byte limit")
            }
            LambdaError::DurationExceeded(limit, ran) => {
                write!(f, "invocation exceeded the {limit} s duration limit (ran {ran} s)")
            }
            LambdaError::InjectedFailure(function) => {
                write!(f, "injected invocation failure (function={function})")
            }
        }
    }
}

impl std::error::Error for LambdaError {}

/// Returned by [`LambdaService::begin_invoke`]; carries the start latency
/// the executor charges before any work.
#[derive(Debug, Clone, PartialEq)]
pub struct InvocationTicket {
    pub cold: bool,
    pub start_latency_s: f64,
    /// Set when the failure injector decided this invocation crashes; the
    /// executor aborts mid-flight and the scheduler retries.
    pub will_fail: bool,
}

pub struct LambdaService {
    /// function name → release times of idle warm containers, oldest
    /// first (a draw takes the most recently released).
    warm: Mutex<BTreeMap<String, Vec<f64>>>,
    /// Virtual wall clock the keep-alive window is judged against;
    /// advanced by the engine between runs/queries (`advance_to`).
    clock: Mutex<f64>,
    /// How long a released container stays warm (`flint.lambda.
    /// keepalive_s`); 0 = forever, the pre-keepalive pool model.
    keepalive_s: f64,
    cold_start_s: f64,
    warm_start_s: f64,
    memory_mb: u64,
    time_limit_s: f64,
    payload_limit: u64,
    max_concurrency: usize,
    price_gb_s: f64,
    price_per_request: f64,
    cost: Arc<CostTracker>,
    metrics: Metrics,
    failure: Arc<FailureInjector>,
}

impl LambdaService {
    pub fn new(
        config: &FlintConfig,
        cost: Arc<CostTracker>,
        metrics: Metrics,
        failure: Arc<FailureInjector>,
    ) -> Self {
        LambdaService {
            warm: Mutex::new(BTreeMap::new()),
            clock: Mutex::new(0.0),
            keepalive_s: config.flint.lambda_keepalive_s,
            cold_start_s: config.sim.lambda_cold_start_s,
            warm_start_s: config.sim.lambda_warm_start_s,
            memory_mb: config.sim.lambda_memory_mb,
            time_limit_s: config.sim.lambda_time_limit_s,
            payload_limit: config.sim.lambda_payload_limit_bytes,
            max_concurrency: config.sim.max_concurrency,
            price_gb_s: config.pricing.lambda_gb_s,
            price_per_request: config.pricing.lambda_per_request,
            cost,
            metrics,
            failure,
        }
    }

    /// The execution-duration cap executors must respect (chain before it).
    pub fn time_limit_s(&self) -> f64 {
        self.time_limit_s
    }

    pub fn memory_bytes(&self) -> u64 {
        self.memory_mb * 1024 * 1024
    }

    /// Advance the keep-alive clock to virtual time `t` (monotonic; a
    /// stale `t` is ignored). The engine calls this between runs and
    /// between service-query arrivals — containers released more than
    /// `keepalive_s` before the new time have been reclaimed by the
    /// provider and their next draw is cold again.
    pub fn advance_to(&self, t: f64) {
        let mut clock = self.clock.lock().expect("lambda clock lock");
        if t > *clock {
            *clock = t;
        }
    }

    /// Current keep-alive clock reading.
    pub fn now(&self) -> f64 {
        *self.clock.lock().expect("lambda clock lock")
    }

    /// Drop containers whose keep-alive window has lapsed. Caller holds
    /// the pool lock; `now` is the current clock reading.
    fn prune_expired(&self, pool: &mut Vec<f64>, now: f64) {
        if self.keepalive_s <= 0.0 {
            return; // 0 = never expire (pre-keepalive model)
        }
        let before = pool.len();
        pool.retain(|&released| now - released <= self.keepalive_s);
        let expired = before - pool.len();
        if expired > 0 {
            self.metrics.add("lambda.keepalive_expired", expired as u64);
        }
    }

    /// Start an invocation: validates the payload size, draws a container
    /// from the warm pool (or pays a cold start), rolls failure injection.
    pub fn begin_invoke(
        &self,
        function: &str,
        payload_bytes: u64,
    ) -> Result<InvocationTicket, LambdaError> {
        if payload_bytes > self.payload_limit {
            self.metrics.incr("lambda.payload_rejected");
            return Err(LambdaError::PayloadTooLarge(payload_bytes, self.payload_limit));
        }
        let cold = {
            let now = self.now();
            let mut warm = self.warm.lock().expect("lambda lock");
            let pool = warm.entry(function.to_string()).or_default();
            self.prune_expired(pool, now);
            // Most recently released container first (deepest remaining
            // keep-alive window stays in the pool).
            pool.pop().is_none()
        };
        self.metrics.incr("lambda.invocations");
        if cold {
            self.metrics.incr("lambda.cold_starts");
        }
        let will_fail = self.failure.lambda_should_fail();
        if will_fail {
            self.metrics.incr("lambda.injected_failures");
        }
        Ok(InvocationTicket {
            cold,
            start_latency_s: if cold { self.cold_start_s } else { self.warm_start_s },
            will_fail,
        })
    }

    /// Finish an invocation of `duration_s` (virtual): bills it and
    /// returns the container to the warm pool. Errors if the duration
    /// exceeded the hard cap — callers must chain before that happens.
    pub fn finish_invoke(&self, function: &str, duration_s: f64) -> Result<(), LambdaError> {
        if duration_s > self.time_limit_s {
            self.metrics.incr("lambda.duration_exceeded");
            // AWS bills the full capped duration on timeout-kill.
            self.bill(self.time_limit_s);
            return Err(LambdaError::DurationExceeded(
                self.time_limit_s as u64,
                duration_s as u64,
            ));
        }
        self.bill(duration_s);
        let now = self.now();
        let mut warm = self.warm.lock().expect("lambda lock");
        let pool = warm.entry(function.to_string()).or_default();
        // The provider caps how many idle containers it keeps around; the
        // account concurrency limit is a reasonable stand-in.
        if pool.len() < self.max_concurrency {
            pool.push(now);
        }
        Ok(())
    }

    fn bill(&self, duration_s: f64) {
        // Round up to 100 ms, charge GB-seconds + request fee.
        let billed = (duration_s * 10.0).ceil() / 10.0;
        let gb = self.memory_mb as f64 / 1024.0;
        self.cost.charge(CostCategory::LambdaCompute, billed * gb * self.price_gb_s);
        self.cost.charge(CostCategory::LambdaRequests, self.price_per_request);
        self.metrics.add("lambda.billed_100ms", (billed * 10.0) as u64);
    }

    /// Bill GB-seconds for occupied-but-idle time: pipelined reducers
    /// long-poll their queues inside a live invocation, so the overlap
    /// that buys latency is not free — AWS bills wall-clock duration,
    /// idle or not (the ROADMAP's pipelined-aware cost item). Charged
    /// once per run from the aggregate virtual idle, no request fee.
    pub fn bill_idle(&self, idle_s: f64) {
        if idle_s <= 0.0 {
            return;
        }
        let billed = (idle_s * 10.0).ceil() / 10.0;
        let gb = self.memory_mb as f64 / 1024.0;
        self.cost.charge(CostCategory::LambdaCompute, billed * gb * self.price_gb_s);
        self.metrics.add("lambda.idle_billed_100ms", (billed * 10.0) as u64);
    }

    /// Current warm-pool size for a function (containers still inside
    /// their keep-alive window; read-only, no expiry metric).
    pub fn warm_count(&self, function: &str) -> usize {
        let now = self.now();
        self.warm
            .lock()
            .expect("lambda lock")
            .get(function)
            .map(|pool| {
                if self.keepalive_s <= 0.0 {
                    pool.len()
                } else {
                    pool.iter().filter(|&&released| now - released <= self.keepalive_s).count()
                }
            })
            .unwrap_or(0)
    }

    /// Pre-warm `n` containers (benchmarks measure "after warm-up", like
    /// the paper's five post-warm-up trials). Pre-warmed containers are
    /// released "now", so their keep-alive window starts fresh.
    pub fn prewarm(&self, function: &str, n: usize) {
        let now = self.now();
        let mut warm = self.warm.lock().expect("lambda lock");
        let pool = warm.entry(function.to_string()).or_default();
        for _ in 0..n {
            if pool.len() >= self.max_concurrency {
                break;
            }
            pool.push(now);
        }
    }

    /// Drop all warm containers (to measure cold behaviour).
    pub fn freeze(&self) {
        self.warm.lock().expect("lambda lock").clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn service(failure_prob: f64) -> (LambdaService, Arc<CostTracker>, Metrics) {
        let cfg = FlintConfig::default();
        let cost = Arc::new(CostTracker::new());
        let metrics = Metrics::new();
        let failure = Arc::new(FailureInjector::new(5, failure_prob, 0.0));
        let svc = LambdaService::new(&cfg, Arc::clone(&cost), metrics.clone(), failure);
        (svc, cost, metrics)
    }

    #[test]
    fn first_invocation_cold_then_warm() {
        let (svc, _, _) = service(0.0);
        let t1 = svc.begin_invoke("exec", 100).unwrap();
        assert!(t1.cold);
        assert_eq!(t1.start_latency_s, 0.250);
        svc.finish_invoke("exec", 1.0).unwrap();
        let t2 = svc.begin_invoke("exec", 100).unwrap();
        assert!(!t2.cold);
        assert_eq!(t2.start_latency_s, 0.015);
    }

    #[test]
    fn concurrent_invocations_each_cold() {
        let (svc, _, _) = service(0.0);
        // Two in flight with empty pool: both cold.
        let a = svc.begin_invoke("exec", 0).unwrap();
        let b = svc.begin_invoke("exec", 0).unwrap();
        assert!(a.cold && b.cold);
        svc.finish_invoke("exec", 1.0).unwrap();
        svc.finish_invoke("exec", 1.0).unwrap();
        assert_eq!(svc.warm_count("exec"), 2);
    }

    #[test]
    fn payload_limit_enforced() {
        let (svc, _, _) = service(0.0);
        let over = 6 * 1024 * 1024 + 1;
        assert!(matches!(
            svc.begin_invoke("exec", over),
            Err(LambdaError::PayloadTooLarge(_, _))
        ));
        assert!(svc.begin_invoke("exec", 6 * 1024 * 1024).is_ok());
    }

    #[test]
    fn duration_limit_enforced_and_billed() {
        let (svc, cost, _) = service(0.0);
        svc.begin_invoke("exec", 0).unwrap();
        let err = svc.finish_invoke("exec", 301.0).unwrap_err();
        assert!(matches!(err, LambdaError::DurationExceeded(300, 301)));
        assert!(cost.total() > 0.0, "timeout is still billed");
        // The container did not return to the pool.
        assert_eq!(svc.warm_count("exec"), 0);
    }

    #[test]
    fn idle_billing_charges_gb_seconds_without_request_fee() {
        let (svc, cost, metrics) = service(0.0);
        svc.bill_idle(2.01);
        let gb = 3008.0 / 1024.0;
        let expected = 2.1 * gb * 0.00001667; // rounded up, no request fee
        assert!((cost.total() - expected).abs() < 1e-12, "{}", cost.total());
        assert_eq!(metrics.get("lambda.idle_billed_100ms"), 21);
        // Zero or negative idle is a no-op.
        svc.bill_idle(0.0);
        assert!((cost.total() - expected).abs() < 1e-12);
    }

    #[test]
    fn billing_rounds_up_to_100ms() {
        let (svc, cost, _) = service(0.0);
        svc.begin_invoke("exec", 0).unwrap();
        svc.finish_invoke("exec", 0.01).unwrap();
        // 0.01s -> billed as 0.1s at 3008MB.
        let gb = 3008.0 / 1024.0;
        let expected = 0.1 * gb * 0.00001667 + 0.0000002;
        assert!((cost.total() - expected).abs() < 1e-12, "{}", cost.total());
    }

    #[test]
    fn failure_injection_marks_ticket() {
        let (svc, _, metrics) = service(1.0);
        let t = svc.begin_invoke("exec", 0).unwrap();
        assert!(t.will_fail);
        assert_eq!(metrics.get("lambda.injected_failures"), 1);
    }

    #[test]
    fn prewarm_and_freeze() {
        let (svc, _, _) = service(0.0);
        svc.prewarm("exec", 10);
        assert_eq!(svc.warm_count("exec"), 10);
        assert!(!svc.begin_invoke("exec", 0).unwrap().cold);
        svc.freeze();
        assert_eq!(svc.warm_count("exec"), 0);
        assert!(svc.begin_invoke("exec", 0).unwrap().cold);
    }

    fn keepalive_service(keepalive_s: f64) -> (LambdaService, Metrics) {
        let mut cfg = FlintConfig::default();
        cfg.flint.lambda_keepalive_s = keepalive_s;
        let cost = Arc::new(CostTracker::new());
        let metrics = Metrics::new();
        let failure = Arc::new(FailureInjector::new(5, 0.0, 0.0));
        let svc = LambdaService::new(&cfg, cost, metrics.clone(), failure);
        (svc, metrics)
    }

    #[test]
    fn keepalive_zero_never_expires() {
        let (svc, metrics) = keepalive_service(0.0);
        svc.begin_invoke("exec", 0).unwrap();
        svc.finish_invoke("exec", 1.0).unwrap();
        svc.advance_to(1.0e9);
        assert_eq!(svc.warm_count("exec"), 1, "0 keepalive = the pre-keepalive model");
        assert!(!svc.begin_invoke("exec", 0).unwrap().cold);
        assert_eq!(metrics.get("lambda.keepalive_expired"), 0);
    }

    #[test]
    fn keepalive_window_expires_containers() {
        let (svc, metrics) = keepalive_service(60.0);
        svc.begin_invoke("exec", 0).unwrap();
        svc.finish_invoke("exec", 1.0).unwrap(); // released at t=0
        svc.advance_to(59.0);
        assert_eq!(svc.warm_count("exec"), 1, "inside the window");
        assert!(!svc.begin_invoke("exec", 0).unwrap().cold);
        svc.finish_invoke("exec", 1.0).unwrap(); // re-released at t=59
        svc.advance_to(120.0);
        assert_eq!(svc.warm_count("exec"), 0, "59 + 60 < 120");
        assert!(svc.begin_invoke("exec", 0).unwrap().cold);
        assert_eq!(metrics.get("lambda.keepalive_expired"), 1);
    }

    #[test]
    fn keepalive_draws_most_recently_released_first() {
        let (svc, _) = keepalive_service(100.0);
        svc.begin_invoke("exec", 0).unwrap();
        svc.begin_invoke("exec", 0).unwrap();
        svc.finish_invoke("exec", 1.0).unwrap(); // released at t=0
        svc.advance_to(90.0);
        svc.finish_invoke("exec", 1.0).unwrap(); // released at t=90
        // Draw one (takes the t=90 release), then expire the rest.
        assert!(!svc.begin_invoke("exec", 0).unwrap().cold);
        svc.advance_to(150.0);
        // The t=0 container lapsed at t=100; only cold remains.
        assert!(svc.begin_invoke("exec", 0).unwrap().cold);
    }

    #[test]
    fn advance_to_is_monotonic() {
        let (svc, _) = keepalive_service(10.0);
        svc.advance_to(50.0);
        svc.advance_to(20.0);
        assert_eq!(svc.now(), 50.0, "stale advances are ignored");
    }

    #[test]
    fn warm_pool_capped_at_concurrency() {
        let (svc, _, _) = service(0.0);
        for _ in 0..100 {
            svc.begin_invoke("exec", 0).unwrap();
        }
        for _ in 0..100 {
            svc.finish_invoke("exec", 0.1).unwrap();
        }
        assert_eq!(svc.warm_count("exec"), 80, "capped at max_concurrency");
    }
}
