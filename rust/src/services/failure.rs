//! Centralized failure injection.
//!
//! §VI of the paper: "Executor failures can be overcome by retries, but
//! another issue is the at-least-once message semantics of SQS." Both
//! failure modes are injected here so experiments are reproducible from a
//! single seed, and tests can also *force* specific failures.

use crate::util::rng::Pcg64;
use std::collections::HashSet;
use std::sync::Mutex;

/// Deterministic, seedable failure source shared by the Lambda and SQS
/// simulators.
pub struct FailureInjector {
    state: Mutex<State>,
    lambda_failure_prob: f64,
    sqs_duplicate_prob: f64,
}

struct State {
    rng: Pcg64,
    /// Task attempts forced to fail: (stage, task, attempt).
    forced_task_failures: HashSet<(u32, u32, u32)>,
}

impl FailureInjector {
    pub fn new(seed: u64, lambda_failure_prob: f64, sqs_duplicate_prob: f64) -> Self {
        FailureInjector {
            state: Mutex::new(State {
                rng: Pcg64::new(seed, 911),
                forced_task_failures: HashSet::new(),
            }),
            lambda_failure_prob,
            sqs_duplicate_prob,
        }
    }

    /// Should this invocation crash? (Random path.)
    pub fn lambda_should_fail(&self) -> bool {
        if self.lambda_failure_prob <= 0.0 {
            return false;
        }
        self.state.lock().expect("failure lock").rng.chance(self.lambda_failure_prob)
    }

    /// Should this delivered SQS message be duplicated?
    pub fn sqs_should_duplicate(&self) -> bool {
        if self.sqs_duplicate_prob <= 0.0 {
            return false;
        }
        self.state.lock().expect("failure lock").rng.chance(self.sqs_duplicate_prob)
    }

    /// Force the given `(stage, task, attempt)` to fail exactly once —
    /// used by retry/chaining tests for surgical fault placement.
    pub fn force_task_failure(&self, stage: u32, task: u32, attempt: u32) {
        self.state
            .lock()
            .expect("failure lock")
            .forced_task_failures
            .insert((stage, task, attempt));
    }

    /// Consume a forced failure if one is registered for this attempt.
    pub fn take_forced_failure(&self, stage: u32, task: u32, attempt: u32) -> bool {
        self.state
            .lock()
            .expect("failure lock")
            .forced_task_failures
            .remove(&(stage, task, attempt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_probability_never_fails() {
        let f = FailureInjector::new(1, 0.0, 0.0);
        assert!((0..1000).all(|_| !f.lambda_should_fail()));
        assert!((0..1000).all(|_| !f.sqs_should_duplicate()));
    }

    #[test]
    fn probability_roughly_respected() {
        let f = FailureInjector::new(7, 0.3, 0.1);
        let fails = (0..10_000).filter(|_| f.lambda_should_fail()).count();
        let dups = (0..10_000).filter(|_| f.sqs_should_duplicate()).count();
        assert!((fails as f64 / 10_000.0 - 0.3).abs() < 0.03);
        assert!((dups as f64 / 10_000.0 - 0.1).abs() < 0.02);
    }

    #[test]
    fn forced_failures_fire_once() {
        let f = FailureInjector::new(1, 0.0, 0.0);
        f.force_task_failure(1, 5, 0);
        assert!(!f.take_forced_failure(1, 5, 1), "different attempt");
        assert!(f.take_forced_failure(1, 5, 0));
        assert!(!f.take_forced_failure(1, 5, 0), "consumed");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = FailureInjector::new(99, 0.5, 0.0);
        let b = FailureInjector::new(99, 0.5, 0.0);
        let seq_a: Vec<bool> = (0..100).map(|_| a.lambda_should_fail()).collect();
        let seq_b: Vec<bool> = (0..100).map(|_| b.lambda_should_fail()).collect();
        assert_eq!(seq_a, seq_b);
    }
}
