//! Centralized failure injection.
//!
//! §VI of the paper: "Executor failures can be overcome by retries, but
//! another issue is the at-least-once message semantics of SQS." Both
//! failure modes are injected here so experiments are reproducible from a
//! single seed, and tests can also *force* specific failures.
//!
//! The third injected hazard is the **straggler**: a task attempt that
//! lands on a slow container and runs a heavy-tailed multiple of its
//! normal duration (the motivation for speculative re-execution).
//! Straggler draws are *stateless* — hashed from `(seed, stage, task,
//! attempt)` — so the same attempts straggle no matter how host threads
//! interleave or how often the run repeats, and a straggling attempt's
//! backup (a different attempt number) rolls independently: the classic
//! "slow node, not slow work" assumption behind backup tasks.

use crate::util::rng::Pcg64;
use std::collections::{HashMap, HashSet};
use std::sync::Mutex;

/// Straggler slowdowns are capped here: a Pareto tail occasionally draws
/// absurd factors, and a 25x-slow Lambda would hit the duration cap
/// (chaining) long before running 100x over.
pub const MAX_STRAGGLER_FACTOR: f64 = 25.0;

/// Deterministic, seedable failure source shared by the Lambda and SQS
/// simulators.
pub struct FailureInjector {
    state: Mutex<State>,
    seed: u64,
    lambda_failure_prob: f64,
    sqs_duplicate_prob: f64,
    straggler_prob: f64,
    straggler_factor: f64,
    straggler_alpha: f64,
    /// When > 0, straggling is a property of the *container* an attempt
    /// lands on, not of the attempt itself (see [`Self::container_of`]).
    straggler_containers: usize,
}

struct State {
    rng: Pcg64,
    /// Task attempts forced to fail: (stage, task, attempt).
    forced_task_failures: HashSet<(u32, u32, u32)>,
    /// Task attempts forced to straggle: (stage, task, attempt) → factor.
    forced_stragglers: HashMap<(u32, u32, u32), f64>,
}

impl FailureInjector {
    pub fn new(seed: u64, lambda_failure_prob: f64, sqs_duplicate_prob: f64) -> Self {
        FailureInjector {
            state: Mutex::new(State {
                rng: Pcg64::new(seed, 911),
                forced_task_failures: HashSet::new(),
                forced_stragglers: HashMap::new(),
            }),
            seed,
            lambda_failure_prob,
            sqs_duplicate_prob,
            straggler_prob: 0.0,
            straggler_factor: 6.0,
            straggler_alpha: 2.0,
            straggler_containers: 0,
        }
    }

    /// Enable random heavy-tailed straggler injection (builder-style;
    /// `SimEnv` wires `sim.straggler_*` through here).
    pub fn with_stragglers(mut self, prob: f64, factor: f64, alpha: f64) -> Self {
        self.straggler_prob = prob;
        self.straggler_factor = factor.max(1.0);
        self.straggler_alpha = alpha.max(0.1);
        self
    }

    /// Switch straggling from per-attempt i.i.d. draws to
    /// container-affinity mode with `n` simulated containers
    /// (`sim.straggler_containers`; 0 keeps the i.i.d. model).
    pub fn with_straggler_containers(mut self, n: usize) -> Self {
        self.straggler_containers = n;
        self
    }

    /// Should this invocation crash? (Random path.)
    pub fn lambda_should_fail(&self) -> bool {
        if self.lambda_failure_prob <= 0.0 {
            return false;
        }
        self.state.lock().expect("failure lock").rng.chance(self.lambda_failure_prob)
    }

    /// Should this delivered SQS message be duplicated?
    pub fn sqs_should_duplicate(&self) -> bool {
        if self.sqs_duplicate_prob <= 0.0 {
            return false;
        }
        self.state.lock().expect("failure lock").rng.chance(self.sqs_duplicate_prob)
    }

    /// Force the given `(stage, task, attempt)` to fail exactly once —
    /// used by retry/chaining tests for surgical fault placement.
    pub fn force_task_failure(&self, stage: u32, task: u32, attempt: u32) {
        self.state
            .lock()
            .expect("failure lock")
            .forced_task_failures
            .insert((stage, task, attempt));
    }

    /// Consume a forced failure if one is registered for this attempt.
    pub fn take_forced_failure(&self, stage: u32, task: u32, attempt: u32) -> bool {
        self.state
            .lock()
            .expect("failure lock")
            .forced_task_failures
            .remove(&(stage, task, attempt))
    }

    /// Force `(stage, task, attempt)` to run `factor`× slower, exactly
    /// once — surgical straggler placement for speculation tests.
    pub fn force_straggler(&self, stage: u32, task: u32, attempt: u32, factor: f64) {
        self.state
            .lock()
            .expect("failure lock")
            .forced_stragglers
            .insert((stage, task, attempt), factor.max(1.0));
    }

    /// Slowdown factor for this attempt, if it straggles. Forced entries
    /// fire once; the random path is a pure hash of
    /// `(seed, stage, task, attempt)` — thread-interleaving-independent
    /// and repeatable, so speculation ablations compare identical runs.
    pub fn straggler_factor(&self, stage: u32, task: u32, attempt: u32) -> Option<f64> {
        if let Some(f) = self
            .state
            .lock()
            .expect("failure lock")
            .forced_stragglers
            .remove(&(stage, task, attempt))
        {
            return Some(f);
        }
        if self.straggler_prob <= 0.0 {
            return None;
        }
        if self.straggler_containers > 0 {
            return self.container_factor(self.container_of(stage, task, attempt)?);
        }
        let h = mix64(
            self.seed ^ 0x5354_5241_4747_4c45, // "STRAGGLE"
            ((stage as u64) << 40) | ((task as u64) << 8) | attempt as u64,
        );
        if unit_f64(h) >= self.straggler_prob {
            return None;
        }
        // Pareto(alpha) tail scaled by the minimum factor, capped.
        let u = unit_f64(mix64(h, 0x9e37_79b9_7f4a_7c15));
        let pareto = (1.0 - u).max(1e-9).powf(-1.0 / self.straggler_alpha);
        Some((self.straggler_factor * pareto).min(MAX_STRAGGLER_FACTOR))
    }

    /// The simulated container this attempt lands on, in
    /// container-affinity mode (`None` in the i.i.d. model). Placement is
    /// a stateless hash of `(seed, stage, task, attempt)`, so a backup
    /// (different attempt id) usually lands elsewhere — the premise of
    /// backup tasks — and the driver can attribute spans to containers
    /// for straggler *prediction*.
    pub fn container_of(&self, stage: u32, task: u32, attempt: u32) -> Option<u32> {
        if self.straggler_containers == 0 {
            return None;
        }
        let h = mix64(
            self.seed ^ 0x504c_4143_454d_4e54, // "PLACEMNT"
            ((stage as u64) << 40) | ((task as u64) << 8) | attempt as u64,
        );
        Some((h % self.straggler_containers as u64) as u32)
    }

    /// Slowdown factor of a container, stable for the whole run: slow
    /// containers are drawn once with `straggler_prob`, and every attempt
    /// placed on one inherits its factor ("slow node, not slow work").
    pub fn container_factor(&self, container: u32) -> Option<f64> {
        if self.straggler_prob <= 0.0 || self.straggler_containers == 0 {
            return None;
        }
        let h = mix64(self.seed ^ 0x434f_4e54_4149_4e45, container as u64); // "CONTAINE"
        if unit_f64(h) >= self.straggler_prob {
            return None;
        }
        let u = unit_f64(mix64(h, 0x9e37_79b9_7f4a_7c15));
        let pareto = (1.0 - u).max(1e-9).powf(-1.0 / self.straggler_alpha);
        Some((self.straggler_factor * pareto).min(MAX_STRAGGLER_FACTOR))
    }
}

/// SplitMix64-style stateless mixer.
fn mix64(a: u64, b: u64) -> u64 {
    let mut z = a ^ b.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Uniform in [0, 1) from the top 53 bits.
fn unit_f64(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_probability_never_fails() {
        let f = FailureInjector::new(1, 0.0, 0.0);
        assert!((0..1000).all(|_| !f.lambda_should_fail()));
        assert!((0..1000).all(|_| !f.sqs_should_duplicate()));
    }

    #[test]
    fn probability_roughly_respected() {
        let f = FailureInjector::new(7, 0.3, 0.1);
        let fails = (0..10_000).filter(|_| f.lambda_should_fail()).count();
        let dups = (0..10_000).filter(|_| f.sqs_should_duplicate()).count();
        assert!((fails as f64 / 10_000.0 - 0.3).abs() < 0.03);
        assert!((dups as f64 / 10_000.0 - 0.1).abs() < 0.02);
    }

    #[test]
    fn forced_failures_fire_once() {
        let f = FailureInjector::new(1, 0.0, 0.0);
        f.force_task_failure(1, 5, 0);
        assert!(!f.take_forced_failure(1, 5, 1), "different attempt");
        assert!(f.take_forced_failure(1, 5, 0));
        assert!(!f.take_forced_failure(1, 5, 0), "consumed");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = FailureInjector::new(99, 0.5, 0.0);
        let b = FailureInjector::new(99, 0.5, 0.0);
        let seq_a: Vec<bool> = (0..100).map(|_| a.lambda_should_fail()).collect();
        let seq_b: Vec<bool> = (0..100).map(|_| b.lambda_should_fail()).collect();
        assert_eq!(seq_a, seq_b);
    }

    #[test]
    fn forced_stragglers_fire_once() {
        let f = FailureInjector::new(1, 0.0, 0.0);
        f.force_straggler(0, 3, 0, 8.0);
        assert_eq!(f.straggler_factor(0, 3, 1), None, "different attempt");
        assert_eq!(f.straggler_factor(0, 3, 0), Some(8.0));
        assert_eq!(f.straggler_factor(0, 3, 0), None, "consumed");
    }

    #[test]
    fn random_stragglers_are_stateless_and_heavy_tailed() {
        let f = FailureInjector::new(7, 0.0, 0.0).with_stragglers(0.2, 4.0, 2.0);
        // Stateless: the same attempt draws the same factor regardless of
        // query order or thread interleaving.
        let a = f.straggler_factor(1, 5, 0);
        for _ in 0..10 {
            assert_eq!(f.straggler_factor(1, 5, 0), a);
        }
        // Rate roughly respected over many attempts; every straggler is
        // at least the minimum factor and capped.
        let mut hits = 0usize;
        for stage in 0..4u32 {
            for task in 0..500u32 {
                if let Some(fac) = f.straggler_factor(stage, task, 0) {
                    hits += 1;
                    assert!((4.0..=MAX_STRAGGLER_FACTOR).contains(&fac), "{fac}");
                }
            }
        }
        let rate = hits as f64 / 2000.0;
        assert!((rate - 0.2).abs() < 0.05, "straggler rate {rate}");
        // Independent across attempts: a straggling attempt's backup is
        // usually clean (different attempt id → fresh draw).
        let f2 = FailureInjector::new(8, 0.0, 0.0).with_stragglers(0.2, 4.0, 2.0);
        let mut both = 0;
        let mut first = 0;
        for task in 0..2000u32 {
            let a0 = f2.straggler_factor(0, task, 0).is_some();
            let a1 = f2.straggler_factor(0, task, 1).is_some();
            first += a0 as usize;
            both += (a0 && a1) as usize;
        }
        assert!(both < first / 2, "attempt draws must be independent ({both}/{first})");
        // A different seed draws a different pattern.
        let f3 = FailureInjector::new(9, 0.0, 0.0).with_stragglers(0.2, 4.0, 2.0);
        let same = (0..2000u32)
            .filter(|&t| f2.straggler_factor(1, t, 0).is_some() == f3.straggler_factor(1, t, 0).is_some())
            .count();
        assert!(same < 2000, "seeds must matter");
    }

    #[test]
    fn zero_probability_never_straggles() {
        let f = FailureInjector::new(1, 0.0, 0.0);
        assert!((0..500u32).all(|t| f.straggler_factor(0, t, 0).is_none()));
    }

    #[test]
    fn container_mode_makes_straggling_a_container_property() {
        let f = FailureInjector::new(11, 0.0, 0.0)
            .with_stragglers(0.25, 4.0, 2.0)
            .with_straggler_containers(8);
        // Every attempt lands on some container; placement is stable.
        for task in 0..200u32 {
            let c = f.container_of(0, task, 0).unwrap();
            assert!(c < 8);
            assert_eq!(f.container_of(0, task, 0), Some(c));
            // The attempt straggles iff its container does, with the
            // container's factor.
            assert_eq!(f.straggler_factor(0, task, 0), f.container_factor(c));
        }
        // Attempts spread across containers, and a backup (attempt 1)
        // usually lands on a different container than attempt 0.
        let containers: std::collections::HashSet<u32> =
            (0..200u32).filter_map(|t| f.container_of(0, t, 0)).collect();
        assert!(containers.len() > 4, "placement must spread: {containers:?}");
        let moved = (0..200u32)
            .filter(|&t| f.container_of(0, t, 0) != f.container_of(0, t, 1))
            .count();
        assert!(moved > 100, "backups must usually move containers ({moved}/200)");
        // Container factors are stable and some (not all) containers are
        // slow at prob 0.25 over enough containers.
        let f2 = FailureInjector::new(12, 0.0, 0.0)
            .with_stragglers(0.25, 4.0, 2.0)
            .with_straggler_containers(64);
        let slow = (0..64u32).filter(|&c| f2.container_factor(c).is_some()).count();
        assert!(slow > 4 && slow < 40, "slow-container rate off: {slow}/64");
        for c in 0..64u32 {
            assert_eq!(f2.container_factor(c), f2.container_factor(c));
        }
    }

    #[test]
    fn iid_mode_has_no_containers() {
        let f = FailureInjector::new(1, 0.0, 0.0).with_stragglers(0.5, 4.0, 2.0);
        assert_eq!(f.container_of(0, 0, 0), None);
        assert_eq!(f.container_factor(3), None);
    }
}
