//! Simulated S3-style object store.
//!
//! Captures what mattered to the paper: byte-range GETs (Flint's input
//! splits are "a range of bytes from an S3 object"), per-stream throughput
//! (the boto-vs-Hadoop gap behind the paper's Q0 result), first-byte
//! latency, request pricing, and bucket/key listing. Data lives in memory
//! behind `Arc`s; reads hand out zero-copy views.

use crate::config::FlintConfig;
use crate::cost::{CostCategory, CostTracker};
use crate::metrics::Metrics;
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

/// Throughput/latency profile of a reader — Flint's Python/boto executors
/// and Spark's Hadoop connector see different numbers (DESIGN.md §5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReadProfile {
    pub first_byte_s: f64,
    pub mbps: f64,
}

impl ReadProfile {
    /// Modeled wall time to stream `bytes` through this profile.
    pub fn read_time_s(&self, bytes: u64) -> f64 {
        self.first_byte_s + bytes as f64 / (self.mbps * 1e6)
    }
}

/// A zero-copy view over a stored object (or a byte range of it).
#[derive(Debug, Clone)]
pub struct S3Object {
    data: Arc<Vec<u8>>,
    start: usize,
    end: usize,
}

impl S3Object {
    pub fn bytes(&self) -> &[u8] {
        &self.data[self.start..self.end]
    }

    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl std::ops::Deref for S3Object {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.bytes()
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum S3Error {
    NoSuchBucket(String),
    NoSuchKey(String, String),
    InvalidRange(u64, u64, u64),
}

impl std::fmt::Display for S3Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            S3Error::NoSuchBucket(bucket) => write!(f, "no such bucket: {bucket}"),
            S3Error::NoSuchKey(bucket, key) => write!(f, "no such key: {bucket}/{key}"),
            S3Error::InvalidRange(len, start, end) => {
                write!(f, "invalid range {start}..{end} for object of {len} bytes")
            }
        }
    }
}

impl std::error::Error for S3Error {}

type Buckets = BTreeMap<String, BTreeMap<String, Arc<Vec<u8>>>>;

/// The store itself. All operations return `(value, modeled_duration_s)`;
/// callers charge the duration to their task timeline.
pub struct ObjectStore {
    buckets: RwLock<Buckets>,
    /// User metadata per `bucket/key` (set at PUT time on real S3, here
    /// via [`ObjectStore::set_object_meta`]; returned by HEAD).
    meta: RwLock<BTreeMap<String, Arc<Vec<(String, String)>>>>,
    /// Per-bucket write generation: bumped by every mutation that can
    /// change what a LIST/HEAD under the bucket observes (PUT, rename
    /// commit, DELETE, metadata attach). Listing caches snapshot it to
    /// validate their entries ([`ObjectStore::write_generation`]).
    generation: RwLock<BTreeMap<String, u64>>,
    put_mbps: f64,
    first_byte_s: f64,
    get_per_1000: f64,
    put_per_1000: f64,
    cost: Arc<CostTracker>,
    metrics: Metrics,
}

impl ObjectStore {
    pub fn new(config: &FlintConfig, cost: Arc<CostTracker>, metrics: Metrics) -> Self {
        ObjectStore {
            buckets: RwLock::new(BTreeMap::new()),
            meta: RwLock::new(BTreeMap::new()),
            generation: RwLock::new(BTreeMap::new()),
            put_mbps: config.sim.s3_put_mbps,
            first_byte_s: config.sim.s3_first_byte_s,
            get_per_1000: config.pricing.s3_get_per_1000,
            put_per_1000: config.pricing.s3_put_per_1000,
            cost,
            metrics,
        }
    }

    /// Create a bucket (idempotent, like the real thing for an owner).
    pub fn create_bucket(&self, bucket: &str) {
        self.buckets
            .write()
            .expect("s3 lock")
            .entry(bucket.to_string())
            .or_default();
    }

    pub fn bucket_exists(&self, bucket: &str) -> bool {
        self.buckets.read().expect("s3 lock").contains_key(bucket)
    }

    /// Current write generation of a bucket (0 until its first
    /// mutation). Any PUT, rename commit, DELETE, or metadata attach
    /// under the bucket advances it, so a listing resolved while the
    /// bucket was at generation `g` is valid exactly as long as the
    /// bucket is still at `g` — the invalidation signal for shared
    /// scan-listing caches.
    pub fn write_generation(&self, bucket: &str) -> u64 {
        self.generation
            .read()
            .expect("s3 generation lock")
            .get(bucket)
            .copied()
            .unwrap_or(0)
    }

    fn bump_generation(&self, bucket: &str) {
        *self
            .generation
            .write()
            .expect("s3 generation lock")
            .entry(bucket.to_string())
            .or_insert(0) += 1;
    }

    /// PUT an object. Returns the modeled upload duration.
    pub fn put_object(
        &self,
        bucket: &str,
        key: &str,
        data: Vec<u8>,
    ) -> Result<f64, S3Error> {
        let len = data.len() as u64;
        {
            let mut buckets = self.buckets.write().expect("s3 lock");
            let b = buckets
                .get_mut(bucket)
                .ok_or_else(|| S3Error::NoSuchBucket(bucket.to_string()))?;
            b.insert(key.to_string(), Arc::new(data));
        }
        self.bump_generation(bucket);
        self.cost.charge(CostCategory::S3Requests, self.put_per_1000 / 1000.0);
        self.metrics.incr("s3.put");
        self.metrics.add("s3.bytes_written", len);
        Ok(self.first_byte_s + len as f64 / (self.put_mbps * 1e6))
    }

    /// GET a whole object.
    pub fn get_object(
        &self,
        bucket: &str,
        key: &str,
        profile: ReadProfile,
    ) -> Result<(S3Object, f64), S3Error> {
        let data = self.lookup(bucket, key)?;
        let len = data.len();
        self.charge_get(len as u64);
        Ok((
            S3Object { data, start: 0, end: len },
            profile.read_time_s(len as u64),
        ))
    }

    /// GET a byte range `[start, end)` — Flint input splits use this.
    pub fn get_range(
        &self,
        bucket: &str,
        key: &str,
        start: u64,
        end: u64,
        profile: ReadProfile,
    ) -> Result<(S3Object, f64), S3Error> {
        let data = self.lookup(bucket, key)?;
        let len = data.len() as u64;
        if start > end || end > len {
            return Err(S3Error::InvalidRange(len, start, end));
        }
        self.charge_get(end - start);
        Ok((
            S3Object { data, start: start as usize, end: end as usize },
            profile.read_time_s(end - start),
        ))
    }

    /// Object size without reading (HEAD).
    pub fn head_object(&self, bucket: &str, key: &str) -> Result<u64, S3Error> {
        let data = self.lookup(bucket, key)?;
        self.metrics.incr("s3.head");
        Ok(data.len() as u64)
    }

    /// Simulation-side introspection: the object's bytes with **no**
    /// request, cost, or metric. Used where the simulator models data
    /// that is already resident outside S3 — e.g. populating the
    /// lineage cache's warm-container memory tier from the committed
    /// object the builder just wrote (the real system keeps those bytes
    /// in the container; round-tripping them through a priced GET would
    /// double-charge the build). Never call this on a data path that
    /// models a real S3 read — use `get_object`/`get_range`.
    pub fn peek_object(&self, bucket: &str, key: &str) -> Result<Arc<Vec<u8>>, S3Error> {
        self.lookup(bucket, key)
    }

    /// Attach user metadata to an existing object. On real S3 metadata
    /// rides the PUT itself, so this books no extra request or time —
    /// it only has to happen before anyone HEADs the object.
    pub fn set_object_meta(
        &self,
        bucket: &str,
        key: &str,
        meta: Vec<(String, String)>,
    ) -> Result<(), S3Error> {
        // Existence check under the bucket lock keeps meta from outliving
        // (or predating) its object.
        let _ = self.lookup(bucket, key)?;
        self.meta
            .write()
            .expect("s3 meta lock")
            .insert(format!("{bucket}/{key}"), Arc::new(meta));
        // Metadata feeds the per-object stats that ride input splits, so
        // attaching it changes what a scan resolution would observe.
        self.bump_generation(bucket);
        Ok(())
    }

    /// HEAD an object, returning `(size, user_metadata)`. Priced as a
    /// GET-class request (that is how AWS bills HEAD).
    pub fn head_object_meta(
        &self,
        bucket: &str,
        key: &str,
    ) -> Result<(u64, Arc<Vec<(String, String)>>), S3Error> {
        let data = self.lookup(bucket, key)?;
        self.cost.charge(CostCategory::S3Requests, self.get_per_1000 / 1000.0);
        self.metrics.incr("s3.head");
        let meta = self
            .meta
            .read()
            .expect("s3 meta lock")
            .get(&format!("{bucket}/{key}"))
            .cloned()
            .unwrap_or_default();
        Ok((data.len() as u64, meta))
    }

    /// Atomic rename-on-commit — the attempt-scoped output committer's
    /// primitive. Moves `src` to `dst` unless `dst` already exists
    /// (first-commit-wins); either way `src` is consumed. One write lock
    /// covers the probe and the move, so two racing commits can never
    /// both win or leave `dst` torn. Returns `(duration, won)`: the
    /// modeled server-side copy time (request round-trip only, no body
    /// transfer) and whether this commit took the final key.
    pub fn commit_rename(
        &self,
        bucket: &str,
        src: &str,
        dst: &str,
    ) -> Result<(f64, bool), S3Error> {
        let won = {
            let mut buckets = self.buckets.write().expect("s3 lock");
            let b = buckets
                .get_mut(bucket)
                .ok_or_else(|| S3Error::NoSuchBucket(bucket.to_string()))?;
            let data = b
                .remove(src)
                .ok_or_else(|| S3Error::NoSuchKey(bucket.to_string(), src.to_string()))?;
            if b.contains_key(dst) {
                false // lost the race: the temp object is dropped
            } else {
                b.insert(dst.to_string(), data);
                true
            }
        };
        // Win or lose, the temp key is gone (and on a win the final key
        // appeared) — either way listings changed.
        self.bump_generation(bucket);
        // Billed like a COPY (PUT-class) + free DELETE; server-side, so
        // the modeled time is one request round-trip regardless of size.
        self.cost.charge(CostCategory::S3Requests, self.put_per_1000 / 1000.0);
        self.metrics.incr("s3.rename");
        if !won {
            self.metrics.incr("s3.commit_lost");
        }
        Ok((self.first_byte_s, won))
    }

    /// List `(key, size)` under a prefix, lexicographically.
    pub fn list(&self, bucket: &str, prefix: &str) -> Result<Vec<(String, u64)>, S3Error> {
        let buckets = self.buckets.read().expect("s3 lock");
        let b = buckets
            .get(bucket)
            .ok_or_else(|| S3Error::NoSuchBucket(bucket.to_string()))?;
        self.metrics.incr("s3.list");
        Ok(b.range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.clone(), v.len() as u64))
            .collect())
    }

    pub fn delete_object(&self, bucket: &str, key: &str) -> Result<(), S3Error> {
        {
            let mut buckets = self.buckets.write().expect("s3 lock");
            let b = buckets
                .get_mut(bucket)
                .ok_or_else(|| S3Error::NoSuchBucket(bucket.to_string()))?;
            b.remove(key)
                .ok_or_else(|| S3Error::NoSuchKey(bucket.to_string(), key.to_string()))?;
        }
        self.bump_generation(bucket);
        Ok(())
    }

    /// Delete every object under a prefix; returns how many were removed.
    pub fn delete_prefix(&self, bucket: &str, prefix: &str) -> Result<usize, S3Error> {
        let removed = {
            let mut buckets = self.buckets.write().expect("s3 lock");
            let b = buckets
                .get_mut(bucket)
                .ok_or_else(|| S3Error::NoSuchBucket(bucket.to_string()))?;
            let keys: Vec<String> = b
                .range(prefix.to_string()..)
                .take_while(|(k, _)| k.starts_with(prefix))
                .map(|(k, _)| k.clone())
                .collect();
            for k in &keys {
                b.remove(k);
            }
            keys.len()
        };
        if removed > 0 {
            self.bump_generation(bucket);
        }
        Ok(removed)
    }

    /// Total bytes stored in a bucket (diagnostics).
    pub fn bucket_bytes(&self, bucket: &str) -> u64 {
        self.buckets
            .read()
            .expect("s3 lock")
            .get(bucket)
            .map(|b| b.values().map(|v| v.len() as u64).sum())
            .unwrap_or(0)
    }

    fn lookup(&self, bucket: &str, key: &str) -> Result<Arc<Vec<u8>>, S3Error> {
        let buckets = self.buckets.read().expect("s3 lock");
        let b = buckets
            .get(bucket)
            .ok_or_else(|| S3Error::NoSuchBucket(bucket.to_string()))?;
        b.get(key)
            .cloned()
            .ok_or_else(|| S3Error::NoSuchKey(bucket.to_string(), key.to_string()))
    }

    fn charge_get(&self, bytes: u64) {
        self.cost.charge(CostCategory::S3Requests, self.get_per_1000 / 1000.0);
        self.metrics.incr("s3.get");
        self.metrics.add("s3.bytes_read", bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ObjectStore {
        let cfg = FlintConfig::default();
        ObjectStore::new(&cfg, Arc::new(CostTracker::new()), Metrics::new())
    }

    fn profile() -> ReadProfile {
        ReadProfile { first_byte_s: 0.02, mbps: 100.0 }
    }

    #[test]
    fn put_get_roundtrip() {
        let s3 = store();
        s3.create_bucket("in");
        s3.put_object("in", "a.csv", b"hello,world".to_vec()).unwrap();
        let (obj, dt) = s3.get_object("in", "a.csv", profile()).unwrap();
        assert_eq!(obj.bytes(), b"hello,world");
        assert!(dt > 0.02, "first byte latency included");
    }

    #[test]
    fn range_reads() {
        let s3 = store();
        s3.create_bucket("in");
        s3.put_object("in", "k", (0u8..100).collect()).unwrap();
        let (obj, _) = s3.get_range("in", "k", 10, 20, profile()).unwrap();
        assert_eq!(obj.bytes(), &(10u8..20).collect::<Vec<_>>()[..]);
        assert_eq!(obj.len(), 10);
        assert!(matches!(
            s3.get_range("in", "k", 90, 120, profile()),
            Err(S3Error::InvalidRange(100, 90, 120))
        ));
    }

    #[test]
    fn missing_bucket_and_key() {
        let s3 = store();
        assert!(matches!(
            s3.get_object("nope", "k", profile()),
            Err(S3Error::NoSuchBucket(_))
        ));
        s3.create_bucket("b");
        assert!(matches!(
            s3.get_object("b", "k", profile()),
            Err(S3Error::NoSuchKey(_, _))
        ));
    }

    #[test]
    fn list_respects_prefix_and_order() {
        let s3 = store();
        s3.create_bucket("b");
        s3.put_object("b", "data/part-0002", vec![0; 2]).unwrap();
        s3.put_object("b", "data/part-0001", vec![0; 1]).unwrap();
        s3.put_object("b", "other/x", vec![0; 9]).unwrap();
        let listed = s3.list("b", "data/").unwrap();
        assert_eq!(
            listed,
            vec![("data/part-0001".to_string(), 1), ("data/part-0002".to_string(), 2)]
        );
    }

    #[test]
    fn delete_prefix_counts() {
        let s3 = store();
        s3.create_bucket("b");
        for i in 0..5 {
            s3.put_object("b", &format!("tmp/{i}"), vec![1]).unwrap();
        }
        s3.put_object("b", "keep", vec![1]).unwrap();
        assert_eq!(s3.delete_prefix("b", "tmp/").unwrap(), 5);
        assert_eq!(s3.list("b", "").unwrap().len(), 1);
    }

    #[test]
    fn read_time_scales_with_profile() {
        let fast = ReadProfile { first_byte_s: 0.0, mbps: 100.0 };
        let slow = ReadProfile { first_byte_s: 0.0, mbps: 50.0 };
        let bytes = 100 * 1024 * 1024;
        assert!(slow.read_time_s(bytes) > fast.read_time_s(bytes) * 1.99);
    }

    #[test]
    fn costs_and_metrics_accrue() {
        let cfg = FlintConfig::default();
        let cost = Arc::new(CostTracker::new());
        let metrics = Metrics::new();
        let s3 = ObjectStore::new(&cfg, Arc::clone(&cost), metrics.clone());
        s3.create_bucket("b");
        s3.put_object("b", "k", vec![0; 1000]).unwrap();
        s3.get_object("b", "k", profile()).unwrap();
        assert_eq!(metrics.get("s3.put"), 1);
        assert_eq!(metrics.get("s3.get"), 1);
        assert_eq!(metrics.get("s3.bytes_read"), 1000);
        assert!(cost.total() > 0.0);
    }

    #[test]
    fn commit_rename_first_wins_and_consumes_src() {
        let s3 = store();
        s3.create_bucket("b");
        s3.put_object("b", "tmp/part.a0", b"winner".to_vec()).unwrap();
        s3.put_object("b", "tmp/part.a1", b"loser".to_vec()).unwrap();
        let (dt, won) = s3.commit_rename("b", "tmp/part.a0", "part").unwrap();
        assert!(won && dt > 0.0);
        // The racing attempt loses, its temp object is consumed, and the
        // winner's bytes are untouched (no tear, no clobber).
        let (_, won2) = s3.commit_rename("b", "tmp/part.a1", "part").unwrap();
        assert!(!won2);
        let (obj, _) = s3.get_object("b", "part", profile()).unwrap();
        assert_eq!(obj.bytes(), b"winner");
        assert!(s3.list("b", "tmp/").unwrap().is_empty(), "both temps consumed");
        // A commit without its temp object is an error, not a silent win.
        assert!(matches!(
            s3.commit_rename("b", "tmp/part.a0", "part"),
            Err(S3Error::NoSuchKey(_, _))
        ));
    }

    #[test]
    fn head_object_meta_roundtrips_and_is_billed() {
        let cfg = FlintConfig::default();
        let cost = Arc::new(CostTracker::new());
        let metrics = Metrics::new();
        let s3 = ObjectStore::new(&cfg, Arc::clone(&cost), metrics.clone());
        s3.create_bucket("b");
        s3.put_object("b", "k", vec![0; 64]).unwrap();
        assert!(s3.set_object_meta("b", "missing", Vec::new()).is_err());
        s3.set_object_meta("b", "k", vec![("min-day".into(), "3".into())]).unwrap();
        let before = cost.total();
        let (len, meta) = s3.head_object_meta("b", "k").unwrap();
        assert_eq!(len, 64);
        assert_eq!(meta.as_slice(), &[("min-day".to_string(), "3".to_string())]);
        assert!(cost.total() > before, "HEAD is a billed request");
        assert_eq!(metrics.get("s3.head"), 1);
    }

    #[test]
    fn write_generation_tracks_every_mutation() {
        let s3 = store();
        s3.create_bucket("b");
        assert_eq!(s3.write_generation("b"), 0, "fresh bucket");
        assert_eq!(s3.write_generation("nope"), 0, "unknown bucket reads as 0");

        s3.put_object("b", "tmp/k.a0", b"x".to_vec()).unwrap();
        let after_put = s3.write_generation("b");
        assert!(after_put > 0);

        // Reads never advance the generation.
        s3.get_object("b", "tmp/k.a0", profile()).unwrap();
        s3.list("b", "").unwrap();
        s3.head_object("b", "tmp/k.a0").unwrap();
        assert_eq!(s3.write_generation("b"), after_put);

        s3.set_object_meta("b", "tmp/k.a0", vec![("rows".into(), "1".into())]).unwrap();
        let after_meta = s3.write_generation("b");
        assert!(after_meta > after_put, "metadata feeds split stats");

        s3.commit_rename("b", "tmp/k.a0", "k").unwrap();
        let after_commit = s3.write_generation("b");
        assert!(after_commit > after_meta, "a commit changes listings");

        s3.delete_object("b", "k").unwrap();
        let after_delete = s3.write_generation("b");
        assert!(after_delete > after_commit);
        assert!(s3.delete_object("b", "k").is_err());
        assert_eq!(s3.write_generation("b"), after_delete, "a failed delete is not a write");

        s3.put_object("b", "p/x", b"x".to_vec()).unwrap();
        let g = s3.write_generation("b");
        assert_eq!(s3.delete_prefix("b", "none/").unwrap(), 0);
        assert_eq!(s3.write_generation("b"), g, "a no-op prefix delete is not a write");
        assert_eq!(s3.delete_prefix("b", "p/").unwrap(), 1);
        assert!(s3.write_generation("b") > g);
    }

    #[test]
    fn zero_copy_views_share_data() {
        let s3 = store();
        s3.create_bucket("b");
        s3.put_object("b", "k", vec![7; 1 << 20]).unwrap();
        let (a, _) = s3.get_object("b", "k", profile()).unwrap();
        let (b, _) = s3.get_range("b", "k", 0, 1 << 20, profile()).unwrap();
        // Same backing allocation.
        assert!(std::ptr::eq(a.data.as_ptr(), b.data.as_ptr()));
    }
}
