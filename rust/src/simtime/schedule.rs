//! Cross-stage virtual-time scheduling: an event-driven global clock
//! that places every task of a plan's stage DAG onto the shared
//! Lambda-concurrency (or cluster-core) slots.
//!
//! Two modes, selected per run:
//!
//! * **Barrier** — the original serial driver's model, kept for the
//!   Qubole-style S3 shuffle backend and as the Table I baseline: stages
//!   execute strictly one after another; stage latency is its task
//!   makespan plus driver overhead, and plan latency is the sum. This
//!   reproduces the pre-DAG Σ-makespan numbers exactly.
//! * **Pipelined** — the paper's SQS semantics (§III-A): a stage's tasks
//!   become launchable as soon as *every parent has started producing*
//!   (reduce tasks long-poll their queues concurrently with map
//!   flushes). A consumer task's work is modelled as arriving in equal
//!   chunks, one per producer task, released when that producer
//!   finishes; the consumer occupies its slot while long-polling and
//!   completes once it has processed every chunk. Producer stages get
//!   strict dispatch priority (lower stage id first), so pipelining
//!   never starves the tasks that feed it. Because non-preemptive
//!   overlap scheduling has classical anomalies on multi-root DAGs, the
//!   scheduler prices the serial plan too and falls back to it whenever
//!   overlap would lose — pipelined mode never schedules worse than
//!   barrier mode.
//!
//! The driver runs tasks on real threads in topological order (the
//! simulated queues hold data only after producers flush); this module
//! is where the *virtual* overlap between stages is computed from the
//! per-task durations those runs measured.

use crate::simtime::makespan::makespan_assignments;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// How stages are allowed to overlap in virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleMode {
    /// Serial stages with a hard barrier between them (Σ makespans).
    Barrier,
    /// Dependency-aware overlap: consumers launch once all parents have
    /// started producing.
    Pipelined,
}

impl std::str::FromStr for ScheduleMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "barrier" => Ok(ScheduleMode::Barrier),
            "pipelined" => Ok(ScheduleMode::Pipelined),
            other => Err(format!("unknown scheduler `{other}` (want barrier|pipelined)")),
        }
    }
}

impl ScheduleMode {
    pub fn name(&self) -> &'static str {
        match self {
            ScheduleMode::Barrier => "barrier",
            ScheduleMode::Pipelined => "pipelined",
        }
    }
}

/// One stage's scheduling inputs: the DAG edge structure plus the
/// measured virtual duration of each task.
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub id: u32,
    /// Parent stage ids (must be < `id`; stages arrive topo-ordered).
    pub parents: Vec<u32>,
    /// Virtual duration of each task, in submission order.
    pub task_durations: Vec<f64>,
    /// Driver-side overhead for this stage (task serialization, queue
    /// management). Charged serially after the stage in barrier mode —
    /// matching the original Σ model — and before its first task can
    /// launch in pipelined mode.
    pub overhead_s: f64,
}

/// Where one stage landed on the virtual clock.
#[derive(Debug, Clone)]
pub struct StageWindow {
    pub id: u32,
    /// When the stage became runnable / its first task started.
    pub start: f64,
    /// When its last task finished (barrier: plus driver overhead).
    pub end: f64,
    /// Per-task `(start, end)` spans, in submission order.
    pub tasks: Vec<(f64, f64)>,
}

impl StageWindow {
    /// Seconds this window overlaps another (0 when disjoint).
    pub fn overlap_s(&self, other: &StageWindow) -> f64 {
        (self.end.min(other.end) - self.start.max(other.start)).max(0.0)
    }
}

/// The scheduled plan.
#[derive(Debug, Clone)]
pub struct ScheduleOut {
    /// End-to-end virtual latency (time the last task/overhead ends).
    pub latency_s: f64,
    pub stages: Vec<StageWindow>,
}

/// Schedule a stage DAG onto `slots` shared concurrency slots.
///
/// `stages` must be topologically ordered with dense ids (`id == index`,
/// `parents[i] < id`) — the invariant `PhysicalPlan::validate` checks.
pub fn schedule_dag(stages: &[StageSpec], slots: usize, mode: ScheduleMode) -> ScheduleOut {
    assert!(slots > 0, "schedule_dag needs at least one slot");
    for (i, s) in stages.iter().enumerate() {
        assert_eq!(s.id as usize, i, "stage ids must be dense and ordered");
        for &p in &s.parents {
            assert!(p < s.id, "stage {} parent {p} breaks topo order", s.id);
        }
    }
    match mode {
        ScheduleMode::Barrier => schedule_barrier(stages, slots),
        ScheduleMode::Pipelined => {
            let sim = schedule_pipelined(stages, slots);
            // Non-preemptive overlap scheduling has classical anomalies:
            // with several root stages whose ready times differ, a
            // later-ready but lower-priority stage can seize slots and
            // delay a critical producer, losing to the serial plan
            // (measured: rare, worst ~4% on random two-level DAGs). The
            // scheduler prices both plans and keeps the serial one
            // whenever overlap would lose, so pipelined mode is never
            // worse than barrier mode by construction.
            let serial = schedule_barrier(stages, slots);
            if sim.latency_s <= serial.latency_s {
                sim
            } else {
                serial
            }
        }
    }
}

/// Serial stage-by-stage execution: exactly the original driver's
/// Σ(makespan + overhead) model, expressed on the global clock.
fn schedule_barrier(stages: &[StageSpec], slots: usize) -> ScheduleOut {
    let mut clock = 0.0f64;
    let mut windows = Vec::with_capacity(stages.len());
    for s in stages {
        let (ms, spans) = makespan_assignments(&s.task_durations, slots);
        let start = clock;
        let end = start + ms + s.overhead_s;
        windows.push(StageWindow {
            id: s.id,
            start,
            end,
            tasks: spans.iter().map(|(a, b, _)| (start + a, start + b)).collect(),
        });
        clock = end;
    }
    ScheduleOut { latency_s: clock, stages: windows }
}

// ---------------------------------------------------------------------
// Pipelined mode: event-driven simulation
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    /// Stage becomes launchable (overhead paid, parents started).
    StageReady { stage: usize },
    /// A task finished; frees its slot and releases chunks downstream.
    TaskEnd { stage: usize, task: usize },
}

#[derive(Debug, PartialEq)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse so the *earliest* event pops
        // first, with insertion order as the deterministic tie-break.
        self.time
            .total_cmp(&other.time)
            .then(self.seq.cmp(&other.seq))
            .reverse()
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

#[derive(Debug, Clone, Copy)]
enum TaskState {
    NotStarted,
    /// Long-polling/processing: `busy_until` is when already-released
    /// work finishes; `remaining` producer tasks still owe a chunk.
    Running { start: f64, busy_until: f64, remaining: usize, chunk_w: f64 },
    Done { start: f64, end: f64 },
}

struct Sim<'a> {
    stages: &'a [StageSpec],
    /// Total producer tasks feeding each stage (sum over parents).
    producer_tasks: Vec<usize>,
    /// Producer tasks already finished, per consumer stage.
    released: Vec<usize>,
    children: Vec<Vec<usize>>,
    ready: Vec<bool>,
    first_start: Vec<Option<f64>>,
    /// Parents that have started producing, per stage.
    parents_started: Vec<usize>,
    pending: Vec<VecDeque<usize>>,
    tasks: Vec<Vec<TaskState>>,
    free_slots: usize,
    events: BinaryHeap<Event>,
    seq: u64,
    ends_left: usize,
}

impl<'a> Sim<'a> {
    fn push(&mut self, time: f64, kind: EventKind) {
        self.seq += 1;
        self.events.push(Event { time, seq: self.seq, kind });
    }

    /// Mark `stage` as having started producing at `now`, waking any
    /// child whose parents have now all started.
    // Index loops: the bodies need `&mut self` (event pushes), so
    // iterator-style traversal would hold a conflicting borrow.
    #[allow(clippy::needless_range_loop)]
    fn note_first_start(&mut self, stage: usize, now: f64) {
        if self.first_start[stage].is_some() {
            return;
        }
        self.first_start[stage] = Some(now);
        for ci in 0..self.children[stage].len() {
            let child = self.children[stage][ci];
            self.parents_started[child] += 1;
            if self.parents_started[child] == self.stages[child].parents.len() {
                self.push(
                    now + self.stages[child].overhead_s,
                    EventKind::StageReady { stage: child },
                );
            }
        }
    }

    /// Start task `t` of `stage` at `now` (a slot has been claimed).
    fn start_task(&mut self, stage: usize, t: usize, now: f64) {
        let d = self.stages[stage].task_durations[t];
        self.note_first_start(stage, now);
        let m = self.producer_tasks[stage];
        if m == 0 {
            // Source task: all input available immediately.
            self.tasks[stage][t] =
                TaskState::Running { start: now, busy_until: now + d, remaining: 0, chunk_w: 0.0 };
            self.push(now + d, EventKind::TaskEnd { stage, task: t });
        } else {
            let chunk_w = d / m as f64;
            let released = self.released[stage];
            let busy_until = now + released as f64 * chunk_w;
            let remaining = m - released;
            self.tasks[stage][t] =
                TaskState::Running { start: now, busy_until, remaining, chunk_w };
            if remaining == 0 {
                self.push(busy_until, EventKind::TaskEnd { stage, task: t });
            }
        }
    }

    /// A producer task of `stage` finished at `now`: release one chunk
    /// to every task of every child stage.
    #[allow(clippy::needless_range_loop)]
    fn release_chunks(&mut self, stage: usize, now: f64) {
        for ci in 0..self.children[stage].len() {
            let child = self.children[stage][ci];
            self.released[child] += 1;
            for t in 0..self.tasks[child].len() {
                if let TaskState::Running { start, busy_until, remaining, chunk_w } =
                    self.tasks[child][t]
                {
                    debug_assert!(remaining > 0, "running consumer ran out of chunks early");
                    let busy_until = busy_until.max(now) + chunk_w;
                    let remaining = remaining - 1;
                    self.tasks[child][t] =
                        TaskState::Running { start, busy_until, remaining, chunk_w };
                    if remaining == 0 {
                        self.push(busy_until, EventKind::TaskEnd { stage: child, task: t });
                    }
                }
            }
        }
    }

    fn handle(&mut self, ev: Event) {
        let now = ev.time;
        match ev.kind {
            EventKind::StageReady { stage } => {
                self.ready[stage] = true;
                if self.stages[stage].task_durations.is_empty() {
                    // Degenerate empty stage: "starts producing" (and
                    // finishes) the moment it is ready. It contributes no
                    // producer tasks, so children wait on nothing from it.
                    self.note_first_start(stage, now);
                }
            }
            EventKind::TaskEnd { stage, task } => {
                if let TaskState::Running { start, busy_until, .. } = self.tasks[stage][task] {
                    self.tasks[stage][task] = TaskState::Done { start, end: busy_until };
                }
                self.free_slots += 1;
                self.ends_left -= 1;
                self.release_chunks(stage, now);
            }
        }
    }

    /// Claim slots for pending tasks, producers (lower stage ids) first.
    fn dispatch(&mut self, now: f64) {
        while self.free_slots > 0 {
            let mut picked = None;
            for s in 0..self.stages.len() {
                if self.ready[s] && !self.pending[s].is_empty() {
                    picked = Some(s);
                    break;
                }
            }
            let Some(s) = picked else { break };
            let t = self.pending[s].pop_front().expect("non-empty pending");
            self.free_slots -= 1;
            self.start_task(s, t, now);
        }
    }
}

/// Event-driven pipelined schedule (see module docs for the model).
fn schedule_pipelined(stages: &[StageSpec], slots: usize) -> ScheduleOut {
    let n = stages.len();
    if n == 0 {
        return ScheduleOut { latency_s: 0.0, stages: Vec::new() };
    }
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut producer_tasks = vec![0usize; n];
    for s in stages {
        for &p in &s.parents {
            children[p as usize].push(s.id as usize);
            producer_tasks[s.id as usize] += stages[p as usize].task_durations.len();
        }
    }
    let mut sim = Sim {
        stages,
        producer_tasks,
        released: vec![0; n],
        children,
        ready: vec![false; n],
        first_start: vec![None; n],
        parents_started: vec![0; n],
        pending: stages
            .iter()
            .map(|s| (0..s.task_durations.len()).collect())
            .collect(),
        tasks: stages
            .iter()
            .map(|s| vec![TaskState::NotStarted; s.task_durations.len()])
            .collect(),
        free_slots: slots,
        events: BinaryHeap::new(),
        seq: 0,
        ends_left: stages.iter().map(|s| s.task_durations.len()).sum(),
    };

    // Root stages become ready once their driver overhead is paid.
    for s in stages {
        if s.parents.is_empty() {
            sim.push(s.overhead_s, EventKind::StageReady { stage: s.id as usize });
        }
    }

    let mut latency = 0.0f64;
    while let Some(ev) = sim.events.pop() {
        let now = ev.time;
        latency = latency.max(now);
        sim.handle(ev);
        // Drain every simultaneous event before dispatching, so a
        // same-instant readiness/completion can't lose a slot to a
        // lower-priority task.
        while sim.events.peek().map(|e| e.time == now).unwrap_or(false) {
            let ev = sim.events.pop().expect("peeked");
            sim.handle(ev);
        }
        sim.dispatch(now);
    }
    assert_eq!(sim.ends_left, 0, "pipelined schedule deadlocked");

    let windows = stages
        .iter()
        .map(|s| {
            let i = s.id as usize;
            let tasks: Vec<(f64, f64)> = sim.tasks[i]
                .iter()
                .map(|t| match t {
                    TaskState::Done { start, end } => (*start, *end),
                    other => unreachable!("unfinished task {other:?}"),
                })
                .collect();
            let start = sim.first_start[i].unwrap_or(0.0);
            let end = tasks.iter().fold(start, |acc, (_, e)| acc.max(*e));
            StageWindow { id: s.id, start, end, tasks }
        })
        .collect();
    ScheduleOut { latency_s: latency, stages: windows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simtime::makespan;
    use crate::util::propcheck::forall;

    fn chain(stage_tasks: &[Vec<f64>], overhead: f64) -> Vec<StageSpec> {
        stage_tasks
            .iter()
            .enumerate()
            .map(|(i, d)| StageSpec {
                id: i as u32,
                parents: if i == 0 { Vec::new() } else { vec![(i - 1) as u32] },
                task_durations: d.clone(),
                overhead_s: overhead,
            })
            .collect()
    }

    #[test]
    fn barrier_reproduces_sigma_makespan() {
        let stages = chain(&[vec![3.0, 1.0, 2.0, 2.0], vec![1.0, 1.0]], 0.5);
        let out = schedule_dag(&stages, 2, ScheduleMode::Barrier);
        let expect: f64 = stages
            .iter()
            .map(|s| makespan(&s.task_durations, 2) + s.overhead_s)
            .sum();
        assert!((out.latency_s - expect).abs() < 1e-12, "{} vs {expect}", out.latency_s);
        // Windows are contiguous.
        assert!((out.stages[0].end - out.stages[1].start).abs() < 1e-12);
    }

    #[test]
    fn pipelined_overlaps_two_stage_chain() {
        // Staggered maps (one straggler) + 2 reduces: the short maps'
        // flushes are drained while the straggler still runs.
        let stages = chain(&[vec![4.0, 1.0, 1.0, 1.0], vec![2.0; 2]], 0.0);
        let barrier = schedule_dag(&stages, 4, ScheduleMode::Barrier);
        let pipe = schedule_dag(&stages, 4, ScheduleMode::Pipelined);
        assert!(
            pipe.latency_s < barrier.latency_s - 1e-9,
            "pipelined {} must beat barrier {}",
            pipe.latency_s,
            barrier.latency_s
        );
        // Reducers started while maps still ran.
        assert!(pipe.stages[1].overlap_s(&pipe.stages[0]) > 0.0);
        // But a reducer cannot finish before the last map flush.
        let maps_done = pipe.stages[0].end;
        for (_, end) in &pipe.stages[1].tasks {
            assert!(*end >= maps_done - 1e-9, "reduce ended {end} before maps {maps_done}");
        }
    }

    #[test]
    fn pipelined_single_stage_matches_barrier_minus_overhead_position() {
        // One stage: same makespan either way (overhead before vs after
        // does not change the total).
        let stages = chain(&[vec![2.0, 3.0, 1.0]], 0.25);
        let b = schedule_dag(&stages, 2, ScheduleMode::Barrier);
        let p = schedule_dag(&stages, 2, ScheduleMode::Pipelined);
        assert!((b.latency_s - p.latency_s).abs() < 1e-12, "{} vs {}", b.latency_s, p.latency_s);
    }

    #[test]
    fn pipelined_respects_slot_limit() {
        let stages = chain(&[vec![1.0; 6], vec![1.0; 3]], 0.0);
        let out = schedule_dag(&stages, 2, ScheduleMode::Pipelined);
        // Collect all spans and check concurrency never exceeds 2: at any
        // task start, count overlapping spans.
        let mut spans: Vec<(f64, f64)> = Vec::new();
        for w in &out.stages {
            spans.extend(w.tasks.iter().copied());
        }
        for &(s, _) in &spans {
            let live = spans.iter().filter(|&&(a, b)| a <= s + 1e-12 && b > s + 1e-12).count();
            assert!(live <= 2, "{live} tasks live at {s}");
        }
    }

    #[test]
    fn multi_parent_stage_waits_for_all_parents() {
        // Two roots with very different lengths; sink needs both started.
        let stages = vec![
            StageSpec { id: 0, parents: vec![], task_durations: vec![10.0], overhead_s: 0.0 },
            StageSpec { id: 1, parents: vec![], task_durations: vec![1.0], overhead_s: 0.0 },
            StageSpec {
                id: 2,
                parents: vec![0, 1],
                task_durations: vec![2.0, 2.0],
                overhead_s: 0.0,
            },
        ];
        let out = schedule_dag(&stages, 8, ScheduleMode::Pipelined);
        // Sink tasks cannot end before the slow root's only task ends
        // (its chunk arrives at t=10).
        for (_, end) in &out.stages[2].tasks {
            assert!(*end >= 10.0 - 1e-9, "sink finished at {end} before slow parent");
        }
        // But they started long before that (pipelined launch).
        assert!(out.stages[2].start < 1.0 + 1e-9, "sink started at {}", out.stages[2].start);
        // And the whole DAG beats the serial barrier.
        let b = schedule_dag(&stages, 8, ScheduleMode::Barrier);
        assert!(out.latency_s < b.latency_s - 1e-9);
    }

    #[test]
    fn producers_keep_dispatch_priority() {
        // 1 slot: the reducer must not grab the slot while maps pend.
        let stages = chain(&[vec![2.0, 2.0], vec![1.0]], 0.0);
        let out = schedule_dag(&stages, 1, ScheduleMode::Pipelined);
        let map_spans = &out.stages[0].tasks;
        let red_span = out.stages[1].tasks[0];
        assert!(red_span.0 >= map_spans[1].0, "reduce started before last map");
        // Serial on one slot: total = 2 + 2 + 1.
        assert!((out.latency_s - 5.0).abs() < 1e-9, "{}", out.latency_s);
    }

    #[test]
    fn empty_stage_does_not_deadlock() {
        let stages = vec![
            StageSpec { id: 0, parents: vec![], task_durations: vec![], overhead_s: 0.1 },
            StageSpec { id: 1, parents: vec![0], task_durations: vec![1.0], overhead_s: 0.1 },
        ];
        let out = schedule_dag(&stages, 2, ScheduleMode::Pipelined);
        assert!(out.latency_s > 1.0, "{}", out.latency_s);
        assert_eq!(out.stages[1].tasks.len(), 1);
    }

    #[test]
    fn prop_pipelined_never_slower_than_barrier_on_two_level_dags() {
        // Random two-level DAGs (N roots feeding one sink): pipelining
        // must never lose to the serial barrier. On single-root chains
        // the event clock wins outright; on multi-root DAGs with skewed
        // ready times the serial-fallback guard is what keeps this true
        // (greedy non-preemptive overlap alone loses ~0.01% of cases).
        forall("pipelined-le-barrier", 150, |g| {
            let slots = g.usize(7) + 1;
            let roots = g.usize(3) + 1;
            let mut stages = Vec::new();
            for r in 0..roots {
                let d = g.vec(6, |g| g.f64(0.1, 5.0));
                stages.push(StageSpec {
                    id: r as u32,
                    parents: Vec::new(),
                    task_durations: if d.is_empty() { vec![1.0] } else { d },
                    overhead_s: g.f64(0.0, 0.5),
                });
            }
            let sink_tasks = g.usize(5) + 1;
            stages.push(StageSpec {
                id: roots as u32,
                parents: (0..roots as u32).collect(),
                task_durations: (0..sink_tasks).map(|_| g.f64(0.1, 3.0)).collect(),
                overhead_s: g.f64(0.0, 0.5),
            });
            let b = schedule_dag(&stages, slots, ScheduleMode::Barrier);
            let p = schedule_dag(&stages, slots, ScheduleMode::Pipelined);
            if p.latency_s > b.latency_s + 1e-9 {
                return Err(format!(
                    "pipelined {} > barrier {} (slots {slots}, roots {roots})",
                    p.latency_s, b.latency_s
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_pipelined_respects_lower_bounds() {
        // Latency can never undercut (a) any single stage's own makespan
        // requirement total/slots, (b) the longest task + its stage
        // readiness, (c) total work / slots.
        forall("pipelined-lower-bounds", 150, |g| {
            let slots = g.usize(7) + 1;
            let d0 = g.vec(8, |g| g.f64(0.1, 4.0));
            let d1 = g.vec(4, |g| g.f64(0.1, 4.0));
            if d0.is_empty() {
                return Ok(());
            }
            let stages = chain(&[d0.clone(), d1.clone()], 0.0);
            let p = schedule_dag(&stages, slots, ScheduleMode::Pipelined);
            let total: f64 = d0.iter().chain(d1.iter()).sum();
            let lower = total / slots as f64;
            if p.latency_s < lower - 1e-9 {
                return Err(format!("latency {} under work bound {lower}", p.latency_s));
            }
            // Reducers cannot finish before all maps finish.
            let maps_end = stages_end(&p, 0);
            if !d1.is_empty() && stages_end(&p, 1) < maps_end - 1e-9 {
                return Err("reduce stage ended before maps".into());
            }
            Ok(())
        });
    }

    fn stages_end(out: &ScheduleOut, id: usize) -> f64 {
        out.stages[id].end
    }
}
