//! Cross-stage virtual-time scheduling: an event-driven global clock
//! that places every task **attempt** of a plan's stage DAG onto the
//! shared Lambda-concurrency (or cluster-core) slots.
//!
//! Two modes, selected per run:
//!
//! * **Barrier** — the original serial driver's model, kept for the
//!   Qubole-style S3 shuffle backend and as the exact-paper-reproduction
//!   mode: stages execute strictly one after another; stage latency is
//!   its task makespan plus driver overhead, and plan latency is the
//!   sum. This reproduces the pre-DAG Σ-makespan numbers exactly.
//! * **Pipelined** — the paper's SQS semantics (§III-A): a stage's tasks
//!   become launchable as soon as *every parent has started producing*
//!   (reduce tasks long-poll their queues concurrently with map
//!   flushes). A consumer task's work is modelled as arriving in equal
//!   chunks, one per producer task, released when that producer
//!   finishes; the consumer occupies its slot while long-polling and
//!   completes once it has processed every chunk. Producer stages get
//!   strict dispatch priority (lower stage id first), so pipelining
//!   never starves the tasks that feed it. Because non-preemptive
//!   overlap scheduling has classical anomalies on multi-root DAGs, the
//!   scheduler prices the serial plan too and falls back to it whenever
//!   overlap would lose — pipelined mode never schedules worse than
//!   barrier mode.
//!
//! # The attempt model and the live tail signal
//!
//! Tasks are no longer single-shot: with a [`SpecPolicy`], the event
//! clock watches each stage's *tail*. Once `quantile` of a stage's
//! tasks have committed, any task still running past `multiplier` × the
//! median committed span raises the tail signal and the clock emits a
//! **backup-launch event** for it (classic MapReduce/Spark backup-task
//! speculation). A backup attempt queues for a slot *behind* all
//! primary work, runs the task's re-measured backup duration, and the
//! task commits when its **first** attempt finishes — first-commit-wins.
//! The losing attempt is cancelled the instant the winner commits: its
//! slot frees immediately, but the host still billed its full runtime
//! (Lambda has no mid-flight cancellation; the §VI dedup machinery is
//! what makes the loser's duplicate output harmless).
//!
//! Two uses of the same machinery:
//! * [`tail_signal`] — decide-only, single stage: which tasks *would*
//!   get backups, and when. The driver uses this right after a stage's
//!   primary attempts finish (so backup attempts can actually re-execute
//!   while the stage's shuffle queues still exist).
//! * [`schedule_dag_spec`] — model mode: place primaries *and* measured
//!   backup attempts on the global clock, deriving launch times, the
//!   winner, loser cancellation, and occupied-but-idle (long-polling)
//!   time per attempt for the pipelined cost model.
//!
//! The driver runs attempts on real threads in topological order (the
//! simulated queues hold data only after producers flush); this module
//! is where the *virtual* overlap between stages — and the race between
//! attempts — is computed from the per-attempt durations those runs
//! measured. With no policy, the schedule is byte-identical to the
//! pre-speculation scheduler (`flint.speculation = off` pins this).

use crate::simtime::makespan::makespan_assignments;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

const EPS: f64 = 1e-12;

/// How stages are allowed to overlap in virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleMode {
    /// Serial stages with a hard barrier between them (Σ makespans).
    Barrier,
    /// Dependency-aware overlap: consumers launch once all parents have
    /// started producing.
    Pipelined,
}

impl std::str::FromStr for ScheduleMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "barrier" => Ok(ScheduleMode::Barrier),
            "pipelined" => Ok(ScheduleMode::Pipelined),
            other => Err(format!("unknown scheduler `{other}` (want barrier|pipelined)")),
        }
    }
}

impl ScheduleMode {
    pub fn name(&self) -> &'static str {
        match self {
            ScheduleMode::Barrier => "barrier",
            ScheduleMode::Pipelined => "pipelined",
        }
    }
}

/// Speculative-execution policy for the clock's tail signal (see module
/// docs). `multiplier` × the median committed span is the threshold;
/// `quantile` is the fraction of a stage's tasks that must commit before
/// the median is trusted (1.0 disables the signal entirely).
#[derive(Debug, Clone, Copy)]
pub struct SpecPolicy {
    pub multiplier: f64,
    pub quantile: f64,
}

/// One backup-launch decision from the decide-only tail signal.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpecDecision {
    /// Task index within the stage (submission order).
    pub task: usize,
    /// When the task's primary attempt started on the stage-local clock.
    pub primary_start: f64,
    /// When the tail signal fired (the backup-launch event time).
    pub launch_at: f64,
}

/// One stage's scheduling inputs: the DAG edge structure plus the
/// measured virtual duration of each attempt.
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub id: u32,
    /// Parent stage ids (must be < `id`; stages arrive topo-ordered).
    pub parents: Vec<u32>,
    /// Virtual duration of each task's primary attempt, in submission
    /// order.
    pub task_durations: Vec<f64>,
    /// Measured duration of each task's speculative backup attempt, when
    /// one was launched (empty = no backups for this stage). Only
    /// consulted under a [`SpecPolicy`].
    pub backups: Vec<Option<f64>>,
    /// Driver-side overhead for this stage (task serialization, queue
    /// management). Charged serially after the stage in barrier mode —
    /// matching the original Σ model — and before its first task can
    /// launch in pipelined mode.
    pub overhead_s: f64,
}

impl StageSpec {
    fn backup_of(&self, task: usize) -> Option<f64> {
        self.backups.get(task).copied().flatten()
    }
}

/// A launched backup attempt's span on the clock.
#[derive(Debug, Clone, Copy)]
pub struct BackupWindow {
    pub task: usize,
    pub start: f64,
    /// Commit time if it won, cancellation time if it lost.
    pub end: f64,
    pub won: bool,
}

/// Where one stage landed on the virtual clock.
#[derive(Debug, Clone)]
pub struct StageWindow {
    pub id: u32,
    /// When the stage became runnable / its first task started.
    pub start: f64,
    /// When its last task finished (barrier: plus driver overhead).
    pub end: f64,
    /// Per-task `(start, commit)` spans, in submission order. A task's
    /// span closes at its *first* committing attempt.
    pub tasks: Vec<(f64, f64)>,
    /// Speculative backup attempts launched for this stage's tasks.
    pub backups: Vec<BackupWindow>,
}

impl StageWindow {
    /// Seconds this window overlaps another (0 when disjoint).
    pub fn overlap_s(&self, other: &StageWindow) -> f64 {
        (self.end.min(other.end) - self.start.max(other.start)).max(0.0)
    }
}

/// The scheduled plan.
#[derive(Debug, Clone)]
pub struct ScheduleOut {
    /// End-to-end virtual latency (time the last task/overhead ends).
    pub latency_s: f64,
    pub stages: Vec<StageWindow>,
    /// Occupied-but-idle seconds summed over all attempts: the time a
    /// long-polling consumer held its slot (and its Lambda) while
    /// waiting for producer chunks. Zero in barrier mode. The pipelined
    /// cost model bills these GB-seconds.
    pub idle_s: f64,
    /// Backup attempts the clock launched.
    pub spec_launches: u64,
    /// Backup attempts that committed before their primary.
    pub spec_wins: u64,
}

/// Schedule a stage DAG onto `slots` shared concurrency slots, with no
/// speculation — byte-identical to the pre-attempt-model scheduler.
pub fn schedule_dag(stages: &[StageSpec], slots: usize, mode: ScheduleMode) -> ScheduleOut {
    schedule_dag_spec(stages, slots, mode, None)
}

/// Schedule a stage DAG onto `slots` shared concurrency slots.
///
/// `stages` must be topologically ordered with dense ids (`id == index`,
/// `parents[i] < id`) — the invariant `PhysicalPlan::validate` checks.
/// With a [`SpecPolicy`], stages' measured `backups` are placed by the
/// live tail signal (see module docs); with `None` the backups are
/// ignored and the schedule is byte-identical to [`schedule_dag`].
pub fn schedule_dag_spec(
    stages: &[StageSpec],
    slots: usize,
    mode: ScheduleMode,
    policy: Option<&SpecPolicy>,
) -> ScheduleOut {
    assert!(slots > 0, "schedule_dag needs at least one slot");
    for (i, s) in stages.iter().enumerate() {
        assert_eq!(s.id as usize, i, "stage ids must be dense and ordered");
        for &p in &s.parents {
            assert!(p < s.id, "stage {} parent {p} breaks topo order", s.id);
        }
        assert!(
            s.backups.is_empty() || s.backups.len() == s.task_durations.len(),
            "stage {}: backups must be empty or one slot per task",
            s.id
        );
    }
    match mode {
        ScheduleMode::Barrier => match policy {
            None => schedule_barrier(stages, slots),
            Some(p) => schedule_barrier_spec(stages, slots, p),
        },
        ScheduleMode::Pipelined => {
            let sim = simulate(stages, slots, policy, false).out;
            // Non-preemptive overlap scheduling has classical anomalies:
            // with several root stages whose ready times differ, a
            // later-ready but lower-priority stage can seize slots and
            // delay a critical producer, losing to the serial plan
            // (measured: rare, worst ~4% on random two-level DAGs). The
            // scheduler prices both plans and keeps the serial one
            // whenever overlap would lose, so pipelined mode is never
            // worse than barrier mode by construction.
            let serial = match policy {
                None => schedule_barrier(stages, slots),
                Some(p) => schedule_barrier_spec(stages, slots, p),
            };
            if sim.latency_s <= serial.latency_s {
                sim
            } else {
                serial
            }
        }
    }
}

/// Decide-only tail signal over one stage's primary durations: which
/// tasks would get a backup attempt, and when the backup-launch event
/// fires on the stage-local event clock. The driver calls this right
/// after a stage's primary attempts complete, then actually re-executes
/// the decided tasks while the stage's shuffle queues still exist.
pub fn tail_signal(durations: &[f64], slots: usize, policy: &SpecPolicy) -> Vec<SpecDecision> {
    if durations.len() < 2 {
        return Vec::new();
    }
    let stage = [StageSpec {
        id: 0,
        parents: Vec::new(),
        task_durations: durations.to_vec(),
        backups: Vec::new(),
        overhead_s: 0.0,
    }];
    simulate(&stage, slots, Some(policy), true).decisions
}

// ---------------------------------------------------------------------
// Multi-query service scheduling (shared slot pool)
// ---------------------------------------------------------------------

/// How the service arbitrates the shared slot pool between admitted
/// queries (`flint.service.policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServicePolicy {
    /// Strict arrival order, one query at a time: each query gets the
    /// whole pool and runs exactly its solo schedule (including the
    /// pipelined serial-fallback guard); the next starts when it ends.
    Fifo,
    /// Max-min fair slot sharing: every free slot goes to the admitted
    /// query currently holding the fewest slots.
    Fair,
    /// Weighted fair sharing: slots go to the query minimizing
    /// held/weight, so a weight-2 tenant holds twice a weight-1
    /// tenant's share under saturation.
    Weighted,
}

impl std::str::FromStr for ServicePolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "fifo" => Ok(ServicePolicy::Fifo),
            "fair" => Ok(ServicePolicy::Fair),
            "weighted" => Ok(ServicePolicy::Weighted),
            other => Err(format!("unknown service policy `{other}` (want fifo|fair|weighted)")),
        }
    }
}

impl ServicePolicy {
    pub fn name(&self) -> &'static str {
        match self {
            ServicePolicy::Fifo => "fifo",
            ServicePolicy::Fair => "fair",
            ServicePolicy::Weighted => "weighted",
        }
    }
}

/// One admitted query's scheduling inputs.
#[derive(Debug, Clone)]
pub struct ServiceQuerySpec {
    /// The query's stage DAG (same invariants as [`schedule_dag_spec`]:
    /// topo order, dense query-local ids).
    pub stages: Vec<StageSpec>,
    /// When the query was admitted on the service clock.
    pub arrival_s: f64,
    /// Fair-share weight (> 0; only consulted under
    /// [`ServicePolicy::Weighted`]).
    pub weight: f64,
    /// Per-tenant concurrency quota (`flint.service.max_slots.<tenant>`):
    /// a hard cap on the slots this query may hold at once, primaries
    /// and backups combined. `None` = uncapped (the pool is the limit).
    /// The cap only defers dispatch — capped work runs as the job's own
    /// attempts finish, so it can never deadlock (a job at cap always
    /// has running attempts about to free its slots).
    pub quota: Option<usize>,
}

/// Where one query landed on the shared service clock.
#[derive(Debug, Clone, Copy)]
pub struct QueryWindow {
    /// Index into the submitted query list.
    pub query: usize,
    pub arrival_s: f64,
    /// First task launch (`arrival_s` + overhead when the pool had room;
    /// later when the query waited for slots).
    pub start_s: f64,
    /// When the query's last task committed.
    pub end_s: f64,
    /// End-to-end latency including queue wait: `end_s - arrival_s`.
    pub latency_s: f64,
    /// Occupied-but-idle (long-polling) seconds of this query's attempts.
    pub idle_s: f64,
    pub spec_launches: u64,
    pub spec_wins: u64,
}

/// The scheduled multi-query workload.
#[derive(Debug, Clone)]
pub struct ServiceScheduleOut {
    /// When the last admitted query finished (aggregate makespan).
    pub makespan_s: f64,
    /// Total occupied-but-idle seconds across all queries.
    pub idle_s: f64,
    /// Per-query windows, indexed by submission order.
    pub queries: Vec<QueryWindow>,
}

/// Schedule many queries' stage DAGs onto one shared pool of `slots`.
///
/// Under [`ServicePolicy::Fifo`] queries run strictly one at a time in
/// arrival order — each one's schedule is exactly its solo
/// [`schedule_dag_spec`] run, offset on the clock. Under
/// `Fair`/`Weighted` all admitted queries share one event clock: every
/// free slot is granted to the query minimizing held-slots/weight
/// (ties: earlier arrival, then submission order), with producers
/// keeping their dispatch priority *within* each query and backups
/// queueing behind all primary work, exactly like the single-query
/// clock. Barrier mode serializes each query's own stages
/// (commit-ordered, the solo Σ model) while still interleaving queries.
pub fn schedule_service(
    queries: &[ServiceQuerySpec],
    slots: usize,
    mode: ScheduleMode,
    policy: ServicePolicy,
    spec: Option<&SpecPolicy>,
) -> ServiceScheduleOut {
    assert!(slots > 0, "schedule_service needs at least one slot");
    for q in queries {
        assert!(q.weight > 0.0 && q.weight.is_finite(), "query weight must be positive");
        assert!(q.arrival_s >= 0.0, "query arrival must be non-negative");
        assert!(q.quota != Some(0), "a zero quota would starve the query forever");
        for (i, s) in q.stages.iter().enumerate() {
            assert_eq!(s.id as usize, i, "stage ids must be dense and ordered");
            for &p in &s.parents {
                assert!(p < s.id, "stage {} parent {p} breaks topo order", s.id);
            }
            assert!(
                s.backups.is_empty() || s.backups.len() == s.task_durations.len(),
                "stage {}: backups must be empty or one slot per task",
                s.id
            );
        }
    }
    match policy {
        ServicePolicy::Fifo => schedule_service_fifo(queries, slots, mode, spec),
        ServicePolicy::Fair | ServicePolicy::Weighted => simulate_service(
            queries,
            slots,
            mode == ScheduleMode::Barrier,
            policy == ServicePolicy::Weighted,
            spec,
        ),
    }
}

/// FIFO: strictly serial back-to-back solo runs in arrival order.
fn schedule_service_fifo(
    queries: &[ServiceQuerySpec],
    slots: usize,
    mode: ScheduleMode,
    spec: Option<&SpecPolicy>,
) -> ServiceScheduleOut {
    let mut order: Vec<usize> = (0..queries.len()).collect();
    order.sort_by(|&a, &b| {
        queries[a]
            .arrival_s
            .total_cmp(&queries[b].arrival_s)
            .then(a.cmp(&b))
    });
    let mut windows: Vec<Option<QueryWindow>> = vec![None; queries.len()];
    let mut clock = 0.0f64;
    let mut idle_s = 0.0;
    for qi in order {
        let q = &queries[qi];
        let start = clock.max(q.arrival_s);
        // Even running alone, a quota'd tenant never holds more than its
        // cap: the solo schedule sees a pool shrunk to the quota.
        let q_slots = q.quota.map_or(slots, |n| n.min(slots));
        let solo = schedule_dag_spec(&q.stages, q_slots, mode, spec);
        let end = start + solo.latency_s;
        idle_s += solo.idle_s;
        windows[qi] = Some(QueryWindow {
            query: qi,
            arrival_s: q.arrival_s,
            start_s: start,
            end_s: end,
            latency_s: end - q.arrival_s,
            idle_s: solo.idle_s,
            spec_launches: solo.spec_launches,
            spec_wins: solo.spec_wins,
        });
        clock = end;
    }
    ServiceScheduleOut {
        makespan_s: clock,
        idle_s,
        queries: windows
            .into_iter()
            .map(|w| w.expect("one window per query"))
            .collect(),
    }
}

/// Serial stage-by-stage execution: exactly the original driver's
/// Σ(makespan + overhead) model, expressed on the global clock.
fn schedule_barrier(stages: &[StageSpec], slots: usize) -> ScheduleOut {
    let mut clock = 0.0f64;
    let mut windows = Vec::with_capacity(stages.len());
    for s in stages {
        let (ms, spans) = makespan_assignments(&s.task_durations, slots);
        let start = clock;
        let end = start + ms + s.overhead_s;
        windows.push(StageWindow {
            id: s.id,
            start,
            end,
            tasks: spans.iter().map(|(a, b, _)| (start + a, start + b)).collect(),
            backups: Vec::new(),
        });
        clock = end;
    }
    ScheduleOut {
        latency_s: clock,
        stages: windows,
        idle_s: 0.0,
        spec_launches: 0,
        spec_wins: 0,
    }
}

/// Barrier mode with speculation: each stage independently runs the
/// speculative event clock (all of its input is on hand when the stage
/// starts, so it is a single-stage simulation), then stages are laid
/// end-to-end exactly like the plain Σ model.
fn schedule_barrier_spec(stages: &[StageSpec], slots: usize, policy: &SpecPolicy) -> ScheduleOut {
    let mut clock = 0.0f64;
    let mut windows = Vec::with_capacity(stages.len());
    let mut idle_s = 0.0;
    let mut spec_launches = 0;
    let mut spec_wins = 0;
    for s in stages {
        let single = [StageSpec {
            id: 0,
            parents: Vec::new(),
            task_durations: s.task_durations.clone(),
            backups: s.backups.clone(),
            overhead_s: 0.0,
        }];
        let run = simulate(&single, slots, Some(policy), false).out;
        let start = clock;
        let end = start + run.latency_s + s.overhead_s;
        let w = &run.stages[0];
        windows.push(StageWindow {
            id: s.id,
            start,
            end,
            tasks: w.tasks.iter().map(|(a, b)| (start + a, start + b)).collect(),
            backups: w
                .backups
                .iter()
                .map(|b| BackupWindow { start: start + b.start, end: start + b.end, ..*b })
                .collect(),
        });
        idle_s += run.idle_s;
        spec_launches += run.spec_launches;
        spec_wins += run.spec_wins;
        clock = end;
    }
    ScheduleOut { latency_s: clock, stages: windows, idle_s, spec_launches, spec_wins }
}

// ---------------------------------------------------------------------
// Event-driven simulation (pipelined mode + all speculation)
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
enum EventKind {
    /// Stage becomes launchable (overhead paid, parents started).
    StageReady { stage: usize },
    /// A primary attempt finished; commits the task unless cancelled.
    TaskEnd { stage: usize, task: usize },
    /// A backup attempt finished; commits the task unless cancelled.
    BackupEnd { stage: usize, task: usize },
    /// Re-evaluate the tail signal for one still-running task.
    SpecCheck { stage: usize, task: usize },
}

#[derive(Debug, PartialEq)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; reverse so the *earliest* event pops
        // first, with insertion order as the deterministic tie-break.
        self.time
            .total_cmp(&other.time)
            .then(self.seq.cmp(&other.seq))
            .reverse()
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// One attempt's lifecycle on the clock. A task holds one primary and at
/// most one backup attempt; the first attempt to finish commits the task
/// and the other is `Cancelled` at that instant (slot freed, span
/// recorded — the host still billed its full runtime).
#[derive(Debug, Clone, Copy)]
enum AttemptState {
    NotStarted,
    /// Long-polling/processing: `busy_until` is when already-released
    /// work finishes; `remaining` producer tasks still owe a chunk.
    Running { start: f64, busy_until: f64, remaining: usize, chunk_w: f64 },
    Done { start: f64, end: f64 },
    Cancelled { start: f64, end: f64 },
}

impl AttemptState {
    fn running_start(&self) -> Option<f64> {
        match self {
            AttemptState::Running { start, .. } => Some(*start),
            _ => None,
        }
    }
}

struct SimRun {
    out: ScheduleOut,
    decisions: Vec<SpecDecision>,
}

/// Multi-query context threaded through the event clock by
/// [`schedule_service`]: which job each flattened stage belongs to,
/// per-job weights/arrivals, and the slot-share ledger the fair
/// dispatcher consults. `None` on every single-query entry point — the
/// solo schedule stays byte-identical to the pre-service scheduler by
/// construction (all service branches are guarded on this option).
struct SvcCtx {
    /// Flattened stage index → job (query) index.
    job: Vec<usize>,
    /// Fair-share weight per job (all 1.0 under [`ServicePolicy::Fair`]).
    weight: Vec<f64>,
    /// Admission time per job.
    arrival: Vec<f64>,
    /// Concurrency quota per job (`usize::MAX` = uncapped): dispatch
    /// never grants a job a slot that would push `held` past it.
    quota: Vec<usize>,
    /// Serialize each job's stages (barrier mode): a stage becomes ready
    /// only after every earlier stage of its job fully committed.
    barrier: bool,
    /// Slots currently held per job (primaries + backups).
    held: Vec<usize>,
    /// Uncommitted tasks per stage (drives barrier advancement).
    tasks_left: Vec<usize>,
    /// Flattened stage ids per job, in id order (the barrier pipeline).
    stage_seq: Vec<Vec<usize>>,
    /// Per-job latest event time (query end on the shared clock).
    job_end: Vec<f64>,
    /// Per-job first task launch.
    job_start: Vec<Option<f64>>,
}

struct Sim<'a> {
    stages: &'a [StageSpec],
    policy: Option<&'a SpecPolicy>,
    /// Decide-only mode: record tail-signal decisions, launch nothing.
    decide_only: bool,
    /// Total producer tasks feeding each stage (sum over parents).
    producer_tasks: Vec<usize>,
    /// Producer tasks already finished, per consumer stage.
    released: Vec<usize>,
    children: Vec<Vec<usize>>,
    ready: Vec<bool>,
    first_start: Vec<Option<f64>>,
    /// Parents that have started producing, per stage.
    parents_started: Vec<usize>,
    pending: Vec<VecDeque<usize>>,
    primary: Vec<Vec<AttemptState>>,
    backup: Vec<Vec<AttemptState>>,
    /// Tail signal already fired for this task (decision recorded or
    /// backup queued) — it fires at most once per task.
    triggered: Vec<Vec<bool>>,
    /// Backups waiting for a slot (behind all primary work).
    spec_pending: VecDeque<(usize, usize)>,
    /// Committed task spans per stage, kept sorted (the tail signal's
    /// median input).
    done_spans: Vec<Vec<f64>>,
    /// Last SpecCheck time booked per task (exact-duplicate dedup).
    check_booked: Vec<Vec<f64>>,
    decisions: Vec<SpecDecision>,
    free_slots: usize,
    events: BinaryHeap<Event>,
    seq: u64,
    ends_left: usize,
    latency: f64,
    spec_launches: u64,
    spec_wins: u64,
    /// Multi-query service context; `None` for all solo schedules.
    svc: Option<SvcCtx>,
}

impl<'a> Sim<'a> {
    fn push(&mut self, time: f64, kind: EventKind) {
        self.seq += 1;
        self.events.push(Event { time, seq: self.seq, kind });
    }

    fn barrier_svc(&self) -> bool {
        self.svc.as_ref().map(|s| s.barrier).unwrap_or(false)
    }

    /// Claim a slot for an attempt of `stage` (service: charge the job's
    /// share ledger).
    fn claim(&mut self, stage: usize) {
        self.free_slots -= 1;
        if let Some(svc) = &mut self.svc {
            svc.held[svc.job[stage]] += 1;
        }
    }

    /// Free a slot held by an attempt of `stage`.
    fn unclaim(&mut self, stage: usize) {
        self.free_slots += 1;
        if let Some(svc) = &mut self.svc {
            svc.held[svc.job[stage]] -= 1;
        }
    }

    /// Record a clock event on `stage`'s job (per-query end time).
    fn note_job_event(&mut self, stage: usize, now: f64) {
        if let Some(svc) = &mut self.svc {
            let j = svc.job[stage];
            svc.job_end[j] = svc.job_end[j].max(now);
        }
    }

    /// Barrier-mode service pipeline: `stage` fully committed — ready
    /// its job's next stage (its own driver overhead charged serially,
    /// exactly like the solo Σ model).
    fn advance_barrier_job(&mut self, stage: usize, now: f64) {
        let svc = self.svc.as_ref().expect("barrier advance without service ctx");
        let j = svc.job[stage];
        let seq = &svc.stage_seq[j];
        let pos = seq.iter().position(|&s| s == stage).expect("stage in its own job");
        if let Some(&next) = seq.get(pos + 1) {
            self.push(now + self.stages[next].overhead_s, EventKind::StageReady { stage: next });
        }
    }

    /// Mark `stage` as having started producing at `now`, waking any
    /// child whose parents have now all started.
    // Index loops: the bodies need `&mut self` (event pushes), so
    // iterator-style traversal would hold a conflicting borrow.
    #[allow(clippy::needless_range_loop)]
    fn note_first_start(&mut self, stage: usize, now: f64) {
        if self.first_start[stage].is_some() {
            return;
        }
        self.first_start[stage] = Some(now);
        if self.barrier_svc() {
            // Barrier-mode service: readiness advances on full stage
            // commits (see `advance_barrier_job`), never on first starts.
            return;
        }
        for ci in 0..self.children[stage].len() {
            let child = self.children[stage][ci];
            self.parents_started[child] += 1;
            if self.parents_started[child] == self.stages[child].parents.len() {
                self.push(
                    now + self.stages[child].overhead_s,
                    EventKind::StageReady { stage: child },
                );
            }
        }
    }

    /// Start the primary attempt of task `t` of `stage` at `now` (a slot
    /// has been claimed).
    fn start_task(&mut self, stage: usize, t: usize, now: f64) {
        let d = self.stages[stage].task_durations[t];
        if let Some(svc) = &mut self.svc {
            let j = svc.job[stage];
            svc.job_start[j].get_or_insert(now);
        }
        self.note_first_start(stage, now);
        self.primary[stage][t] = self.start_attempt(stage, d, now);
        if let AttemptState::Running { busy_until, remaining: 0, .. } = self.primary[stage][t] {
            self.push(busy_until, EventKind::TaskEnd { stage, task: t });
        }
        // A task launched after its stage's quorum already committed
        // (late waves) gets its tail check booked at start — commits
        // alone would never re-examine it.
        if self.eligible(stage, t) {
            if let Some(th) = self.threshold(stage) {
                self.book_check(stage, t, now + th);
            }
        }
    }

    /// Start a backup attempt for task `t` of `stage` at `now`. The
    /// backup sees every chunk released so far immediately (the data is
    /// sitting in the queues) and long-polls for the rest.
    fn start_backup(&mut self, stage: usize, t: usize, now: f64) {
        let d = self.stages[stage].backup_of(t).expect("backup duration");
        self.spec_launches += 1;
        self.backup[stage][t] = self.start_attempt(stage, d, now);
        if let AttemptState::Running { busy_until, remaining: 0, .. } = self.backup[stage][t] {
            self.push(busy_until, EventKind::BackupEnd { stage, task: t });
        }
    }

    fn start_attempt(&mut self, stage: usize, d: f64, now: f64) -> AttemptState {
        let m = self.producer_tasks[stage];
        if m == 0 {
            // Source task: all input available immediately.
            AttemptState::Running { start: now, busy_until: now + d, remaining: 0, chunk_w: 0.0 }
        } else {
            let chunk_w = d / m as f64;
            let released = self.released[stage];
            AttemptState::Running {
                start: now,
                busy_until: now + released as f64 * chunk_w,
                remaining: m - released,
                chunk_w,
            }
        }
    }

    /// A producer task of `stage` committed at `now`: release one chunk
    /// to every attempt of every task of every child stage.
    #[allow(clippy::needless_range_loop)]
    fn release_chunks(&mut self, stage: usize, now: f64) {
        for ci in 0..self.children[stage].len() {
            let child = self.children[stage][ci];
            self.released[child] += 1;
            for t in 0..self.primary[child].len() {
                if let Some(end) = advance_attempt(&mut self.primary[child][t], now) {
                    self.push(end, EventKind::TaskEnd { stage: child, task: t });
                }
                if let Some(end) = advance_attempt(&mut self.backup[child][t], now) {
                    self.push(end, EventKind::BackupEnd { stage: child, task: t });
                }
            }
        }
    }

    /// Shared commit bookkeeping once a task's first attempt finished.
    fn commit_task(&mut self, stage: usize, task: usize, start: f64, now: f64) {
        let _ = task;
        self.ends_left -= 1;
        self.latency = self.latency.max(now);
        self.note_job_event(stage, now);
        let mut advance = false;
        if let Some(svc) = &mut self.svc {
            svc.tasks_left[stage] -= 1;
            advance = svc.barrier && svc.tasks_left[stage] == 0;
        }
        if advance {
            self.advance_barrier_job(stage, now);
        }
        self.release_chunks(stage, now);
        // Sorted insertion keeps the median O(1) per threshold check
        // (spans are finite, so a plain `<=` partition is total).
        let span = now - start;
        let spans = &mut self.done_spans[stage];
        let pos = spans.partition_point(|&x| x <= span);
        spans.insert(pos, span);
        self.check_tail(stage, now);
    }

    /// The tail-signal threshold for `stage`, if the quorum has been
    /// reached: `multiplier` × median committed span.
    fn threshold(&self, stage: usize) -> Option<f64> {
        let policy = self.policy?;
        let n = self.stages[stage].task_durations.len();
        let done = self.done_spans[stage].len();
        if n < 2 || done >= n {
            return None;
        }
        let quorum = ((policy.quantile * n as f64).ceil() as usize).max(2);
        if done < quorum {
            return None;
        }
        // `done_spans` is maintained sorted by `commit_task`.
        let spans = &self.done_spans[stage];
        let median = if spans.len() % 2 == 1 {
            spans[spans.len() / 2]
        } else {
            0.5 * (spans[spans.len() / 2 - 1] + spans[spans.len() / 2])
        };
        let th = policy.multiplier * median;
        (th > 0.0).then_some(th)
    }

    fn eligible(&self, stage: usize, task: usize) -> bool {
        !self.triggered[stage][task]
            && (self.decide_only || self.stages[stage].backup_of(task).is_some())
    }

    /// Evaluate the tail signal for every running task of `stage`:
    /// trigger overdue ones now, book a [`EventKind::SpecCheck`] at the
    /// projected crossing time for the rest.
    #[allow(clippy::needless_range_loop)]
    fn check_tail(&mut self, stage: usize, now: f64) {
        let Some(th) = self.threshold(stage) else { return };
        for t in 0..self.primary[stage].len() {
            if !self.eligible(stage, t) {
                continue;
            }
            let Some(start) = self.primary[stage][t].running_start() else { continue };
            if now - start >= th - EPS {
                self.trigger(stage, t, start, now);
            } else {
                self.book_check(stage, t, start + th);
            }
        }
    }

    /// Book a tail check, suppressing exact duplicates: successive
    /// commits under an unchanged median would otherwise book an
    /// identical `start + threshold` check per commit. A duplicate
    /// fires as a pure no-op (trigger is idempotent, a re-book lands on
    /// the same time), so skipping it is behavior-identical while
    /// keeping the event queue linear in the common case.
    fn book_check(&mut self, stage: usize, task: usize, time: f64) {
        if self.check_booked[stage][task] == time {
            return;
        }
        self.check_booked[stage][task] = time;
        self.push(time, EventKind::SpecCheck { stage, task });
    }

    /// The tail signal fired for (stage, task): record the decision or
    /// queue the backup launch.
    fn trigger(&mut self, stage: usize, task: usize, start: f64, now: f64) {
        self.triggered[stage][task] = true;
        if self.decide_only {
            self.decisions
                .push(SpecDecision { task, primary_start: start, launch_at: now });
        } else {
            self.spec_pending.push_back((stage, task));
        }
    }

    fn handle(&mut self, ev: Event) {
        let now = ev.time;
        match ev.kind {
            EventKind::StageReady { stage } => {
                self.latency = self.latency.max(now);
                self.note_job_event(stage, now);
                self.ready[stage] = true;
                if self.stages[stage].task_durations.is_empty() {
                    // Degenerate empty stage: "starts producing" (and
                    // finishes) the moment it is ready. It contributes no
                    // producer tasks, so children wait on nothing from it.
                    self.note_first_start(stage, now);
                    if self.barrier_svc() {
                        // No tasks will commit, so the barrier pipeline
                        // falls straight through to the next stage.
                        self.advance_barrier_job(stage, now);
                    }
                }
            }
            EventKind::TaskEnd { stage, task } => {
                // Stale when the backup already committed this task.
                let AttemptState::Running { start, .. } = self.primary[stage][task] else {
                    return;
                };
                self.primary[stage][task] = AttemptState::Done { start, end: now };
                self.unclaim(stage);
                // First-commit-wins: a racing backup is cancelled at the
                // commit instant (slot freed, span closed).
                if let AttemptState::Running { start: bs, .. } = self.backup[stage][task] {
                    self.backup[stage][task] = AttemptState::Cancelled { start: bs, end: now };
                    self.unclaim(stage);
                }
                self.commit_task(stage, task, start, now);
            }
            EventKind::BackupEnd { stage, task } => {
                // Stale when the primary already committed this task.
                let AttemptState::Running { start: bs, .. } = self.backup[stage][task] else {
                    return;
                };
                self.backup[stage][task] = AttemptState::Done { start: bs, end: now };
                self.unclaim(stage);
                self.spec_wins += 1;
                // The primary is still running (otherwise this backup
                // would have been cancelled at the primary's commit).
                let AttemptState::Running { start, .. } = self.primary[stage][task] else {
                    unreachable!("backup finished for a task with no running primary")
                };
                self.primary[stage][task] = AttemptState::Cancelled { start, end: now };
                self.unclaim(stage);
                self.commit_task(stage, task, start, now);
            }
            EventKind::SpecCheck { stage, task } => {
                if !self.eligible(stage, task) {
                    return;
                }
                let Some(start) = self.primary[stage][task].running_start() else { return };
                // The median may have moved since this check was booked;
                // re-evaluate against the current threshold.
                let Some(th) = self.threshold(stage) else { return };
                if now - start >= th - EPS {
                    self.trigger(stage, task, start, now);
                } else {
                    self.book_check(stage, task, start + th);
                }
            }
        }
    }

    /// Claim slots for pending work: primaries first (producers — lower
    /// stage ids — before consumers), then queued backups. Backups never
    /// displace primary work. Under a service context the next slot goes
    /// to the *fairest* job first; within a job producers keep priority.
    fn dispatch(&mut self, now: f64) {
        while self.free_slots > 0 {
            let picked = match &self.svc {
                None => self.pick_solo(),
                Some(_) => self.pick_fair(),
            };
            let Some(s) = picked else { break };
            let t = self.pending[s].pop_front().expect("non-empty pending");
            self.claim(s);
            self.start_task(s, t, now);
        }
        let mut deferred: VecDeque<(usize, usize)> = VecDeque::new();
        while self.free_slots > 0 {
            // A queued backup whose primary committed while it waited is
            // moot — skip it without ever launching.
            let Some((s, t)) = self.next_live_backup() else { break };
            if self.quota_blocked(s) {
                // The job is at its concurrency cap: the backup keeps its
                // queue position and waits for one of the job's own
                // attempts to free a slot.
                deferred.push_back((s, t));
                continue;
            }
            self.claim(s);
            self.start_backup(s, t, now);
        }
        while let Some(e) = deferred.pop_back() {
            self.spec_pending.push_front(e);
        }
    }

    /// Would granting `stage`'s job one more slot exceed its quota?
    fn quota_blocked(&self, stage: usize) -> bool {
        match &self.svc {
            Some(svc) => {
                let j = svc.job[stage];
                svc.held[j] >= svc.quota[j]
            }
            None => false,
        }
    }

    /// Solo dispatch order: the lowest ready stage id with pending work.
    fn pick_solo(&self) -> Option<usize> {
        (0..self.stages.len()).find(|&s| self.ready[s] && !self.pending[s].is_empty())
    }

    /// Weighted-fair dispatch: among jobs with dispatchable work, the
    /// one with the smallest held/weight ratio wins the slot (ties:
    /// earlier arrival, then submission order — jobs are flattened in
    /// submission order, so the first candidate stage seen for a job is
    /// also its lowest stage id, preserving producer priority within
    /// the job).
    fn pick_fair(&self) -> Option<usize> {
        let svc = self.svc.as_ref().expect("fair pick without service ctx");
        let mut best: Option<(usize, usize)> = None; // (job, stage)
        for s in 0..self.stages.len() {
            if !self.ready[s] || self.pending[s].is_empty() {
                continue;
            }
            let j = svc.job[s];
            if svc.held[j] >= svc.quota[j] {
                continue; // at its per-tenant concurrency cap
            }
            let Some((bj, _)) = best else {
                best = Some((j, s));
                continue;
            };
            if j == bj {
                continue; // the job's lowest dispatchable stage is kept
            }
            let share = svc.held[j] as f64 / svc.weight[j];
            let best_share = svc.held[bj] as f64 / svc.weight[bj];
            // Strictly fairer, or equal share but earlier arrival (the
            // submission-order tie favours the incumbent `bj < j`).
            if share < best_share - EPS
                || (share < best_share + EPS && svc.arrival[j] < svc.arrival[bj] - EPS)
            {
                best = Some((j, s));
            }
        }
        best.map(|(_, s)| s)
    }

    fn next_live_backup(&mut self) -> Option<(usize, usize)> {
        while let Some((s, t)) = self.spec_pending.pop_front() {
            if self.primary[s][t].running_start().is_some() {
                return Some((s, t));
            }
        }
        None
    }
}

/// Advance a running attempt by one released chunk. Returns the finish
/// time to book when this was the last chunk it owed.
fn advance_attempt(state: &mut AttemptState, now: f64) -> Option<f64> {
    if let AttemptState::Running { start, busy_until, remaining, chunk_w } = *state {
        debug_assert!(remaining > 0, "running consumer ran out of chunks early");
        let busy_until = busy_until.max(now) + chunk_w;
        let remaining = remaining - 1;
        *state = AttemptState::Running { start, busy_until, remaining, chunk_w };
        if remaining == 0 {
            return Some(busy_until);
        }
    }
    None
}

/// Event-driven schedule (see module docs for the model). Pipelined
/// stage overlap; with a policy, speculative backups ride the same
/// clock. `decide_only` records tail-signal decisions without modelling
/// backup execution (used by [`tail_signal`]).
fn simulate(
    stages: &[StageSpec],
    slots: usize,
    policy: Option<&SpecPolicy>,
    decide_only: bool,
) -> SimRun {
    if stages.is_empty() {
        return SimRun {
            out: ScheduleOut {
                latency_s: 0.0,
                stages: Vec::new(),
                idle_s: 0.0,
                spec_launches: 0,
                spec_wins: 0,
            },
            decisions: Vec::new(),
        };
    }
    let mut sim = new_sim(stages, slots, policy, decide_only, None);

    // Root stages become ready once their driver overhead is paid.
    for s in stages {
        if s.parents.is_empty() {
            sim.push(s.overhead_s, EventKind::StageReady { stage: s.id as usize });
        }
    }

    run_events(&mut sim);
    let (windows, stage_idle) = collect_windows(&sim);
    SimRun {
        out: ScheduleOut {
            latency_s: sim.latency,
            stages: windows,
            idle_s: stage_idle.iter().sum(),
            spec_launches: sim.spec_launches,
            spec_wins: sim.spec_wins,
        },
        decisions: sim.decisions,
    }
}

/// Build the event clock's state for a stage list (solo or flattened
/// multi-query).
fn new_sim<'a>(
    stages: &'a [StageSpec],
    slots: usize,
    policy: Option<&'a SpecPolicy>,
    decide_only: bool,
    svc: Option<SvcCtx>,
) -> Sim<'a> {
    let n = stages.len();
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut producer_tasks = vec![0usize; n];
    for s in stages {
        for &p in &s.parents {
            children[p as usize].push(s.id as usize);
            producer_tasks[s.id as usize] += stages[p as usize].task_durations.len();
        }
    }
    Sim {
        stages,
        policy,
        decide_only,
        producer_tasks,
        released: vec![0; n],
        children,
        ready: vec![false; n],
        first_start: vec![None; n],
        parents_started: vec![0; n],
        pending: stages
            .iter()
            .map(|s| (0..s.task_durations.len()).collect())
            .collect(),
        primary: stages
            .iter()
            .map(|s| vec![AttemptState::NotStarted; s.task_durations.len()])
            .collect(),
        backup: stages
            .iter()
            .map(|s| vec![AttemptState::NotStarted; s.task_durations.len()])
            .collect(),
        triggered: stages
            .iter()
            .map(|s| vec![false; s.task_durations.len()])
            .collect(),
        spec_pending: VecDeque::new(),
        done_spans: vec![Vec::new(); n],
        check_booked: stages
            .iter()
            .map(|s| vec![f64::NEG_INFINITY; s.task_durations.len()])
            .collect(),
        decisions: Vec::new(),
        free_slots: slots,
        events: BinaryHeap::new(),
        seq: 0,
        ends_left: stages.iter().map(|s| s.task_durations.len()).sum(),
        latency: 0.0,
        spec_launches: 0,
        spec_wins: 0,
        svc,
    }
}

/// Drain the event heap to completion (the clock's main loop).
fn run_events(sim: &mut Sim) {
    while let Some(ev) = sim.events.pop() {
        let now = ev.time;
        sim.handle(ev);
        // Drain every simultaneous event before dispatching, so a
        // same-instant readiness/completion can't lose a slot to a
        // lower-priority task.
        while sim.events.peek().map(|e| e.time == now).unwrap_or(false) {
            let ev = sim.events.pop().expect("peeked");
            sim.handle(ev);
        }
        sim.dispatch(now);
    }
    assert_eq!(sim.ends_left, 0, "event schedule deadlocked");
}

/// Extract per-stage windows and per-stage occupied-but-idle seconds
/// from a finished clock.
fn collect_windows(sim: &Sim) -> (Vec<StageWindow>, Vec<f64>) {
    let mut stage_idle = vec![0.0f64; sim.stages.len()];
    let windows = sim
        .stages
        .iter()
        .map(|s| {
            let i = s.id as usize;
            let tasks: Vec<(f64, f64)> = sim.primary[i]
                .iter()
                .map(|t| match t {
                    AttemptState::Done { start, end } => (*start, *end),
                    AttemptState::Cancelled { start, end } => (*start, *end),
                    other => unreachable!("unfinished task {other:?}"),
                })
                .collect();
            for (t, (a, b)) in tasks.iter().enumerate() {
                stage_idle[i] += (b - a - s.task_durations[t]).max(0.0);
            }
            let backups: Vec<BackupWindow> = sim.backup[i]
                .iter()
                .enumerate()
                .filter_map(|(t, b)| match b {
                    AttemptState::Done { start, end } => {
                        Some(BackupWindow { task: t, start: *start, end: *end, won: true })
                    }
                    AttemptState::Cancelled { start, end } => {
                        Some(BackupWindow { task: t, start: *start, end: *end, won: false })
                    }
                    _ => None,
                })
                .collect();
            for b in &backups {
                if let Some(d) = s.backup_of(b.task) {
                    stage_idle[i] += (b.end - b.start - d).max(0.0);
                }
            }
            let start = sim.first_start[i].unwrap_or(0.0);
            let end = tasks.iter().fold(start, |acc, (_, e)| acc.max(*e));
            StageWindow { id: s.id, start, end, tasks, backups }
        })
        .collect();
    (windows, stage_idle)
}

/// Multi-query event schedule (fair / weighted): every query's stage
/// DAG flattened onto one clock, the fair dispatcher arbitrating slots
/// (see [`schedule_service`]).
fn simulate_service(
    queries: &[ServiceQuerySpec],
    slots: usize,
    barrier: bool,
    weighted: bool,
    policy: Option<&SpecPolicy>,
) -> ServiceScheduleOut {
    let nq = queries.len();
    // Flatten every query's stages into one dense global id space.
    let mut flat: Vec<StageSpec> = Vec::new();
    let mut job_of: Vec<usize> = Vec::new();
    let mut stage_seq: Vec<Vec<usize>> = vec![Vec::new(); nq];
    for (j, q) in queries.iter().enumerate() {
        let off = flat.len() as u32;
        for s in &q.stages {
            stage_seq[j].push(flat.len());
            job_of.push(j);
            flat.push(StageSpec {
                id: off + s.id,
                parents: s.parents.iter().map(|&p| off + p).collect(),
                task_durations: s.task_durations.clone(),
                backups: s.backups.clone(),
                overhead_s: s.overhead_s,
            });
        }
    }
    // Seed readiness before the context is moved into the clock:
    // pipelined roots are each query's parentless stages; barrier admits
    // only each query's first stage — the rest ready as predecessors
    // commit.
    let mut seeds: Vec<(f64, usize)> = Vec::new();
    if barrier {
        for (j, q) in queries.iter().enumerate() {
            if let Some(&first) = stage_seq[j].first() {
                seeds.push((q.arrival_s + flat[first].overhead_s, first));
            }
        }
    } else {
        for (gi, s) in flat.iter().enumerate() {
            if s.parents.is_empty() {
                seeds.push((queries[job_of[gi]].arrival_s + s.overhead_s, gi));
            }
        }
    }
    let svc = SvcCtx {
        job: job_of,
        weight: queries
            .iter()
            .map(|q| if weighted { q.weight } else { 1.0 })
            .collect(),
        arrival: queries.iter().map(|q| q.arrival_s).collect(),
        quota: queries.iter().map(|q| q.quota.unwrap_or(usize::MAX)).collect(),
        barrier,
        held: vec![0; nq],
        tasks_left: flat.iter().map(|s| s.task_durations.len()).collect(),
        stage_seq,
        job_end: queries.iter().map(|q| q.arrival_s).collect(),
        job_start: vec![None; nq],
    };
    let mut sim = new_sim(&flat, slots, policy, false, Some(svc));
    for (t, s) in seeds {
        sim.push(t, EventKind::StageReady { stage: s });
    }
    run_events(&mut sim);

    let (windows, stage_idle) = collect_windows(&sim);
    let svc = sim.svc.as_ref().expect("service ctx survives the run");
    let mut q_idle = vec![0.0f64; nq];
    let mut q_launches = vec![0u64; nq];
    let mut q_wins = vec![0u64; nq];
    for (gi, w) in windows.iter().enumerate() {
        let j = svc.job[gi];
        q_idle[j] += stage_idle[gi];
        q_launches[j] += w.backups.len() as u64;
        q_wins[j] += w.backups.iter().filter(|b| b.won).count() as u64;
    }
    let out: Vec<QueryWindow> = (0..nq)
        .map(|j| {
            let end = svc.job_end[j];
            QueryWindow {
                query: j,
                arrival_s: queries[j].arrival_s,
                start_s: svc.job_start[j].unwrap_or(queries[j].arrival_s),
                end_s: end,
                latency_s: end - queries[j].arrival_s,
                idle_s: q_idle[j],
                spec_launches: q_launches[j],
                spec_wins: q_wins[j],
            }
        })
        .collect();
    ServiceScheduleOut {
        makespan_s: out.iter().fold(0.0f64, |a, w| a.max(w.end_s)),
        idle_s: q_idle.iter().sum(),
        queries: out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simtime::makespan;
    use crate::util::propcheck::forall;

    fn chain(stage_tasks: &[Vec<f64>], overhead: f64) -> Vec<StageSpec> {
        stage_tasks
            .iter()
            .enumerate()
            .map(|(i, d)| StageSpec {
                id: i as u32,
                parents: if i == 0 { Vec::new() } else { vec![(i - 1) as u32] },
                task_durations: d.clone(),
                backups: Vec::new(),
                overhead_s: overhead,
            })
            .collect()
    }

    #[test]
    fn barrier_reproduces_sigma_makespan() {
        let stages = chain(&[vec![3.0, 1.0, 2.0, 2.0], vec![1.0, 1.0]], 0.5);
        let out = schedule_dag(&stages, 2, ScheduleMode::Barrier);
        let expect: f64 = stages
            .iter()
            .map(|s| makespan(&s.task_durations, 2) + s.overhead_s)
            .sum();
        assert!((out.latency_s - expect).abs() < 1e-12, "{} vs {expect}", out.latency_s);
        // Windows are contiguous.
        assert!((out.stages[0].end - out.stages[1].start).abs() < 1e-12);
        assert_eq!(out.idle_s, 0.0);
    }

    #[test]
    fn pipelined_overlaps_two_stage_chain() {
        // Staggered maps (one straggler) + 2 reduces: the short maps'
        // flushes are drained while the straggler still runs.
        let stages = chain(&[vec![4.0, 1.0, 1.0, 1.0], vec![2.0; 2]], 0.0);
        let barrier = schedule_dag(&stages, 4, ScheduleMode::Barrier);
        let pipe = schedule_dag(&stages, 4, ScheduleMode::Pipelined);
        assert!(
            pipe.latency_s < barrier.latency_s - 1e-9,
            "pipelined {} must beat barrier {}",
            pipe.latency_s,
            barrier.latency_s
        );
        // Reducers started while maps still ran.
        assert!(pipe.stages[1].overlap_s(&pipe.stages[0]) > 0.0);
        // But a reducer cannot finish before the last map flush.
        let maps_done = pipe.stages[0].end;
        for (_, end) in &pipe.stages[1].tasks {
            assert!(*end >= maps_done - 1e-9, "reduce ended {end} before maps {maps_done}");
        }
        // Long-polling reducers hold their slots while waiting: the
        // pipelined clock reports occupied-but-idle time to bill.
        assert!(pipe.idle_s > 0.0, "reducers long-polled, idle must be > 0");
    }

    #[test]
    fn pipelined_single_stage_matches_barrier_minus_overhead_position() {
        // One stage: same makespan either way (overhead before vs after
        // does not change the total).
        let stages = chain(&[vec![2.0, 3.0, 1.0]], 0.25);
        let b = schedule_dag(&stages, 2, ScheduleMode::Barrier);
        let p = schedule_dag(&stages, 2, ScheduleMode::Pipelined);
        assert!((b.latency_s - p.latency_s).abs() < 1e-12, "{} vs {}", b.latency_s, p.latency_s);
    }

    #[test]
    fn pipelined_respects_slot_limit() {
        let stages = chain(&[vec![1.0; 6], vec![1.0; 3]], 0.0);
        let out = schedule_dag(&stages, 2, ScheduleMode::Pipelined);
        // Collect all spans and check concurrency never exceeds 2: at any
        // task start, count overlapping spans.
        let mut spans: Vec<(f64, f64)> = Vec::new();
        for w in &out.stages {
            spans.extend(w.tasks.iter().copied());
        }
        for &(s, _) in &spans {
            let live = spans.iter().filter(|&&(a, b)| a <= s + 1e-12 && b > s + 1e-12).count();
            assert!(live <= 2, "{live} tasks live at {s}");
        }
    }

    #[test]
    fn multi_parent_stage_waits_for_all_parents() {
        // Two roots with very different lengths; sink needs both started.
        let stages = vec![
            StageSpec {
                id: 0,
                parents: vec![],
                task_durations: vec![10.0],
                backups: Vec::new(),
                overhead_s: 0.0,
            },
            StageSpec {
                id: 1,
                parents: vec![],
                task_durations: vec![1.0],
                backups: Vec::new(),
                overhead_s: 0.0,
            },
            StageSpec {
                id: 2,
                parents: vec![0, 1],
                task_durations: vec![2.0, 2.0],
                backups: Vec::new(),
                overhead_s: 0.0,
            },
        ];
        let out = schedule_dag(&stages, 8, ScheduleMode::Pipelined);
        // Sink tasks cannot end before the slow root's only task ends
        // (its chunk arrives at t=10).
        for (_, end) in &out.stages[2].tasks {
            assert!(*end >= 10.0 - 1e-9, "sink finished at {end} before slow parent");
        }
        // But they started long before that (pipelined launch).
        assert!(out.stages[2].start < 1.0 + 1e-9, "sink started at {}", out.stages[2].start);
        // And the whole DAG beats the serial barrier.
        let b = schedule_dag(&stages, 8, ScheduleMode::Barrier);
        assert!(out.latency_s < b.latency_s - 1e-9);
    }

    #[test]
    fn producers_keep_dispatch_priority() {
        // 1 slot: the reducer must not grab the slot while maps pend.
        let stages = chain(&[vec![2.0, 2.0], vec![1.0]], 0.0);
        let out = schedule_dag(&stages, 1, ScheduleMode::Pipelined);
        let map_spans = &out.stages[0].tasks;
        let red_span = out.stages[1].tasks[0];
        assert!(red_span.0 >= map_spans[1].0, "reduce started before last map");
        // Serial on one slot: total = 2 + 2 + 1.
        assert!((out.latency_s - 5.0).abs() < 1e-9, "{}", out.latency_s);
    }

    #[test]
    fn empty_stage_does_not_deadlock() {
        let stages = vec![
            StageSpec {
                id: 0,
                parents: vec![],
                task_durations: vec![],
                backups: Vec::new(),
                overhead_s: 0.1,
            },
            StageSpec {
                id: 1,
                parents: vec![0],
                task_durations: vec![1.0],
                backups: Vec::new(),
                overhead_s: 0.1,
            },
        ];
        let out = schedule_dag(&stages, 2, ScheduleMode::Pipelined);
        assert!(out.latency_s > 1.0, "{}", out.latency_s);
        assert_eq!(out.stages[1].tasks.len(), 1);
    }

    #[test]
    fn prop_pipelined_never_slower_than_barrier_on_two_level_dags() {
        // Random two-level DAGs (N roots feeding one sink): pipelining
        // must never lose to the serial barrier. On single-root chains
        // the event clock wins outright; on multi-root DAGs with skewed
        // ready times the serial-fallback guard is what keeps this true
        // (greedy non-preemptive overlap alone loses ~0.01% of cases).
        forall("pipelined-le-barrier", 150, |g| {
            let slots = g.usize(7) + 1;
            let roots = g.usize(3) + 1;
            let mut stages = Vec::new();
            for r in 0..roots {
                let d = g.vec(6, |g| g.f64(0.1, 5.0));
                stages.push(StageSpec {
                    id: r as u32,
                    parents: Vec::new(),
                    task_durations: if d.is_empty() { vec![1.0] } else { d },
                    backups: Vec::new(),
                    overhead_s: g.f64(0.0, 0.5),
                });
            }
            let sink_tasks = g.usize(5) + 1;
            stages.push(StageSpec {
                id: roots as u32,
                parents: (0..roots as u32).collect(),
                task_durations: (0..sink_tasks).map(|_| g.f64(0.1, 3.0)).collect(),
                backups: Vec::new(),
                overhead_s: g.f64(0.0, 0.5),
            });
            let b = schedule_dag(&stages, slots, ScheduleMode::Barrier);
            let p = schedule_dag(&stages, slots, ScheduleMode::Pipelined);
            if p.latency_s > b.latency_s + 1e-9 {
                return Err(format!(
                    "pipelined {} > barrier {} (slots {slots}, roots {roots})",
                    p.latency_s, b.latency_s
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_pipelined_respects_lower_bounds() {
        // Latency can never undercut (a) any single stage's own makespan
        // requirement total/slots, (b) the longest task + its stage
        // readiness, (c) total work / slots.
        forall("pipelined-lower-bounds", 150, |g| {
            let slots = g.usize(7) + 1;
            let d0 = g.vec(8, |g| g.f64(0.1, 4.0));
            let d1 = g.vec(4, |g| g.f64(0.1, 4.0));
            if d0.is_empty() {
                return Ok(());
            }
            let stages = chain(&[d0.clone(), d1.clone()], 0.0);
            let p = schedule_dag(&stages, slots, ScheduleMode::Pipelined);
            let total: f64 = d0.iter().chain(d1.iter()).sum();
            let lower = total / slots as f64;
            if p.latency_s < lower - 1e-9 {
                return Err(format!("latency {} under work bound {lower}", p.latency_s));
            }
            // Reducers cannot finish before all maps finish.
            let maps_end = stages_end(&p, 0);
            if !d1.is_empty() && stages_end(&p, 1) < maps_end - 1e-9 {
                return Err("reduce stage ended before maps".into());
            }
            Ok(())
        });
    }

    fn stages_end(out: &ScheduleOut, id: usize) -> f64 {
        out.stages[id].end
    }

    // -- the attempt model ------------------------------------------------

    const POLICY: SpecPolicy = SpecPolicy { multiplier: 1.5, quantile: 0.75 };

    #[test]
    fn tail_signal_flags_the_straggler() {
        // 3 short tasks + 1 straggler on 4 slots: quorum (ceil(.75*4)=3)
        // is reached at t=1 with median 1; the threshold crossing for the
        // straggler (started at 0) is t=1.5.
        let decisions = tail_signal(&[1.0, 1.0, 1.0, 8.0], 4, &POLICY);
        assert_eq!(decisions.len(), 1, "{decisions:?}");
        let d = decisions[0];
        assert_eq!(d.task, 3);
        assert!((d.primary_start - 0.0).abs() < 1e-9);
        assert!((d.launch_at - 1.5).abs() < 1e-9, "launch at {}", d.launch_at);
    }

    #[test]
    fn tail_signal_quiet_on_homogeneous_stages() {
        assert!(tail_signal(&[1.0; 12], 4, &POLICY).is_empty());
        // Waved execution of equal tasks must not speculate either: a
        // second-wave task's elapsed time never exceeds the threshold.
        assert!(tail_signal(&[2.0; 10], 3, &POLICY).is_empty());
    }

    #[test]
    fn tail_signal_needs_quorum() {
        // Quantile 1.0 disables the signal outright.
        let p = SpecPolicy { multiplier: 1.5, quantile: 1.0 };
        assert!(tail_signal(&[1.0, 1.0, 1.0, 50.0], 4, &p).is_empty());
        // Fewer than two tasks: no peers, no medians, no signal.
        assert!(tail_signal(&[50.0], 4, &POLICY).is_empty());
    }

    #[test]
    fn backup_wins_cut_the_straggler_short() {
        // One straggling map (8s vs 1s peers) with a measured 1s backup:
        // the backup launches at ~1.5s and commits at ~2.5s, so the stage
        // (and the reduce behind it) no longer waits 8s.
        let mut stages = chain(&[vec![1.0, 1.0, 1.0, 8.0], vec![0.5, 0.5]], 0.0);
        stages[0].backups = vec![None, None, None, Some(1.0)];
        let plain = schedule_dag(&stages, 8, ScheduleMode::Pipelined);
        let spec = schedule_dag_spec(&stages, 8, ScheduleMode::Pipelined, Some(&POLICY));
        assert!(
            spec.latency_s < plain.latency_s - 1e-9,
            "spec {} must strictly beat plain {}",
            spec.latency_s,
            plain.latency_s
        );
        assert_eq!(spec.spec_launches, 1);
        assert_eq!(spec.spec_wins, 1);
        let bw = &spec.stages[0].backups;
        assert_eq!(bw.len(), 1);
        assert!(bw[0].won);
        assert_eq!(bw[0].task, 3);
        assert!((bw[0].start - 1.5).abs() < 1e-9, "backup launch at {}", bw[0].start);
        assert!((bw[0].end - 2.5).abs() < 1e-9, "backup commit at {}", bw[0].end);
        // The cancelled primary's span closes at the backup's commit.
        let (ps, pe) = spec.stages[0].tasks[3];
        assert!((ps - 0.0).abs() < 1e-9 && (pe - 2.5).abs() < 1e-9, "{ps}..{pe}");
    }

    #[test]
    fn slow_backup_loses_and_is_cancelled() {
        // The backup is no faster than the remaining straggler work: the
        // primary commits first and the backup is cancelled at that
        // instant — first-commit-wins, never last-attempt-overwrites.
        let mut stages = chain(&[vec![1.0, 1.0, 1.0, 2.2]], 0.0);
        stages[0].backups = vec![None, None, None, Some(50.0)];
        let spec = schedule_dag_spec(&stages, 8, ScheduleMode::Pipelined, Some(&POLICY));
        assert_eq!(spec.spec_launches, 1);
        assert_eq!(spec.spec_wins, 0);
        let bw = &spec.stages[0].backups[0];
        assert!(!bw.won);
        assert!((bw.end - 2.2).abs() < 1e-9, "cancelled at the primary's commit, {}", bw.end);
        // Latency is the primary's own finish: speculation didn't help,
        // and didn't hurt either.
        assert!((spec.latency_s - 2.2).abs() < 1e-9, "{}", spec.latency_s);
    }

    #[test]
    fn backups_respect_the_slot_limit() {
        // 2 slots: the straggler lands in the last wave (started after
        // the quorum committed — the start-time tail check covers it),
        // and its backup must wait for a free slot behind primaries,
        // never exceeding the concurrency limit.
        let mut stages = chain(&[vec![1.0, 1.0, 1.0, 1.0, 9.0]], 0.0);
        stages[0].backups = vec![None, None, None, None, Some(1.0)];
        let spec = schedule_dag_spec(&stages, 2, ScheduleMode::Pipelined, Some(&POLICY));
        let mut spans: Vec<(f64, f64)> = spec.stages[0].tasks.clone();
        spans.extend(spec.stages[0].backups.iter().map(|b| (b.start, b.end)));
        for &(s, _) in &spans {
            let live = spans.iter().filter(|&&(a, b)| a <= s + 1e-12 && b > s + 1e-12).count();
            assert!(live <= 2, "{live} attempts live at {s}");
        }
        assert_eq!(spec.spec_launches, 1);
    }

    #[test]
    fn spec_none_is_byte_identical_to_plain_scheduler() {
        // The refactor's contract: with no policy the attempt-model
        // scheduler produces the exact same schedule as before, even
        // when measured backups are present in the specs.
        forall("spec-none-identity", 100, |g| {
            let slots = g.usize(7) + 1;
            let d0 = g.vec(8, |g| g.f64(0.1, 4.0));
            let d1 = g.vec(4, |g| g.f64(0.1, 4.0));
            if d0.is_empty() {
                return Ok(());
            }
            let mut stages = chain(&[d0.clone(), d1], g.f64(0.0, 0.5));
            stages[0].backups = d0.iter().map(|_| g.bool().then_some(1.0)).collect();
            for mode in [ScheduleMode::Barrier, ScheduleMode::Pipelined] {
                let a = schedule_dag(&stages, slots, mode);
                let b = schedule_dag_spec(&stages, slots, mode, None);
                if a.latency_s != b.latency_s {
                    return Err(format!("{mode:?}: {} != {}", a.latency_s, b.latency_s));
                }
                for (wa, wb) in a.stages.iter().zip(b.stages.iter()) {
                    if wa.tasks != wb.tasks || wa.start != wb.start || wa.end != wb.end {
                        return Err(format!("{mode:?}: windows diverge at stage {}", wa.id));
                    }
                    if !wb.backups.is_empty() {
                        return Err("backups modelled without a policy".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_speculation_never_loses_on_straggler_chains() {
        // With a backup measured at the stage's typical duration, the
        // speculative schedule must never be slower than the plain one
        // (backups queue behind all primary work, so they only use slots
        // nothing else wants), and backups must actually win across the
        // sample (a straggler that commits before its own signal fires
        // legitimately gets no backup, so wins are aggregate, not
        // per-case).
        let wins = std::cell::Cell::new(0u64);
        forall("spec-beats-straggler", 100, |g| {
            let slots = g.usize(6) + 2;
            let base = g.f64(0.5, 2.0);
            let n = g.usize(6) + 4;
            let mut d0 = vec![base; n];
            let straggler = g.usize(n);
            let factor = g.f64(4.0, 12.0);
            d0[straggler] = base * factor;
            let d1 = g.vec(3, |g| g.f64(0.1, 1.0));
            let mut stages = chain(&[d0, d1], 0.0);
            let mut backups = vec![None; n];
            backups[straggler] = Some(base);
            stages[0].backups = backups;
            let plain = schedule_dag(&stages, slots, ScheduleMode::Pipelined);
            let spec = schedule_dag_spec(&stages, slots, ScheduleMode::Pipelined, Some(&POLICY));
            if spec.latency_s > plain.latency_s + 1e-9 {
                return Err(format!(
                    "spec {} > plain {} (slots {slots}, n {n}, factor {factor:.1})",
                    spec.latency_s, plain.latency_s
                ));
            }
            wins.set(wins.get() + spec.spec_wins);
            Ok(())
        });
        assert!(wins.get() > 50, "backups should win across the sample, got {}", wins.get());
    }

    #[test]
    fn barrier_spec_sums_speculative_stage_makespans() {
        let mut stages = chain(&[vec![1.0, 1.0, 1.0, 8.0], vec![0.5, 0.5]], 0.25);
        stages[0].backups = vec![None, None, None, Some(1.0)];
        let plain = schedule_dag(&stages, 8, ScheduleMode::Barrier);
        let spec = schedule_dag_spec(&stages, 8, ScheduleMode::Barrier, Some(&POLICY));
        // Stage 0 commits at 2.5 (backup win) instead of 8.0.
        let expect = (2.5 + 0.25) + (0.5 + 0.25);
        assert!((spec.latency_s - expect).abs() < 1e-9, "{}", spec.latency_s);
        assert!(spec.latency_s < plain.latency_s);
        // Windows stay serial and contiguous.
        assert!((spec.stages[0].end - spec.stages[1].start).abs() < 1e-12);
        assert_eq!(spec.spec_wins, 1);
    }

    #[test]
    fn pipelined_idle_matches_longpoll_gaps() {
        // 1 map of 4s feeding 1 reduce of 1s: the reduce launches at 0
        // (ready immediately), long-polls until the map's only chunk at
        // t=4, and works 1s — span 5s, busy 1s, idle 4s.
        let stages = chain(&[vec![4.0], vec![1.0]], 0.0);
        let out = schedule_dag(&stages, 4, ScheduleMode::Pipelined);
        assert!((out.stages[1].tasks[0].1 - 5.0).abs() < 1e-9);
        assert!((out.idle_s - 4.0).abs() < 1e-9, "idle {}", out.idle_s);
    }

    // -- the multi-query service clock -------------------------------------

    fn query(stage_tasks: &[Vec<f64>], arrival: f64, weight: f64) -> ServiceQuerySpec {
        ServiceQuerySpec { stages: chain(stage_tasks, 0.0), arrival_s: arrival, weight, quota: None }
    }

    #[test]
    fn service_fifo_is_serial_back_to_back() {
        let q1 = query(&[vec![2.0, 2.0], vec![1.0]], 0.0, 1.0);
        let q2 = query(&[vec![3.0]], 0.0, 1.0);
        let solo1 = schedule_dag(&q1.stages, 4, ScheduleMode::Pipelined);
        let solo2 = schedule_dag(&q2.stages, 4, ScheduleMode::Pipelined);
        let out = schedule_service(
            &[q1, q2],
            4,
            ScheduleMode::Pipelined,
            ServicePolicy::Fifo,
            None,
        );
        assert!((out.queries[0].latency_s - solo1.latency_s).abs() < 1e-12);
        // The second query waits for the first: latency includes the wait.
        assert!((out.queries[1].start_s - solo1.latency_s).abs() < 1e-12);
        assert!((out.queries[1].latency_s - (solo1.latency_s + solo2.latency_s)).abs() < 1e-12);
        assert!((out.makespan_s - (solo1.latency_s + solo2.latency_s)).abs() < 1e-12);
    }

    #[test]
    fn service_fifo_honours_arrivals() {
        // Late arrival with an idle gap: query 2 starts at its arrival,
        // not at query 1's end.
        let q1 = query(&[vec![1.0]], 0.0, 1.0);
        let q2 = query(&[vec![1.0]], 5.0, 1.0);
        let out = schedule_service(
            &[q1, q2],
            4,
            ScheduleMode::Pipelined,
            ServicePolicy::Fifo,
            None,
        );
        assert!((out.queries[1].start_s - 5.0).abs() < 1e-12);
        assert!((out.queries[1].latency_s - 1.0).abs() < 1e-12);
        assert!((out.makespan_s - 6.0).abs() < 1e-12);
    }

    #[test]
    fn service_fair_solo_matches_single_query_clock() {
        // One admitted query: the fair clock degenerates to the solo
        // event clock, both modes.
        let stages = &[vec![3.0, 1.0, 2.0, 2.0], vec![1.0, 1.0]];
        for mode in [ScheduleMode::Barrier, ScheduleMode::Pipelined] {
            let solo = schedule_dag(&chain(stages, 0.3), 2, mode);
            let q = ServiceQuerySpec {
                stages: chain(stages, 0.3),
                arrival_s: 0.0,
                weight: 1.0,
                quota: None,
            };
            let out = schedule_service(&[q], 2, mode, ServicePolicy::Fair, None);
            assert!(
                (out.queries[0].latency_s - solo.latency_s).abs() < 1e-9,
                "{mode:?}: {} vs {}",
                out.queries[0].latency_s,
                solo.latency_s
            );
            assert!((out.idle_s - solo.idle_s).abs() < 1e-9);
        }
    }

    #[test]
    fn service_fair_overlaps_nonconflicting_queries() {
        // Two 2-task queries on 4 slots: no contention, both finish at
        // their solo latency — fair sharing costs nothing when the pool
        // has room.
        let stages = &[vec![2.0, 2.0]];
        let solo = schedule_dag(&chain(stages, 0.0), 4, ScheduleMode::Pipelined);
        let qs = vec![query(stages, 0.0, 1.0), query(stages, 0.0, 1.0)];
        let out =
            schedule_service(&qs, 4, ScheduleMode::Pipelined, ServicePolicy::Fair, None);
        for w in &out.queries {
            assert!(
                (w.latency_s - solo.latency_s).abs() < 1e-9,
                "query {} latency {} vs solo {}",
                w.query,
                w.latency_s,
                solo.latency_s
            );
        }
        assert!((out.makespan_s - solo.latency_s).abs() < 1e-9);
    }

    #[test]
    fn service_fair_beats_fifo_tail_under_saturation() {
        // 4 equal queries, each only half as wide as the pool: FIFO runs
        // them one at a time and wastes the other half of the slots
        // (head-of-line blocking), so its last query waits through three
        // full solo runs; fair co-schedules, so both the *worst* latency
        // and the makespan strictly improve. (When every query saturates
        // the pool on its own, both policies are work-conserving and the
        // tails tie — the contrast needs per-query width < slots.)
        let stages = &[vec![1.0; 4]];
        let qs: Vec<ServiceQuerySpec> =
            (0..4).map(|_| query(stages, 0.0, 1.0)).collect();
        let fifo =
            schedule_service(&qs, 8, ScheduleMode::Pipelined, ServicePolicy::Fifo, None);
        let fair =
            schedule_service(&qs, 8, ScheduleMode::Pipelined, ServicePolicy::Fair, None);
        let worst = |o: &ServiceScheduleOut| {
            o.queries.iter().fold(0.0f64, |a, w| a.max(w.latency_s))
        };
        assert!(
            worst(&fair) < worst(&fifo) - 1e-9,
            "fair p-max {} must beat fifo {}",
            worst(&fair),
            worst(&fifo)
        );
        assert!(fair.makespan_s <= fifo.makespan_s + 1e-9, "no throughput regression");
    }

    #[test]
    fn service_fair_share_within_one_task_under_saturation() {
        // 2 queries × 12 equal unit tasks on 6 slots: at every dispatch
        // instant each query holds 3 ± 1 slots.
        let stages = &[vec![1.0; 12]];
        let qs = vec![query(stages, 0.0, 1.0), query(stages, 0.0, 1.0)];
        let out =
            schedule_service(&qs, 6, ScheduleMode::Pipelined, ServicePolicy::Fair, None);
        // Equal demand + fair sharing: both queries must finish together
        // (within one task) and split the pool, so each takes ~4s
        // (24 task-seconds / 6 slots), not 2s-then-4s.
        let l0 = out.queries[0].latency_s;
        let l1 = out.queries[1].latency_s;
        assert!((l0 - l1).abs() <= 1.0 + 1e-9, "fair split diverged: {l0} vs {l1}");
        assert!((out.makespan_s - 4.0).abs() < 1e-9, "makespan {}", out.makespan_s);
        assert!(l0 > 3.0 && l1 > 3.0, "neither query may hog the pool: {l0}, {l1}");
    }

    #[test]
    fn service_weighted_prefers_heavy_tenant() {
        // Weight 3 vs 1 on a saturated pool: the heavy tenant holds ~3/4
        // of the slots and finishes first.
        let stages = &[vec![1.0; 16]];
        let qs = vec![query(stages, 0.0, 3.0), query(stages, 0.0, 1.0)];
        let out = schedule_service(
            &qs,
            8,
            ScheduleMode::Pipelined,
            ServicePolicy::Weighted,
            None,
        );
        assert!(
            out.queries[0].latency_s < out.queries[1].latency_s - 1e-9,
            "weighted: heavy {} must beat light {}",
            out.queries[0].latency_s,
            out.queries[1].latency_s
        );
        // Under fair the same workload ties (within a task).
        let qs_fair = vec![query(stages, 0.0, 1.0), query(stages, 0.0, 1.0)];
        let fair = schedule_service(
            &qs_fair,
            8,
            ScheduleMode::Pipelined,
            ServicePolicy::Fair,
            None,
        );
        assert!((fair.queries[0].latency_s - fair.queries[1].latency_s).abs() <= 1.0 + 1e-9);
    }

    #[test]
    fn service_quota_caps_held_slots() {
        // 8 unit tasks, quota 2, 8 free slots: the tenant may never hold
        // more than 2, so the work runs in 4 waves (latency 4) and
        // concurrency stays within the cap at every instant.
        let mut q = query(&[vec![1.0; 8]], 0.0, 1.0);
        q.quota = Some(2);
        let out =
            schedule_service(&[q], 8, ScheduleMode::Pipelined, ServicePolicy::Fair, None);
        assert!((out.queries[0].latency_s - 4.0).abs() < 1e-9, "{}", out.queries[0].latency_s);
    }

    #[test]
    fn service_quota_frees_slots_for_the_uncapped_tenant() {
        // Both tenants want the whole 8-slot pool; tenant 0 is capped at
        // 2. Fair sharing would split 4/4 and tie; the quota hands the
        // other 6 slots to tenant 1, which must now finish first.
        let mut q0 = query(&[vec![1.0; 12]], 0.0, 1.0);
        q0.quota = Some(2);
        let q1 = query(&[vec![1.0; 12]], 0.0, 1.0);
        let out = schedule_service(
            &[q0, q1],
            8,
            ScheduleMode::Pipelined,
            ServicePolicy::Fair,
            None,
        );
        // Capped tenant: 12 tasks / 2 slots = 6 waves.
        assert!((out.queries[0].latency_s - 6.0).abs() < 1e-9, "{}", out.queries[0].latency_s);
        // Uncapped tenant gets the remaining 6 slots: 12 / 6 = 2 waves.
        assert!((out.queries[1].latency_s - 2.0).abs() < 1e-9, "{}", out.queries[1].latency_s);
    }

    #[test]
    fn service_quota_caps_fifo_solo_runs() {
        // FIFO runs each query alone, but a quota'd tenant still cannot
        // exceed its cap: its solo schedule sees a pool of min(slots,
        // quota) slots.
        let mut q = query(&[vec![1.0; 8]], 0.0, 1.0);
        q.quota = Some(2);
        let out =
            schedule_service(&[q], 8, ScheduleMode::Pipelined, ServicePolicy::Fifo, None);
        assert!((out.queries[0].latency_s - 4.0).abs() < 1e-9, "{}", out.queries[0].latency_s);
    }

    #[test]
    fn service_quota_defers_backups_behind_the_cap() {
        // One straggler with a fast measured backup. Uncapped (or quota
        // 2) the backup launches beside the still-running primary and
        // wins; at quota 1 the backup would need a second slot the job
        // may not hold, so it defers until the primary commits — at
        // which point it is moot and never launches at all.
        let make = |quota| {
            let mut stages = chain(&[vec![1.0, 1.0, 1.0, 8.0]], 0.0);
            stages[0].backups = vec![None, None, None, Some(1.0)];
            ServiceQuerySpec { stages, arrival_s: 0.0, weight: 1.0, quota }
        };
        let capped = schedule_service(
            &[make(Some(1))],
            8,
            ScheduleMode::Pipelined,
            ServicePolicy::Fair,
            Some(&POLICY),
        );
        assert_eq!(capped.queries[0].spec_launches, 0, "no second slot to launch into");
        // Serial under quota 1: 1 + 1 + 1 + 8.
        assert!((capped.queries[0].latency_s - 11.0).abs() < 1e-9);
        let roomy = schedule_service(
            &[make(Some(2))],
            8,
            ScheduleMode::Pipelined,
            ServicePolicy::Fair,
            Some(&POLICY),
        );
        assert_eq!(roomy.queries[0].spec_launches, 1);
        assert_eq!(roomy.queries[0].spec_wins, 1);
        assert!(roomy.queries[0].latency_s < capped.queries[0].latency_s - 1e-9);
    }

    #[test]
    fn service_respects_slot_cap_across_queries() {
        // Aggregate concurrency across all queries must never exceed the
        // pool. Reconstruct spans via a fair run on a tight pool.
        let stages = &[vec![1.5; 5], vec![0.5; 2]];
        let qs: Vec<ServiceQuerySpec> =
            (0..3).map(|_| query(stages, 0.0, 1.0)).collect();
        let slots = 4;
        let out =
            schedule_service(&qs, slots, ScheduleMode::Pipelined, ServicePolicy::Fair, None);
        // Work-conservation lower bound: total busy work / slots.
        let total: f64 = qs
            .iter()
            .flat_map(|q| q.stages.iter())
            .flat_map(|s| s.task_durations.iter())
            .sum();
        assert!(
            out.makespan_s >= total / slots as f64 - 1e-9,
            "makespan {} under the work bound {}",
            out.makespan_s,
            total / slots as f64
        );
    }

    #[test]
    fn service_barrier_solo_matches_sigma_model() {
        // Barrier-mode service with one query reproduces Σ(makespan +
        // overhead) exactly, overheads included.
        let stages = &[vec![3.0, 1.0, 2.0, 2.0], vec![1.0, 1.0]];
        let solo = schedule_dag(&chain(stages, 0.5), 2, ScheduleMode::Barrier);
        let q = ServiceQuerySpec {
            stages: chain(stages, 0.5),
            arrival_s: 0.0,
            weight: 1.0,
            quota: None,
        };
        let out = schedule_service(&[q], 2, ScheduleMode::Barrier, ServicePolicy::Fair, None);
        assert!(
            (out.queries[0].latency_s - solo.latency_s).abs() < 1e-9,
            "{} vs {}",
            out.queries[0].latency_s,
            solo.latency_s
        );
    }

    #[test]
    fn service_speculation_rides_the_shared_clock() {
        // A straggling query under fair sharing still gets its backup
        // launched and won on the shared clock.
        let mut stages = chain(&[vec![1.0, 1.0, 1.0, 8.0]], 0.0);
        stages[0].backups = vec![None, None, None, Some(1.0)];
        let qs = vec![
            ServiceQuerySpec { stages, arrival_s: 0.0, weight: 1.0, quota: None },
            query(&[vec![1.0; 4]], 0.0, 1.0),
        ];
        let out = schedule_service(
            &qs,
            8,
            ScheduleMode::Pipelined,
            ServicePolicy::Fair,
            Some(&POLICY),
        );
        assert_eq!(out.queries[0].spec_launches, 1);
        assert_eq!(out.queries[0].spec_wins, 1);
        assert_eq!(out.queries[1].spec_launches, 0);
        assert!(
            out.queries[0].latency_s < 8.0 - 1e-9,
            "backup win must cut the straggler: {}",
            out.queries[0].latency_s
        );
    }

    #[test]
    fn prop_service_fair_conserves_work_and_bounds_latency() {
        // Random query mixes: (a) the fair makespan never beats the
        // work-conservation bound, (b) every query's latency is at least
        // its own critical work / pool, (c) aggregate idle is finite and
        // non-negative.
        forall("service-fair-sane", 80, |g| {
            let slots = g.usize(6) + 2;
            let nq = g.usize(3) + 1;
            let mut qs = Vec::new();
            for _ in 0..nq {
                let d0 = {
                    let v = g.vec(6, |g| g.f64(0.2, 3.0));
                    if v.is_empty() {
                        vec![1.0]
                    } else {
                        v
                    }
                };
                let d1 = g.vec(3, |g| g.f64(0.1, 1.0));
                qs.push(ServiceQuerySpec {
                    stages: chain(&[d0, d1], g.f64(0.0, 0.3)),
                    arrival_s: g.f64(0.0, 2.0),
                    weight: 1.0,
                    quota: None,
                });
            }
            let out = schedule_service(
                &qs,
                slots,
                ScheduleMode::Pipelined,
                ServicePolicy::Fair,
                None,
            );
            let total: f64 = qs
                .iter()
                .flat_map(|q| q.stages.iter())
                .flat_map(|s| s.task_durations.iter())
                .sum();
            let earliest = qs.iter().fold(f64::INFINITY, |a, q| a.min(q.arrival_s));
            if out.makespan_s < earliest + total / slots as f64 - 1e-9 {
                return Err(format!(
                    "makespan {} beat the work bound {}",
                    out.makespan_s,
                    earliest + total / slots as f64
                ));
            }
            for (q, w) in qs.iter().zip(&out.queries) {
                let own: f64 = q
                    .stages
                    .iter()
                    .flat_map(|s| s.task_durations.iter())
                    .sum();
                if w.latency_s < own / slots as f64 - 1e-9 {
                    return Err(format!(
                        "query {} latency {} under its own work bound",
                        w.query, w.latency_s
                    ));
                }
                if w.end_s < w.arrival_s - 1e-12 || w.idle_s < -1e-12 {
                    return Err(format!("query {} has a negative span/idle", w.query));
                }
            }
            Ok(())
        });
    }
}
