//! Hybrid virtual-time accounting.
//!
//! Nothing in this repo talks to real AWS, so query latency cannot be
//! measured directly. Instead every simulated service charges a *modeled*
//! duration, real compute charges a *measured* duration, and each task
//! **attempt** accumulates both into a [`Timeline`]. Plan latency comes
//! from the event-driven DAG clock in [`schedule`]: every attempt of
//! every stage is placed onto the `K` shared concurrency slots, either
//! with hard barriers between stages (the original Σ-makespan model,
//! kept for the S3 shuffle backend and the exact-paper-reproduction
//! mode) or *pipelined*, overlapping reduce long-polling with map
//! flushes per §III-A. The same clock carries the speculation machinery:
//! its **tail signal** ([`schedule::tail_signal`]) flags tasks running
//! past `multiplier` × the median committed span of their stage peers,
//! emits backup-launch events, and commits each task at its
//! first-finishing attempt ([`schedule::schedule_dag_spec`]); it also
//! meters the occupied-but-idle long-polling time the pipelined cost
//! model bills. [`makespan`] remains the single-stage primitive the
//! barrier path is built from.
//!
//! See DESIGN.md §5 for the calibration constants and rationale.

pub mod makespan;
pub mod schedule;
pub mod timeline;

pub use makespan::{makespan, makespan_assignments};
pub use schedule::{
    schedule_dag, schedule_dag_spec, schedule_service, tail_signal, BackupWindow, QueryWindow,
    ScheduleMode, ScheduleOut, ServicePolicy, ServiceQuerySpec, ServiceScheduleOut, SpecDecision,
    SpecPolicy, StageSpec, StageWindow,
};
pub use timeline::{Component, Timeline};

use std::time::Instant;

/// A stopwatch for the *measured* part of the hybrid model: wraps real
/// monotonic time around actual Rust/PJRT compute.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed wall-clock seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Current thread's CPU time in seconds. Task compute is measured with
/// this rather than wall clock so that running many simulated executors
/// on few host cores doesn't inflate per-task compute through scheduler
/// contention (the simulated Lambdas would each have had a core).
pub fn thread_cpu_time_s() -> f64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    let rc = unsafe { libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    debug_assert_eq!(rc, 0);
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Stopwatch over thread CPU time (see [`thread_cpu_time_s`]).
pub struct CpuStopwatch {
    start: f64,
}

impl CpuStopwatch {
    pub fn start() -> CpuStopwatch {
        CpuStopwatch { start: thread_cpu_time_s() }
    }

    pub fn elapsed_s(&self) -> f64 {
        (thread_cpu_time_s() - self.start).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sw.elapsed_s() >= 0.004);
    }

    #[test]
    fn cpu_stopwatch_counts_work_not_sleep() {
        let sw = CpuStopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(20));
        let after_sleep = sw.elapsed_s();
        assert!(after_sleep < 0.015, "sleep must not count as CPU: {after_sleep}");
        // Burn some CPU.
        let mut x = 0u64;
        for i in 0..20_000_000u64 {
            x = x.wrapping_mul(31).wrapping_add(i);
        }
        std::hint::black_box(x);
        assert!(sw.elapsed_s() > after_sleep, "CPU work must advance the clock");
    }
}
