//! Stage-makespan computation: greedy list scheduling of task durations
//! onto `K` concurrency slots, in submission order.
//!
//! This models both AWS Lambda's per-account concurrency throttle (the
//! paper sets it to 80) and the cluster baseline's fixed 80 vCores: a
//! barrier-synchronized stage finishes when its last task does, and tasks
//! start in submission order as slots free up. For identical-duration
//! tasks this reduces to `ceil(n/K) * d`, matching the wave behaviour the
//! paper describes.
//!
//! Earliest-free-slot selection is a linear scan for small `K` (better
//! constants, cache-friendly) and a binary heap above
//! [`HEAP_SLOT_THRESHOLD`] slots, taking the overall cost from `O(n·k)`
//! to `O(n log k)` — the elasticity sweeps run thousands of slots. Both
//! paths break ties identically (lowest slot index), so they produce
//! bit-identical schedules; `rust/benches/hotpath.rs` guards the
//! large-`k` path.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// Slot count at which earliest-free-slot selection switches from the
/// linear scan to a binary heap.
pub const HEAP_SLOT_THRESHOLD: usize = 64;

/// A slot's next-free time, ordered (time, slot index) ascending so the
/// heap pops exactly the slot the linear scan's `min_by` would pick
/// (first minimum = lowest index).
#[derive(PartialEq)]
struct SlotFree {
    at: f64,
    slot: usize,
}

impl Eq for SlotFree {}

impl Ord for SlotFree {
    fn cmp(&self, other: &Self) -> Ordering {
        self.at.total_cmp(&other.at).then(self.slot.cmp(&other.slot))
    }
}

impl PartialOrd for SlotFree {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Completion time of `durations` scheduled FIFO onto `slots` slots.
pub fn makespan(durations: &[f64], slots: usize) -> f64 {
    assert!(slots > 0, "makespan needs at least one slot");
    if durations.is_empty() {
        return 0.0;
    }
    let k = slots.min(durations.len());
    if k <= HEAP_SLOT_THRESHOLD {
        makespan_linear(durations, k)
    } else {
        makespan_heap(durations, k)
    }
}

fn makespan_linear(durations: &[f64], k: usize) -> f64 {
    let mut free = vec![0.0f64; k];
    let mut end = 0.0f64;
    for &d in durations {
        debug_assert!(d >= 0.0, "negative task duration {d}");
        // earliest-free slot (first minimum = lowest index)
        let (idx, _) = free
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        free[idx] += d;
        if free[idx] > end {
            end = free[idx];
        }
    }
    end
}

fn makespan_heap(durations: &[f64], k: usize) -> f64 {
    let mut heap: BinaryHeap<Reverse<SlotFree>> =
        (0..k).map(|slot| Reverse(SlotFree { at: 0.0, slot })).collect();
    let mut end = 0.0f64;
    for &d in durations {
        debug_assert!(d >= 0.0, "negative task duration {d}");
        let Reverse(SlotFree { at, slot }) = heap.pop().expect("k > 0");
        let done = at + d;
        if done > end {
            end = done;
        }
        heap.push(Reverse(SlotFree { at: done, slot }));
    }
    end
}

/// Like [`makespan`] but also returns `(start, end, slot)` per task, for
/// `flint explain` and the timeline reports.
pub fn makespan_assignments(durations: &[f64], slots: usize) -> (f64, Vec<(f64, f64, usize)>) {
    assert!(slots > 0);
    if durations.is_empty() {
        return (0.0, Vec::new());
    }
    let k = slots.min(durations.len());
    if k <= HEAP_SLOT_THRESHOLD {
        makespan_assignments_linear(durations, k)
    } else {
        makespan_assignments_heap(durations, k)
    }
}

fn makespan_assignments_linear(durations: &[f64], k: usize) -> (f64, Vec<(f64, f64, usize)>) {
    let mut free = vec![0.0f64; k];
    let mut out = Vec::with_capacity(durations.len());
    let mut end = 0.0f64;
    for &d in durations {
        let (idx, _) = free
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let start = free[idx];
        free[idx] = start + d;
        out.push((start, free[idx], idx));
        if free[idx] > end {
            end = free[idx];
        }
    }
    (end, out)
}

fn makespan_assignments_heap(durations: &[f64], k: usize) -> (f64, Vec<(f64, f64, usize)>) {
    let mut heap: BinaryHeap<Reverse<SlotFree>> =
        (0..k).map(|slot| Reverse(SlotFree { at: 0.0, slot })).collect();
    let mut out = Vec::with_capacity(durations.len());
    let mut end = 0.0f64;
    for &d in durations {
        let Reverse(SlotFree { at, slot }) = heap.pop().expect("k > 0");
        let done = at + d;
        out.push((at, done, slot));
        if done > end {
            end = done;
        }
        heap.push(Reverse(SlotFree { at: done, slot }));
    }
    (end, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    #[test]
    fn empty_is_zero() {
        assert_eq!(makespan(&[], 4), 0.0);
    }

    #[test]
    fn serial_when_one_slot() {
        assert!((makespan(&[1.0, 2.0, 3.0], 1) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn fully_parallel_when_enough_slots() {
        assert!((makespan(&[1.0, 2.0, 3.0], 8) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn waves_of_identical_tasks() {
        // 10 tasks of 2s on 4 slots -> ceil(10/4)=3 waves -> 6s.
        let d = vec![2.0; 10];
        assert!((makespan(&d, 4) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn heap_path_matches_linear_exactly() {
        // Deterministic pseudo-random durations, k on both sides of the
        // threshold: the two implementations must agree bit-for-bit.
        let durations: Vec<f64> = (0..5_000u64)
            .map(|i| ((i.wrapping_mul(2654435761) % 1000) as f64) / 100.0 + 0.01)
            .collect();
        for k in [1, 2, 63, 64, 65, 128, 500, 4_999] {
            let k = k.min(durations.len());
            assert_eq!(
                makespan_linear(&durations, k),
                makespan_heap(&durations, k),
                "makespan mismatch at k={k}"
            );
            let (el, al) = makespan_assignments_linear(&durations, k);
            let (eh, ah) = makespan_assignments_heap(&durations, k);
            assert_eq!(el, eh, "assignment end mismatch at k={k}");
            assert_eq!(al, ah, "assignment spans mismatch at k={k}");
        }
    }

    #[test]
    fn assignments_cover_all_tasks_and_respect_slots() {
        let d = [1.0, 4.0, 2.0, 2.0, 1.0];
        let (end, asg) = makespan_assignments(&d, 2);
        assert_eq!(asg.len(), d.len());
        assert!((end - makespan(&d, 2)).abs() < 1e-12);
        for (start, stop, slot) in &asg {
            assert!(stop >= start);
            assert!(*slot < 2);
        }
        // No overlap within a slot.
        for s in 0..2 {
            let mut spans: Vec<(f64, f64)> = asg
                .iter()
                .filter(|(_, _, slot)| *slot == s)
                .map(|(a, b, _)| (*a, *b))
                .collect();
            spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in spans.windows(2) {
                assert!(w[0].1 <= w[1].0 + 1e-12, "overlap in slot {s}");
            }
        }
    }

    #[test]
    fn prop_makespan_bounds() {
        // Classic list-scheduling bounds:
        //   max(total/K, longest) <= makespan <= total/K + longest
        forall("makespan-bounds", 300, |g| {
            let k = g.usize(200) + 1; // crosses HEAP_SLOT_THRESHOLD
            let d = g.vec(300, |g| g.f64(0.0, 10.0));
            if d.is_empty() {
                return Ok(());
            }
            let ms = makespan(&d, k);
            let total: f64 = d.iter().sum();
            let longest = d.iter().cloned().fold(0.0, f64::max);
            let lower = (total / k as f64).max(longest);
            let upper = total / k as f64 + longest;
            if ms < lower - 1e-9 {
                return Err(format!("makespan {ms} below lower bound {lower}"));
            }
            if ms > upper + 1e-9 {
                return Err(format!("makespan {ms} above upper bound {upper}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_monotone_in_slots() {
        forall("makespan-monotone-slots", 200, |g| {
            let d = g.vec(30, |g| g.f64(0.1, 5.0));
            if d.is_empty() {
                return Ok(());
            }
            let k = g.usize(8) + 1;
            let a = makespan(&d, k);
            let b = makespan(&d, k + 1);
            // More slots can't make FIFO list scheduling *worse* for these
            // bounds... strictly, list scheduling anomalies exist for DAGs
            // with dependencies, but for independent tasks more slots never
            // hurt.
            if b > a + 1e-9 {
                return Err(format!("k={k}: {a} -> k+1: {b}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_heap_equals_linear() {
        forall("makespan-heap-equals-linear", 120, |g| {
            let d = g.vec(120, |g| g.f64(0.0, 8.0));
            if d.is_empty() {
                return Ok(());
            }
            let k = (g.usize(120) + 1).min(d.len());
            if makespan_linear(&d, k) != makespan_heap(&d, k) {
                return Err(format!("heap/linear diverge at k={k}"));
            }
            Ok(())
        });
    }
}
