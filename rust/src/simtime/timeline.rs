//! Per-task virtual-time ledger.

use std::collections::BTreeMap;
use std::fmt;

/// Where a task spent its (virtual) time. The breakdown mirrors the
/// paper's discussion: S3 streaming dominates, SQS round trips explain
//  Flint's shuffle sensitivity, pipe overhead explains PySpark.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Component {
    /// Lambda container cold-start provisioning.
    ColdStart,
    /// Warm container dispatch latency.
    WarmStart,
    /// Request payload decode / task deserialization.
    PayloadDecode,
    /// Streaming reads from the object store.
    S3Read,
    /// Writes to the object store (results, spilled payloads).
    S3Write,
    /// Sending shuffle message batches.
    SqsSend,
    /// Receiving/draining shuffle message batches.
    SqsReceive,
    /// Real, measured compute (parse + kernels).
    Compute,
    /// Injected straggler slowdown: extra virtual time a slow container
    /// spends over its normal billed duration (heavy-tail injection).
    Straggler,
    /// Per-record JVM↔Python serialization (PySpark baseline only).
    PipeOverhead,
    /// Driver-side work between stages.
    Scheduler,
    /// Anything else (response encode, cleanup, ...).
    Other,
}

impl Component {
    pub const ALL: [Component; 12] = [
        Component::ColdStart,
        Component::WarmStart,
        Component::PayloadDecode,
        Component::S3Read,
        Component::S3Write,
        Component::SqsSend,
        Component::SqsReceive,
        Component::Compute,
        Component::Straggler,
        Component::PipeOverhead,
        Component::Scheduler,
        Component::Other,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Component::ColdStart => "cold_start",
            Component::WarmStart => "warm_start",
            Component::PayloadDecode => "payload_decode",
            Component::S3Read => "s3_read",
            Component::S3Write => "s3_write",
            Component::SqsSend => "sqs_send",
            Component::SqsReceive => "sqs_receive",
            Component::Compute => "compute",
            Component::Straggler => "straggler",
            Component::PipeOverhead => "pipe_overhead",
            Component::Scheduler => "scheduler",
            Component::Other => "other",
        }
    }
}

/// Accumulated virtual time, broken down by component. Cheap to merge;
/// a task carries one, a stage aggregates many.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Timeline {
    parts: BTreeMap<Component, f64>,
}

impl Timeline {
    pub fn new() -> Timeline {
        Timeline::default()
    }

    /// Charge `secs` of virtual time to `component`.
    pub fn charge(&mut self, component: Component, secs: f64) {
        debug_assert!(secs >= 0.0, "negative time charge: {secs}");
        if secs > 0.0 {
            *self.parts.entry(component).or_insert(0.0) += secs;
        }
    }

    /// Total virtual duration of this timeline.
    pub fn total(&self) -> f64 {
        self.parts.values().sum()
    }

    pub fn get(&self, component: Component) -> f64 {
        self.parts.get(&component).copied().unwrap_or(0.0)
    }

    /// Merge another timeline into this one (component-wise sum).
    pub fn merge(&mut self, other: &Timeline) {
        for (c, v) in &other.parts {
            *self.parts.entry(*c).or_insert(0.0) += v;
        }
    }

    /// Non-zero components in a stable order.
    pub fn breakdown(&self) -> Vec<(Component, f64)> {
        self.parts.iter().map(|(c, v)| (*c, *v)).collect()
    }

    /// Fraction of total attributable to `component` (0 if empty).
    pub fn share(&self, component: Component) -> f64 {
        let total = self.total();
        if total <= 0.0 {
            0.0
        } else {
            self.get(component) / total
        }
    }
}

impl fmt::Display for Timeline {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}s [", self.total())?;
        for (i, (c, v)) in self.breakdown().iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{}={:.3}", c.name(), v)?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_and_total() {
        let mut t = Timeline::new();
        t.charge(Component::S3Read, 1.5);
        t.charge(Component::Compute, 0.5);
        t.charge(Component::S3Read, 0.5);
        assert!((t.total() - 2.5).abs() < 1e-12);
        assert!((t.get(Component::S3Read) - 2.0).abs() < 1e-12);
        assert_eq!(t.get(Component::SqsSend), 0.0);
    }

    #[test]
    fn merge_sums_components() {
        let mut a = Timeline::new();
        a.charge(Component::Compute, 1.0);
        let mut b = Timeline::new();
        b.charge(Component::Compute, 2.0);
        b.charge(Component::ColdStart, 0.25);
        a.merge(&b);
        assert!((a.get(Component::Compute) - 3.0).abs() < 1e-12);
        assert!((a.get(Component::ColdStart) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn zero_charges_ignored() {
        let mut t = Timeline::new();
        t.charge(Component::Other, 0.0);
        assert_eq!(t.breakdown().len(), 0);
    }

    #[test]
    fn share_computation() {
        let mut t = Timeline::new();
        t.charge(Component::S3Read, 3.0);
        t.charge(Component::Compute, 1.0);
        assert!((t.share(Component::S3Read) - 0.75).abs() < 1e-12);
        assert_eq!(Timeline::new().share(Component::Compute), 0.0);
    }

    #[test]
    fn display_is_readable() {
        let mut t = Timeline::new();
        t.charge(Component::Compute, 1.0);
        let s = format!("{t}");
        assert!(s.contains("compute=1.000"), "{s}");
    }
}
