//! A small fixed-size thread pool for executing simulated Lambda
//! invocations and cluster executor slots concurrently.
//!
//! tokio is unavailable offline; the coordinator's concurrency needs are
//! simple fan-out/fan-in per stage, which `std::thread` + channels cover.
//! The pool is shared and long-lived (building threads per stage would
//! skew the hot-path profile).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` workers (min 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::Builder::new()
                    .name(format!("flint-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().expect("pool receiver poisoned");
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // Panics in jobs must not kill the worker:
                                // the submitting side observes them through
                                // its result channel instead.
                                let _ = catch_unwind(AssertUnwindSafe(job));
                                queued.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, queued }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("pool channel closed");
    }

    /// Run a closure over each item concurrently and collect results in
    /// input order. Panics in a worker propagate as Err strings.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<Result<R, String>>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx): (Sender<(usize, Result<R, String>)>, Receiver<_>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(item)))
                    .map_err(|e| panic_message(e.as_ref()));
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut results: Vec<Option<Result<R, String>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("pool worker dropped result channel");
            results[i] = Some(r);
        }
        results.into_iter().map(|r| r.expect("all results filled")).collect()
    }
}

/// Run `f` over `items` on up to `workers` scoped threads, preserving
/// input order. Unlike [`ThreadPool::map`], borrows are allowed (no
/// `'static` bound) — the stage driver passes contexts by reference.
/// Panics propagate as `Err(message)` per item.
pub fn scoped_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<Result<R, String>>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<Result<R, String>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let out = catch_unwind(AssertUnwindSafe(|| f(i, &items[i])))
                    .map_err(|e| panic_message(e.as_ref()));
                *results[i].lock().expect("scoped_map slot") = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().expect("slot lock").expect("slot filled"))
        .collect()
}

fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked".to_string()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100u64).collect(), |x| x * 2);
        let vals: Vec<u64> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_concurrently() {
        let pool = ThreadPool::new(8);
        let counter = Arc::new(AtomicU64::new(0));
        let peak = Arc::new(AtomicU64::new(0));
        let c2 = Arc::clone(&counter);
        let p2 = Arc::clone(&peak);
        pool.map((0..32).collect::<Vec<u32>>(), move |_| {
            let now = c2.fetch_add(1, Ordering::SeqCst) + 1;
            p2.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(10));
            c2.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) > 1, "expected parallelism");
    }

    #[test]
    fn panic_is_captured_not_fatal() {
        let pool = ThreadPool::new(2);
        let out = pool.map(vec![1u32, 2, 3], |x| {
            if x == 2 {
                panic!("boom {x}");
            }
            x
        });
        assert_eq!(out[0], Ok(1));
        assert!(out[1].as_ref().unwrap_err().contains("boom"));
        assert_eq!(out[2], Ok(3));
        // Pool still usable after a panic.
        let again = pool.map(vec![10u32], |x| x + 1);
        assert_eq!(again[0], Ok(11));
    }

    #[test]
    fn zero_size_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let out = pool.map(vec![5u8], |x| x);
        assert_eq!(out[0], Ok(5));
    }

    #[test]
    fn scoped_map_preserves_order_and_borrows() {
        let data: Vec<u64> = (0..50).collect();
        let offset = 100u64; // borrowed by the closure, not moved
        let out = scoped_map(&data, 8, |i, x| x * 2 + offset + i as u64 * 0);
        let vals: Vec<u64> = out.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(vals, (0..50).map(|x| x * 2 + 100).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_captures_panics() {
        let data = vec![1u32, 2, 3];
        let out = scoped_map(&data, 2, |_, x| {
            if *x == 2 {
                panic!("bad item");
            }
            *x
        });
        assert_eq!(out[0], Ok(1));
        assert!(out[1].as_ref().unwrap_err().contains("bad item"));
        assert_eq!(out[2], Ok(3));
    }

    #[test]
    fn scoped_map_empty() {
        let out: Vec<Result<u32, String>> = scoped_map(&[] as &[u32], 4, |_, x| *x);
        assert!(out.is_empty());
    }
}
