//! Human-readable formatting for bytes and durations in reports.

/// `1536 → "1.5 KiB"`, `215 * 2^30 → "215.0 GiB"`.
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.1} {}", UNITS[unit])
    }
}

/// Seconds to `"1h 02m"`, `"3m 05s"`, `"12.3s"`, `"45ms"`.
pub fn human_duration(secs: f64) -> String {
    if secs < 0.001 {
        format!("{:.0}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.0}ms", secs * 1e3)
    } else if secs < 60.0 {
        format!("{secs:.1}s")
    } else if secs < 3600.0 {
        let m = (secs / 60.0).floor();
        format!("{m:.0}m {:02.0}s", secs - m * 60.0)
    } else {
        let h = (secs / 3600.0).floor();
        let m = ((secs - h * 3600.0) / 60.0).floor();
        format!("{h:.0}h {m:02.0}m")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(0), "0 B");
        assert_eq!(human_bytes(999), "999 B");
        assert_eq!(human_bytes(1536), "1.5 KiB");
        assert_eq!(human_bytes(215 * 1024 * 1024 * 1024), "215.0 GiB");
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(human_duration(0.0000005), "0us");
        assert_eq!(human_duration(0.045), "45ms");
        assert_eq!(human_duration(12.34), "12.3s");
        assert_eq!(human_duration(185.0), "3m 05s");
        assert_eq!(human_duration(3720.0), "1h 02m");
    }
}
