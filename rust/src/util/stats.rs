//! Summary statistics for the bench harness: mean, standard deviation,
//! 95% confidence intervals (Student t for the small trial counts the
//! paper uses — 5 Flint trials, 3 cluster trials), and percentiles.

/// Summary of a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    /// Half-width of the 95% confidence interval of the mean.
    pub ci95: f64,
}

/// Two-sided 95% Student-t critical values by degrees of freedom (1..=30);
/// beyond 30 we use the normal 1.96.
const T95: [f64; 30] = [
    12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179, 2.160,
    2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064, 2.060, 2.056,
    2.052, 2.048, 2.045, 2.042,
];

pub fn t95(df: usize) -> f64 {
    if df == 0 {
        f64::INFINITY
    } else if df <= 30 {
        T95[df - 1]
    } else {
        1.96
    }
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n as f64 - 1.0)
        } else {
            0.0
        };
        let std = var.sqrt();
        let ci95 = if n > 1 { t95(n - 1) * std / (n as f64).sqrt() } else { 0.0 };
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Summary { n, mean, std, min, max, ci95 }
    }

    /// The paper's Table I style: `mean [lo - hi]`. Integer rendering at
    /// paper magnitudes; two decimals for small (measured-mode) values.
    pub fn fmt_ci(&self, unit_scale: f64) -> String {
        let digits: usize = if self.mean * unit_scale < 10.0 { 2 } else { 0 };
        format!(
            "{:.digits$} [{:.digits$} - {:.digits$}]",
            self.mean * unit_scale,
            (self.mean - self.ci95) * unit_scale,
            (self.mean + self.ci95) * unit_scale,
        )
    }
}

/// Percentile with linear interpolation (p in [0,100]). Sorts a copy.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() as f64 - 1.0);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        // t(4) = 2.776
        let expect = 2.776 * (2.5f64).sqrt() / (5f64).sqrt();
        assert!((s.ci95 - expect).abs() < 1e-9);
    }

    #[test]
    fn single_sample_has_zero_ci() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.ci95, 0.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.mean, 7.0);
    }

    #[test]
    fn percentiles() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&v, 0.0), 10.0);
        assert_eq!(percentile(&v, 100.0), 40.0);
        assert!((percentile(&v, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn t_table_monotone_towards_normal() {
        assert!(t95(1) > t95(2));
        assert!(t95(30) > t95(31));
        assert_eq!(t95(1000), 1.96);
    }

    #[test]
    fn fmt_ci_matches_paper_style() {
        let s = Summary::of(&[100.0, 102.0, 101.0, 99.0, 103.0]);
        let text = s.fmt_ci(1.0);
        assert!(text.starts_with("101 ["), "{text}");
    }
}
