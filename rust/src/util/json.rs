//! Minimal JSON value model, encoder, and recursive-descent parser.
//!
//! Used for the Lambda request/response payloads (the paper serializes
//! task descriptors into the invocation payload), config files, and the
//! bench harness's machine-readable reports. `serde` is not available in
//! the offline vendor set, so this is self-contained.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON document node. Object keys are ordered (BTreeMap) so encoded
/// payloads are byte-stable — payload-size accounting and dedup hashing
/// rely on that.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder-style insert; panics if `self` is not an object.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as u64) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors used by payload decoding; errors carry the
    /// key name so malformed payloads are diagnosable.
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, JsonError> {
        self.get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    pub fn req_i64(&self, key: &str) -> Result<i64, JsonError> {
        self.get(key)
            .and_then(Json::as_i64)
            .ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    /// Serialize to a compact string.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(JsonError::Trailing(p.pos));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum JsonError {
    Eof,
    Unexpected(usize, char),
    Trailing(usize),
    BadNumber(usize),
    BadEscape(usize),
    Missing(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Eof => write!(f, "unexpected end of input"),
            JsonError::Unexpected(pos, byte) => {
                write!(f, "unexpected byte {byte:?} at offset {pos}")
            }
            JsonError::Trailing(pos) => write!(f, "trailing characters at offset {pos}"),
            JsonError::BadNumber(pos) => write!(f, "invalid number at offset {pos}"),
            JsonError::BadEscape(pos) => write!(f, "invalid string escape at offset {pos}"),
            JsonError::Missing(field) => write!(f, "missing or mistyped field `{field}`"),
        }
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8, JsonError> {
        self.bytes.get(self.pos).copied().ok_or(JsonError::Eof)
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        let got = self.peek()?;
        if got != b {
            return Err(JsonError::Unexpected(self.pos, got as char));
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(self.pos, c as char)),
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(JsonError::Unexpected(self.pos, self.bytes[self.pos] as char))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| b.is_ascii_digit() || matches!(b, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(JsonError::BadNumber(start))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(JsonError::Eof);
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| JsonError::BadEscape(self.pos))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::BadEscape(self.pos))?;
                            self.pos += 4;
                            // Surrogate pairs: accept and combine.
                            let c = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = std::str::from_utf8(
                                        &self.bytes[self.pos + 2..self.pos + 6],
                                    )
                                    .map_err(|_| JsonError::BadEscape(self.pos))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| JsonError::BadEscape(self.pos))?;
                                    self.pos += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(JsonError::BadEscape(self.pos));
                                }
                            } else {
                                code
                            };
                            out.push(char::from_u32(c).ok_or(JsonError::BadEscape(self.pos))?);
                        }
                        _ => return Err(JsonError::BadEscape(self.pos - 1)),
                    }
                }
                _ => {
                    // Re-decode UTF-8 from the raw byte stream.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    if start + len > self.bytes.len() {
                        return Err(JsonError::Eof);
                    }
                    let s = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| JsonError::BadEscape(start))?;
                    out.push_str(s);
                    self.pos = start + len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => return Err(JsonError::Unexpected(self.pos, c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => return Err(JsonError::Unexpected(self.pos, c as char)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.encode())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj()
            .set("task", 3u64)
            .set("stage", 1u64)
            .set("name", "flint")
            .set("ok", true)
            .set("ratio", 0.5)
            .set("items", Json::Arr(vec![Json::from(1u64), Json::from(2u64)]));
        let text = j.encode();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "x\ny"}, null], "c": -2.5e3}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_f64(), Some(-2500.0));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[1].get("b").unwrap().as_str(), Some("x\ny"));
        assert_eq!(arr[2], Json::Null);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(matches!(Json::parse("{} x"), Err(JsonError::Trailing(_))));
    }

    #[test]
    fn rejects_truncated() {
        assert!(Json::parse(r#"{"a": "#).is_err());
        assert!(Json::parse(r#"["#).is_err());
        assert!(Json::parse(r#""abc"#).is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "tab\t quote\" backslash\\ newline\n unicode \u{1F600} ctrl\u{1}";
        let j = Json::Str(s.to_string());
        assert_eq!(Json::parse(&j.encode()).unwrap().as_str(), Some(s));
    }

    #[test]
    fn surrogate_pair_parses() {
        let j = Json::parse(r#""😀""#).unwrap();
        assert_eq!(j.as_str(), Some("\u{1F600}"));
    }

    #[test]
    fn integers_encode_without_fraction() {
        assert_eq!(Json::from(42u64).encode(), "42");
        assert_eq!(Json::from(-3i64).encode(), "-3");
        assert_eq!(Json::from(1.5).encode(), "1.5");
    }

    #[test]
    fn required_field_errors_name_the_key() {
        let j = Json::obj().set("a", 1u64);
        let err = j.req_str("missing").unwrap_err();
        assert_eq!(err, JsonError::Missing("missing".into()));
    }

    #[test]
    fn encoding_is_stable() {
        // BTreeMap ordering => byte-stable output regardless of insert order.
        let a = Json::obj().set("z", 1u64).set("a", 2u64);
        let b = Json::obj().set("a", 2u64).set("z", 1u64);
        assert_eq!(a.encode(), b.encode());
    }
}
