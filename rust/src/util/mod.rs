//! Small self-contained utilities.
//!
//! The build environment is fully offline with a narrow vendored crate set
//! (no `rand`, `serde`, `clap`, `proptest`, `criterion`), so this module
//! carries minimal in-house replacements: a PCG RNG, a JSON codec, summary
//! statistics, a scoped thread pool, and a property-testing harness.

pub mod humansize;
pub mod idgen;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod threadpool;

pub use humansize::{human_bytes, human_duration};
pub use idgen::IdGen;
pub use rng::Pcg64;
pub use stats::Summary;
pub use threadpool::ThreadPool;

/// FNV-1a 64-bit hash, used wherever the paper's system needs a stable,
/// portable hash (hash partitioning, dedup keys). Deliberately independent
/// of `std::hash` so partition assignment is reproducible across runs and
/// platforms.
#[inline]
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Stable hash of an i64 key (the common shuffle key type).
#[inline]
pub fn hash_i64(k: i64) -> u64 {
    fnv1a64(&k.to_le_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        // Published FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn hash_i64_distinct() {
        let a = hash_i64(0);
        let b = hash_i64(1);
        let c = hash_i64(-1);
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert_ne!(a, c);
    }
}
