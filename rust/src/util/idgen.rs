//! Monotonic id generation for requests, invocations, messages, and
//! shuffle sequence numbers. Thread-safe; ids are unique per generator.

use std::sync::atomic::{AtomicU64, Ordering};

/// A thread-safe monotonically increasing id source.
#[derive(Debug, Default)]
pub struct IdGen {
    next: AtomicU64,
}

impl IdGen {
    pub fn new() -> IdGen {
        IdGen { next: AtomicU64::new(0) }
    }

    /// Allocate the next id.
    pub fn next(&self) -> u64 {
        self.next.fetch_add(1, Ordering::Relaxed)
    }

    /// Number of ids allocated so far.
    pub fn issued(&self) -> u64 {
        self.next.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sequential_ids() {
        let g = IdGen::new();
        assert_eq!(g.next(), 0);
        assert_eq!(g.next(), 1);
        assert_eq!(g.issued(), 2);
    }

    #[test]
    fn unique_across_threads() {
        let g = Arc::new(IdGen::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let g = Arc::clone(&g);
            handles.push(std::thread::spawn(move || {
                (0..1000).map(|_| g.next()).collect::<Vec<_>>()
            }));
        }
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 8000, "no duplicate ids");
    }
}
