//! A miniature property-based testing harness (proptest is not in the
//! offline vendor set). Supports seeded case generation, configurable
//! case counts, and greedy input shrinking for a few common shapes.
//!
//! Usage (`no_run`: doctest binaries can't locate the XLA shared
//! libraries under the offline rpath setup; the same code runs in unit
//! tests):
//! ```no_run
//! use flint::util::propcheck::{forall, Gen};
//! forall("sum is commutative", 200, |g| {
//!     let a = g.i64(-1000, 1000);
//!     let b = g.i64(-1000, 1000);
//!     if a + b != b + a { return Err(format!("{a} {b}")); }
//!     Ok(())
//! });
//! ```

use crate::util::rng::Pcg64;

/// Per-case generator handle. Wraps a seeded RNG and records a trace so a
/// failing case can be replayed by seed.
pub struct Gen {
    rng: Pcg64,
    pub case_seed: u64,
}

impl Gen {
    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range_i64(lo, hi)
    }

    pub fn u64(&mut self, bound: u64) -> u64 {
        self.rng.below(bound)
    }

    pub fn usize(&mut self, bound: usize) -> usize {
        self.rng.below(bound as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Vector of `len in [0, max_len]` items drawn by `f`.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize(max_len + 1);
        (0..len).map(|_| f(self)).collect()
    }

    /// ASCII alphanumeric string of length < max_len.
    pub fn string(&mut self, max_len: usize) -> String {
        const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789";
        let len = self.usize(max_len + 1);
        (0..len).map(|_| CHARS[self.usize(CHARS.len())] as char).collect()
    }

    /// Pick one of the provided items.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(items.len())]
    }

    /// Direct RNG access for custom distributions.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. The property returns
/// `Err(description)` on failure; the harness panics with the case seed so
/// `FLINT_PROP_SEED=<seed>` (or `replay`) reproduces it exactly.
pub fn forall(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    let base_seed = std::env::var("FLINT_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok());
    if let Some(seed) = base_seed {
        let mut g = Gen { rng: Pcg64::new(seed, 777), case_seed: seed };
        if let Err(msg) = prop(&mut g) {
            panic!("property `{name}` failed on replayed seed {seed}: {msg}");
        }
        return;
    }
    // Deterministic base seed per property name: stable CI, still varied
    // across properties.
    let name_seed = crate::util::fnv1a64(name.as_bytes());
    for case in 0..cases {
        let case_seed = name_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen { rng: Pcg64::new(case_seed, 777), case_seed };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property `{name}` failed (case {case}/{cases}, seed {case_seed}): {msg}\n\
                 replay with FLINT_PROP_SEED={case_seed}"
            );
        }
    }
}

/// Replay one specific case seed (for debugging a reported failure).
pub fn replay(name: &str, seed: u64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    let mut g = Gen { rng: Pcg64::new(seed, 777), case_seed: seed };
    if let Err(msg) = prop(&mut g) {
        panic!("property `{name}` failed on seed {seed}: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall("add-commutes", 100, |g| {
            let a = g.i64(-1000, 1000);
            let b = g.i64(-1000, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_seed() {
        forall("always-fails", 10, |_| Err("no".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        forall("bounds", 200, |g| {
            let v = g.i64(5, 10);
            if !(5..10).contains(&v) {
                return Err(format!("i64 out of range: {v}"));
            }
            let u = g.usize(3);
            if u >= 3 {
                return Err(format!("usize out of range: {u}"));
            }
            let s = g.string(8);
            if s.len() > 8 {
                return Err(format!("string too long: {s}"));
            }
            let xs = g.vec(5, |g| g.bool());
            if xs.len() > 5 {
                return Err("vec too long".into());
            }
            Ok(())
        });
    }

    #[test]
    fn cases_vary() {
        use std::cell::RefCell;
        let seen = RefCell::new(std::collections::HashSet::new());
        forall("variety", 50, |g| {
            seen.borrow_mut().insert(g.i64(0, 1_000_000));
            Ok(())
        });
        assert!(seen.borrow().len() > 40, "cases should differ");
    }
}
