//! PCG-XSH-RR 64/32 pseudo-random generator (O'Neill 2014), plus the
//! distribution helpers the data generator and failure injector need.
//!
//! Offline build: the `rand` crate is unavailable, and determinism across
//! runs matters more than cryptographic quality here — every simulated
//! component is seeded explicitly so experiments replay bit-identically.

/// A 64-bit-state PCG generator producing 32-bit outputs (combined for 64).
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Distinct streams
    /// with the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    #[inline]
    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire reduction).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)` (i64).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-12 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda`.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.f64();
        -u.ln() / lambda
    }

    /// Pick an index from cumulative weights (caller guarantees the last
    /// entry is the total weight > 0).
    pub fn pick_cumulative(&mut self, cum: &[f64]) -> usize {
        let total = *cum.last().expect("non-empty weights");
        let x = self.f64() * total;
        match cum.binary_search_by(|w| w.partial_cmp(&x).unwrap()) {
            Ok(i) => (i + 1).min(cum.len() - 1),
            Err(i) => i.min(cum.len() - 1),
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Pcg64::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Pcg64::seeded(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean ~0.5, got {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn chance_frequency() {
        let mut r = Pcg64::seeded(13);
        let hits = (0..10_000).filter(|_| r.chance(0.25)).count();
        let p = hits as f64 / 10_000.0;
        assert!((p - 0.25).abs() < 0.02, "p {p}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely identity");
    }

    #[test]
    fn pick_cumulative_respects_weights() {
        let mut r = Pcg64::seeded(19);
        // weights 1, 3 -> cum [1.0, 4.0]; expect ~25% index 0.
        let cum = [1.0, 4.0];
        let zeros = (0..10_000).filter(|_| r.pick_cumulative(&cum) == 0).count();
        let p = zeros as f64 / 10_000.0;
        assert!((p - 0.25).abs() < 0.02, "p {p}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Pcg64::seeded(23);
        let n = 20_000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
