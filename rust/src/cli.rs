//! Minimal command-line parsing (clap is not in the offline vendor set).
//!
//! Grammar: `flint <command> [positional ...] [--key value | --key=value
//! | --flag] ...`. Repeated `--set k=v` accumulate into config
//! overrides. Positional operands (e.g. the query text of `flint sql
//! "SELECT …"`) are collected in order; commands that take none reject
//! them at dispatch.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    /// Bare operands after the command, in order.
    pub positional: Vec<String>,
    pub options: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse(raw: impl Iterator<Item = String>) -> Result<Args, String> {
        let mut args = Args::default();
        let mut raw = raw.peekable();
        if let Some(first) = raw.peek() {
            if !first.starts_with("--") {
                args.command = raw.next();
            }
        }
        while let Some(tok) = raw.next() {
            let Some(key) = tok.strip_prefix("--") else {
                args.positional.push(tok);
                continue;
            };
            if let Some((k, v)) = key.split_once('=') {
                args.options.entry(k.to_string()).or_default().push(v.to_string());
            } else if raw.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                let v = raw.next().expect("peeked");
                args.options.entry(key.to_string()).or_default().push(v);
            } else {
                // Bare flag.
                args.options.entry(key.to_string()).or_default().push(String::new());
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    /// Last value of an option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(|v| v.last()).map(String::as_str)
    }

    /// All values of a repeatable option.
    pub fn all(&self, key: &str) -> &[String] {
        self.options.get(key).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Presence of a bare flag (or any value).
    pub fn flag(&self, key: &str) -> bool {
        self.options.contains_key(key)
    }

    /// Typed option with default.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("bad value `{v}` for --{key}")),
        }
    }

    /// Config overrides from repeated `--set k=v`.
    pub fn overrides(&self) -> Result<Vec<(String, String)>, String> {
        self.all("set")
            .iter()
            .map(|kv| {
                kv.split_once('=')
                    .map(|(k, v)| (k.to_string(), v.to_string()))
                    .ok_or_else(|| format!("--set expects key=value, got `{kv}`"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn command_and_options() {
        let a = parse("table1 --trips 50000 --paper --set sim.max_concurrency=40");
        assert_eq!(a.command.as_deref(), Some("table1"));
        assert_eq!(a.get("trips"), Some("50000"));
        assert!(a.flag("paper"));
        assert!(!a.flag("missing"));
        assert_eq!(a.overrides().unwrap(), vec![("sim.max_concurrency".into(), "40".into())]);
    }

    #[test]
    fn equals_form_and_repeats() {
        let a = parse("run --query=Q1 --set a=1 --set b=2");
        assert_eq!(a.get("query"), Some("Q1"));
        assert_eq!(a.all("set").len(), 2);
    }

    #[test]
    fn typed_defaults() {
        let a = parse("run --trips 10");
        assert_eq!(a.get_parsed("trips", 5u64).unwrap(), 10);
        assert_eq!(a.get_parsed("other", 7u64).unwrap(), 7);
        assert!(parse("run --trips xyz").get_parsed("trips", 0u64).is_err());
    }

    #[test]
    fn collects_positionals() {
        // One shell-quoted operand arrives as one element (the `flint
        // sql "<query>"` path); commands that take no operands check
        // `positional` at dispatch and reject.
        let a = Args::parse(
            ["sql".into(), "SELECT COUNT(*) FROM trips".into(), "--trips".into(), "9".into()]
                .into_iter(),
        )
        .unwrap();
        assert_eq!(a.command.as_deref(), Some("sql"));
        assert_eq!(a.positional, vec!["SELECT COUNT(*) FROM trips"]);
        assert_eq!(a.get("trips"), Some("9"));
        let b = Args::parse(["run".into(), "oops".into()].into_iter()).unwrap();
        assert_eq!(b.positional, vec!["oops"]);
    }

    #[test]
    fn bad_set_reports() {
        assert!(parse("run --set novalue").overrides().is_err());
    }
}
