//! Shuffle transport: how intermediate `(key, value)` data moves between
//! stages.
//!
//! Three backends behind one interface:
//! * **SQS** — the paper's design (§III-A): one queue per reduce
//!   partition; map tasks flush message batches, reduce tasks drain.
//! * **S3** — the Qubole alternative the paper contrasts with (§V/§VI):
//!   one object per flush under a partition prefix; reducers list + get.
//! * **Memory** — the cluster baseline's local shuffle (bytes/second
//!   model of Spark's disk+network path).
//!
//! Shuffle streams are keyed **per DAG edge** (producer stage →
//! consumer stage), not per producer: a stage whose output is shared by
//! several consumers (`plan::lower`'s shared sub-lineages) writes each
//! partition's messages once per consuming edge, so every consumer
//! drains its own copy even on destructive-read backends, and the
//! scheduler tears an edge's queues down the moment *its* consumer
//! finishes — no cross-consumer refcounting.
//!
//! Determinism contract (what makes §VI dedup sound): a task's shuffle
//! output — record order, message boundaries, sequence numbers — is a
//! pure function of its input, never of timing. Buffers flush on byte
//! thresholds; a re-executed attempt therefore re-sends byte-identical
//! `(producer, seq)` messages (`producer_id` stays keyed by
//! (stage, task), never by attempt) and the reduce side drops duplicates
//! of all three kinds — SQS at-least-once redelivery, retry re-sends,
//! and **speculative backup attempts** racing their primary — with one
//! mechanism. Executors seal an attempt's complete output through this
//! layer *before* acking the input it was derived from, so a cancelled
//! or crashed attempt never leaves a torn partition behind.

use crate::compute::value::Value;
use crate::config::ShuffleCodec;
use crate::data::SHUFFLE_BUCKET;
use crate::services::{Message, S3Error, SimEnv};
use crate::simtime::{Component, Timeline};
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, HashSet};
use std::sync::{Arc, Mutex};

/// A shuffle record: the typed kernel path ships `(bucket, sum, count)`;
/// the generic path ships encoded [`Value`] pairs. The two `*Chunk`
/// variants are the columnar wire format (`flint.shuffle.codec =
/// columnar`): a sorted run of kernel partials rides as delta-encoded
/// key + column arrays, a run of dyn pairs as front-coded encodings.
/// Readers decode all four tags regardless of the writer's codec, so
/// mixed streams (e.g. across a rolling config change) stay readable.
#[derive(Debug, Clone, PartialEq)]
pub enum ShuffleRec {
    Kernel { key: i64, sum: f64, count: f64 },
    Dyn { pair: Value },
    /// Columnar run of kernel partials (parallel columns, same length).
    Chunk { keys: Vec<i64>, sums: Vec<f64>, counts: Vec<f64> },
    /// Columnar run of dyn pairs: each element is one pair's full
    /// [`Value`] encoding (stored raw so front-coding and byte
    /// accounting need no re-encode; validated back to values on decode).
    DynChunk { encs: Vec<Vec<u8>> },
}

/// `Chunk` flag bits: which compressed layout each value column uses.
const CHUNK_COUNTS_VARINT: u8 = 1;
const CHUNK_SUMS_EQ_COUNTS: u8 = 2;
const CHUNK_SUMS_VARINT: u8 = 4;
const CHUNK_FLAGS_MASK: u8 = CHUNK_COUNTS_VARINT | CHUNK_SUMS_EQ_COUNTS | CHUNK_SUMS_VARINT;

/// LEB128 varint encode.
fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn varint_len(mut v: u64) -> usize {
    let mut n = 1;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n
}

fn get_varint(bytes: &[u8], pos: &mut usize) -> Option<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *bytes.get(*pos)?;
        *pos += 1;
        if shift == 63 && (b & 0x7f) > 1 {
            return None; // overflows u64
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Some(v);
        }
        shift += 7;
        if shift > 63 {
            return None;
        }
    }
}

fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

/// A f64 that is exactly a small non-negative integer (varint-safe:
/// `(x as u64) as f64 == x`). Rejects -0.0, NaN, infinities, and
/// anything above 2^53 so the roundtrip is bit-exact.
fn small_uint(x: f64) -> Option<u64> {
    if x.is_sign_positive() && x.fract() == 0.0 && x <= 9_007_199_254_740_992.0 {
        Some(x as u64)
    } else {
        None
    }
}

/// Pick the cheapest lossless layout for a chunk's value columns.
fn chunk_flags(sums: &[f64], counts: &[f64]) -> u8 {
    let mut flags = 0u8;
    if counts.iter().all(|&c| small_uint(c).is_some()) {
        flags |= CHUNK_COUNTS_VARINT;
    }
    if sums.len() == counts.len()
        && sums.iter().zip(counts).all(|(s, c)| s.to_bits() == c.to_bits())
    {
        // The common `count(*)`-style queries (value source One) ship
        // sum == count per key; the sums column vanishes entirely.
        flags |= CHUNK_SUMS_EQ_COUNTS;
    } else if sums.iter().all(|&s| small_uint(s).is_some()) {
        flags |= CHUNK_SUMS_VARINT;
    }
    flags
}

fn common_prefix(a: &[u8], b: &[u8]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

impl ShuffleRec {
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            ShuffleRec::Kernel { key, sum, count } => {
                out.push(0);
                out.extend_from_slice(&key.to_le_bytes());
                out.extend_from_slice(&sum.to_le_bytes());
                out.extend_from_slice(&count.to_le_bytes());
            }
            ShuffleRec::Dyn { pair } => {
                out.push(1);
                pair.encode_into(out);
            }
            ShuffleRec::Chunk { keys, sums, counts } => {
                assert_eq!(keys.len(), sums.len());
                assert_eq!(keys.len(), counts.len());
                out.push(2);
                let flags = chunk_flags(sums, counts);
                out.push(flags);
                put_varint(out, keys.len() as u64);
                // Keys: zigzag of the first, zigzag deltas after — sorted
                // runs (the writer's case) cost ~1 byte per key, but the
                // codec stays total over any key sequence via wrapping.
                let mut prev = 0i64;
                for (i, &k) in keys.iter().enumerate() {
                    let d = if i == 0 { k } else { k.wrapping_sub(prev) };
                    put_varint(out, zigzag(d));
                    prev = k;
                }
                if flags & CHUNK_COUNTS_VARINT != 0 {
                    for &c in counts {
                        put_varint(out, c as u64);
                    }
                } else {
                    for &c in counts {
                        out.extend_from_slice(&c.to_le_bytes());
                    }
                }
                if flags & CHUNK_SUMS_EQ_COUNTS == 0 {
                    if flags & CHUNK_SUMS_VARINT != 0 {
                        for &s in sums {
                            put_varint(out, s as u64);
                        }
                    } else {
                        for &s in sums {
                            out.extend_from_slice(&s.to_le_bytes());
                        }
                    }
                }
            }
            ShuffleRec::DynChunk { encs } => {
                out.push(3);
                put_varint(out, encs.len() as u64);
                for (i, enc) in encs.iter().enumerate() {
                    if i == 0 {
                        put_varint(out, enc.len() as u64);
                        out.extend_from_slice(enc);
                    } else {
                        // Front-coding: shared prefix with the previous
                        // encoding (sorted map-side combine output shares
                        // pair-tag + key prefixes), then the suffix.
                        let p = common_prefix(&encs[i - 1], enc);
                        put_varint(out, p as u64);
                        put_varint(out, (enc.len() - p) as u64);
                        out.extend_from_slice(&enc[p..]);
                    }
                }
            }
        }
    }

    fn decode(bytes: &[u8]) -> Option<(ShuffleRec, usize)> {
        match *bytes.first()? {
            0 => {
                if bytes.len() < 25 {
                    return None;
                }
                let key = i64::from_le_bytes(bytes[1..9].try_into().ok()?);
                let sum = f64::from_le_bytes(bytes[9..17].try_into().ok()?);
                let count = f64::from_le_bytes(bytes[17..25].try_into().ok()?);
                Some((ShuffleRec::Kernel { key, sum, count }, 25))
            }
            1 => {
                let (pair, n) = Value::decode(&bytes[1..])?;
                Some((ShuffleRec::Dyn { pair }, 1 + n))
            }
            2 => {
                let flags = *bytes.get(1)?;
                if flags & !CHUNK_FLAGS_MASK != 0 {
                    return None;
                }
                let mut pos = 2;
                let n = get_varint(bytes, &mut pos)? as usize;
                // Every key needs at least one byte; bounding n against
                // the remaining bytes keeps garbage from over-allocating.
                if n == 0 || n > bytes.len().saturating_sub(pos) {
                    return None;
                }
                let mut keys = Vec::with_capacity(n);
                let mut prev = 0i64;
                for i in 0..n {
                    let d = unzigzag(get_varint(bytes, &mut pos)?);
                    let k = if i == 0 { d } else { prev.wrapping_add(d) };
                    keys.push(k);
                    prev = k;
                }
                let mut counts = Vec::with_capacity(n);
                if flags & CHUNK_COUNTS_VARINT != 0 {
                    for _ in 0..n {
                        counts.push(get_varint(bytes, &mut pos)? as f64);
                    }
                } else {
                    for _ in 0..n {
                        let raw: [u8; 8] =
                            bytes.get(pos..pos.checked_add(8)?)?.try_into().ok()?;
                        counts.push(f64::from_le_bytes(raw));
                        pos += 8;
                    }
                }
                let sums = if flags & CHUNK_SUMS_EQ_COUNTS != 0 {
                    counts.clone()
                } else if flags & CHUNK_SUMS_VARINT != 0 {
                    let mut sums = Vec::with_capacity(n);
                    for _ in 0..n {
                        sums.push(get_varint(bytes, &mut pos)? as f64);
                    }
                    sums
                } else {
                    let mut sums = Vec::with_capacity(n);
                    for _ in 0..n {
                        let raw: [u8; 8] =
                            bytes.get(pos..pos.checked_add(8)?)?.try_into().ok()?;
                        sums.push(f64::from_le_bytes(raw));
                        pos += 8;
                    }
                    sums
                };
                Some((ShuffleRec::Chunk { keys, sums, counts }, pos))
            }
            3 => {
                let mut pos = 1;
                let n = get_varint(bytes, &mut pos)? as usize;
                if n == 0 || n > bytes.len().saturating_sub(pos) {
                    return None;
                }
                let mut encs: Vec<Vec<u8>> = Vec::with_capacity(n.min(1024));
                for i in 0..n {
                    let enc = if i == 0 {
                        let len = get_varint(bytes, &mut pos)? as usize;
                        let e = bytes.get(pos..pos.checked_add(len)?)?.to_vec();
                        pos += len;
                        e
                    } else {
                        let p = get_varint(bytes, &mut pos)? as usize;
                        let slen = get_varint(bytes, &mut pos)? as usize;
                        let prev = encs.last().expect("i > 0");
                        if p > prev.len() {
                            return None;
                        }
                        let suffix = bytes.get(pos..pos.checked_add(slen)?)?;
                        let mut e = Vec::with_capacity(p + slen);
                        e.extend_from_slice(&prev[..p]);
                        e.extend_from_slice(suffix);
                        pos += slen;
                        e
                    };
                    // Each stored encoding must be exactly one value —
                    // consumers decode these unconditionally.
                    match Value::decode(&enc) {
                        Some((_, used)) if used == enc.len() => {}
                        _ => return None,
                    }
                    encs.push(enc);
                }
                Some((ShuffleRec::DynChunk { encs }, pos))
            }
            _ => None,
        }
    }

    pub fn decode_all(mut bytes: &[u8]) -> Option<Vec<ShuffleRec>> {
        let mut out = Vec::new();
        while !bytes.is_empty() {
            let (rec, n) = ShuffleRec::decode(bytes)?;
            out.push(rec);
            bytes = &bytes[n..];
        }
        Some(out)
    }

    /// Exact wire length, computed without encoding (the byte-aware
    /// chunking in [`ShuffleWriter::write`] asks this per record).
    pub fn encoded_len(&self) -> usize {
        match self {
            ShuffleRec::Kernel { .. } => 25,
            ShuffleRec::Dyn { pair } => 1 + pair.encoded_len(),
            ShuffleRec::Chunk { keys, sums, counts } => {
                let flags = chunk_flags(sums, counts);
                let mut len = 2 + varint_len(keys.len() as u64);
                let mut prev = 0i64;
                for (i, &k) in keys.iter().enumerate() {
                    let d = if i == 0 { k } else { k.wrapping_sub(prev) };
                    len += varint_len(zigzag(d));
                    prev = k;
                }
                len += if flags & CHUNK_COUNTS_VARINT != 0 {
                    counts.iter().map(|&c| varint_len(c as u64)).sum::<usize>()
                } else {
                    8 * counts.len()
                };
                if flags & CHUNK_SUMS_EQ_COUNTS == 0 {
                    len += if flags & CHUNK_SUMS_VARINT != 0 {
                        sums.iter().map(|&s| varint_len(s as u64)).sum::<usize>()
                    } else {
                        8 * sums.len()
                    };
                }
                len
            }
            ShuffleRec::DynChunk { encs } => {
                let mut len = 1 + varint_len(encs.len() as u64);
                for (i, enc) in encs.iter().enumerate() {
                    if i == 0 {
                        len += varint_len(enc.len() as u64) + enc.len();
                    } else {
                        let p = common_prefix(&encs[i - 1], enc);
                        len += varint_len(p as u64)
                            + varint_len((enc.len() - p) as u64)
                            + (enc.len() - p);
                    }
                }
                len
            }
        }
    }
}

/// Cap on entries per packed chunk: keeps a single chunk comfortably
/// inside one sealed message so byte-aware chunking still operates at
/// message granularity.
pub const CHUNK_MAX_RECS: usize = 1024;
/// Byte budget per packed dyn chunk (pair encodings vary wildly).
pub const CHUNK_TARGET_BYTES: usize = 12 * 1024;

/// Pack one partition's run of kernel partials for the wire, in emit
/// order. `Rows` produces the legacy record-per-key stream; `Columnar`
/// packs the same partials, in the same order, into [`ShuffleRec::Chunk`]
/// column runs — reducers see an identical merge stream either way.
pub fn pack_kernel_run(rows: &[(i64, f64, f64)], codec: ShuffleCodec) -> Vec<ShuffleRec> {
    match codec {
        ShuffleCodec::Rows => rows
            .iter()
            .map(|&(key, sum, count)| ShuffleRec::Kernel { key, sum, count })
            .collect(),
        ShuffleCodec::Columnar => rows
            .chunks(CHUNK_MAX_RECS)
            .map(|run| ShuffleRec::Chunk {
                keys: run.iter().map(|r| r.0).collect(),
                sums: run.iter().map(|r| r.1).collect(),
                counts: run.iter().map(|r| r.2).collect(),
            })
            .collect(),
    }
}

/// Pack one partition's run of dyn pairs (already in emit order).
/// `Columnar` groups consecutive pair encodings into front-coded
/// [`ShuffleRec::DynChunk`]s, capped by count and bytes.
pub fn pack_dyn_run(pairs: &[Value], codec: ShuffleCodec) -> Vec<ShuffleRec> {
    match codec {
        ShuffleCodec::Rows => {
            pairs.iter().map(|pair| ShuffleRec::Dyn { pair: pair.clone() }).collect()
        }
        ShuffleCodec::Columnar => {
            let mut out = Vec::new();
            let mut encs: Vec<Vec<u8>> = Vec::new();
            let mut bytes = 0usize;
            for pair in pairs {
                let enc = pair.encode();
                if !encs.is_empty()
                    && (encs.len() >= CHUNK_MAX_RECS || bytes + enc.len() > CHUNK_TARGET_BYTES)
                {
                    out.push(ShuffleRec::DynChunk { encs: std::mem::take(&mut encs) });
                    bytes = 0;
                }
                bytes += enc.len();
                encs.push(enc);
            }
            if !encs.is_empty() {
                out.push(ShuffleRec::DynChunk { encs });
            }
            out
        }
    }
}

/// Decode a [`ShuffleRec::DynChunk`]'s stored pair encodings back to
/// values. Wire-decoded chunks always succeed (each encoding was
/// validated in `decode`); the `Option` guards hand-built chunks.
pub fn dyn_chunk_values(encs: &[Vec<u8>]) -> Option<Vec<Value>> {
    let mut out = Vec::with_capacity(encs.len());
    for enc in encs {
        match Value::decode(enc) {
            Some((v, used)) if used == enc.len() => out.push(v),
            _ => return None,
        }
    }
    Some(out)
}

/// The in-process backend for the cluster baseline. Partitions are
/// keyed per DAG edge: (producer stage, consumer stage, partition).
#[derive(Default)]
pub struct MemoryShuffle {
    parts: Mutex<BTreeMap<(u32, u32, u32), Vec<Message>>>,
    /// Delivered-but-unacked messages, the SQS visibility-timeout
    /// analogue: a reader that dies after draining nacks them back so
    /// its retry sees the data again (without this, a forced reducer
    /// crash on the memory backend silently lost the partition).
    in_flight: Mutex<BTreeMap<(u32, u32, u32), Vec<Message>>>,
}

impl MemoryShuffle {
    pub fn new() -> Arc<MemoryShuffle> {
        Arc::new(MemoryShuffle::default())
    }

    fn push(&self, from: u32, to: u32, part: u32, msg: Message) {
        self.parts
            .lock()
            .expect("mem shuffle")
            .entry((from, to, part))
            .or_default()
            .push(msg);
    }

    fn drain(&self, from: u32, to: u32, part: u32) -> Vec<Message> {
        let msgs = self
            .parts
            .lock()
            .expect("mem shuffle")
            .remove(&(from, to, part))
            .unwrap_or_default();
        if !msgs.is_empty() {
            self.in_flight
                .lock()
                .expect("mem shuffle in-flight")
                .entry((from, to, part))
                .or_default()
                .extend(msgs.iter().cloned());
        }
        msgs
    }

    /// Task success: drop the delivered messages for good.
    fn ack(&self, from: u32, to: u32, part: u32) {
        self.in_flight
            .lock()
            .expect("mem shuffle in-flight")
            .remove(&(from, to, part));
    }

    /// Task failure: return the delivered messages to the partition.
    fn nack(&self, from: u32, to: u32, part: u32) {
        let returned = self
            .in_flight
            .lock()
            .expect("mem shuffle in-flight")
            .remove(&(from, to, part));
        if let Some(msgs) = returned {
            self.parts
                .lock()
                .expect("mem shuffle")
                .entry((from, to, part))
                .or_default()
                .extend(msgs);
        }
    }
}

/// Which transport a writer/reader uses.
#[derive(Clone)]
pub enum Transport {
    Sqs,
    S3,
    Memory(Arc<MemoryShuffle>),
    /// Flock-style payload-inline transport for small edges: partitions
    /// ride the next invocation's request payload (modeled as the
    /// in-process store, free of per-request transport charges), with
    /// overflow past the 6 MB payload cap spilled to the ordinary S3
    /// shuffle prefix. `(producer, seq)` dedup makes the two legs'
    /// union safe.
    Payload(Arc<MemoryShuffle>),
}

impl Transport {
    pub fn name(&self) -> &'static str {
        match self {
            Transport::Sqs => "sqs",
            Transport::S3 => "s3",
            Transport::Memory(_) => "memory",
            Transport::Payload(_) => "payload",
        }
    }

    /// Whether a consumer can re-read this edge after a successful drain
    /// (list-then-get semantics) — what makes a consuming task safe to
    /// speculate: a backup attempt re-reads the same input instead of
    /// racing its primary for destructively-read messages.
    pub fn rereadable(&self) -> bool {
        matches!(self, Transport::S3)
    }
}

/// Per-edge exchange configuration, aligned with a writer's `consumers`
/// list: which transport the edge uses and, for S3 edges, whether the
/// tree exchange's level-1 grouping is active (`Some(consumer_groups)`).
#[derive(Clone)]
pub struct EdgeExchange {
    pub transport: Transport,
    pub tree_groups: Option<u32>,
}

impl EdgeExchange {
    pub fn direct(transport: Transport) -> EdgeExchange {
        EdgeExchange { transport, tree_groups: None }
    }
}

/// Shape of one edge's tree (multi-level) exchange: producers write one
/// combined object per consumer *group* (level 1), then
/// `producer_groups` × `consumer_groups` merge tasks re-partition those
/// into the ordinary per-partition prefixes. √n-sized groups turn the
/// direct exchange's O(P·R) object count into O(P·√R + √P·R).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TreePlan {
    pub producers: u32,
    pub partitions: u32,
    pub producer_groups: u32,
    pub consumer_groups: u32,
}

/// Consumer group of a partition: contiguous ascending ranges, so merged
/// keys (which sort by producer group) preserve the direct exchange's
/// lexicographic (producer, seq) record order exactly.
pub fn consumer_group_of(partition: u32, partitions: u32, groups: u32) -> u32 {
    (partition as u64 * groups as u64 / partitions as u64) as u32
}

/// Decide whether the tree exchange activates for an edge, and with what
/// group counts. `None` below the fan-out threshold (or on degenerate
/// edges): the extra level only pays for itself once per-edge request
/// counts dominate, so small edges stay direct even under
/// `flint.shuffle.exchange = tree`.
pub fn tree_plan(producers: u32, partitions: u32, fanout_threshold: usize) -> Option<TreePlan> {
    if producers < 2 || partitions < 2 {
        return None;
    }
    if (producers.max(partitions) as usize) < fanout_threshold {
        return None;
    }
    Some(TreePlan {
        producers,
        partitions,
        producer_groups: (producers as f64).sqrt().ceil() as u32,
        consumer_groups: (partitions as f64).sqrt().ceil() as u32,
    })
}

/// High bit marks merge-level producer ids. Real producers are
/// `(stage << 32) | task` (`TaskDescriptor::producer_id`) and can never
/// set it, so merged objects share the `p{partition}/` key space without
/// aliasing a producer's dedup identity.
pub const MERGE_PRODUCER_BASE: u64 = 0x8000_0000_0000_0000;

/// Queue name for one DAG edge's partition (plan, producing stage,
/// consuming stage, partition) — created/deleted by the scheduler
/// (§III-A: "queue management is performed by the scheduler").
pub fn queue_name(plan_id: &str, from: u32, to: u32, partition: u32) -> String {
    format!("{plan_id}-s{from}-s{to}-p{partition}")
}

/// S3 prefix for the S3 shuffle backend (same per-edge keying).
pub fn s3_prefix(plan_id: &str, from: u32, to: u32, partition: u32) -> String {
    format!("{plan_id}/s{from}-s{to}/p{partition}/")
}

/// Prefix owning every object of one DAG edge (partition prefixes, tree
/// group prefixes, and attempt temp prefixes alike) — what the
/// scheduler's lifecycle cleanup deletes when the edge's consumer is
/// done.
pub fn s3_edge_prefix(plan_id: &str, from: u32, to: u32) -> String {
    format!("{plan_id}/s{from}-s{to}/")
}

/// Attempt-scoped temp sibling of [`s3_prefix`]: uncommitted objects
/// live here (suffixed `.a{attempt}`) until the writing attempt commits
/// them via atomic rename, so a reader's `p{partition}/` listing can
/// never observe a torn or partial attempt, and racing attempts resolve
/// first-commit-wins per object.
pub fn s3_temp_prefix(plan_id: &str, from: u32, to: u32, partition: u32) -> String {
    format!("{plan_id}/s{from}-s{to}/t{partition}/")
}

/// Level-1 prefix of the tree exchange: producers write combined
/// objects per consumer *group* here; the merge level re-partitions
/// them into the ordinary `p{partition}/` prefixes.
pub fn s3_group_prefix(plan_id: &str, from: u32, to: u32, group: u32) -> String {
    format!("{plan_id}/s{from}-s{to}/g{group}/")
}

/// Temp sibling of [`s3_group_prefix`] (same commit protocol).
pub fn s3_group_temp_prefix(plan_id: &str, from: u32, to: u32, group: u32) -> String {
    format!("{plan_id}/s{from}-s{to}/tg{group}/")
}

/// Frame one sealed message into a tree-exchange combined object:
/// varint(partition), varint(len), body. The producer rides in the
/// object key; per-message seq identity is not needed past level 1
/// because merge output carries merge-level identities.
fn put_frame(out: &mut Vec<u8>, partition: u32, body: &[u8]) {
    put_varint(out, partition as u64);
    put_varint(out, body.len() as u64);
    out.extend_from_slice(body);
}

fn get_frame<'b>(bytes: &'b [u8], pos: &mut usize) -> Option<(u32, &'b [u8])> {
    let partition = get_varint(bytes, pos)?;
    let len = get_varint(bytes, pos)? as usize;
    let body = bytes.get(*pos..pos.checked_add(len)?)?;
    *pos += len;
    Some((u32::try_from(partition).ok()?, body))
}

/// Target message body size: leave headroom under the 256 KB batch cap
/// for wire overhead; ten ~24 KB messages fill one batch call.
const MSG_TARGET_BYTES: usize = 24 * 1024;

/// Map-side shuffle writer for one task. Writes each sealed message to
/// every consuming edge (`consumers`): one send per (edge, partition),
/// so fan-out stages duplicate their stream per consumer while the
/// common single-consumer case stays one send.
pub struct ShuffleWriter<'a> {
    env: &'a SimEnv,
    plan_id: String,
    stage: u32,
    /// Consuming stage ids — the DAG edges this stage's shuffle feeds.
    consumers: Vec<u32>,
    /// Per-edge transport/exchange, aligned with `consumers` (all edges
    /// share the `new()` transport unless overridden via `with_edges`).
    edges: Vec<EdgeExchange>,
    producer: u64,
    partitions: u32,
    /// Attempt number scoping this writer's S3 temp keys (`with_attempt`).
    attempt: u32,
    /// Per-partition encode buffer (records encoded back-to-back).
    bufs: Vec<Vec<u8>>,
    /// Per-partition pending messages awaiting a batch send.
    pending: Vec<Vec<Message>>,
    /// Per-partition next sequence number.
    seqs: Vec<u64>,
    /// Per-edge tree-exchange buffers: consumer group → framed sealed
    /// messages awaiting a level-1 combined-object flush.
    group_bufs: Vec<BTreeMap<u32, Vec<u8>>>,
    /// Staged `(temp key, final key)` renames awaiting commit.
    staged: Vec<(String, String)>,
    /// Per-edge, per-partition bytes already riding the invocation
    /// payload (the Payload transport's 6 MB cap accounting).
    payload_bytes: Vec<Vec<u64>>,
    pub msgs_sent: u64,
    pub bytes_sent: u64,
    /// Bytes sent per consuming edge, aligned with `consumers`.
    edge_bytes: Vec<u64>,
}

impl<'a> ShuffleWriter<'a> {
    pub fn new(
        env: &'a SimEnv,
        transport: Transport,
        plan_id: &str,
        stage: u32,
        consumers: Vec<u32>,
        producer: u64,
        partitions: u32,
        resume_seqs: Option<Vec<u64>>,
    ) -> ShuffleWriter<'a> {
        let seqs = resume_seqs.unwrap_or_else(|| vec![0; partitions as usize]);
        assert_eq!(seqs.len(), partitions as usize);
        let edge_bytes = vec![0; consumers.len()];
        let edges: Vec<EdgeExchange> =
            consumers.iter().map(|_| EdgeExchange::direct(transport.clone())).collect();
        let group_bufs = consumers.iter().map(|_| BTreeMap::new()).collect();
        let payload_bytes = consumers.iter().map(|_| vec![0; partitions as usize]).collect();
        ShuffleWriter {
            env,
            plan_id: plan_id.to_string(),
            stage,
            consumers,
            edges,
            producer,
            partitions,
            attempt: 0,
            bufs: (0..partitions).map(|_| Vec::new()).collect(),
            pending: (0..partitions).map(|_| Vec::new()).collect(),
            seqs,
            group_bufs,
            staged: Vec::new(),
            payload_bytes,
            msgs_sent: 0,
            bytes_sent: 0,
            edge_bytes,
        }
    }

    /// Scope this writer's S3 temp keys to a task attempt: a speculative
    /// backup or retry writes `.a{attempt}` temps and commits through
    /// first-wins renames instead of clobbering the primary's objects.
    pub fn with_attempt(mut self, attempt: u32) -> Self {
        self.attempt = attempt;
        self
    }

    /// Per-edge transport/exchange overrides (auto backend selection and
    /// the tree exchange), aligned with `consumers`.
    pub fn with_edges(mut self, edges: Vec<EdgeExchange>) -> Self {
        assert_eq!(edges.len(), self.consumers.len());
        self.edges = edges;
        self
    }

    /// Bytes sent so far per consuming edge: `(consumer stage, bytes)`.
    pub fn edge_bytes(&self) -> Vec<(u32, u64)> {
        self.consumers.iter().copied().zip(self.edge_bytes.iter().copied()).collect()
    }

    /// Current sequence counters (serialized into chain resume state).
    pub fn seqs(&self) -> Vec<u64> {
        self.seqs.clone()
    }

    /// Approximate buffered bytes (executor memory accounting).
    pub fn buffered_bytes(&self) -> usize {
        self.bufs.iter().map(Vec::len).sum::<usize>()
            + self
                .pending
                .iter()
                .flat_map(|p| p.iter().map(Message::wire_bytes))
                .sum::<usize>()
            + self
                .group_bufs
                .iter()
                .flat_map(|e| e.values().map(Vec::len))
                .sum::<usize>()
    }

    /// Append a record destined for `partition`. Seals a message when the
    /// buffer reaches the deterministic size threshold.
    pub fn write(&mut self, partition: u32, rec: &ShuffleRec, tl: &mut Timeline) -> Result<()> {
        debug_assert!(partition < self.partitions);
        let buf = &mut self.bufs[partition as usize];
        rec.encode_into(buf);
        if buf.len() >= MSG_TARGET_BYTES {
            self.seal(partition);
            // Send when a full batch (10 messages) is pending.
            if self.pending[partition as usize].len() >= self.env.config().sim.sqs_batch_max_msgs
            {
                self.flush_partition(partition, tl)?;
            }
        }
        Ok(())
    }

    fn seal(&mut self, partition: u32) {
        let buf = std::mem::take(&mut self.bufs[partition as usize]);
        if buf.is_empty() {
            return;
        }
        let seq = self.seqs[partition as usize];
        self.seqs[partition as usize] += 1;
        self.pending[partition as usize].push(Message::new(buf, self.producer, seq));
    }

    fn flush_partition(&mut self, partition: u32, tl: &mut Timeline) -> Result<()> {
        let mut msgs = std::mem::take(&mut self.pending[partition as usize]);
        if msgs.is_empty() {
            return Ok(());
        }
        let bytes: usize = msgs.iter().map(Message::wire_bytes).sum();
        // One physical copy per consuming edge: a fan-out stage really
        // does pay the extra sends (and the consumers each drain their
        // own). The last edge takes the buffer by move, so the dominant
        // single-consumer case copies nothing. Zero consumers (a
        // degenerate unconsumed shuffle) sends nothing.
        for ci in 0..self.consumers.len() {
            let to = self.consumers[ci];
            let edge_msgs = if ci + 1 == self.consumers.len() {
                std::mem::take(&mut msgs)
            } else {
                msgs.clone()
            };
            self.msgs_sent += edge_msgs.len() as u64;
            self.bytes_sent += bytes as u64;
            self.edge_bytes[ci] += bytes as u64;
            let transport = self.edges[ci].transport.clone();
            match &transport {
                Transport::Sqs => {
                    // Chunk by message count AND wire bytes: a message seals
                    // only after crossing MSG_TARGET_BYTES, so one big record
                    // (a large Dyn value) makes an oversized message and ten
                    // of them blow the 256 KB per-batch cap if count were the
                    // only limit.
                    let q = queue_name(&self.plan_id, self.stage, to, partition);
                    let max_msgs = self.env.config().sim.sqs_batch_max_msgs;
                    let max_bytes = self.env.config().sim.sqs_batch_max_bytes;
                    let mut batch: Vec<Message> = Vec::new();
                    let mut batch_bytes = 0usize;
                    for m in edge_msgs {
                        let w = m.wire_bytes();
                        if !batch.is_empty()
                            && (batch.len() >= max_msgs || batch_bytes + w > max_bytes)
                        {
                            let dt = self
                                .env
                                .sqs()
                                .send_batch(&q, std::mem::take(&mut batch))
                                .map_err(|e| anyhow!("shuffle send: {e}"))?;
                            tl.charge(Component::SqsSend, dt);
                            batch_bytes = 0;
                        }
                        batch_bytes += w;
                        batch.push(m);
                    }
                    if !batch.is_empty() {
                        let dt = self
                            .env
                            .sqs()
                            .send_batch(&q, batch)
                            .map_err(|e| anyhow!("shuffle send: {e}"))?;
                        tl.charge(Component::SqsSend, dt);
                    }
                }
                Transport::S3 => {
                    if let Some(groups) = self.edges[ci].tree_groups {
                        // Tree exchange level 1: frame the sealed
                        // messages into this partition's consumer-group
                        // buffer; combined objects flush on a byte
                        // threshold and at `flush_all`.
                        let cg = consumer_group_of(partition, self.partitions, groups);
                        let buf = self.group_bufs[ci].entry(cg).or_default();
                        for m in edge_msgs {
                            put_frame(buf, partition, &m.body);
                        }
                        if self.group_bufs[ci][&cg].len() >= GROUP_TARGET_BYTES {
                            self.flush_group(ci, cg, tl)?;
                        }
                    } else {
                        // One object per message-equivalent flush, staged
                        // under the attempt's temp prefix; the key stem
                        // carries the dedup identity so retries commit
                        // idempotently.
                        for m in edge_msgs {
                            self.stage_object(to, partition, m, tl)?;
                        }
                    }
                }
                Transport::Memory(mem) => {
                    let mbps = self.env.config().sim.cluster_shuffle_mbps;
                    tl.charge(Component::Other, bytes as f64 / (mbps * 1e6));
                    for m in edge_msgs {
                        mem.push(self.stage, to, partition, m);
                    }
                }
                Transport::Payload(mem) => {
                    // Inline until the edge-partition's payload budget is
                    // spent (the invocation itself is billed elsewhere;
                    // the ride is free), then spill to the ordinary S3
                    // prefix. Spills commit like any S3 object.
                    let cap = self.env.config().sim.lambda_payload_limit_bytes;
                    for m in edge_msgs {
                        let w = m.wire_bytes() as u64;
                        let used = &mut self.payload_bytes[ci][partition as usize];
                        if *used + w > cap {
                            self.env.metrics().incr("shuffle.payload_spills");
                            self.stage_object(to, partition, m, tl)?;
                        } else {
                            *used += w;
                            mem.push(self.stage, to, partition, m);
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Stage one message as an attempt-scoped S3 temp object; the final
    /// key becomes visible only when [`flush_all`] commits the rename.
    fn stage_object(
        &mut self,
        to: u32,
        partition: u32,
        m: Message,
        tl: &mut Timeline,
    ) -> Result<()> {
        let stem = format!("{:016x}-{:08}", m.producer, m.seq);
        let tmp = format!(
            "{}{stem}.a{}",
            s3_temp_prefix(&self.plan_id, self.stage, to, partition),
            self.attempt
        );
        let dst = format!("{}{stem}", s3_prefix(&self.plan_id, self.stage, to, partition));
        let dt = self
            .env
            .s3()
            .put_object(SHUFFLE_BUCKET, &tmp, m.body)
            .map_err(|e| anyhow!("shuffle put: {e}"))?;
        tl.charge(Component::S3Write, dt);
        self.staged.push((tmp, dst));
        Ok(())
    }

    /// Flush one edge's consumer-group buffer as a level-1 combined
    /// object. The object's sequence number is the sum of the group's
    /// partition seq counters: strictly increasing between flushes
    /// (every flush carries at least one newly sealed message) and
    /// identical on a resumed or retried attempt, so keys are unique
    /// yet retry-idempotent.
    fn flush_group(&mut self, ci: usize, group: u32, tl: &mut Timeline) -> Result<()> {
        let buf = match self.group_bufs[ci].get_mut(&group) {
            Some(b) if !b.is_empty() => std::mem::take(b),
            _ => return Ok(()),
        };
        let to = self.consumers[ci];
        let groups = self.edges[ci].tree_groups.expect("tree edge");
        let gseq: u64 = (0..self.partitions)
            .filter(|&p| consumer_group_of(p, self.partitions, groups) == group)
            .map(|p| self.seqs[p as usize])
            .sum();
        let stem = format!("{:016x}-{:08}", self.producer, gseq);
        let tmp = format!(
            "{}{stem}.a{}",
            s3_group_temp_prefix(&self.plan_id, self.stage, to, group),
            self.attempt
        );
        let dst = format!("{}{stem}", s3_group_prefix(&self.plan_id, self.stage, to, group));
        let dt = self
            .env
            .s3()
            .put_object(SHUFFLE_BUCKET, &tmp, buf)
            .map_err(|e| anyhow!("shuffle group put: {e}"))?;
        tl.charge(Component::S3Write, dt);
        self.staged.push((tmp, dst));
        Ok(())
    }

    /// Commit every staged S3 object: rename temp → final, first commit
    /// wins. A rename whose source vanished lost to a winner's temp
    /// cleanup; both loss shapes are benign because a task's final key
    /// set and bytes are deterministic across attempts.
    fn commit_staged(&mut self, tl: &mut Timeline) -> Result<()> {
        for (src, dst) in std::mem::take(&mut self.staged) {
            match self.env.s3().commit_rename(SHUFFLE_BUCKET, &src, &dst) {
                Ok((dt, _won)) => tl.charge(Component::S3Write, dt),
                Err(S3Error::NoSuchKey(..)) => {}
                Err(e) => return Err(anyhow!("shuffle commit: {e}")),
            }
        }
        Ok(())
    }

    /// Seal, send, and commit everything buffered (end of task or chain
    /// point — either way the attempt's output must be durably visible
    /// before the input it derives from is acked).
    pub fn flush_all(&mut self, tl: &mut Timeline) -> Result<()> {
        for p in 0..self.partitions {
            self.seal(p);
            self.flush_partition(p, tl)?;
        }
        for ci in 0..self.consumers.len() {
            let groups: Vec<u32> = self.group_bufs[ci].keys().copied().collect();
            for g in groups {
                self.flush_group(ci, g, tl)?;
            }
        }
        self.commit_staged(tl)
    }
}

/// Byte threshold for flushing a tree-exchange combined object mid-task
/// (deterministic, like message sealing).
const GROUP_TARGET_BYTES: usize = 256 * 1024;

/// Reduce-side reader outcome.
pub struct ShuffleRead {
    pub records: Vec<ShuffleRec>,
    /// Messages received (pre-dedup).
    pub messages: u64,
    /// Messages dropped as duplicates.
    pub duplicates_dropped: u64,
}

/// Reduce-side reader: drains one partition, deduplicating by
/// `(producer, seq)` when enabled. On success callers `ack`; a failed
/// task's handles are nacked back to the queue by [`ReadGuard::abandon`].
pub struct ShuffleReader<'a> {
    env: &'a SimEnv,
    transport: Transport,
    plan_id: String,
    /// Producing stage (the edge's tail).
    stage: u32,
    /// Consuming stage (the edge's head — the reader's own stage).
    to_stage: u32,
    partition: u32,
    dedup: bool,
    /// SQS receipt handles held until ack.
    receipts: Vec<u64>,
    /// Dedup set, persisted across chain links via resume state.
    pub seen: HashSet<(u64, u64)>,
}

impl<'a> ShuffleReader<'a> {
    pub fn new(
        env: &'a SimEnv,
        transport: Transport,
        plan_id: &str,
        stage: u32,
        to_stage: u32,
        partition: u32,
        dedup: bool,
    ) -> ShuffleReader<'a> {
        ShuffleReader {
            env,
            transport,
            plan_id: plan_id.to_string(),
            stage,
            to_stage,
            partition,
            dedup,
            receipts: Vec::new(),
            seen: HashSet::new(),
        }
    }

    fn queue(&self) -> String {
        queue_name(&self.plan_id, self.stage, self.to_stage, self.partition)
    }

    /// Drain everything currently available. Returns records + stats.
    pub fn drain(&mut self, tl: &mut Timeline) -> Result<ShuffleRead> {
        let mut out = ShuffleRead { records: Vec::new(), messages: 0, duplicates_dropped: 0 };
        match self.transport.clone() {
            Transport::Sqs => loop {
                let (batch, dt) = self
                    .env
                    .sqs()
                    .receive_batch(&self.queue(), self.env.config().sim.sqs_batch_max_msgs)
                    .map_err(|e| anyhow!("shuffle receive: {e}"))?;
                tl.charge(Component::SqsReceive, dt);
                if batch.is_empty() {
                    break;
                }
                for (msg, receipt) in batch {
                    self.receipts.push(receipt);
                    self.take(msg, &mut out)?;
                }
            },
            Transport::S3 => self.drain_s3(&mut out, tl)?,
            Transport::Memory(mem) => {
                let msgs = mem.drain(self.stage, self.to_stage, self.partition);
                let bytes: usize = msgs.iter().map(Message::wire_bytes).sum();
                let mbps = self.env.config().sim.cluster_shuffle_mbps;
                tl.charge(Component::Other, bytes as f64 / (mbps * 1e6));
                for m in msgs {
                    self.take(m, &mut out)?;
                }
            }
            Transport::Payload(mem) => {
                // The inline leg rode the invocation payload — no
                // transport charge of its own. Overflow spilled past the
                // payload cap lives under the ordinary S3 prefix;
                // (producer, seq) dedup makes the two legs' union safe.
                let msgs = mem.drain(self.stage, self.to_stage, self.partition);
                for m in msgs {
                    self.take(m, &mut out)?;
                }
                self.drain_s3(&mut out, tl)?;
            }
        }
        Ok(out)
    }

    /// Drain the edge-partition's S3 prefix (the S3 backend's whole
    /// stream; the Payload backend's spill leg).
    fn drain_s3(&mut self, out: &mut ShuffleRead, tl: &mut Timeline) -> Result<()> {
        let prefix = s3_prefix(&self.plan_id, self.stage, self.to_stage, self.partition);
        let listed = self
            .env
            .s3()
            .list(SHUFFLE_BUCKET, &prefix)
            .map_err(|e| anyhow!("shuffle list: {e}"))?;
        // LIST round trip.
        tl.charge(Component::S3Read, self.env.config().sim.s3_first_byte_s);
        for (key, _) in listed {
            let (obj, dt) = self
                .env
                .s3()
                .get_object(SHUFFLE_BUCKET, &key, self.env.flint_read_profile())
                .map_err(|e| anyhow!("shuffle get: {e}"))?;
            tl.charge(Component::S3Read, dt);
            // Reconstruct dedup identity from the key. A key that
            // does not parse is a hard error: defaulting (the old
            // behaviour) made every malformed/foreign key alias
            // to (0, 0), so dedup silently dropped all but the
            // first such object's records.
            let stem = key.rsplit('/').next().unwrap_or("");
            let (p, s) = stem.split_once('-').ok_or_else(|| {
                anyhow!("shuffle object key {key:?} lacks a producer-seq stem")
            })?;
            let producer = u64::from_str_radix(p, 16).map_err(|e| {
                anyhow!("shuffle object key {key:?} has a bad producer id: {e}")
            })?;
            let seq: u64 = s.parse().map_err(|e| {
                anyhow!("shuffle object key {key:?} has a bad sequence number: {e}")
            })?;
            self.take(Message::new(obj.bytes().to_vec(), producer, seq), out)?;
        }
        Ok(())
    }

    fn take(&mut self, msg: Message, out: &mut ShuffleRead) -> Result<()> {
        out.messages += 1;
        if self.dedup && !self.seen.insert((msg.producer, msg.seq)) {
            out.duplicates_dropped += 1;
            self.env.metrics().incr("shuffle.duplicates_dropped");
            return Ok(());
        }
        let recs = ShuffleRec::decode_all(&msg.body)
            .ok_or_else(|| anyhow!("corrupt shuffle message (producer={})", msg.producer))?;
        out.records.extend(recs);
        Ok(())
    }

    /// Acknowledge everything received (task success): SQS deletes in
    /// batches of 10 — billed requests, exactly like the real API. The
    /// memory backend drops its in-flight copies; S3 objects are owned by
    /// the scheduler's prefix lifecycle and need no per-task ack.
    pub fn ack(&mut self, tl: &mut Timeline) -> Result<()> {
        match &self.transport {
            Transport::Sqs => {
                let q = self.queue();
                for chunk in self.receipts.chunks(10) {
                    let dt = self
                        .env
                        .sqs()
                        .delete_batch(&q, chunk)
                        .map_err(|e| anyhow!("shuffle ack: {e}"))?;
                    tl.charge(Component::SqsReceive, dt);
                }
            }
            Transport::Memory(mem) | Transport::Payload(mem) => {
                mem.ack(self.stage, self.to_stage, self.partition)
            }
            Transport::S3 => {}
        }
        self.receipts.clear();
        Ok(())
    }

    /// Task failed: return in-flight messages to the queue (visibility
    /// timeout semantics) so the retry sees them. The memory backend
    /// mirrors this; the S3 backend's objects persist until the scheduler
    /// tears the prefix down, so a retry re-lists them anyway.
    pub fn abandon(&mut self) {
        match &self.transport {
            Transport::Sqs => {
                let q = self.queue();
                let _ = self.env.sqs().nack(&q, &self.receipts);
            }
            Transport::Memory(mem) | Transport::Payload(mem) => {
                mem.nack(self.stage, self.to_stage, self.partition)
            }
            Transport::S3 => {}
        }
        self.receipts.clear();
    }
}

/// Accounting for one edge's tree-exchange merge level.
#[derive(Debug, Default, Clone)]
pub struct TreeMergeReport {
    /// Modeled duration of each (producer group × consumer group) merge
    /// task. The driver packs these onto the slot pool and folds the
    /// resulting makespan into the producing stage's overhead, so the
    /// event clock sees the extra level's requests and serialization
    /// exactly (the S3 backend pins barrier scheduling, under which the
    /// merge level really does sit between the two stages).
    pub task_durations: Vec<f64>,
    pub objects_read: u64,
    pub objects_written: u64,
    /// Component-wise sum over the merge tasks (folded into the run's
    /// aggregate timeline so the extra level's time is attributed).
    pub timeline: Timeline,
}

/// Run the tree exchange's merge level for one DAG edge: list each
/// consumer group's combined level-1 objects, re-partition their frames,
/// and commit one merged object per (producer group, partition) into the
/// ordinary `p{partition}/` prefix — [`ShuffleReader`] consumes tree
/// output unchanged.
///
/// Record order is preserved exactly. Producer groups are contiguous
/// ascending producer-id ranges and merged keys sort by producer group,
/// so a reader's lexicographic listing replays the direct exchange's
/// (producer asc, seq asc) merge stream — bit-identical results with
/// O(√n) objects per partition instead of O(n).
pub fn merge_tree_level(
    env: &SimEnv,
    plan_id: &str,
    from: u32,
    to: u32,
    plan: &TreePlan,
) -> Result<TreeMergeReport> {
    let mut report = TreeMergeReport::default();
    for cg in 0..plan.consumer_groups {
        let prefix = s3_group_prefix(plan_id, from, to, cg);
        let listed = env
            .s3()
            .list(SHUFFLE_BUCKET, &prefix)
            .map_err(|e| anyhow!("tree merge list: {e}"))?;
        if listed.is_empty() {
            continue;
        }
        // Group the level-1 objects by producer, ascending.
        let mut by_producer: BTreeMap<u64, Vec<(u64, String)>> = BTreeMap::new();
        for (key, _) in listed {
            let stem = key.rsplit('/').next().unwrap_or("");
            let (p, s) = stem.split_once('-').ok_or_else(|| {
                anyhow!("tree level-1 key {key:?} lacks a producer-seq stem")
            })?;
            let producer = u64::from_str_radix(p, 16)
                .map_err(|e| anyhow!("tree level-1 key {key:?} has a bad producer id: {e}"))?;
            let gseq: u64 = s.parse().map_err(|e| {
                anyhow!("tree level-1 key {key:?} has a bad sequence number: {e}")
            })?;
            by_producer.entry(producer).or_default().push((gseq, key));
        }
        let producers: Vec<u64> = by_producer.keys().copied().collect();
        let n = producers.len() as u64;
        let pgs = plan.producer_groups.min(producers.len() as u32).max(1);
        for pg in 0..pgs {
            // Contiguous rank ranges over the observed producers.
            let lo = (pg as u64 * n / pgs as u64) as usize;
            let hi = ((pg as u64 + 1) * n / pgs as u64) as usize;
            if lo == hi {
                continue;
            }
            let mut tl = Timeline::new();
            // Each merge task lists its group prefix once.
            tl.charge(Component::S3Read, env.config().sim.s3_first_byte_s);
            let mut per_part: BTreeMap<u32, Vec<u8>> = BTreeMap::new();
            for producer in &producers[lo..hi] {
                let mut objs = by_producer[producer].clone();
                objs.sort(); // numeric gseq order, robust past 8 digits
                for (_gseq, key) in objs {
                    let (obj, dt) = env
                        .s3()
                        .get_object(SHUFFLE_BUCKET, &key, env.flint_read_profile())
                        .map_err(|e| anyhow!("tree merge get: {e}"))?;
                    tl.charge(Component::S3Read, dt);
                    report.objects_read += 1;
                    let bytes = obj.bytes();
                    let mut pos = 0usize;
                    while pos < bytes.len() {
                        let (part, body) = get_frame(bytes, &mut pos)
                            .ok_or_else(|| anyhow!("corrupt tree frame in {key:?}"))?;
                        per_part.entry(part).or_default().extend_from_slice(body);
                    }
                }
            }
            // One merged object per partition, committed through the
            // same temp + rename protocol as every S3-materializing
            // writer (the merge level is driver-driven and single-
            // attempt, but uniformity keeps partial state invisible).
            let merger = MERGE_PRODUCER_BASE | pg as u64;
            for (part, body) in per_part {
                let stem = format!("{merger:016x}-{part:08}");
                let tmp = format!("{}{stem}.a0", s3_temp_prefix(plan_id, from, to, part));
                let dst = format!("{}{stem}", s3_prefix(plan_id, from, to, part));
                let dt = env
                    .s3()
                    .put_object(SHUFFLE_BUCKET, &tmp, body)
                    .map_err(|e| anyhow!("tree merge put: {e}"))?;
                tl.charge(Component::S3Write, dt);
                let (dt, _won) = env
                    .s3()
                    .commit_rename(SHUFFLE_BUCKET, &tmp, &dst)
                    .map_err(|e| anyhow!("tree merge commit: {e}"))?;
                tl.charge(Component::S3Write, dt);
                report.objects_written += 1;
            }
            report.task_durations.push(tl.total());
            report.timeline.merge(&tl);
        }
    }
    Ok(report)
}

/// Hash-partitioner for kernel records (bucket keys): mirrors Spark's
/// `HashPartitioner` (non-negative modulo of the key hash). Unchanged
/// from the seed so the published queries' partition routing — and with
/// it the Table I makespans — stays byte-stable.
pub fn kernel_partition(key: i64, partitions: u32) -> u32 {
    (crate::util::hash_i64(key) % partitions as u64) as u32
}

/// Partitioner for dynamic pairs. `I64` keys route through
/// [`kernel_partition`]: a dyn stream and a typed kernel stream
/// partitioned on the same i64 join key MUST land in the same reduce
/// partition, or the join stage never sees the two sides together
/// (the cogroup plans `plan::lower` emits and `build_kernel_join_plan`
/// rely on this; pinned by
/// `prop_kernel_and_dyn_partitioners_agree_on_i64`). Other key types
/// hash their stable encoding, as before.
pub fn dyn_partition(key: &Value, partitions: u32) -> u32 {
    if let Some(k) = key.as_i64() {
        return kernel_partition(k, partitions);
    }
    (key.stable_hash() % partitions as u64) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FlintConfig;
    use crate::util::propcheck::forall;

    fn env_with(dup: f64) -> SimEnv {
        let mut cfg = FlintConfig::for_tests();
        cfg.sim.sqs_duplicate_prob = dup;
        let env = SimEnv::new(cfg);
        env.s3().create_bucket(SHUFFLE_BUCKET);
        env
    }

    fn krec(key: i64, count: f64) -> ShuffleRec {
        ShuffleRec::Kernel { key, sum: count, count }
    }

    fn roundtrip(transport: Transport, env: &SimEnv, dedup: bool) -> (Vec<ShuffleRec>, u64) {
        // Writer: 2 partitions, 100 records each, over the s0 -> s1 edge.
        if matches!(transport, Transport::Sqs) {
            for p in 0..2 {
                env.sqs().create_queue(&queue_name("t", 0, 1, p));
            }
        }
        let mut tl = Timeline::new();
        let mut w = ShuffleWriter::new(env, transport.clone(), "t", 0, vec![1], 7, 2, None);
        for i in 0..200i64 {
            w.write((i % 2) as u32, &krec(i, 1.0), &mut tl).unwrap();
        }
        w.flush_all(&mut tl).unwrap();

        let mut all = Vec::new();
        let mut dups = 0;
        for p in 0..2 {
            let mut r = ShuffleReader::new(env, transport.clone(), "t", 0, 1, p, dedup);
            let read = r.drain(&mut tl).unwrap();
            r.ack(&mut tl).unwrap();
            dups += read.duplicates_dropped;
            all.extend(read.records);
        }
        (all, dups)
    }

    #[test]
    fn sqs_roundtrip_delivers_everything_once() {
        let env = env_with(0.0);
        let (recs, dups) = roundtrip(Transport::Sqs, &env, true);
        assert_eq!(recs.len(), 200);
        assert_eq!(dups, 0);
        let keys: std::collections::BTreeSet<i64> = recs
            .iter()
            .map(|r| match r {
                ShuffleRec::Kernel { key, .. } => *key,
                _ => panic!(),
            })
            .collect();
        assert_eq!(keys.len(), 200);
    }

    #[test]
    fn s3_roundtrip_delivers_everything() {
        let env = env_with(0.0);
        let (recs, _) = roundtrip(Transport::S3, &env, true);
        assert_eq!(recs.len(), 200);
    }

    #[test]
    fn memory_roundtrip_delivers_everything() {
        let env = env_with(0.0);
        let (recs, _) = roundtrip(Transport::Memory(MemoryShuffle::new()), &env, false);
        assert_eq!(recs.len(), 200);
    }

    #[test]
    fn dedup_drops_injected_duplicates() {
        let env = env_with(0.5);
        let (recs, dups) = roundtrip(Transport::Sqs, &env, true);
        assert_eq!(recs.len(), 200, "dedup restores exactly-once");
        assert!(dups > 0, "duplicates were actually injected and dropped");
    }

    #[test]
    fn without_dedup_duplicates_leak() {
        let env = env_with(0.5);
        let (recs, _) = roundtrip(Transport::Sqs, &env, false);
        assert!(recs.len() > 200, "at-least-once shows through without §VI dedup");
    }

    #[test]
    fn retry_resends_are_deduped() {
        // Simulate a map-task retry: same producer writes everything twice.
        let env = env_with(0.0);
        env.sqs().create_queue(&queue_name("t", 0, 1, 0));
        let mut tl = Timeline::new();
        for _attempt in 0..2 {
            let mut w = ShuffleWriter::new(&env, Transport::Sqs, "t", 0, vec![1], 7, 1, None);
            for i in 0..50i64 {
                w.write(0, &krec(i, 1.0), &mut tl).unwrap();
            }
            w.flush_all(&mut tl).unwrap();
        }
        let mut r = ShuffleReader::new(&env, Transport::Sqs, "t", 0, 1, 0, true);
        let read = r.drain(&mut tl).unwrap();
        assert_eq!(read.records.len(), 50, "attempt 2's identical messages dropped");
        assert!(read.duplicates_dropped > 0);
    }

    #[test]
    fn abandon_returns_messages_for_retry() {
        let env = env_with(0.0);
        env.sqs().create_queue(&queue_name("t", 1, 2, 0));
        let mut tl = Timeline::new();
        let mut w = ShuffleWriter::new(&env, Transport::Sqs, "t", 1, vec![2], 3, 1, None);
        for i in 0..10i64 {
            w.write(0, &krec(i, 1.0), &mut tl).unwrap();
        }
        w.flush_all(&mut tl).unwrap();
        // First reader dies after draining.
        let mut r1 = ShuffleReader::new(&env, Transport::Sqs, "t", 1, 2, 0, true);
        let read1 = r1.drain(&mut tl).unwrap();
        assert_eq!(read1.records.len(), 10);
        r1.abandon();
        // Retry sees everything again.
        let mut r2 = ShuffleReader::new(&env, Transport::Sqs, "t", 1, 2, 0, true);
        let read2 = r2.drain(&mut tl).unwrap();
        r2.ack(&mut tl).unwrap();
        assert_eq!(read2.records.len(), 10);
    }

    #[test]
    fn writer_seqs_deterministic_and_resumable() {
        let env = env_with(0.0);
        env.sqs().create_queue(&queue_name("t", 2, 3, 0));
        let mut tl = Timeline::new();
        let mut w1 = ShuffleWriter::new(&env, Transport::Sqs, "t", 2, vec![3], 9, 1, None);
        let mut w2 = ShuffleWriter::new(&env, Transport::Sqs, "t", 2, vec![3], 9, 1, None);
        for i in 0..5000i64 {
            w1.write(0, &krec(i, 1.0), &mut tl).unwrap();
            w2.write(0, &krec(i, 1.0), &mut tl).unwrap();
        }
        assert_eq!(w1.seqs(), w2.seqs(), "same input -> same seq stream");
        // Resume continues the stream.
        let resumed =
            ShuffleWriter::new(&env, Transport::Sqs, "t", 2, vec![3], 9, 1, Some(w1.seqs()));
        assert_eq!(resumed.seqs(), w1.seqs());
    }

    #[test]
    fn prop_partitioners_cover_and_are_stable() {
        forall("partitioner", 300, |g| {
            let parts = g.u64(64) as u32 + 1;
            let key = g.i64(i64::MIN / 2, i64::MAX / 2);
            let p1 = kernel_partition(key, parts);
            let p2 = kernel_partition(key, parts);
            if p1 != p2 {
                return Err("unstable".into());
            }
            if p1 >= parts {
                return Err(format!("partition {p1} out of range {parts}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_kernel_and_dyn_partitioners_agree_on_i64() {
        // The join plans hash-partition a typed kernel stream and a dyn
        // stream on the same i64 key; they must agree for every key.
        forall("kernel-dyn-partition-agree", 300, |g| {
            let parts = g.u64(64) as u32 + 1;
            let key = g.i64(i64::MIN / 2, i64::MAX / 2);
            let kp = kernel_partition(key, parts);
            let dp = dyn_partition(&Value::I64(key), parts);
            if kp != dp {
                return Err(format!("key {key}: kernel {kp} vs dyn {dp} ({parts} parts)"));
            }
            Ok(())
        });
    }

    #[test]
    fn sqs_flush_chunks_by_bytes_and_count() {
        // Regression: messages seal only after crossing MSG_TARGET_BYTES,
        // so one large Dyn value makes one ~40 KB message; ten of them
        // used to go out as a single 400 KB send and fail the whole
        // query with BatchTooLarge. The writer must chunk by bytes too.
        let env = env_with(0.0);
        env.sqs().create_queue(&queue_name("big", 0, 1, 0));
        let mut tl = Timeline::new();
        let mut w = ShuffleWriter::new(&env, Transport::Sqs, "big", 0, vec![1], 1, 1, None);
        let n = 12;
        for i in 0..n {
            let pair = Value::pair(Value::I64(i), Value::str("x".repeat(40 * 1024)));
            w.write(0, &ShuffleRec::Dyn { pair }, &mut tl).unwrap();
        }
        w.flush_all(&mut tl).unwrap();
        assert_eq!(w.msgs_sent, n as u64, "every large record became its own message");
        // 256 KB cap fits six ~40 KB messages per send.
        assert!(
            env.metrics().get("sqs.send_batch") >= 2,
            "byte cap must split the flush into multiple sends"
        );
        let mut r = ShuffleReader::new(&env, Transport::Sqs, "big", 0, 1, 0, true);
        let read = r.drain(&mut tl).unwrap();
        r.ack(&mut tl).unwrap();
        assert_eq!(read.records.len(), n as usize, "nothing lost to batch limits");
    }

    #[test]
    fn s3_reader_rejects_malformed_dedup_keys() {
        // Regression: the S3 reader used to fall back to (producer=0,
        // seq=0) when a key failed to parse, so two malformed/foreign
        // keys aliased and dedup silently dropped the second object.
        let env = env_with(0.0);
        let mut tl = Timeline::new();
        let mut w = ShuffleWriter::new(&env, Transport::S3, "bad", 0, vec![1], 7, 1, None);
        for i in 0..10i64 {
            w.write(0, &krec(i, 1.0), &mut tl).unwrap();
        }
        w.flush_all(&mut tl).unwrap();
        // Two foreign objects under the shuffle prefix, both unparseable:
        // no '-' stem at all, and a non-decimal sequence part.
        let prefix = s3_prefix("bad", 0, 1, 0);
        env.s3()
            .put_object(SHUFFLE_BUCKET, &format!("{prefix}junkobject"), b"junk".to_vec())
            .unwrap();
        env.s3()
            .put_object(SHUFFLE_BUCKET, &format!("{prefix}feed-beef"), b"junk".to_vec())
            .unwrap();
        let mut r = ShuffleReader::new(&env, Transport::S3, "bad", 0, 1, 0, true);
        let err = r.drain(&mut tl).unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("shuffle object key"), "{text}");
    }

    #[test]
    fn memory_abandon_redelivers_for_retry() {
        // The memory backend now has visibility-timeout semantics: a
        // reader that dies after draining returns its messages, so the
        // retry sees the partition again (reducer crash/retry on the
        // cluster baseline).
        let env = env_with(0.0);
        let mem = MemoryShuffle::new();
        let transport = || Transport::Memory(Arc::clone(&mem));
        let mut tl = Timeline::new();
        let mut w = ShuffleWriter::new(&env, transport(), "m", 2, vec![3], 5, 1, None);
        for i in 0..10i64 {
            w.write(0, &krec(i, 1.0), &mut tl).unwrap();
        }
        w.flush_all(&mut tl).unwrap();
        let mut r1 = ShuffleReader::new(&env, transport(), "m", 2, 3, 0, false);
        assert_eq!(r1.drain(&mut tl).unwrap().records.len(), 10);
        r1.abandon();
        let mut r2 = ShuffleReader::new(&env, transport(), "m", 2, 3, 0, false);
        let read2 = r2.drain(&mut tl).unwrap();
        r2.ack(&mut tl).unwrap();
        assert_eq!(read2.records.len(), 10, "abandoned messages redelivered");
        // Acked for good: a third reader sees nothing.
        let mut r3 = ShuffleReader::new(&env, Transport::Memory(mem), "m", 2, 3, 0, false);
        assert_eq!(r3.drain(&mut tl).unwrap().records.len(), 0);
    }

    #[test]
    fn fan_out_writer_delivers_a_full_copy_per_edge() {
        // A shared stage (plan::lower's shared sub-lineages) lists two
        // consumers: each edge must receive the complete stream with the
        // same (producer, seq) identities, and draining one edge must
        // not disturb the other.
        for transport in [
            Transport::Sqs,
            Transport::S3,
            Transport::Memory(MemoryShuffle::new()),
        ] {
            let env = env_with(0.0);
            if matches!(transport, Transport::Sqs) {
                env.sqs().create_queue(&queue_name("f", 0, 1, 0));
                env.sqs().create_queue(&queue_name("f", 0, 2, 0));
            }
            let mut tl = Timeline::new();
            let mut w =
                ShuffleWriter::new(&env, transport.clone(), "f", 0, vec![1, 2], 7, 1, None);
            for i in 0..30i64 {
                w.write(0, &krec(i, 1.0), &mut tl).unwrap();
            }
            w.flush_all(&mut tl).unwrap();
            assert_eq!(w.msgs_sent % 2, 0, "every message sent once per edge");
            for to in [1u32, 2u32] {
                let mut r = ShuffleReader::new(&env, transport.clone(), "f", 0, to, 0, true);
                let read = r.drain(&mut tl).unwrap();
                r.ack(&mut tl).unwrap();
                assert_eq!(
                    read.records.len(),
                    30,
                    "edge s0->s{to} got the full stream ({})",
                    transport.name()
                );
                assert_eq!(read.duplicates_dropped, 0, "edges do not alias");
            }
        }
    }

    use crate::util::propcheck::Gen;

    fn gen_value(g: &mut Gen, depth: usize) -> Value {
        let pick = if depth == 0 { g.usize(5) } else { g.usize(7) };
        match pick {
            0 => Value::Null,
            1 => Value::Bool(g.bool()),
            2 => Value::I64(g.i64(i64::MIN / 2, i64::MAX / 2)),
            3 => Value::F64(g.f64(-1e9, 1e9)),
            4 => Value::str(g.string(12)),
            5 => Value::pair(gen_value(g, depth - 1), gen_value(g, depth - 1)),
            _ => Value::List(g.vec(3, |g| gen_value(g, depth - 1))),
        }
    }

    fn gen_chunk(g: &mut Gen) -> ShuffleRec {
        let n = g.usize(40) + 1;
        // Mostly sorted runs (the writer's case), sometimes arbitrary
        // keys — the codec must be total either way.
        let mut keys = Vec::with_capacity(n);
        if g.bool() {
            let mut k = g.i64(-1_000_000, 1_000_000);
            for _ in 0..n {
                keys.push(k);
                k = k.wrapping_add(g.i64(0, 1000));
            }
        } else {
            for _ in 0..n {
                keys.push(g.i64(i64::MIN / 2, i64::MAX / 2));
            }
        }
        // Exercise every column layout: integral counts, sums == counts,
        // integral sums, and raw f64 columns.
        let counts: Vec<f64> = if g.bool() {
            (0..n).map(|_| g.u64(100_000) as f64).collect()
        } else {
            (0..n).map(|_| g.f64(0.0, 1e6)).collect()
        };
        let sums: Vec<f64> = match g.usize(3) {
            0 => counts.clone(),
            1 => (0..n).map(|_| g.u64(100_000) as f64).collect(),
            _ => (0..n).map(|_| g.f64(-1e6, 1e6)).collect(),
        };
        ShuffleRec::Chunk { keys, sums, counts }
    }

    fn gen_dyn_chunk(g: &mut Gen) -> ShuffleRec {
        let n = g.usize(10) + 1;
        let encs = (0..n)
            .map(|_| Value::pair(gen_value(g, 1), gen_value(g, 1)).encode())
            .collect();
        ShuffleRec::DynChunk { encs }
    }

    fn gen_rec(g: &mut Gen) -> ShuffleRec {
        match g.usize(4) {
            0 => ShuffleRec::Kernel {
                key: g.i64(-1_000_000, 1_000_000),
                sum: g.f64(-1e6, 1e6),
                count: g.f64(0.0, 1e6),
            },
            1 => ShuffleRec::Dyn { pair: Value::pair(gen_value(g, 2), gen_value(g, 2)) },
            2 => gen_chunk(g),
            _ => gen_dyn_chunk(g),
        }
    }

    #[test]
    fn prop_shufflerec_roundtrip() {
        forall("shufflerec-roundtrip", 300, |g| {
            let recs: Vec<ShuffleRec> = (0..g.usize(20) + 1).map(|_| gen_rec(g)).collect();
            let mut buf = Vec::new();
            for r in &recs {
                r.encode_into(&mut buf);
            }
            match ShuffleRec::decode_all(&buf) {
                Some(back) if back == recs => {}
                other => {
                    return Err(format!(
                        "roundtrip failed for {} recs: got {other:?}",
                        recs.len()
                    ))
                }
            }
            // `encoded_len` must agree with the actual encoding (the
            // writer's buffered-bytes accounting depends on it).
            let total: usize = recs.iter().map(ShuffleRec::encoded_len).sum();
            if total != buf.len() {
                return Err(format!("encoded_len sum {total} != buffer {}", buf.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_shufflerec_truncation_decodes_to_none() {
        forall("shufflerec-truncation", 300, |g| {
            let recs: Vec<ShuffleRec> = (0..g.usize(10) + 1).map(|_| gen_rec(g)).collect();
            let mut buf = Vec::new();
            for r in &recs {
                r.encode_into(&mut buf);
            }
            // Cut strictly inside the final record: the stream must be
            // rejected as a whole, not silently shortened.
            let last_len = recs.last().expect("non-empty").encoded_len();
            let cut = g.usize(last_len - 1) + 1;
            let truncated = &buf[..buf.len() - cut];
            if let Some(back) = ShuffleRec::decode_all(truncated) {
                return Err(format!(
                    "buffer truncated by {cut} bytes decoded to {} recs",
                    back.len()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_shufflerec_garbage_is_graceful() {
        forall("shufflerec-garbage", 200, |g| {
            // An unknown tag byte must yield None.
            let rec = gen_rec(g);
            let mut buf = Vec::new();
            rec.encode_into(&mut buf);
            buf[0] = 4 + g.u64(252) as u8; // any tag outside {0, 1, 2, 3}
            if ShuffleRec::decode_all(&buf).is_some() {
                return Err(format!("tag {} decoded as a record", buf[0]));
            }
            // Arbitrary byte soup must never panic (None or an accidental
            // parse are both acceptable; crashing the reducer is not).
            let soup: Vec<u8> = g.vec(64, |g| g.u64(256) as u8);
            let _ = ShuffleRec::decode_all(&soup);
            Ok(())
        });
    }

    #[test]
    fn rec_roundtrip_mixed() {
        let recs = vec![
            krec(5, 2.0),
            ShuffleRec::Dyn { pair: Value::pair(Value::str("k"), Value::I64(1)) },
            krec(-3, 0.5),
        ];
        let mut buf = Vec::new();
        for r in &recs {
            r.encode_into(&mut buf);
        }
        assert_eq!(ShuffleRec::decode_all(&buf).unwrap(), recs);
        assert!(ShuffleRec::decode_all(&[9, 9]).is_none());
    }

    /// Every record a packed stream carries, in order, regardless of
    /// wire variant — what a reducer merges.
    fn unpacked(recs: &[ShuffleRec]) -> Vec<ShuffleRec> {
        let mut out = Vec::new();
        for r in recs {
            match r {
                ShuffleRec::Chunk { keys, sums, counts } => {
                    for i in 0..keys.len() {
                        out.push(ShuffleRec::Kernel {
                            key: keys[i],
                            sum: sums[i],
                            count: counts[i],
                        });
                    }
                }
                ShuffleRec::DynChunk { encs } => {
                    for pair in dyn_chunk_values(encs).expect("valid chunk") {
                        out.push(ShuffleRec::Dyn { pair });
                    }
                }
                other => out.push(other.clone()),
            }
        }
        out
    }

    #[test]
    fn prop_pack_kernel_run_preserves_partials_and_shrinks_bytes() {
        forall("pack-kernel-run", 200, |g| {
            // A sorted run with integral counts — what `HistAccum::to_rows`
            // actually produces.
            let n = g.usize(200) + 1;
            let mut key = g.i64(-1000, 1000);
            let mut rows = Vec::with_capacity(n);
            for _ in 0..n {
                let count = (g.u64(50) + 1) as f64;
                let sum = if g.bool() { count } else { g.f64(0.0, 1e4) };
                rows.push((key, sum, count));
                key += g.i64(1, 40);
            }
            let rows_codec = pack_kernel_run(&rows, ShuffleCodec::Rows);
            let col_codec = pack_kernel_run(&rows, ShuffleCodec::Columnar);
            if unpacked(&rows_codec) != unpacked(&col_codec) {
                return Err("codecs disagree on carried partials".into());
            }
            let rows_bytes: usize = rows_codec.iter().map(ShuffleRec::encoded_len).sum();
            let col_bytes: usize = col_codec.iter().map(ShuffleRec::encoded_len).sum();
            if col_bytes >= rows_bytes {
                return Err(format!(
                    "columnar {col_bytes} B must beat rows {rows_bytes} B on a sorted run of {n}"
                ));
            }
            // And the packed chunks roundtrip through the wire.
            let mut buf = Vec::new();
            for r in &col_codec {
                r.encode_into(&mut buf);
            }
            match ShuffleRec::decode_all(&buf) {
                Some(back) if back == col_codec => Ok(()),
                other => Err(format!("chunk wire roundtrip failed: {other:?}")),
            }
        });
    }

    #[test]
    fn prop_pack_dyn_run_preserves_pairs() {
        forall("pack-dyn-run", 200, |g| {
            let n = g.usize(60) + 1;
            // Sorted-by-encoding pairs, like flush_side emits.
            let mut pairs: Vec<Value> =
                (0..n).map(|_| Value::pair(gen_value(g, 1), gen_value(g, 1))).collect();
            pairs.sort_by(|a, b| a.encode().cmp(&b.encode()));
            let rows_codec = pack_dyn_run(&pairs, ShuffleCodec::Rows);
            let col_codec = pack_dyn_run(&pairs, ShuffleCodec::Columnar);
            if unpacked(&rows_codec) != unpacked(&col_codec) {
                return Err("codecs disagree on carried pairs".into());
            }
            let mut buf = Vec::new();
            for r in &col_codec {
                r.encode_into(&mut buf);
            }
            match ShuffleRec::decode_all(&buf) {
                Some(back) if back == col_codec => Ok(()),
                other => Err(format!("dyn chunk wire roundtrip failed: {other:?}")),
            }
        });
    }

    #[test]
    fn chunk_sum_eq_count_column_is_elided() {
        // Q1-style partials (value source One): sums == counts, so the
        // sums column vanishes and small keys/counts ride as varints.
        let rows: Vec<(i64, f64, f64)> = (0..24).map(|k| (k, 10.0, 10.0)).collect();
        let packed = pack_kernel_run(&rows, ShuffleCodec::Columnar);
        let [chunk] = &packed[..] else {
            panic!("one chunk expected");
        };
        // tag + flags + n + 24 single-byte key deltas + 24 single-byte counts.
        assert_eq!(chunk.encoded_len(), 3 + 24 + 24);
        assert_eq!(chunk.encoded_len(), {
            let mut buf = Vec::new();
            chunk.encode_into(&mut buf);
            buf.len()
        });
        let rows_bytes: usize =
            pack_kernel_run(&rows, ShuffleCodec::Rows).iter().map(ShuffleRec::encoded_len).sum();
        assert_eq!(rows_bytes, 24 * 25);
    }

    #[test]
    fn reader_decodes_mixed_rows_and_columnar_stream() {
        // Interop: one queue carrying both wire formats (e.g. a config
        // change between attempts) must drain cleanly.
        let env = env_with(0.0);
        env.sqs().create_queue(&queue_name("mix", 0, 1, 0));
        let mut tl = Timeline::new();
        let rows: Vec<(i64, f64, f64)> = (0..100).map(|k| (k, k as f64, 1.0)).collect();
        let pairs: Vec<Value> =
            (0..20).map(|i| Value::pair(Value::I64(i), Value::F64(i as f64))).collect();

        let mut w = ShuffleWriter::new(&env, Transport::Sqs, "mix", 0, vec![1], 7, 1, None);
        for rec in pack_kernel_run(&rows, ShuffleCodec::Rows) {
            w.write(0, &rec, &mut tl).unwrap();
        }
        for rec in pack_kernel_run(&rows, ShuffleCodec::Columnar) {
            w.write(0, &rec, &mut tl).unwrap();
        }
        for rec in pack_dyn_run(&pairs, ShuffleCodec::Rows) {
            w.write(0, &rec, &mut tl).unwrap();
        }
        for rec in pack_dyn_run(&pairs, ShuffleCodec::Columnar) {
            w.write(0, &rec, &mut tl).unwrap();
        }
        w.flush_all(&mut tl).unwrap();

        let mut r = ShuffleReader::new(&env, Transport::Sqs, "mix", 0, 1, 0, true);
        let read = r.drain(&mut tl).unwrap();
        r.ack(&mut tl).unwrap();
        let flat = unpacked(&read.records);
        assert_eq!(flat.len(), 2 * 100 + 2 * 20);
        // Both codecs carried identical logical streams.
        assert_eq!(flat[..100], flat[100..200]);
        assert_eq!(flat[200..220], flat[220..240]);
    }

    #[test]
    fn writer_tracks_bytes_per_edge() {
        let env = env_with(0.0);
        let mut tl = Timeline::new();
        let mut w = ShuffleWriter::new(&env, Transport::S3, "eb", 0, vec![1, 2], 7, 1, None);
        for i in 0..500i64 {
            w.write(0, &krec(i, 1.0), &mut tl).unwrap();
        }
        w.flush_all(&mut tl).unwrap();
        let per_edge = w.edge_bytes();
        assert_eq!(per_edge.len(), 2);
        assert_eq!(per_edge[0].0, 1);
        assert_eq!(per_edge[1].0, 2);
        assert!(per_edge[0].1 > 0);
        assert_eq!(per_edge[0].1, per_edge[1].1, "each edge gets a full copy");
        assert_eq!(per_edge[0].1 + per_edge[1].1, w.bytes_sent);
    }

    #[test]
    fn payload_roundtrip_delivers_everything() {
        let env = env_with(0.0);
        let (recs, _) = roundtrip(Transport::Payload(MemoryShuffle::new()), &env, true);
        assert_eq!(recs.len(), 200);
        assert_eq!(env.metrics().get("shuffle.payload_spills"), 0, "small edge stays inline");
    }

    #[test]
    fn payload_spills_past_cap_to_s3_and_union_drains() {
        let mut cfg = FlintConfig::for_tests();
        // A 50 KB payload budget: ~24 KB sealed messages spill quickly.
        cfg.sim.lambda_payload_limit_bytes = 50 * 1024;
        let env = SimEnv::new(cfg);
        env.s3().create_bucket(SHUFFLE_BUCKET);
        let mem = MemoryShuffle::new();
        let transport = Transport::Payload(Arc::clone(&mem));
        let mut tl = Timeline::new();
        let mut w = ShuffleWriter::new(&env, transport.clone(), "pl", 0, vec![1], 7, 1, None);
        let n = 20_000i64;
        for i in 0..n {
            w.write(0, &krec(i, 1.0), &mut tl).unwrap();
        }
        w.flush_all(&mut tl).unwrap();
        assert!(env.metrics().get("shuffle.payload_spills") > 0, "cap forced spills");
        let spilled = env.s3().list(SHUFFLE_BUCKET, &s3_prefix("pl", 0, 1, 0)).unwrap();
        assert!(!spilled.is_empty(), "spilled objects committed under the ordinary prefix");
        let mut r = ShuffleReader::new(&env, transport, "pl", 0, 1, 0, true);
        let read = r.drain(&mut tl).unwrap();
        r.ack(&mut tl).unwrap();
        let total: usize = unpacked(&read.records).len();
        assert_eq!(total as i64, n, "inline + spill legs union to the full stream");
        assert_eq!(read.duplicates_dropped, 0, "the two legs never alias");
    }

    #[test]
    fn s3_temp_objects_invisible_until_commit() {
        let env = env_with(0.0);
        let mut tl = Timeline::new();
        let mut w = ShuffleWriter::new(&env, Transport::S3, "tmp", 0, vec![1], 7, 1, None)
            .with_attempt(2);
        // Enough records that mid-task flushes stage temp objects.
        for i in 0..20_000i64 {
            w.write(0, &krec(i, 1.0), &mut tl).unwrap();
        }
        let visible = env.s3().list(SHUFFLE_BUCKET, &s3_prefix("tmp", 0, 1, 0)).unwrap();
        assert!(visible.is_empty(), "nothing visible before commit");
        let temps = env.s3().list(SHUFFLE_BUCKET, &s3_temp_prefix("tmp", 0, 1, 0)).unwrap();
        assert!(!temps.is_empty(), "mid-task flushes staged temp objects");
        assert!(temps.iter().all(|(k, _)| k.ends_with(".a2")), "temps are attempt-scoped");
        w.flush_all(&mut tl).unwrap();
        let visible = env.s3().list(SHUFFLE_BUCKET, &s3_prefix("tmp", 0, 1, 0)).unwrap();
        assert!(!visible.is_empty(), "commit renamed everything into place");
        let temps = env.s3().list(SHUFFLE_BUCKET, &s3_temp_prefix("tmp", 0, 1, 0)).unwrap();
        assert!(temps.is_empty(), "commit consumed every temp");
    }

    #[test]
    fn racing_s3_attempts_commit_first_wins_without_duplicates() {
        // A primary and a speculative backup write byte-identical output
        // under different attempt temps; whoever commits a key first
        // wins it, the other's rename is consumed benignly, and the
        // reader sees exactly one copy.
        let env = env_with(0.0);
        let mut tl = Timeline::new();
        let mut primary =
            ShuffleWriter::new(&env, Transport::S3, "race", 3, vec![4], 9, 1, None);
        let mut backup = ShuffleWriter::new(&env, Transport::S3, "race", 3, vec![4], 9, 1, None)
            .with_attempt(1);
        for i in 0..500i64 {
            primary.write(0, &krec(i, 1.0), &mut tl).unwrap();
            backup.write(0, &krec(i, 1.0), &mut tl).unwrap();
        }
        primary.flush_all(&mut tl).unwrap();
        backup.flush_all(&mut tl).unwrap();
        assert!(env.metrics().get("s3.commit_lost") > 0, "the backup really lost races");
        let mut r = ShuffleReader::new(&env, Transport::S3, "race", 3, 4, 0, true);
        let read = r.drain(&mut tl).unwrap();
        assert_eq!(unpacked(&read.records).len(), 500, "exactly one copy survives");
        assert_eq!(read.duplicates_dropped, 0, "renames, not duplicate keys");
        let temps = env.s3().list(SHUFFLE_BUCKET, &s3_temp_prefix("race", 3, 4, 0)).unwrap();
        assert!(temps.is_empty(), "both attempts' temps consumed");
    }

    #[test]
    fn tree_exchange_is_bit_identical_to_direct() {
        // 6 producers × 8 partitions through both exchanges: the merged
        // per-partition record streams must be byte-for-byte identical,
        // in order, to direct's.
        let env = env_with(0.0);
        let mut tl = Timeline::new();
        let producers: Vec<u64> = (0..6).map(|t| (2u64 << 32) | t).collect();
        let plan = tree_plan(6, 8, 2).expect("above threshold");
        assert_eq!(plan.producer_groups, 3);
        assert_eq!(plan.consumer_groups, 3);
        for &p in &producers {
            let mut wd = ShuffleWriter::new(&env, Transport::S3, "dir", 2, vec![3], p, 8, None);
            let mut wt = ShuffleWriter::new(&env, Transport::S3, "tre", 2, vec![3], p, 8, None)
                .with_edges(vec![EdgeExchange {
                    transport: Transport::S3,
                    tree_groups: Some(plan.consumer_groups),
                }]);
            for i in 0..4000i64 {
                let rec = krec(i.wrapping_mul(p as i64 | 1), 1.0);
                let part = (i % 8) as u32;
                wd.write(part, &rec, &mut tl).unwrap();
                wt.write(part, &rec, &mut tl).unwrap();
            }
            wd.flush_all(&mut tl).unwrap();
            wt.flush_all(&mut tl).unwrap();
        }
        // Level 1 wrote combined objects only; partitions are empty
        // until the merge level runs.
        assert!(env.s3().list(SHUFFLE_BUCKET, &s3_prefix("tre", 2, 3, 0)).unwrap().is_empty());
        let report = merge_tree_level(&env, "tre", 2, 3, &plan).unwrap();
        assert!(!report.task_durations.is_empty());
        assert!(report.objects_written > 0);
        for part in 0..8u32 {
            let mut rd = ShuffleReader::new(&env, Transport::S3, "dir", 2, 3, part, true);
            let mut rt = ShuffleReader::new(&env, Transport::S3, "tre", 2, 3, part, true);
            let direct = rd.drain(&mut tl).unwrap();
            let tree = rt.drain(&mut tl).unwrap();
            assert_eq!(
                unpacked(&direct.records),
                unpacked(&tree.records),
                "partition {part}: tree must replay direct's record stream exactly"
            );
            assert!(
                tree.messages < direct.messages,
                "partition {part}: merged objects arrive in fewer, larger reads"
            );
        }
    }

    #[test]
    fn tree_plan_respects_fanout_threshold() {
        assert!(tree_plan(8, 8, 64).is_none(), "below threshold stays direct");
        assert!(tree_plan(2, 1024, 64).is_some(), "partition fan-out alone can trigger");
        assert!(tree_plan(1, 1024, 2).is_none(), "degenerate edges stay direct");
        let p = tree_plan(1024, 1024, 64).unwrap();
        assert_eq!(p.producer_groups, 32);
        assert_eq!(p.consumer_groups, 32);
        // Contiguous ascending group ranges (order preservation).
        let mut last = 0;
        for part in 0..1024 {
            let g = consumer_group_of(part, 1024, p.consumer_groups);
            assert!(g >= last && g < p.consumer_groups);
            last = g;
        }
    }
}
