//! [`FlintContext`] — the PySpark-parity session object, the public
//! entry point of the generic API.
//!
//! ```text
//! let sc = FlintContext::new(env);          # SparkContext analogue
//! let trips = sc.text_file("bucket", "p/"); # Rdd bound to the session
//! let hist = trips.map(...).reduce_by_key(30, add);
//! println!("{}", hist.explain());           # compiled stage DAG
//! let rows = hist.collect()?;               # lower + run, serverlessly
//! ```
//!
//! A context wraps one engine: [`FlintContext::new`] the serverless
//! Flint engine (simulated Lambda + SQS), [`FlintContext::cluster`] one
//! of the always-on Spark/PySpark baselines — both run the *same*
//! compiled plans, so any lineage can be cross-checked across engines
//! by running it on several contexts ([`FlintContext::collect`] accepts
//! unbound lineages for exactly that).
//!
//! `text_file` sources resolve their input splits from a registered
//! dataset manifest when one covers the source (manifests carry the
//! per-object statistics that power `flint.scan.prune`), falling back
//! to listing the simulated object store. See
//! [`FlintContext::register_manifest`].
//!
//! The session is also the SQL entry point: [`FlintContext::sql`] runs
//! `SELECT …`/`EXPLAIN SELECT …` text through the `sql` frontend,
//! which lowers onto the same `Rdd` lineage API.

use crate::compute::value::Value;
use crate::config::CacheTier;
use crate::data::{Dataset, ObjectStats, CACHE_BUCKET};
use crate::exec::cache::{pinned_lineage_fingerprint, LineagePins, ServiceShared};
use crate::exec::cluster::{ClusterEngine, ClusterMode};
use crate::exec::flint::FlintEngine;
use crate::exec::QueryReport;
use crate::plan::rdd::RddNode;
use crate::plan::{
    dag, Action, ActionOut, CachePart, InputSplit, PhysicalPlan, Rdd, SessionBinding, StorageLevel,
};
use crate::services::SimEnv;
use crate::sql::{SqlError, SqlJob, SqlResult};
use anyhow::{anyhow, Result};
use std::sync::{Arc, Mutex};

enum Backend {
    Flint(FlintEngine),
    Cluster(ClusterEngine),
}

impl Backend {
    fn env(&self) -> &SimEnv {
        match self {
            Backend::Flint(e) => e.env(),
            Backend::Cluster(e) => e.env(),
        }
    }

    fn run_plan_raw(&self, plan: &PhysicalPlan) -> Result<ActionOut> {
        match self {
            Backend::Flint(e) => Ok(e.run_plan_raw(plan)?.out),
            Backend::Cluster(e) => Ok(e.run_plan_raw(plan)?.out),
        }
    }

    fn run_plan(&self, plan: &PhysicalPlan) -> Result<QueryReport> {
        match self {
            Backend::Flint(e) => e.run_plan(plan),
            Backend::Cluster(e) => e.run_plan(plan),
        }
    }
}

struct SessionInner {
    backend: Backend,
    /// The tenant this session bills to: every metric and dollar a query
    /// spends through this context rolls up into that tenant's
    /// [`crate::cost::report::CostLedger`] when the session runs under a
    /// [`crate::exec::service::FlintService`]. Standalone sessions all
    /// bill the `"default"` tenant.
    tenant: String,
    /// Out-of-band dataset manifests (sources whose objects are not
    /// listable in the simulated store).
    manifests: Mutex<Vec<Dataset>>,
    /// Per-object stats recovered via HEAD for listing-resolved splits,
    /// keyed `bucket/key`. `None` records a HEAD that found no stats
    /// metadata, so even stat-less objects are HEADed at most once per
    /// session (repeat queries hit the cache: `scan.stats_cache_hits`).
    stats_cache: Mutex<std::collections::BTreeMap<String, Option<ObjectStats>>>,
    /// Cross-session shared state: the lineage cache registry and the
    /// hoisted scan-listing cache. Under a [`FlintService`] every
    /// per-query session holds the same instance; standalone contexts
    /// own a private one.
    ///
    /// [`FlintService`]: crate::exec::service::FlintService
    shared: Arc<ServiceShared>,
    /// Latencies of cache-build sub-plans run by `resolve_cache` since
    /// the last drain — a report-producing run folds them into its
    /// `QueryReport` (the builds ran serially ahead of the truncated
    /// plan, so a cold cached run is honestly slower end-to-end).
    build_log: Mutex<Vec<f64>>,
}

impl SessionInner {
    /// Stats for one listed object: session cache first, then one HEAD
    /// (priced as a GET-class request) to read the user metadata the
    /// generator stamped at PUT time.
    fn object_stats(&self, bucket: &str, key: &str) -> Option<ObjectStats> {
        let env = self.backend.env();
        let id = format!("{bucket}/{key}");
        {
            let cache = self.stats_cache.lock().expect("session stats cache");
            if let Some(hit) = cache.get(&id) {
                env.metrics().incr("scan.stats_cache_hits");
                return *hit;
            }
        }
        let stats = env
            .s3()
            .head_object_meta(bucket, key)
            .ok()
            .and_then(|(_, meta)| ObjectStats::from_meta(&meta));
        self.stats_cache
            .lock()
            .expect("session stats cache")
            .insert(id, stats);
        stats
    }

    /// Build one cache entry: run the sub-lineage below a `Cached`
    /// marker as its own `CacheWrite` plan (committed S3 parts under
    /// `fp-<fingerprint>/`), decide the memory tier, and register the
    /// result. The build executes through this session's backend, so
    /// its spend lands in whatever cost window the caller opened — the
    /// builder pays, by construction.
    fn build_cache_entry(
        &self,
        parent: &Rdd,
        level: StorageLevel,
        fp: u64,
        pins: LineagePins,
        resolution: &dag::CacheResolution,
    ) -> Result<Arc<Vec<CachePart>>> {
        let env = self.backend.env();
        let cfg = env.config();
        let prefix = format!("fp-{fp:016x}");
        let action =
            Action::CacheWrite { bucket: CACHE_BUCKET.to_string(), prefix: prefix.clone() };
        // Inner markers already resolved (innermost-first order) cut the
        // build plan too — a nested cache builds on top of the cache.
        let plan = dag::lower_resolved(
            parent,
            action,
            &|bucket, pfx| self.input_splits(bucket, pfx),
            resolution,
        );
        let report = self.backend.run_plan(&plan)?;
        env.metrics().incr("cache.builds");
        self.build_log.lock().expect("session build log").push(report.latency_s);
        // List the committed parts; the builder pays this LIST like any
        // client finalizing an upload. Temp keys of crashed attempts are
        // excluded (the committer's winner sweeps its own).
        let listed = env
            .s3()
            .list(CACHE_BUCKET, &format!("{prefix}/"))
            .map_err(|e| anyhow!("cache part listing: {e}"))?;
        let mut parts: Vec<CachePart> = listed
            .into_iter()
            .filter(|(key, _)| !key.contains("/_tmp/"))
            .map(|(key, bytes)| CachePart {
                bucket: CACHE_BUCKET.to_string(),
                key,
                bytes,
                mem: None,
            })
            .collect();
        parts.sort_by(|a, b| a.key.cmp(&b.key));
        // Tier decision: the effective tier is the per-node storage
        // level ∩ the global `flint.cache.tier` policy, and the memory
        // copy is only worth holding when recomputing the cut costs
        // more than re-reading it from S3 (cost-based promotion).
        let mem_allowed = matches!(cfg.flint.cache.tier, CacheTier::Memory | CacheTier::Both)
            && matches!(level, StorageLevel::Memory | StorageLevel::MemoryAndS3);
        if mem_allowed {
            let total: u64 = parts.iter().map(|p| p.bytes).sum();
            let s3_read_s = cfg.sim.s3_first_byte_s * parts.len().max(1) as f64
                + total as f64 / (cfg.sim.s3_flint_mbps * 1e6);
            if report.latency_s > s3_read_s {
                for p in &mut parts {
                    // Unpriced introspection: the real system keeps these
                    // bytes in the container that just produced them.
                    if let Ok(bytes) = env.s3().peek_object(CACHE_BUCKET, &p.key) {
                        p.mem = Some(bytes);
                    }
                }
            }
        }
        let parts = Arc::new(parts);
        self.shared.registry.admit(
            fp,
            Arc::clone(&parts),
            pins,
            cfg.flint.cache.capacity_bytes,
            env.metrics(),
        );
        Ok(parts)
    }

    /// Drain the build-latency log (the report-producing run folds these
    /// into its latency — builds ran serially ahead of it).
    fn take_builds(&self) -> Vec<f64> {
        std::mem::take(&mut *self.build_log.lock().expect("session build log"))
    }
}

/// Collect `Cached` markers innermost-first (post-order), one entry per
/// distinct node — a diamond's shared marker resolves once.
fn collect_cached(rdd: &Rdd, seen: &mut std::collections::HashSet<usize>, out: &mut Vec<Rdd>) {
    if !seen.insert(dag::CacheResolution::node_key(rdd)) {
        return;
    }
    match &*rdd.node {
        RddNode::TextFile { .. } => {}
        RddNode::Narrow { parent, .. } | RddNode::ReduceByKey { parent, .. } => {
            collect_cached(parent, seen, out)
        }
        RddNode::CoGroup { left, right, .. } => {
            collect_cached(left, seen, out);
            collect_cached(right, seen, out);
        }
        RddNode::Cached { parent, .. } => {
            collect_cached(parent, seen, out);
            out.push(rdd.clone());
        }
    }
}

impl SessionBinding for SessionInner {
    /// Resolve a source's input splits; multi-source lineages
    /// (`cogroup`/`join` across prefixes) each resolve their own
    /// objects. A registered manifest for that exact source wins over a
    /// raw bucket listing: a manifest carries per-object day/month
    /// statistics (the `flint.scan.prune` signal), a listing only names
    /// and sizes — preferring the listing would silently disable split
    /// pruning for every manifest-backed source. Sources with neither a
    /// manifest nor listed objects scan nothing rather than
    /// substituting the wrong data.
    fn input_splits(&self, bucket: &str, prefix: &str) -> Vec<InputSplit> {
        let env = self.backend.env();
        let split_bytes = env.config().flint.input_split_bytes;
        {
            let manifests = self.manifests.lock().expect("session manifests");
            for ds in manifests.iter() {
                if ds.bucket == bucket
                    && ds.prefix.trim_end_matches('/') == prefix.trim_end_matches('/')
                {
                    return dag::input_splits(ds, split_bytes);
                }
            }
        }
        // Hoisted listing cache: every session of a service shares one
        // `(bucket, prefix)` → splits map, so a popular prefix pays its
        // LIST and per-object stats HEADs exactly once per service —
        // not once per query (the per-session `stats_cache` only ever
        // helped repeat queries on one session). Entries are validated
        // against the bucket's write generation, snapshotted *before*
        // the listing: output this service writes under a cached prefix
        // (or late data registration) invalidates, never goes stale.
        let generation = env.s3().write_generation(bucket);
        if let Some(cached) = self.shared.scans.get(bucket, prefix, generation) {
            env.metrics().incr("scan.list_cache_hits");
            return (*cached).clone();
        }
        let listed = env.s3().list(bucket, prefix).unwrap_or_default();
        let prune = env.config().flint.scan_prune;
        let mut splits = Vec::new();
        for (key, size) in listed {
            // A listing names objects but carries no column stats; one
            // HEAD per object (cached for the session) recovers the
            // stats the generator stamped into S3 user metadata, so
            // `flint.scan.prune` works without a registered manifest.
            let stats = if prune { self.object_stats(bucket, &key) } else { None };
            for (start, end) in crate::compute::csv::split_ranges(size, split_bytes) {
                splits.push(InputSplit {
                    bucket: bucket.to_string(),
                    key: key.clone(),
                    start,
                    end,
                    object_size: size,
                    stats,
                });
            }
        }
        self.shared.scans.put(bucket, prefix, generation, Arc::new(splits.clone()));
        splits
    }

    fn execute(&self, plan: &PhysicalPlan) -> Result<ActionOut> {
        self.backend.run_plan_raw(plan)
    }

    /// Resolve every admitted `Cached` marker of `rdd` against the
    /// shared registry, building missing entries. Innermost markers
    /// resolve first, so an outer build's plan already cuts at inner
    /// entries. Disabled (`capacity_bytes = 0`) or cluster sessions
    /// resolve nothing — every marker stays transparent and lowering is
    /// byte-identical to the pre-cache compiler. A failed build only
    /// logs: the marker stays transparent and the query recomputes, it
    /// never fails because a cache couldn't materialize.
    fn resolve_cache(&self, rdd: &Rdd) -> dag::CacheResolution {
        let mut resolution = dag::CacheResolution::default();
        // The cache models warm Lambda containers + committed S3 cuts —
        // a serverless-engine feature; cluster baselines stay exact.
        if !matches!(self.backend, Backend::Flint(_)) {
            return resolution;
        }
        let env = self.backend.env();
        if env.config().flint.cache.capacity_bytes == 0 {
            return resolution;
        }
        let mut markers = Vec::new();
        collect_cached(rdd, &mut std::collections::HashSet::new(), &mut markers);
        for marker in markers {
            let RddNode::Cached { parent, level } = &*marker.node else { unreachable!() };
            // Pins ride along to `admit` on a miss; on a hit they are
            // dropped — the live entry already pins the same `Arc`s
            // (equal fingerprint + live pins ⇒ same addresses ⇒ same
            // closures).
            let (fp, pins) = pinned_lineage_fingerprint(parent, &|b, p| self.input_splits(b, p));
            let key = dag::CacheResolution::node_key(&marker);
            if let Some(parts) = self.shared.registry.lookup(fp) {
                env.metrics().incr("cache.hits");
                resolution.insert(key, parts);
                continue;
            }
            match self.build_cache_entry(parent, *level, fp, pins, &resolution) {
                Ok(parts) => resolution.insert(key, parts),
                Err(e) => {
                    log::warn!("cache build fp-{fp:016x} failed, marker left transparent: {e:#}")
                }
            }
        }
        resolution
    }
}

/// The session object every generic driver program starts from.
/// Cheap to clone (a handle onto one shared engine).
#[derive(Clone)]
pub struct FlintContext {
    inner: Arc<SessionInner>,
}

impl FlintContext {
    fn from_backend(backend: Backend) -> FlintContext {
        Self::from_backend_for_tenant(backend, "default")
    }

    fn from_backend_for_tenant(backend: Backend, tenant: &str) -> FlintContext {
        Self::from_backend_shared(backend, tenant, ServiceShared::new())
    }

    fn from_backend_shared(
        backend: Backend,
        tenant: &str,
        shared: Arc<ServiceShared>,
    ) -> FlintContext {
        FlintContext {
            inner: Arc::new(SessionInner {
                backend,
                tenant: tenant.to_string(),
                manifests: Mutex::new(Vec::new()),
                stats_cache: Mutex::new(std::collections::BTreeMap::new()),
                shared,
                build_log: Mutex::new(Vec::new()),
            }),
        }
    }

    /// A serverless session: tasks run in simulated Lambdas, shuffle
    /// rides the configured backend (SQS or S3).
    pub fn new(env: SimEnv) -> FlintContext {
        Self::from_backend(Backend::Flint(FlintEngine::new(env)))
    }

    /// A serverless session over a pre-built engine (shared PJRT
    /// runtime, pre-warmed pools).
    pub fn with_engine(engine: FlintEngine) -> FlintContext {
        Self::from_backend(Backend::Flint(engine))
    }

    /// A serverless session billed to `tenant` — how
    /// [`crate::exec::service::FlintService`] binds each admitted
    /// session to its cost ledger.
    pub fn with_engine_for_tenant(engine: FlintEngine, tenant: &str) -> FlintContext {
        Self::from_backend_for_tenant(Backend::Flint(engine), tenant)
    }

    /// A serverless session sharing a service's cache registry and scan
    /// cache — how [`crate::exec::service::FlintService`] gives every
    /// per-query session one lineage cache across queries and tenants.
    pub fn with_engine_for_tenant_shared(
        engine: FlintEngine,
        tenant: &str,
        shared: Arc<ServiceShared>,
    ) -> FlintContext {
        Self::from_backend_shared(Backend::Flint(engine), tenant, shared)
    }

    /// The tenant this session's spend is attributed to.
    pub fn tenant(&self) -> &str {
        &self.inner.tenant
    }

    /// The underlying Flint engine, when this is a serverless session —
    /// the service's path to raw `RunOutput` (stage specs, idle).
    pub(crate) fn flint_engine(&self) -> Option<&FlintEngine> {
        match &self.inner.backend {
            Backend::Flint(e) => Some(e),
            Backend::Cluster(_) => None,
        }
    }

    /// An always-on cluster session (the Table I baselines). Runs the
    /// same lineages over the in-memory shuffle, for cross-checking.
    pub fn cluster(env: SimEnv, mode: ClusterMode) -> FlintContext {
        Self::from_backend(Backend::Cluster(ClusterEngine::new(env, mode)))
    }

    pub fn env(&self) -> &SimEnv {
        self.inner.backend.env()
    }

    /// Warm the Lambda container pool (no-op on cluster sessions).
    pub fn prewarm(&self) {
        if let Backend::Flint(e) = &self.inner.backend {
            e.prewarm();
        }
    }

    /// Register an out-of-band dataset manifest as a split-resolution
    /// fallback for its source.
    pub fn register_manifest(&self, dataset: &Dataset) {
        self.inner
            .manifests
            .lock()
            .expect("session manifests")
            .push(dataset.clone());
    }

    /// `sc.textFile(...)`: a lazy source bound to this session —
    /// transformations accumulate lineage, actions compile and run it
    /// here.
    pub fn text_file(&self, bucket: &str, prefix: &str) -> Rdd {
        Rdd::text_file(bucket, prefix)
            .with_session(Arc::clone(&self.inner) as Arc<dyn SessionBinding>)
    }

    /// Compile `rdd` with this session's split resolution (works on
    /// lineages bound elsewhere or not at all — the cross-engine path).
    /// Cache markers stay transparent: this is the build-free compile
    /// `explain`-style callers want; running paths go through
    /// [`FlintContext::lower_for_run`].
    pub fn lower(&self, rdd: &Rdd, action: Action) -> PhysicalPlan {
        dag::lower(rdd, action, &|bucket, prefix| self.inner.input_splits(bucket, prefix))
    }

    /// Compile `rdd` for execution: resolve every admitted `Cached`
    /// marker against the shared registry (building missing entries
    /// through this session's backend — the caller's open cost window
    /// pays), then lower with the plan cut at the resolved markers.
    pub(crate) fn lower_for_run(&self, rdd: &Rdd, action: Action) -> PhysicalPlan {
        let resolution = self.inner.resolve_cache(rdd);
        dag::lower_resolved(
            rdd,
            action,
            &|bucket, prefix| self.inner.input_splits(bucket, prefix),
            &resolution,
        )
    }

    /// Run any lineage on this session and return the full report
    /// (latencies, cost, per-edge shuffle volumes). Cache builds this
    /// run triggered are folded in: they ran serially ahead of the
    /// truncated plan, so the report's latency and spend cover them —
    /// a cold cached run is honestly slower, the warm re-run reaps it.
    pub fn run(&self, rdd: &Rdd, action: Action) -> Result<QueryReport> {
        let env = self.inner.backend.env();
        let before = env.cost().snapshot();
        self.inner.take_builds();
        let plan = self.lower_for_run(rdd, action);
        let mut report = self.inner.backend.run_plan(&plan)?;
        let builds = self.inner.take_builds();
        if !builds.is_empty() {
            let build_s: f64 = builds.iter().sum();
            report.latency_s += build_s;
            report.barrier_latency_s += build_s;
            report.pipelined_latency_s += build_s;
            report.pipelined_nospec_latency_s += build_s;
            report.cost = env.cost().snapshot().since(&before);
            report.cost_usd = report.cost.total();
        }
        Ok(report)
    }

    /// Collect any lineage on this session — including unbound ones, so
    /// one lineage can be executed on several contexts and compared.
    pub fn collect(&self, rdd: &Rdd) -> Result<Vec<Value>> {
        self.inner
            .backend
            .run_plan_raw(&self.lower_for_run(rdd, Action::Collect))?
            .into_values()
    }

    /// Count any lineage on this session (unbound lineages welcome).
    pub fn count(&self, rdd: &Rdd) -> Result<u64> {
        self.inner
            .backend
            .run_plan_raw(&self.lower_for_run(rdd, Action::Count))?
            .into_count()
    }

    /// Resolve a source's input splits with this session's policy
    /// (manifest-first). The SQL planner's table-size estimates read
    /// this.
    pub fn input_splits(&self, bucket: &str, prefix: &str) -> Vec<InputSplit> {
        SessionBinding::input_splits(self.inner.as_ref(), bucket, prefix)
    }

    /// Compile a SQL statement against this session without running it.
    pub fn sql_job(&self, text: &str) -> std::result::Result<SqlJob, SqlError> {
        crate::sql::compile(self, text)
    }

    /// The full EXPLAIN rendering for a SQL statement (logical →
    /// optimized → physical → compiled stage DAG).
    pub fn sql_explain(&self, text: &str) -> std::result::Result<String, SqlError> {
        Ok(self.sql_job(text)?.explain_text())
    }

    /// Run a SQL statement on this session. `EXPLAIN SELECT …` returns
    /// the plan rendering as rows instead of executing.
    pub fn sql(&self, text: &str) -> Result<SqlResult> {
        let job = self.sql_job(text)?;
        if job.is_explain {
            return Ok(SqlResult {
                columns: vec!["plan".to_string()],
                rows: job
                    .explain_text()
                    .lines()
                    .map(|l| vec![Value::Str(l.to_string())])
                    .collect(),
            });
        }
        job.collect()
    }
}
