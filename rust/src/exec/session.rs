//! [`FlintContext`] — the PySpark-parity session object, the public
//! entry point of the generic API.
//!
//! ```text
//! let sc = FlintContext::new(env);          # SparkContext analogue
//! let trips = sc.text_file("bucket", "p/"); # Rdd bound to the session
//! let hist = trips.map(...).reduce_by_key(30, add);
//! println!("{}", hist.explain());           # compiled stage DAG
//! let rows = hist.collect()?;               # lower + run, serverlessly
//! ```
//!
//! A context wraps one engine: [`FlintContext::new`] the serverless
//! Flint engine (simulated Lambda + SQS), [`FlintContext::cluster`] one
//! of the always-on Spark/PySpark baselines — both run the *same*
//! compiled plans, so any lineage can be cross-checked across engines
//! by running it on several contexts ([`FlintContext::collect`] accepts
//! unbound lineages for exactly that).
//!
//! `text_file` sources resolve their input splits from a registered
//! dataset manifest when one covers the source (manifests carry the
//! per-object statistics that power `flint.scan.prune`), falling back
//! to listing the simulated object store. See
//! [`FlintContext::register_manifest`].
//!
//! The session is also the SQL entry point: [`FlintContext::sql`] runs
//! `SELECT …`/`EXPLAIN SELECT …` text through the `sql` frontend,
//! which lowers onto the same `Rdd` lineage API.

use crate::compute::value::Value;
use crate::data::{Dataset, ObjectStats};
use crate::exec::cluster::{ClusterEngine, ClusterMode};
use crate::exec::flint::FlintEngine;
use crate::exec::QueryReport;
use crate::plan::{dag, Action, ActionOut, InputSplit, PhysicalPlan, Rdd, SessionBinding};
use crate::services::SimEnv;
use crate::sql::{SqlError, SqlJob, SqlResult};
use anyhow::Result;
use std::sync::{Arc, Mutex};

enum Backend {
    Flint(FlintEngine),
    Cluster(ClusterEngine),
}

impl Backend {
    fn env(&self) -> &SimEnv {
        match self {
            Backend::Flint(e) => e.env(),
            Backend::Cluster(e) => e.env(),
        }
    }

    fn run_plan_raw(&self, plan: &PhysicalPlan) -> Result<ActionOut> {
        match self {
            Backend::Flint(e) => Ok(e.run_plan_raw(plan)?.out),
            Backend::Cluster(e) => Ok(e.run_plan_raw(plan)?.out),
        }
    }

    fn run_plan(&self, plan: &PhysicalPlan) -> Result<QueryReport> {
        match self {
            Backend::Flint(e) => e.run_plan(plan),
            Backend::Cluster(e) => e.run_plan(plan),
        }
    }
}

struct SessionInner {
    backend: Backend,
    /// The tenant this session bills to: every metric and dollar a query
    /// spends through this context rolls up into that tenant's
    /// [`crate::cost::report::CostLedger`] when the session runs under a
    /// [`crate::exec::service::FlintService`]. Standalone sessions all
    /// bill the `"default"` tenant.
    tenant: String,
    /// Out-of-band dataset manifests (sources whose objects are not
    /// listable in the simulated store).
    manifests: Mutex<Vec<Dataset>>,
    /// Per-object stats recovered via HEAD for listing-resolved splits,
    /// keyed `bucket/key`. `None` records a HEAD that found no stats
    /// metadata, so even stat-less objects are HEADed at most once per
    /// session (repeat queries hit the cache: `scan.stats_cache_hits`).
    stats_cache: Mutex<std::collections::BTreeMap<String, Option<ObjectStats>>>,
}

impl SessionInner {
    /// Stats for one listed object: session cache first, then one HEAD
    /// (priced as a GET-class request) to read the user metadata the
    /// generator stamped at PUT time.
    fn object_stats(&self, bucket: &str, key: &str) -> Option<ObjectStats> {
        let env = self.backend.env();
        let id = format!("{bucket}/{key}");
        {
            let cache = self.stats_cache.lock().expect("session stats cache");
            if let Some(hit) = cache.get(&id) {
                env.metrics().incr("scan.stats_cache_hits");
                return *hit;
            }
        }
        let stats = env
            .s3()
            .head_object_meta(bucket, key)
            .ok()
            .and_then(|(_, meta)| ObjectStats::from_meta(&meta));
        self.stats_cache
            .lock()
            .expect("session stats cache")
            .insert(id, stats);
        stats
    }
}

impl SessionBinding for SessionInner {
    /// Resolve a source's input splits; multi-source lineages
    /// (`cogroup`/`join` across prefixes) each resolve their own
    /// objects. A registered manifest for that exact source wins over a
    /// raw bucket listing: a manifest carries per-object day/month
    /// statistics (the `flint.scan.prune` signal), a listing only names
    /// and sizes — preferring the listing would silently disable split
    /// pruning for every manifest-backed source. Sources with neither a
    /// manifest nor listed objects scan nothing rather than
    /// substituting the wrong data.
    fn input_splits(&self, bucket: &str, prefix: &str) -> Vec<InputSplit> {
        let env = self.backend.env();
        let split_bytes = env.config().flint.input_split_bytes;
        {
            let manifests = self.manifests.lock().expect("session manifests");
            for ds in manifests.iter() {
                if ds.bucket == bucket
                    && ds.prefix.trim_end_matches('/') == prefix.trim_end_matches('/')
                {
                    return dag::input_splits(ds, split_bytes);
                }
            }
        }
        let listed = env.s3().list(bucket, prefix).unwrap_or_default();
        let prune = env.config().flint.scan_prune;
        let mut splits = Vec::new();
        for (key, size) in listed {
            // A listing names objects but carries no column stats; one
            // HEAD per object (cached for the session) recovers the
            // stats the generator stamped into S3 user metadata, so
            // `flint.scan.prune` works without a registered manifest.
            let stats = if prune { self.object_stats(bucket, &key) } else { None };
            for (start, end) in crate::compute::csv::split_ranges(size, split_bytes) {
                splits.push(InputSplit {
                    bucket: bucket.to_string(),
                    key: key.clone(),
                    start,
                    end,
                    object_size: size,
                    stats,
                });
            }
        }
        splits
    }

    fn execute(&self, plan: &PhysicalPlan) -> Result<ActionOut> {
        self.backend.run_plan_raw(plan)
    }
}

/// The session object every generic driver program starts from.
/// Cheap to clone (a handle onto one shared engine).
#[derive(Clone)]
pub struct FlintContext {
    inner: Arc<SessionInner>,
}

impl FlintContext {
    fn from_backend(backend: Backend) -> FlintContext {
        Self::from_backend_for_tenant(backend, "default")
    }

    fn from_backend_for_tenant(backend: Backend, tenant: &str) -> FlintContext {
        FlintContext {
            inner: Arc::new(SessionInner {
                backend,
                tenant: tenant.to_string(),
                manifests: Mutex::new(Vec::new()),
                stats_cache: Mutex::new(std::collections::BTreeMap::new()),
            }),
        }
    }

    /// A serverless session: tasks run in simulated Lambdas, shuffle
    /// rides the configured backend (SQS or S3).
    pub fn new(env: SimEnv) -> FlintContext {
        Self::from_backend(Backend::Flint(FlintEngine::new(env)))
    }

    /// A serverless session over a pre-built engine (shared PJRT
    /// runtime, pre-warmed pools).
    pub fn with_engine(engine: FlintEngine) -> FlintContext {
        Self::from_backend(Backend::Flint(engine))
    }

    /// A serverless session billed to `tenant` — how
    /// [`crate::exec::service::FlintService`] binds each admitted
    /// session to its cost ledger.
    pub fn with_engine_for_tenant(engine: FlintEngine, tenant: &str) -> FlintContext {
        Self::from_backend_for_tenant(Backend::Flint(engine), tenant)
    }

    /// The tenant this session's spend is attributed to.
    pub fn tenant(&self) -> &str {
        &self.inner.tenant
    }

    /// The underlying Flint engine, when this is a serverless session —
    /// the service's path to raw `RunOutput` (stage specs, idle).
    pub(crate) fn flint_engine(&self) -> Option<&FlintEngine> {
        match &self.inner.backend {
            Backend::Flint(e) => Some(e),
            Backend::Cluster(_) => None,
        }
    }

    /// An always-on cluster session (the Table I baselines). Runs the
    /// same lineages over the in-memory shuffle, for cross-checking.
    pub fn cluster(env: SimEnv, mode: ClusterMode) -> FlintContext {
        Self::from_backend(Backend::Cluster(ClusterEngine::new(env, mode)))
    }

    pub fn env(&self) -> &SimEnv {
        self.inner.backend.env()
    }

    /// Warm the Lambda container pool (no-op on cluster sessions).
    pub fn prewarm(&self) {
        if let Backend::Flint(e) = &self.inner.backend {
            e.prewarm();
        }
    }

    /// Register an out-of-band dataset manifest as a split-resolution
    /// fallback for its source.
    pub fn register_manifest(&self, dataset: &Dataset) {
        self.inner
            .manifests
            .lock()
            .expect("session manifests")
            .push(dataset.clone());
    }

    /// `sc.textFile(...)`: a lazy source bound to this session —
    /// transformations accumulate lineage, actions compile and run it
    /// here.
    pub fn text_file(&self, bucket: &str, prefix: &str) -> Rdd {
        Rdd::text_file(bucket, prefix)
            .with_session(Arc::clone(&self.inner) as Arc<dyn SessionBinding>)
    }

    /// Compile `rdd` with this session's split resolution (works on
    /// lineages bound elsewhere or not at all — the cross-engine path).
    pub fn lower(&self, rdd: &Rdd, action: Action) -> PhysicalPlan {
        dag::lower(rdd, action, &|bucket, prefix| self.inner.input_splits(bucket, prefix))
    }

    /// Run any lineage on this session and return the full report
    /// (latencies, cost, per-edge shuffle volumes).
    pub fn run(&self, rdd: &Rdd, action: Action) -> Result<QueryReport> {
        self.inner.backend.run_plan(&self.lower(rdd, action))
    }

    /// Collect any lineage on this session — including unbound ones, so
    /// one lineage can be executed on several contexts and compared.
    pub fn collect(&self, rdd: &Rdd) -> Result<Vec<Value>> {
        self.inner
            .backend
            .run_plan_raw(&self.lower(rdd, Action::Collect))?
            .into_values()
    }

    /// Count any lineage on this session (unbound lineages welcome).
    pub fn count(&self, rdd: &Rdd) -> Result<u64> {
        self.inner
            .backend
            .run_plan_raw(&self.lower(rdd, Action::Count))?
            .into_count()
    }

    /// Resolve a source's input splits with this session's policy
    /// (manifest-first). The SQL planner's table-size estimates read
    /// this.
    pub fn input_splits(&self, bucket: &str, prefix: &str) -> Vec<InputSplit> {
        SessionBinding::input_splits(self.inner.as_ref(), bucket, prefix)
    }

    /// Compile a SQL statement against this session without running it.
    pub fn sql_job(&self, text: &str) -> std::result::Result<SqlJob, SqlError> {
        crate::sql::compile(self, text)
    }

    /// The full EXPLAIN rendering for a SQL statement (logical →
    /// optimized → physical → compiled stage DAG).
    pub fn sql_explain(&self, text: &str) -> std::result::Result<String, SqlError> {
        Ok(self.sql_job(text)?.explain_text())
    }

    /// Run a SQL statement on this session. `EXPLAIN SELECT …` returns
    /// the plan rendering as rows instead of executing.
    pub fn sql(&self, text: &str) -> Result<SqlResult> {
        let job = self.sql_job(text)?;
        if job.is_explain {
            return Ok(SqlResult {
                columns: vec!["plan".to_string()],
                rows: job
                    .explain_text()
                    .lines()
                    .map(|l| vec![Value::Str(l.to_string())])
                    .collect(),
            });
        }
        job.collect()
    }
}
