//! The lineage-keyed cache: structural fingerprints over `Rdd` DAGs, a
//! capacity-bounded registry of materialized cuts, and the state one
//! `FlintService` shares across every query/tenant session (the cache
//! registry plus the hoisted scan-listing cache).
//!
//! # Fingerprints
//!
//! A cached cut is keyed by a canonical 64-bit FNV-1a hash over
//! everything that determines the cut's *bytes*:
//!
//! * the lineage structure below the marker (node kinds, partition
//!   counts, op chains),
//! * dataset identity: a `TextFile` source hashes its **resolved
//!   splits** — bucket, key, byte ranges, object sizes, and manifest
//!   stats — so re-generated data, a different split size, or a changed
//!   `scan_prune` stats view all change the key (invalidation by
//!   construction, never by TTL),
//! * result-affecting ops: a typed `DayRange` hashes its parameters;
//!   opaque closures (`Map`/`Filter`/`FlatMap`, `reduceByKey` combine)
//!   hash by `Arc` pointer identity.
//!
//! Closure pointer identity means cross-query reuse requires the
//! queries to *share* the op `Arc`s — i.e. be derived from the same
//! `Rdd` handles, exactly how a driver program reuses a cached RDD in
//! Spark. Two textually identical closures compiled separately never
//! alias, so the registry can never serve a wrong entry; it can only
//! miss. Diamonds hash each shared node once (pointer-memoized walk).
//!
//! Pointer identity is only sound while the hashed `Arc`s are alive —
//! a freed closure's address can be reallocated to a new, semantically
//! different closure. The registry outlives the query lineages it was
//! fed, so every entry stores [`LineagePins`]: strong references to
//! each pointer-hashed closure of the lineage that built it. While an
//! entry can be served, its hashed addresses cannot be recycled; the
//! pins drop with the entry on eviction or replacement.
//!
//! # Registry
//!
//! Entries are LRU-over-bytes under `flint.cache.capacity_bytes`;
//! `capacity_bytes = 0` disables the cache entirely (markers stay
//! transparent, byte-identical to a build without this module). An
//! evicted entry only drops the registry mapping — its committed S3
//! objects stay until the bucket dies, and an identical rebuild
//! re-commits the same keys idempotently (first-commit-wins renames).

use crate::metrics::Metrics;
use crate::plan::rdd::{CombineFn, DynOp, Rdd, RddNode};
use crate::plan::task::{CachePart, InputSplit};
use crate::util::fnv1a64;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Strong references to every closure a fingerprint hashed by pointer
/// identity. A registry entry keeps the pins of the lineage that built
/// it, so the hashed addresses stay allocated for as long as the entry
/// can be served — an equal fingerprint from a later query can then
/// only come from the *same* live `Arc`s, never from a reallocation.
/// Deliberately opaque: the closures are held, never called.
///
/// (Pinning whole `Rdd` handles would be simpler but leaks: an `Rdd`
/// carries its session binding, and sessions hold the shared registry —
/// a reference cycle through every admitted entry.)
#[derive(Default)]
pub struct LineagePins {
    ops: Vec<DynOp>,
    combines: Vec<CombineFn>,
}

/// Hash one op into the node buffer: kind tag, then parameters (typed
/// predicates) or closure identity (opaque ones, pinned).
fn fp_op(op: &DynOp, buf: &mut Vec<u8>, pins: &mut LineagePins) {
    match op {
        DynOp::Map(f) => {
            buf.push(1);
            buf.extend_from_slice(&(Arc::as_ptr(f) as *const () as usize as u64).to_le_bytes());
        }
        DynOp::Filter(f) => {
            buf.push(2);
            buf.extend_from_slice(&(Arc::as_ptr(f) as *const () as usize as u64).to_le_bytes());
        }
        DynOp::FlatMap(f) => {
            buf.push(3);
            buf.extend_from_slice(&(Arc::as_ptr(f) as *const () as usize as u64).to_le_bytes());
        }
        DynOp::DayRange { min_day, max_day } => {
            buf.push(4);
            buf.extend_from_slice(&min_day.to_le_bytes());
            buf.extend_from_slice(&max_day.to_le_bytes());
        }
    }
    // DayRange hashes by value; everything else hashed an address and
    // must be pinned.
    if !matches!(op, DynOp::DayRange { .. }) {
        pins.ops.push(op.clone());
    }
}

fn fp_splits(splits: &[InputSplit], buf: &mut Vec<u8>) {
    buf.extend_from_slice(&(splits.len() as u64).to_le_bytes());
    for s in splits {
        buf.extend_from_slice(s.bucket.as_bytes());
        buf.push(0);
        buf.extend_from_slice(s.key.as_bytes());
        buf.push(0);
        buf.extend_from_slice(&s.start.to_le_bytes());
        buf.extend_from_slice(&s.end.to_le_bytes());
        buf.extend_from_slice(&s.object_size.to_le_bytes());
        match &s.stats {
            None => buf.push(0),
            Some(st) => {
                buf.push(1);
                buf.extend_from_slice(&st.min_day.to_le_bytes());
                buf.extend_from_slice(&st.max_day.to_le_bytes());
                buf.extend_from_slice(&st.min_month.to_le_bytes());
                buf.extend_from_slice(&st.max_month.to_le_bytes());
                buf.extend_from_slice(&st.rows.to_le_bytes());
            }
        }
    }
}

fn fp_node(
    rdd: &Rdd,
    splits: &dyn Fn(&str, &str) -> Vec<InputSplit>,
    memo: &mut HashMap<usize, u64>,
    pins: &mut LineagePins,
) -> u64 {
    let key = Arc::as_ptr(&rdd.node) as *const () as usize;
    if let Some(h) = memo.get(&key) {
        return *h;
    }
    let mut buf = Vec::new();
    match &*rdd.node {
        RddNode::TextFile { bucket, prefix } => {
            buf.push(1);
            buf.extend_from_slice(bucket.as_bytes());
            buf.push(0);
            buf.extend_from_slice(prefix.as_bytes());
            buf.push(0);
            fp_splits(&splits(bucket, prefix), &mut buf);
        }
        RddNode::Narrow { parent, op } => {
            buf.push(2);
            fp_op(op, &mut buf, pins);
            buf.extend_from_slice(&fp_node(parent, splits, memo, pins).to_le_bytes());
        }
        RddNode::ReduceByKey { parent, partitions, combine } => {
            buf.push(3);
            buf.extend_from_slice(&(*partitions as u64).to_le_bytes());
            buf.extend_from_slice(
                &(Arc::as_ptr(combine) as *const () as usize as u64).to_le_bytes(),
            );
            pins.combines.push(Arc::clone(combine));
            buf.extend_from_slice(&fp_node(parent, splits, memo, pins).to_le_bytes());
        }
        RddNode::CoGroup { left, right, partitions } => {
            buf.push(4);
            buf.extend_from_slice(&(*partitions as u64).to_le_bytes());
            buf.extend_from_slice(&fp_node(left, splits, memo, pins).to_le_bytes());
            buf.extend_from_slice(&fp_node(right, splits, memo, pins).to_le_bytes());
        }
        // A nested marker is part of the structure but its storage level
        // is not: `persist(Memory)` and `persist(S3)` over the same
        // parent describe the same bytes, so they share one entry.
        RddNode::Cached { parent, .. } => {
            buf.push(5);
            buf.extend_from_slice(&fp_node(parent, splits, memo, pins).to_le_bytes());
        }
    }
    let h = fnv1a64(&buf);
    memo.insert(key, h);
    h
}

/// Canonical fingerprint of a lineage (see module docs for what it
/// covers). `splits` resolves `TextFile` sources exactly like lowering
/// does — dataset identity and the stats view are part of the key.
pub fn lineage_fingerprint(rdd: &Rdd, splits: &dyn Fn(&str, &str) -> Vec<InputSplit>) -> u64 {
    pinned_lineage_fingerprint(rdd, splits).0
}

/// [`lineage_fingerprint`] plus the [`LineagePins`] that keep it sound:
/// a caller admitting a registry entry under the returned hash MUST
/// store the pins in the entry, so the pointer-hashed closures outlive
/// every lookup that could match it.
pub fn pinned_lineage_fingerprint(
    rdd: &Rdd,
    splits: &dyn Fn(&str, &str) -> Vec<InputSplit>,
) -> (u64, LineagePins) {
    let mut pins = LineagePins::default();
    let h = fp_node(rdd, splits, &mut HashMap::new(), &mut pins);
    (h, pins)
}

struct CacheEntry {
    parts: Arc<Vec<CachePart>>,
    bytes: u64,
    last_used: u64,
    /// Keeps the building lineage's pointer-hashed closures alive while
    /// this entry can be served (see [`LineagePins`]); released on
    /// eviction or replacement by dropping the entry.
    _pins: LineagePins,
}

#[derive(Default)]
struct RegistryInner {
    entries: HashMap<u64, CacheEntry>,
    bytes: u64,
    tick: u64,
}

/// The shared fingerprint → materialized-parts registry. Admission and
/// eviction are byte-budgeted (LRU over bytes); the *tier* decision
/// (which parts carry a memory copy) is made by the session that built
/// the entry, before admitting it.
#[derive(Default)]
pub struct CacheRegistry {
    inner: Mutex<RegistryInner>,
}

impl CacheRegistry {
    pub fn new() -> CacheRegistry {
        CacheRegistry::default()
    }

    /// Look up a fingerprint, bumping its recency on a hit.
    pub fn lookup(&self, fp: u64) -> Option<Arc<Vec<CachePart>>> {
        let mut inner = self.inner.lock().expect("cache registry lock");
        inner.tick += 1;
        let tick = inner.tick;
        let entry = inner.entries.get_mut(&fp)?;
        entry.last_used = tick;
        Some(Arc::clone(&entry.parts))
    }

    /// Admit a freshly built entry, evicting least-recently-used entries
    /// until it fits. An entry larger than the whole capacity is
    /// rejected (the build's S3 objects still served the building query;
    /// they just aren't registered for reuse). `pins` must be the
    /// [`LineagePins`] collected while fingerprinting `fp` — the entry
    /// holds them so the hashed closure addresses can't be reallocated
    /// while it lives. Returns whether the entry was admitted.
    pub fn admit(
        &self,
        fp: u64,
        parts: Arc<Vec<CachePart>>,
        pins: LineagePins,
        capacity_bytes: u64,
        metrics: &Metrics,
    ) -> bool {
        let bytes: u64 = parts.iter().map(|p| p.bytes).sum();
        if bytes > capacity_bytes {
            metrics.incr("cache.admission_rejected");
            return false;
        }
        let mut inner = self.inner.lock().expect("cache registry lock");
        if let Some(old) = inner.entries.remove(&fp) {
            // Racing builders (two sessions missed concurrently): keep
            // the newcomer, the bytes are identical by determinism.
            inner.bytes -= old.bytes;
        }
        while inner.bytes + bytes > capacity_bytes {
            let Some((&victim, _)) =
                inner.entries.iter().min_by_key(|(_, e)| e.last_used)
            else {
                break;
            };
            let evicted = inner.entries.remove(&victim).expect("victim exists");
            inner.bytes -= evicted.bytes;
            metrics.incr("cache.evictions");
        }
        inner.tick += 1;
        let tick = inner.tick;
        inner
            .entries
            .insert(fp, CacheEntry { parts, bytes, last_used: tick, _pins: pins });
        inner.bytes += bytes;
        // Cumulative admission volume; resident bytes (net of evictions
        // and replacements) are [`CacheRegistry::bytes`].
        metrics.add("cache.admitted_bytes", bytes);
        true
    }

    /// Number of registered entries (tests/diagnostics).
    pub fn len(&self) -> usize {
        self.inner.lock().expect("cache registry lock").entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Registered bytes (tests/diagnostics).
    pub fn bytes(&self) -> u64 {
        self.inner.lock().expect("cache registry lock").bytes
    }
}

/// The hoisted scan-listing cache: one `(bucket, prefix)` → resolved
/// splits map shared by every session of a service, so repeat scans of
/// a popular prefix stop paying the LIST + per-object HEAD tax on every
/// query. There is no TTL; instead every entry records the bucket's S3
/// write generation at resolution time and is served only while the
/// bucket is still at that generation — any PUT/commit/DELETE under the
/// bucket (including output the service itself just wrote with
/// `save_as_text_file`) invalidates its entries by construction.
#[derive(Default)]
pub struct ScanCache {
    inner: Mutex<HashMap<(String, String), (u64, Arc<Vec<InputSplit>>)>>,
}

impl ScanCache {
    /// Look up a resolution, valid only at the bucket's current write
    /// `generation` (see [`crate::services::s3::ObjectStore::write_generation`]).
    pub fn get(&self, bucket: &str, prefix: &str, generation: u64) -> Option<Arc<Vec<InputSplit>>> {
        match self
            .inner
            .lock()
            .expect("scan cache lock")
            .get(&(bucket.to_string(), prefix.to_string()))
        {
            Some((gen, splits)) if *gen == generation => Some(Arc::clone(splits)),
            _ => None,
        }
    }

    /// Record a resolution made while the bucket was at `generation`
    /// (snapshot the generation *before* listing, so a racing write at
    /// worst discards a fresh entry, never validates a stale one).
    /// Empty resolutions are never cached: an empty listing usually
    /// means the data isn't registered yet, and pinning it would starve
    /// every later scan of the prefix.
    pub fn put(&self, bucket: &str, prefix: &str, generation: u64, splits: Arc<Vec<InputSplit>>) {
        if splits.is_empty() {
            return;
        }
        self.inner
            .lock()
            .expect("scan cache lock")
            .insert((bucket.to_string(), prefix.to_string()), (generation, splits));
    }
}

/// Everything a `FlintService` shares across its per-query sessions:
/// the lineage cache registry and the scan-listing cache. Standalone
/// contexts own a private instance, which still gives repeat actions on
/// one context the same reuse.
#[derive(Default)]
pub struct ServiceShared {
    pub registry: CacheRegistry,
    pub scans: ScanCache,
}

impl ServiceShared {
    pub fn new() -> Arc<ServiceShared> {
        Arc::new(ServiceShared::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn part(key: &str, bytes: u64) -> CachePart {
        CachePart { bucket: "flint-cache".into(), key: key.into(), bytes, mem: None }
    }

    fn parts(total: u64, n: u64) -> Arc<Vec<CachePart>> {
        Arc::new((0..n).map(|i| part(&format!("p{i}"), total / n)).collect())
    }

    #[test]
    fn registry_lru_eviction_over_bytes() {
        let reg = CacheRegistry::new();
        let m = Metrics::new();
        let pins = LineagePins::default;
        assert!(reg.admit(1, parts(400, 2), pins(), 1000, &m));
        assert!(reg.admit(2, parts(400, 2), pins(), 1000, &m));
        // Touch 1 so 2 becomes the LRU victim.
        assert!(reg.lookup(1).is_some());
        assert!(reg.admit(3, parts(400, 2), pins(), 1000, &m));
        assert_eq!(m.get("cache.evictions"), 1);
        assert!(reg.lookup(2).is_none(), "LRU entry evicted");
        assert!(reg.lookup(1).is_some());
        assert!(reg.lookup(3).is_some());
        assert_eq!(reg.bytes(), 800);
        // An entry bigger than the whole budget is rejected outright.
        assert!(!reg.admit(4, parts(2000, 4), pins(), 1000, &m));
        assert_eq!(m.get("cache.admission_rejected"), 1);
        assert_eq!(reg.len(), 2);
        // The admission meter is cumulative (3 × 400 admitted), while
        // `bytes()` reports what is resident after evictions.
        assert_eq!(m.get("cache.admitted_bytes"), 1200);
    }

    #[test]
    fn admitted_entries_pin_their_hashed_closures() {
        let splits = |_: &str, _: &str| Vec::new();
        let reg = CacheRegistry::new();
        let m = Metrics::new();
        let lineage = Rdd::text_file("b", "data/").map(|v| v);
        let f = match &*lineage.node {
            RddNode::Narrow { op: DynOp::Map(f), .. } => Arc::clone(f),
            _ => unreachable!("text_file().map() is a Narrow(Map) node"),
        };
        let (fp, pins) = pinned_lineage_fingerprint(&lineage, &splits);
        assert!(reg.admit(fp, parts(100, 1), pins, 1000, &m));
        drop(lineage);
        // The query's lineage is gone, but the entry still pins the
        // closure that was hashed by address: held here + by the entry,
        // so the address can't be reallocated while `fp` is servable.
        assert_eq!(Arc::strong_count(&f), 2, "entry keeps the hashed closure alive");
        // Evicting the entry (a bigger admit floods the budget) releases
        // the pin.
        assert!(reg.admit(99, parts(1000, 1), LineagePins::default(), 1000, &m));
        assert!(reg.lookup(fp).is_none());
        assert_eq!(Arc::strong_count(&f), 1, "eviction drops the pin");
    }

    #[test]
    fn fingerprint_is_structural_and_pointer_memoized() {
        let splits = |_: &str, _: &str| Vec::new();
        let base = Rdd::text_file("b", "data/");
        let mapped = base.map(|v| v);
        // Same handle → same fingerprint; a diamond sharing the node
        // hashes identically to either arm.
        assert_eq!(
            lineage_fingerprint(&mapped, &splits),
            lineage_fingerprint(&mapped.clone(), &splits)
        );
        // A structurally identical but separately compiled closure does
        // NOT alias (pointer identity): the registry can only miss, never
        // serve a wrong entry.
        let other = base.map(|v| v);
        assert_ne!(lineage_fingerprint(&mapped, &splits), lineage_fingerprint(&other, &splits));
        // Storage level is excluded: persist(Memory) and persist(S3)
        // over one parent describe the same bytes.
        use crate::plan::StorageLevel;
        assert_eq!(
            lineage_fingerprint(&mapped.persist(StorageLevel::Memory), &splits),
            lineage_fingerprint(&mapped.persist(StorageLevel::S3), &splits)
        );
        // But the marker itself is structural: cached vs plain differ.
        assert_ne!(
            lineage_fingerprint(&mapped.cache(), &splits),
            lineage_fingerprint(&mapped, &splits)
        );
        // Typed predicates hash by value, so two independently built
        // DayRange chains over the same source DO share.
        assert_eq!(
            lineage_fingerprint(&base.filter_day_range(3, 9), &splits),
            lineage_fingerprint(&base.filter_day_range(3, 9), &splits)
        );
        assert_ne!(
            lineage_fingerprint(&base.filter_day_range(3, 9), &splits),
            lineage_fingerprint(&base.filter_day_range(3, 10), &splits)
        );
    }

    #[test]
    fn fingerprint_covers_dataset_identity_via_splits() {
        let rdd = Rdd::text_file("b", "data/");
        let empty = |_: &str, _: &str| Vec::new();
        let one = |_: &str, _: &str| {
            vec![InputSplit {
                bucket: "b".into(),
                key: "data/part-0".into(),
                start: 0,
                end: 100,
                object_size: 100,
                stats: None,
            }]
        };
        let grown = |_: &str, _: &str| {
            vec![InputSplit {
                bucket: "b".into(),
                key: "data/part-0".into(),
                start: 0,
                end: 150,
                object_size: 150,
                stats: None,
            }]
        };
        let a = lineage_fingerprint(&rdd, &empty);
        let b = lineage_fingerprint(&rdd, &one);
        let c = lineage_fingerprint(&rdd, &grown);
        assert_ne!(a, b, "resolved splits are part of the key");
        assert_ne!(b, c, "a re-written object invalidates the entry");
    }

    #[test]
    fn scan_cache_round_trip_and_generation_invalidation() {
        let split = |key: &str| InputSplit {
            bucket: "b".into(),
            key: key.into(),
            start: 0,
            end: 10,
            object_size: 10,
            stats: None,
        };
        let sc = ScanCache::default();
        assert!(sc.get("b", "p/", 0).is_none());
        sc.put("b", "p/", 3, Arc::new(vec![split("p/part-0")]));
        assert!(sc.get("b", "p/", 3).is_some());
        assert!(sc.get("b", "q/", 3).is_none());
        // A bucket write advanced the generation: the entry is stale and
        // must not be served (e.g. the service just committed output
        // under the prefix it cached).
        assert!(sc.get("b", "p/", 4).is_none());
        // Re-resolution at the new generation replaces the entry.
        sc.put("b", "p/", 4, Arc::new(vec![split("p/part-0"), split("p/part-1")]));
        assert_eq!(sc.get("b", "p/", 4).unwrap().len(), 2);
        // Empty resolutions are never cached: a prefix read before its
        // data exists must re-list next time, not stay empty forever.
        sc.put("b", "empty/", 4, Arc::new(Vec::new()));
        assert!(sc.get("b", "empty/", 4).is_none());
    }
}
