//! The lineage-keyed cache: structural fingerprints over `Rdd` DAGs, a
//! capacity-bounded registry of materialized cuts, and the state one
//! `FlintService` shares across every query/tenant session (the cache
//! registry plus the hoisted scan-listing cache).
//!
//! # Fingerprints
//!
//! A cached cut is keyed by a canonical 64-bit FNV-1a hash over
//! everything that determines the cut's *bytes*:
//!
//! * the lineage structure below the marker (node kinds, partition
//!   counts, op chains),
//! * dataset identity: a `TextFile` source hashes its **resolved
//!   splits** — bucket, key, byte ranges, object sizes, and manifest
//!   stats — so re-generated data, a different split size, or a changed
//!   `scan_prune` stats view all change the key (invalidation by
//!   construction, never by TTL),
//! * result-affecting ops: a typed `DayRange` hashes its parameters;
//!   opaque closures (`Map`/`Filter`/`FlatMap`, `reduceByKey` combine)
//!   hash by `Arc` pointer identity.
//!
//! Closure pointer identity means cross-query reuse requires the
//! queries to *share* the op `Arc`s — i.e. be derived from the same
//! `Rdd` handles, exactly how a driver program reuses a cached RDD in
//! Spark. Two textually identical closures compiled separately never
//! alias, so the registry can never serve a wrong entry; it can only
//! miss. Diamonds hash each shared node once (pointer-memoized walk).
//!
//! # Registry
//!
//! Entries are LRU-over-bytes under `flint.cache.capacity_bytes`;
//! `capacity_bytes = 0` disables the cache entirely (markers stay
//! transparent, byte-identical to a build without this module). An
//! evicted entry only drops the registry mapping — its committed S3
//! objects stay until the bucket dies, and an identical rebuild
//! re-commits the same keys idempotently (first-commit-wins renames).

use crate::metrics::Metrics;
use crate::plan::rdd::{DynOp, Rdd, RddNode};
use crate::plan::task::{CachePart, InputSplit};
use crate::util::fnv1a64;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Hash one op into the node buffer: kind tag, then parameters (typed
/// predicates) or closure identity (opaque ones).
fn fp_op(op: &DynOp, buf: &mut Vec<u8>) {
    match op {
        DynOp::Map(f) => {
            buf.push(1);
            buf.extend_from_slice(&(Arc::as_ptr(f) as *const () as usize as u64).to_le_bytes());
        }
        DynOp::Filter(f) => {
            buf.push(2);
            buf.extend_from_slice(&(Arc::as_ptr(f) as *const () as usize as u64).to_le_bytes());
        }
        DynOp::FlatMap(f) => {
            buf.push(3);
            buf.extend_from_slice(&(Arc::as_ptr(f) as *const () as usize as u64).to_le_bytes());
        }
        DynOp::DayRange { min_day, max_day } => {
            buf.push(4);
            buf.extend_from_slice(&min_day.to_le_bytes());
            buf.extend_from_slice(&max_day.to_le_bytes());
        }
    }
}

fn fp_splits(splits: &[InputSplit], buf: &mut Vec<u8>) {
    buf.extend_from_slice(&(splits.len() as u64).to_le_bytes());
    for s in splits {
        buf.extend_from_slice(s.bucket.as_bytes());
        buf.push(0);
        buf.extend_from_slice(s.key.as_bytes());
        buf.push(0);
        buf.extend_from_slice(&s.start.to_le_bytes());
        buf.extend_from_slice(&s.end.to_le_bytes());
        buf.extend_from_slice(&s.object_size.to_le_bytes());
        match &s.stats {
            None => buf.push(0),
            Some(st) => {
                buf.push(1);
                buf.extend_from_slice(&st.min_day.to_le_bytes());
                buf.extend_from_slice(&st.max_day.to_le_bytes());
                buf.extend_from_slice(&st.min_month.to_le_bytes());
                buf.extend_from_slice(&st.max_month.to_le_bytes());
                buf.extend_from_slice(&st.rows.to_le_bytes());
            }
        }
    }
}

fn fp_node(
    rdd: &Rdd,
    splits: &dyn Fn(&str, &str) -> Vec<InputSplit>,
    memo: &mut HashMap<usize, u64>,
) -> u64 {
    let key = Arc::as_ptr(&rdd.node) as *const () as usize;
    if let Some(h) = memo.get(&key) {
        return *h;
    }
    let mut buf = Vec::new();
    match &*rdd.node {
        RddNode::TextFile { bucket, prefix } => {
            buf.push(1);
            buf.extend_from_slice(bucket.as_bytes());
            buf.push(0);
            buf.extend_from_slice(prefix.as_bytes());
            buf.push(0);
            fp_splits(&splits(bucket, prefix), &mut buf);
        }
        RddNode::Narrow { parent, op } => {
            buf.push(2);
            fp_op(op, &mut buf);
            buf.extend_from_slice(&fp_node(parent, splits, memo).to_le_bytes());
        }
        RddNode::ReduceByKey { parent, partitions, combine } => {
            buf.push(3);
            buf.extend_from_slice(&(*partitions as u64).to_le_bytes());
            buf.extend_from_slice(
                &(Arc::as_ptr(combine) as *const () as usize as u64).to_le_bytes(),
            );
            buf.extend_from_slice(&fp_node(parent, splits, memo).to_le_bytes());
        }
        RddNode::CoGroup { left, right, partitions } => {
            buf.push(4);
            buf.extend_from_slice(&(*partitions as u64).to_le_bytes());
            buf.extend_from_slice(&fp_node(left, splits, memo).to_le_bytes());
            buf.extend_from_slice(&fp_node(right, splits, memo).to_le_bytes());
        }
        // A nested marker is part of the structure but its storage level
        // is not: `persist(Memory)` and `persist(S3)` over the same
        // parent describe the same bytes, so they share one entry.
        RddNode::Cached { parent, .. } => {
            buf.push(5);
            buf.extend_from_slice(&fp_node(parent, splits, memo).to_le_bytes());
        }
    }
    let h = fnv1a64(&buf);
    memo.insert(key, h);
    h
}

/// Canonical fingerprint of a lineage (see module docs for what it
/// covers). `splits` resolves `TextFile` sources exactly like lowering
/// does — dataset identity and the stats view are part of the key.
pub fn lineage_fingerprint(rdd: &Rdd, splits: &dyn Fn(&str, &str) -> Vec<InputSplit>) -> u64 {
    fp_node(rdd, splits, &mut HashMap::new())
}

struct CacheEntry {
    parts: Arc<Vec<CachePart>>,
    bytes: u64,
    last_used: u64,
}

#[derive(Default)]
struct RegistryInner {
    entries: HashMap<u64, CacheEntry>,
    bytes: u64,
    tick: u64,
}

/// The shared fingerprint → materialized-parts registry. Admission and
/// eviction are byte-budgeted (LRU over bytes); the *tier* decision
/// (which parts carry a memory copy) is made by the session that built
/// the entry, before admitting it.
#[derive(Default)]
pub struct CacheRegistry {
    inner: Mutex<RegistryInner>,
}

impl CacheRegistry {
    pub fn new() -> CacheRegistry {
        CacheRegistry::default()
    }

    /// Look up a fingerprint, bumping its recency on a hit.
    pub fn lookup(&self, fp: u64) -> Option<Arc<Vec<CachePart>>> {
        let mut inner = self.inner.lock().expect("cache registry lock");
        inner.tick += 1;
        let tick = inner.tick;
        let entry = inner.entries.get_mut(&fp)?;
        entry.last_used = tick;
        Some(Arc::clone(&entry.parts))
    }

    /// Admit a freshly built entry, evicting least-recently-used entries
    /// until it fits. An entry larger than the whole capacity is
    /// rejected (the build's S3 objects still served the building query;
    /// they just aren't registered for reuse). Returns whether the entry
    /// was admitted.
    pub fn admit(
        &self,
        fp: u64,
        parts: Arc<Vec<CachePart>>,
        capacity_bytes: u64,
        metrics: &Metrics,
    ) -> bool {
        let bytes: u64 = parts.iter().map(|p| p.bytes).sum();
        if bytes > capacity_bytes {
            metrics.incr("cache.admission_rejected");
            return false;
        }
        let mut inner = self.inner.lock().expect("cache registry lock");
        if let Some(old) = inner.entries.remove(&fp) {
            // Racing builders (two sessions missed concurrently): keep
            // the newcomer, the bytes are identical by determinism.
            inner.bytes -= old.bytes;
        }
        while inner.bytes + bytes > capacity_bytes {
            let Some((&victim, _)) =
                inner.entries.iter().min_by_key(|(_, e)| e.last_used)
            else {
                break;
            };
            let evicted = inner.entries.remove(&victim).expect("victim exists");
            inner.bytes -= evicted.bytes;
            metrics.incr("cache.evictions");
        }
        inner.tick += 1;
        let tick = inner.tick;
        inner.entries.insert(fp, CacheEntry { parts, bytes, last_used: tick });
        inner.bytes += bytes;
        metrics.add("cache.bytes", bytes);
        true
    }

    /// Number of registered entries (tests/diagnostics).
    pub fn len(&self) -> usize {
        self.inner.lock().expect("cache registry lock").entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Registered bytes (tests/diagnostics).
    pub fn bytes(&self) -> u64 {
        self.inner.lock().expect("cache registry lock").bytes
    }
}

/// The hoisted scan-listing cache: one `(bucket, prefix)` → resolved
/// splits map shared by every session of a service, so repeat scans of
/// a popular prefix stop paying the LIST + per-object HEAD tax on every
/// query. Entries embed the stats view current at first resolution;
/// the cache lives exactly as long as the service (no TTL — the sim's
/// datasets are immutable once registered).
#[derive(Default)]
pub struct ScanCache {
    inner: Mutex<HashMap<(String, String), Arc<Vec<InputSplit>>>>,
}

impl ScanCache {
    pub fn get(&self, bucket: &str, prefix: &str) -> Option<Arc<Vec<InputSplit>>> {
        self.inner
            .lock()
            .expect("scan cache lock")
            .get(&(bucket.to_string(), prefix.to_string()))
            .cloned()
    }

    pub fn put(&self, bucket: &str, prefix: &str, splits: Arc<Vec<InputSplit>>) {
        self.inner
            .lock()
            .expect("scan cache lock")
            .insert((bucket.to_string(), prefix.to_string()), splits);
    }
}

/// Everything a `FlintService` shares across its per-query sessions:
/// the lineage cache registry and the scan-listing cache. Standalone
/// contexts own a private instance, which still gives repeat actions on
/// one context the same reuse.
#[derive(Default)]
pub struct ServiceShared {
    pub registry: CacheRegistry,
    pub scans: ScanCache,
}

impl ServiceShared {
    pub fn new() -> Arc<ServiceShared> {
        Arc::new(ServiceShared::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn part(key: &str, bytes: u64) -> CachePart {
        CachePart { bucket: "flint-cache".into(), key: key.into(), bytes, mem: None }
    }

    fn parts(total: u64, n: u64) -> Arc<Vec<CachePart>> {
        Arc::new((0..n).map(|i| part(&format!("p{i}"), total / n)).collect())
    }

    #[test]
    fn registry_lru_eviction_over_bytes() {
        let reg = CacheRegistry::new();
        let m = Metrics::new();
        assert!(reg.admit(1, parts(400, 2), 1000, &m));
        assert!(reg.admit(2, parts(400, 2), 1000, &m));
        // Touch 1 so 2 becomes the LRU victim.
        assert!(reg.lookup(1).is_some());
        assert!(reg.admit(3, parts(400, 2), 1000, &m));
        assert_eq!(m.get("cache.evictions"), 1);
        assert!(reg.lookup(2).is_none(), "LRU entry evicted");
        assert!(reg.lookup(1).is_some());
        assert!(reg.lookup(3).is_some());
        assert_eq!(reg.bytes(), 800);
        // An entry bigger than the whole budget is rejected outright.
        assert!(!reg.admit(4, parts(2000, 4), 1000, &m));
        assert_eq!(m.get("cache.admission_rejected"), 1);
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn fingerprint_is_structural_and_pointer_memoized() {
        let splits = |_: &str, _: &str| Vec::new();
        let base = Rdd::text_file("b", "data/");
        let mapped = base.map(|v| v);
        // Same handle → same fingerprint; a diamond sharing the node
        // hashes identically to either arm.
        assert_eq!(
            lineage_fingerprint(&mapped, &splits),
            lineage_fingerprint(&mapped.clone(), &splits)
        );
        // A structurally identical but separately compiled closure does
        // NOT alias (pointer identity): the registry can only miss, never
        // serve a wrong entry.
        let other = base.map(|v| v);
        assert_ne!(lineage_fingerprint(&mapped, &splits), lineage_fingerprint(&other, &splits));
        // Storage level is excluded: persist(Memory) and persist(S3)
        // over one parent describe the same bytes.
        use crate::plan::StorageLevel;
        assert_eq!(
            lineage_fingerprint(&mapped.persist(StorageLevel::Memory), &splits),
            lineage_fingerprint(&mapped.persist(StorageLevel::S3), &splits)
        );
        // But the marker itself is structural: cached vs plain differ.
        assert_ne!(
            lineage_fingerprint(&mapped.cache(), &splits),
            lineage_fingerprint(&mapped, &splits)
        );
        // Typed predicates hash by value, so two independently built
        // DayRange chains over the same source DO share.
        assert_eq!(
            lineage_fingerprint(&base.filter_day_range(3, 9), &splits),
            lineage_fingerprint(&base.filter_day_range(3, 9), &splits)
        );
        assert_ne!(
            lineage_fingerprint(&base.filter_day_range(3, 9), &splits),
            lineage_fingerprint(&base.filter_day_range(3, 10), &splits)
        );
    }

    #[test]
    fn fingerprint_covers_dataset_identity_via_splits() {
        let rdd = Rdd::text_file("b", "data/");
        let empty = |_: &str, _: &str| Vec::new();
        let one = |_: &str, _: &str| {
            vec![InputSplit {
                bucket: "b".into(),
                key: "data/part-0".into(),
                start: 0,
                end: 100,
                object_size: 100,
                stats: None,
            }]
        };
        let grown = |_: &str, _: &str| {
            vec![InputSplit {
                bucket: "b".into(),
                key: "data/part-0".into(),
                start: 0,
                end: 150,
                object_size: 150,
                stats: None,
            }]
        };
        let a = lineage_fingerprint(&rdd, &empty);
        let b = lineage_fingerprint(&rdd, &one);
        let c = lineage_fingerprint(&rdd, &grown);
        assert_ne!(a, b, "resolved splits are part of the key");
        assert_ne!(b, c, "a re-written object invalidates the entry");
    }

    #[test]
    fn scan_cache_round_trip() {
        let sc = ScanCache::default();
        assert!(sc.get("b", "p/").is_none());
        sc.put("b", "p/", Arc::new(Vec::new()));
        assert!(sc.get("b", "p/").is_some());
        assert!(sc.get("b", "q/").is_none());
    }
}
