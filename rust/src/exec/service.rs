//! [`FlintService`] — the multi-tenant query service layer.
//!
//! One Flint deployment, many tenants: the service admits sessions'
//! queries into a bounded queue (`flint.service.max_queued`; anything
//! past it is a typed [`ServiceError::QueueFull`] rejection, not a
//! panic), executes each admitted query through its own
//! metrics-namespaced view of the shared [`SimEnv`] (`q{n}.*`), and
//! places all of them on ONE shared slot pool with one event clock
//! ([`crate::simtime::schedule_service`]) under the configured
//! arbitration policy:
//!
//! * `fifo` — strict arrival order, one query at a time (each runs its
//!   exact solo schedule);
//! * `fair` — max-min fair slot sharing: every free slot goes to the
//!   admitted query holding the fewest;
//! * `weighted` — fair sharing over `flint.service.weight.<tenant>`, so
//!   a weight-2 tenant holds twice a weight-1 tenant's share under
//!   saturation.
//!
//! # Cost attribution
//!
//! Every dollar is attributed to exactly one tenant. Each query's spend
//! is an exact [`CostSnapshot`] diff around its execution (host
//! execution is serial, so the diffs partition the pool's spend), and
//! each query's *shared-clock* long-poll idle is billed afterwards from
//! its [`QueryWindow`] — single-query engines bill idle inside the
//! driver, but the service clears [`RunParams::bill_idle`] so idle
//! spend lands in the right [`CostLedger`]. By construction the ledgers
//! sum to the pool's total billed spend to the last bit (pinned by
//! `tests/multi_tenant.rs`).
//!
//! # Straggler prediction
//!
//! The service outlives any one query, so it can learn what a single
//! run cannot: which *containers* are slow. A [`StragglerPredictor`]
//! keeps a per-container EWMA of duration/median ratios (fed by the
//! driver after each stage commits; container identity comes from
//! `sim.straggler_containers` affinity mode) and the tail signal's
//! backup decisions are suppressed for tasks whose container has a
//! demonstrably non-slow history — that straggler is slow *work*, and
//! a backup would redo it at the same speed and lose. Unknown
//! containers keep the tail signal's call.
//!
//! [`CostSnapshot`]: crate::cost::CostSnapshot
//! [`QueryWindow`]: crate::simtime::QueryWindow
//! [`RunParams::bill_idle`]: crate::exec::driver::RunParams

use crate::config::ShuffleBackend;
use crate::cost::report::CostLedger;
use crate::cost::{CostCategory, CostSnapshot};
use crate::exec::cache::ServiceShared;
use crate::exec::flint::FlintEngine;
use crate::exec::session::FlintContext;
use crate::plan::{Action, ActionOut, Rdd};
use crate::services::SimEnv;
use crate::simtime::schedule::SpecPolicy;
use crate::simtime::{
    schedule_service, QueryWindow, ScheduleMode, ServicePolicy, ServiceQuerySpec,
};
use anyhow::Result;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, Mutex};

/// Typed admission failures — the driver-side contract callers program
/// against (retry-with-backoff on `QueueFull`, not string matching).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// The bounded admission queue is full (`flint.service.max_queued`).
    QueueFull { queued: usize, limit: usize },
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::QueueFull { queued, limit } => write!(
                f,
                "admission queue full: {queued} queries queued (flint.service.max_queued = {limit})"
            ),
        }
    }
}

impl std::error::Error for ServiceError {}

/// Per-container execution history for straggler *prediction* (the
/// speculation follow-up): an EWMA of each container's
/// duration-over-stage-median ratio, accumulated across every query the
/// service runs. `worth_backup` answers the only question the tail
/// signal needs: is this task slow because its *node* is slow?
#[derive(Debug, Default)]
pub struct StragglerPredictor {
    /// container id → (EWMA of duration/median, observations).
    history: Mutex<BTreeMap<u32, (f64, u64)>>,
}

/// EWMA weight of a new observation (history-heavy: one slow-work
/// outlier must not reclassify a consistently fast container).
const PREDICTOR_ALPHA: f64 = 0.3;
/// EWMA at or above this brands the container a slow node.
const PREDICTOR_SLOW_RATIO: f64 = 1.5;

impl StragglerPredictor {
    pub fn new() -> StragglerPredictor {
        StragglerPredictor::default()
    }

    /// Record one committed primary attempt: `ratio` is its duration
    /// over its stage's median.
    pub fn observe(&self, container: u32, ratio: f64) {
        if !ratio.is_finite() || ratio < 0.0 {
            return;
        }
        let mut h = self.history.lock().expect("predictor history");
        match h.get_mut(&container) {
            Some((ewma, n)) => {
                *ewma = (1.0 - PREDICTOR_ALPHA) * *ewma + PREDICTOR_ALPHA * ratio;
                *n += 1;
            }
            None => {
                h.insert(container, (ratio, 1));
            }
        }
    }

    /// Should a tail-signal decision against this container stand? True
    /// for slow-node history AND for unknown containers (no history —
    /// the tail signal's call is all there is). False only when the
    /// container has demonstrated it is not slow: then the straggle is
    /// slow work, and a backup would lose.
    pub fn worth_backup(&self, container: u32) -> bool {
        let h = self.history.lock().expect("predictor history");
        match h.get(&container) {
            Some((ewma, _)) => *ewma >= PREDICTOR_SLOW_RATIO,
            None => true,
        }
    }

    /// Containers with recorded history.
    pub fn containers_seen(&self) -> usize {
        self.history.lock().expect("predictor history").len()
    }
}

/// One admitted, not-yet-run query.
struct Pending {
    /// Service-lifetime query index (the `q{n}` metrics namespace).
    qid: usize,
    tenant: String,
    rdd: Rdd,
    action: Action,
    arrival_s: f64,
}

struct SvcState {
    pending: Vec<Pending>,
    next_qid: usize,
    ledgers: BTreeMap<String, CostLedger>,
}

/// One query's outcome on the shared clock.
#[derive(Debug)]
pub struct ServiceQueryReport {
    /// Service-lifetime query index (`q{n}` in the metrics registry).
    pub qid: usize,
    pub tenant: String,
    pub out: ActionOut,
    /// Where the query landed on the shared service clock (latency
    /// includes queue wait).
    pub window: QueryWindow,
    /// This query's exact spend: execution diff + its share of the
    /// shared-clock idle billing.
    pub cost: CostSnapshot,
    pub speculative_launches: u64,
}

/// The scheduled, fully-billed result of one [`FlintService::run`].
#[derive(Debug)]
pub struct ServiceReport {
    pub policy: ServicePolicy,
    pub slots: usize,
    /// When the last query finished on the shared clock.
    pub makespan_s: f64,
    /// Total occupied-but-idle seconds across all queries.
    pub idle_s: f64,
    /// Per-query outcomes, in submission order.
    pub queries: Vec<ServiceQueryReport>,
    /// Per-tenant ledgers as of this run (cumulative over the service
    /// lifetime).
    pub ledgers: BTreeMap<String, CostLedger>,
    /// The pool's total spend during this run — equals the sum of the
    /// run's per-query costs exactly.
    pub run_cost: CostSnapshot,
}

impl ServiceReport {
    /// Markdown ledger table (deterministic tenant order).
    pub fn render_ledgers(&self) -> String {
        crate::cost::report::render_ledgers(&self.ledgers)
    }
}

/// The driver-side multi-tenant service: one shared environment, one
/// slot pool, many tenants' sessions. See the module docs for the
/// admission/arbitration/billing contract.
pub struct FlintService {
    env: SimEnv,
    runtime: Option<Arc<crate::runtime::PjrtRuntime>>,
    predictor: Arc<StragglerPredictor>,
    /// Cross-query shared state: the lineage-keyed cache registry and
    /// the LIST/stats scan cache. Every session and every submitted
    /// query sees the same instance, so identical sub-lineages hit
    /// across queries and tenants.
    shared: Arc<ServiceShared>,
    state: Mutex<SvcState>,
}

impl FlintService {
    /// Stand up a service over `env`. PJRT artifacts (when enabled and
    /// present) are opened once and shared by every query.
    pub fn new(env: SimEnv) -> FlintService {
        let runtime = FlintEngine::new(env.clone()).runtime_handle();
        FlintService {
            env,
            runtime,
            predictor: Arc::new(StragglerPredictor::new()),
            shared: ServiceShared::new(),
            state: Mutex::new(SvcState {
                pending: Vec::new(),
                next_qid: 0,
                ledgers: BTreeMap::new(),
            }),
        }
    }

    pub fn env(&self) -> &SimEnv {
        &self.env
    }

    pub fn predictor(&self) -> &Arc<StragglerPredictor> {
        &self.predictor
    }

    /// Warm the shared Lambda pool (the paper benchmarks post-warm-up).
    pub fn prewarm(&self) {
        self.env
            .lambda()
            .prewarm("flint-exec", self.env.config().sim.max_concurrency);
    }

    /// A session bound to `tenant` for authoring lineages against this
    /// service's object store. (Running a lineage *through the shared
    /// pool* goes via [`FlintService::submit`]; a session used directly
    /// behaves like a standalone single-query engine.)
    pub fn session(&self, tenant: &str) -> FlintContext {
        let mut engine = FlintEngine::with_runtime(self.env.clone(), self.runtime.clone());
        engine.set_service_tuning(true, Some(Arc::clone(&self.predictor)));
        FlintContext::with_engine_for_tenant_shared(engine, tenant, Arc::clone(&self.shared))
    }

    /// The service-wide shared cache state (lineage cache registry +
    /// scan cache) — exposed for tests and cache introspection.
    pub fn shared(&self) -> &Arc<ServiceShared> {
        &self.shared
    }

    /// Submit a query arriving at service time 0 (a concurrent burst).
    pub fn submit(&self, tenant: &str, rdd: &Rdd, action: Action) -> Result<usize, ServiceError> {
        self.submit_at(tenant, rdd, action, 0.0)
    }

    /// Submit a query arriving at `arrival_s` on the service clock.
    /// Returns its service-lifetime query id, or `QueueFull` when the
    /// bounded admission queue is at `flint.service.max_queued`.
    pub fn submit_at(
        &self,
        tenant: &str,
        rdd: &Rdd,
        action: Action,
        arrival_s: f64,
    ) -> Result<usize, ServiceError> {
        let limit = self.env.config().flint.service.max_queued;
        let mut st = self.state.lock().expect("service state");
        if st.pending.len() >= limit {
            return Err(ServiceError::QueueFull { queued: st.pending.len(), limit });
        }
        let qid = st.next_qid;
        st.next_qid += 1;
        st.pending.push(Pending {
            qid,
            tenant: tenant.to_string(),
            rdd: rdd.clone(),
            action,
            arrival_s: arrival_s.max(0.0),
        });
        Ok(qid)
    }

    /// Compile a SQL statement against a session bound to `tenant` and
    /// submit the lowered lineage to the shared pool (arriving at
    /// service time 0, collecting its rows). Both failure modes — SQL
    /// frontend errors and `QueueFull` rejection — surface as typed
    /// errors inside the `anyhow` envelope. The returned query id's
    /// rows come back unshaped (partition order, no ORDER BY/LIMIT);
    /// use [`FlintContext::sql`] on a [`FlintService::session`] for
    /// fully shaped standalone results.
    pub fn submit_sql(&self, tenant: &str, text: &str) -> Result<usize> {
        let sc = self.session(tenant);
        let job = crate::sql::compile(&sc, text)?;
        Ok(self.submit(tenant, &job.rdd, Action::Collect)?)
    }

    /// Queries currently admitted and waiting for [`FlintService::run`].
    pub fn queued(&self) -> usize {
        self.state.lock().expect("service state").pending.len()
    }

    /// Cumulative per-tenant ledgers over the service lifetime.
    pub fn ledgers(&self) -> BTreeMap<String, CostLedger> {
        self.state.lock().expect("service state").ledgers.clone()
    }

    /// Drain the admission queue: execute every admitted query against
    /// the shared substrates (serially on the host — the *virtual*
    /// overlap is the scheduler's job), place all of them on the shared
    /// slot pool under the configured policy, bill each query's
    /// shared-clock idle to its tenant, and roll everything up into the
    /// per-tenant ledgers.
    pub fn run(&self) -> Result<ServiceReport> {
        let batch = {
            let mut st = self.state.lock().expect("service state");
            std::mem::take(&mut st.pending)
        };
        let cfg = self.env.config().clone();
        let svc = &cfg.flint.service;
        let slots = cfg.sim.max_concurrency;
        // Same mode resolution as the single-query engine: the S3
        // shuffle backend cannot overlap, so it pins the barrier clock.
        let mode = match cfg.flint.shuffle_backend {
            ShuffleBackend::Sqs => cfg.flint.scheduler,
            ShuffleBackend::S3 => ScheduleMode::Barrier,
            // Auto starts from the configured scheduler; inside each
            // query's run the driver demotes to barrier when an edge
            // resolves to S3, and the shared clock's stage specs carry
            // those measured durations either way.
            ShuffleBackend::Auto => cfg.flint.scheduler,
        };
        let spec_policy = cfg.flint.speculation.enabled.then(|| SpecPolicy {
            multiplier: cfg.flint.speculation.multiplier.max(1.0),
            quantile: cfg.flint.speculation.quantile.clamp(0.0, 1.0),
        });

        let run_start = self.env.cost().snapshot();
        let mut qspecs: Vec<ServiceQuerySpec> = Vec::with_capacity(batch.len());
        let mut partial: Vec<ServiceQueryReport> = Vec::with_capacity(batch.len());
        for p in batch {
            // Each query sees the shared services through its own
            // metrics namespace: scheduler counters land under
            // `q{n}.scheduler.*` while the substrates' own meters stay
            // global (shared infrastructure).
            let qenv = self.env.scoped(&format!("q{}", p.qid));
            let mut engine = FlintEngine::with_runtime(qenv.clone(), self.runtime.clone());
            engine.set_service_tuning(false, Some(Arc::clone(&self.predictor)));
            let ctx =
                FlintContext::with_engine_for_tenant_shared(engine, &p.tenant, Arc::clone(&self.shared));
            // Warm-container model: containers released before this
            // query's arrival past the keepalive window are gone.
            self.env.lambda().advance_to(p.arrival_s);
            // Snapshot BEFORE lowering: cache-marker resolution may
            // build cache entries (whole sub-plans run through the
            // shared substrates), and that spend belongs to the tenant
            // whose query triggered the build.
            let before = self.env.cost().snapshot();
            let plan = ctx.lower_for_run(&p.rdd, p.action.clone());
            let out = ctx
                .flint_engine()
                .expect("service sessions are Flint-backed")
                .run_plan_raw(&plan)?;
            let cost = self.env.cost().snapshot().since(&before);
            // Per-tenant metric rollup: everything this query metered
            // (its whole `q{n}.*` namespace) accumulates under
            // `tenant.{tenant}.*` too.
            let tm = self.env.metrics().scoped(&format!("tenant.{}", p.tenant));
            for (k, v) in qenv.metrics().snapshot() {
                tm.add(&k, v);
            }
            qspecs.push(ServiceQuerySpec {
                stages: out.stage_specs.clone(),
                arrival_s: p.arrival_s,
                weight: svc.weight_of(&p.tenant),
                quota: svc.quota_of(&p.tenant),
            });
            partial.push(ServiceQueryReport {
                qid: p.qid,
                tenant: p.tenant,
                out: out.out,
                // Placeholder until the shared clock runs below.
                window: QueryWindow {
                    query: 0,
                    arrival_s: p.arrival_s,
                    start_s: 0.0,
                    end_s: 0.0,
                    latency_s: 0.0,
                    idle_s: 0.0,
                    spec_launches: out.speculative_launches,
                    spec_wins: out.speculative_wins,
                },
                cost,
                speculative_launches: out.speculative_launches,
            });
        }

        // One shared clock over every query's measured stage specs.
        let sched = schedule_service(&qspecs, slots, mode, svc.policy, spec_policy.as_ref());
        for w in &sched.queries {
            let q = &mut partial[w.query];
            let (sl, sw) = (q.window.spec_launches, q.window.spec_wins);
            q.window = *w;
            // The host-side launch counts are the ground truth (the
            // clock re-derives timing, not the attempt table).
            q.window.spec_launches = sl;
            q.window.spec_wins = sw;
            // Shared-clock idle billing, attributed per query: the
            // driver skipped it (`bill_idle = false`), so the long-poll
            // GB-seconds each query actually held on the *service* clock
            // are charged here, into this tenant's diff window.
            if mode == ScheduleMode::Pipelined && w.idle_s > 0.0 {
                let before = self.env.cost().snapshot();
                self.env.lambda().bill_idle(w.idle_s);
                q.cost.add(&self.env.cost().snapshot().since(&before));
            }
        }
        let run_cost = self.env.cost().snapshot().since(&run_start);

        // Ledger rollup: every run_cost dollar is in exactly one
        // query's diff window, so Σ ledgers == pool spend exactly.
        let mut st = self.state.lock().expect("service state");
        for q in &partial {
            let ledger = st.ledgers.entry(q.tenant.clone()).or_default();
            ledger.queries += 1;
            ledger.gb_seconds +=
                q.cost.get(CostCategory::LambdaCompute) / cfg.pricing.lambda_gb_s;
            ledger.idle_s += q.window.idle_s;
            ledger.speculative_launches += q.speculative_launches;
            ledger.cost.add(&q.cost);
        }
        let ledgers = st.ledgers.clone();
        drop(st);

        Ok(ServiceReport {
            policy: svc.policy,
            slots,
            makespan_s: sched.makespan_s,
            idle_s: sched.idle_s,
            queries: partial,
            ledgers,
            run_cost,
        })
    }
}
