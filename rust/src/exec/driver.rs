//! The stage driver — the engine-agnostic core of the scheduler
//! (`SchedulerBackend` in the paper's terms): executes a physical plan
//! stage by stage with a barrier between stages, manages shuffle queue
//! lifecycle, launches tasks, handles retries and executor chaining, and
//! folds per-task timelines into the virtual-time stage makespan.

use crate::compute::queries::QueryResult;
use crate::compute::value::Value;
use crate::exec::executor::{run_task, Emitted, ExecCtx, IoMode, TaskOutcome};
use crate::exec::shuffle::{queue_name, Transport};
use crate::plan::{
    PhysicalPlan, ResumeState, Stage, StageInput, StageOutput, TaskDescriptor, TaskInput,
    TaskOutput,
};
use crate::runtime::PjrtRuntime;
use crate::services::SimEnv;
use crate::simtime::{makespan, Component, Timeline};
use anyhow::{anyhow, Result};

/// Engine-specific run parameters.
pub struct RunParams {
    pub mode: IoMode,
    pub transport: Transport,
    /// Virtual concurrency slots (Lambda concurrency limit or cluster
    /// cores) for the makespan model.
    pub slots: usize,
    /// Whether tasks run as Lambda invocations (cold starts, payload and
    /// duration limits, GB-second billing).
    pub lambda: bool,
    /// Real worker threads driving the simulation.
    pub host_parallelism: usize,
}

/// Merged result of a plan's final stage.
#[derive(Debug, Clone)]
pub enum ActionOut {
    Count(u64),
    KernelRows(Vec<(i64, f64, f64)>),
    Values(Vec<Value>),
    Saved(u64),
}

impl ActionOut {
    /// Convert to the benchmark-comparable form (kernel queries only).
    pub fn to_query_result(&self) -> Option<QueryResult> {
        match self {
            ActionOut::Count(n) => Some(QueryResult::Count(*n)),
            ActionOut::KernelRows(rows) => {
                let mut rows = rows.clone();
                rows.sort_by_key(|(k, _, _)| *k);
                Some(QueryResult::Buckets(rows))
            }
            _ => None,
        }
    }
}

/// Everything a plan run produces.
#[derive(Debug)]
pub struct RunOutput {
    pub out: ActionOut,
    /// Virtual query latency (Σ stage makespans + driver overhead).
    pub latency_s: f64,
    pub stage_latencies: Vec<f64>,
    /// Component-wise sum over all tasks (where the time went).
    pub timeline: Timeline,
    pub tasks: u64,
    pub invocations: u64,
    pub retries: u64,
    pub chains: u64,
    pub shuffle_msgs: u64,
    pub duplicates_dropped: u64,
    pub rows: u64,
}

/// Per-task accumulated stats returned by the task worker.
struct TaskStats {
    duration_s: f64,
    timeline: Timeline,
    invocations: u64,
    retries: u64,
    chains: u64,
    msgs_sent: u64,
    msgs_received: u64,
    duplicates_dropped: u64,
    rows: u64,
    emitted: Emitted,
}

const LAMBDA_FN: &str = "flint-exec";

/// Execute a physical plan.
pub fn run_plan(
    env: &SimEnv,
    runtime: Option<&PjrtRuntime>,
    plan: &PhysicalPlan,
    params: &RunParams,
) -> Result<RunOutput> {
    let cfg = env.config();
    let ctx = ExecCtx {
        env,
        runtime,
        plan,
        transport: params.transport.clone(),
        mode: params.mode,
        time_limit_s: params.lambda.then_some(cfg.sim.lambda_time_limit_s),
        chain_margin_s: cfg.sim.lambda_chain_margin_s,
        memory_limit_bytes: if params.lambda {
            env.lambda().memory_bytes()
        } else {
            // m4.2xlarge: 32 GiB over 8 task slots.
            4 * 1024 * 1024 * 1024
        },
    };

    let mut stage_latencies = Vec::new();
    let mut merged_tl = Timeline::new();
    let mut totals = RunOutput {
        out: ActionOut::Count(0),
        latency_s: 0.0,
        stage_latencies: Vec::new(),
        timeline: Timeline::new(),
        tasks: 0,
        invocations: 0,
        retries: 0,
        chains: 0,
        shuffle_msgs: 0,
        duplicates_dropped: 0,
        rows: 0,
    };
    let mut final_emits: Vec<Emitted> = Vec::new();
    let mut prev_stage_tasks = 0u32;

    for stage in &plan.stages {
        // Queue management is performed by the scheduler (§III-A):
        // create this stage's output queues before launching it.
        if let (StageOutput::Shuffle { partitions, .. }, Transport::Sqs) =
            (&stage.output, &params.transport)
        {
            for p in 0..*partitions {
                env.sqs().create_queue(&queue_name(&plan.plan_id, stage.id, p as u32));
            }
        }

        let descriptors = build_descriptors(plan, stage, prev_stage_tasks);
        let n_tasks = descriptors.len();
        let results = crate::util::threadpool::scoped_map(
            &descriptors,
            params.host_parallelism,
            |_, desc| run_task_with_recovery(&ctx, desc, params),
        );

        let mut durations = Vec::with_capacity(n_tasks);
        for r in results {
            let stats = r.map_err(|panic| anyhow!("task worker panicked: {panic}"))??;
            durations.push(stats.duration_s);
            merged_tl.merge(&stats.timeline);
            totals.invocations += stats.invocations;
            totals.retries += stats.retries;
            totals.chains += stats.chains;
            totals.shuffle_msgs += stats.msgs_sent + stats.msgs_received;
            totals.duplicates_dropped += stats.duplicates_dropped;
            totals.rows += stats.rows;
            if matches!(stage.output, StageOutput::Act(_)) {
                final_emits.push(stats.emitted);
            }
        }
        totals.tasks += n_tasks as u64;

        // Barrier: the stage finishes when its last task does.
        let overhead = cfg.sim.scheduler_overhead_per_stage_s
            + n_tasks as f64 * cfg.sim.scheduler_overhead_per_task_s;
        merged_tl.charge(Component::Scheduler, overhead);
        let stage_latency = makespan(&durations, params.slots) + overhead;
        stage_latencies.push(stage_latency);

        // Tear down the queues this stage consumed.
        if let (StageInput::Shuffle { partitions }, Transport::Sqs) =
            (&stage.input, &params.transport)
        {
            for p in 0..*partitions {
                let _ = env
                    .sqs()
                    .delete_queue(&queue_name(&plan.plan_id, stage.id - 1, p as u32));
            }
        }
        prev_stage_tasks = n_tasks as u32;
    }

    totals.out = merge_emits(final_emits)?;
    totals.latency_s = stage_latencies.iter().sum();
    totals.stage_latencies = stage_latencies;
    totals.timeline = merged_tl;
    Ok(totals)
}

fn build_descriptors(plan: &PhysicalPlan, stage: &Stage, prev_tasks: u32) -> Vec<TaskDescriptor> {
    let output = match &stage.output {
        StageOutput::Shuffle { partitions, .. } => {
            TaskOutput::Shuffle { partitions: *partitions as u32 }
        }
        StageOutput::Act(crate::plan::Action::SaveAsText { bucket, prefix }) => {
            TaskOutput::S3 { bucket: bucket.clone(), prefix: prefix.clone() }
        }
        StageOutput::Act(_) => TaskOutput::Driver,
    };
    let code_bytes = match &stage.compute {
        crate::plan::StageCompute::DynScan { ops } => {
            ops.iter().map(|o| o.code_bytes()).sum::<u64>() + 1024
        }
        crate::plan::StageCompute::DynReduce { post_ops, .. } => {
            post_ops.iter().map(|o| o.code_bytes()).sum::<u64>() + 2048
        }
        // Kernel tasks reference a named AOT artifact, not shipped code.
        _ => 256,
    };
    match &stage.input {
        StageInput::S3Splits(splits) => splits
            .iter()
            .enumerate()
            .map(|(i, split)| TaskDescriptor {
                plan_id: plan.plan_id.clone(),
                stage_id: stage.id,
                task_index: i as u32,
                attempt: 0,
                input: TaskInput::Split(split.clone()),
                output: output.clone(),
                resume: None,
                code_bytes,
            })
            .collect(),
        StageInput::Shuffle { partitions } => (0..*partitions)
            .map(|p| TaskDescriptor {
                plan_id: plan.plan_id.clone(),
                stage_id: stage.id,
                task_index: p as u32,
                attempt: 0,
                input: TaskInput::ShufflePartition {
                    partition: p as u32,
                    map_tasks: prev_tasks,
                },
                output: output.clone(),
                resume: None,
                code_bytes,
            })
            .collect(),
    }
}

/// Drive one task through chains and retries to completion.
fn run_task_with_recovery(
    ctx: &ExecCtx,
    base: &TaskDescriptor,
    params: &RunParams,
) -> Result<TaskStats> {
    let cfg = ctx.env.config();
    let max_retries = cfg.flint.max_task_retries;
    let mut stats = TaskStats {
        duration_s: 0.0,
        timeline: Timeline::new(),
        invocations: 0,
        retries: 0,
        chains: 0,
        msgs_sent: 0,
        msgs_received: 0,
        duplicates_dropped: 0,
        rows: 0,
        emitted: Emitted::Nothing,
    };
    let mut attempt: u32 = 0;
    // Chain checkpoints survive retries: a failed link restarts from the
    // last checkpoint, not from scratch (§III-B + §VI determinism).
    let mut resume: Option<ResumeState> = None;

    loop {
        let mut desc = base.clone();
        desc.attempt = attempt;
        desc.resume = resume.clone();

        let mut base_tl = Timeline::new();
        let mut will_fail = false;
        if params.lambda {
            // Payload-split workaround (§III-B): oversized task state is
            // staged through S3 instead of the invocation payload.
            let mut payload_len = desc.payload_len();
            if payload_len > cfg.sim.lambda_payload_limit_bytes {
                ctx.env.metrics().incr("scheduler.payload_spills");
                let spilled = desc.resume.as_ref().map(|r| r.partial.len()).unwrap_or(0) as u64
                    + desc.code_bytes;
                // Driver uploads, executor downloads.
                let put_dt = ctx.env.config().sim.s3_first_byte_s
                    + spilled as f64 / (ctx.env.config().sim.s3_put_mbps * 1e6);
                let get_dt = ctx.env.flint_read_profile().read_time_s(spilled);
                base_tl.charge(Component::S3Write, put_dt);
                base_tl.charge(Component::S3Read, get_dt);
                payload_len = 256; // the S3 reference that remains inline
            }
            let ticket = ctx
                .env
                .lambda()
                .begin_invoke(LAMBDA_FN, payload_len)
                .map_err(|e| anyhow!("invoke: {e}"))?;
            base_tl.charge(
                if ticket.cold { Component::ColdStart } else { Component::WarmStart },
                ticket.start_latency_s,
            );
            will_fail = ticket.will_fail;
            stats.invocations += 1;
        }

        let outcome = if will_fail {
            // The container died underneath the executor; whatever it had
            // received stays in flight until the visibility timeout. Our
            // model nacks immediately via the retry path (reducers nack in
            // their own failure handling; an early crash received nothing).
            TaskOutcome::Failed { error: "injected invocation crash".into(), timeline: base_tl }
        } else {
            run_task(ctx, &desc, base_tl)
        };

        match outcome {
            TaskOutcome::Done(resp) => {
                if params.lambda {
                    finish_lambda(ctx, &resp.timeline)?;
                }
                stats.duration_s += resp.timeline.total();
                stats.timeline.merge(&resp.timeline);
                stats.msgs_sent += resp.msgs_sent;
                stats.msgs_received += resp.shuffle_msgs_received;
                stats.duplicates_dropped += resp.duplicates_dropped;
                stats.rows = resp.rows;
                stats.emitted = resp.emitted;
                return Ok(stats);
            }
            TaskOutcome::Chained { resume: r, resp } => {
                if params.lambda {
                    finish_lambda(ctx, &resp.timeline)?;
                }
                ctx.env.metrics().incr("scheduler.chains");
                stats.duration_s += resp.timeline.total();
                stats.timeline.merge(&resp.timeline);
                stats.msgs_sent += resp.msgs_sent;
                stats.msgs_received += resp.shuffle_msgs_received;
                stats.chains += 1;
                resume = Some(r);
                // Same attempt continues in a fresh (warm) invocation.
            }
            TaskOutcome::Failed { error, timeline } => {
                if params.lambda {
                    // AWS bills the crashed invocation too.
                    let billed = crate::exec::executor::billed_duration(&timeline)
                        .min(ctx.env.config().sim.lambda_time_limit_s);
                    let _ = ctx.env.lambda().finish_invoke(LAMBDA_FN, billed);
                }
                stats.duration_s += timeline.total();
                stats.timeline.merge(&timeline);
                stats.retries += 1;
                ctx.env.metrics().incr("scheduler.task_retries");
                attempt += 1;
                if attempt > max_retries {
                    return Err(anyhow!(
                        "task s{}t{} failed after {} attempts: {error}",
                        base.stage_id,
                        base.task_index,
                        attempt
                    ));
                }
            }
        }
    }
}

fn finish_lambda(ctx: &ExecCtx, tl: &Timeline) -> Result<()> {
    ctx.env
        .lambda()
        .finish_invoke(LAMBDA_FN, crate::exec::executor::billed_duration(tl))
        .map_err(|e| anyhow!("lambda duration cap: {e} — chaining should have fired"))
}

fn merge_emits(emits: Vec<Emitted>) -> Result<ActionOut> {
    let mut count: Option<u64> = None;
    let mut rows: Vec<(i64, f64, f64)> = Vec::new();
    let mut values: Vec<Value> = Vec::new();
    let mut saved: Option<u64> = None;
    let mut saw_rows = false;
    for e in emits {
        match e {
            Emitted::Nothing => {}
            Emitted::Count(n) => *count.get_or_insert(0) += n,
            Emitted::KernelRows(mut r) => {
                saw_rows = true;
                rows.append(&mut r);
            }
            Emitted::Values(mut v) => values.append(&mut v),
            Emitted::Saved(n) => *saved.get_or_insert(0) += n,
        }
    }
    if let Some(n) = count {
        return Ok(ActionOut::Count(n));
    }
    if let Some(n) = saved {
        return Ok(ActionOut::Saved(n));
    }
    if saw_rows {
        rows.sort_by_key(|(k, _, _)| *k);
        return Ok(ActionOut::KernelRows(rows));
    }
    values.sort_by(|a, b| a.total_cmp(b));
    Ok(ActionOut::Values(values))
}
