//! The stage driver — the engine-agnostic core of the scheduler
//! (`SchedulerBackend` in the paper's terms), built around the stage
//! **DAG** and a first-class **task-attempt model**: it walks the plan
//! in dependency (topological) order, launches each stage's tasks onto
//! real worker threads, manages shuffle queue lifecycle per DAG edge (a
//! producer materializes one queue set per consuming stage — so fan-out
//! stages feed each consumer its own copy — and an edge's queues are
//! deleted the moment its consumer completes), and hands every
//! attempt's measured virtual duration to the event-driven global clock
//! (`simtime::schedule`) which decides how much of the execution
//! *overlaps*:
//!
//! * **barrier** mode reproduces the original serial model — a hard
//!   barrier between stages, latency = Σ (stage makespan + driver
//!   overhead). This is the honest model for the Qubole-style S3 shuffle
//!   backend and the exact-paper-reproduction mode whose numbers match
//!   the original Table I baseline.
//! * **pipelined** mode (the default since the Table I re-baseline) is
//!   the paper's SQS semantics (§III-A): reduce tasks are launched
//!   while their map stages still flush, long-poll their queues, and
//!   drain concurrently — so a consumer stage starts as soon as every
//!   parent has *started producing* rather than after it finished. The
//!   overlap is not free: a long-polling reducer occupies a live Lambda
//!   while idle, and the driver bills those GB-seconds
//!   (`RunOutput::pipelined_idle_s`).
//!
//! # The attempt model
//!
//! A task no longer "runs once, retries overwrite it". Each task owns a
//! table of **attempts**:
//!
//! * attempt 0 is the primary; a *failed* attempt N relaunches as
//!   attempt N+1 from the last chain checkpoint (`scheduler.task_retries`
//!   counts exactly the relaunches — per attempt, never per chain
//!   segment, and a task that exhausts its budget counts only the
//!   retries actually launched);
//! * with `flint.speculation = on`, the event clock's tail signal
//!   ([`crate::simtime::schedule::tail_signal`]) picks stragglers —
//!   tasks still running past `flint.speculation.multiplier` × the
//!   median committed span once `flint.speculation.quantile` of their
//!   stage committed — and the driver launches a **speculative backup
//!   attempt** (the next attempt number) that really re-executes on the
//!   host, racing the primary's output through the shuffle;
//! * commits are **first-attempt-wins**: the virtual clock commits a
//!   task at its earliest-finishing attempt and cancels the loser at
//!   that instant (`scheduler.speculative_launches` /
//!   `scheduler.speculative_wins`). On the host, the winner's emitted
//!   result is the one merged; the loser's duplicate shuffle output is
//!   byte-identical by the determinism contract and dedups away —
//!   attempt-safe commits (`exec::executor` seals every attempt's
//!   output *before* its input ack) mean a cancelled loser can never
//!   leave a torn partition. Every attempt — including cancelled losers
//!   — bills its GB-seconds: Lambda has no mid-flight cancellation.
//!
//! Host execution always proceeds parent-before-child (the simulated
//! queues only hold data after producers flush); the *virtual* overlap
//! is computed from the measured per-attempt durations. Both latencies
//! (and the speculation-free pipelined clock) are reported on every
//! run, so ablations never need a second execution.

use crate::compute::value::Value;
use crate::exec::exchange::plan_exchanges;
use crate::exec::executor::{run_task, Emitted, ExecCtx, IoMode, TaskOutcome};
use crate::exec::shuffle::{merge_tree_level, queue_name, s3_edge_prefix, Transport};
use crate::plan::{
    PhysicalPlan, ResumeState, Stage, StageInput, StageOutput, TaskDescriptor, TaskInput,
    TaskOutput,
};

pub use crate::plan::ActionOut;
use crate::runtime::PjrtRuntime;
use crate::services::SimEnv;
use crate::simtime::schedule::{schedule_dag_spec, tail_signal, SpecPolicy};
use crate::simtime::{
    makespan, schedule_dag, Component, ScheduleMode, StageSpec, StageWindow, Timeline,
};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Engine-specific run parameters.
pub struct RunParams {
    pub mode: IoMode,
    pub transport: Transport,
    /// Virtual concurrency slots (Lambda concurrency limit or cluster
    /// cores) for the scheduling model.
    pub slots: usize,
    /// Whether tasks run as Lambda invocations (cold starts, payload and
    /// duration limits, GB-second billing).
    pub lambda: bool,
    /// Real worker threads driving the simulation.
    pub host_parallelism: usize,
    /// Stage-overlap policy for the virtual clock: `Barrier` is the
    /// serial Σ-makespan model, `Pipelined` overlaps reduce long-polling
    /// with map flushes (§III-A).
    pub schedule: ScheduleMode,
    /// Bill pipelined long-poll idle GB-seconds inside this run. Single
    /// query engines leave this on; the multi-tenant service turns it
    /// off and bills each query's idle from the *shared-clock* schedule
    /// instead, so the spend lands in the right tenant's ledger.
    pub bill_idle: bool,
    /// Per-container execution history feeding the speculation tail
    /// signal: when present, a threshold-crossing task whose container
    /// has a non-slow track record is treated as slow *work* (not a slow
    /// node) and its backup is suppressed.
    pub predictor: Option<std::sync::Arc<crate::exec::service::StragglerPredictor>>,
}

/// Shuffle volume over one DAG edge (producer stage → consumer stage).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EdgeShuffle {
    pub from: u32,
    pub to: u32,
    /// Messages the consumer stage received over this edge (pre-dedup).
    pub msgs: u64,
    /// Encoded shuffle record bytes the producer stage sent over this
    /// edge — the quantity the rows-vs-columnar codec ablation compares.
    pub bytes: u64,
}

/// Everything a plan run produces.
#[derive(Debug)]
pub struct RunOutput {
    pub out: ActionOut,
    /// Virtual query latency under the *selected* schedule mode.
    pub latency_s: f64,
    /// Latency under the serial barrier model (always computed).
    pub barrier_latency_s: f64,
    /// Latency under the pipelined model (always computed).
    pub pipelined_latency_s: f64,
    /// Per-stage `makespan + overhead` (the classic Σ terms).
    pub stage_latencies: Vec<f64>,
    /// Per-stage start/end on the serial barrier clock.
    pub barrier_windows: Vec<StageWindow>,
    /// Per-stage start/end on the pipelined DAG clock.
    pub pipelined_windows: Vec<StageWindow>,
    /// Per-edge shuffle receive volume.
    pub edge_shuffle: Vec<EdgeShuffle>,
    /// Component-wise sum over all tasks (where the time went).
    pub timeline: Timeline,
    pub tasks: u64,
    pub invocations: u64,
    pub retries: u64,
    pub chains: u64,
    pub shuffle_msgs: u64,
    pub duplicates_dropped: u64,
    pub rows: u64,
    /// Speculative backup attempts the driver actually launched.
    pub speculative_launches: u64,
    /// Backups that would commit before their primary (stage-local
    /// first-commit-wins; the global clocks re-derive exact timing).
    pub speculative_wins: u64,
    /// Occupied-but-idle seconds on the pipelined clock (long-polling
    /// reducers holding live Lambdas); billed as GB-seconds whenever the
    /// pipelined schedule is the selected one.
    pub pipelined_idle_s: f64,
    /// The pipelined clock *without* speculative backups — equals
    /// `pipelined_latency_s` when speculation is off, so one execution
    /// yields the exact speculation ablation.
    pub pipelined_nospec_latency_s: f64,
    /// The measured per-stage schedule inputs (durations, backups,
    /// overheads, DAG edges). The multi-tenant service replays these
    /// through the shared-clock scheduler to place many queries on one
    /// slot pool without re-executing anything.
    pub stage_specs: Vec<StageSpec>,
}

/// Per-task accumulated stats returned by the task worker.
struct TaskStats {
    duration_s: f64,
    timeline: Timeline,
    invocations: u64,
    retries: u64,
    chains: u64,
    msgs_sent: u64,
    msgs_received: u64,
    duplicates_dropped: u64,
    rows: u64,
    /// Messages received per parent stage (DAG edge accounting).
    edge_received: Vec<(u32, u64)>,
    /// Encoded bytes sent per consuming stage (codec accounting).
    edge_sent: Vec<(u32, u64)>,
    emitted: Emitted,
}

const LAMBDA_FN: &str = "flint-exec";

/// Execute a physical plan.
pub fn run_plan(
    env: &SimEnv,
    runtime: Option<&PjrtRuntime>,
    plan: &PhysicalPlan,
    params: &RunParams,
) -> Result<RunOutput> {
    plan.validate().map_err(|e| anyhow!("invalid plan {}: {e}", plan.plan_id))?;
    let cfg = env.config();
    // Resolve every DAG edge to its transport/exchange up front (the
    // `flint.shuffle.backend = auto` cost model and the tree exchange
    // both live here; explicit backends map every edge to the base
    // transport as before).
    let exchange = std::sync::Arc::new(plan_exchanges(&cfg, plan, &params.transport));
    // One-shot list-then-get S3 edges cannot overlap reduce drain with
    // map flushes, so any S3-resolved edge demotes the selected clock
    // to the barrier model (explicit `backend = s3` already arrives
    // with barrier forced; this generalizes the rule to `auto`).
    let schedule = if exchange.any_s3() { ScheduleMode::Barrier } else { params.schedule };
    let ctx = ExecCtx {
        env,
        runtime,
        plan,
        transport: params.transport.clone(),
        exchange: exchange.clone(),
        mode: params.mode,
        time_limit_s: params.lambda.then_some(cfg.sim.lambda_time_limit_s),
        chain_margin_s: cfg.sim.lambda_chain_margin_s,
        memory_limit_bytes: if params.lambda {
            env.lambda().memory_bytes()
        } else {
            // m4.2xlarge: 32 GiB over 8 task slots.
            4 * 1024 * 1024 * 1024
        },
    };

    // The tail-signal policy: `flint.speculation = off` takes the exact
    // pre-attempt-model code paths (no tail signal, no backups, plain
    // schedules) — byte-identical by construction.
    let policy = if cfg.flint.speculation.enabled {
        Some(SpecPolicy {
            multiplier: cfg.flint.speculation.multiplier.max(1.0),
            quantile: cfg.flint.speculation.quantile.clamp(0.0, 1.0),
        })
    } else {
        None
    };

    let mut specs: Vec<StageSpec> = Vec::with_capacity(plan.stages.len());
    let mut stage_latencies = Vec::new();
    let mut merged_tl = Timeline::new();
    let mut totals = RunOutput {
        out: ActionOut::Count(0),
        latency_s: 0.0,
        barrier_latency_s: 0.0,
        pipelined_latency_s: 0.0,
        stage_latencies: Vec::new(),
        barrier_windows: Vec::new(),
        pipelined_windows: Vec::new(),
        edge_shuffle: Vec::new(),
        timeline: Timeline::new(),
        tasks: 0,
        invocations: 0,
        retries: 0,
        chains: 0,
        shuffle_msgs: 0,
        duplicates_dropped: 0,
        rows: 0,
        speculative_launches: 0,
        speculative_wins: 0,
        pipelined_idle_s: 0.0,
        pipelined_nospec_latency_s: 0.0,
        stage_specs: Vec::new(),
    };
    let mut final_emits: Vec<Emitted> = Vec::new();
    let mut edge_msgs: BTreeMap<(u32, u32), u64> = BTreeMap::new();
    let mut edge_bytes: BTreeMap<(u32, u32), u64> = BTreeMap::new();

    // Host execution in topological (id) order: the simulated shuffle
    // substrates hold a producer's data only after it flushed, so real
    // threads must respect dependencies even when the virtual clock
    // overlaps the stages.
    for stage in &plan.stages {
        // Create this stage's output queues before launching it: one
        // queue set per SQS-resolved consuming edge (§III-A: "queue
        // management is performed by the scheduler") — payload and S3
        // edges need no queues. A shuffle stage nothing consumes
        // (degenerate plans) has no edges and so no queues — its writer
        // drops the stream.
        if let StageOutput::Shuffle { partitions, .. } = &stage.output {
            for to in plan.children(stage.id) {
                if matches!(exchange.transport_for(stage.id, to), Transport::Sqs) {
                    for p in 0..*partitions {
                        env.sqs()
                            .create_queue(&queue_name(&plan.plan_id, stage.id, to, p as u32));
                    }
                }
            }
        }

        let descriptors = build_descriptors(plan, stage);
        let n_tasks = descriptors.len();
        let results = crate::util::threadpool::scoped_map(
            &descriptors,
            params.host_parallelism,
            |_, desc| run_task_with_recovery(&ctx, desc, params),
        );

        // Attempt table, primary column: one committed attempt per task.
        let mut primaries: Vec<TaskStats> = Vec::with_capacity(n_tasks);
        for r in results {
            let stats = r.map_err(|panic| anyhow!("task worker panicked: {panic}"))??;
            primaries.push(stats);
        }

        // Attempt table, speculative column: the stage-local tail signal
        // (the same event clock the global schedule uses) picks the
        // stragglers, and the driver re-executes them NOW — the stage's
        // input (S3 splits / parent queues) and output queues still
        // exist, so the backup races the primary's commit for real. The
        // backup is the task's next attempt number; its byte-identical
        // shuffle re-sends dedup downstream, and only the winning
        // attempt's driver-facing result is merged.
        let mut backups: Vec<Option<f64>> = vec![None; n_tasks];
        if let Some(policy) = &policy {
            let durations: Vec<f64> = primaries.iter().map(|s| s.duration_s).collect();
            let mut decisions = tail_signal(&durations, params.slots, policy);
            // Which tasks may actually speculate — a per-edge question
            // since auto backend selection: a shuffle-input task may
            // back up only when EVERY parent edge is re-readable
            // (list-then-get S3). On destructive-read edges (SQS,
            // memory, payload-inline) the primary's commit acked the
            // partition away, so a backup would drain an empty queue in
            // ~0s — an unmeasurable (and dishonestly flattering)
            // duration; the host runs stages serially and cannot
            // reproduce the real race against the visibility timeout.
            // S3-materializing reduce tasks speculate like any other
            // since the attempt-scoped output committer (temp key +
            // first-wins rename) — the PR 4 carve-out is lifted. Scan
            // tasks (re-readable S3 splits) always may.
            decisions.retain(|d| match &descriptors[d.task].input {
                TaskInput::ShufflePartition { parents, .. } => parents
                    .iter()
                    .all(|p| exchange.transport_for(*p, stage.id).rereadable()),
                _ => true,
            });
            // Straggler prediction (the PR-4 follow-up): a task past the
            // tail threshold on a container whose history says "not
            // slow" is slow *work* — a backup would redo the same work
            // at the same speed and lose. Suppress it. Containers with
            // no history (and i.i.d. straggler mode, which has no
            // containers at all) keep the tail signal's call.
            if let Some(pred) = &params.predictor {
                decisions.retain(|d| {
                    let keep = env
                        .failure()
                        .container_of(stage.id, d.task as u32, primaries[d.task].retries as u32)
                        .map(|c| pred.worth_backup(c))
                        .unwrap_or(true);
                    if !keep {
                        env.metrics().incr("scheduler.speculative_suppressed");
                    }
                    keep
                });
            }
            if !decisions.is_empty() {
                let backup_descs: Vec<TaskDescriptor> = decisions
                    .iter()
                    .map(|d| {
                        let mut b = descriptors[d.task].clone();
                        b.attempt = primaries[d.task].retries as u32 + 1;
                        b
                    })
                    .collect();
                let backup_results = crate::util::threadpool::scoped_map(
                    &backup_descs,
                    params.host_parallelism,
                    |_, desc| run_task_with_recovery(&ctx, desc, params),
                );
                for (d, r) in decisions.iter().zip(backup_results) {
                    env.metrics().incr("scheduler.speculative_launches");
                    totals.speculative_launches += 1;
                    match r.map_err(|panic| anyhow!("backup worker panicked: {panic}"))? {
                        Ok(bstats) => {
                            if d.launch_at + bstats.duration_s
                                < d.primary_start + primaries[d.task].duration_s
                            {
                                env.metrics().incr("scheduler.speculative_wins");
                                totals.speculative_wins += 1;
                            }
                            backups[d.task] = Some(bstats.duration_s);
                            // Resource accounting is real for both
                            // attempts; results are merged winner-only
                            // (and a backup's duplicate output is
                            // byte-identical anyway).
                            merged_tl.merge(&bstats.timeline);
                            totals.invocations += bstats.invocations;
                            totals.retries += bstats.retries;
                            totals.chains += bstats.chains;
                            totals.shuffle_msgs += bstats.msgs_sent + bstats.msgs_received;
                            totals.duplicates_dropped += bstats.duplicates_dropped;
                            for (from, msgs) in &bstats.edge_received {
                                *edge_msgs.entry((*from, stage.id)).or_insert(0) += *msgs;
                            }
                            for (to, b) in &bstats.edge_sent {
                                *edge_bytes.entry((stage.id, *to)).or_insert(0) += *b;
                            }
                        }
                        Err(_) => {
                            // A backup that crashes out never fails the
                            // query — the primary already committed.
                            env.metrics().incr("scheduler.speculative_failures");
                        }
                    }
                }
            }
        }

        // Per-container execution history (straggler *prediction*):
        // each committed primary reports its container and its
        // duration-over-stage-median ratio. Observed AFTER this stage's
        // backup decisions — suppression must judge a container on its
        // *prior* record, not on the very observation that tripped the
        // tail signal. Over a service lifetime the history spans
        // queries, because container placement does too.
        if let Some(pred) = &params.predictor {
            let mut sorted: Vec<f64> = primaries.iter().map(|s| s.duration_s).collect();
            sorted.sort_by(f64::total_cmp);
            let med = sorted[sorted.len() / 2].max(1e-9);
            for (t, s) in primaries.iter().enumerate() {
                if let Some(c) =
                    env.failure().container_of(stage.id, t as u32, s.retries as u32)
                {
                    pred.observe(c, s.duration_s / med);
                }
            }
        }

        // Tree exchange: run each tree edge's merge level now that every
        // attempt of this stage (primaries and backups) has committed
        // its level-1 objects. The merge tasks sit between this stage
        // and its consumers; packing their durations onto the slot pool
        // and folding the makespan into this stage's overhead models the
        // extra level exactly under the barrier clock — which S3 edges
        // pin (see the `schedule` demotion above).
        let mut merge_overhead_s = 0.0;
        for to in plan.children(stage.id) {
            let Some(tp) = exchange.edge(stage.id, to).and_then(|e| e.tree) else { continue };
            let report = merge_tree_level(env, &plan.plan_id, stage.id, to, &tp)?;
            if report.task_durations.is_empty() {
                continue;
            }
            if params.lambda {
                // Merge tasks hold live Lambdas for their modeled
                // duration; billed as GB-seconds (no failure injection —
                // the level is driver-coordinated and single-attempt).
                env.lambda().bill_idle(report.task_durations.iter().sum());
            }
            env.metrics()
                .add("shuffle.tree_merge_tasks", report.task_durations.len() as u64);
            env.metrics().add("shuffle.tree_objects_read", report.objects_read);
            env.metrics().add("shuffle.tree_objects_written", report.objects_written);
            merged_tl.merge(&report.timeline);
            merge_overhead_s += makespan(&report.task_durations, params.slots);
        }

        let mut durations = Vec::with_capacity(n_tasks);
        for stats in primaries {
            durations.push(stats.duration_s);
            merged_tl.merge(&stats.timeline);
            totals.invocations += stats.invocations;
            totals.retries += stats.retries;
            totals.chains += stats.chains;
            totals.shuffle_msgs += stats.msgs_sent + stats.msgs_received;
            totals.duplicates_dropped += stats.duplicates_dropped;
            totals.rows += stats.rows;
            for (from, msgs) in &stats.edge_received {
                *edge_msgs.entry((*from, stage.id)).or_insert(0) += *msgs;
            }
            for (to, b) in &stats.edge_sent {
                *edge_bytes.entry((stage.id, *to)).or_insert(0) += *b;
            }
            if matches!(stage.output, StageOutput::Act(_)) {
                final_emits.push(stats.emitted);
            }
        }
        totals.tasks += n_tasks as u64;

        let overhead = cfg.sim.scheduler_overhead_per_stage_s
            + n_tasks as f64 * cfg.sim.scheduler_overhead_per_task_s
            + merge_overhead_s;
        merged_tl.charge(Component::Scheduler, overhead);
        let ms = makespan(&durations, params.slots);
        stage_latencies.push(ms + overhead);
        specs.push(StageSpec {
            id: stage.id,
            parents: stage.parents.clone(),
            task_durations: durations,
            backups,
            overhead_s: overhead,
        });

        // Per-edge teardown: an edge's substrate belongs to exactly one
        // (parent → this stage) pair, so it dies the moment this stage —
        // its only consumer — completes. SQS edges delete their queue
        // set; S3 edges (and payload edges' spill leg) delete the edge's
        // whole key prefix — committed objects, tree group objects, and
        // any crashed attempt's orphaned temps alike. A fan-out parent's
        // other edges are untouched (their consumers haven't run yet).
        for &p in &stage.parents {
            match exchange.transport_for(p, stage.id) {
                Transport::Sqs => delete_edge_queues(env, plan, p, stage.id),
                Transport::S3 | Transport::Payload(_) => {
                    let _ = env.s3().delete_prefix(
                        crate::data::SHUFFLE_BUCKET,
                        &s3_edge_prefix(&plan.plan_id, p, stage.id),
                    );
                }
                Transport::Memory(_) => {}
            }
        }
    }

    // Both clocks from the same measured attempt durations: ablation for
    // free. With speculation on, the clocks place the backups too; the
    // speculation-free pipelined clock is always computed alongside so
    // one execution prices the exact latency speculation bought.
    let barrier = schedule_dag_spec(&specs, params.slots, ScheduleMode::Barrier, policy.as_ref());
    let pipelined =
        schedule_dag_spec(&specs, params.slots, ScheduleMode::Pipelined, policy.as_ref());
    totals.pipelined_nospec_latency_s = if policy.is_some() {
        schedule_dag(&specs, params.slots, ScheduleMode::Pipelined).latency_s
    } else {
        pipelined.latency_s
    };

    for ((from, to), msgs) in &edge_msgs {
        env.metrics().add(&format!("shuffle.edge.s{from}-s{to}.msgs"), *msgs);
    }
    for ((from, to), bytes) in &edge_bytes {
        env.metrics().add(&format!("shuffle.edge.s{from}-s{to}.bytes"), *bytes);
    }

    totals.out = merge_emits(final_emits)?;
    totals.latency_s = match schedule {
        ScheduleMode::Barrier => barrier.latency_s,
        ScheduleMode::Pipelined => pipelined.latency_s,
    };
    totals.pipelined_idle_s = pipelined.idle_s;
    // The pipelined overlap's cost side: long-polling consumers hold
    // live Lambdas while idle, and AWS bills wall-clock duration. Only
    // the selected clock's idle is billed (barrier runs have none), and
    // only on Lambda-backed engines — cluster executors bill by the
    // hour, idle included, already. The multi-tenant service clears
    // `bill_idle` and charges each query's idle from the shared clock.
    if params.lambda && params.bill_idle && schedule == ScheduleMode::Pipelined {
        env.lambda().bill_idle(pipelined.idle_s);
    }
    totals.barrier_latency_s = barrier.latency_s;
    totals.pipelined_latency_s = pipelined.latency_s;
    totals.barrier_windows = barrier.stages;
    totals.pipelined_windows = pipelined.stages;
    totals.stage_latencies = stage_latencies;
    // One row per edge, msgs from the receiver side and bytes from the
    // sender side (the maps cover the same edges on a clean run; a union
    // keeps partial accounting honest if one side is missing).
    let mut edges: BTreeMap<(u32, u32), (u64, u64)> = BTreeMap::new();
    for ((from, to), msgs) in edge_msgs {
        edges.entry((from, to)).or_insert((0, 0)).0 = msgs;
    }
    for ((from, to), bytes) in edge_bytes {
        edges.entry((from, to)).or_insert((0, 0)).1 = bytes;
    }
    totals.edge_shuffle = edges
        .into_iter()
        .map(|((from, to), (msgs, bytes))| EdgeShuffle { from, to, msgs, bytes })
        .collect();
    totals.timeline = merged_tl;
    totals.stage_specs = specs;
    Ok(totals)
}

fn delete_edge_queues(env: &SimEnv, plan: &PhysicalPlan, from: u32, to: u32) {
    if let StageOutput::Shuffle { partitions, .. } = &plan.stage(from).output {
        for p in 0..*partitions {
            let _ = env
                .sqs()
                .delete_queue(&queue_name(&plan.plan_id, from, to, p as u32));
        }
    }
}

fn build_descriptors(plan: &PhysicalPlan, stage: &Stage) -> Vec<TaskDescriptor> {
    let output = match &stage.output {
        StageOutput::Shuffle { partitions, .. } => {
            TaskOutput::Shuffle { partitions: *partitions as u32 }
        }
        StageOutput::Act(crate::plan::Action::SaveAsText { bucket, prefix }) => {
            TaskOutput::S3 { bucket: bucket.clone(), prefix: prefix.clone() }
        }
        StageOutput::Act(crate::plan::Action::CacheWrite { bucket, prefix }) => {
            TaskOutput::S3 { bucket: bucket.clone(), prefix: prefix.clone() }
        }
        StageOutput::Act(_) => TaskOutput::Driver,
    };
    let code_bytes = match &stage.compute {
        crate::plan::StageCompute::DynScan { ops } => {
            ops.iter().map(|o| o.code_bytes()).sum::<u64>() + 1024
        }
        crate::plan::StageCompute::CachedScan { ops } => {
            ops.iter().map(|o| o.code_bytes()).sum::<u64>() + 1024
        }
        crate::plan::StageCompute::DynReduce { post_ops, .. } => {
            post_ops.iter().map(|o| o.code_bytes()).sum::<u64>() + 2048
        }
        crate::plan::StageCompute::DynCoGroup { post_ops } => {
            post_ops.iter().map(|o| o.code_bytes()).sum::<u64>() + 2048
        }
        // Kernel tasks reference a named AOT artifact, not shipped code.
        _ => 256,
    };
    match &stage.input {
        StageInput::S3Splits(splits) => splits
            .iter()
            .enumerate()
            .map(|(i, split)| TaskDescriptor {
                plan_id: plan.plan_id.clone(),
                stage_id: stage.id,
                task_index: i as u32,
                attempt: 0,
                input: TaskInput::Split(split.clone()),
                output: output.clone(),
                resume: None,
                code_bytes,
            })
            .collect(),
        StageInput::Shuffle { partitions } => (0..*partitions)
            .map(|p| TaskDescriptor {
                plan_id: plan.plan_id.clone(),
                stage_id: stage.id,
                task_index: p as u32,
                attempt: 0,
                input: TaskInput::ShufflePartition {
                    partition: p as u32,
                    parents: stage.parents.clone(),
                },
                output: output.clone(),
                resume: None,
                code_bytes,
            })
            .collect(),
        StageInput::CacheParts(parts) => parts
            .iter()
            .enumerate()
            .map(|(i, part)| TaskDescriptor {
                plan_id: plan.plan_id.clone(),
                stage_id: stage.id,
                task_index: i as u32,
                attempt: 0,
                input: TaskInput::CachedPart(part.clone()),
                output: output.clone(),
                resume: None,
                code_bytes,
            })
            .collect(),
    }
}

/// Merge per-edge received counts (small vectors; linear scan is fine).
fn merge_edges(into: &mut Vec<(u32, u64)>, from: &[(u32, u64)]) {
    for &(p, m) in from {
        match into.iter_mut().find(|(q, _)| *q == p) {
            Some((_, tot)) => *tot += m,
            None => into.push((p, m)),
        }
    }
}

/// Drive one task through chains and retries to completion.
fn run_task_with_recovery(
    ctx: &ExecCtx,
    base: &TaskDescriptor,
    params: &RunParams,
) -> Result<TaskStats> {
    let cfg = ctx.env.config();
    let max_retries = cfg.flint.max_task_retries;
    let mut stats = TaskStats {
        duration_s: 0.0,
        timeline: Timeline::new(),
        invocations: 0,
        retries: 0,
        chains: 0,
        msgs_sent: 0,
        msgs_received: 0,
        duplicates_dropped: 0,
        rows: 0,
        edge_received: Vec::new(),
        edge_sent: Vec::new(),
        emitted: Emitted::Nothing,
    };
    // Primaries arrive as attempt 0; a speculative backup arrives with
    // its own (higher) attempt number and MUST keep it — the straggler
    // draw below is keyed by attempt, which is exactly what lets a
    // backup land on a clean container while its primary straggles.
    let mut attempt: u32 = base.attempt;
    // Chain checkpoints survive retries: a failed link restarts from the
    // last checkpoint, not from scratch (§III-B + §VI determinism).
    let mut resume: Option<ResumeState> = None;
    // One straggler draw per *attempt* (a slow container is slow for
    // every chain link it hosts; the attempt's retry — and a speculative
    // backup, which arrives here as a higher attempt number — draws
    // fresh).
    let mut straggle = ctx
        .env
        .failure()
        .straggler_factor(base.stage_id, base.task_index, attempt);

    loop {
        let mut desc = base.clone();
        desc.attempt = attempt;
        desc.resume = resume.clone();

        let mut base_tl = Timeline::new();
        let mut will_fail = false;
        // Only a Lambda invocation that drew a live container from the
        // warm pool runs "warm" — non-Lambda engines provision nothing.
        let mut warm_container = false;
        if params.lambda {
            // Payload-split workaround (§III-B): oversized task state is
            // staged through S3 instead of the invocation payload.
            let mut payload_len = desc.payload_len();
            if payload_len > cfg.sim.lambda_payload_limit_bytes {
                ctx.env.metrics().incr("scheduler.payload_spills");
                let spilled = desc.resume.as_ref().map(|r| r.partial.len()).unwrap_or(0) as u64
                    + desc.code_bytes;
                // Driver uploads, executor downloads.
                let put_dt = ctx.env.config().sim.s3_first_byte_s
                    + spilled as f64 / (ctx.env.config().sim.s3_put_mbps * 1e6);
                let get_dt = ctx.env.flint_read_profile().read_time_s(spilled);
                base_tl.charge(Component::S3Write, put_dt);
                base_tl.charge(Component::S3Read, get_dt);
                payload_len = 256; // the S3 reference that remains inline
            }
            let ticket = ctx
                .env
                .lambda()
                .begin_invoke(LAMBDA_FN, payload_len)
                .map_err(|e| anyhow!("invoke: {e}"))?;
            base_tl.charge(
                if ticket.cold { Component::ColdStart } else { Component::WarmStart },
                ticket.start_latency_s,
            );
            warm_container = !ticket.cold;
            will_fail = ticket.will_fail;
            stats.invocations += 1;
        }

        let outcome = if will_fail {
            // The container died underneath the executor; whatever it had
            // received stays in flight until the visibility timeout. Our
            // model nacks immediately via the retry path (reducers nack in
            // their own failure handling; an early crash received nothing).
            TaskOutcome::Failed { error: "injected invocation crash".into(), timeline: base_tl }
        } else {
            run_task(ctx, &desc, base_tl, warm_container)
        };

        match outcome {
            TaskOutcome::Done(mut resp) => {
                charge_straggle(ctx, &mut resp.timeline, straggle);
                if params.lambda {
                    finish_lambda(ctx, &resp.timeline)?;
                }
                stats.duration_s += resp.timeline.total();
                stats.timeline.merge(&resp.timeline);
                stats.msgs_sent += resp.msgs_sent;
                stats.msgs_received += resp.shuffle_msgs_received;
                stats.duplicates_dropped += resp.duplicates_dropped;
                merge_edges(&mut stats.edge_received, &resp.edge_received);
                merge_edges(&mut stats.edge_sent, &resp.edge_sent_bytes);
                stats.rows = resp.rows;
                stats.emitted = resp.emitted;
                return Ok(stats);
            }
            TaskOutcome::Chained { resume: r, mut resp } => {
                charge_straggle(ctx, &mut resp.timeline, straggle);
                if params.lambda {
                    finish_lambda(ctx, &resp.timeline)?;
                }
                ctx.env.metrics().incr("scheduler.chains");
                stats.duration_s += resp.timeline.total();
                stats.timeline.merge(&resp.timeline);
                stats.msgs_sent += resp.msgs_sent;
                stats.msgs_received += resp.shuffle_msgs_received;
                merge_edges(&mut stats.edge_received, &resp.edge_received);
                merge_edges(&mut stats.edge_sent, &resp.edge_sent_bytes);
                stats.chains += 1;
                resume = Some(r);
                // Same attempt continues in a fresh (warm) invocation.
            }
            TaskOutcome::Failed { error, timeline } => {
                if params.lambda {
                    // AWS bills the crashed invocation too.
                    let billed = crate::exec::executor::billed_duration(&timeline)
                        .min(ctx.env.config().sim.lambda_time_limit_s);
                    let _ = ctx.env.lambda().finish_invoke(LAMBDA_FN, billed);
                }
                stats.duration_s += timeline.total();
                stats.timeline.merge(&timeline);
                attempt += 1;
                if attempt > max_retries {
                    return Err(anyhow!(
                        "task s{}t{} failed after {} attempts: {error}",
                        base.stage_id,
                        base.task_index,
                        attempt
                    ));
                }
                // Per-attempt accounting: `retries` counts relaunches
                // actually made. A chain-resume retry is ONE new attempt
                // no matter how many segments the attempt later chains
                // through, and a failure the retry budget refuses is not
                // a retry (the old code counted it, overstating retry
                // rates in RunOutput by one per exhausted task).
                stats.retries += 1;
                ctx.env.metrics().incr("scheduler.task_retries");
                straggle = ctx
                    .env
                    .failure()
                    .straggler_factor(base.stage_id, base.task_index, attempt);
            }
        }
    }
}

/// Inflate a straggling attempt's billed duration: a slow container
/// stretches its *work* (not its cold start) by `factor`, charged as
/// [`Component::Straggler`] so timelines show where the time went. The
/// extra stays under the Lambda duration cap — a real straggler would
/// chain before the kill, and modelling that crash/chain dance adds
/// nothing to the speculation story.
fn charge_straggle(ctx: &ExecCtx, tl: &mut Timeline, factor: Option<f64>) {
    let Some(factor) = factor else { return };
    let billed = crate::exec::executor::billed_duration(tl);
    let mut extra = (factor - 1.0).max(0.0) * billed;
    if let Some(limit) = ctx.time_limit_s {
        extra = extra.min(((limit - billed) * 0.95).max(0.0));
    }
    if extra > 0.0 {
        ctx.env.metrics().incr("sim.straggler_slowdowns");
        tl.charge(Component::Straggler, extra);
    }
}

fn finish_lambda(ctx: &ExecCtx, tl: &Timeline) -> Result<()> {
    ctx.env
        .lambda()
        .finish_invoke(LAMBDA_FN, crate::exec::executor::billed_duration(tl))
        .map_err(|e| anyhow!("lambda duration cap: {e} — chaining should have fired"))
}

fn merge_emits(emits: Vec<Emitted>) -> Result<ActionOut> {
    let mut count: Option<u64> = None;
    let mut rows: Vec<(i64, f64, f64)> = Vec::new();
    let mut values: Vec<Value> = Vec::new();
    let mut saved: Option<u64> = None;
    let mut saw_rows = false;
    for e in emits {
        match e {
            Emitted::Nothing => {}
            Emitted::Count(n) => *count.get_or_insert(0) += n,
            Emitted::KernelRows(mut r) => {
                saw_rows = true;
                rows.append(&mut r);
            }
            Emitted::Values(mut v) => values.append(&mut v),
            Emitted::Saved(n) => *saved.get_or_insert(0) += n,
        }
    }
    if let Some(n) = count {
        return Ok(ActionOut::Count(n));
    }
    if let Some(n) = saved {
        return Ok(ActionOut::Saved(n));
    }
    if saw_rows {
        // Merge duplicate bucket keys across tasks: a hash-partitioned
        // reduce emits each key from exactly one task, but a join stage
        // answering the driver directly may emit the same output key
        // from several partitions.
        let mut merged: BTreeMap<i64, (f64, f64)> = BTreeMap::new();
        for (k, s, c) in rows {
            let e = merged.entry(k).or_insert((0.0, 0.0));
            e.0 += s;
            e.1 += c;
        }
        return Ok(ActionOut::KernelRows(
            merged.into_iter().map(|(k, (s, c))| (k, s, c)).collect(),
        ));
    }
    values.sort_by(|a, b| a.total_cmp(b));
    Ok(ActionOut::Values(values))
}
