//! The Flint engine: serverless execution on the simulated Lambda + SQS
//! substrates — the paper's system.
//!
//! Construction loads the AOT PJRT artifacts (when present and enabled)
//! and pre-compiles them, so artifact compilation never lands on the
//! query path. `prewarm()` mirrors the paper's measurement protocol
//! ("averages over five trials *after warm-up*").

use crate::compute::queries::{QueryId, QueryResult};
use crate::data::Dataset;
use crate::exec::driver::{run_plan, RunOutput, RunParams};
use crate::exec::executor::IoMode;
use crate::exec::shuffle::Transport;
use crate::exec::{Engine, QueryReport};
use crate::plan::{kernel_plan, PhysicalPlan};
use crate::runtime::PjrtRuntime;
use crate::services::SimEnv;
use anyhow::{Context, Result};
use std::sync::Arc;

pub struct FlintEngine {
    env: SimEnv,
    runtime: Option<Arc<PjrtRuntime>>,
    /// Cleared by the multi-tenant service, which bills each query's
    /// long-poll idle from the shared clock so spend lands per tenant.
    bill_idle: bool,
    /// Service-lifetime per-container history for straggler prediction.
    predictor: Option<Arc<crate::exec::service::StragglerPredictor>>,
}

impl FlintEngine {
    /// Build the engine; loads + pre-compiles PJRT artifacts if
    /// `flint.use_pjrt` and the bundle exists (falls back to the native
    /// kernels otherwise, e.g. in unit tests).
    pub fn new(env: SimEnv) -> FlintEngine {
        let cfg = env.config();
        let runtime = if cfg.flint.use_pjrt && PjrtRuntime::available(&cfg.artifacts_dir) {
            match PjrtRuntime::open(&cfg.artifacts_dir).and_then(|rt| {
                rt.warmup()?;
                Ok(rt)
            }) {
                Ok(rt) => Some(Arc::new(rt)),
                Err(e) => {
                    log::warn!("PJRT artifacts unavailable ({e:#}); using native kernels");
                    None
                }
            }
        } else {
            None
        };
        FlintEngine { env, runtime, bill_idle: true, predictor: None }
    }

    /// Inject a pre-opened runtime (sharing one PJRT client across
    /// engines in benches).
    pub fn with_runtime(env: SimEnv, runtime: Option<Arc<PjrtRuntime>>) -> FlintEngine {
        FlintEngine { env, runtime, bill_idle: true, predictor: None }
    }

    /// Service-mode tuning (see [`crate::exec::service`]): idle billing
    /// moves to the shared clock, and a long-lived predictor threads its
    /// per-container history through every run.
    pub(crate) fn set_service_tuning(
        &mut self,
        bill_idle: bool,
        predictor: Option<Arc<crate::exec::service::StragglerPredictor>>,
    ) {
        self.bill_idle = bill_idle;
        self.predictor = predictor;
    }

    pub fn env(&self) -> &SimEnv {
        &self.env
    }

    pub fn uses_pjrt(&self) -> bool {
        self.runtime.is_some()
    }

    /// Hand the opened PJRT runtime (if any) to a caller that builds
    /// more engines over the same artifacts — the service opens it once
    /// and shares it across every query's engine.
    pub(crate) fn runtime_handle(&self) -> Option<Arc<PjrtRuntime>> {
        self.runtime.clone()
    }

    /// Warm the Lambda container pool (the paper benchmarks post-warm-up).
    pub fn prewarm(&self) {
        self.env
            .lambda()
            .prewarm("flint-exec", self.env.config().sim.max_concurrency);
    }

    fn transport(&self) -> Transport {
        match self.env.config().flint.shuffle_backend {
            crate::config::ShuffleBackend::Sqs => Transport::Sqs,
            crate::config::ShuffleBackend::S3 => Transport::S3,
            // Auto resolves per DAG edge inside the driver
            // (`exec::exchange`); the engine default is the base/fallback
            // transport for anything off the edge map.
            crate::config::ShuffleBackend::Auto => Transport::Sqs,
        }
    }

    fn params(&self) -> RunParams {
        let cfg = self.env.config();
        // The S3 backend's one-shot list-then-get shuffle (the Qubole
        // alternative) cannot overlap reduce drain with map flushes, so
        // pipelined scheduling is SQS-only: with the S3 backend the
        // headline clock is always the barrier model, whatever
        // `flint.scheduler` says.
        let schedule = match cfg.flint.shuffle_backend {
            crate::config::ShuffleBackend::Sqs => cfg.flint.scheduler,
            crate::config::ShuffleBackend::S3 => crate::simtime::ScheduleMode::Barrier,
            // Auto starts from the configured scheduler; the driver
            // demotes to barrier per plan when any edge resolves to S3.
            crate::config::ShuffleBackend::Auto => cfg.flint.scheduler,
        };
        RunParams {
            mode: IoMode::Flint,
            transport: self.transport(),
            slots: cfg.sim.max_concurrency,
            lambda: true,
            host_parallelism: host_parallelism(),
            schedule,
            bill_idle: self.bill_idle,
            predictor: self.predictor.clone(),
        }
    }

    /// Execute an arbitrary physical plan, returning the raw driver
    /// output (the session layer's entry point — `ActionOut` carries
    /// generic collect values the `QueryReport` form cannot).
    pub fn run_plan_raw(&self, plan: &PhysicalPlan) -> Result<RunOutput> {
        self.env.s3().create_bucket(crate::data::SHUFFLE_BUCKET);
        self.env.s3().create_bucket(crate::data::OUTPUT_BUCKET);
        self.env.s3().create_bucket(crate::data::CACHE_BUCKET);
        let out = run_plan(&self.env, self.runtime.as_deref(), plan, &self.params())
            .with_context(|| format!("flint plan {}", plan.plan_id))?;
        // Warm-container model: a run occupies the pool for its virtual
        // latency, so containers age by that much before the next plan
        // (keepalive expiry is pruned lazily; `keepalive_s = 0` means
        // never-expire, keeping this a no-op for the default config).
        let lam = self.env.lambda();
        lam.advance_to(lam.now() + out.latency_s);
        Ok(out)
    }

    /// Execute an arbitrary physical plan and summarize it as a report.
    pub fn run_plan(&self, plan: &PhysicalPlan) -> Result<QueryReport> {
        let before = self.env.cost().snapshot();
        let out = self.run_plan_raw(plan)?;
        let cost = self.env.cost().snapshot().since(&before);
        Ok(report("flint", plan.query, out, cost))
    }
}

impl Engine for FlintEngine {
    fn name(&self) -> &'static str {
        "flint"
    }

    fn run_query(&self, query: QueryId, dataset: &Dataset) -> Result<QueryReport> {
        let plan = kernel_plan(query, dataset, self.env.config());
        self.run_plan(&plan)
    }
}

pub(crate) fn host_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

pub(crate) fn report(
    engine: &str,
    query: Option<QueryId>,
    out: crate::exec::driver::RunOutput,
    cost: crate::cost::CostSnapshot,
) -> QueryReport {
    let result = out
        .out
        .to_query_result()
        .unwrap_or(QueryResult::Count(0));
    // Ordering contract: edge rows are sorted by (from, to) so reports,
    // diffs, and the CLI printout are deterministic whatever map the
    // driver accumulated them in.
    let mut edge_shuffle = out.edge_shuffle;
    edge_shuffle.sort_by_key(|e| (e.from, e.to));
    QueryReport {
        engine: engine.to_string(),
        query,
        result,
        latency_s: out.latency_s,
        barrier_latency_s: out.barrier_latency_s,
        pipelined_latency_s: out.pipelined_latency_s,
        pipelined_nospec_latency_s: out.pipelined_nospec_latency_s,
        pipelined_idle_s: out.pipelined_idle_s,
        cost_usd: cost.total(),
        cost,
        stage_latencies: out.stage_latencies,
        barrier_windows: out.barrier_windows,
        pipelined_windows: out.pipelined_windows,
        edge_shuffle,
        timeline: out.timeline,
        tasks: out.tasks,
        invocations: out.invocations,
        retries: out.retries,
        chains: out.chains,
        shuffle_msgs: out.shuffle_msgs,
        duplicates_dropped: out.duplicates_dropped,
        speculative_launches: out.speculative_launches,
        speculative_wins: out.speculative_wins,
    }
}


