//! Per-edge shuffle exchange planning: resolve every DAG edge to a
//! concrete transport (payload-inline, SQS, or S3) and — for S3 edges
//! above the tree fan-out threshold — to the multi-level exchange shape.
//!
//! With `flint.shuffle.backend = sqs|s3` every edge uses the configured
//! backend, exactly as before this module existed. With `auto`, each
//! edge is priced under the calibrated service constants (the same
//! constants the simulator charges, so the pick optimizes exactly what
//! the virtual clock measures) and the cheapest backend wins:
//!
//! * **payload-inline** (Flock-style) when the producer's output is
//!   known-small — kernel histogram partials bounded by the bucket
//!   count — so partitions ride the invocation payload for free, with
//!   the 6 MB payload-spill machinery as the overflow guard-rail;
//! * **SQS** for mid-size edges, where a ~1.5 ms queue round trip beats
//!   a ~20 ms S3 request and fan-out is too small for request counts to
//!   dominate;
//! * **S3** (direct or tree per `flint.shuffle.exchange`) once the edge
//!   is wide enough that the tree's O(P·√R + √P·R) object count beats
//!   the per-message queue costs.
//!
//! Ties break toward SQS — the engine default — so `auto` never loses
//! to the backend a user would have gotten without the knob.

use crate::config::{FlintConfig, ShuffleBackend, ShuffleExchange};
use crate::exec::shuffle::{tree_plan, EdgeExchange, MemoryShuffle, Transport, TreePlan};
use crate::plan::{PhysicalPlan, StageCompute, StageOutput};
use std::collections::BTreeMap;
use std::sync::Arc;

/// What the auto cost model knows about one DAG edge before running it.
#[derive(Debug, Clone, Copy)]
pub struct EdgeStats {
    /// Producing-stage task count (level-0 writers).
    pub producers: u32,
    /// Consumer-side partition count.
    pub partitions: u32,
    /// Producer output is known-small: kernel stages emit per-bucket
    /// histogram partials whose row count is bounded by the spec's
    /// bucket count, so the whole edge fits the invocation payload.
    /// Generic (dyn) stages can ship arbitrarily wide data and never
    /// qualify.
    pub compact_output: bool,
}

/// The auto pick for one edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendChoice {
    Payload,
    Sqs,
    S3,
}

/// One DAG edge's resolved exchange: what every producing task's writer
/// uses, plus the merge-level shape the driver runs for tree edges.
#[derive(Clone)]
pub struct PlannedEdge {
    pub exchange: EdgeExchange,
    pub tree: Option<TreePlan>,
}

/// Per-plan map of resolved exchanges, keyed by (producer, consumer)
/// stage ids. Built once per run by the driver and threaded into every
/// writer/reader through [`crate::exec::executor::ExecCtx`].
pub struct ExchangePlan {
    edges: BTreeMap<(u32, u32), PlannedEdge>,
    /// Fallback for lookups off the map (degenerate edges); also what
    /// non-shuffle code paths see.
    default: Transport,
}

impl ExchangePlan {
    pub fn edge(&self, from: u32, to: u32) -> Option<&PlannedEdge> {
        self.edges.get(&(from, to))
    }

    /// The transport a reader of edge (from → to) drains.
    pub fn transport_for(&self, from: u32, to: u32) -> Transport {
        self.edges
            .get(&(from, to))
            .map(|e| e.exchange.transport.clone())
            .unwrap_or_else(|| self.default.clone())
    }

    /// Writer-side exchange vector aligned with a stage's consumer list.
    pub fn edges_for(&self, from: u32, consumers: &[u32]) -> Vec<EdgeExchange> {
        consumers
            .iter()
            .map(|&to| {
                self.edges
                    .get(&(from, to))
                    .map(|e| e.exchange.clone())
                    .unwrap_or_else(|| EdgeExchange::direct(self.default.clone()))
            })
            .collect()
    }

    /// Whether any edge resolved to the S3 backend (direct or tree).
    /// The one-shot list-then-get S3 shuffle cannot overlap reduce
    /// drain with map flushes, so the driver demotes the schedule to
    /// the barrier model whenever this is true.
    pub fn any_s3(&self) -> bool {
        self.edges
            .values()
            .any(|e| matches!(e.exchange.transport, Transport::S3))
    }
}

/// Resolve every shuffle edge of a plan. Cluster engines (memory
/// transport) keep each edge on the base transport — auto-selection and
/// the tree exchange are Flint-only.
pub fn plan_exchanges(cfg: &FlintConfig, plan: &PhysicalPlan, base: &Transport) -> ExchangePlan {
    let mut edges = BTreeMap::new();
    let flint_base = matches!(base, Transport::Sqs | Transport::S3);
    // One in-process store shared by every payload edge of this run
    // (messages are keyed by (from, to, partition), so edges never mix).
    let mut payload: Option<Arc<MemoryShuffle>> = None;
    for stage in &plan.stages {
        let StageOutput::Shuffle { partitions, .. } = &stage.output else { continue };
        let stats = EdgeStats {
            producers: stage.num_tasks() as u32,
            partitions: *partitions as u32,
            compact_output: matches!(
                stage.compute,
                StageCompute::KernelScan { .. }
                    | StageCompute::KernelReduce { .. }
                    | StageCompute::KernelJoin { .. }
            ),
        };
        for to in plan.children(stage.id) {
            let planned = if flint_base {
                resolve_edge(cfg, &stats, &mut payload)
            } else {
                PlannedEdge { exchange: EdgeExchange::direct(base.clone()), tree: None }
            };
            edges.insert((stage.id, to), planned);
        }
    }
    ExchangePlan { edges, default: base.clone() }
}

/// Resolve one edge under the configured backend.
fn resolve_edge(
    cfg: &FlintConfig,
    stats: &EdgeStats,
    payload: &mut Option<Arc<MemoryShuffle>>,
) -> PlannedEdge {
    let choice = match cfg.flint.shuffle_backend {
        ShuffleBackend::Sqs => BackendChoice::Sqs,
        ShuffleBackend::S3 => BackendChoice::S3,
        ShuffleBackend::Auto => choose_backend(cfg, stats),
    };
    match choice {
        BackendChoice::Payload => {
            let store = payload.get_or_insert_with(MemoryShuffle::new).clone();
            PlannedEdge { exchange: EdgeExchange::direct(Transport::Payload(store)), tree: None }
        }
        BackendChoice::Sqs => {
            PlannedEdge { exchange: EdgeExchange::direct(Transport::Sqs), tree: None }
        }
        BackendChoice::S3 => {
            let tree = edge_tree(cfg, stats);
            PlannedEdge {
                exchange: EdgeExchange {
                    transport: Transport::S3,
                    tree_groups: tree.map(|t| t.consumer_groups),
                },
                tree,
            }
        }
    }
}

/// The tree shape an S3 edge uses, when `flint.shuffle.exchange = tree`
/// and the edge clears the fan-out threshold.
pub fn edge_tree(cfg: &FlintConfig, stats: &EdgeStats) -> Option<TreePlan> {
    if cfg.flint.shuffle_exchange != ShuffleExchange::Tree {
        return None;
    }
    tree_plan(stats.producers, stats.partitions, cfg.flint.tree_fanout)
}

/// Auto backend pick for one edge: cheapest modeled exchange time wins,
/// ties toward SQS.
pub fn choose_backend(cfg: &FlintConfig, stats: &EdgeStats) -> BackendChoice {
    // Known-small edges ride the invocation payload: the inline leg has
    // no per-request transport charge at all, and overflow past the
    // 6 MB cap degrades gracefully through the S3 spill leg.
    if stats.compact_output {
        return BackendChoice::Payload;
    }
    let sqs = est_sqs_s(cfg, stats);
    let s3 = est_s3_s(cfg, stats);
    if s3 < sqs {
        BackendChoice::S3
    } else {
        BackendChoice::Sqs
    }
}

/// Modeled per-edge seconds on the SQS backend: each producer sends one
/// message round trip per populated partition (bounded by R), and each
/// reader drains its P producer messages in receive batches.
pub fn est_sqs_s(cfg: &FlintConfig, stats: &EdgeStats) -> f64 {
    let rtt = cfg.sim.sqs_rtt_s;
    let batch = cfg.sim.sqs_batch_max_msgs.max(1) as f64;
    stats.partitions as f64 * rtt + (stats.producers as f64 / batch).ceil() * rtt
}

/// Modeled per-edge seconds on the S3 backend — the tree shape when it
/// activates, the direct O(P·R) exchange otherwise.
pub fn est_s3_s(cfg: &FlintConfig, stats: &EdgeStats) -> f64 {
    match edge_tree(cfg, stats) {
        Some(tp) => est_s3_tree_s(cfg, &tp),
        None => est_s3_direct_s(cfg, stats),
    }
}

/// Direct S3 exchange: each producer PUTs one object per partition;
/// each reader LISTs its partition prefix and GETs P objects.
pub fn est_s3_direct_s(cfg: &FlintConfig, stats: &EdgeStats) -> f64 {
    let fb = cfg.sim.s3_first_byte_s;
    stats.partitions as f64 * fb + (1.0 + stats.producers as f64) * fb
}

/// Tree exchange: producers write one combined object per consumer
/// group; each merge task lists its group, GETs its producer-rank
/// share, and PUT+renames one merged object per partition of its group;
/// readers GET one merged object per producer group.
pub fn est_s3_tree_s(cfg: &FlintConfig, tp: &TreePlan) -> f64 {
    let fb = cfg.sim.s3_first_byte_s;
    let level1 = tp.consumer_groups as f64 * fb;
    let merge = (1.0
        + (tp.producers as f64 / tp.producer_groups as f64).ceil()
        + 2.0 * (tp.partitions as f64 / tp.consumer_groups as f64).ceil())
        * fb;
    let read = (1.0 + tp.producer_groups as f64) * fb;
    level1 + merge + read
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FlintConfig;

    fn dyn_edge(producers: u32, partitions: u32) -> EdgeStats {
        EdgeStats { producers, partitions, compact_output: false }
    }

    #[test]
    fn auto_inlines_compact_kernel_edges() {
        let cfg = FlintConfig::default();
        let stats = EdgeStats { producers: 400, partitions: 8, compact_output: true };
        assert_eq!(choose_backend(&cfg, &stats), BackendChoice::Payload);
    }

    #[test]
    fn auto_keeps_small_dyn_edges_on_sqs() {
        let cfg = FlintConfig::default();
        for (p, r) in [(2, 2), (40, 8), (256, 64), (1024, 256)] {
            assert_eq!(
                choose_backend(&cfg, &dyn_edge(p, r)),
                BackendChoice::Sqs,
                "{p}x{r} should stay on the default backend"
            );
        }
    }

    #[test]
    fn auto_moves_huge_fanout_to_tree_s3() {
        let mut cfg = FlintConfig::default();
        cfg.set("flint.shuffle.exchange", "tree").unwrap();
        let stats = dyn_edge(8192, 8192);
        // The tree estimate is O(√n)·s3_first_byte while SQS stays
        // linear in n, so the pick flips at large fan-out…
        assert!(est_s3_tree_s(&cfg, &edge_tree(&cfg, &stats).unwrap()) < est_sqs_s(&cfg, &stats));
        assert_eq!(choose_backend(&cfg, &stats), BackendChoice::S3);
        // …but never without the tree: direct S3's O(n²) requests lose
        // to SQS at every size, so `exchange = direct` pins auto to SQS.
        let mut direct = FlintConfig::default();
        direct.set("flint.shuffle.exchange", "direct").unwrap();
        assert_eq!(choose_backend(&direct, &stats), BackendChoice::Sqs);
    }

    #[test]
    fn estimates_are_monotone_in_fanout() {
        let cfg = FlintConfig::default();
        assert!(est_sqs_s(&cfg, &dyn_edge(64, 64)) < est_sqs_s(&cfg, &dyn_edge(1024, 1024)));
        assert!(
            est_s3_direct_s(&cfg, &dyn_edge(64, 64))
                < est_s3_direct_s(&cfg, &dyn_edge(1024, 1024))
        );
    }

    #[test]
    fn explicit_backends_bypass_the_cost_model() {
        let mut cfg = FlintConfig::default();
        cfg.set("flint.shuffle.backend", "s3").unwrap();
        cfg.set("flint.shuffle.exchange", "tree").unwrap();
        cfg.set("flint.shuffle.tree_fanout", "64").unwrap();
        let mut payload = None;
        // A huge dyn edge under explicit s3 + tree: S3 transport with
        // level-1 grouping active.
        let stats = dyn_edge(1024, 1024);
        let planned = resolve_edge(&cfg, &stats, &mut payload);
        assert!(matches!(planned.exchange.transport, Transport::S3));
        let tp = planned.tree.expect("tree activates above the fan-out threshold");
        assert_eq!(planned.exchange.tree_groups, Some(tp.consumer_groups));
        assert_eq!((tp.producer_groups, tp.consumer_groups), (32, 32));
        // Below the threshold the same config stays direct.
        let small = resolve_edge(&cfg, &dyn_edge(8, 8), &mut payload);
        assert!(matches!(small.exchange.transport, Transport::S3));
        assert!(small.tree.is_none() && small.exchange.tree_groups.is_none());
        // Explicit sqs ignores the exchange knob entirely.
        cfg.set("flint.shuffle.backend", "sqs").unwrap();
        let sqs = resolve_edge(&cfg, &stats, &mut payload);
        assert!(matches!(sqs.exchange.transport, Transport::Sqs));
        assert!(sqs.tree.is_none());
        assert!(payload.is_none(), "no payload store unless an edge chose it");
    }
}
