//! The cluster baselines: Scala Spark and PySpark on an always-on
//! 11 × m4.2xlarge Databricks-style deployment (80 vCores) — the
//! comparison conditions of Table I.
//!
//! Differences from Flint, mirroring the paper's analysis:
//! * S3 reads go through the Hadoop-S3A-class profile (slower per stream
//!   than Flint's boto — the paper's Q0 finding),
//! * PySpark additionally pays a per-record JVM→Python pipe overhead
//!   ("every input record passes from the JVM to the Python
//!   interpreter"),
//! * shuffle is cluster-local (memory/disk/network), not SQS,
//! * executors are long-running: no cold starts, no per-invocation
//!   billing — instead the whole cluster bills by the hour, idle or not.

use crate::compute::queries::QueryId;
use crate::data::Dataset;
use crate::exec::driver::{run_plan, RunOutput, RunParams};
use crate::exec::executor::IoMode;
use crate::exec::flint::{host_parallelism, report};
use crate::exec::shuffle::{MemoryShuffle, Transport};
use crate::exec::{Engine, QueryReport};
use crate::plan::{kernel_plan, PhysicalPlan};
use crate::services::SimEnv;
use anyhow::{Context, Result};

/// Which language binding the baseline models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterMode {
    /// Scala Spark: native JVM execution.
    Spark,
    /// PySpark: per-record pipe overhead on top.
    PySpark,
}

impl ClusterMode {
    pub fn name(&self) -> &'static str {
        match self {
            ClusterMode::Spark => "spark",
            ClusterMode::PySpark => "pyspark",
        }
    }
}

pub struct ClusterEngine {
    env: SimEnv,
    mode: ClusterMode,
}

impl ClusterEngine {
    pub fn new(env: SimEnv, mode: ClusterMode) -> ClusterEngine {
        ClusterEngine { env, mode }
    }

    pub fn env(&self) -> &SimEnv {
        &self.env
    }

    fn params(&self) -> RunParams {
        RunParams {
            mode: match self.mode {
                ClusterMode::Spark => IoMode::Spark,
                ClusterMode::PySpark => IoMode::PySpark,
            },
            transport: Transport::Memory(MemoryShuffle::new()),
            slots: self.env.config().cluster.cores,
            lambda: false,
            host_parallelism: host_parallelism(),
            // Spark's execution model: a hard barrier at every shuffle
            // boundary (the cluster-local transport is not a long-poll
            // queue), so the baseline keeps the Σ-makespan clock.
            schedule: crate::simtime::ScheduleMode::Barrier,
            bill_idle: true,
            predictor: None,
        }
    }

    /// Execute an arbitrary physical plan, returning the raw driver
    /// output and charging cluster time — the session layer runs the
    /// same generic lineages here for cross-checking against Flint.
    pub fn run_plan_raw(&self, plan: &PhysicalPlan) -> Result<RunOutput> {
        self.env.s3().create_bucket(crate::data::OUTPUT_BUCKET);
        // The cluster executes the same physical plan; Spark's kernels are
        // the native Rust path (no PJRT — that's Flint's build pipeline).
        let out = run_plan(&self.env, None, plan, &self.params())
            .with_context(|| format!("{} plan {}", self.mode.name(), plan.plan_id))?;
        // Per the paper: cost = query latency × per-second cluster price
        // (startup excluded, favourably for Spark).
        let usd = out.latency_s * self.env.config().pricing.cluster_per_hour / 3600.0;
        self.env
            .cost()
            .charge(crate::cost::CostCategory::ClusterTime, usd);
        Ok(out)
    }

    /// Execute an arbitrary physical plan and summarize it as a report.
    pub fn run_plan(&self, plan: &PhysicalPlan) -> Result<QueryReport> {
        let before = self.env.cost().snapshot();
        let out = self.run_plan_raw(plan)?;
        let cost = self.env.cost().snapshot().since(&before);
        Ok(report(self.mode.name(), plan.query, out, cost))
    }
}

impl Engine for ClusterEngine {
    fn name(&self) -> &'static str {
        match self.mode {
            ClusterMode::Spark => "spark",
            ClusterMode::PySpark => "pyspark",
        }
    }

    fn run_query(&self, query: QueryId, dataset: &Dataset) -> Result<QueryReport> {
        let plan = kernel_plan(query, dataset, self.env.config());
        self.run_plan(&plan)
    }
}
