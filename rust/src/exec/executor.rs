//! The task executor — the code that runs "inside" a Lambda invocation
//! (§III-A) or a cluster executor slot, shared by every engine via
//! [`IoMode`].
//!
//! Responsibilities, mirroring the paper's executor:
//! 1. deserialize the task, build the input iterator (S3 byte range or
//!    shuffle partition),
//! 2. run the stage's compute (kernel batches through PJRT/native, or
//!    the dynamic op chain),
//! 3. route output (hash-partitioned shuffle writes, driver response, or
//!    S3 materialization),
//! 4. **chain** before the Lambda duration cap: serialize read offset +
//!    partial state back to the scheduler (§III-B),
//! 5. respect the memory cap (flush shuffle buffers; error with the
//!    paper's "increase the number of partitions" advice if aggregation
//!    state can't fit).
//!
//! **Attempt-safe commits:** every reduce-side path seals its complete
//! output — the final shuffle flush, the S3 materialization, or the
//! driver-facing emit — *before* acking its drained input, and nacks
//! everything back on any error in between. A task attempt therefore
//! commits atomically: either its full output exists and the input is
//! consumed, or the input returns to the queues for the next attempt.
//! This is what makes racing duplicate attempts (retries *and*
//! speculative backups) safe: a cancelled or crashed loser can never
//! leave a torn partition, and a winner's byte-identical duplicate
//! `(producer, seq)` messages dedup downstream (§VI).

use crate::compute::batch::ColumnBatch;
use crate::compute::csv::{fetch_range, SplitLines};
use crate::compute::kernels::{prepare_keys, prepare_values, run_batch_native, HistAccum};
use crate::compute::queries::KeySource;
use crate::compute::value::Value;
use crate::config::ShuffleCodec;
use crate::data::weather::WeatherTable;
use crate::exec::exchange::ExchangePlan;
use crate::exec::shuffle::{
    dyn_chunk_values, dyn_partition, kernel_partition, pack_dyn_run, pack_kernel_run,
    ShuffleReader, ShuffleRec, ShuffleWriter, Transport,
};
use crate::plan::{
    Action, PhysicalPlan, ResumeState, StageCompute, StageOutput, TaskDescriptor, TaskInput,
    TaskOutput,
};
use crate::runtime::PjrtRuntime;
use crate::services::SimEnv;
use crate::simtime::{Component, CpuStopwatch, Timeline};
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, HashSet};
use std::sync::Arc;

/// Which engine's I/O model this executor runs under.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoMode {
    /// Flint: boto-class S3 reads, Lambda limits apply.
    Flint,
    /// Scala Spark on the cluster: Hadoop-S3A-class reads.
    Spark,
    /// PySpark on the cluster: Hadoop reads + per-record pipe overhead.
    PySpark,
}

/// Execution context shared by all tasks of one stage.
pub struct ExecCtx<'a> {
    pub env: &'a SimEnv,
    pub runtime: Option<&'a PjrtRuntime>,
    pub plan: &'a PhysicalPlan,
    pub transport: Transport,
    /// Per-edge transport/exchange resolution (auto backend selection
    /// and the tree exchange) — every writer and reader consults this
    /// instead of assuming the base transport.
    pub exchange: Arc<ExchangePlan>,
    pub mode: IoMode,
    /// Virtual duration cap per invocation (Lambda limit); None on the
    /// cluster.
    pub time_limit_s: Option<f64>,
    /// Chain this long before the cap.
    pub chain_margin_s: f64,
    /// Memory cap per executor.
    pub memory_limit_bytes: u64,
}

impl<'a> ExecCtx<'a> {
    fn read_profile(&self) -> crate::services::ReadProfile {
        match self.mode {
            IoMode::Flint => self.env.flint_read_profile(),
            IoMode::Spark | IoMode::PySpark => self.env.spark_read_profile(),
        }
    }

    fn compute_scale(&self) -> f64 {
        self.env.config().sim.compute_scale
    }

    /// Should we checkpoint-and-chain now? Compares *billed* execution
    /// time: AWS's duration cap starts at handler entry, after container
    /// provisioning, so cold/warm start latency doesn't count against it.
    fn should_chain(&self, tl: &Timeline) -> bool {
        match self.time_limit_s {
            Some(limit) => billed_duration(tl) >= limit - self.chain_margin_s,
            None => false,
        }
    }
}

/// What a finished task hands back to the scheduler.
#[derive(Debug, Clone)]
pub enum Emitted {
    Nothing,
    Count(u64),
    /// Kernel-path rows: (bucket, sum, count).
    KernelRows(Vec<(i64, f64, f64)>),
    /// Dyn-path collected values.
    Values(Vec<Value>),
    /// Objects written by saveAsTextFile.
    Saved(u64),
}

/// Executor response (the paper: "a response containing a variety of
/// diagnostic information").
#[derive(Debug, Clone)]
pub struct TaskResponse {
    pub timeline: Timeline,
    pub emitted: Emitted,
    pub rows: u64,
    pub malformed: u64,
    pub msgs_sent: u64,
    pub shuffle_msgs_received: u64,
    pub duplicates_dropped: u64,
    /// Messages received per parent stage (per-edge shuffle accounting).
    pub edge_received: Vec<(u32, u64)>,
    /// Encoded record bytes sent per consuming stage (per-edge codec
    /// accounting — what the rows-vs-columnar ablation measures).
    pub edge_sent_bytes: Vec<(u32, u64)>,
}

impl TaskResponse {
    fn new() -> TaskResponse {
        TaskResponse {
            timeline: Timeline::new(),
            emitted: Emitted::Nothing,
            rows: 0,
            malformed: 0,
            msgs_sent: 0,
            shuffle_msgs_received: 0,
            duplicates_dropped: 0,
            edge_received: Vec::new(),
            edge_sent_bytes: Vec::new(),
        }
    }
}

/// Task outcome, as seen by the scheduler.
pub enum TaskOutcome {
    Done(TaskResponse),
    /// Hit the duration guard: partial response + resume state (§III-B).
    Chained { resume: ResumeState, resp: TaskResponse },
    /// Crashed (injected or real); timeline covers what was consumed.
    Failed { error: String, timeline: Timeline },
}

/// Run one task attempt. `start_latency` (cold/warm start) is already
/// charged by the caller into `base_timeline`. `warm_container` is the
/// invocation ticket's verdict — true only when this attempt landed on
/// a live container from the warm pool (always false for engines that
/// provision nothing, like the cluster baselines); cached scans use it
/// to decide whether the memory tier exists.
pub fn run_task(
    ctx: &ExecCtx,
    task: &TaskDescriptor,
    base_timeline: Timeline,
    warm_container: bool,
) -> TaskOutcome {
    let mut resp = TaskResponse::new();
    resp.timeline = base_timeline;
    // Payload decode: a fixed small cost plus size-proportional parse.
    resp.timeline
        .charge(Component::PayloadDecode, 0.002 + task.payload_len() as f64 * 2e-9);

    let stage = &ctx.plan.stages[task.stage_id as usize];
    let result = match (&stage.compute, &task.input) {
        (StageCompute::KernelScan { spec }, TaskInput::Split(_)) => {
            kernel_scan(ctx, task, *spec, &mut resp)
        }
        (StageCompute::KernelReduce { spec }, TaskInput::ShufflePartition { .. }) => {
            kernel_reduce(ctx, task, *spec, &mut resp)
        }
        (StageCompute::DynScan { ops }, TaskInput::Split(_)) => dyn_scan(ctx, task, ops, &mut resp),
        (StageCompute::CachedScan { ops }, TaskInput::CachedPart(_)) => {
            cached_scan(ctx, task, ops, warm_container, &mut resp)
        }
        (StageCompute::DynReduce { combine, post_ops }, TaskInput::ShufflePartition { .. }) => {
            dyn_reduce(ctx, task, combine.clone(), post_ops, &mut resp)
        }
        (StageCompute::KernelJoin { spec }, TaskInput::ShufflePartition { .. }) => {
            kernel_join(ctx, task, *spec, &mut resp)
        }
        (StageCompute::DynCoGroup { post_ops }, TaskInput::ShufflePartition { .. }) => {
            dyn_cogroup(ctx, task, post_ops, &mut resp)
        }
        (c, i) => Err(anyhow!("task/stage mismatch: {c:?} with {i:?}")),
    };
    match result {
        Ok(Some(resume)) => TaskOutcome::Chained { resume, resp },
        Ok(None) => TaskOutcome::Done(resp),
        Err(e) => TaskOutcome::Failed { error: format!("{e:#}"), timeline: resp.timeline },
    }
}

/// Billed execution duration of an invocation: everything except the
/// provisioning (cold/warm start) latency.
pub fn billed_duration(tl: &Timeline) -> f64 {
    (tl.total() - tl.get(Component::ColdStart) - tl.get(Component::WarmStart)).max(0.0)
}

/// Build a task's shuffle writer with the run's per-edge exchange
/// resolution and the task's attempt scope applied — every producing
/// site goes through here so a speculative backup's S3 output is
/// temp-keyed by its own attempt number.
fn make_writer<'a>(
    ctx: &ExecCtx<'a>,
    task: &TaskDescriptor,
    partitions: u32,
    resume_seqs: Option<Vec<u64>>,
) -> ShuffleWriter<'a> {
    let consumers = ctx.plan.children(task.stage_id);
    let edges = ctx.exchange.edges_for(task.stage_id, &consumers);
    ShuffleWriter::new(
        ctx.env,
        ctx.transport.clone(),
        &ctx.plan.plan_id,
        task.stage_id,
        consumers,
        task.producer_id(),
        partitions,
        resume_seqs,
    )
    .with_attempt(task.attempt)
    .with_edges(edges)
}

/// Attempt-scoped output committer for final S3 part files
/// (`saveAsTextFile` and the kernel reduce's materialized partials):
/// the part is staged under an attempt-suffixed temp key and atomically
/// renamed into place, first-commit-wins, so racing attempts — retries
/// and speculative backups — can never tear or clobber a part file.
/// The winning attempt sweeps any crashed older attempts' orphaned
/// temps off its task's temp prefix.
fn commit_part(
    ctx: &ExecCtx,
    bucket: &str,
    prefix: &str,
    task_index: u32,
    attempt: u32,
    bytes: Vec<u8>,
    tl: &mut Timeline,
) -> Result<()> {
    let tmp_prefix = format!("{prefix}/_tmp/part-{task_index:05}.");
    let tmp = format!("{tmp_prefix}a{attempt}");
    let dst = format!("{prefix}/part-{task_index:05}");
    let dt = ctx
        .env
        .s3()
        .put_object(bucket, &tmp, bytes)
        .map_err(|e| anyhow!("save: {e}"))?;
    tl.charge(Component::S3Write, dt);
    let (dt, won) = ctx
        .env
        .s3()
        .commit_rename(bucket, &tmp, &dst)
        .map_err(|e| anyhow!("save commit: {e}"))?;
    tl.charge(Component::S3Write, dt);
    if won {
        let _ = ctx.env.s3().delete_prefix(bucket, &tmp_prefix);
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Kernel scan (map stage of the benchmark queries)
// ---------------------------------------------------------------------

fn load_weather(ctx: &ExecCtx, tl: &mut Timeline) -> Result<Option<WeatherTable>> {
    match &ctx.plan.weather {
        None => Ok(None),
        Some((bucket, key)) => {
            let (obj, dt) = ctx
                .env
                .s3()
                .get_object(bucket, key, ctx.read_profile())
                .map_err(|e| anyhow!("weather table: {e}"))?;
            tl.charge(Component::S3Read, dt);
            Ok(Some(
                WeatherTable::from_csv(obj.bytes()).ok_or_else(|| anyhow!("weather corrupt"))?,
            ))
        }
    }
}

fn kernel_scan(
    ctx: &ExecCtx,
    task: &TaskDescriptor,
    spec: crate::compute::queries::KernelSpec,
    resp: &mut TaskResponse,
) -> Result<Option<ResumeState>> {
    let TaskInput::Split(split) = &task.input else { unreachable!() };

    let mut accum = HistAccum::new(spec.buckets);
    let mut writer = stage_output_partitions(ctx, task).map(|parts| {
        make_writer(ctx, task, parts, task.resume.as_ref().map(|r| r.next_seqs.clone()))
    });
    let count_only = spec.key == KeySource::None && spec.reduce_partitions == 0;
    let has_ranges = spec.day_range.is_some() || spec.month_range.is_some();
    // Count can skip parsing entirely — unless a day/month predicate is
    // set, in which case every line must be parsed so the count honors
    // the predicate (and stays consistent with stats-based pruning).
    let fast_count = count_only && !has_ranges;
    if let Some(r) = &task.resume {
        resp.rows = r.rows_done;
        if !r.partial.is_empty() {
            decode_hist(&r.partial, &mut accum)?;
        }
        if r.input_done {
            // Emit-only continuation: the previous link consumed all
            // input but chained before the output flush would have blown
            // the duration cap.
            return kernel_emit(ctx, task, &spec, &accum, writer.as_mut(), count_only, resp);
        }
    }
    // Fetch the unconsumed remainder of the split (continuations resume
    // mid-split with a fresh range GET — §III-B: "continue processing
    // the uncompleted input split where the previous invocation left
    // off"), plus the overfetch window for the trailing line.
    //
    // `consumed` may exceed the owned length: the last owned line can
    // extend into (or start at the very end of) the overfetch region.
    // In that case there is nothing left to read — go straight to emit.
    let consumed = task.resume.as_ref().map(|r| r.input_offset).unwrap_or(0);
    if consumed > split.len() {
        return kernel_emit(ctx, task, &spec, &accum, writer.as_mut(), count_only, resp);
    }
    // Statistics-based scan pruning: when the manifest's per-object
    // day/month ranges are disjoint from the spec's predicate, no row of
    // this split can survive the filter — skip the S3 GET entirely and
    // emit the empty histogram. Because `rows_seen` counts *post*-
    // predicate rows whenever a range is set, a pruned split is
    // byte-identical to one whose rows were all filtered out, so results
    // match the prune-off run exactly.
    if ctx.env.config().flint.scan_prune {
        if let Some(st) = &split.stats {
            let day_hit = spec.day_range.map_or(true, |(lo, hi)| st.overlaps_days(lo, hi));
            let month_hit =
                spec.month_range.map_or(true, |(lo, hi)| st.overlaps_months(lo, hi));
            if !day_hit || !month_hit {
                ctx.env.metrics().incr("scan.splits_pruned");
                return kernel_emit(ctx, task, &spec, &accum, writer.as_mut(), count_only, resp);
            }
        }
    }
    let weather = load_weather(ctx, &mut resp.timeline)?;
    let read_start = split.start + consumed;
    let (_, fe) = fetch_range(split.start, split.end, split.object_size);
    let (window, dt) = ctx
        .env
        .s3()
        .get_range(&split.bucket, &split.key, read_start, fe, ctx.read_profile())
        .map_err(|e| anyhow!("input split: {e}"))?;
    resp.timeline.charge(Component::S3Read, dt);

    if window.len() as u64 > ctx.memory_limit_bytes {
        return Err(anyhow!(
            "split of {} bytes exceeds executor memory {} — lower flint.input_split_bytes",
            window.len(),
            ctx.memory_limit_bytes
        ));
    }

    // Ownership within the sub-window: a line starting at window-relative
    // q is owned iff read_start + q <= split.end. A resumed offset always
    // sits at a line boundary, so no leading-line skip is needed there.
    let own_len = split.end - read_start;
    let is_first = split.start == 0 || consumed > 0;
    let mut lines = SplitLines::new(window.bytes(), own_len, is_first);

    let mut batch = ColumnBatch::with_capacity(batch_capacity(ctx));
    // Only the columns the spec references are parsed out of each line;
    // the per-task field count is metered for the projection ablation.
    let proj = spec.projection();
    if !fast_count {
        ctx.env.metrics().add("scan.cols_parsed", proj.num_fields() as u64);
    }
    let pipe_rate = ctx.env.config().sim.pyspark_pipe_per_record_s;
    let mut lines_since_check = 0u64;

    loop {
        let sw = CpuStopwatch::start();
        let mut batch_lines = 0u64;
        // Fill one batch (or count a block of lines for Q0).
        if fast_count {
            for _ in 0..65_536 {
                match lines.next() {
                    Some(_) => {
                        resp.rows += 1;
                        batch_lines += 1;
                    }
                    None => break,
                }
            }
        } else {
            while !batch.is_full() {
                match lines.next() {
                    Some(line) => {
                        batch_lines += 1;
                        if batch.push_line_projected(line, proj) {
                            resp.rows += 1;
                        } else {
                            resp.malformed += 1;
                        }
                    }
                    None => break,
                }
            }
            if !batch.is_empty() {
                run_kernel_batch(ctx, &spec, &mut batch, weather.as_ref(), &mut accum)?;
                batch.clear();
            }
        }
        resp.timeline
            .charge(Component::Compute, sw.elapsed_s() * ctx.compute_scale());
        if ctx.mode == IoMode::PySpark && batch_lines > 0 {
            resp.timeline
                .charge(Component::PipeOverhead, batch_lines as f64 * pipe_rate);
        }
        lines_since_check += batch_lines;

        if batch_lines == 0 {
            break; // input exhausted
        }

        // Deterministic crash point for forced failures: after the first
        // block, before output flush.
        if lines_since_check > 0
            && ctx
                .env
                .failure()
                .take_forced_failure(task.stage_id, task.task_index, task.attempt)
        {
            return Err(anyhow!(
                "injected executor crash (stage {} task {} attempt {})",
                task.stage_id,
                task.task_index,
                task.attempt
            ));
        }

        // Chain before the Lambda duration cap (§III-B).
        if ctx.should_chain(&resp.timeline) {
            let resume = ResumeState {
                input_offset: consumed + lines.offset() as u64,
                input_done: false,
                rows_done: resp.rows,
                partial: encode_hist(&accum),
                next_seqs: writer.as_ref().map(|w| w.seqs()).unwrap_or_default(),
                links: task.resume.as_ref().map(|r| r.links + 1).unwrap_or(1),
            };
            return Ok(Some(resume));
        }
    }

    // Input exhausted. If the output flush wouldn't fit under the
    // remaining duration budget, chain once more and flush from a fresh
    // invocation (the flush itself has no intermediate chain points).
    if writer.is_some() {
        let flush_est =
            estimate_flush_s(ctx, task, &accum, stage_output_partitions(ctx, task).unwrap());
        let mut projected = resp.timeline.clone();
        projected.charge(Component::SqsSend, flush_est);
        if ctx.should_chain(&projected) {
            let resume = ResumeState {
                input_offset: consumed + lines.offset() as u64,
                input_done: true,
                rows_done: resp.rows,
                partial: encode_hist(&accum),
                next_seqs: writer.as_ref().map(|w| w.seqs()).unwrap_or_default(),
                links: task.resume.as_ref().map(|r| r.links + 1).unwrap_or(1),
            };
            return Ok(Some(resume));
        }
    }

    kernel_emit(ctx, task, &spec, &accum, writer.as_mut(), count_only, resp)
}

/// Rough cost of flushing a kernel histogram to the shuffle: one send
/// per distinct destination partition and consuming edge (records are
/// tiny).
fn estimate_flush_s(
    ctx: &ExecCtx,
    task: &TaskDescriptor,
    accum: &HistAccum,
    partitions: u32,
) -> f64 {
    let distinct: std::collections::HashSet<u32> = accum
        .to_rows()
        .iter()
        .map(|(k, _, _)| kernel_partition(*k, partitions))
        .collect();
    let edges = ctx.plan.children(task.stage_id).len().max(1);
    (distinct.len() * edges) as f64 * ctx.env.config().sim.sqs_rtt_s * 1.5
}

fn kernel_emit(
    ctx: &ExecCtx,
    task: &TaskDescriptor,
    spec: &crate::compute::queries::KernelSpec,
    accum: &HistAccum,
    writer: Option<&mut ShuffleWriter>,
    count_only: bool,
    resp: &mut TaskResponse,
) -> Result<Option<ResumeState>> {
    match (&task.output, writer) {
        (TaskOutput::Shuffle { partitions }, Some(w)) => {
            // Group the sorted histogram rows into per-partition runs and
            // pack each run with the configured codec (columnar chunks or
            // the legacy record-per-key stream).
            let codec = ctx.env.config().flint.shuffle_codec;
            let mut runs: BTreeMap<u32, Vec<(i64, f64, f64)>> = BTreeMap::new();
            for (key, sum, count) in accum.to_rows() {
                runs.entry(kernel_partition(key, *partitions))
                    .or_default()
                    .push((key, sum, count));
            }
            for (p, run) in runs {
                for rec in pack_kernel_run(&run, codec) {
                    w.write(p, &rec, &mut resp.timeline)?;
                }
            }
            w.flush_all(&mut resp.timeline)?;
            resp.msgs_sent = w.msgs_sent;
            resp.edge_sent_bytes = w.edge_bytes();
            resp.emitted = Emitted::Nothing;
        }
        (TaskOutput::Driver, _) => {
            resp.emitted = if count_only {
                // With a day/month predicate the raw line count is wrong —
                // the kernel's post-predicate `rows_seen` is the answer
                // (and agrees with stats-based pruning).
                if spec.day_range.is_some() || spec.month_range.is_some() {
                    Emitted::Count(accum.rows_seen)
                } else {
                    Emitted::Count(resp.rows)
                }
            } else {
                Emitted::KernelRows(accum.to_rows())
            };
        }
        (out, _) => return Err(anyhow!("kernel scan cannot emit to {out:?}")),
    }
    Ok(None)
}

fn batch_capacity(ctx: &ExecCtx) -> usize {
    match ctx.runtime {
        Some(rt) => rt.batch_rows(),
        None => ctx.env.config().flint.batch_rows,
    }
}

fn run_kernel_batch(
    ctx: &ExecCtx,
    spec: &crate::compute::queries::KernelSpec,
    batch: &mut ColumnBatch,
    weather: Option<&WeatherTable>,
    accum: &mut HistAccum,
) -> Result<()> {
    // AOT artifacts bake in only the geo/tip filter; a spec carrying a
    // day/month predicate must run natively or the predicate would be
    // silently dropped.
    let ranged = spec.day_range.is_some() || spec.month_range.is_some();
    match ctx.runtime {
        // Published queries always go to PJRT when a runtime is loaded —
        // `run_hist` fails loudly on a missing/stale artifact, so a
        // misconfigured manifest can never silently report native-kernel
        // timings as PJRT numbers. Extension queries (Q6J's day-keyed
        // scan, no published row) were never AOT-lowered: they take the
        // native kernel unless an artifact actually exists for them.
        Some(rt) if !ranged && (spec.query.published_index().is_some() || rt.supports(spec)) => {
            batch.pad_to_capacity();
            let keys = prepare_keys(spec, batch, weather);
            let values = prepare_values(spec, batch);
            rt.run_hist(spec, batch, &keys, &values, accum)
        }
        _ => {
            let keys = prepare_keys(spec, batch, weather);
            let values = prepare_values(spec, batch);
            run_batch_native(spec, batch, &keys, &values, accum);
            Ok(())
        }
    }
}

fn stage_output_partitions(ctx: &ExecCtx, task: &TaskDescriptor) -> Option<u32> {
    match &ctx.plan.stages[task.stage_id as usize].output {
        StageOutput::Shuffle { partitions, .. } => Some(*partitions as u32),
        StageOutput::Act(_) => None,
    }
}

// ---------------------------------------------------------------------
// Kernel reduce
// ---------------------------------------------------------------------

/// Return every reader's in-flight messages to their queues (task
/// failure: visibility-timeout semantics so the retry sees them).
fn abandon_all(readers: &mut [ShuffleReader]) {
    for r in readers.iter_mut() {
        r.abandon();
    }
}

/// Fail a reduce-side task *after* its drain succeeded: every error
/// path between drain and ack must nack the in-flight messages back
/// first, or the retry finds an empty partition and silently emits a
/// wrong (partial/empty) result instead of failing loudly.
fn abandon_and_fail<T>(readers: &mut [ShuffleReader], e: anyhow::Error) -> Result<T> {
    abandon_all(readers);
    Err(e)
}

/// One parent edge's drained records, tagged with the producing stage.
/// Records from reader *i* belong to parent edge `parents[i]` — this is
/// what turns a multi-parent reduce from a stream *union* into a
/// semantics-aware cogroup/join: the compute sees each side separately.
struct TaggedRecords {
    /// The producing stage id (the DAG edge this stream arrived over).
    parent: u32,
    records: Vec<ShuffleRec>,
}

/// One reader per parent edge: a multi-parent reduce drains its
/// partition's queue of every producing stage, over its own
/// (parent → this stage) edge.
fn open_parent_readers<'a>(
    ctx: &'a ExecCtx,
    task: &TaskDescriptor,
    parents: &[u32],
    partition: u32,
    dedup: bool,
) -> Vec<ShuffleReader<'a>> {
    parents
        .iter()
        .map(|&p| {
            ShuffleReader::new(
                ctx.env,
                ctx.exchange.transport_for(p, task.stage_id),
                &ctx.plan.plan_id,
                p,
                task.stage_id,
                partition,
                dedup,
            )
        })
        .collect()
}

/// Drain every parent edge in order, returning the records per edge.
/// One `seen` set is threaded through all readers by swap — sound
/// across parents because producer ids embed the producing stage
/// (pinned by `producer_ids_collision_free_across_stages`), so
/// `(producer, seq)` spaces from different edges never alias. On a
/// drain error every reader's in-flight messages are nacked back.
fn drain_tagged(
    readers: &mut [ShuffleReader],
    parents: &[u32],
    seen: &mut HashSet<(u64, u64)>,
    resp: &mut TaskResponse,
) -> Result<Vec<TaggedRecords>> {
    let mut out = Vec::with_capacity(readers.len());
    let mut drain_err = None;
    for i in 0..readers.len() {
        std::mem::swap(&mut readers[i].seen, seen);
        let drained = readers[i].drain(&mut resp.timeline);
        std::mem::swap(&mut readers[i].seen, seen);
        match drained {
            Ok(read) => {
                resp.shuffle_msgs_received += read.messages;
                resp.duplicates_dropped += read.duplicates_dropped;
                resp.edge_received.push((parents[i], read.messages));
                out.push(TaggedRecords { parent: parents[i], records: read.records });
            }
            Err(e) => {
                drain_err = Some(e);
                break;
            }
        }
    }
    match drain_err {
        Some(e) => {
            abandon_all(readers);
            Err(e)
        }
        None => Ok(out),
    }
}

fn kernel_reduce(
    ctx: &ExecCtx,
    task: &TaskDescriptor,
    spec: crate::compute::queries::KernelSpec,
    resp: &mut TaskResponse,
) -> Result<Option<ResumeState>> {
    let TaskInput::ShufflePartition { partition, parents, .. } = &task.input else {
        unreachable!()
    };
    let dedup = ctx.env.config().flint.dedup_enabled;
    let mut agg: BTreeMap<i64, (f64, f64)> = BTreeMap::new();
    // Dedup state persists across chain links; one merged set is sound
    // across all parent edges because producer ids embed the producing
    // stage (see `drain_tagged`).
    let mut seen: HashSet<(u64, u64)> = HashSet::new();
    if let Some(r) = &task.resume {
        decode_reduce_state(&r.partial, &mut agg, &mut seen)?;
    }

    let mut readers = open_parent_readers(ctx, task, parents, *partition, dedup);
    // KernelReduce has *union* semantics: the per-edge tags are folded
    // back into one stream (a cogroup/join stage keeps them apart).
    let tagged = drain_tagged(&mut readers, parents, &mut seen, resp)?;
    let records: Vec<ShuffleRec> = tagged.into_iter().flat_map(|t| t.records).collect();

    // Injected crash point: after drain, before ack — the retry must see
    // the messages again (visibility timeout semantics).
    if ctx
        .env
        .failure()
        .take_forced_failure(task.stage_id, task.task_index, task.attempt)
    {
        abandon_all(&mut readers);
        return Err(anyhow!(
            "injected reducer crash (stage {} task {} attempt {})",
            task.stage_id,
            task.task_index,
            task.attempt
        ));
    }

    let sw = CpuStopwatch::start();
    // Vectorized merge: histogram keys are dense bucket indexes in
    // [0, spec.buckets), so the hot path is plain array indexing over
    // contiguous sum/count columns (chunked input merges column-slices
    // directly). Out-of-range keys — join re-keys, hand-built plans —
    // fall back to the map. The dense state folds back into `agg`
    // afterwards, so chain resume, the memory guard, and emission reuse
    // the exact BTreeMap code (and its sorted order) unchanged.
    let dense_n = spec.buckets;
    let mut dense_sums = vec![0.0f64; dense_n];
    let mut dense_counts = vec![0.0f64; dense_n];
    let mut dense_hit = vec![false; dense_n];
    for rec in records {
        match rec {
            ShuffleRec::Kernel { key, sum, count } => {
                if key >= 0 && (key as usize) < dense_n {
                    let i = key as usize;
                    dense_sums[i] += sum;
                    dense_counts[i] += count;
                    dense_hit[i] = true;
                } else {
                    let e = agg.entry(key).or_insert((0.0, 0.0));
                    e.0 += sum;
                    e.1 += count;
                }
                resp.rows += 1;
            }
            ShuffleRec::Chunk { keys, sums, counts } => {
                resp.rows += keys.len() as u64;
                for ((&key, &sum), &count) in keys.iter().zip(&sums).zip(&counts) {
                    if key >= 0 && (key as usize) < dense_n {
                        let i = key as usize;
                        dense_sums[i] += sum;
                        dense_counts[i] += count;
                        dense_hit[i] = true;
                    } else {
                        let e = agg.entry(key).or_insert((0.0, 0.0));
                        e.0 += sum;
                        e.1 += count;
                    }
                }
            }
            ShuffleRec::Dyn { .. } | ShuffleRec::DynChunk { .. } => {
                return abandon_and_fail(&mut readers, anyhow!("dyn record in kernel reduce"))
            }
        }
    }
    for i in 0..dense_n {
        if dense_hit[i] {
            let e = agg.entry(i as i64).or_insert((0.0, 0.0));
            e.0 += dense_sums[i];
            e.1 += dense_counts[i];
        }
    }
    resp.timeline
        .charge(Component::Compute, sw.elapsed_s() * ctx.compute_scale());

    // Memory guard — the paper's answer is more partitions, not spill.
    let agg_bytes = agg.len() as u64 * 32;
    if agg_bytes > ctx.memory_limit_bytes {
        return abandon_and_fail(
            &mut readers,
            anyhow!(
                "aggregation state ({agg_bytes} B) exceeds executor memory — \
                 increase the number of partitions (spec has {})",
                spec.reduce_partitions
            ),
        );
    }

    if ctx.should_chain(&resp.timeline) {
        for r in readers.iter_mut() {
            r.ack(&mut resp.timeline)?;
        }
        let resume = ResumeState {
            input_offset: 0,
            input_done: false,
            rows_done: resp.rows,
            partial: encode_reduce_state(&agg, &seen),
            next_seqs: Vec::new(),
            links: task.resume.as_ref().map(|r| r.links + 1).unwrap_or(1),
        };
        return Ok(Some(resume));
    }

    // Seal the attempt's complete output BEFORE acking the drained
    // input (attempt-safe commit): an S3 write that fails must leave the
    // messages in flight — nacked below — so the next attempt re-reads
    // them instead of finding acked-empty queues and silently emitting a
    // partial result.
    match &task.output {
        TaskOutput::Driver => {
            resp.emitted =
                Emitted::KernelRows(agg.into_iter().map(|(k, (s, c))| (k, s, c)).collect());
        }
        TaskOutput::S3 { bucket, prefix } => {
            let mut text = String::new();
            for (k, (s, c)) in &agg {
                text.push_str(&format!("{k}\t{s}\t{c}\n"));
            }
            // Attempt-scoped commit: a speculative backup racing the
            // primary stages its own temp and the rename resolves
            // first-wins — no clobbered or torn part files.
            if let Err(e) = commit_part(
                ctx,
                bucket,
                prefix,
                task.task_index,
                task.attempt,
                text.into_bytes(),
                &mut resp.timeline,
            ) {
                return abandon_and_fail(&mut readers, e);
            }
            resp.emitted = Emitted::Saved(1);
        }
        out => {
            return abandon_and_fail(&mut readers, anyhow!("kernel reduce cannot emit to {out:?}"))
        }
    }
    for r in readers.iter_mut() {
        r.ack(&mut resp.timeline)?;
    }
    Ok(None)
}

// ---------------------------------------------------------------------
// Kernel join (typed two-sided equi-join, Q6J)
// ---------------------------------------------------------------------

/// Typed shuffle join: parent edge 0 (the *fact* side) ships per-key
/// Kernel partials, parent edge 1 (the *dimension* side) ships
/// `(join_key, value)` Dyn pairs — heterogeneous record types on one
/// reduce, disambiguated purely by the per-parent stream tags. The
/// output re-keys fact partials by their dimension value (Q6J: day →
/// precip bucket) and shuffles them to the final reduce.
fn kernel_join(
    ctx: &ExecCtx,
    task: &TaskDescriptor,
    spec: crate::compute::queries::KernelSpec,
    resp: &mut TaskResponse,
) -> Result<Option<ResumeState>> {
    let TaskInput::ShufflePartition { partition, parents } = &task.input else {
        unreachable!()
    };
    if parents.len() != 2 {
        return Err(anyhow!(
            "kernel join needs exactly 2 parent edges (fact, dimension), got {}",
            parents.len()
        ));
    }
    let dedup = ctx.env.config().flint.dedup_enabled;
    // Per-edge partial state, tagged through chain resume: facts keep
    // per-join-key (sum, count), the dimension keeps join_key → value.
    let mut facts: BTreeMap<i64, (f64, f64)> = BTreeMap::new();
    let mut dim: BTreeMap<i64, i64> = BTreeMap::new();
    let mut seen: HashSet<(u64, u64)> = HashSet::new();
    if let Some(r) = &task.resume {
        decode_join_state(&r.partial, &mut facts, &mut dim, &mut seen)?;
    }

    let mut readers = open_parent_readers(ctx, task, parents, *partition, dedup);
    let tagged = drain_tagged(&mut readers, parents, &mut seen, resp)?;

    // Injected crash point: after drain, before ack — the retry must see
    // every message again (visibility timeout semantics).
    if ctx
        .env
        .failure()
        .take_forced_failure(task.stage_id, task.task_index, task.attempt)
    {
        abandon_all(&mut readers);
        return Err(anyhow!(
            "injected join crash (stage {} task {} attempt {})",
            task.stage_id,
            task.task_index,
            task.attempt
        ));
    }

    let sw = CpuStopwatch::start();
    let fact_edge = parents[0];
    for TaggedRecords { parent, records } in tagged {
        if parent == fact_edge {
            for rec in records {
                match rec {
                    ShuffleRec::Kernel { key, sum, count } => {
                        let e = facts.entry(key).or_insert((0.0, 0.0));
                        e.0 += sum;
                        e.1 += count;
                        resp.rows += 1;
                    }
                    ShuffleRec::Chunk { keys, sums, counts } => {
                        resp.rows += keys.len() as u64;
                        for ((&key, &sum), &count) in keys.iter().zip(&sums).zip(&counts) {
                            let e = facts.entry(key).or_insert((0.0, 0.0));
                            e.0 += sum;
                            e.1 += count;
                        }
                    }
                    _ => {
                        return abandon_and_fail(
                            &mut readers,
                            anyhow!("dyn record on the fact edge (stage {parent})"),
                        )
                    }
                }
            }
        } else {
            for rec in records {
                let pairs = match rec {
                    ShuffleRec::Dyn { pair } => vec![pair],
                    ShuffleRec::DynChunk { encs } => match dyn_chunk_values(&encs) {
                        Some(pairs) => pairs,
                        None => {
                            return abandon_and_fail(
                                &mut readers,
                                anyhow!("corrupt dyn chunk on the dimension edge"),
                            )
                        }
                    },
                    _ => {
                        return abandon_and_fail(
                            &mut readers,
                            anyhow!("kernel record on the dimension edge (stage {parent})"),
                        )
                    }
                };
                for pair in pairs {
                    let Some(k) = pair.key().as_i64() else {
                        return abandon_and_fail(
                            &mut readers,
                            anyhow!("non-i64 join key on the dimension edge"),
                        );
                    };
                    let Some(v) = pair.val().as_i64() else {
                        return abandon_and_fail(&mut readers, anyhow!("non-i64 dimension value"));
                    };
                    dim.insert(k, v);
                    resp.rows += 1;
                }
            }
        }
    }
    resp.timeline
        .charge(Component::Compute, sw.elapsed_s() * ctx.compute_scale());

    // Memory guard — the paper's answer is more partitions, not spill.
    let state_bytes = (facts.len() as u64) * 32 + (dim.len() as u64) * 16;
    if state_bytes > ctx.memory_limit_bytes {
        return abandon_and_fail(
            &mut readers,
            anyhow!(
                "join state ({state_bytes} B) exceeds executor memory — \
                 increase the number of partitions (spec has {})",
                spec.reduce_partitions
            ),
        );
    }

    if ctx.should_chain(&resp.timeline) {
        for r in readers.iter_mut() {
            r.ack(&mut resp.timeline)?;
        }
        let resume = ResumeState {
            input_offset: 0,
            input_done: false,
            rows_done: resp.rows,
            partial: encode_join_state(&facts, &dim, &seen),
            next_seqs: Vec::new(),
            links: task.resume.as_ref().map(|r| r.links + 1).unwrap_or(1),
        };
        return Ok(Some(resume));
    }

    // Inner hash join: each fact partial picks up its dimension row and
    // is re-keyed by the dimension value; keys with no dimension row are
    // dropped (inner semantics).
    let mut joined: BTreeMap<i64, (f64, f64)> = BTreeMap::new();
    for (k, (s, c)) in &facts {
        let Some(&out_key) = dim.get(k) else { continue };
        let e = joined.entry(out_key).or_insert((0.0, 0.0));
        e.0 += s;
        e.1 += c;
    }

    // Route the output BEFORE acking the drained inputs: a failed write
    // must leave the messages in flight (nacked below) so the retry
    // re-reads them — its byte-identical re-sends are deduped
    // downstream. Acking first would hand the retry empty queues and a
    // silently empty join result.
    match &task.output {
        TaskOutput::Shuffle { partitions } => {
            let mut w = make_writer(ctx, task, *partitions, None);
            let codec = ctx.env.config().flint.shuffle_codec;
            if let Err(e) = write_join_output(&mut w, joined, *partitions, codec, &mut resp.timeline)
            {
                return abandon_and_fail(&mut readers, e);
            }
            resp.msgs_sent = w.msgs_sent;
            resp.edge_sent_bytes = w.edge_bytes();
        }
        TaskOutput::Driver => {
            resp.emitted =
                Emitted::KernelRows(joined.into_iter().map(|(k, (s, c))| (k, s, c)).collect());
        }
        out => {
            return abandon_and_fail(
                &mut readers,
                anyhow!("kernel join cannot emit to {out:?}"),
            )
        }
    }
    for r in readers.iter_mut() {
        r.ack(&mut resp.timeline)?;
    }
    Ok(None)
}

/// Write the join stage's re-keyed partials to its output shuffle
/// (fallible: called before the input ack so the caller can nack).
fn write_join_output(
    w: &mut ShuffleWriter,
    joined: BTreeMap<i64, (f64, f64)>,
    partitions: u32,
    codec: ShuffleCodec,
    tl: &mut Timeline,
) -> Result<()> {
    let mut runs: BTreeMap<u32, Vec<(i64, f64, f64)>> = BTreeMap::new();
    for (key, (sum, count)) in joined {
        runs.entry(kernel_partition(key, partitions))
            .or_default()
            .push((key, sum, count));
    }
    for (p, run) in runs {
        for rec in pack_kernel_run(&run, codec) {
            w.write(p, &rec, tl)?;
        }
    }
    w.flush_all(tl)
}

/// Chain-state codec for the join: the per-edge tag survives the chain
/// (facts and dimension are stored as separate sections, plus the
/// shared dedup set).
fn encode_join_state(
    facts: &BTreeMap<i64, (f64, f64)>,
    dim: &BTreeMap<i64, i64>,
    seen: &HashSet<(u64, u64)>,
) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(facts.len() as u64).to_le_bytes());
    for (k, (s, c)) in facts {
        out.extend_from_slice(&k.to_le_bytes());
        out.extend_from_slice(&s.to_le_bytes());
        out.extend_from_slice(&c.to_le_bytes());
    }
    out.extend_from_slice(&(dim.len() as u64).to_le_bytes());
    for (k, v) in dim {
        out.extend_from_slice(&k.to_le_bytes());
        out.extend_from_slice(&v.to_le_bytes());
    }
    let mut seen_sorted: Vec<(u64, u64)> = seen.iter().copied().collect();
    seen_sorted.sort_unstable();
    out.extend_from_slice(&(seen_sorted.len() as u64).to_le_bytes());
    for (p, s) in seen_sorted {
        out.extend_from_slice(&p.to_le_bytes());
        out.extend_from_slice(&s.to_le_bytes());
    }
    out
}

fn decode_join_state(
    bytes: &[u8],
    facts: &mut BTreeMap<i64, (f64, f64)>,
    dim: &mut BTreeMap<i64, i64>,
    seen: &mut HashSet<(u64, u64)>,
) -> Result<()> {
    let err = || anyhow!("corrupt join partial");
    let mut pos = 0usize;
    let take8 = |pos: &mut usize| -> Result<[u8; 8]> {
        let out: [u8; 8] = bytes.get(*pos..*pos + 8).ok_or_else(err)?.try_into().unwrap();
        *pos += 8;
        Ok(out)
    };
    let n = u64::from_le_bytes(take8(&mut pos)?) as usize;
    for _ in 0..n {
        let k = i64::from_le_bytes(take8(&mut pos)?);
        let s = f64::from_le_bytes(take8(&mut pos)?);
        let c = f64::from_le_bytes(take8(&mut pos)?);
        facts.insert(k, (s, c));
    }
    let m = u64::from_le_bytes(take8(&mut pos)?) as usize;
    for _ in 0..m {
        let k = i64::from_le_bytes(take8(&mut pos)?);
        let v = i64::from_le_bytes(take8(&mut pos)?);
        dim.insert(k, v);
    }
    let d = u64::from_le_bytes(take8(&mut pos)?) as usize;
    for _ in 0..d {
        let p = u64::from_le_bytes(take8(&mut pos)?);
        let s = u64::from_le_bytes(take8(&mut pos)?);
        seen.insert((p, s));
    }
    if pos != bytes.len() {
        return Err(err());
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Dynamic scan / reduce (generic RDD path)
// ---------------------------------------------------------------------

fn dyn_scan(
    ctx: &ExecCtx,
    task: &TaskDescriptor,
    ops: &[crate::plan::DynOp],
    resp: &mut TaskResponse,
) -> Result<Option<ResumeState>> {
    let TaskInput::Split(split) = &task.input else { unreachable!() };
    // Statistics-based pruning on the generic path: leading
    // `filter_day_range` ops expose a typed day predicate to the planner;
    // when it is disjoint from the split's manifest stats no line can
    // survive the chain's head, so the S3 GET is skipped outright. (A
    // resumed link never prunes — its first link already read data.)
    let pruned = ctx.env.config().flint.scan_prune
        && task.resume.is_none()
        && match (crate::plan::DynOp::leading_day_range(ops), &split.stats) {
            (Some((lo, hi)), Some(st)) => !st.overlaps_days(lo, hi),
            _ => false,
        };
    let window;
    let mut lines = if pruned {
        ctx.env.metrics().incr("scan.splits_pruned");
        SplitLines::new(&[], 0, true)
    } else {
        let (fs, fe) = fetch_range(split.start, split.end, split.object_size);
        let (w, dt) = ctx
            .env
            .s3()
            .get_range(&split.bucket, &split.key, fs, fe, ctx.read_profile())
            .map_err(|e| anyhow!("input split: {e}"))?;
        resp.timeline.charge(Component::S3Read, dt);
        window = w;
        SplitLines::new(window.bytes(), split.len(), split.start == 0)
    };
    if let Some(r) = &task.resume {
        lines.seek(r.input_offset as usize);
        resp.rows = r.rows_done;
    }

    let out_parts = stage_output_partitions(ctx, task);
    let combine = match &ctx.plan.stages[task.stage_id as usize].output {
        StageOutput::Shuffle { combine, .. } => combine.clone(),
        _ => None,
    };
    let mut writer = out_parts.map(|parts| {
        make_writer(ctx, task, parts, task.resume.as_ref().map(|r| r.next_seqs.clone()))
    });

    // Map-side combine buffer (deterministic BTreeMap by encoded key).
    let mut side: BTreeMap<Vec<u8>, Value> = BTreeMap::new();
    if let Some(r) = &task.resume {
        if !r.partial.is_empty() {
            decode_side(&r.partial, &mut side)?;
        }
    }
    let mut collected: Vec<Value> = Vec::new();
    let mut count: u64 = 0;
    let mut emitted_buf: Vec<Value> = Vec::new();
    let pipe_rate = ctx.env.config().sim.pyspark_pipe_per_record_s;
    let flush_bytes = ctx.env.config().flint.shuffle_buffer_bytes;

    loop {
        let sw = CpuStopwatch::start();
        let mut block_lines = 0u64;
        for _ in 0..4096 {
            let Some(line) = lines.next() else { break };
            block_lines += 1;
            resp.rows += 1;
            let input = Value::Str(String::from_utf8_lossy(line).into_owned());
            emitted_buf.clear();
            crate::plan::DynOp::apply_chain(ops, input, &mut emitted_buf);
            for v in emitted_buf.drain(..) {
                match (&task.output, combine.as_ref()) {
                    (TaskOutput::Shuffle { .. }, Some(c)) => {
                        // reduceByKey: map-side combine.
                        let key_bytes = v.key().encode();
                        let val = v.val().clone();
                        match side.remove(&key_bytes) {
                            Some(prev) => {
                                side.insert(key_bytes, c(prev, val));
                            }
                            None => {
                                side.insert(key_bytes, val);
                            }
                        }
                    }
                    (TaskOutput::Shuffle { partitions }, None) => {
                        let p = dyn_partition(v.key(), *partitions);
                        writer.as_mut().unwrap().write(
                            p,
                            &ShuffleRec::Dyn { pair: v },
                            &mut resp.timeline,
                        )?;
                    }
                    (TaskOutput::Driver, _) => match &ctx.plan.action {
                        Action::Count => count += 1,
                        _ => collected.push(v),
                    },
                    (TaskOutput::S3 { .. }, _) => collected.push(v),
                }
            }
        }
        resp.timeline
            .charge(Component::Compute, sw.elapsed_s() * ctx.compute_scale());
        if ctx.mode == IoMode::PySpark && block_lines > 0 {
            resp.timeline
                .charge(Component::PipeOverhead, block_lines as f64 * pipe_rate);
        }
        if block_lines == 0 {
            break;
        }

        if ctx
            .env
            .failure()
            .take_forced_failure(task.stage_id, task.task_index, task.attempt)
        {
            return Err(anyhow!(
                "injected executor crash (stage {} task {} attempt {})",
                task.stage_id,
                task.task_index,
                task.attempt
            ));
        }

        // Memory pressure: flush combined groups to the shuffle (the
        // paper's executors do exactly this).
        let side_bytes: usize = side.iter().map(|(k, v)| k.len() + v.mem_bytes()).sum();
        if let (Some(w), true) = (writer.as_mut(), side_bytes > flush_bytes) {
            flush_side(&mut side, w, ctx.env.config().flint.shuffle_codec, &mut resp.timeline)?;
        }
        let mem_used = window.len() as u64
            + side_bytes as u64
            + writer.as_ref().map(|w| w.buffered_bytes() as u64).unwrap_or(0)
            + collected.iter().map(|v| v.mem_bytes() as u64).sum::<u64>();
        if mem_used > ctx.memory_limit_bytes {
            return Err(anyhow!(
                "executor memory exceeded ({mem_used} B) — increase partitions or split size"
            ));
        }

        if ctx.should_chain(&resp.timeline) {
            let resume = ResumeState {
                input_offset: lines.offset() as u64,
                input_done: false,
                rows_done: resp.rows,
                partial: encode_side(&side),
                next_seqs: writer.as_ref().map(|w| w.seqs()).unwrap_or_default(),
                links: task.resume.as_ref().map(|r| r.links + 1).unwrap_or(1),
            };
            return Ok(Some(resume));
        }
    }

    match &task.output {
        TaskOutput::Shuffle { .. } => {
            let w = writer.as_mut().expect("writer for shuffle output");
            flush_side(&mut side, w, ctx.env.config().flint.shuffle_codec, &mut resp.timeline)?;
            w.flush_all(&mut resp.timeline)?;
            resp.msgs_sent = w.msgs_sent;
            resp.edge_sent_bytes = w.edge_bytes();
        }
        TaskOutput::Driver => {
            resp.emitted = match &ctx.plan.action {
                Action::Count => Emitted::Count(count),
                _ => Emitted::Values(std::mem::take(&mut collected)),
            };
        }
        TaskOutput::S3 { bucket, prefix } => {
            resp.emitted = save_values(ctx, bucket, prefix, task, &collected, &mut resp.timeline)?;
        }
    }
    Ok(None)
}

/// Scan a materialized cache partition (the warm-run replacement for a
/// [`dyn_scan`] over the original input): read the committed `Value`
/// stream from the warm container's memory tier when this invocation is
/// warm and the part was promoted, else from the S3 tier, then run the
/// post-marker op chain and route exactly like a dyn scan. Cache reads
/// never chain — parts are bounded by one build task's output, far
/// below the duration cap.
fn cached_scan(
    ctx: &ExecCtx,
    task: &TaskDescriptor,
    ops: &[crate::plan::DynOp],
    warm_container: bool,
    resp: &mut TaskResponse,
) -> Result<Option<ResumeState>> {
    let TaskInput::CachedPart(part) = &task.input else { unreachable!() };
    // Warm-container placement: the driver threads the invocation
    // ticket's cold/warm verdict through `run_task` — only a live
    // container drawn from the warm pool holds the memory tier.
    // (Inferring warmth from a zero ColdStart charge would misread cold
    // invocations whenever `sim.lambda_cold_start_s` is configured 0.)
    // Cold containers — and engines that provision nothing — fall back
    // to the S3 tier object the build committed.
    let bytes: Arc<Vec<u8>> = match (&part.mem, warm_container) {
        (Some(mem), true) => {
            ctx.env.metrics().incr("cache.mem_reads");
            // Memory-tier read: no S3 round trip, just a memcpy-rate
            // walk of the resident bytes.
            resp.timeline
                .charge(Component::Compute, mem.len() as f64 / 1e10);
            Arc::clone(mem)
        }
        _ => {
            let (obj, dt) = ctx
                .env
                .s3()
                .get_object(&part.bucket, &part.key, ctx.read_profile())
                .map_err(|e| anyhow!("cache part: {e}"))?;
            resp.timeline.charge(Component::S3Read, dt);
            ctx.env.metrics().incr("cache.s3_reads");
            Arc::new(obj.bytes().to_vec())
        }
    };

    if ctx
        .env
        .failure()
        .take_forced_failure(task.stage_id, task.task_index, task.attempt)
    {
        return Err(anyhow!(
            "injected executor crash (stage {} task {} attempt {})",
            task.stage_id,
            task.task_index,
            task.attempt
        ));
    }

    let values =
        Value::decode_stream(&bytes).ok_or_else(|| anyhow!("corrupt cache part {}", part.key))?;

    let out_parts = stage_output_partitions(ctx, task);
    let combine = match &ctx.plan.stages[task.stage_id as usize].output {
        StageOutput::Shuffle { combine, .. } => combine.clone(),
        _ => None,
    };
    let mut writer = out_parts.map(|parts| make_writer(ctx, task, parts, None));
    let mut side: BTreeMap<Vec<u8>, Value> = BTreeMap::new();
    let mut collected: Vec<Value> = Vec::new();
    let mut count: u64 = 0;
    let mut emitted_buf: Vec<Value> = Vec::new();

    let sw = CpuStopwatch::start();
    for input in values {
        resp.rows += 1;
        emitted_buf.clear();
        crate::plan::DynOp::apply_chain(ops, input, &mut emitted_buf);
        for v in emitted_buf.drain(..) {
            match (&task.output, combine.as_ref()) {
                (TaskOutput::Shuffle { .. }, Some(c)) => {
                    let key_bytes = v.key().encode();
                    let val = v.val().clone();
                    match side.remove(&key_bytes) {
                        Some(prev) => {
                            side.insert(key_bytes, c(prev, val));
                        }
                        None => {
                            side.insert(key_bytes, val);
                        }
                    }
                }
                (TaskOutput::Shuffle { partitions }, None) => {
                    let p = dyn_partition(v.key(), *partitions);
                    writer.as_mut().unwrap().write(
                        p,
                        &ShuffleRec::Dyn { pair: v },
                        &mut resp.timeline,
                    )?;
                }
                (TaskOutput::Driver, _) => match &ctx.plan.action {
                    Action::Count => count += 1,
                    _ => collected.push(v),
                },
                (TaskOutput::S3 { .. }, _) => collected.push(v),
            }
        }
    }
    resp.timeline
        .charge(Component::Compute, sw.elapsed_s() * ctx.compute_scale());

    let side_bytes: usize = side.iter().map(|(k, v)| k.len() + v.mem_bytes()).sum();
    let mem_used = bytes.len() as u64
        + side_bytes as u64
        + writer.as_ref().map(|w| w.buffered_bytes() as u64).unwrap_or(0)
        + collected.iter().map(|v| v.mem_bytes() as u64).sum::<u64>();
    if mem_used > ctx.memory_limit_bytes {
        return Err(anyhow!(
            "executor memory exceeded ({mem_used} B) — increase partitions or split size"
        ));
    }

    match &task.output {
        TaskOutput::Shuffle { .. } => {
            let w = writer.as_mut().expect("writer for shuffle output");
            flush_side(&mut side, w, ctx.env.config().flint.shuffle_codec, &mut resp.timeline)?;
            w.flush_all(&mut resp.timeline)?;
            resp.msgs_sent = w.msgs_sent;
            resp.edge_sent_bytes = w.edge_bytes();
        }
        TaskOutput::Driver => {
            resp.emitted = match &ctx.plan.action {
                Action::Count => Emitted::Count(count),
                _ => Emitted::Values(std::mem::take(&mut collected)),
            };
        }
        TaskOutput::S3 { bucket, prefix } => {
            resp.emitted = save_values(ctx, bucket, prefix, task, &collected, &mut resp.timeline)?;
        }
    }
    Ok(None)
}

fn flush_side(
    side: &mut BTreeMap<Vec<u8>, Value>,
    writer: &mut ShuffleWriter,
    codec: ShuffleCodec,
    tl: &mut Timeline,
) -> Result<()> {
    // The side map iterates in encoded-key order, so each partition's
    // run stays sorted — exactly what the columnar front-coding wants.
    let partitions = writer_partitions(writer);
    let mut runs: Vec<Vec<Value>> = vec![Vec::new(); partitions as usize];
    for (key_bytes, val) in std::mem::take(side) {
        let (key, _) = Value::decode(&key_bytes).ok_or_else(|| anyhow!("corrupt side key"))?;
        let p = dyn_partition(&key, partitions);
        runs[p as usize].push(Value::pair(key, val));
    }
    for (p, run) in runs.iter().enumerate() {
        if run.is_empty() {
            continue;
        }
        for rec in pack_dyn_run(run, codec) {
            writer.write(p as u32, &rec, tl)?;
        }
    }
    Ok(())
}

fn writer_partitions(w: &ShuffleWriter) -> u32 {
    w.seqs().len() as u32
}

fn dyn_reduce(
    ctx: &ExecCtx,
    task: &TaskDescriptor,
    combine: crate::plan::rdd::CombineFn,
    post_ops: &[crate::plan::DynOp],
    resp: &mut TaskResponse,
) -> Result<Option<ResumeState>> {
    let TaskInput::ShufflePartition { partition, parents } = &task.input else {
        unreachable!()
    };
    let dedup = ctx.env.config().flint.dedup_enabled;
    let mut seen: HashSet<(u64, u64)> = HashSet::new();
    let mut readers = open_parent_readers(ctx, task, parents, *partition, dedup);
    // DynReduce has *union* semantics over its parent edges; the tags
    // are folded back into one stream (DynCoGroup keeps them apart).
    let tagged = drain_tagged(&mut readers, parents, &mut seen, resp)?;

    if ctx
        .env
        .failure()
        .take_forced_failure(task.stage_id, task.task_index, task.attempt)
    {
        abandon_all(&mut readers);
        return Err(anyhow!("injected reducer crash"));
    }

    let sw = CpuStopwatch::start();
    let mut agg: BTreeMap<Vec<u8>, Value> = BTreeMap::new();
    for rec in tagged.into_iter().flat_map(|t| t.records) {
        let pairs = match rec {
            ShuffleRec::Dyn { pair } => vec![pair],
            ShuffleRec::DynChunk { encs } => match dyn_chunk_values(&encs) {
                Some(pairs) => pairs,
                None => {
                    return abandon_and_fail(&mut readers, anyhow!("corrupt dyn chunk in reduce"))
                }
            },
            _ => return abandon_and_fail(&mut readers, anyhow!("kernel record in dyn reduce")),
        };
        for pair in pairs {
            resp.rows += 1;
            let key_bytes = pair.key().encode();
            let val = pair.val().clone();
            match agg.remove(&key_bytes) {
                Some(prev) => {
                    agg.insert(key_bytes, combine(prev, val));
                }
                None => {
                    agg.insert(key_bytes, val);
                }
            }
        }
    }
    let mut pairs = Vec::with_capacity(agg.len());
    for (key_bytes, val) in agg {
        let Some((key, _)) = Value::decode(&key_bytes) else {
            return abandon_and_fail(&mut readers, anyhow!("corrupt agg key"));
        };
        pairs.push((key, val));
    }
    resp.timeline
        .charge(Component::Compute, sw.elapsed_s() * ctx.compute_scale());

    route_post_ops(ctx, task, pairs, post_ops, &mut readers, resp)
}

/// Generic cogroup over the *tagged* parent streams: each key's values
/// are grouped per origin edge and emitted as
/// `(key, [side0_values, side1_values, ...])` through the post chain —
/// the reduce-side shape `Rdd::cogroup`/`Rdd::join` lower to. Each
/// side's list is sorted into the deterministic total order because
/// queue arrival order across producers is a host-thread race.
fn dyn_cogroup(
    ctx: &ExecCtx,
    task: &TaskDescriptor,
    post_ops: &[crate::plan::DynOp],
    resp: &mut TaskResponse,
) -> Result<Option<ResumeState>> {
    let TaskInput::ShufflePartition { partition, parents } = &task.input else {
        unreachable!()
    };
    let dedup = ctx.env.config().flint.dedup_enabled;
    let mut seen: HashSet<(u64, u64)> = HashSet::new();
    let mut readers = open_parent_readers(ctx, task, parents, *partition, dedup);
    let tagged = drain_tagged(&mut readers, parents, &mut seen, resp)?;

    if ctx
        .env
        .failure()
        .take_forced_failure(task.stage_id, task.task_index, task.attempt)
    {
        abandon_all(&mut readers);
        return Err(anyhow!(
            "injected cogroup crash (stage {} task {} attempt {})",
            task.stage_id,
            task.task_index,
            task.attempt
        ));
    }

    let sw = CpuStopwatch::start();
    let n_sides = parents.len();
    // key bytes → one value list per parent edge (index = edge position).
    let mut groups: BTreeMap<Vec<u8>, Vec<Vec<Value>>> = BTreeMap::new();
    for (side, TaggedRecords { parent, records }) in tagged.into_iter().enumerate() {
        for rec in records {
            let pairs = match rec {
                ShuffleRec::Dyn { pair } => vec![pair],
                ShuffleRec::DynChunk { encs } => match dyn_chunk_values(&encs) {
                    Some(pairs) => pairs,
                    None => {
                        return abandon_and_fail(
                            &mut readers,
                            anyhow!("corrupt dyn chunk in cogroup (edge from stage {parent})"),
                        )
                    }
                },
                _ => {
                    return abandon_and_fail(
                        &mut readers,
                        anyhow!("kernel record in cogroup (edge from stage {parent})"),
                    )
                }
            };
            for pair in pairs {
                resp.rows += 1;
                let kb = pair.key().encode();
                let sides = groups.entry(kb).or_insert_with(|| vec![Vec::new(); n_sides]);
                sides[side].push(pair.val().clone());
            }
        }
    }
    let mut pairs = Vec::with_capacity(groups.len());
    for (kb, mut sides) in groups {
        let Some((key, _)) = Value::decode(&kb) else {
            return abandon_and_fail(&mut readers, anyhow!("corrupt cogroup key"));
        };
        for side in &mut sides {
            side.sort_by(|a, b| a.total_cmp(b));
        }
        pairs.push((key, Value::List(sides.into_iter().map(Value::List).collect())));
    }
    resp.timeline
        .charge(Component::Compute, sw.elapsed_s() * ctx.compute_scale());

    route_post_ops(ctx, task, pairs, post_ops, &mut readers, resp)
}

/// Pre-ack routing state produced by [`route_pairs`].
struct RoutedOutputs<'a> {
    writer: Option<ShuffleWriter<'a>>,
    next_side: BTreeMap<Vec<u8>, Value>,
    collected: Vec<Value>,
    count: u64,
}

/// Run the post-op chain over grouped pairs and buffer/route the
/// outputs. Fallible (shuffle writes) and called *before* the readers
/// ack, so the caller can nack on error.
fn route_pairs<'a>(
    ctx: &ExecCtx<'a>,
    task: &TaskDescriptor,
    pairs: Vec<(Value, Value)>,
    post_ops: &[crate::plan::DynOp],
    resp: &mut TaskResponse,
) -> Result<RoutedOutputs<'a>> {
    let sw = CpuStopwatch::start();
    let out_parts = stage_output_partitions(ctx, task);
    let next_combine = match &ctx.plan.stages[task.stage_id as usize].output {
        StageOutput::Shuffle { combine, .. } => combine.clone(),
        _ => None,
    };
    let mut writer = out_parts.map(|parts| make_writer(ctx, task, parts, None));
    let mut collected = Vec::new();
    let mut count = 0u64;
    let mut buf = Vec::new();
    let mut next_side: BTreeMap<Vec<u8>, Value> = BTreeMap::new();
    for (key, val) in pairs {
        buf.clear();
        crate::plan::DynOp::apply_chain(post_ops, Value::pair(key, val), &mut buf);
        for v in buf.drain(..) {
            match (&task.output, next_combine.as_ref()) {
                (TaskOutput::Shuffle { .. }, Some(c)) => {
                    let kb = v.key().encode();
                    let vv = v.val().clone();
                    match next_side.remove(&kb) {
                        Some(prev) => {
                            next_side.insert(kb, c(prev, vv));
                        }
                        None => {
                            next_side.insert(kb, vv);
                        }
                    }
                }
                (TaskOutput::Shuffle { partitions }, None) => {
                    let p = dyn_partition(v.key(), *partitions);
                    writer.as_mut().unwrap().write(
                        p,
                        &ShuffleRec::Dyn { pair: v },
                        &mut resp.timeline,
                    )?;
                }
                (TaskOutput::Driver, _) => match &ctx.plan.action {
                    Action::Count => count += 1,
                    _ => collected.push(v),
                },
                (TaskOutput::S3 { .. }, _) => collected.push(v),
            }
        }
    }
    resp.timeline
        .charge(Component::Compute, sw.elapsed_s() * ctx.compute_scale());
    Ok(RoutedOutputs { writer, next_side, collected, count })
}

/// Apply a reduce-side post-op chain to grouped `(key, value)` records
/// and route the results (next shuffle stage, driver response, or S3) —
/// the shared tail of DynReduce and DynCoGroup. The attempt's complete
/// output (final shuffle flush included) is sealed *before* the drained
/// readers ack, and any routing/flush error nacks everything back: a
/// crashed or cancelled attempt can never leave acked-empty input
/// behind a partial output (attempt-safe commit — what makes racing
/// duplicate attempts and speculative backups safe on every backend).
fn route_post_ops(
    ctx: &ExecCtx,
    task: &TaskDescriptor,
    pairs: Vec<(Value, Value)>,
    post_ops: &[crate::plan::DynOp],
    readers: &mut [ShuffleReader],
    resp: &mut TaskResponse,
) -> Result<Option<ResumeState>> {
    let routed = match route_pairs(ctx, task, pairs, post_ops, resp) {
        Ok(r) => r,
        Err(e) => return abandon_and_fail(readers, e),
    };
    let RoutedOutputs { mut writer, mut next_side, collected, count } = routed;

    match &task.output {
        TaskOutput::Shuffle { .. } => {
            let w = writer.as_mut().expect("writer");
            let codec = ctx.env.config().flint.shuffle_codec;
            let sealed = flush_side(&mut next_side, w, codec, &mut resp.timeline)
                .and_then(|()| w.flush_all(&mut resp.timeline));
            if let Err(e) = sealed {
                return abandon_and_fail(readers, e);
            }
            resp.msgs_sent = w.msgs_sent;
            resp.edge_sent_bytes = w.edge_bytes();
        }
        TaskOutput::Driver => {
            resp.emitted = match &ctx.plan.action {
                Action::Count => Emitted::Count(count),
                _ => Emitted::Values(collected),
            };
        }
        TaskOutput::S3 { bucket, prefix } => {
            match save_values(ctx, bucket, prefix, task, &collected, &mut resp.timeline) {
                Ok(emitted) => resp.emitted = emitted,
                Err(e) => return abandon_and_fail(readers, e),
            }
        }
    }
    for r in readers.iter_mut() {
        r.ack(&mut resp.timeline)?;
    }
    Ok(None)
}

fn save_values(
    ctx: &ExecCtx,
    bucket: &str,
    prefix: &str,
    task: &TaskDescriptor,
    values: &[Value],
    tl: &mut Timeline,
) -> Result<Emitted> {
    // Cache materialization keeps the exact `Value` encoding so a warm
    // run's `cached_scan` decodes bit-identical values back; user-facing
    // saveAsTextFile keeps the readable text form.
    let bytes = if matches!(ctx.plan.action, Action::CacheWrite { .. }) {
        let mut out = Vec::new();
        for v in values {
            v.encode_into(&mut out);
        }
        out
    } else {
        let mut text = String::new();
        for v in values {
            match v {
                Value::Pair(k, val) => text.push_str(&format!("{k:?}\t{val:?}\n")),
                other => text.push_str(&format!("{other:?}\n")),
            }
        }
        text.into_bytes()
    };
    commit_part(ctx, bucket, prefix, task.task_index, task.attempt, bytes, tl)?;
    Ok(Emitted::Saved(1))
}

// ---------------------------------------------------------------------
// Partial-state (chaining) serialization
// ---------------------------------------------------------------------

fn encode_hist(h: &HistAccum) -> Vec<u8> {
    let k = h.sums.len();
    let mut out = Vec::with_capacity(8 + k * 16 + 8);
    out.extend_from_slice(&(k as u64).to_le_bytes());
    for v in &h.sums {
        out.extend_from_slice(&v.to_le_bytes());
    }
    for v in &h.counts {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out.extend_from_slice(&h.rows_seen.to_le_bytes());
    out
}

fn decode_hist(bytes: &[u8], h: &mut HistAccum) -> Result<()> {
    let err = || anyhow!("corrupt hist partial");
    if bytes.len() < 8 {
        return Err(err());
    }
    let k = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
    if k != h.sums.len() || bytes.len() != 8 + k * 16 + 8 {
        return Err(err());
    }
    for i in 0..k {
        let off = 8 + i * 8;
        h.sums[i] = f64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
    }
    for i in 0..k {
        let off = 8 + k * 8 + i * 8;
        h.counts[i] = f64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
    }
    h.rows_seen = u64::from_le_bytes(bytes[8 + k * 16..].try_into().unwrap());
    Ok(())
}

fn encode_reduce_state(agg: &BTreeMap<i64, (f64, f64)>, seen: &HashSet<(u64, u64)>) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(agg.len() as u64).to_le_bytes());
    for (k, (s, c)) in agg {
        out.extend_from_slice(&k.to_le_bytes());
        out.extend_from_slice(&s.to_le_bytes());
        out.extend_from_slice(&c.to_le_bytes());
    }
    let mut seen_sorted: Vec<(u64, u64)> = seen.iter().copied().collect();
    seen_sorted.sort_unstable();
    out.extend_from_slice(&(seen_sorted.len() as u64).to_le_bytes());
    for (p, s) in seen_sorted {
        out.extend_from_slice(&p.to_le_bytes());
        out.extend_from_slice(&s.to_le_bytes());
    }
    out
}

fn decode_reduce_state(
    bytes: &[u8],
    agg: &mut BTreeMap<i64, (f64, f64)>,
    seen: &mut HashSet<(u64, u64)>,
) -> Result<()> {
    let err = || anyhow!("corrupt reduce partial");
    let mut pos = 0usize;
    let take8 = |pos: &mut usize| -> Result<[u8; 8]> {
        let out: [u8; 8] = bytes.get(*pos..*pos + 8).ok_or_else(err)?.try_into().unwrap();
        *pos += 8;
        Ok(out)
    };
    let n = u64::from_le_bytes(take8(&mut pos)?) as usize;
    for _ in 0..n {
        let k = i64::from_le_bytes(take8(&mut pos)?);
        let s = f64::from_le_bytes(take8(&mut pos)?);
        let c = f64::from_le_bytes(take8(&mut pos)?);
        agg.insert(k, (s, c));
    }
    let m = u64::from_le_bytes(take8(&mut pos)?) as usize;
    for _ in 0..m {
        let p = u64::from_le_bytes(take8(&mut pos)?);
        let s = u64::from_le_bytes(take8(&mut pos)?);
        seen.insert((p, s));
    }
    if pos != bytes.len() {
        return Err(err());
    }
    Ok(())
}

fn encode_side(side: &BTreeMap<Vec<u8>, Value>) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(side.len() as u64).to_le_bytes());
    for (k, v) in side {
        out.extend_from_slice(&(k.len() as u64).to_le_bytes());
        out.extend_from_slice(k);
        v.encode_into(&mut out);
    }
    out
}

fn decode_side(bytes: &[u8], side: &mut BTreeMap<Vec<u8>, Value>) -> Result<()> {
    let err = || anyhow!("corrupt side partial");
    let mut pos = 0usize;
    if bytes.len() < 8 {
        return Err(err());
    }
    let n = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
    pos += 8;
    for _ in 0..n {
        let klen =
            u64::from_le_bytes(bytes.get(pos..pos + 8).ok_or_else(err)?.try_into().unwrap())
                as usize;
        pos += 8;
        let k = bytes.get(pos..pos + klen).ok_or_else(err)?.to_vec();
        pos += klen;
        let (v, used) = Value::decode(&bytes[pos..]).ok_or_else(err)?;
        pos += used;
        side.insert(k, v);
    }
    if pos != bytes.len() {
        return Err(err());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_partial_roundtrip() {
        let mut h = HistAccum::new(5);
        h.sums[2] = 1.5;
        h.counts[2] = 3.0;
        h.rows_seen = 99;
        let enc = encode_hist(&h);
        let mut back = HistAccum::new(5);
        decode_hist(&enc, &mut back).unwrap();
        assert_eq!(back, h);
        // Wrong bucket count rejected.
        let mut wrong = HistAccum::new(4);
        assert!(decode_hist(&enc, &mut wrong).is_err());
    }

    #[test]
    fn reduce_state_roundtrip() {
        let mut agg = BTreeMap::new();
        agg.insert(3i64, (1.0, 2.0));
        agg.insert(-9i64, (0.5, 1.0));
        let mut seen = HashSet::new();
        seen.insert((7u64, 0u64));
        seen.insert((7u64, 1u64));
        let enc = encode_reduce_state(&agg, &seen);
        let mut agg2 = BTreeMap::new();
        let mut seen2 = HashSet::new();
        decode_reduce_state(&enc, &mut agg2, &mut seen2).unwrap();
        assert_eq!(agg2, agg);
        assert_eq!(seen2, seen);
        assert!(decode_reduce_state(&enc[..enc.len() - 1], &mut agg2, &mut seen2).is_err());
    }

    #[test]
    fn join_state_roundtrip_keeps_edges_apart() {
        // The chain-resume partial for a join is tag-separated: fact
        // partials and dimension rows must come back on their own sides.
        let mut facts = BTreeMap::new();
        facts.insert(100i64, (3.0, 3.0));
        facts.insert(-2i64, (1.5, 2.0));
        let mut dim = BTreeMap::new();
        dim.insert(100i64, 4i64);
        let mut seen = HashSet::new();
        seen.insert((1u64 << 32, 0u64));
        seen.insert((0u64, 0u64));
        let enc = encode_join_state(&facts, &dim, &seen);
        let (mut f2, mut d2, mut s2) = (BTreeMap::new(), BTreeMap::new(), HashSet::new());
        decode_join_state(&enc, &mut f2, &mut d2, &mut s2).unwrap();
        assert_eq!(f2, facts);
        assert_eq!(d2, dim);
        assert_eq!(s2, seen);
        // Truncation is rejected, not silently shortened.
        assert!(decode_join_state(&enc[..enc.len() - 1], &mut f2, &mut d2, &mut s2).is_err());
    }

    #[test]
    fn side_state_roundtrip() {
        let mut side = BTreeMap::new();
        side.insert(Value::str("a").encode(), Value::I64(3));
        side.insert(Value::I64(9).encode(), Value::F64(0.5));
        let enc = encode_side(&side);
        let mut back = BTreeMap::new();
        decode_side(&enc, &mut back).unwrap();
        assert_eq!(back, side);
    }
}
