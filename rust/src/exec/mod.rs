//! Execution engines: Flint (serverless, the paper's system) and the
//! cluster baselines (Scala Spark / PySpark) it is evaluated against.

pub mod cache;
pub mod cluster;
pub mod driver;
pub mod exchange;
pub mod executor;
pub mod flint;
pub mod service;
pub mod session;
pub mod shuffle;

pub use cache::{
    lineage_fingerprint, pinned_lineage_fingerprint, CacheRegistry, LineagePins, ScanCache,
    ServiceShared,
};
pub use cluster::{ClusterEngine, ClusterMode};
pub use driver::{ActionOut, EdgeShuffle, RunOutput};
pub use flint::FlintEngine;
pub use service::{
    FlintService, ServiceError, ServiceQueryReport, ServiceReport, StragglerPredictor,
};
pub use session::FlintContext;

use crate::compute::queries::{QueryId, QueryResult};
use crate::cost::CostSnapshot;
use crate::data::Dataset;
use crate::simtime::{StageWindow, Timeline};
use anyhow::Result;

/// What every engine reports per query — the two Table I columns plus
/// the diagnostics behind them.
#[derive(Debug)]
pub struct QueryReport {
    pub engine: String,
    pub query: Option<QueryId>,
    pub result: QueryResult,
    /// Virtual query latency in seconds (Table I column 1), under the
    /// engine's configured schedule mode.
    pub latency_s: f64,
    /// Latency under the serial stage-barrier clock (always computed).
    pub barrier_latency_s: f64,
    /// Latency under the pipelined DAG clock (always computed).
    pub pipelined_latency_s: f64,
    /// The pipelined clock with speculative backups ignored — equals
    /// `pipelined_latency_s` when speculation is off, so one run prices
    /// the exact latency speculation bought.
    pub pipelined_nospec_latency_s: f64,
    /// Occupied-but-idle long-polling seconds on the pipelined clock
    /// (billed as GB-seconds when pipelined is the selected schedule).
    pub pipelined_idle_s: f64,
    /// USD for this query (Table I column 2).
    pub cost_usd: f64,
    pub cost: CostSnapshot,
    pub stage_latencies: Vec<f64>,
    /// Per-stage start/end on the serial barrier clock.
    pub barrier_windows: Vec<StageWindow>,
    /// Per-stage start/end on the pipelined DAG clock.
    pub pipelined_windows: Vec<StageWindow>,
    /// Shuffle receive volume per DAG edge.
    pub edge_shuffle: Vec<EdgeShuffle>,
    /// Where task time went, summed across tasks.
    pub timeline: Timeline,
    pub tasks: u64,
    pub invocations: u64,
    pub retries: u64,
    pub chains: u64,
    pub shuffle_msgs: u64,
    pub duplicates_dropped: u64,
    /// Speculative backup attempts launched / won (attempt model).
    pub speculative_launches: u64,
    pub speculative_wins: u64,
}

impl QueryReport {
    /// One-line summary for examples/CLI.
    pub fn summary(&self) -> String {
        format!(
            "{:8} {}: latency {:7.1}s  cost ${:.4}  ({} tasks, {} invocations, {} chains, {} retries)",
            self.engine,
            self.query.map(|q| q.name()).unwrap_or("plan"),
            self.latency_s,
            self.cost_usd,
            self.tasks,
            self.invocations,
            self.chains,
            self.retries
        )
    }
}

/// A query execution engine.
pub trait Engine {
    fn name(&self) -> &'static str;

    /// Run one of the paper's benchmark queries over a dataset.
    fn run_query(&self, query: QueryId, dataset: &Dataset) -> Result<QueryReport>;
}
