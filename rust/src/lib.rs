//! # Flint — serverless data analytics, reproduced
//!
//! A reproduction of *"Serverless Data Analytics with Flint"* (Kim & Lin,
//! 2018) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the Flint coordinator: an RDD → DAG → stage →
//!   task pipeline whose tasks execute inside a simulated AWS Lambda
//!   substrate, shuffling intermediate data through a simulated SQS, with
//!   S3-style object storage for input/output. Baseline "Spark cluster"
//!   engines (Scala-Spark-like and PySpark-like) run the same plans for
//!   the paper's Table I comparison.
//! * **L2** — JAX compute graphs for the paper's evaluation queries
//!   (Q0–Q6 over NYC-taxi-schema data), AOT-lowered to HLO text at build
//!   time (`make artifacts`).
//! * **L1** — a fused Pallas filter+histogram kernel called by L2.
//!
//! Python never runs at query time: the Rust executors load the HLO
//! artifacts through PJRT (`runtime`) and invoke them on columnar batches.

pub mod bench;
pub mod cli;
pub mod compute;
pub mod config;
pub mod cost;
pub mod data;
pub mod exec;
pub mod metrics;
pub mod plan;
pub mod runtime;
pub mod services;
pub mod simtime;
pub mod sql;
pub mod util;

/// Convenient re-exports for the common driver workflow.
pub mod prelude {
    pub use crate::compute::queries::QueryId;
    pub use crate::config::FlintConfig;
    pub use crate::data::Dataset;
    pub use crate::exec::cluster::{ClusterEngine, ClusterMode};
    pub use crate::exec::flint::FlintEngine;
    pub use crate::exec::session::FlintContext;
    pub use crate::exec::{Engine, QueryReport};
    pub use crate::plan::{Action, Rdd};
    pub use crate::services::SimEnv;
    pub use crate::sql::{SqlError, SqlResult};
}
