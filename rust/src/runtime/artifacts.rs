//! Artifact bundle manifest — the contract between `python/compile/aot.py`
//! (which writes it) and the Rust runtime (which validates against it).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One query artifact's metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryArtifact {
    /// Histogram buckets in the artifact's output shape `[K, 2]`.
    pub buckets: usize,
}

/// `artifacts/manifest.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactManifest {
    /// Static row count every artifact was lowered with.
    pub batch_rows: usize,
    /// jax version that produced the bundle (provenance).
    pub jax_version: String,
    /// Artifact stem → metadata.
    pub queries: BTreeMap<String, QueryArtifact>,
}

impl ArtifactManifest {
    pub fn read(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<ArtifactManifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let batch_rows = j.req_u64("batch_rows").map_err(|e| anyhow!("manifest: {e}"))? as usize;
        let jax_version = j
            .get("jax_version")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        let queries_obj = j
            .get("queries")
            .ok_or_else(|| anyhow!("manifest: missing `queries`"))?;
        let Json::Obj(map) = queries_obj else {
            return Err(anyhow!("manifest: `queries` must be an object"));
        };
        let mut queries = BTreeMap::new();
        for (stem, meta) in map {
            let buckets =
                meta.req_u64("buckets").map_err(|e| anyhow!("manifest {stem}: {e}"))? as usize;
            queries.insert(stem.clone(), QueryArtifact { buckets });
        }
        Ok(ArtifactManifest { batch_rows, jax_version, queries })
    }

    /// All `<stem>.hlo.txt` files that must exist beside the manifest.
    pub fn expected_files(&self) -> Vec<String> {
        self.queries.keys().map(|s| format!("{s}.hlo.txt")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "batch_rows": 8192,
        "jax_version": "0.8.2",
        "queries": {
            "q1_hist": {"buckets": 24},
            "q4_hist": {"buckets": 90}
        }
    }"#;

    #[test]
    fn parses_manifest() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch_rows, 8192);
        assert_eq!(m.jax_version, "0.8.2");
        assert_eq!(m.queries["q1_hist"].buckets, 24);
        assert_eq!(m.queries["q4_hist"].buckets, 90);
        assert_eq!(m.expected_files(), vec!["q1_hist.hlo.txt", "q4_hist.hlo.txt"]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(ArtifactManifest::parse("{}").is_err());
        assert!(ArtifactManifest::parse("not json").is_err());
        assert!(ArtifactManifest::parse(r#"{"batch_rows": 8, "queries": 3}"#).is_err());
        assert!(
            ArtifactManifest::parse(r#"{"batch_rows": 8, "queries": {"x": {}}}"#).is_err(),
            "missing buckets"
        );
    }
}
