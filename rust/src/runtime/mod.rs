//! PJRT runtime — loads the AOT HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Interchange is **HLO text** (not serialized `HloModuleProto`): jax
//! ≥ 0.5 emits protos with 64-bit instruction ids which this image's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! `/opt/xla-example/README.md`). Python runs only at `make artifacts`
//! time; after that the Rust binary is self-contained.
//!
//! The XLA-backed implementation lives behind the `pjrt` cargo feature:
//! it needs the vendored `xla` crate plus the XLA shared libraries,
//! which not every build environment ships. Without the feature this
//! module exports a stub [`PjrtRuntime`] whose `available()` is always
//! false, so engines silently fall back to the native Rust kernels (the
//! exact path unit tests exercise anyway via `flint.use_pjrt = false`).
//!
//! Thread-safety (feature `pjrt`): the `xla` crate's wrappers hold raw
//! pointers and are not `Send`/`Sync` by auto-derivation, but the PJRT
//! C API is specified thread-safe for compilation and execution.
//! `SharedExec` asserts that (and the concurrency tests in `rust/tests/`
//! exercise it).

pub mod artifacts;

pub use artifacts::{ArtifactManifest, QueryArtifact};

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::ArtifactManifest;
    use crate::compute::batch::ColumnBatch;
    use crate::compute::kernels::HistAccum;
    use crate::compute::queries::KernelSpec;
    use anyhow::{anyhow, Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::{Arc, RwLock};

    struct SharedClient(xla::PjRtClient);
    // SAFETY: PJRT clients/executables are documented thread-safe; all
    // mutation happens behind the C API's own synchronization.
    unsafe impl Send for SharedClient {}
    unsafe impl Sync for SharedClient {}

    struct SharedExec(xla::PjRtLoadedExecutable);
    // SAFETY: see SharedClient.
    unsafe impl Send for SharedExec {}
    unsafe impl Sync for SharedExec {}

    /// Loads, caches, and executes the per-query histogram artifacts.
    pub struct PjrtRuntime {
        client: SharedClient,
        dir: PathBuf,
        manifest: ArtifactManifest,
        execs: RwLock<HashMap<String, Arc<SharedExec>>>,
    }

    impl PjrtRuntime {
        /// True when `dir` holds a usable artifact bundle (manifest present).
        pub fn available(dir: &str) -> bool {
            Path::new(dir).join("manifest.json").is_file()
        }

        /// Open the artifact bundle and start a CPU PJRT client.
        pub fn open(dir: &str) -> Result<PjrtRuntime> {
            let manifest = ArtifactManifest::read(Path::new(dir))
                .with_context(|| format!("reading artifact manifest in {dir}"))?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
            Ok(PjrtRuntime {
                client: SharedClient(client),
                dir: PathBuf::from(dir),
                manifest,
                execs: RwLock::new(HashMap::new()),
            })
        }

        pub fn manifest(&self) -> &ArtifactManifest {
            &self.manifest
        }

        /// Static batch row count the artifacts were lowered with.
        pub fn batch_rows(&self) -> usize {
            self.manifest.batch_rows
        }

        /// Whether the manifest carries a matching artifact for `spec`.
        /// Extension queries (e.g. Q6J's day-keyed scan) may not be
        /// AOT-lowered; callers fall back to the native kernel.
        pub fn supports(&self, spec: &KernelSpec) -> bool {
            self.manifest
                .queries
                .get(&spec.artifact_stem())
                .map(|a| a.buckets == spec.buckets)
                .unwrap_or(false)
        }

        fn executable(&self, stem: &str) -> Result<Arc<SharedExec>> {
            if let Some(e) = self.execs.read().expect("exec cache").get(stem) {
                return Ok(Arc::clone(e));
            }
            let path = self.dir.join(format!("{stem}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .0
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {stem}: {e:?}"))?;
            let exe = Arc::new(SharedExec(exe));
            self.execs
                .write()
                .expect("exec cache")
                .insert(stem.to_string(), Arc::clone(&exe));
            Ok(exe)
        }

        /// Pre-compile every artifact in the manifest (done once at engine
        /// startup so compilation never lands on the query path).
        pub fn warmup(&self) -> Result<()> {
            let stems: Vec<String> = self.manifest.queries.keys().cloned().collect();
            for stem in stems {
                self.executable(&stem)?;
            }
            Ok(())
        }

        /// Run the fused filter+histogram artifact for `spec` over a padded
        /// batch with prepared keys/values, merging the result into `accum`.
        ///
        /// The artifact's signature (see `python/compile/model.py`) is
        /// `(lon f32[B], lat f32[B], tip f32[B], key i32[B], val f32[B])
        /// -> (hist f32[K,2],)` where `hist[k] = (Σ val, Σ 1)` over rows that
        /// pass the query's baked-in geo/tip filter and have key == k.
        pub fn run_hist(
            &self,
            spec: &KernelSpec,
            batch: &ColumnBatch,
            keys: &[i32],
            values: &[f32],
            accum: &mut HistAccum,
        ) -> Result<()> {
            let stem = spec.artifact_stem();
            let art = self
                .manifest
                .queries
                .get(&stem)
                .ok_or_else(|| anyhow!("artifact {stem} missing from manifest"))?;
            let b = self.manifest.batch_rows;
            if batch.lon.len() != b || keys.len() != b || values.len() != b {
                return Err(anyhow!(
                    "batch not padded to artifact rows: got {}, artifact wants {b}",
                    batch.lon.len()
                ));
            }
            if art.buckets != spec.buckets {
                return Err(anyhow!(
                    "artifact {stem} has {} buckets, spec wants {}",
                    art.buckets,
                    spec.buckets
                ));
            }
            let exe = self.executable(&stem)?;
            let args = [
                xla::Literal::vec1(&batch.lon),
                xla::Literal::vec1(&batch.lat),
                xla::Literal::vec1(&batch.tip),
                xla::Literal::vec1(keys),
                xla::Literal::vec1(values),
            ];
            let result = exe.0.execute(&args).map_err(|e| anyhow!("execute {stem}: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result {stem}: {e:?}"))?;
            let hist = lit
                .to_tuple1()
                .map_err(|e| anyhow!("untuple {stem}: {e:?}"))?
                .to_vec::<f32>()
                .map_err(|e| anyhow!("read result {stem}: {e:?}"))?;
            if hist.len() != spec.buckets * 2 {
                return Err(anyhow!(
                    "artifact {stem} returned {} values, want {}",
                    hist.len(),
                    spec.buckets * 2
                ));
            }
            // hist layout: [K, 2] row-major = (sum, count) per bucket.
            for k in 0..spec.buckets {
                accum.sums[k] += hist[k * 2] as f64;
                accum.counts[k] += hist[k * 2 + 1] as f64;
            }
            accum.rows_seen += batch.len as u64;
            Ok(())
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::PjrtRuntime;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::ArtifactManifest;
    use crate::compute::batch::ColumnBatch;
    use crate::compute::kernels::HistAccum;
    use crate::compute::queries::KernelSpec;
    use anyhow::{anyhow, Result};

    /// Stub runtime for builds without the `pjrt` feature: never reports
    /// artifacts as available, so every caller takes the native-kernel
    /// fallback. The API mirrors the real runtime exactly.
    pub struct PjrtRuntime {
        manifest: ArtifactManifest,
    }

    impl PjrtRuntime {
        /// Always false: without the `pjrt` feature no artifact can run.
        pub fn available(_dir: &str) -> bool {
            false
        }

        pub fn open(dir: &str) -> Result<PjrtRuntime> {
            Err(anyhow!(
                "flint was built without the `pjrt` feature; cannot open artifacts in {dir}"
            ))
        }

        pub fn manifest(&self) -> &ArtifactManifest {
            &self.manifest
        }

        pub fn batch_rows(&self) -> usize {
            self.manifest.batch_rows
        }

        /// Always false: the stub cannot execute any artifact.
        pub fn supports(&self, _spec: &KernelSpec) -> bool {
            false
        }

        pub fn warmup(&self) -> Result<()> {
            Ok(())
        }

        pub fn run_hist(
            &self,
            _spec: &KernelSpec,
            _batch: &ColumnBatch,
            _keys: &[i32],
            _values: &[f32],
            _accum: &mut HistAccum,
        ) -> Result<()> {
            Err(anyhow!("PJRT disabled at build time (enable the `pjrt` feature)"))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtRuntime;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn available_is_false_for_missing_dir() {
        assert!(!PjrtRuntime::available("/definitely/not/here"));
    }

    #[test]
    fn open_fails_cleanly_without_manifest() {
        let Err(err) = PjrtRuntime::open("/tmp/flint-no-artifacts-here") else {
            panic!("open must fail without a manifest")
        };
        let msg = format!("{err:#}");
        assert!(
            msg.contains("manifest") || msg.contains("pjrt"),
            "unexpected error: {msg}"
        );
    }
}
