//! `flint` — the command-line launcher.
//!
//! ```text
//! flint gen      --trips 1000000                      generate a dataset (stats only)
//! flint run      --query Q1 [--engine flint|spark|pyspark] [--trips N]
//! flint explain  --query Q1 [--no-run] [--generic]    print the stage DAG + its barrier/pipelined schedule windows
//! flint table1   [--trips N] [--trials N] [--paper]   regenerate Table I
//! flint micro    --bench s3|coldstart|shuffle         the in-text microbenchmarks
//! flint sql      "<query>" [--trips N]                run SQL (or EXPLAIN SELECT …)
//! flint config   [--config file.toml] [--set k=v]...  print the effective config
//! ```
//!
//! Every command accepts `--config <toml>` and repeated `--set key=value`.
//! Queries are Q0..Q6 plus Q6J, the shuffle-join variant of Q6.
//! `flint explain --generic` builds Q1 as a *generic lineage* through
//! the session API (`FlintContext::text_file` → map/filter/map →
//! reduceByKey) and shows what the general lineage→DAG compiler
//! (`plan::lower`) makes of it, instead of the typed kernel plan.
//! `flint explain --query Q6J` renders the join diamond — two scan
//! stages (trips, weather) fanning into a `KernelJoin` stage and a
//! final per-bucket reduce:
//!
//! ```text
//!   stage 0: [s3 xN]   -> KernelScan(Q6J)   -> Shuffle(30) (N tasks)
//!   stage 1: [s3 x1]   -> DynScan(1 ops)    -> Shuffle(30) (1 tasks)
//!   stage 2: [sqs x30] -> KernelJoin(Q6J)   -> Shuffle(6)  (30 tasks)  <- s0, s1
//!   stage 3: [sqs x6]  -> KernelReduce(Q6J) -> Act(Collect) (6 tasks)  <- s2
//! ```
//!
//! followed by the barrier/pipelined schedule windows (under the
//! pipelined clock the two scans overlap each other and the join
//! long-polls both of them) and the per-edge shuffle volumes
//! (`edge s0->s2`, `edge s1->s2`, `edge s2->s3`).

use flint::bench::{run_table1, Table1Options};
use flint::cli::Args;
use flint::compute::queries::QueryId;
use flint::config::FlintConfig;
use flint::data::generate_taxi_dataset;
use flint::exec::{ClusterEngine, ClusterMode, Engine, FlintEngine};
use flint::plan::PhysicalPlan;
use flint::services::SimEnv;
use flint::simtime::StageWindow;
use flint::util::{human_bytes, human_duration};

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> Result<FlintConfig, String> {
    let overrides = args.overrides()?;
    let mut cfg = match args.get("config") {
        Some(path) => FlintConfig::load(path, &overrides)?,
        None => {
            let mut cfg = FlintConfig::default();
            for (k, v) in &overrides {
                cfg.set(k, v)?;
            }
            cfg
        }
    };
    if cfg.artifacts_dir.is_empty() {
        cfg.artifacts_dir = "artifacts".to_string();
    }
    Ok(cfg)
}

fn real_main() -> Result<(), String> {
    let args = Args::from_env()?;
    let cfg = load_config(&args)?;
    // Only `sql` takes positional operands (the query text).
    if args.command.as_deref() != Some("sql") {
        if let Some(stray) = args.positional.first() {
            return Err(format!("unexpected positional argument `{stray}`"));
        }
    }
    match args.command.as_deref() {
        Some("gen") => cmd_gen(&args, cfg),
        Some("run") => cmd_run(&args, cfg),
        Some("explain") => cmd_explain(&args, cfg),
        Some("table1") => cmd_table1(&args, cfg),
        Some("micro") => cmd_micro(&args, cfg),
        Some("sql") => cmd_sql(&args, cfg),
        Some("config") => {
            println!("{}", cfg.to_json().encode());
            Ok(())
        }
        Some(other) => Err(format!(
            "unknown command `{other}` (try: gen run explain table1 micro sql config)"
        )),
        None => {
            println!("flint — serverless data analytics (Kim & Lin 2018, reproduced)");
            println!("commands: gen | run | explain | table1 | micro | sql | config");
            Ok(())
        }
    }
}

fn parse_query(args: &Args) -> Result<QueryId, String> {
    let name = args.get("query").unwrap_or("Q1");
    QueryId::parse(name).ok_or_else(|| format!("unknown query `{name}` (Q0..Q6, Q6J)"))
}

fn cmd_gen(args: &Args, cfg: FlintConfig) -> Result<(), String> {
    let trips = args.get_parsed("trips", cfg.data.trips)?;
    let env = SimEnv::new(cfg);
    let t0 = std::time::Instant::now();
    let ds = generate_taxi_dataset(&env, "trips", trips);
    println!(
        "generated {} trips, {} objects, {} in {:.1}s (seed {})",
        ds.trips,
        ds.num_objects(),
        human_bytes(ds.total_bytes),
        t0.elapsed().as_secs_f64(),
        ds.seed
    );
    Ok(())
}

fn cmd_run(args: &Args, cfg: FlintConfig) -> Result<(), String> {
    let query = parse_query(args)?;
    let trips = args.get_parsed("trips", cfg.data.trips)?;
    let engine_name = args.get("engine").unwrap_or("flint").to_string();
    let env = SimEnv::new(cfg);
    eprintln!("generating {trips} trips...");
    let ds = generate_taxi_dataset(&env, "trips", trips);
    let report = match engine_name.as_str() {
        "flint" => {
            let e = FlintEngine::new(env.clone());
            if args.flag("prewarm") {
                e.prewarm();
            }
            e.run_query(query, &ds)
        }
        "spark" => ClusterEngine::new(env.clone(), ClusterMode::Spark).run_query(query, &ds),
        "pyspark" => ClusterEngine::new(env.clone(), ClusterMode::PySpark).run_query(query, &ds),
        other => return Err(format!("unknown engine `{other}`")),
    }
    .map_err(|e| format!("{e:#}"))?;
    println!("{}", report.summary());
    println!("\n{}", report.result.render(query));
    println!("virtual latency: {}", human_duration(report.latency_s));
    println!("time breakdown (per-task sum): {}", report.timeline);
    println!("cost: {}", report.cost);
    if report.speculative_launches > 0 {
        println!(
            "speculation: {} backup(s), {} won (pipelined {:.2}s vs {:.2}s without)",
            report.speculative_launches,
            report.speculative_wins,
            report.pipelined_latency_s,
            report.pipelined_nospec_latency_s
        );
    }
    Ok(())
}

fn cmd_explain(args: &Args, cfg: FlintConfig) -> Result<(), String> {
    let query = parse_query(args)?;
    let trips = args.get_parsed("trips", 50_000u64)?;
    let env = SimEnv::new(cfg.clone());
    let ds = generate_taxi_dataset(&env, "trips", trips);
    let plan = if args.flag("generic") {
        // The session-API route: the same query as a generic lineage,
        // compiled by the general lineage→DAG compiler. Only Q1 has a
        // hand-written generic form.
        if !matches!(query, QueryId::Q1) {
            return Err(format!(
                "explain --generic only supports Q1 (got {query}); drop --generic \
                 for the typed kernel plan"
            ));
        }
        let sc = flint::exec::FlintContext::new(env.clone());
        sc.lower(&generic_q1_lineage(&sc), flint::plan::Action::Collect)
    } else {
        flint::plan::kernel_plan(query, &ds, &cfg)
    };
    println!("{}", plan.explain());
    if args.flag("no-run") {
        return Ok(());
    }
    // Execute the *printed* plan once: the driver computes both the
    // barrier and pipelined clocks from the same measured task
    // durations, showing how barrier stages serialize while pipelined
    // stages overlap (§III-A).
    let engine = FlintEngine::new(env.clone());
    engine.prewarm();
    let report = engine.run_plan(&plan).map_err(|e| format!("{e:#}"))?;
    println!(
        "{}",
        render_schedule("barrier", &plan, &report.barrier_windows, report.barrier_latency_s)
    );
    if matches!(cfg.flint.shuffle_backend, flint::config::ShuffleBackend::S3) {
        // The engine forces barrier for the S3 backend (list-then-get
        // cannot overlap); don't render a schedule it will never use.
        println!("(s3 shuffle backend: pipelined scheduling not applicable)\n");
    } else {
        println!(
            "{}",
            render_schedule(
                "pipelined",
                &plan,
                &report.pipelined_windows,
                report.pipelined_latency_s
            )
        );
    }
    // Deterministic printout: edges in (from, to) order whatever order
    // the report carries them in.
    let mut edges = report.edge_shuffle.clone();
    edges.sort_by_key(|e| (e.from, e.to));
    for e in &edges {
        println!(
            "edge s{}->s{}: {} shuffle msgs, {} record bytes",
            e.from, e.to, e.msgs, e.bytes
        );
    }
    // The latency-vs-cost trade the overlap (and speculation) buys:
    // long-polling reducers bill GB-seconds while idle, and every
    // speculative attempt bills even when it loses the race.
    if report.pipelined_idle_s > 0.0 {
        println!(
            "pipelined long-poll idle: {:.2}s of occupied-but-idle Lambda time (billed as GB-seconds when pipelined is selected)",
            report.pipelined_idle_s
        );
    }
    if report.speculative_launches > 0 {
        println!(
            "speculation: {} backup attempt(s) launched, {} won — pipelined {:.2}s vs {:.2}s without backups",
            report.speculative_launches,
            report.speculative_wins,
            report.pipelined_latency_s,
            report.pipelined_nospec_latency_s
        );
    }
    Ok(())
}

/// The paper's §IV Q1 driver program as a generic session-API lineage
/// (`flint explain --generic` compiles and runs this instead of the
/// typed kernel plan).
fn generic_q1_lineage(sc: &flint::exec::FlintContext) -> flint::plan::Rdd {
    use flint::compute::value::Value;
    use flint::data::schema::{TripRecord, GOLDMAN};
    sc.text_file(flint::data::INPUT_BUCKET, "trips/")
        .flat_map(|line| {
            let Some(text) = line.as_str() else { return Vec::new() };
            let Some(r) = TripRecord::parse_csv(text.as_bytes()) else { return Vec::new() };
            if !GOLDMAN.contains(r.dropoff_lon, r.dropoff_lat) {
                return Vec::new();
            }
            vec![Value::pair(
                Value::I64(flint::data::chrono::hour_of_day(r.dropoff_ts) as i64),
                Value::I64(1),
            )]
        })
        .reduce_by_key(30, |a, b| Value::I64(a.as_i64().unwrap() + b.as_i64().unwrap()))
}

/// Render per-stage start/end windows (and parent overlap) on the
/// global virtual clock.
fn render_schedule(
    label: &str,
    plan: &PhysicalPlan,
    windows: &[StageWindow],
    total_s: f64,
) -> String {
    let mut out = format!("schedule ({label}): total {total_s:.2}s\n");
    for w in windows {
        let stage = plan.stage(w.id);
        let deps = if stage.parents.is_empty() {
            String::new()
        } else {
            format!(
                " <- {}",
                stage
                    .parents
                    .iter()
                    .map(|p| format!("s{p}"))
                    .collect::<Vec<_>>()
                    .join(",")
            )
        };
        let mut overlap = String::new();
        for &p in &stage.parents {
            let o = w.overlap_s(&windows[p as usize]);
            if o > 0.0 {
                overlap.push_str(&format!("  (overlaps s{p} by {o:.2}s)"));
            }
        }
        out.push_str(&format!(
            "  stage {}{deps}: {:8.2}s .. {:8.2}s  [{} tasks]{overlap}\n",
            w.id,
            w.start,
            w.end,
            w.tasks.len()
        ));
    }
    out
}

fn cmd_table1(args: &Args, cfg: FlintConfig) -> Result<(), String> {
    let queries = match args.get("queries") {
        None => QueryId::ALL.to_vec(),
        Some(list) => list
            .split(',')
            .map(|q| QueryId::parse(q).ok_or_else(|| format!("unknown query `{q}`")))
            .collect::<Result<Vec<_>, _>>()?,
    };
    let opts = Table1Options {
        trips: args.get_parsed("trips", cfg.data.trips)?,
        trials_flint: args.get_parsed("trials", 5usize)?,
        trials_cluster: args.get_parsed("cluster-trials", 3usize)?,
        queries,
        paper_scale: !args.flag("no-paper"),
    };
    eprintln!("table1: {} trips, {} flint trials", opts.trips, opts.trials_flint);
    let (ds, rows) = run_table1(&cfg, &opts).map_err(|e| format!("{e:#}"))?;
    println!(
        "dataset: {} trips, {} ({} objects)\n",
        ds.trips,
        human_bytes(ds.total_bytes),
        ds.num_objects()
    );
    println!("{}", flint::bench::table1::render_measured(&rows));
    if opts.paper_scale {
        println!("{}", flint::bench::table1::render_paper_scale(&rows));
    }
    Ok(())
}

/// `flint sql "<query>"` — generate the dataset, register its manifest
/// (so the planner sees table sizes and scan pruning sees per-object
/// stats), then run the query on a serverless session. `EXPLAIN
/// SELECT …` prints the plan pipeline instead of executing.
fn cmd_sql(args: &Args, cfg: FlintConfig) -> Result<(), String> {
    let text = match args.positional.as_slice() {
        [one] => one.clone(),
        [] => return Err("sql: expected a query, e.g. flint sql \"SELECT COUNT(*) FROM trips\"".into()),
        many => many.join(" "), // unquoted queries arrive as many operands
    };
    let trips = args.get_parsed("trips", cfg.data.trips)?;
    let env = SimEnv::new(cfg);
    eprintln!("generating {trips} trips...");
    let ds = generate_taxi_dataset(&env, "trips", trips);
    let sc = flint::exec::FlintContext::new(env);
    sc.register_manifest(&ds);
    let result = sc.sql(&text).map_err(|e| format!("{e:#}"))?;
    if result.columns == ["plan"] {
        // EXPLAIN: the rows are the plan rendering, print them bare.
        for row in &result.rows {
            if let Some(flint::compute::value::Value::Str(line)) = row.first() {
                println!("{line}");
            }
        }
    } else {
        print!("{}", result.render());
        println!("({} rows)", result.rows.len());
    }
    Ok(())
}

fn cmd_micro(args: &Args, cfg: FlintConfig) -> Result<(), String> {
    let which = args.get("bench").unwrap_or("s3");
    match which {
        "s3" => {
            let (f, s) =
                flint::bench::micro::s3_throughput(&cfg, 256).map_err(|e| format!("{e:#}"))?;
            println!(
                "single-stream S3 read: flint/boto {f:.1} MB/s, spark/hadoop {s:.1} MB/s ({:.2}x)",
                f / s
            );
        }
        "coldstart" => {
            let (cold, warm, chained, unchained, links) =
                flint::bench::micro::cold_warm_chain(&cfg, 100_000)
                    .map_err(|e| format!("{e:#}"))?;
            println!("Q0 cold-pool: {:.2}s | warm: {:.2}s", cold, warm);
            println!(
                "Q1 chained ({links} links): {:.2}s vs unchained {:.2}s ({:+.1}%)",
                chained,
                unchained,
                (chained / unchained - 1.0) * 100.0
            );
        }
        "shuffle" => {
            let rows = flint::bench::micro::shuffle_ablation(&cfg, 200_000, QueryId::Q5)
                .map_err(|e| format!("{e:#}"))?;
            for (name, lat, cost, msgs) in rows {
                println!("{name:6} shuffle: {lat:8.2}s  ${cost:.4}  {msgs} msgs");
            }
        }
        "elasticity" => {
            let rows = flint::bench::micro::elasticity_sweep(
                &cfg,
                400_000,
                QueryId::Q1,
                &[20, 40, 80, 160, 320],
            )
            .map_err(|e| format!("{e:#}"))?;
            println!("Q1, 400k trips — the pay-as-you-go curve:");
            for (slots, lat, cost) in rows {
                println!("  concurrency {slots:4}: {lat:7.2}s  ${cost:.4}");
            }
        }
        other => {
            return Err(format!(
                "unknown micro bench `{other}` (s3|coldstart|shuffle|elasticity)"
            ))
        }
    }
    Ok(())
}
